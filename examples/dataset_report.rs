//! Dataset characterization (the paper's §3 analysis on OUR datasets):
//! n-gram redundancy, entropy under three tokenizations, mutual
//! information, and baseline compressibility — for one LLM-generated
//! dataset, its human counterpart, and TPC-H comments.
//!
//! ```sh
//! cargo run --release --example dataset_report            # wiki
//! cargo run --release --example dataset_report -- code    # other domain
//! ```

use llmzip::analysis::{self, EntropyReport};
use llmzip::compress::registry::all_baselines;
use llmzip::experiments::{human_text, llm_dataset};
use llmzip::runtime::ArtifactStore;
use llmzip::textgen::Domain;

fn report(label: &str, data: &[u8]) {
    let text = String::from_utf8_lossy(data).into_owned();
    let e = EntropyReport::measure(&text);
    let ng = analysis::top_k_share(&text, 10);
    println!("\n--- {label} ({} bytes) ---", data.len());
    println!("entropy/byte   char {:.2}  bpe {:.2}  word {:.2}", e.char_e, e.bpe_e, e.word_e);
    println!("mutual info    {:.2} bits", e.mutual_info);
    println!(
        "top-10 n-grams 1g {:.1}%  2g {:.1}%  3g {:.1}%  4g {:.1}%",
        ng[0] * 100.0, ng[1] * 100.0, ng[2] * 100.0, ng[3] * 100.0
    );
    print!("baselines      ");
    for c in all_baselines().expect("baseline registry") {
        let z = c.compress(data).expect("compress");
        print!("{} {:.2}x  ", c.name(), data.len() as f64 / z.len() as f64);
    }
    println!();
}

fn main() -> llmzip::Result<()> {
    let domain = std::env::args()
        .nth(1)
        .map(|d| Domain::from_name(&d))
        .transpose()?
        .unwrap_or(Domain::Wiki);
    let bytes = 48 * 1024;

    let store = ArtifactStore::open(None)?;
    let llm = llm_dataset(&store, "data", "teacher", domain, bytes)?;
    report(&format!("LLM-generated {} (teacher, temp 0.6)", domain.name()), &llm);
    report(&format!("human {} (held-out procedural)", domain.name()), &human_text(domain, bytes));
    report("TPC-H comments", &human_text(Domain::Tpch, bytes));
    Ok(())
}
