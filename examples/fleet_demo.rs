//! Fleet smoke driver (CI's multi-model E2E): host two model pools — nano
//! f32/range and nano int8/fse — behind one TCP endpoint, run mixed-tenant
//! clients against both over the multiplexed wire protocol, cross-check
//! every container against the direct single-compressor path, and
//! demonstrate that load shedding surfaces as a clean wire error.
//!
//! ```sh
//! cargo run --release --example fleet_demo
//! ```
//!
//! No artifacts needed: both pools run the native nano engine on
//! deterministic random weights.

use llmzip::compress::{Codec, Compressor, LlmCompressor, LlmCompressorConfig};
use llmzip::coordinator::wire::serve_connection;
use llmzip::coordinator::{
    BatchPolicy, FleetConfig, FleetModelSpec, FleetServer, MuxClient, ServerConfig, TenantSpec,
};
use llmzip::lm::config::by_name;
use llmzip::lm::weights::Weights;
use llmzip::lm::{ExecutorKind, Precision};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

const CHUNK: usize = 128;

fn compressor_cfg(precision: Precision, codec: Codec) -> LlmCompressorConfig {
    LlmCompressorConfig {
        model: "nano".into(),
        chunk_tokens: CHUNK,
        stream_bytes: 512,
        executor: ExecutorKind::Native,
        lanes: 4,
        threads: 1,
        precision,
        codec,
        ..Default::default()
    }
}

fn spec(key: &str, precision: Precision, codec: Codec, seed: u64) -> FleetModelSpec {
    FleetModelSpec {
        key: key.to_string(),
        compressor: compressor_cfg(precision, codec),
        server: ServerConfig {
            chunk_tokens: CHUNK,
            codec,
            policy: BatchPolicy { lanes: 4, max_wait: Duration::from_millis(3) },
            ..Default::default()
        },
        load: Arc::new(move || Ok(Weights::random(by_name("nano")?, seed))),
    }
}

fn direct(precision: Precision, codec: Codec, seed: u64) -> llmzip::Result<LlmCompressor> {
    let cfg = by_name("nano")?;
    let weights = Weights::random(cfg, seed);
    let weights =
        if precision == Precision::Int8 { Arc::new(weights.quantize()) } else { Arc::new(weights) };
    LlmCompressor::from_shared(cfg, weights, compressor_cfg(precision, codec))
}

fn main() -> llmzip::Result<()> {
    println!("starting fleet: nano-f32 (f32/range) + nano-int8 (int8/fse), tenants alice:3 bob:1");
    let fleet = Arc::new(FleetServer::start(
        vec![
            spec("nano-f32", Precision::F32, Codec::Range, 7),
            spec("nano-int8", Precision::Int8, Codec::Fse, 8),
        ],
        FleetConfig {
            max_inflight: 16,
            tenants: vec![
                TenantSpec {
                    name: "alice".into(),
                    weight: 3,
                    rate_bytes_per_sec: 0.0,
                    burst_bytes: 0.0,
                },
                TenantSpec {
                    name: "bob".into(),
                    weight: 1,
                    rate_bytes_per_sec: 0.0,
                    burst_bytes: 0.0,
                },
            ],
            ..Default::default()
        },
    )?);

    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    {
        let fleet = fleet.clone();
        std::thread::spawn(move || {
            while let Ok((stream, _)) = listener.accept() {
                let fl = fleet.clone();
                std::thread::spawn(move || {
                    let _ = serve_connection(stream, &*fl);
                });
            }
        });
    }
    println!("fleet listening on {addr} (models: {})", fleet.model_keys().join(", "));

    // Reference compressors: the bytes every fleet response must equal.
    let direct_f32 = direct(Precision::F32, Codec::Range, 7)?;
    let direct_int8 = direct(Precision::Int8, Codec::Fse, 8)?;

    // Mixed-tenant mixed-model clients over one multiplexed connection
    // each: alice on f32, bob on int8, both checked bit-for-bit.
    let mut totals = Vec::new();
    for (tenant, key, seed) in
        [("alice", "nano-f32", 41u64), ("bob", "nano-int8", 42), ("alice", "nano-int8", 43)]
    {
        let mut client = MuxClient::connect(&addr)?;
        client.set_tenant(tenant)?;
        let data = llmzip::textgen::quick_sample(1500, seed);
        let id = client.submit_compress_tagged(key, &data, false)?;
        let (rid, result) = client.recv()?;
        if rid != id {
            anyhow::bail!("response id mismatch");
        }
        let z = result?;
        let golden = if key == "nano-int8" {
            direct_int8.compress(&data)?
        } else {
            direct_f32.compress(&data)?
        };
        if z != golden {
            anyhow::bail!("fleet container differs from direct path on {key}");
        }
        // Unrouted decompress: the container's own tag picks the pool.
        let did = client.submit_decompress(&z)?;
        let (rid, back) = client.recv()?;
        if rid != did {
            anyhow::bail!("response id mismatch");
        }
        if back? != data {
            anyhow::bail!("roundtrip mismatch");
        }
        println!(
            "tenant {tenant:<5} model {key:<9} {} bytes -> {} bytes, matches direct path",
            data.len(),
            z.len()
        );
        totals.push((key, data.len(), z.len()));
    }
    println!("cross-decode ok: every container routed home by its own tag");

    // Streaming upload routed by key, equal to the one-shot container.
    let mut client = MuxClient::connect(&addr)?;
    client.set_tenant("alice")?;
    let data = llmzip::textgen::quick_sample(2000, 44);
    let sid = client.open_stream_for("nano-int8")?;
    for piece in data.chunks(357) {
        client.stream_chunk(sid, piece)?;
    }
    client.stream_finish(sid)?;
    let (rid, result) = client.recv()?;
    if rid != sid {
        anyhow::bail!("response id mismatch");
    }
    if result? != direct_int8.compress(&data)? {
        anyhow::bail!("stream differs from one-shot");
    }
    println!("tenant alice streamed {} bytes to nano-int8, matches one-shot", data.len());

    // Load shedding: a 1-slot fleet with its slot pinned by an open stream
    // must refuse the next request with a clean wire error — not a hang.
    let capped = Arc::new(FleetServer::start(
        vec![spec("nano-f32", Precision::F32, Codec::Range, 7)],
        FleetConfig { max_inflight: 1, ..Default::default() },
    )?);
    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let capped_addr = listener.local_addr()?.to_string();
    {
        let capped = capped.clone();
        std::thread::spawn(move || {
            while let Ok((stream, _)) = listener.accept() {
                let fl = capped.clone();
                std::thread::spawn(move || {
                    let _ = serve_connection(stream, &*fl);
                });
            }
        });
    }
    let mut client = MuxClient::connect(&capped_addr)?;
    let small = llmzip::textgen::quick_sample(400, 45);
    let sid = client.open_stream_for("nano-f32")?;
    client.stream_chunk(sid, &small)?;
    let shed_id = client.submit_compress_tagged("nano-f32", &small, false)?;
    let (rid, result) = client.recv()?;
    if rid != shed_id {
        anyhow::bail!("shed response must come back first");
    }
    let err = result.expect_err("over-cap request must be refused");
    println!("load shed surfaced as clean wire error: {err:#}");
    client.stream_finish(sid)?;
    let (rid, result) = client.recv()?;
    if rid != sid || result.is_err() {
        anyhow::bail!("pinned stream must still complete");
    }
    println!(
        "fleet metrics: shed={} rate_limited={} page_outs={} page_ins={}",
        capped.metrics.shed.load(Ordering::Relaxed),
        capped.metrics.rate_limited.load(Ordering::Relaxed),
        fleet.metrics.page_outs.load(Ordering::Relaxed),
        fleet.metrics.page_ins.load(Ordering::Relaxed),
    );

    for (key, raw, z) in totals {
        println!("summary {key:<9} ratio {:.3}", z as f64 / raw as f64);
    }
    println!("fleet demo ok");
    Ok(())
}
