//! Walk the model ladder (the paper's Fig 5/6 in miniature): compress one
//! LLM-generated dataset with every registered model and watch the ratio
//! climb with scale — and the domain specialists win inside their domain.
//!
//! ```sh
//! cargo run --release --example model_ladder
//! ```

use llmzip::compress::{Compressor, LlmCompressor, LlmCompressorConfig};
use llmzip::experiments::llm_dataset;
use llmzip::lm::config::MODELS;
use llmzip::lm::ExecutorKind;
use llmzip::runtime::ArtifactStore;
use llmzip::textgen::Domain;
use std::time::Instant;

fn main() -> llmzip::Result<()> {
    let store = ArtifactStore::open(None)?;
    let bytes = 24 * 1024;
    let wiki = llm_dataset(&store, "data", "teacher", Domain::Wiki, bytes)?;
    let math = llm_dataset(&store, "data", "teacher", Domain::Math, bytes)?;

    println!("{:<18} {:>9} {:>10} {:>10} {:>9}", "MODEL", "PARAMS", "WIKI", "MATH", "SPEED");
    for m in &MODELS {
        let comp = LlmCompressor::open(
            &store,
            LlmCompressorConfig {
                model: m.name.into(),
                chunk_tokens: 256,
                stream_bytes: 4096,
                executor: ExecutorKind::PjrtForward,
                ..Default::default()
            },
        )?;
        let t0 = Instant::now();
        let zw = comp.compress(&wiki)?;
        let dt = t0.elapsed().as_secs_f64();
        let zm = comp.compress(&math)?;
        println!(
            "{:<18} {:>8}K {:>9.2}x {:>9.2}x {:>7.1}K/s",
            m.name,
            m.param_count() / 1000,
            wiki.len() as f64 / zw.len() as f64,
            math.len() as f64 / zm.len() as f64,
            wiki.len() as f64 / 1024.0 / dt,
        );
    }
    println!("\n(expected shape: ratio rises with params; small-math beats small on MATH)");
    Ok(())
}
