//! Quickstart: compress LLM-generated text with the LLM compressor and see
//! why the paper's headline holds — the same bytes barely move under gzip.
//!
//! Run after `make artifacts`:
//! ```sh
//! cargo run --release --example quickstart
//! ```

use llmzip::compress::{baseline_by_name, Compressor, LlmCompressor, LlmCompressorConfig};
use llmzip::lm::ExecutorKind;
use llmzip::runtime::ArtifactStore;
use llmzip::sampling::DatasetFactory;
use llmzip::textgen::Domain;

fn main() -> llmzip::Result<()> {
    let store = ArtifactStore::open(None)?;

    // 1. Produce some genuinely LLM-generated text (temperature sampling
    //    from the trained `medium` model, conditioned on the wiki domain).
    let factory = DatasetFactory::from_store(&store, "teacher")?;
    let text = factory.generate_dataset(Domain::Wiki, 8 * 1024, 0.8, 7)?;
    println!("generated {} bytes of LLM text; first line:", text.len());
    let first = text.split(|&b| b == b'\n').next().unwrap_or(&text);
    println!("  {}\n", String::from_utf8_lossy(&first[..first.len().min(100)]));

    // 2. Compress with the paper's method: next-token prediction feeding an
    //    arithmetic coder.
    let llm = LlmCompressor::open(
        &store,
        LlmCompressorConfig {
            model: "medium".into(),
            chunk_tokens: 256,
            stream_bytes: 4096,
            executor: ExecutorKind::PjrtForward,
            ..Default::default()
        },
    )?;
    let z = llm.compress(&text)?;
    println!("llm compressor : {} -> {} bytes  ({:.2}x)", text.len(), z.len(),
        text.len() as f64 / z.len() as f64);

    // 3. Baselines for contrast.
    for name in ["gzip", "lzma", "zstd"] {
        let c = baseline_by_name(name)?;
        let zb = c.compress(&text)?;
        println!("{:<15}: {} -> {} bytes  ({:.2}x)", name, text.len(), zb.len(),
            text.len() as f64 / zb.len() as f64);
    }

    // 4. Losslessness is verified, not assumed (CRC in the container).
    let back = llm.decompress(&z)?;
    assert_eq!(back, text);
    println!("\ndecompressed and CRC-verified: lossless ✓");

    // 5. Streaming: LLM output is a token stream, and the API has the
    //    same shape. `CompressWriter` implements std::io::Write — feed it
    //    bytes as they are generated (here: 1 KiB at a time), and framed
    //    container chunks flush incrementally with bounded memory. The
    //    result is byte-identical to the one-shot call above.
    use std::io::{Read, Write};
    let mut writer = llm.stream_compress(Vec::new())?;
    for piece in text.chunks(1024) {
        writer.write_all(piece)?;
    }
    let (streamed, summary) = writer.finish()?;
    assert_eq!(streamed, z, "streaming emits the identical container");
    println!(
        "streamed {} bytes -> {} container bytes in {} chunks (identical to one-shot ✓)",
        summary.bytes_in, summary.bytes_out, summary.chunks
    );

    //    Decode side: `DecompressReader` implements std::io::Read and
    //    verifies the CRC when it reaches the trailer...
    let mut reader = llm.stream_decompress(&streamed[..])?;
    let mut round = Vec::new();
    reader.read_to_end(&mut round)?;
    assert_eq!(round, text);

    //    ...and the v2 container's trailer index gives random access:
    //    decode 100 bytes from the middle without touching the rest.
    let mid = text.len() as u64 / 2;
    let slice = llm.decompress_range(&streamed, mid, 100)?;
    assert_eq!(slice, &text[mid as usize..mid as usize + 100]);
    println!("random-access decode of [{mid}, {mid}+100): exact ✓");
    Ok(())
}
