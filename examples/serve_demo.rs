//! End-to-end serving driver (the repository's E2E validation run): start
//! the batched compression service, fire concurrent client workloads at it,
//! and report latency/throughput plus coordinator metrics — the serving-
//! system view of the paper's compressor.
//!
//! ```sh
//! cargo run --release --example serve_demo            # PJRT engine
//! cargo run --release --example serve_demo -- native  # no artifacts needed
//! ```

use llmzip::compress::{LlmCompressor, LlmCompressorConfig};
use llmzip::coordinator::{BatchPolicy, Server, ServerConfig};
use llmzip::lm::weights::Weights;
use llmzip::lm::ExecutorKind;
use llmzip::util::stats::percentile;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

fn main() -> llmzip::Result<()> {
    let native = std::env::args().any(|a| a == "native");
    let executor = if native { ExecutorKind::Native } else { ExecutorKind::PjrtForward };
    let model = "medium";
    // Native path runs two engine replicas off ONE shared copy of the
    // weights (loaded here, cloned as an Arc into each worker).
    let replicas = if native { 2 } else { 1 };
    let shared: Option<Arc<Weights>> = if native {
        let cfg = llmzip::lm::config::by_name(model)?;
        let store = llmzip::runtime::ArtifactStore::open(None)?;
        Some(Arc::new(store.weights(cfg)?))
    } else {
        None
    };
    println!("starting server (model={model}, executor={executor:?}, replicas={replicas})...");
    let server = Arc::new(Server::start(
        move || {
            let comp_cfg = LlmCompressorConfig {
                model: model.into(),
                chunk_tokens: 256,
                stream_bytes: 4096,
                executor,
                ..Default::default()
            };
            if let Some(weights) = &shared {
                let cfg = llmzip::lm::config::by_name(model)?;
                LlmCompressor::from_shared(cfg, weights.clone(), comp_cfg)
            } else {
                let store = llmzip::runtime::ArtifactStore::open(None)?;
                LlmCompressor::open(&store, comp_cfg)
            }
        },
        ServerConfig {
            chunk_tokens: 256,
            replicas,
            policy: BatchPolicy { lanes: 8, max_wait: Duration::from_millis(15) },
            ..Default::default()
        },
    )?);

    // Workload: N clients, each compressing a few KiB of held-out text and
    // verifying the decompressed roundtrip through the same service.
    let n_clients = 6;
    let reqs_per_client = 4;
    let latencies = Arc::new(Mutex::new(Vec::new()));
    let t0 = Instant::now();
    let mut handles = Vec::new();
    let mut total_bytes = 0usize;
    for c in 0..n_clients {
        let srv = server.clone();
        let lat = latencies.clone();
        let data = llmzip::experiments::human_text(
            llmzip::textgen::Domain::EVAL[c % 8],
            2048 + 512 * c,
        );
        total_bytes += data.len() * reqs_per_client;
        handles.push(std::thread::spawn(move || -> llmzip::Result<f64> {
            let mut ratio = 0.0;
            for _ in 0..reqs_per_client {
                let t = Instant::now();
                let z = srv.compress(&data)?;
                let back = srv.decompress(&z)?;
                lat.lock().unwrap().push(t.elapsed().as_secs_f64() * 1e3);
                assert_eq!(back, data, "lossless roundtrip");
                ratio = data.len() as f64 / z.len() as f64;
            }
            Ok(ratio)
        }));
    }
    let mut ratios = Vec::new();
    for h in handles {
        ratios.push(h.join().expect("client thread")?);
    }
    let wall = t0.elapsed().as_secs_f64();

    let mut lat = latencies.lock().unwrap().clone();
    println!("\n== serving results ==");
    println!("clients                 {n_clients} x {reqs_per_client} compress+decompress requests");
    println!("wall time               {wall:.2}s");
    println!("throughput              {:.1} KiB/s (compress+decompress round trips)",
        total_bytes as f64 / 1024.0 / wall);
    println!("latency p50 / p90 / max {:.0} / {:.0} / {:.0} ms",
        percentile(&mut lat, 0.5), percentile(&mut lat, 0.9), percentile(&mut lat, 1.0));
    println!("ratios per client       {:?}",
        ratios.iter().map(|r| format!("{r:.1}x")).collect::<Vec<_>>());
    println!("coordinator             {}", server.metrics.report());
    println!("\nall roundtrips lossless ✓");
    Ok(())
}
