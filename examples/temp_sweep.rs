//! Sweep generation temperature vs achieved compression ratio (ablation).
use llmzip::compress::{Compressor, LlmCompressor, LlmCompressorConfig};
use llmzip::lm::ExecutorKind;
use llmzip::runtime::ArtifactStore;
use llmzip::sampling::DatasetFactory;
use llmzip::textgen::Domain;

fn main() -> llmzip::Result<()> {
    let store = ArtifactStore::open(None)?;
    let factory = DatasetFactory::from_store(&store, "medium")?;
    let comp = LlmCompressor::open(&store, LlmCompressorConfig {
        model: "medium".into(), chunk_tokens: 256, stream_bytes: 4096,
        executor: ExecutorKind::PjrtForward, ..Default::default() })?;
    println!("{:<6} {:>8} {:>12}", "TEMP", "RATIO", "bits/byte");
    for temp in [1.0, 0.8, 0.6, 0.5, 0.4, 0.3] {
        let data = factory.generate_dataset(Domain::Wiki, 16*1024, temp, 11)?;
        let z = comp.compress(&data)?;
        let r = data.len() as f64 / z.len() as f64;
        println!("{:<6} {:>7.2}x {:>11.3}", temp, r, 8.0 / r);
    }
    Ok(())
}
