//! The baseline ratchet.
//!
//! The repo predates the linter, so hundreds of findings exist at the
//! moment L1–L5 turn on. Blocking on them would make the linter
//! unadoptable; ignoring them would make it toothless. The ratchet is
//! the middle path: every pre-existing finding is recorded in a
//! committed `lint/baseline.txt`, CI fails the moment a count EXCEEDS
//! its recorded value (a regression) and merely notes counts that
//! dropped (an improvement — shrink the baseline with
//! `--update-baseline` in the same PR). The debt can only burn down.
//!
//! Entries are keyed `(rule, path, symbol)` with a count rather than a
//! line number, so refactors that move code without adding violations
//! do not churn the file.
//!
//! Format, one entry per line, tab-separated, sorted:
//!
//! ```text
//! L3<TAB>rust/src/compress/stream.rs<TAB>next_chunk<TAB>2
//! ```

use crate::rules::Finding;
use std::collections::BTreeMap;
use std::fmt;

/// `(rule, path, symbol)` — the granularity at which counts ratchet.
pub type Key = (String, String, String);

#[derive(Clone, Debug, Default)]
pub struct Baseline {
    pub counts: BTreeMap<Key, u64>,
}

/// One key whose current count exceeds the committed allowance.
#[derive(Clone, Debug)]
pub struct Regression {
    pub key: Key,
    pub current: u64,
    pub allowed: u64,
}

/// Check outcome: regressions fail the build, improvements are notes.
#[derive(Clone, Debug, Default)]
pub struct Diff {
    pub regressions: Vec<Regression>,
    pub improvements: Vec<Regression>,
}

#[derive(Debug)]
pub struct BaselineError {
    pub line: usize,
    pub message: String,
}

impl fmt::Display for BaselineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "baseline line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for BaselineError {}

const HEADER: &str = "\
# pallas-lint baseline: pre-existing findings, allowed to shrink but never to grow.
# Format: rule<TAB>path<TAB>symbol<TAB>count (sorted). Do not edit by hand;
# regenerate with `cargo run -p pallas-lint -- --update-baseline` after fixing findings.";

impl Baseline {
    pub fn parse(src: &str) -> Result<Baseline, BaselineError> {
        let mut counts = BTreeMap::new();
        for (idx, raw) in src.lines().enumerate() {
            let line = raw.trim_end();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let fields: Vec<&str> = line.split('\t').collect();
            let &[rule, path, symbol, count] = fields.as_slice() else {
                return Err(BaselineError {
                    line: idx + 1,
                    message: format!("expected 4 tab-separated fields, got {}", fields.len()),
                });
            };
            let count: u64 = count.parse().map_err(|_| BaselineError {
                line: idx + 1,
                message: format!("bad count `{count}`"),
            })?;
            counts.insert((rule.to_string(), path.to_string(), symbol.to_string()), count);
        }
        Ok(Baseline { counts })
    }

    pub fn from_findings(findings: &[Finding]) -> Baseline {
        let mut counts: BTreeMap<Key, u64> = BTreeMap::new();
        for f in findings {
            *counts.entry(f.key()).or_insert(0) += 1;
        }
        Baseline { counts }
    }

    /// Serialized form, stable: header, then sorted entries. A trailing
    /// newline keeps `wc -l` (the CI never-grows grep) honest.
    pub fn render(&self) -> String {
        let mut out = String::from(HEADER);
        out.push('\n');
        for ((rule, path, symbol), count) in &self.counts {
            out.push_str(&format!("{rule}\t{path}\t{symbol}\t{count}\n"));
        }
        out
    }

    pub fn total(&self) -> u64 {
        self.counts.values().sum()
    }

    /// Compare the scan against the committed allowance.
    pub fn diff(current: &Baseline, committed: &Baseline) -> Diff {
        let mut diff = Diff::default();
        for (key, &cur) in &current.counts {
            let allowed = committed.counts.get(key).copied().unwrap_or(0);
            if cur > allowed {
                diff.regressions.push(Regression { key: key.clone(), current: cur, allowed });
            } else if cur < allowed {
                diff.improvements.push(Regression { key: key.clone(), current: cur, allowed });
            }
        }
        for (key, &allowed) in &committed.counts {
            if !current.counts.contains_key(key) {
                diff.improvements.push(Regression { key: key.clone(), current: 0, allowed });
            }
        }
        diff
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rules::Rule;

    fn finding(rule: Rule, path: &str, symbol: &str) -> Finding {
        Finding {
            rule,
            path: path.to_string(),
            line: 1,
            symbol: symbol.to_string(),
            message: String::new(),
        }
    }

    #[test]
    fn roundtrips_through_render() {
        let fs = vec![
            finding(Rule::L3, "a.rs", "f"),
            finding(Rule::L3, "a.rs", "f"),
            finding(Rule::L1, "b.rs", "-"),
        ];
        let b = Baseline::from_findings(&fs);
        let reparsed = Baseline::parse(&b.render()).unwrap();
        assert_eq!(reparsed.counts, b.counts);
        assert_eq!(reparsed.total(), 3);
        let key = ("L3".to_string(), "a.rs".to_string(), "f".to_string());
        assert_eq!(reparsed.counts[&key], 2);
    }

    #[test]
    fn diff_flags_growth_only() {
        let committed = Baseline::from_findings(&[
            finding(Rule::L3, "a.rs", "f"),
            finding(Rule::L4, "gone.rs", "g"),
        ]);
        // Same L3 count, a brand-new L1, the L4 fixed entirely.
        let current = Baseline::from_findings(&[
            finding(Rule::L3, "a.rs", "f"),
            finding(Rule::L1, "new.rs", "h"),
        ]);
        let diff = Baseline::diff(&current, &committed);
        assert_eq!(diff.regressions.len(), 1);
        assert_eq!(diff.regressions[0].key.0, "L1");
        assert_eq!(diff.regressions[0].allowed, 0);
        assert_eq!(diff.improvements.len(), 1);
        assert_eq!(diff.improvements[0].key.0, "L4");
    }

    #[test]
    fn diff_flags_count_increase_within_key() {
        let committed = Baseline::from_findings(&[finding(Rule::L3, "a.rs", "f")]);
        let current = Baseline::from_findings(&[
            finding(Rule::L3, "a.rs", "f"),
            finding(Rule::L3, "a.rs", "f"),
        ]);
        let diff = Baseline::diff(&current, &committed);
        assert_eq!(diff.regressions.len(), 1);
        assert_eq!(diff.regressions[0].current, 2);
        assert_eq!(diff.regressions[0].allowed, 1);
    }

    #[test]
    fn parse_rejects_malformed_lines() {
        assert!(Baseline::parse("L3\tonly_two\n").is_err());
        assert!(Baseline::parse("L3\ta.rs\tf\tnot_a_number\n").is_err());
        assert!(Baseline::parse("# comment only\n\n").unwrap().counts.is_empty());
    }
}
