//! A hand-rolled Rust lexer that PRESERVES COMMENTS.
//!
//! `pallas-lint`'s rules are lexical: they need to see `// SAFETY:`
//! comments, `// lint: allow(..)` waivers, and the token stream around an
//! `as u32` cast — exactly the information `syn`-style parsers throw away
//! and this offline environment could not download anyway. The lexer
//! therefore stays deliberately small: it distinguishes identifiers,
//! numeric literals (integer vs float — rule L5 keys on floats), string
//! and char literals (so `"unsafe"` in a string is never a keyword),
//! lifetimes, comments (line, block with nesting, doc) and punctuation,
//! each tagged with its 1-based source line.
//!
//! It is NOT a full Rust parser. It does not need to be: every rule is
//! defined directly in terms of this token stream (see `docs/lint.md`),
//! so "what the linter enforces" has no gap to "what the lexer sees".
//!
//! NOTE: `lint/tools/gen_baseline.py` is a line-for-line transliteration
//! of this module (the bootstrap path for environments without cargo).
//! Change them together.

/// Token classes. Comments are real tokens here — rules L1 (SAFETY
/// comments) and the waiver grammar read them.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Lifetime,
    Int,
    Float,
    Str,
    Char,
    LineComment,
    BlockComment,
    Punct,
}

/// One token: kind, verbatim text, and the 1-based line it STARTS on.
#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

impl Tok {
    pub fn is_comment(&self) -> bool {
        matches!(self.kind, TokKind::LineComment | TokKind::BlockComment)
    }
}

/// Multi-character operators, longest first so `>>=` never lexes as
/// `>` `>` `=`. Order matters.
const MULTI_PUNCT: [&str; 24] = [
    "<<=", ">>=", "..=", "...", "::", "->", "=>", "==", "!=", "<=", ">=", "&&", "||", "+=", "-=",
    "*=", "/=", "%=", "^=", "&=", "|=", "<<", ">>", "..",
];

fn is_ident_start(c: u8) -> bool {
    c.is_ascii_alphabetic() || c == b'_'
}

fn is_ident_cont(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// Tokenize `src`. Lexing is total: any byte sequence produces a token
/// stream (unrecognized bytes become single-char `Punct` tokens), so a
/// syntactically broken fixture file still lints.
pub fn lex(src: &str) -> Vec<Tok> {
    Lexer { b: src.as_bytes(), i: 0, line: 1, out: Vec::new() }.run()
}

struct Lexer<'a> {
    b: &'a [u8],
    i: usize,
    line: u32,
    out: Vec<Tok>,
}

impl Lexer<'_> {
    fn peek(&self, ahead: usize) -> u8 {
        *self.b.get(self.i + ahead).unwrap_or(&0)
    }

    /// Advance one byte, tracking line numbers.
    fn bump(&mut self) {
        if self.peek(0) == b'\n' {
            self.line += 1;
        }
        self.i += 1;
    }

    fn push(&mut self, kind: TokKind, start: usize, line: u32) {
        let text = String::from_utf8_lossy(&self.b[start..self.i]).into_owned();
        self.out.push(Tok { kind, text, line });
    }

    fn run(mut self) -> Vec<Tok> {
        while self.i < self.b.len() {
            let c = self.peek(0);
            let (start, line) = (self.i, self.line);
            if c.is_ascii_whitespace() {
                self.bump();
            } else if c == b'/' && self.peek(1) == b'/' {
                while self.i < self.b.len() && self.peek(0) != b'\n' {
                    self.bump();
                }
                self.push(TokKind::LineComment, start, line);
            } else if c == b'/' && self.peek(1) == b'*' {
                self.block_comment();
                self.push(TokKind::BlockComment, start, line);
            } else if c == b'r' && self.raw_string_ahead() {
                self.raw_string();
                self.push(TokKind::Str, start, line);
            } else if c == b'b' && self.peek(1) == b'r' && self.raw_string_ahead_at(1) {
                self.bump();
                self.raw_string();
                self.push(TokKind::Str, start, line);
            } else if c == b'b' && self.peek(1) == b'"' {
                self.bump();
                self.quoted(b'"');
                self.push(TokKind::Str, start, line);
            } else if c == b'b' && self.peek(1) == b'\'' {
                self.bump();
                self.quoted(b'\'');
                self.push(TokKind::Char, start, line);
            } else if c == b'r' && self.peek(1) == b'#' && is_ident_start(self.peek(2)) {
                // Raw identifier r#foo: strip the prefix so rules see `foo`.
                self.bump();
                self.bump();
                while is_ident_cont(self.peek(0)) {
                    self.bump();
                }
                let text = String::from_utf8_lossy(&self.b[start + 2..self.i]).into_owned();
                self.out.push(Tok { kind: TokKind::Ident, text, line });
            } else if is_ident_start(c) {
                while is_ident_cont(self.peek(0)) {
                    self.bump();
                }
                self.push(TokKind::Ident, start, line);
            } else if c.is_ascii_digit() {
                let kind = self.number();
                self.push(kind, start, line);
            } else if c == b'"' {
                self.quoted(b'"');
                self.push(TokKind::Str, start, line);
            } else if c == b'\'' {
                self.lifetime_or_char(start, line);
            } else {
                self.punct(start, line);
            }
        }
        self.out
    }

    /// Nested block comment; leaves `i` past the closing `*/` (or at EOF).
    fn block_comment(&mut self) {
        self.bump();
        self.bump();
        let mut depth = 1usize;
        while self.i < self.b.len() && depth > 0 {
            if self.peek(0) == b'/' && self.peek(1) == b'*' {
                depth += 1;
                self.bump();
                self.bump();
            } else if self.peek(0) == b'*' && self.peek(1) == b'/' {
                depth -= 1;
                self.bump();
                self.bump();
            } else {
                self.bump();
            }
        }
    }

    fn raw_string_ahead(&self) -> bool {
        self.raw_string_ahead_at(0)
    }

    /// Does `r`[#...]`"` start at offset `at` from the cursor?
    fn raw_string_ahead_at(&self, at: usize) -> bool {
        let mut j = at + 1;
        while self.peek(j) == b'#' {
            j += 1;
        }
        self.peek(j) == b'"'
    }

    /// Raw string starting at the `r`; ends at `"` followed by the same
    /// number of `#` as the opener.
    fn raw_string(&mut self) {
        self.bump(); // r
        let mut hashes = 0usize;
        while self.peek(0) == b'#' {
            hashes += 1;
            self.bump();
        }
        self.bump(); // opening quote
        while self.i < self.b.len() {
            if self.peek(0) == b'"' {
                let mut ok = true;
                for k in 0..hashes {
                    if self.peek(1 + k) != b'#' {
                        ok = false;
                        break;
                    }
                }
                if ok {
                    for _ in 0..=hashes {
                        self.bump();
                    }
                    return;
                }
            }
            self.bump();
        }
    }

    /// Escaped quoted literal (string or char), cursor on the quote.
    fn quoted(&mut self, q: u8) {
        self.bump();
        while self.i < self.b.len() {
            let c = self.peek(0);
            if c == b'\\' {
                self.bump();
                self.bump();
            } else if c == q {
                self.bump();
                return;
            } else {
                self.bump();
            }
        }
    }

    /// Number starting with a digit. Float iff it has a fractional part,
    /// an exponent, or an `f32`/`f64` suffix — rule L5's trigger.
    fn number(&mut self) -> TokKind {
        let mut float = false;
        if self.peek(0) == b'0' && matches!(self.peek(1), b'x' | b'o' | b'b') {
            self.bump();
            self.bump();
            while is_ident_cont(self.peek(0)) {
                self.bump();
            }
            return TokKind::Int;
        }
        while self.peek(0).is_ascii_digit() || self.peek(0) == b'_' {
            self.bump();
        }
        if self.peek(0) == b'.' && self.peek(1).is_ascii_digit() {
            float = true;
            self.bump();
            while self.peek(0).is_ascii_digit() || self.peek(0) == b'_' {
                self.bump();
            }
        }
        if matches!(self.peek(0), b'e' | b'E')
            && (self.peek(1).is_ascii_digit()
                || (matches!(self.peek(1), b'+' | b'-') && self.peek(2).is_ascii_digit()))
        {
            float = true;
            self.bump();
            self.bump();
            while self.peek(0).is_ascii_digit() || self.peek(0) == b'_' {
                self.bump();
            }
        }
        // Type suffix (f32, u64, usize, ...) rides on the same token.
        let suffix_at = self.i;
        while is_ident_cont(self.peek(0)) {
            self.bump();
        }
        let suffix = &self.b[suffix_at..self.i];
        if suffix == b"f32" || suffix == b"f64" {
            float = true;
        }
        if float {
            TokKind::Float
        } else {
            TokKind::Int
        }
    }

    /// `'` starts a lifetime (`'a`, `'static`) or a char (`'x'`, `'\n'`).
    fn lifetime_or_char(&mut self, start: usize, line: u32) {
        if self.peek(1) == b'\\' {
            self.quoted(b'\'');
            self.push(TokKind::Char, start, line);
        } else if is_ident_start(self.peek(1)) {
            // Identifier-shaped: char iff a closing quote follows it
            // immediately ('a' vs 'a as in &'a str).
            let mut j = 2;
            while is_ident_cont(self.peek(j)) {
                j += 1;
            }
            if self.peek(j) == b'\'' {
                self.quoted(b'\'');
                self.push(TokKind::Char, start, line);
            } else {
                self.bump();
                while is_ident_cont(self.peek(0)) {
                    self.bump();
                }
                self.push(TokKind::Lifetime, start, line);
            }
        } else {
            self.quoted(b'\'');
            self.push(TokKind::Char, start, line);
        }
    }

    fn punct(&mut self, start: usize, line: u32) {
        for op in MULTI_PUNCT {
            if self.b[self.i..].starts_with(op.as_bytes()) {
                for _ in 0..op.len() {
                    self.bump();
                }
                self.push(TokKind::Punct, start, line);
                return;
            }
        }
        self.bump();
        self.push(TokKind::Punct, start, line);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn comments_are_preserved_with_lines() {
        let toks = lex("// SAFETY: fine\nlet x = 1; /* a /* nested */ b */ y");
        assert_eq!(toks[0].kind, TokKind::LineComment);
        assert_eq!(toks[0].text, "// SAFETY: fine");
        assert_eq!(toks[0].line, 1);
        let block = toks.iter().find(|t| t.kind == TokKind::BlockComment).unwrap();
        assert!(block.text.contains("nested"));
        assert_eq!(block.line, 2);
        assert_eq!(toks.last().unwrap().text, "y");
    }

    #[test]
    fn strings_hide_keywords_and_track_lines() {
        let toks = lex("let s = \"unsafe // not a comment\";\nnext");
        assert!(toks.iter().all(|t| t.kind != TokKind::LineComment));
        assert_eq!(toks.iter().filter(|t| t.text == "unsafe").count(), 0);
        assert_eq!(toks.last().unwrap().line, 2);
    }

    #[test]
    fn raw_strings_and_bytes() {
        let toks = kinds(r####"r#"has "quotes" inside"# b"bytes" b'x' r"plain""####);
        assert_eq!(
            toks.iter().map(|(k, _)| *k).collect::<Vec<_>>(),
            vec![TokKind::Str, TokKind::Str, TokKind::Char, TokKind::Str]
        );
    }

    #[test]
    fn lifetimes_vs_chars() {
        let toks = kinds("&'a str 'x' '\\n' 'static");
        let kindv: Vec<TokKind> = toks.iter().map(|(k, _)| *k).collect();
        assert_eq!(
            kindv,
            vec![
                TokKind::Punct,
                TokKind::Lifetime,
                TokKind::Ident,
                TokKind::Char,
                TokKind::Char,
                TokKind::Lifetime,
            ]
        );
    }

    #[test]
    fn float_classification_drives_l5() {
        for (src, kind) in [
            ("1.5", TokKind::Float),
            ("1e-6", TokKind::Float),
            ("2f32", TokKind::Float),
            ("1_000.25", TokKind::Float),
            ("0x4E", TokKind::Int),
            ("17", TokKind::Int),
            ("3usize", TokKind::Int),
        ] {
            let toks = lex(src);
            assert_eq!(toks.len(), 1, "{src}");
            assert_eq!(toks[0].kind, kind, "{src}");
        }
        // `0..10` is two ints and a range, not a float.
        let toks = kinds("0..10");
        assert_eq!(
            toks.iter().map(|(k, _)| *k).collect::<Vec<_>>(),
            vec![TokKind::Int, TokKind::Punct, TokKind::Int]
        );
    }

    #[test]
    fn multi_char_punct_is_greedy() {
        let texts: Vec<String> =
            lex("a >>= b :: c -> d ..= e").into_iter().map(|t| t.text).collect();
        assert!(texts.contains(&">>=".to_string()));
        assert!(texts.contains(&"::".to_string()));
        assert!(texts.contains(&"->".to_string()));
        assert!(texts.contains(&"..=".to_string()));
    }

    #[test]
    fn raw_idents_lose_their_sigil() {
        let toks = lex("r#type r#match");
        assert_eq!(toks[0].kind, TokKind::Ident);
        assert_eq!(toks[0].text, "type");
        assert_eq!(toks[1].text, "match");
    }

    #[test]
    fn lexing_is_total_on_garbage() {
        let toks = lex("\u{1F980} @@@ $ ` 'unterminated");
        assert!(!toks.is_empty());
    }
}
