//! `pallas-lint`: offline invariant linter for the llmzip workspace.
//!
//! The compressor's correctness story is byte-identity under every
//! deployment shape, and several past bugs (silent `as u32` wire
//! truncations, panics reachable from hostile container bytes, f32
//! reassociation) share a property: they are *lexically visible*. This
//! crate mechanizes those checks — five rules over a comment-preserving
//! token stream, zone-scoped by `lint/zones.toml`, ratcheted against
//! `lint/baseline.txt`. Zero external dependencies by design: it must
//! build in the same offline environments as the rest of the workspace.
//!
//! See `docs/lint.md` for the rule catalog, waiver grammar, and
//! workflow; `lint/tools/gen_baseline.py` is the no-cargo bootstrap
//! mirror of the scanner.

pub mod baseline;
pub mod lexer;
pub mod report;
pub mod rules;
pub mod zones;

use rules::Finding;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};
use zones::Zones;

/// All `.rs` files under `root`'s scan roots, sorted by normalized
/// path so every run (and the Python mirror) sees the same order.
pub fn collect_rs_files(root: &Path, zones: &Zones) -> io::Result<Vec<PathBuf>> {
    let mut files = Vec::new();
    for scan in &zones.scan {
        let base = if scan.is_empty() { root.to_path_buf() } else { root.join(scan) };
        walk(&base, &mut files)?;
    }
    files.sort();
    files.dedup();
    Ok(files)
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    if dir.is_file() {
        out.push(dir.to_path_buf());
        return Ok(());
    }
    let mut entries = Vec::new();
    for entry in fs::read_dir(dir)? {
        entries.push(entry?.path());
    }
    entries.sort();
    for path in entries {
        if path.is_dir() {
            walk(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Scan every file under the manifest's roots; paths in findings are
/// `root`-relative and `/`-separated (the zone/baseline key form).
pub fn scan_tree(root: &Path, zones: &Zones) -> io::Result<Vec<Finding>> {
    let mut findings = Vec::new();
    for file in collect_rs_files(root, zones)? {
        let src = fs::read_to_string(&file)?;
        let rel = file.strip_prefix(root).unwrap_or(&file);
        let rel = zones::normalize(rel);
        findings.extend(rules::scan_file(&rel, &src, zones));
    }
    Ok(findings)
}
