//! CLI entry point.
//!
//! ```text
//! cargo run -p pallas-lint -- --check            # CI mode: diff vs baseline
//! cargo run -p pallas-lint -- --check --json     # machine-readable findings
//! cargo run -p pallas-lint -- --update-baseline  # rewrite lint/baseline.txt
//! ```
//!
//! Exit codes: 0 clean (or improvements only), 1 regressions vs the
//! baseline, 2 usage/configuration error. Paths for `--zones` and
//! `--baseline` are resolved relative to `--root` (default `.`), so the
//! tool works from the workspace root and from fixture trees alike.

use pallas_lint::baseline::Baseline;
use pallas_lint::rules::Rule;
use pallas_lint::zones::Zones;
use pallas_lint::{report, scan_tree};
use std::fs;
use std::path::PathBuf;
use std::process::ExitCode;

const USAGE: &str = "\
pallas-lint: invariant linter for the llmzip workspace (see docs/lint.md)

USAGE: pallas-lint [--check | --update-baseline] [OPTIONS]

  --check             scan and diff against the committed baseline (default)
  --update-baseline   scan and rewrite the baseline file to current findings
  --json              emit the machine-readable report on stdout
  --only <RULE>       restrict to one rule (L1..L5); baseline filtered too
  --root <DIR>        tree to lint (default: .)
  --zones <FILE>      zone manifest, relative to --root (default: lint/zones.toml)
  --baseline <FILE>   baseline file, relative to --root (default: lint/baseline.txt)";

struct Opts {
    update: bool,
    json: bool,
    only: Option<Rule>,
    root: PathBuf,
    zones: String,
    baseline: String,
}

fn parse_opts() -> Result<Opts, String> {
    let mut opts = Opts {
        update: false,
        json: false,
        only: None,
        root: PathBuf::from("."),
        zones: "lint/zones.toml".to_string(),
        baseline: "lint/baseline.txt".to_string(),
    };
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        let mut value = |name: &str| args.next().ok_or_else(|| format!("{name} needs a value"));
        match arg.as_str() {
            "--check" => opts.update = false,
            "--update-baseline" => opts.update = true,
            "--json" => opts.json = true,
            "--only" => {
                let v = value("--only")?;
                opts.only = Some(Rule::parse(&v).ok_or_else(|| format!("unknown rule `{v}`"))?);
            }
            "--root" => opts.root = PathBuf::from(value("--root")?),
            "--zones" => opts.zones = value("--zones")?,
            "--baseline" => opts.baseline = value("--baseline")?,
            "--help" | "-h" => return Err(String::new()),
            other => return Err(format!("unknown argument `{other}`")),
        }
    }
    Ok(opts)
}

fn run(opts: &Opts) -> Result<ExitCode, String> {
    let zones_path = opts.root.join(&opts.zones);
    let zones_src = fs::read_to_string(&zones_path)
        .map_err(|e| format!("reading {}: {e}", zones_path.display()))?;
    let zones = Zones::parse(&zones_src).map_err(|e| e.to_string())?;

    let mut findings = scan_tree(&opts.root, &zones)
        .map_err(|e| format!("scanning {}: {e}", opts.root.display()))?;
    if let Some(rule) = opts.only {
        findings.retain(|f| f.rule == rule);
    }

    let baseline_path = opts.root.join(&opts.baseline);
    if opts.update {
        let current = Baseline::from_findings(&findings);
        fs::write(&baseline_path, current.render())
            .map_err(|e| format!("writing {}: {e}", baseline_path.display()))?;
        println!(
            "pallas-lint: wrote {} entries ({} findings) to {}",
            current.counts.len(),
            current.total(),
            baseline_path.display()
        );
        return Ok(ExitCode::SUCCESS);
    }

    let committed_src = fs::read_to_string(&baseline_path)
        .map_err(|e| format!("reading {}: {e} (run --update-baseline?)", baseline_path.display()))?;
    let mut committed = Baseline::parse(&committed_src).map_err(|e| e.to_string())?;
    if let Some(rule) = opts.only {
        committed.counts.retain(|(r, _, _), _| r == rule.as_str());
    }

    let current = Baseline::from_findings(&findings);
    let diff = Baseline::diff(&current, &committed);
    if opts.json {
        print!("{}", report::json(&findings, &diff));
    } else {
        print!("{}", report::human(&findings, &diff));
    }
    if diff.regressions.is_empty() {
        Ok(ExitCode::SUCCESS)
    } else {
        Ok(ExitCode::from(1))
    }
}

fn main() -> ExitCode {
    let opts = match parse_opts() {
        Ok(opts) => opts,
        Err(msg) => {
            if msg.is_empty() {
                println!("{USAGE}");
                return ExitCode::SUCCESS;
            }
            eprintln!("pallas-lint: {msg}\n\n{USAGE}");
            return ExitCode::from(2);
        }
    };
    match run(&opts) {
        Ok(code) => code,
        Err(msg) => {
            eprintln!("pallas-lint: {msg}");
            ExitCode::from(2)
        }
    }
}
