//! Finding reporters: human text for terminals/CI logs, hand-rolled
//! JSON (`--json`) for tooling. Both are pure string builders so the
//! integration tests can assert on them without capturing stdout.

use crate::baseline::{Diff, Regression};
use crate::rules::Finding;

/// Human report for `--check`: regressions first (these fail the
/// build), each with the concrete `path:line` sites from the current
/// scan, then improvement notes, then a one-line summary.
pub fn human(findings: &[Finding], diff: &Diff) -> String {
    let mut out = String::new();
    for r in &diff.regressions {
        let (rule, path, symbol) = &r.key;
        out.push_str(&format!(
            "REGRESSION {rule} {path} [{symbol}]: {} finding(s), baseline allows {}\n",
            r.current, r.allowed
        ));
        for f in findings.iter().filter(|f| keyed(f, r)) {
            out.push_str(&format!("  {}:{}: {} ({})\n", f.path, f.line, f.message, f.rule));
        }
    }
    for r in &diff.improvements {
        let (rule, path, symbol) = &r.key;
        out.push_str(&format!(
            "improved {rule} {path} [{symbol}]: {} -> {} (shrink the baseline: --update-baseline)\n",
            r.allowed, r.current
        ));
    }
    let status = if diff.regressions.is_empty() { "ok" } else { "FAIL" };
    out.push_str(&format!(
        "pallas-lint: {status} — {} finding(s), {} regression(s), {} improvement(s)\n",
        findings.len(),
        diff.regressions.len(),
        diff.improvements.len()
    ));
    out
}

fn keyed(f: &Finding, r: &Regression) -> bool {
    let (rule, path, symbol) = &r.key;
    f.rule.as_str() == rule && &f.path == path && &f.symbol == symbol
}

/// Machine-readable report: every current finding plus the diff.
pub fn json(findings: &[Finding], diff: &Diff) -> String {
    let mut out = String::from("{\n  \"findings\": [");
    for (i, f) in findings.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n    {{\"rule\": \"{}\", \"path\": \"{}\", \"line\": {}, \"symbol\": \"{}\", \
             \"message\": \"{}\"}}",
            f.rule,
            escape(&f.path),
            f.line,
            escape(&f.symbol),
            escape(&f.message)
        ));
    }
    out.push_str("\n  ],\n  \"regressions\": [");
    push_keys(&mut out, &diff.regressions);
    out.push_str("\n  ],\n  \"improvements\": [");
    push_keys(&mut out, &diff.improvements);
    out.push_str(&format!(
        "\n  ],\n  \"ok\": {}\n}}\n",
        if diff.regressions.is_empty() { "true" } else { "false" }
    ));
    out
}

fn push_keys(out: &mut String, entries: &[Regression]) {
    for (i, r) in entries.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let (rule, path, symbol) = &r.key;
        out.push_str(&format!(
            "\n    {{\"rule\": \"{}\", \"path\": \"{}\", \"symbol\": \"{}\", \
             \"current\": {}, \"allowed\": {}}}",
            escape(rule),
            escape(path),
            escape(symbol),
            r.current,
            r.allowed
        ));
    }
}

/// Minimal JSON string escaping: quotes, backslashes, control chars.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::Baseline;
    use crate::rules::{Finding, Rule};

    fn sample() -> (Vec<Finding>, Diff) {
        let findings = vec![Finding {
            rule: Rule::L3,
            path: "a.rs".to_string(),
            line: 7,
            symbol: "f".to_string(),
            message: "`.unwrap()` in decode-reachable code".to_string(),
        }];
        let diff = Baseline::diff(&Baseline::from_findings(&findings), &Baseline::default());
        (findings, diff)
    }

    #[test]
    fn human_report_names_the_site() {
        let (findings, diff) = sample();
        let text = human(&findings, &diff);
        assert!(text.contains("REGRESSION L3 a.rs [f]"));
        assert!(text.contains("a.rs:7:"));
        assert!(text.contains("FAIL"));
        let clean = human(&[], &Baseline::diff(&Baseline::default(), &Baseline::default()));
        assert!(clean.contains("ok"));
        assert!(!clean.contains("REGRESSION"));
    }

    #[test]
    fn json_report_is_parseable_shape() {
        let (findings, diff) = sample();
        let j = json(&findings, &diff);
        assert!(j.contains("\"rule\": \"L3\""));
        assert!(j.contains("\"line\": 7"));
        assert!(j.contains("\"ok\": false"));
        // Balanced braces/brackets (cheap well-formedness proxy).
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
    }

    #[test]
    fn escape_handles_specials() {
        assert_eq!(escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(escape("\u{1}"), "\\u0001");
    }
}
