//! The five invariant rules, defined directly over the token stream.
//!
//! Each rule mechanizes a contract this repo previously enforced by
//! hand-audit (see `docs/lint.md` for the catalog and the incidents
//! behind each one):
//!
//! - **L1** `unsafe` without an adjacent `// SAFETY:` comment (all files).
//! - **L2** truncating `as u16`/`as u32` on a length-like expression
//!   (decode-reachable) — wire lengths route through `check_wire_len`.
//! - **L3** `panic!`/`unwrap`/`expect` in decode-reachable code.
//! - **L4** nondeterminism sources (`HashMap`/`HashSet`, `Instant::now`,
//!   `SystemTime`, env reads) in coded zones.
//! - **L5** f32 arithmetic and `mul_add` outside `lm/kernels` — PR 6's
//!   "no arithmetic inner loops in native.rs" contract.
//!
//! Rules are lexical, not type-aware: they are deliberately defined so
//! that "what the linter sees" is exactly "what a reviewer greps for",
//! and so the Python bootstrap (`lint/tools/gen_baseline.py`) can mirror
//! them line-for-line. False positives are handled by the waiver
//! grammar (`// lint: allow(<rules>) <reason>`, covering its own line
//! and the next) or by the committed baseline ratchet.
//!
//! `#[test]` / `#[cfg(test)]` items are skipped entirely: test code may
//! panic and use HashMaps freely.

use crate::lexer::{lex, Tok, TokKind};
use crate::zones::Zones;
use std::collections::BTreeMap;
use std::fmt;

/// Rule identifiers. Stable strings — they appear in baselines and
/// waivers, so renaming one invalidates committed state.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    L1,
    L2,
    L3,
    L4,
    L5,
}

impl Rule {
    pub const ALL: [Rule; 5] = [Rule::L1, Rule::L2, Rule::L3, Rule::L4, Rule::L5];

    pub fn as_str(self) -> &'static str {
        match self {
            Rule::L1 => "L1",
            Rule::L2 => "L2",
            Rule::L3 => "L3",
            Rule::L4 => "L4",
            Rule::L5 => "L5",
        }
    }

    pub fn parse(s: &str) -> Option<Rule> {
        match s.to_ascii_uppercase().as_str() {
            "L1" => Some(Rule::L1),
            "L2" => Some(Rule::L2),
            "L3" => Some(Rule::L3),
            "L4" => Some(Rule::L4),
            "L5" => Some(Rule::L5),
            _ => None,
        }
    }

    /// One-line description, used by reports and `docs/lint.md`.
    pub fn title(self) -> &'static str {
        match self {
            Rule::L1 => "unsafe without a SAFETY comment",
            Rule::L2 => "truncating length cast (use check_wire_len)",
            Rule::L3 => "panic path in decode-reachable code",
            Rule::L4 => "nondeterminism source in a coded zone",
            Rule::L5 => "float arithmetic outside lm/kernels",
        }
    }
}

impl fmt::Display for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// One finding. `symbol` is the enclosing `fn` (or `-` at item level):
/// the baseline is keyed on `(rule, path, symbol)` with a count, so it
/// survives line churn without going stale.
#[derive(Clone, Debug)]
pub struct Finding {
    pub rule: Rule,
    pub path: String,
    pub line: u32,
    pub symbol: String,
    pub message: String,
}

impl Finding {
    pub fn key(&self) -> (String, String, String) {
        (self.rule.as_str().to_string(), self.path.clone(), self.symbol.clone())
    }
}

/// Lint one file. `path` must be lint-root-relative and `/`-separated
/// (see `zones::normalize`); zone membership decides which rules run.
pub fn scan_file(path: &str, src: &str, zones: &Zones) -> Vec<Finding> {
    let coded = zones.in_zone("coded", path);
    let decode = zones.in_zone("decode_reachable", path);
    let kernel = zones.in_zone("kernel", path);

    let all = lex(src);
    let lines: Vec<&str> = src.lines().collect();
    let waivers = collect_waivers(&all);
    let t: Vec<Tok> = all.into_iter().filter(|t| !t.is_comment()).collect();
    let skip = test_item_mask(&t);
    let symbols = enclosing_fn(&t);

    let cx = Cx { path, t: &t, skip: &skip, symbols: &symbols, lines: &lines };
    let mut out = Vec::new();
    rule_l1(&cx, &mut out);
    if decode {
        rule_l2(&cx, &mut out);
        rule_l3(&cx, &mut out);
    }
    if coded {
        rule_l4(&cx, &mut out);
        if !kernel {
            rule_l5(&cx, &mut out);
        }
    }

    out.retain(|f| !waived(&waivers, f));
    out.sort_by_key(|f| (f.line, f.rule));
    out
}

/// Per-file context shared by the rule passes. `t` is the token stream
/// with comments removed; `skip` masks `#[test]`/`#[cfg(test)]` items.
struct Cx<'a> {
    path: &'a str,
    t: &'a [Tok],
    skip: &'a [bool],
    symbols: &'a [String],
    lines: &'a [&'a str],
}

impl Cx<'_> {
    fn push(&self, out: &mut Vec<Finding>, rule: Rule, j: usize, message: String) {
        out.push(Finding {
            rule,
            path: self.path.to_string(),
            line: self.t[j].line,
            symbol: self.symbols[j].clone(),
            message,
        });
    }

    fn ident_at(&self, j: usize, text: &str) -> bool {
        self.t.get(j).is_some_and(|t| t.kind == TokKind::Ident && t.text == text)
    }

    fn punct_at(&self, j: usize, text: &str) -> bool {
        self.t.get(j).is_some_and(|t| t.kind == TokKind::Punct && t.text == text)
    }
}

// ---------------------------------------------------------------- waivers

const WAIVER_MARK: &str = "lint: allow(";

fn collect_waivers(toks: &[Tok]) -> BTreeMap<u32, Vec<Rule>> {
    let mut map: BTreeMap<u32, Vec<Rule>> = BTreeMap::new();
    for t in toks.iter().filter(|t| t.is_comment()) {
        let Some(pos) = t.text.find(WAIVER_MARK) else { continue };
        let rest = &t.text[pos + WAIVER_MARK.len()..];
        let Some(end) = rest.find(')') else { continue };
        let rules: Vec<Rule> = rest[..end].split([',', ' ']).filter_map(Rule::parse).collect();
        if !rules.is_empty() {
            map.entry(t.line).or_default().extend(rules);
        }
    }
    map
}

/// A waiver covers its own line and the next one (comment above the
/// offending line, or trailing on the same line).
fn waived(map: &BTreeMap<u32, Vec<Rule>>, f: &Finding) -> bool {
    let hit = |l: u32| map.get(&l).is_some_and(|v| v.contains(&f.rule));
    hit(f.line) || (f.line > 1 && hit(f.line - 1))
}

// ------------------------------------------------- test-item skipping

/// Mask tokens belonging to items annotated `#[test]` / `#[cfg(test)]`
/// (any attribute containing the ident `test` but not `not`, so
/// `#[cfg(not(test))]` items still lint). The skipped item runs to the
/// matching `}` of its first `{`, or to a `;` before any brace.
fn test_item_mask(t: &[Tok]) -> Vec<bool> {
    let mut skip = vec![false; t.len()];
    let mut i = 0;
    while i < t.len() {
        if !(t[i].kind == TokKind::Punct && t[i].text == "#") {
            i += 1;
            continue;
        }
        let mut j = i + 1;
        if j < t.len() && t[j].kind == TokKind::Punct && t[j].text == "!" {
            j += 1;
        }
        if !(j < t.len() && t[j].kind == TokKind::Punct && t[j].text == "[") {
            i += 1;
            continue;
        }
        let mut depth = 0i32;
        let mut has_test = false;
        let mut has_not = false;
        while j < t.len() {
            match (t[j].kind, t[j].text.as_str()) {
                (TokKind::Punct, "[") => depth += 1,
                (TokKind::Punct, "]") => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                (TokKind::Ident, "test") => has_test = true,
                (TokKind::Ident, "not") => has_not = true,
                _ => {}
            }
            j += 1;
        }
        if has_test && !has_not {
            let end = item_end(t, j + 1);
            for s in skip.iter_mut().take(end).skip(i) {
                *s = true;
            }
            i = end;
        } else {
            i = j + 1;
        }
    }
    skip
}

/// First index past the item starting at `i`: past the matching `}` of
/// the first `{`, or past a `;` seen before any brace.
fn item_end(t: &[Tok], mut i: usize) -> usize {
    let mut brace = 0i32;
    while i < t.len() {
        if t[i].kind == TokKind::Punct {
            match t[i].text.as_str() {
                "{" => brace += 1,
                "}" => {
                    brace -= 1;
                    if brace <= 0 {
                        return i + 1;
                    }
                }
                ";" if brace == 0 => return i + 1,
                _ => {}
            }
        }
        i += 1;
    }
    t.len()
}

// ---------------------------------------------------- enclosing symbol

/// Enclosing `fn` name per token (`-` at item level). Tracks brace
/// depth; a `fn` name is pushed when its body `{` opens and popped at
/// the matching `}`. Trait-method declarations (`fn f();`) never open.
fn enclosing_fn(t: &[Tok]) -> Vec<String> {
    let mut out = Vec::with_capacity(t.len());
    let mut stack: Vec<(String, i32)> = Vec::new();
    let mut depth = 0i32;
    let mut pending: Option<String> = None;
    for (i, tok) in t.iter().enumerate() {
        out.push(stack.last().map_or_else(|| "-".to_string(), |(n, _)| n.clone()));
        match (tok.kind, tok.text.as_str()) {
            (TokKind::Ident, "fn") => {
                if let Some(next) = t.get(i + 1) {
                    if next.kind == TokKind::Ident {
                        pending = Some(next.text.clone());
                    }
                }
            }
            (TokKind::Punct, "{") => {
                depth += 1;
                if let Some(name) = pending.take() {
                    stack.push((name, depth));
                }
            }
            (TokKind::Punct, "}") => {
                if stack.last().is_some_and(|&(_, d)| d == depth) {
                    stack.pop();
                }
                depth -= 1;
            }
            (TokKind::Punct, ";") => pending = None,
            _ => {}
        }
    }
    out
}

// -------------------------------------------------------------- rule L1

fn has_safety(line: &str) -> bool {
    line.contains("SAFETY") || line.contains("# Safety")
}

/// Is there a SAFETY comment on `line` itself, or in the contiguous run
/// of comment/attribute lines directly above it? The walk is raw-text
/// on purpose: the Rust and Python implementations cannot diverge over
/// comment token subtleties.
fn safety_nearby(lines: &[&str], line: u32) -> bool {
    let idx = line as usize - 1;
    if lines.get(idx).is_some_and(|l| has_safety(l)) {
        return true;
    }
    let mut k = idx;
    while k > 0 {
        k -= 1;
        let trimmed = lines[k].trim_start();
        let carrier = trimmed.starts_with("//")
            || trimmed.starts_with("#[")
            || trimmed.starts_with("#!");
        if !carrier {
            return false;
        }
        if has_safety(trimmed) {
            return true;
        }
    }
    false
}

fn rule_l1(cx: &Cx<'_>, out: &mut Vec<Finding>) {
    for (j, tok) in cx.t.iter().enumerate() {
        if cx.skip[j] || tok.kind != TokKind::Ident || tok.text != "unsafe" {
            continue;
        }
        if safety_nearby(cx.lines, tok.line) {
            continue;
        }
        // `unsafe fn name` reports under `name`, not the outer scope.
        let symbol = if cx.ident_at(j + 1, "fn") {
            cx.t.get(j + 2)
                .filter(|n| n.kind == TokKind::Ident)
                .map_or_else(|| cx.symbols[j].clone(), |n| n.text.clone())
        } else {
            cx.symbols[j].clone()
        };
        out.push(Finding {
            rule: Rule::L1,
            path: cx.path.to_string(),
            line: tok.line,
            symbol,
            message: "`unsafe` without an adjacent `// SAFETY:` comment".to_string(),
        });
    }
}

// -------------------------------------------------------------- rule L2

/// How far back from `as` the cast operand is searched for a
/// length-like name before giving up or hitting a statement boundary.
const CAST_LOOKBACK: usize = 12;
const CAST_STOPPERS: [&str; 5] = [";", "{", "}", ",", "="];

fn length_like(name: &str) -> bool {
    let n = name.to_ascii_lowercase();
    n.contains("len")
        || n.ends_with("size")
        || n.ends_with("count")
        || n.ends_with("capacity")
        || n.ends_with("offset")
        || n.ends_with("off")
        || n.starts_with("n_")
}

fn rule_l2(cx: &Cx<'_>, out: &mut Vec<Finding>) {
    for (j, tok) in cx.t.iter().enumerate() {
        if cx.skip[j] || tok.kind != TokKind::Ident || tok.text != "as" {
            continue;
        }
        let narrow = cx.ident_at(j + 1, "u16") || cx.ident_at(j + 1, "u32");
        if !narrow {
            continue;
        }
        let ty = cx.t[j + 1].text.clone();
        let mut culprit: Option<String> = None;
        for back in 1..=CAST_LOOKBACK {
            let Some(k) = j.checked_sub(back) else { break };
            let p = &cx.t[k];
            if p.kind == TokKind::Punct && CAST_STOPPERS.contains(&p.text.as_str()) {
                break;
            }
            if p.kind == TokKind::Ident && length_like(&p.text) {
                culprit = Some(p.text.clone());
                break;
            }
        }
        if let Some(name) = culprit {
            let message = format!(
                "truncating `as {ty}` on length-like `{name}` (route through check_wire_len)"
            );
            cx.push(out, Rule::L2, j, message);
        }
    }
}

// -------------------------------------------------------------- rule L3

const PANIC_MACROS: [&str; 4] = ["panic", "unreachable", "todo", "unimplemented"];

fn rule_l3(cx: &Cx<'_>, out: &mut Vec<Finding>) {
    for (j, tok) in cx.t.iter().enumerate() {
        if cx.skip[j] || tok.kind != TokKind::Ident {
            continue;
        }
        let name = tok.text.as_str();
        if (name == "unwrap" || name == "expect")
            && j > 0
            && cx.punct_at(j - 1, ".")
            && cx.punct_at(j + 1, "(")
        {
            cx.push(out, Rule::L3, j, format!("`.{name}()` in decode-reachable code"));
        } else if PANIC_MACROS.contains(&name) && cx.punct_at(j + 1, "!") {
            cx.push(out, Rule::L3, j, format!("`{name}!` in decode-reachable code"));
        }
    }
}

// -------------------------------------------------------------- rule L4

fn rule_l4(cx: &Cx<'_>, out: &mut Vec<Finding>) {
    for (j, tok) in cx.t.iter().enumerate() {
        if cx.skip[j] || tok.kind != TokKind::Ident {
            continue;
        }
        match tok.text.as_str() {
            "HashMap" | "HashSet" => {
                let message = format!("`{}` iteration order is nondeterministic", tok.text);
                cx.push(out, Rule::L4, j, message);
            }
            "SystemTime" => {
                cx.push(out, Rule::L4, j, "`SystemTime` in a coded zone".to_string());
            }
            "Instant" if cx.punct_at(j + 1, "::") && cx.ident_at(j + 2, "now") => {
                cx.push(out, Rule::L4, j, "`Instant::now` in a coded zone".to_string());
            }
            "env" => {
                let read = cx.punct_at(j + 1, "::")
                    && (cx.ident_at(j + 2, "var") || cx.ident_at(j + 2, "var_os"));
                if read {
                    let message = format!("`env::{}` reads the environment", cx.t[j + 2].text);
                    cx.push(out, Rule::L4, j, message);
                }
            }
            _ => {}
        }
    }
}

// -------------------------------------------------------------- rule L5

const FLOAT_METHODS: [&str; 17] = [
    "exp", "exp2", "exp_m1", "ln", "ln_1p", "log2", "log10", "powf", "powi", "sqrt", "recip",
    "hypot", "sin", "cos", "tan", "to_degrees", "to_radians",
];
const ARITH_OPS: [&str; 8] = ["+", "-", "*", "/", "+=", "-=", "*=", "/="];
/// Idents after which a `-` is a sign, not a subtraction.
const UNARY_PREV: [&str; 7] = ["return", "as", "else", "in", "match", "if", "while"];

fn floaty(tok: Option<&Tok>) -> bool {
    tok.is_some_and(|t| {
        t.kind == TokKind::Float
            || (t.kind == TokKind::Ident && (t.text == "f32" || t.text == "f64"))
    })
}

fn rule_l5(cx: &Cx<'_>, out: &mut Vec<Finding>) {
    for (j, tok) in cx.t.iter().enumerate() {
        if cx.skip[j] {
            continue;
        }
        if tok.kind == TokKind::Ident && j > 0 && cx.punct_at(j - 1, ".") {
            if tok.text == "mul_add" {
                cx.push(out, Rule::L5, j, "`mul_add` outside lm/kernels".to_string());
                continue;
            }
            if FLOAT_METHODS.contains(&tok.text.as_str()) && cx.punct_at(j + 1, "(") {
                let message = format!("float method `.{}()` outside lm/kernels", tok.text);
                cx.push(out, Rule::L5, j, message);
                continue;
            }
        }
        if tok.kind != TokKind::Punct || !ARITH_OPS.contains(&tok.text.as_str()) {
            continue;
        }
        if tok.text == "-" && minus_is_unary(cx, j) {
            continue;
        }
        let prev = if j > 0 { cx.t.get(j - 1) } else { None };
        if floaty(prev) || floaty(cx.t.get(j + 1)) {
            let message = format!("float arithmetic `{}` outside lm/kernels", tok.text);
            cx.push(out, Rule::L5, j, message);
        }
    }
}

/// A leading `-` (start of expression) negates a literal; only binary
/// minus is arithmetic.
fn minus_is_unary(cx: &Cx<'_>, j: usize) -> bool {
    let Some(k) = j.checked_sub(1) else { return true };
    let p = &cx.t[k];
    match p.kind {
        TokKind::Punct => p.text != ")" && p.text != "]",
        TokKind::Ident => UNARY_PREV.contains(&p.text.as_str()),
        _ => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn zones_all() -> Zones {
        Zones::parse(concat!(
            "scan = [\"\"]\n",
            "[zone.coded]\ninclude = [\"\"]\n",
            "[zone.decode_reachable]\ninclude = [\"\"]\n",
            "[zone.kernel]\ninclude = [\"kernel/\"]\n",
        ))
        .unwrap()
    }

    fn findings(src: &str) -> Vec<Finding> {
        scan_file("x.rs", src, &zones_all())
    }

    fn count(src: &str, rule: Rule) -> usize {
        findings(src).iter().filter(|f| f.rule == rule).count()
    }

    #[test]
    fn l1_unsafe_needs_safety() {
        assert_eq!(count("fn f() { unsafe { g() } }", Rule::L1), 1);
        assert_eq!(count("fn f() {\n    // SAFETY: g is fine\n    unsafe { g() }\n}", Rule::L1), 0);
        assert_eq!(count("fn f() { unsafe { g() } } // SAFETY: same line\n", Rule::L1), 0);
        // Walks through attribute + doc-comment runs.
        let doc = "/// # Safety\n/// caller checks\n#[inline]\npub unsafe fn f() {}\n";
        assert_eq!(count(doc, Rule::L1), 0);
        // A code line breaks the walk.
        let broken = "// SAFETY: too far\nlet y = 1;\nunsafe { g() }\n";
        assert_eq!(count(broken, Rule::L1), 1);
    }

    #[test]
    fn l1_symbol_is_fn_name() {
        let f = findings("unsafe fn boom() {}\n");
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].symbol, "boom");
        let f = findings("fn outer() { unsafe { g() } }\n");
        assert_eq!(f[0].symbol, "outer");
    }

    #[test]
    fn l2_flags_length_casts() {
        assert_eq!(count("fn f(b: &[u8]) { w(b.len() as u32); }", Rule::L2), 1);
        assert_eq!(count("fn f(b: &[u8]) { w(b.len() as u16); }", Rule::L2), 1);
        assert_eq!(count("fn f() { let x = comp_off as u32; }", Rule::L2), 1);
        // Widening casts and non-length operands are fine.
        assert_eq!(count("fn f(b: &[u8]) { w(b.len() as u64); }", Rule::L2), 0);
        assert_eq!(count("fn f(x: u64) { w(x as u32); }", Rule::L2), 0);
        // A statement boundary ends the lookback.
        assert_eq!(count("fn f(n: usize) { let _ = n.len(); let y = x as u32; }", Rule::L2), 0);
    }

    #[test]
    fn l3_flags_panic_paths_not_tests() {
        let src = "fn f(x: Option<u8>) { x.unwrap(); }";
        assert_eq!(count(src, Rule::L3), 1);
        assert_eq!(count("fn f() { panic!(\"boom\"); }", Rule::L3), 1);
        assert_eq!(count("fn f() { unreachable!() }", Rule::L3), 1);
        assert_eq!(count("fn f(x: Option<u8>) { x.unwrap_or(0); }", Rule::L3), 0);
        assert_eq!(count("#[test]\nfn t() { x.unwrap(); }", Rule::L3), 0);
        let module = "#[cfg(test)]\nmod tests {\n    fn t() { x.unwrap(); }\n}\nfn f() {}\n";
        assert_eq!(count(module, Rule::L3), 0);
        let not_test = "#[cfg(not(test))]\nfn f(x: Option<u8>) { x.unwrap(); }";
        assert_eq!(count(not_test, Rule::L3), 1);
    }

    #[test]
    fn l4_flags_nondeterminism() {
        assert_eq!(count("use std::collections::HashMap;", Rule::L4), 1);
        assert_eq!(count("fn f() { let s: HashSet<u8> = HashSet::new(); }", Rule::L4), 2);
        assert_eq!(count("fn f() { let t = Instant::now(); }", Rule::L4), 1);
        assert_eq!(count("fn f() { let v = std::env::var(\"X\"); }", Rule::L4), 1);
        // Instant as a type (metrics plumbing) is not the violation.
        assert_eq!(count("fn f(t: Instant) {}", Rule::L4), 0);
        assert_eq!(count("use std::collections::BTreeMap;", Rule::L4), 0);
    }

    #[test]
    fn l5_flags_float_arith_and_methods() {
        assert_eq!(count("fn f(x: f32) -> f32 { x * 2.0 }", Rule::L5), 1);
        assert_eq!(count("fn f(x: f32) -> f32 { x.exp() }", Rule::L5), 1);
        assert_eq!(count("fn f(x: f32) -> f32 { x.mul_add(2.0, 1.0) }", Rule::L5), 1);
        assert_eq!(count("fn f(x: u32) -> f32 { x as f32 / 3.0 }", Rule::L5), 1);
        // Integer arithmetic and negative float constants are fine.
        assert_eq!(count("fn f(x: u32) -> u32 { x * 2 }", Rule::L5), 0);
        assert_eq!(count("const X: f32 = -1.5;", Rule::L5), 0);
        assert_eq!(count("fn f() { g(-1.5); }", Rule::L5), 0);
        // Binary minus on floats IS arithmetic.
        assert_eq!(count("fn f(x: f32) -> f32 { x - 1.5 }", Rule::L5), 1);
    }

    #[test]
    fn l5_skipped_in_kernel_zone() {
        let src = "fn f(x: f32) -> f32 { x * 2.0 }";
        let z = zones_all();
        assert_eq!(scan_file("kernel/k.rs", src, &z).len(), 0);
        assert_eq!(scan_file("other/k.rs", src, &z).len(), 1);
    }

    #[test]
    fn waivers_cover_same_and_next_line() {
        let above = "fn f(x: Option<u8>) {\n    // lint: allow(L3) startup only\n    x.unwrap();\n}";
        assert_eq!(count(above, Rule::L3), 0);
        let trailing = "fn f(x: Option<u8>) { x.unwrap(); } // lint: allow(L3) startup only";
        assert_eq!(count(trailing, Rule::L3), 0);
        // A waiver for one rule does not silence another.
        let wrong = "fn f(x: Option<u8>) {\n    // lint: allow(L2) mismatched\n    x.unwrap();\n}";
        assert_eq!(count(wrong, Rule::L3), 1);
        // Multi-rule waivers.
        let multi = "fn f() {\n    // lint: allow(L3, L5) both\n    panic!(\"{}\", 1.0 * 2.0);\n}";
        assert_eq!(scan_file("x.rs", multi, &zones_all()).len(), 0);
    }

    #[test]
    fn rules_gate_on_zones() {
        let z = Zones::parse(concat!(
            "scan = [\"\"]\n",
            "[zone.coded]\ninclude = [\"coded/\"]\n",
            "[zone.decode_reachable]\ninclude = [\"coded/\", \"wire.rs\"]\n",
            "[zone.kernel]\ninclude = []\n",
        ))
        .unwrap();
        let src = "fn f(x: Option<u8>) { x.unwrap(); let m: HashMap<u8, u8>; }";
        // wire.rs: decode-reachable (L3 fires) but not coded (L4 silent).
        let wire: Vec<Rule> = scan_file("wire.rs", src, &z).iter().map(|f| f.rule).collect();
        assert_eq!(wire, vec![Rule::L3]);
        // coded/: both.
        assert_eq!(scan_file("coded/a.rs", src, &z).len(), 2);
        // outside both zones: neither (L1 still applies everywhere).
        assert_eq!(scan_file("elsewhere.rs", src, &z).len(), 0);
    }

    #[test]
    fn finding_keys_are_symbol_scoped() {
        let src = "fn a(x: Option<u8>) { x.unwrap(); }\nfn b(x: Option<u8>) { x.unwrap(); }";
        let keys: Vec<_> = findings(src).into_iter().map(|f| f.key()).collect();
        assert_eq!(keys.len(), 2);
        assert_ne!(keys[0], keys[1]);
        assert_eq!(keys[0].2, "a");
        assert_eq!(keys[1].2, "b");
    }
}
