//! Zone classification from a checked-in manifest (`lint/zones.toml`).
//!
//! The rules are not uniform over the tree: panic-freedom (L3) matters
//! exactly where hostile container bytes can reach, nondeterminism (L4)
//! matters exactly where bits are coded, and the f32 ban (L5) carves the
//! kernel layer OUT of the coded zone. Those boundaries are repository
//! policy, so they live in a committed manifest the linter reads — not in
//! linter source where they would drift silently.
//!
//! The manifest is a small TOML subset parsed by hand (zero deps):
//!
//! ```toml
//! scan = ["rust/src"]
//!
//! [zone.coded]
//! include = ["rust/src/compress/", "rust/src/entropy/"]
//! exclude = ["rust/src/lm/reference.rs"]
//! ```
//!
//! Matching is by path prefix on `/`-normalized paths relative to the
//! lint root: an entry ending in `/` matches the subtree, any other
//! entry matches the paths it prefixes (in practice, exactly that
//! file), `""` matches everything. `exclude` wins over `include`.

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

/// A manifest error, with the line that caused it.
#[derive(Debug)]
pub struct ManifestError {
    pub line: usize,
    pub message: String,
}

impl fmt::Display for ManifestError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "zones manifest line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for ManifestError {}

/// One zone: include/exclude prefix lists.
#[derive(Clone, Debug, Default)]
pub struct Zone {
    pub include: Vec<String>,
    pub exclude: Vec<String>,
}

impl Zone {
    fn matches_entry(entry: &str, path: &str) -> bool {
        entry.is_empty() || path == entry || path.starts_with(entry)
    }

    pub fn contains(&self, path: &str) -> bool {
        if self.exclude.iter().any(|e| Self::matches_entry(e, path)) {
            return false;
        }
        self.include.iter().any(|e| Self::matches_entry(e, path))
    }
}

/// The parsed manifest: scan roots plus named zones. Zone names the
/// rules engine relies on: `coded`, `decode_reachable`, `kernel`.
#[derive(Clone, Debug, Default)]
pub struct Zones {
    pub scan: Vec<String>,
    zones: BTreeMap<String, Zone>,
}

impl Zones {
    pub fn parse(src: &str) -> Result<Zones, ManifestError> {
        let mut zones = Zones::default();
        let mut section: Option<String> = None;
        for (idx, raw) in src.lines().enumerate() {
            let lineno = idx + 1;
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let Some(name) = rest.strip_suffix(']') else {
                    return Err(ManifestError {
                        line: lineno,
                        message: format!("unterminated section header: {raw}"),
                    });
                };
                let Some(zone) = name.strip_prefix("zone.") else {
                    return Err(ManifestError {
                        line: lineno,
                        message: format!("unknown section [{name}] (expected [zone.<name>])"),
                    });
                };
                zones.zones.entry(zone.to_string()).or_default();
                section = Some(zone.to_string());
                continue;
            }
            let Some((key, value)) = line.split_once('=') else {
                return Err(ManifestError {
                    line: lineno,
                    message: format!("expected `key = [..]`, got: {raw}"),
                });
            };
            let key = key.trim();
            let entries = parse_string_array(value.trim())
                .map_err(|message| ManifestError { line: lineno, message })?;
            match (&section, key) {
                (None, "scan") => zones.scan = entries,
                (None, other) => {
                    return Err(ManifestError {
                        line: lineno,
                        message: format!("unknown top-level key `{other}`"),
                    });
                }
                (Some(zone), "include") => {
                    zones.zones.get_mut(zone).unwrap().include = entries;
                }
                (Some(zone), "exclude") => {
                    zones.zones.get_mut(zone).unwrap().exclude = entries;
                }
                (Some(_), other) => {
                    return Err(ManifestError {
                        line: lineno,
                        message: format!("unknown zone key `{other}` (expected include/exclude)"),
                    });
                }
            }
        }
        if zones.scan.is_empty() {
            return Err(ManifestError {
                line: 0,
                message: "manifest must set `scan = [..]`".to_string(),
            });
        }
        Ok(zones)
    }

    pub fn zone(&self, name: &str) -> Option<&Zone> {
        self.zones.get(name)
    }

    /// Is `path` (lint-root-relative, `/`-separated) in zone `name`?
    /// Unknown zones contain nothing.
    pub fn in_zone(&self, name: &str, path: &str) -> bool {
        self.zones.get(name).is_some_and(|z| z.contains(path))
    }

    pub fn zone_names(&self) -> impl Iterator<Item = &str> {
        self.zones.keys().map(String::as_str)
    }
}

/// Normalize a path for zone matching: relative, forward slashes.
pub fn normalize(path: &Path) -> String {
    let s = path.to_string_lossy().replace('\\', "/");
    s.strip_prefix("./").unwrap_or(&s).to_string()
}

/// Strip a `#` comment, respecting double-quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Parse `["a", "b"]` (single line, trailing comma tolerated).
fn parse_string_array(value: &str) -> Result<Vec<String>, String> {
    let inner = value
        .strip_prefix('[')
        .and_then(|v| v.strip_suffix(']'))
        .ok_or_else(|| format!("expected a [..] array, got: {value}"))?;
    let mut out = Vec::new();
    for part in inner.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let unq = part
            .strip_prefix('"')
            .and_then(|p| p.strip_suffix('"'))
            .ok_or_else(|| format!("array entries must be double-quoted strings, got: {part}"))?;
        out.push(unq.to_string());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    const MANIFEST: &str = r#"
# comment
scan = ["rust/src"]

[zone.coded]
include = ["rust/src/compress/", "rust/src/entropy/", "rust/src/lm/"]
exclude = ["rust/src/lm/reference.rs"]

[zone.kernel]
include = ["rust/src/lm/kernels/"]
"#;

    #[test]
    fn parses_and_classifies() {
        let z = Zones::parse(MANIFEST).unwrap();
        assert_eq!(z.scan, vec!["rust/src"]);
        assert!(z.in_zone("coded", "rust/src/compress/llm.rs"));
        assert!(z.in_zone("coded", "rust/src/lm/kernels/avx2.rs"));
        assert!(!z.in_zone("coded", "rust/src/lm/reference.rs"));
        assert!(!z.in_zone("coded", "rust/src/coordinator/wire.rs"));
        assert!(z.in_zone("kernel", "rust/src/lm/kernels/mod.rs"));
        assert!(!z.in_zone("kernel", "rust/src/lm/native.rs"));
        assert!(!z.in_zone("nonexistent", "rust/src/lm/native.rs"));
    }

    #[test]
    fn exact_file_entries_and_match_all() {
        let z = Zones::parse(
            "scan = [\"\"]\n[zone.a]\ninclude = [\"x/y.rs\"]\n[zone.b]\ninclude = [\"\"]\n",
        )
        .unwrap();
        assert!(z.in_zone("a", "x/y.rs"));
        assert!(!z.in_zone("a", "x/y2.rs"));
        assert!(z.in_zone("b", "anything/at/all.rs"));
    }

    #[test]
    fn rejects_malformed() {
        assert!(Zones::parse("scan = [\"s\"]\n[weird]\n").is_err());
        assert!(Zones::parse("scan = [\"s\"]\nstray\n").is_err());
        assert!(Zones::parse("[zone.a]\ninclude = [\"x\"]\n").is_err(), "missing scan");
        assert!(Zones::parse("scan = [bare]\n").is_err(), "unquoted entry");
    }

    #[test]
    fn comment_stripping_respects_strings() {
        let z = Zones::parse("scan = [\"a#b/\"] # trailing\n[zone.z]\ninclude = [\"a#b/\"]\n")
            .unwrap();
        assert!(z.in_zone("z", "a#b/c.rs"));
    }
}
