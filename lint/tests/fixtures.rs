//! Library-level fixture assertions: exact finding counts per rule,
//! waiver handling, and zone gating, against `tests/fixtures/`.
//!
//! The counts asserted here are the contract the CI fixture legs and
//! the Python bootstrap mirror (`lint/tools/gen_baseline.py`) are
//! checked against — change a fixture and all three move together.

use pallas_lint::rules::{Finding, Rule};
use pallas_lint::scan_tree;
use pallas_lint::zones::Zones;
use std::path::{Path, PathBuf};

fn fixture_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures")
}

fn scan_fixtures() -> Vec<Finding> {
    let root = fixture_root();
    let zones_src = std::fs::read_to_string(root.join("zones.toml")).unwrap();
    let zones = Zones::parse(&zones_src).unwrap();
    scan_tree(&root, &zones).unwrap()
}

fn by_rule(findings: &[Finding], rule: Rule) -> Vec<&Finding> {
    findings.iter().filter(|f| f.rule == rule).collect()
}

#[test]
fn fixture_counts_are_exact() {
    let findings = scan_fixtures();
    assert_eq!(by_rule(&findings, Rule::L1).len(), 1);
    assert_eq!(by_rule(&findings, Rule::L2).len(), 1);
    assert_eq!(by_rule(&findings, Rule::L3).len(), 2);
    assert_eq!(by_rule(&findings, Rule::L4).len(), 5);
    assert_eq!(by_rule(&findings, Rule::L5).len(), 3);
    assert_eq!(findings.len(), 12, "total across all fixture files");
}

#[test]
fn violations_land_in_the_expected_files_and_symbols() {
    let findings = scan_fixtures();
    let l1 = by_rule(&findings, Rule::L1);
    assert_eq!(l1[0].path, "src/decode/l1_bad.rs");
    assert_eq!(l1[0].symbol, "first");

    // The 4 GiB truncation reproduction: the bare `payload.len() as u32`
    // the wire layer shipped before check_wire_len existed.
    let l2 = by_rule(&findings, Rule::L2);
    assert_eq!(l2[0].path, "src/decode/l2_bad.rs");
    assert_eq!(l2[0].symbol, "encode_header");
    assert!(l2[0].message.contains("check_wire_len"), "{}", l2[0].message);

    let l3 = by_rule(&findings, Rule::L3);
    assert!(l3.iter().all(|f| f.path == "src/decode/l3_bad.rs" && f.symbol == "parse"));
    assert!(l3.iter().any(|f| f.message.contains("unwrap")));
    assert!(l3.iter().any(|f| f.message.contains("panic!")));

    // Three HashMap mentions (one at item level), Instant::now, env::var.
    let l4 = by_rule(&findings, Rule::L4);
    assert!(l4.iter().all(|f| f.path == "src/coded/l4_bad.rs"));
    assert_eq!(l4.iter().filter(|f| f.symbol == "-").count(), 1, "use-level HashMap");
    assert_eq!(l4.iter().filter(|f| f.symbol == "entropy_order").count(), 4);

    let l5 = by_rule(&findings, Rule::L5);
    assert!(l5.iter().all(|f| f.path == "src/coded/l5_bad.rs" && f.symbol == "blend"));
}

#[test]
fn clean_waived_and_kernel_files_produce_nothing() {
    let findings = scan_fixtures();
    for quiet in [
        "src/decode/l1_clean.rs",
        "src/decode/l2_clean.rs",
        "src/decode/l3_clean.rs",
        "src/decode/waiver.rs",
        "src/coded/l4_clean.rs",
        "src/kernel/l5_kernel.rs",
    ] {
        assert!(
            findings.iter().all(|f| f.path != quiet),
            "expected no findings in {quiet}"
        );
    }
}

#[test]
fn kernel_zone_exempts_l5_but_not_the_other_rules() {
    // The kernel fixture is byte-identical arithmetic to l5_bad.rs; only
    // its zone differs. An unsafe block without SAFETY in the kernel
    // zone must still fire (the kernel L1 baseline ships empty).
    let root = fixture_root();
    let zones_src = std::fs::read_to_string(root.join("zones.toml")).unwrap();
    let zones = Zones::parse(&zones_src).unwrap();
    let kernel_src = "pub fn peek(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n";
    let f = pallas_lint::rules::scan_file("src/kernel/x.rs", kernel_src, &zones);
    assert_eq!(f.len(), 1);
    assert_eq!(f[0].rule, Rule::L1);
}
