//! Fixture: L4 — nondeterminism sources inside a coded zone.
//! Expected findings: three `HashMap` mentions, one `Instant::now`, one
//! `env::var` — five in total.

use std::collections::HashMap;

pub fn entropy_order(xs: &[u8]) -> usize {
    let start = std::time::Instant::now();
    let mut seen: HashMap<u8, u64> = HashMap::new();
    for &x in xs {
        *seen.entry(x).or_insert(0) += 1;
    }
    let _ = std::env::var("LLMZIP_SEED");
    let _ = start;
    seen.len()
}
