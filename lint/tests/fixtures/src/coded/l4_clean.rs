//! Fixture: L4 counterpart — deterministic containers only.

use std::collections::BTreeMap;

pub fn histogram(xs: &[u8]) -> BTreeMap<u8, u64> {
    let mut h = BTreeMap::new();
    for &x in xs {
        *h.entry(x).or_insert(0) += 1;
    }
    h
}
