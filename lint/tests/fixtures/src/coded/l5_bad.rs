//! Fixture: L5 — float arithmetic outside the kernel layer.
//! Expected findings: one `*` with a float operand, one `mul_add`, one
//! `.exp()` — three in total.

pub fn blend(x: f32, a: f32, b: f32) -> f32 {
    let y = x * 0.5f32;
    y.mul_add(a, b).exp()
}
