//! Fixture: L1 — `unsafe` with no adjacent SAFETY comment.

pub fn first(xs: &[u8]) -> u8 {
    let p = xs.as_ptr();
    unsafe { *p }
}
