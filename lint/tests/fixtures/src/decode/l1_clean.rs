//! Fixture: L1 counterpart — the same read, justified.

pub fn first(xs: &[u8]) -> u8 {
    assert!(!xs.is_empty());
    let p = xs.as_ptr();
    // SAFETY: the assert above guarantees at least one element.
    unsafe { *p }
}
