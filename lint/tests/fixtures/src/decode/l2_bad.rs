//! Fixture: L2 — the 4 GiB wire-truncation bug, verbatim shape.
//!
//! This is the exact pattern the coordinator's frame encoder shipped
//! before PR 9 fixed it: once `payload` reaches 4 GiB the `as u32`
//! wraps, the header's length field lies, and the peer misparses every
//! byte that follows. `check_wire_len` (rust/src/coordinator/wire.rs)
//! is the sanctioned replacement — it refuses over-cap payloads before
//! any header byte reaches the wire.

pub fn encode_header(typ: u8, req_id: u32, payload: &[u8]) -> Vec<u8> {
    let mut hdr = vec![0u8; 9];
    hdr[0] = typ;
    hdr[1..5].copy_from_slice(&req_id.to_le_bytes());
    hdr[5..9].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    hdr
}
