//! Fixture: L2 counterpart — widen instead of truncating.

pub fn encoded_len(payload: &[u8]) -> u64 {
    payload.len() as u64
}
