//! Fixture: L3 — panic paths on the decode side.

pub fn parse(bytes: &[u8]) -> u8 {
    let first = bytes.first().unwrap();
    if *first == 0xFF {
        panic!("reserved marker");
    }
    *first
}
