//! Fixture: L3 counterpart — hostile bytes become named errors.

pub fn parse(bytes: &[u8]) -> Result<u8, String> {
    match bytes.first() {
        Some(&b) if b != 0xFF => Ok(b),
        Some(_) => Err("reserved marker".to_string()),
        None => Err("empty input".to_string()),
    }
}
