//! Fixture: waiver grammar — a violation with a written-down reason is
//! not reported.

pub fn tail(xs: &[u8]) -> u8 {
    // lint: allow(L3) fixture: documented invariant, xs is never empty
    xs.last().copied().unwrap()
}
