//! Fixture: L5 counterpart — byte-for-byte the same arithmetic as
//! `coded/l5_bad.rs`, but in the kernel zone, where the fixed-tree
//! contract makes float arithmetic the point rather than the bug.

pub fn blend(x: f32, a: f32, b: f32) -> f32 {
    let y = x * 0.5f32;
    y.mul_add(a, b).exp()
}
