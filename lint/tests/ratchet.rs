//! End-to-end CLI tests: exit codes, per-rule fixture legs, the
//! baseline ratchet (growth fails, improvements pass), and the JSON
//! report — everything CI's `lint` job relies on.

use std::path::PathBuf;
use std::process::{Command, Output};

/// Run the built binary against the fixture tree with an explicit
/// baseline file.
fn lint(baseline: &str, extra: &[&str]) -> Output {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_pallas-lint"));
    cmd.current_dir(env!("CARGO_MANIFEST_DIR"))
        .arg("--check")
        .args(["--root", "tests/fixtures"])
        .args(["--zones", "zones.toml"])
        .args(["--baseline", baseline])
        .args(extra);
    cmd.output().expect("spawn pallas-lint")
}

fn stdout(out: &Output) -> String {
    String::from_utf8_lossy(&out.stdout).into_owned()
}

/// A scratch path for baselines the tests generate; absolute, so it
/// survives the CLI's `--root`-relative join.
fn scratch(name: &str) -> String {
    let p: PathBuf =
        std::env::temp_dir().join(format!("pallas-lint-{}-{name}", std::process::id()));
    p.to_string_lossy().into_owned()
}

#[test]
fn empty_baseline_fails_with_regressions() {
    let out = lint("baseline_empty.txt", &[]);
    assert_eq!(out.status.code(), Some(1));
    let text = stdout(&out);
    assert!(text.contains("REGRESSION"), "{text}");
    assert!(text.contains("FAIL"), "{text}");
    // The truncation fixture surfaces with the fix pointer in the message.
    assert!(text.contains("check_wire_len"), "{text}");
}

#[test]
fn each_rule_fails_its_own_fixture_leg() {
    // `--check --only L<n>` must exit non-zero for every violation
    // class — the CI legs assert exactly this, one rule at a time.
    for rule in ["L1", "L2", "L3", "L4", "L5"] {
        let out = lint("baseline_empty.txt", &["--only", rule]);
        assert_eq!(out.status.code(), Some(1), "rule {rule} leg must fail");
        let text = stdout(&out);
        assert!(text.contains(&format!("REGRESSION {rule}")), "rule {rule}: {text}");
    }
}

#[test]
fn update_then_check_is_clean_and_growth_fails() {
    let base = scratch("ratchet.txt");
    // 1. Capture the current findings as the baseline.
    let out = lint(&base, &["--update-baseline"]);
    assert_eq!(out.status.code(), Some(0), "{}", stdout(&out));
    // 2. A check against that baseline is clean.
    let out = lint(&base, &[]);
    assert_eq!(out.status.code(), Some(0), "{}", stdout(&out));
    assert!(stdout(&out).contains("pallas-lint: ok"));
    // 3. Shrink one allowance (simulating a baseline that predates a
    //    newly-introduced finding): the ratchet must fail the check.
    let text = std::fs::read_to_string(&base).unwrap();
    let shrunk: String = text
        .lines()
        .map(|l| {
            if l.starts_with("L3") {
                let mut fields: Vec<&str> = l.split('\t').collect();
                assert_eq!(fields.pop(), Some("2"), "fixture L3 count moved; update this test");
                format!("{}\t1\n", fields.join("\t"))
            } else {
                format!("{l}\n")
            }
        })
        .collect();
    assert_ne!(shrunk, text, "expected an L3 entry to shrink");
    std::fs::write(&base, shrunk).unwrap();
    let out = lint(&base, &[]);
    assert_eq!(out.status.code(), Some(1));
    assert!(stdout(&out).contains("REGRESSION L3"), "{}", stdout(&out));
    // 4. An allowance larger than reality is only an improvement note.
    let grown: String = std::fs::read_to_string(&base)
        .unwrap()
        .lines()
        .map(|l| {
            if l.starts_with("L3") {
                let mut fields: Vec<&str> = l.split('\t').collect();
                fields.pop();
                format!("{}\t9\n", fields.join("\t"))
            } else {
                format!("{l}\n")
            }
        })
        .collect();
    std::fs::write(&base, grown).unwrap();
    let out = lint(&base, &[]);
    assert_eq!(out.status.code(), Some(0), "{}", stdout(&out));
    assert!(stdout(&out).contains("improved"), "{}", stdout(&out));
    std::fs::remove_file(&base).ok();
}

#[test]
fn json_report_carries_the_verdict() {
    let out = lint("baseline_empty.txt", &["--json"]);
    assert_eq!(out.status.code(), Some(1));
    let j = stdout(&out);
    assert!(j.contains("\"ok\": false"), "{j}");
    assert!(j.contains("\"rule\": \"L2\""), "{j}");
    assert_eq!(j.matches('{').count(), j.matches('}').count());
}

#[test]
fn missing_baseline_is_a_config_error_not_a_pass() {
    let out = lint("does_not_exist.txt", &[]);
    assert_eq!(out.status.code(), Some(2));
    let err = String::from_utf8_lossy(&out.stderr).into_owned();
    assert!(err.contains("--update-baseline"), "{err}");
}
