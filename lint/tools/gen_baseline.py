#!/usr/bin/env python3
"""Bootstrap mirror of pallas-lint for environments without cargo.

This is a line-for-line transliteration of the Rust scanner
(lint/src/{lexer,zones,rules,baseline}.rs). Its only job is to produce
`lint/baseline.txt` (and fixture expectations) in environments where the
Rust toolchain is unavailable, so the committed baseline can exist
before the first `cargo run -p pallas-lint` ever executes. The Rust
binary is the source of truth; when both are available, their outputs
must be identical — `lint/tests/` pins the fixture counts both
implementations are checked against.

Usage:
    python3 lint/tools/gen_baseline.py \
        [--root DIR] [--zones FILE] [--out FILE] [--findings]

`--zones` and `--out` are resolved relative to `--root` (default `.`),
matching the CLI. `--out -` writes the baseline to stdout; `--findings`
prints individual findings (rule, path, line, symbol, message) instead.
"""

import os
import sys

# ---------------------------------------------------------------- lexer
# Mirrors lint/src/lexer.rs. Tokens are (kind, text, line) with kinds:
IDENT = "Ident"
LIFETIME = "Lifetime"
INT = "Int"
FLOAT = "Float"
STR = "Str"
CHAR = "Char"
LINE_COMMENT = "LineComment"
BLOCK_COMMENT = "BlockComment"
PUNCT = "Punct"

MULTI_PUNCT = [
    "<<=", ">>=", "..=", "...", "::", "->", "=>", "==", "!=", "<=", ">=",
    "&&", "||", "+=", "-=", "*=", "/=", "%=", "^=", "&=", "|=", "<<", ">>",
    "..",
]


def _is_ident_start(c):
    return c.isalpha() or c == "_"


def _is_ident_cont(c):
    return c.isalnum() or c == "_"


class _Lexer:
    def __init__(self, src):
        # Decode as the Rust side does (lossy): errors never abort a scan.
        self.b = src
        self.i = 0
        self.line = 1
        self.out = []

    def peek(self, ahead=0):
        j = self.i + ahead
        return self.b[j] if j < len(self.b) else "\0"

    def bump(self):
        if self.peek(0) == "\n":
            self.line += 1
        self.i += 1

    def push(self, kind, start, line):
        self.out.append((kind, self.b[start:self.i], line))

    def run(self):
        while self.i < len(self.b):
            c = self.peek(0)
            start, line = self.i, self.line
            if c.isspace():
                self.bump()
            elif c == "/" and self.peek(1) == "/":
                while self.i < len(self.b) and self.peek(0) != "\n":
                    self.bump()
                self.push(LINE_COMMENT, start, line)
            elif c == "/" and self.peek(1) == "*":
                self.block_comment()
                self.push(BLOCK_COMMENT, start, line)
            elif c == "r" and self.raw_string_ahead_at(0):
                self.raw_string()
                self.push(STR, start, line)
            elif c == "b" and self.peek(1) == "r" and self.raw_string_ahead_at(1):
                self.bump()
                self.raw_string()
                self.push(STR, start, line)
            elif c == "b" and self.peek(1) == '"':
                self.bump()
                self.quoted('"')
                self.push(STR, start, line)
            elif c == "b" and self.peek(1) == "'":
                self.bump()
                self.quoted("'")
                self.push(CHAR, start, line)
            elif c == "r" and self.peek(1) == "#" and _is_ident_start(self.peek(2)):
                self.bump()
                self.bump()
                while _is_ident_cont(self.peek(0)):
                    self.bump()
                self.out.append((IDENT, self.b[start + 2:self.i], line))
            elif _is_ident_start(c):
                while _is_ident_cont(self.peek(0)):
                    self.bump()
                self.push(IDENT, start, line)
            elif c.isdigit():
                kind = self.number()
                self.push(kind, start, line)
            elif c == '"':
                self.quoted('"')
                self.push(STR, start, line)
            elif c == "'":
                self.lifetime_or_char(start, line)
            else:
                self.punct(start, line)
        return self.out

    def block_comment(self):
        self.bump()
        self.bump()
        depth = 1
        while self.i < len(self.b) and depth > 0:
            if self.peek(0) == "/" and self.peek(1) == "*":
                depth += 1
                self.bump()
                self.bump()
            elif self.peek(0) == "*" and self.peek(1) == "/":
                depth -= 1
                self.bump()
                self.bump()
            else:
                self.bump()

    def raw_string_ahead_at(self, at):
        j = at + 1
        while self.peek(j) == "#":
            j += 1
        return self.peek(j) == '"'

    def raw_string(self):
        self.bump()  # r
        hashes = 0
        while self.peek(0) == "#":
            hashes += 1
            self.bump()
        self.bump()  # opening quote
        while self.i < len(self.b):
            if self.peek(0) == '"':
                ok = all(self.peek(1 + k) == "#" for k in range(hashes))
                if ok:
                    for _ in range(hashes + 1):
                        self.bump()
                    return
            self.bump()

    def quoted(self, q):
        self.bump()
        while self.i < len(self.b):
            c = self.peek(0)
            if c == "\\":
                self.bump()
                self.bump()
            elif c == q:
                self.bump()
                return
            else:
                self.bump()

    def number(self):
        is_float = False
        if self.peek(0) == "0" and self.peek(1) in ("x", "o", "b"):
            self.bump()
            self.bump()
            while _is_ident_cont(self.peek(0)):
                self.bump()
            return INT
        while self.peek(0).isdigit() or self.peek(0) == "_":
            self.bump()
        if self.peek(0) == "." and self.peek(1).isdigit():
            is_float = True
            self.bump()
            while self.peek(0).isdigit() or self.peek(0) == "_":
                self.bump()
        if self.peek(0) in ("e", "E") and (
            self.peek(1).isdigit()
            or (self.peek(1) in ("+", "-") and self.peek(2).isdigit())
        ):
            is_float = True
            self.bump()
            self.bump()
            while self.peek(0).isdigit() or self.peek(0) == "_":
                self.bump()
        suffix_at = self.i
        while _is_ident_cont(self.peek(0)):
            self.bump()
        suffix = self.b[suffix_at:self.i]
        if suffix in ("f32", "f64"):
            is_float = True
        return FLOAT if is_float else INT

    def lifetime_or_char(self, start, line):
        if self.peek(1) == "\\":
            self.quoted("'")
            self.push(CHAR, start, line)
        elif _is_ident_start(self.peek(1)):
            j = 2
            while _is_ident_cont(self.peek(j)):
                j += 1
            if self.peek(j) == "'":
                self.quoted("'")
                self.push(CHAR, start, line)
            else:
                self.bump()
                while _is_ident_cont(self.peek(0)):
                    self.bump()
                self.push(LIFETIME, start, line)
        else:
            self.quoted("'")
            self.push(CHAR, start, line)

    def punct(self, start, line):
        for op in MULTI_PUNCT:
            if self.b.startswith(op, self.i):
                for _ in range(len(op)):
                    self.bump()
                self.push(PUNCT, start, line)
                return
        self.bump()
        self.push(PUNCT, start, line)


def lex(src):
    return _Lexer(src).run()


# ---------------------------------------------------------------- zones
# Mirrors lint/src/zones.rs (the same TOML subset, same errors).


def _strip_comment(line):
    in_str = False
    for i, c in enumerate(line):
        if c == '"':
            in_str = not in_str
        elif c == "#" and not in_str:
            return line[:i]
    return line


def _parse_string_array(value, lineno):
    if not (value.startswith("[") and value.endswith("]")):
        raise SystemExit(f"zones manifest line {lineno}: expected a [..] array, got: {value}")
    out = []
    for part in value[1:-1].split(","):
        part = part.strip()
        if not part:
            continue
        if not (part.startswith('"') and part.endswith('"') and len(part) >= 2):
            raise SystemExit(
                f"zones manifest line {lineno}: array entries must be double-quoted "
                f"strings, got: {part}"
            )
        out.append(part[1:-1])
    return out


def parse_zones(src):
    scan = []
    zones = {}
    section = None
    for idx, raw in enumerate(src.split("\n")):
        lineno = idx + 1
        line = _strip_comment(raw).strip()
        if not line:
            continue
        if line.startswith("["):
            if not line.endswith("]"):
                raise SystemExit(
                    f"zones manifest line {lineno}: unterminated section header: {raw}"
                )
            name = line[1:-1]
            if not name.startswith("zone."):
                raise SystemExit(
                    f"zones manifest line {lineno}: unknown section [{name}] "
                    f"(expected [zone.<name>])"
                )
            section = name[len("zone."):]
            zones.setdefault(section, {"include": [], "exclude": []})
            continue
        if "=" not in line:
            raise SystemExit(f"zones manifest line {lineno}: expected `key = [..]`, got: {raw}")
        key, value = line.split("=", 1)
        key = key.strip()
        entries = _parse_string_array(value.strip(), lineno)
        if section is None:
            if key != "scan":
                raise SystemExit(f"zones manifest line {lineno}: unknown top-level key `{key}`")
            scan = entries
        elif key in ("include", "exclude"):
            zones[section][key] = entries
        else:
            raise SystemExit(
                f"zones manifest line {lineno}: unknown zone key `{key}` "
                f"(expected include/exclude)"
            )
    if not scan:
        raise SystemExit("zones manifest: must set `scan = [..]`")
    return scan, zones


def _matches_entry(entry, path):
    return entry == "" or path == entry or path.startswith(entry)


def in_zone(zones, name, path):
    z = zones.get(name)
    if z is None:
        return False
    if any(_matches_entry(e, path) for e in z["exclude"]):
        return False
    return any(_matches_entry(e, path) for e in z["include"])


def normalize(path):
    s = path.replace("\\", "/")
    return s[2:] if s.startswith("./") else s


# ---------------------------------------------------------------- rules
# Mirrors lint/src/rules.rs. Findings are (rule, path, line, symbol,
# message) tuples.

RULES = ["L1", "L2", "L3", "L4", "L5"]
WAIVER_MARK = "lint: allow("
CAST_LOOKBACK = 12
CAST_STOPPERS = (";", "{", "}", ",", "=")
PANIC_MACROS = ("panic", "unreachable", "todo", "unimplemented")
FLOAT_METHODS = (
    "exp", "exp2", "exp_m1", "ln", "ln_1p", "log2", "log10", "powf", "powi",
    "sqrt", "recip", "hypot", "sin", "cos", "tan", "to_degrees", "to_radians",
)
ARITH_OPS = ("+", "-", "*", "/", "+=", "-=", "*=", "/=")
UNARY_PREV = ("return", "as", "else", "in", "match", "if", "while")


def _parse_rule(s):
    s = s.upper()
    return s if s in RULES else None


def collect_waivers(toks):
    waivers = {}
    for kind, text, line in toks:
        if kind not in (LINE_COMMENT, BLOCK_COMMENT):
            continue
        pos = text.find(WAIVER_MARK)
        if pos < 0:
            continue
        rest = text[pos + len(WAIVER_MARK):]
        end = rest.find(")")
        if end < 0:
            continue
        rules = []
        for piece in rest[:end].replace(",", " ").split(" "):
            r = _parse_rule(piece)
            if r:
                rules.append(r)
        if rules:
            waivers.setdefault(line, []).extend(rules)
    return waivers


def waived(waivers, rule, line):
    if rule in waivers.get(line, ()):
        return True
    return line > 1 and rule in waivers.get(line - 1, ())


def test_item_mask(t):
    skip = [False] * len(t)
    i = 0
    while i < len(t):
        if not (t[i][0] == PUNCT and t[i][1] == "#"):
            i += 1
            continue
        j = i + 1
        if j < len(t) and t[j][0] == PUNCT and t[j][1] == "!":
            j += 1
        if not (j < len(t) and t[j][0] == PUNCT and t[j][1] == "["):
            i += 1
            continue
        depth = 0
        has_test = False
        has_not = False
        while j < len(t):
            kind, text = t[j][0], t[j][1]
            if kind == PUNCT and text == "[":
                depth += 1
            elif kind == PUNCT and text == "]":
                depth -= 1
                if depth == 0:
                    break
            elif kind == IDENT and text == "test":
                has_test = True
            elif kind == IDENT and text == "not":
                has_not = True
            j += 1
        if has_test and not has_not:
            end = item_end(t, j + 1)
            for s in range(i, end):
                skip[s] = True
            i = end
        else:
            i = j + 1
    return skip


def item_end(t, i):
    brace = 0
    while i < len(t):
        if t[i][0] == PUNCT:
            text = t[i][1]
            if text == "{":
                brace += 1
            elif text == "}":
                brace -= 1
                if brace <= 0:
                    return i + 1
            elif text == ";" and brace == 0:
                return i + 1
        i += 1
    return len(t)


def enclosing_fn(t):
    out = []
    stack = []
    depth = 0
    pending = None
    for i, (kind, text, _line) in enumerate(t):
        out.append(stack[-1][0] if stack else "-")
        if kind == IDENT and text == "fn":
            if i + 1 < len(t) and t[i + 1][0] == IDENT:
                pending = t[i + 1][1]
        elif kind == PUNCT and text == "{":
            depth += 1
            if pending is not None:
                stack.append((pending, depth))
                pending = None
        elif kind == PUNCT and text == "}":
            if stack and stack[-1][1] == depth:
                stack.pop()
            depth -= 1
        elif kind == PUNCT and text == ";":
            pending = None
    return out


def _has_safety(line):
    return "SAFETY" in line or "# Safety" in line


def safety_nearby(lines, line):
    idx = line - 1
    if idx < len(lines) and _has_safety(lines[idx]):
        return True
    k = idx
    while k > 0:
        k -= 1
        trimmed = lines[k].lstrip()
        carrier = (
            trimmed.startswith("//")
            or trimmed.startswith("#[")
            or trimmed.startswith("#!")
        )
        if not carrier:
            return False
        if _has_safety(trimmed):
            return True
    return False


def _length_like(name):
    n = name.lower()
    return (
        "len" in n
        or n.endswith("size")
        or n.endswith("count")
        or n.endswith("capacity")
        or n.endswith("offset")
        or n.endswith("off")
        or n.startswith("n_")
    )


def _ident_at(t, j, text):
    return 0 <= j < len(t) and t[j][0] == IDENT and t[j][1] == text


def _punct_at(t, j, text):
    return 0 <= j < len(t) and t[j][0] == PUNCT and t[j][1] == text


def _floaty(tok):
    if tok is None:
        return False
    kind, text = tok[0], tok[1]
    return kind == FLOAT or (kind == IDENT and text in ("f32", "f64"))


def scan_file(path, src, scan_zones):
    coded = in_zone(scan_zones, "coded", path)
    decode = in_zone(scan_zones, "decode_reachable", path)
    kernel = in_zone(scan_zones, "kernel", path)

    all_toks = lex(src)
    lines = [l[:-1] if l.endswith("\r") else l for l in src.split("\n")]
    waivers = collect_waivers(all_toks)
    t = [tok for tok in all_toks if tok[0] not in (LINE_COMMENT, BLOCK_COMMENT)]
    skip = test_item_mask(t)
    symbols = enclosing_fn(t)

    out = []

    def push(rule, j, message, symbol=None):
        out.append((rule, path, t[j][2], symbol if symbol else symbols[j], message))

    # L1 — every file under scan.
    for j, (kind, text, line) in enumerate(t):
        if skip[j] or kind != IDENT or text != "unsafe":
            continue
        if safety_nearby(lines, line):
            continue
        symbol = None
        if _ident_at(t, j + 1, "fn") and j + 2 < len(t) and t[j + 2][0] == IDENT:
            symbol = t[j + 2][1]
        push("L1", j, "`unsafe` without an adjacent `// SAFETY:` comment", symbol)

    if decode:
        # L2 — truncating casts on length-like expressions.
        for j, (kind, text, _line) in enumerate(t):
            if skip[j] or kind != IDENT or text != "as":
                continue
            if not (_ident_at(t, j + 1, "u16") or _ident_at(t, j + 1, "u32")):
                continue
            ty = t[j + 1][1]
            culprit = None
            for back in range(1, CAST_LOOKBACK + 1):
                k = j - back
                if k < 0:
                    break
                pk, pt = t[k][0], t[k][1]
                if pk == PUNCT and pt in CAST_STOPPERS:
                    break
                if pk == IDENT and _length_like(pt):
                    culprit = pt
                    break
            if culprit is not None:
                push(
                    "L2", j,
                    f"truncating `as {ty}` on length-like `{culprit}` "
                    f"(route through check_wire_len)",
                )
        # L3 — panic paths.
        for j, (kind, text, _line) in enumerate(t):
            if skip[j] or kind != IDENT:
                continue
            if (
                text in ("unwrap", "expect")
                and j > 0
                and _punct_at(t, j - 1, ".")
                and _punct_at(t, j + 1, "(")
            ):
                push("L3", j, f"`.{text}()` in decode-reachable code")
            elif text in PANIC_MACROS and _punct_at(t, j + 1, "!"):
                push("L3", j, f"`{text}!` in decode-reachable code")

    if coded:
        # L4 — nondeterminism sources.
        for j, (kind, text, _line) in enumerate(t):
            if skip[j] or kind != IDENT:
                continue
            if text in ("HashMap", "HashSet"):
                push("L4", j, f"`{text}` iteration order is nondeterministic")
            elif text == "SystemTime":
                push("L4", j, "`SystemTime` in a coded zone")
            elif text == "Instant" and _punct_at(t, j + 1, "::") and _ident_at(t, j + 2, "now"):
                push("L4", j, "`Instant::now` in a coded zone")
            elif text == "env":
                read = _punct_at(t, j + 1, "::") and (
                    _ident_at(t, j + 2, "var") or _ident_at(t, j + 2, "var_os")
                )
                if read:
                    push("L4", j, f"`env::{t[j + 2][1]}` reads the environment")
        if not kernel:
            # L5 — float arithmetic and methods.
            for j, (kind, text, _line) in enumerate(t):
                if skip[j]:
                    continue
                if kind == IDENT and j > 0 and _punct_at(t, j - 1, "."):
                    if text == "mul_add":
                        push("L5", j, "`mul_add` outside lm/kernels")
                        continue
                    if text in FLOAT_METHODS and _punct_at(t, j + 1, "("):
                        push("L5", j, f"float method `.{text}()` outside lm/kernels")
                        continue
                if kind != PUNCT or text not in ARITH_OPS:
                    continue
                if text == "-" and _minus_is_unary(t, j):
                    continue
                prev = t[j - 1] if j > 0 else None
                nxt = t[j + 1] if j + 1 < len(t) else None
                if _floaty(prev) or _floaty(nxt):
                    push("L5", j, f"float arithmetic `{text}` outside lm/kernels")

    out = [f for f in out if not waived(waivers, f[0], f[2])]
    out.sort(key=lambda f: (f[2], f[0]))
    return out


def _minus_is_unary(t, j):
    if j == 0:
        return True
    kind, text = t[j - 1][0], t[j - 1][1]
    if kind == PUNCT:
        return text not in (")", "]")
    if kind == IDENT:
        return text in UNARY_PREV
    return False


# ------------------------------------------------------------- baseline

HEADER = (
    "# pallas-lint baseline: pre-existing findings, allowed to shrink but "
    "never to grow.\n"
    "# Format: rule<TAB>path<TAB>symbol<TAB>count (sorted). Do not edit by "
    "hand;\n"
    "# regenerate with `cargo run -p pallas-lint -- --update-baseline` after "
    "fixing findings.\n"
)


def render_baseline(findings):
    counts = {}
    for rule, path, _line, symbol, _message in findings:
        key = (rule, path, symbol)
        counts[key] = counts.get(key, 0) + 1
    out = [HEADER]
    for (rule, path, symbol) in sorted(counts):
        out.append(f"{rule}\t{path}\t{symbol}\t{counts[(rule, path, symbol)]}\n")
    return "".join(out)


# ----------------------------------------------------------------- walk


def collect_rs_files(root, scan):
    files = []

    def walk(d):
        if os.path.isfile(d):
            files.append(d)
            return
        entries = sorted(os.path.join(d, e) for e in os.listdir(d))
        for p in entries:
            if os.path.isdir(p):
                walk(p)
            elif p.endswith(".rs"):
                files.append(p)

    for s in scan:
        walk(os.path.join(root, s) if s else root)
    return sorted(set(files))


def scan_tree(root, scan, zones):
    findings = []
    for f in collect_rs_files(root, scan):
        with open(f, "r", encoding="utf-8", errors="replace") as fh:
            src = fh.read()
        rel = os.path.relpath(f, root)
        findings.extend(scan_file(normalize(rel), src, zones))
    return findings


# ----------------------------------------------------------------- main


def main(argv):
    root, zones_path, out_path, list_findings = ".", "lint/zones.toml", "lint/baseline.txt", False
    i = 0
    while i < len(argv):
        a = argv[i]
        if a == "--root":
            i += 1
            root = argv[i]
        elif a == "--zones":
            i += 1
            zones_path = argv[i]
        elif a == "--out":
            i += 1
            out_path = argv[i]
        elif a == "--findings":
            list_findings = True
        else:
            raise SystemExit(f"unknown argument `{a}` (see the module docstring)")
        i += 1
    with open(os.path.join(root, zones_path), "r", encoding="utf-8") as fh:
        scan, zones = parse_zones(fh.read())
    findings = scan_tree(root, scan, zones)
    if list_findings:
        for rule, path, line, symbol, message in findings:
            sys.stdout.write(f"{rule}\t{path}\t{line}\t{symbol}\t{message}\n")
        sys.stdout.write(f"# {len(findings)} finding(s)\n")
        return
    rendered = render_baseline(findings)
    if out_path == "-":
        sys.stdout.write(rendered)
    else:
        target = os.path.join(root, out_path)
        with open(target, "w", encoding="utf-8") as fh:
            fh.write(rendered)
        entries = sum(1 for l in rendered.splitlines() if l and not l.startswith("#"))
        sys.stdout.write(
            f"gen_baseline: wrote {entries} entries ({len(findings)} findings) to {target}\n"
        )


if __name__ == "__main__":
    main(sys.argv[1:])
