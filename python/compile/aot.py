"""AOT pipeline: train the model family (if weights are missing) and lower
the serving functions to HLO *text* artifacts for the rust runtime.

HLO text — NOT `.serialize()` — is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published `xla` crate) rejects; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs:
  artifacts/weights/<model>.lmz
  artifacts/hlo/<model>__forward_b{B}_s{S}.hlo.txt
  artifacts/hlo/<model>__step_b{B}_s{S}.hlo.txt
  artifacts/hlo/<model>__generate_b{B}_p{P}_n{N}.hlo.txt
  artifacts/hlo/medium__forward_pallas_b1_s{S}.hlo.txt   (kernel parity)
  artifacts/manifest.txt

Usage: python -m compile.aot [--corpus DIR] [--out DIR] [--models a,b,...]
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import configs, model, train, weights


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_forward(cfg, batch, seq, impl):
    spec = model.param_spec(cfg)

    def fn(*args):
        flat, tokens = args[:-1], args[-1]
        params = model.unflatten_params(cfg, flat)
        return (model.forward_logits(cfg, params, tokens, impl=impl),)

    shapes = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in spec]
    shapes.append(jax.ShapeDtypeStruct((batch, seq), jnp.int32))
    return jax.jit(fn).lower(*shapes)


def lower_step(cfg, batch, seq):
    spec = model.param_spec(cfg)

    def fn(*args):
        flat, kv, tok, pos = args[:-3], args[-3], args[-2], args[-1]
        params = model.unflatten_params(cfg, flat)
        logits, kv2 = model.decode_step(cfg, params, kv, tok, pos)
        # Single flat output: the PJRT wrapper in the published xla crate
        # cannot fetch multi-element tuple buffers (CHECK shape.IsArray()).
        # Layout: [logits.flatten() | kv2.flatten()].
        return (jnp.concatenate([logits.reshape(-1), kv2.reshape(-1)]),)

    shapes = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in spec]
    shapes.append(jax.ShapeDtypeStruct((cfg.n_layers, 2, batch, seq, cfg.d_model), jnp.float32))
    shapes.append(jax.ShapeDtypeStruct((batch,), jnp.int32))
    shapes.append(jax.ShapeDtypeStruct((), jnp.int32))
    return jax.jit(fn).lower(*shapes)


def lower_generate(cfg, batch, prompt_len, n_tokens):
    spec = model.param_spec(cfg)

    def fn(*args):
        flat, prompt, seed, temp = args[:-3], args[-3], args[-2], args[-1]
        params = model.unflatten_params(cfg, flat)
        return (model.generate(cfg, params, prompt, seed, temp, n_tokens),)

    shapes = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in spec]
    shapes.append(jax.ShapeDtypeStruct((batch, prompt_len), jnp.int32))
    shapes.append(jax.ShapeDtypeStruct((), jnp.int32))
    shapes.append(jax.ShapeDtypeStruct((), jnp.float32))
    return jax.jit(fn).lower(*shapes)


def train_all(corpus_dir: str, weights_dir: str, only: set[str] | None):
    """Train bases first, then fine-tunes (which init from their base)."""
    os.makedirs(weights_dir, exist_ok=True)
    order = sorted(configs.MODELS.values(), key=lambda c: (c.base_of is not None, c.name))
    trained = {}
    for cfg in order:
        if only and cfg.name not in only:
            continue
        path = os.path.join(weights_dir, f"{cfg.name}.lmz")
        if os.path.exists(path):
            print(f"[aot] weights exist for {cfg.name}, skipping train")
            continue
        if cfg.base_of is None:
            print(f"[aot] training {cfg.name} ({configs.param_count(cfg)} params, "
                  f"{cfg.train_steps} steps)")
            params, _ = train.train(cfg, corpus_dir, cfg.train_steps, seed=0)
        else:
            base_path = os.path.join(weights_dir, f"{cfg.base_of}.lmz")
            base = {k: jnp.asarray(v) for k, v in weights.load(base_path).items()}
            print(f"[aot] fine-tuning {cfg.name} from {cfg.base_of} "
                  f"({cfg.finetune_steps} steps, corpus={cfg.corpus})")
            params, _ = train.train(cfg, corpus_dir, cfg.finetune_steps, init=base, seed=1)
        weights.save(path, cfg, params)
        trained[cfg.name] = params
        print(f"[aot] saved {path}")
    return trained


def emit_hlo(out_dir: str, only: set[str] | None):
    hlo_dir = os.path.join(out_dir, "hlo")
    os.makedirs(hlo_dir, exist_ok=True)
    manifest = []
    s = configs.MAX_CONTEXT
    fb, sb = configs.FORWARD_BATCH, configs.STEP_BATCH
    gb, gp, gn = configs.GEN_BATCH, configs.GEN_PROMPT, configs.GEN_TOKENS
    for name, cfg in sorted(configs.MODELS.items()):
        if only and name not in only:
            continue
        jobs = [
            (f"{name}__forward_b{fb}_s{s}", lambda: lower_forward(cfg, fb, s, "jnp"),
             f"forward {name} batch={fb} seq={s} impl=jnp"),
            (f"{name}__step_b{sb}_s{s}", lambda: lower_step(cfg, sb, s),
             f"step {name} batch={sb} seq={s}"),
            (f"{name}__generate_b{gb}_p{gp}_n{gn}", lambda: lower_generate(cfg, gb, gp, gn),
             f"generate {name} batch={gb} prompt={gp} tokens={gn}"),
        ]
        if name == "medium":
            jobs.append((f"{name}__forward_pallas_b1_s{s}",
                         lambda: lower_forward(cfg, 1, s, "pallas"),
                         f"forward_pallas {name} batch=1 seq={s} impl=pallas"))
        for stem, make, desc in jobs:
            path = os.path.join(hlo_dir, f"{stem}.hlo.txt")
            if not os.path.exists(path):
                print(f"[aot] lowering {stem}")
                text = to_hlo_text(make())
                with open(path, "w") as f:
                    f.write(text)
            manifest.append(f"{stem}.hlo.txt {desc}")
    # Param-order manifest so the rust loader can sanity-check shapes.
    for name, cfg in sorted(configs.MODELS.items()):
        if only and name not in only:
            continue
        for pname, shape in model.param_spec(cfg):
            dims = "x".join(str(d) for d in shape)
            manifest.append(f"param {name} {pname} {dims}")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"[aot] manifest with {len(manifest)} entries")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--corpus", default="../corpus")
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default="")
    args = ap.parse_args()
    only = set(args.models.split(",")) if args.models else None
    if not os.path.isdir(args.corpus):
        sys.exit(f"corpus dir {args.corpus} missing — run `make corpus` first")
    train_all(args.corpus, os.path.join(args.out, "weights"), only)
    emit_hlo(args.out, only)
    print("[aot] done")


if __name__ == "__main__":
    main()
