"""Model registry — the family standing in for the paper's 14 LLMs
(DESIGN.md §6). MUST stay in lockstep with `rust/src/lm/registry.rs`.

All models share: byte vocab (272), ALiBi positions (no positional
parameters -> context-length agnostic), pre-RMSNorm blocks, GELU MLP with
4x expansion, weight-tied output head. Sizes are scaled for the single-core
CPU testbed; the *ratios* between tiers mirror the paper's 1B..14B ladder.
"""

from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_layers: int
    n_heads: int
    # training recipe
    base_of: str | None = None     # fine-tuned from this base model
    corpus: str = "mixed"          # mixed | qa_mix | math | code
    train_steps: int = 2600
    finetune_steps: int = 800
    simulates: str = ""

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model


# Max context length (chunk size ceiling). Matches rust lm::MAX_CONTEXT.
MAX_CONTEXT = 256
# Training window (ALiBi extrapolates to MAX_CONTEXT at inference).
TRAIN_CONTEXT = 128

MODELS: dict[str, ModelConfig] = {
    m.name: m
    for m in [
        ModelConfig("nano", 32, 1, 2, simulates="OpenELM-1.1B / AMD-OLMo-1B tier"),
        ModelConfig("tiny", 48, 2, 2, simulates="Llama-3.2-1B"),
        ModelConfig("tiny-instruct", 48, 2, 2, base_of="tiny", corpus="qa_mix",
                    simulates="Llama-3.2-1B-Instruct"),
        ModelConfig("small", 64, 2, 4, simulates="Llama-3.2-3B"),
        ModelConfig("small-instruct", 64, 2, 4, base_of="small", corpus="qa_mix",
                    simulates="Llama-3.2-3B-Instruct"),
        ModelConfig("small-math", 64, 2, 4, base_of="small", corpus="math",
                    simulates="Qwen2.5-Math-1.5B / Rho-Math-1B"),
        ModelConfig("small-code", 64, 2, 4, base_of="small", corpus="code",
                    simulates="Qwen2.5-Coder-1.5B / DeepSeek-Coder-1.3B"),
        ModelConfig("medium", 96, 3, 4, simulates="Llama-3.1-8B (default)"),
        ModelConfig("teacher", 112, 3, 4, simulates="the data-generating LLMs (GPT-3.5/4, Mixtral)"),
        ModelConfig("medium-instruct", 96, 3, 4, base_of="medium", corpus="qa_mix",
                    simulates="Llama-3.1-8B-Instruct"),
        ModelConfig("large", 128, 4, 4, simulates="Qwen2.5-14B(-Instruct-1M)"),
    ]
}

# Lowered artifact batch shapes (rust pads lanes to these).
FORWARD_BATCH = 8
STEP_BATCH = 32
GEN_BATCH = 16
GEN_PROMPT = 16
GEN_TOKENS = 240  # generated per call (prompt + generated <= MAX_CONTEXT)


def param_count(cfg: ModelConfig) -> int:
    d = cfg.d_model
    per_block = 4 * d * d + 2 * d * (4 * d) + 2 * d  # attn + mlp + 2 norms
    return 272 * d + cfg.n_layers * per_block + d  # embed + blocks + final norm
