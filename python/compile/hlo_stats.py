"""L2 perf analysis: static statistics of the lowered HLO artifacts.

Reports per artifact: instruction count, fusion count, dot/convolution
count, transfer-sized parameters and output bytes — the knobs that matter
for a CPU/TPU serving path (EXPERIMENTS.md §Perf L2).

Usage: python -m compile.hlo_stats [--hlo ../artifacts/hlo]
"""

import argparse
import os
import re


def stats(path: str) -> dict:
    text = open(path).read()
    n_instr = len(re.findall(r"^\s+\S+ = ", text, re.M))
    n_fusion = len(re.findall(r"fusion\(", text))
    n_dot = len(re.findall(r"= f32\[[^\]]*\] dot\(", text)) + len(
        re.findall(r"\bdot\(", text))
    n_while = len(re.findall(r"\bwhile\(", text))
    n_params = len(re.findall(r"^\s+\S+ = [^=]*parameter\(", text, re.M))
    return {
        "instructions": n_instr,
        "fusions": n_fusion,
        "dots": n_dot // 2,  # pattern overlap correction
        "whiles": n_while,
        "parameters": n_params,
        "kib": len(text) // 1024,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--hlo", default="../artifacts/hlo")
    args = ap.parse_args()
    files = sorted(f for f in os.listdir(args.hlo) if f.endswith(".hlo.txt"))
    print(f"{'ARTIFACT':<44} {'instr':>6} {'fus':>5} {'dot':>5} {'while':>6} {'KiB':>6}")
    for f in files:
        s = stats(os.path.join(args.hlo, f))
        print(f"{f:<44} {s['instructions']:>6} {s['fusions']:>5} {s['dots']:>5} "
              f"{s['whiles']:>6} {s['kib']:>6}")


if __name__ == "__main__":
    main()
