"""L1 perf analysis: static VMEM footprint and MXU utilization across
candidate attention block shapes (interpret mode gives CPU wallclock only,
which is not a TPU proxy — DESIGN.md §8 — so the tuning signal is
structural).

Usage: python -m compile.kernel_tuning
"""

from .configs import MAX_CONTEXT, MODELS
from .kernels.attention import mxu_utilization, vmem_bytes


def main() -> None:
    print("Attention block tuning (S = 256)")
    print(f"{'CONFIG':<22} {'dh':>4} {'VMEM KiB':>9} {'MXU util':>9} {'passes/q-block':>15}")
    for name in ["small", "medium", "large"]:
        cfg = MODELS[name]
        dh = cfg.d_head
        for bq, bk in [(32, 32), (64, 64), (128, 64), (64, 128), (128, 128), (256, 64)]:
            if MAX_CONTEXT % bq or MAX_CONTEXT % bk:
                continue
            v = vmem_bytes(bq, bk, dh, MAX_CONTEXT)
            u = mxu_utilization(bq, bk, dh)
            passes = MAX_CONTEXT // bk
            print(f"{name+f' bq={bq} bk={bk}':<22} {dh:>4} {v/1024:>9.1f} {u:>9.3f} {passes:>15}")
    print(
        "\nChosen default: bq=bk=128 (perf pass L1-1; was 64x64) — the"
        "\nQK^T tile fills the MXU's 128x128 systolic face, doubling the"
        "\nestimated utilization at every model size, while the per-program"
        "\nVMEM footprint stays ~160 KiB, far below the 16 MiB/core budget."
        "\nUtilization remains bounded by dh (the contraction dim underfills"
        "\nthe array for dh <= 32) — the roofline for these head sizes."
    )


if __name__ == "__main__":
    main()
