"""L1 Pallas kernel: causal flash attention with ALiBi.

Hardware adaptation (DESIGN.md §7): the paper's workloads run HF
transformers on CUDA; the TPU-shaped rethink tiles Q into `(BLOCK_Q, Dh)`
VMEM blocks and streams K/V in `(BLOCK_K, Dh)` blocks with the online
softmax (flash) recurrence, so the SxS score matrix never materializes.
The matmuls are `(BLOCK_Q, Dh) x (Dh, BLOCK_K)` — MXU-systolic-array
shaped. Grid = (batch*heads, S / BLOCK_Q).

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; on a real TPU the same kernel lowers natively (compile-only
target). VMEM/MXU estimates: see `vmem_bytes` / `mxu_utilization` below and
EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Perf pass L1-1 (EXPERIMENTS.md §Perf): 128x128 blocks double the
# estimated MXU utilization vs 64x64 at identical arithmetic, and the
# per-program VMEM footprint stays ~160 KiB << 16 MiB/core.
BLOCK_Q = 128
BLOCK_K = 128

NEG_INF = float("-inf")


def _attn_kernel(slope_ref, q_ref, k_ref, v_ref, o_ref, *, block_q: int, block_k: int,
                 seq_len: int):
    """One (batch*head, q-block) program: online-softmax over K blocks."""
    qb = pl.program_id(1)
    q = q_ref[...]  # [block_q, dh]
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, dtype=jnp.float32))
    slope = slope_ref[0]

    q_pos = qb * block_q + jax.lax.iota(jnp.int32, block_q)  # [block_q]

    m_i = jnp.full((block_q,), NEG_INF, dtype=jnp.float32)   # running max
    l_i = jnp.zeros((block_q,), dtype=jnp.float32)           # running denom
    acc = jnp.zeros((block_q, dh), dtype=jnp.float32)        # running numer

    num_kb = seq_len // block_k

    def body(kb, carry):
        m_i, l_i, acc = carry
        k = pl.load(k_ref, (pl.dslice(kb * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (pl.dslice(kb * block_k, block_k), slice(None)))
        k_pos = kb * block_k + jax.lax.iota(jnp.int32, block_k)
        s = q @ k.T * scale  # [block_q, block_k] — the MXU matmul
        dist = q_pos[:, None] - k_pos[None, :]
        s = s - slope * dist.astype(jnp.float32)
        causal = k_pos[None, :] <= q_pos[:, None]
        s = jnp.where(causal, s, NEG_INF)
        # online softmax update
        m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))
        # alpha rescales the old accumulator; exp(-inf - -inf) guarded to 0
        alpha = jnp.where(m_i == NEG_INF, 0.0, jnp.exp(m_i - m_new))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(causal, p, 0.0)
        l_new = alpha * l_i + jnp.sum(p, axis=-1)
        acc_new = alpha[:, None] * acc + p @ v
        return m_new, l_new, acc_new

    # Only K blocks overlapping positions <= this Q block's last row are
    # ever unmasked: ceil((qb+1)*block_q / block_k) of them.
    kb_needed = ((qb + 1) * block_q + block_k - 1) // block_k
    m_i, l_i, acc = jax.lax.fori_loop(0, jnp.minimum(kb_needed, num_kb), body,
                                      (m_i, l_i, acc))
    o_ref[...] = acc / l_i[:, None]


@functools.partial(jax.jit, static_argnames=("block_q", "block_k"))
def attention(q, k, v, slopes, *, block_q: int = BLOCK_Q, block_k: int = BLOCK_K):
    """Pallas causal+ALiBi attention. q,k,v: [B, H, S, Dh]; slopes: [H]."""
    b, h, s, dh = q.shape
    # Fit block sizes to the sequence (tests sweep S values the defaults
    # don't divide): largest divisor of S not exceeding the requested block.
    def fit(block: int) -> int:
        b = min(block, s)
        while s % b:
            b -= 1
        return b

    block_q = fit(block_q)
    block_k = fit(block_k)
    qf = q.reshape(b * h, s, dh)
    kf = k.reshape(b * h, s, dh)
    vf = v.reshape(b * h, s, dh)
    slopes_bh = jnp.tile(slopes, b)  # [B*H]

    kernel = functools.partial(_attn_kernel, block_q=block_q, block_k=block_k, seq_len=s)
    out = pl.pallas_call(
        kernel,
        grid=(b * h, s // block_q),
        in_specs=[
            pl.BlockSpec((1,), lambda bh, qb: (bh,)),                    # slope
            pl.BlockSpec((None, block_q, dh), lambda bh, qb: (bh, qb, 0)),  # q
            pl.BlockSpec((None, s, dh), lambda bh, qb: (bh, 0, 0)),      # k (streamed)
            pl.BlockSpec((None, s, dh), lambda bh, qb: (bh, 0, 0)),      # v (streamed)
        ],
        out_specs=pl.BlockSpec((None, block_q, dh), lambda bh, qb: (bh, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, dh), jnp.float32),
        interpret=True,
    )(slopes_bh, qf, kf, vf)
    return out.reshape(b, h, s, dh)


def vmem_bytes(block_q: int, block_k: int, dh: int, seq_len: int) -> int:
    """Static VMEM footprint estimate for one program (f32)."""
    q = block_q * dh * 4
    kv = 2 * seq_len * dh * 4          # K/V panels resident per program
    acc = block_q * dh * 4 + 2 * block_q * 4
    scores = block_q * block_k * 4
    return q + kv + acc + scores


def mxu_utilization(block_q: int, block_k: int, dh: int) -> float:
    """Fraction of a 128x128 MXU pass doing useful MACs for the QK^T tile."""
    useful = block_q * block_k * dh
    passes_m = -(-block_q // 128) * -(-block_k // 128) * -(-dh // 128)
    return useful / (passes_m * 128 * 128 * 128)
