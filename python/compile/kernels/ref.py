"""Pure-jnp reference oracles for the Pallas kernels.

These are the ground truth the kernels are tested against (pytest +
hypothesis sweeps in python/tests/), and the fast lowering path used for
training and the default rust-served artifacts (DESIGN.md §9: on CPU the
interpret-mode Pallas HLO is loopy; the jnp path lowers to fused dense ops
with identical numerics, which the tests enforce).
"""

import jax.numpy as jnp

NEG_INF = float("-inf")


def alibi_slopes(n_heads: int) -> jnp.ndarray:
    """ALiBi head slopes: 2^(-8i/H) for i in 1..H (Press et al.)."""
    return jnp.asarray([2.0 ** (-8.0 * (i + 1) / n_heads) for i in range(n_heads)],
                       dtype=jnp.float32)


def attention_ref(q, k, v, slopes):
    """Causal multi-head attention with ALiBi bias.

    q, k, v: [B, H, S, Dh]; slopes: [H]. Returns [B, H, S, Dh].
    Masked positions contribute exactly 0 to the softmax (required for the
    bit-exact prefix-replay decompression property — see compress/llm.rs).
    """
    b, h, s, dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, dtype=q.dtype))
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    # ALiBi: penalize distance, per-head slope.
    bias = -slopes[None, :, None, None] * (qpos - kpos)[None, None, :, :].astype(q.dtype)
    causal = (kpos <= qpos)[None, None, :, :]
    scores = jnp.where(causal, scores + bias, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    w = e / jnp.sum(e, axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", w, v)


def rmsnorm_ref(x, gain, eps: float = 1e-6):
    """RMSNorm over the last axis: x * gain / rms(x)."""
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * (1.0 / jnp.sqrt(ms + eps)) * gain
