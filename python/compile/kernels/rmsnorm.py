"""L1 Pallas kernel: fused RMSNorm.

Row-blocked over the token axis: each program normalizes a `(BLOCK_N, D)`
VMEM tile in one pass (square, mean, rsqrt, scale — all fused; no HBM
round-trip for the mean). interpret=True on CPU, Mosaic on real TPU.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 64


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...]  # [block_n, d]
    g = g_ref[...]  # [d]
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = x * (1.0 / jnp.sqrt(ms + eps)) * g[None, :]


@functools.partial(jax.jit, static_argnames=("block_n", "eps"))
def rmsnorm(x, gain, *, block_n: int = BLOCK_N, eps: float = 1e-6):
    """Fused RMSNorm. x: [N, D] (N % block_n == 0), gain: [D]."""
    n, d = x.shape
    assert n % block_n == 0, (n, block_n)
    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_n, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=True,
    )(x, gain)
