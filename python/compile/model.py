"""L2: the byte-level decoder-only transformer in JAX.

Architecture (mirrored exactly by `rust/src/lm/model.rs`):
  * token embedding [V, D]; ALiBi positions (no positional parameters)
  * n_layers pre-RMSNorm blocks: MHA (causal+ALiBi) then GELU MLP (4x)
  * final RMSNorm; weight-tied output head (logits = h @ E^T)

Two implementations of the two fused hot-spots, selected by `impl`:
  * "pallas" — the L1 kernels (`kernels/attention.py`, `kernels/rmsnorm.py`)
  * "jnp"    — the pure-jnp oracles (`kernels/ref.py`)
pytest enforces allclose between them; aot.py lowers both variants.

Exported entry points (all lowered to HLO text by aot.py):
  * forward_logits(params, tokens[B,S]) -> logits[B,S,V]   (compression)
  * decode_step(params, kv, tok[B], pos) -> (logits[B,V], kv')  (decode)
  * generate(params, prompt[B,P], seed, temp) -> tokens[B,N]    (datasets)
"""

import functools

import jax
import jax.numpy as jnp

from . import configs
from .kernels import attention as attn_pallas
from .kernels import ref as kref
from .kernels import rmsnorm as rms_pallas
from .vocab import VOCAB_SIZE


# ---------------------------------------------------------------------------
# parameters

def param_spec(cfg: configs.ModelConfig) -> list[tuple[str, tuple[int, ...]]]:
    """Ordered (name, shape) list — THE canonical flattening order shared
    with the rust weights loader (sorted lexicographically by name)."""
    d, ff = cfg.d_model, cfg.d_ff
    spec = [("embed", (VOCAB_SIZE, d)), ("final_norm", (d,))]
    for i in range(cfg.n_layers):
        p = f"layer{i:02d}."
        spec += [
            (p + "attn_norm", (d,)),
            (p + "mlp_norm", (d,)),
            (p + "wq", (d, d)),
            (p + "wk", (d, d)),
            (p + "wv", (d, d)),
            (p + "wo", (d, d)),
            (p + "w1", (d, ff)),
            (p + "w2", (ff, d)),
        ]
    return sorted(spec, key=lambda kv: kv[0])


def init_params(cfg: configs.ModelConfig, seed: int = 0) -> dict[str, jnp.ndarray]:
    key = jax.random.PRNGKey(seed)
    params = {}
    for name, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        if name.endswith("norm"):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name == "embed":
            params[name] = 0.02 * jax.random.normal(sub, shape, jnp.float32)
        else:
            fan_in = shape[0]
            params[name] = jax.random.normal(sub, shape, jnp.float32) / jnp.sqrt(fan_in)
    return params


def flatten_params(cfg: configs.ModelConfig, params: dict) -> list[jnp.ndarray]:
    return [params[name] for name, _ in param_spec(cfg)]


def unflatten_params(cfg: configs.ModelConfig, flat) -> dict:
    return {name: x for (name, _), x in zip(param_spec(cfg), flat)}


# ---------------------------------------------------------------------------
# forward

def _rmsnorm(x, gain, impl: str):
    if impl == "pallas":
        shape = x.shape
        return rms_pallas.rmsnorm(x.reshape(-1, shape[-1]), gain).reshape(shape)
    return kref.rmsnorm_ref(x, gain)


def _attention(q, k, v, slopes, impl: str):
    if impl == "pallas":
        return attn_pallas.attention(q, k, v, slopes)
    return kref.attention_ref(q, k, v, slopes)


def forward_logits(cfg: configs.ModelConfig, params: dict, tokens, impl: str = "jnp"):
    """tokens: int32 [B, S] -> logits f32 [B, S, V].

    Position t's logits depend ONLY on tokens[:, :t+1] (strict causality in
    attention; everything else is position-local). The rust decompressor
    relies on this for bit-exact prefix replay.
    """
    b, s = tokens.shape
    d, h = cfg.d_model, cfg.n_heads
    dh = cfg.d_head
    slopes = kref.alibi_slopes(h)
    x = params["embed"][tokens]  # [B, S, D]
    for i in range(cfg.n_layers):
        p = f"layer{i:02d}."
        hnorm = _rmsnorm(x, params[p + "attn_norm"], impl)
        q = (hnorm @ params[p + "wq"]).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
        k = (hnorm @ params[p + "wk"]).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
        v = (hnorm @ params[p + "wv"]).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
        o = _attention(q, k, v, slopes, impl)
        o = o.transpose(0, 2, 1, 3).reshape(b, s, d)
        x = x + o @ params[p + "wo"]
        hnorm = _rmsnorm(x, params[p + "mlp_norm"], impl)
        x = x + jax.nn.gelu(hnorm @ params[p + "w1"], approximate=True) @ params[p + "w2"]
    x = _rmsnorm(x, params["final_norm"], impl)
    return x @ params["embed"].T  # [B, S, V]


# ---------------------------------------------------------------------------
# incremental decode (KV cache)

def init_kv(cfg: configs.ModelConfig, batch: int, max_len: int):
    return jnp.zeros((cfg.n_layers, 2, batch, max_len, cfg.d_model), jnp.float32)


def decode_step(cfg: configs.ModelConfig, params: dict, kv, tok, pos):
    """One autoregressive step.

    kv: f32 [L, 2, B, S, D]; tok: int32 [B]; pos: int32 scalar (0-based).
    Returns (logits [B, V], kv'). Attention reads cache positions <= pos.
    """
    l, _, b, s, d = kv.shape
    h, dh = cfg.n_heads, cfg.d_head
    slopes = kref.alibi_slopes(h)
    x = params["embed"][tok]  # [B, D]
    positions = jnp.arange(s)
    for i in range(cfg.n_layers):
        p = f"layer{i:02d}."
        hn = kref.rmsnorm_ref(x, params[p + "attn_norm"])
        q = hn @ params[p + "wq"]          # [B, D]
        knew = hn @ params[p + "wk"]
        vnew = hn @ params[p + "wv"]
        kv = kv.at[i, 0, :, pos, :].set(knew)
        kv = kv.at[i, 1, :, pos, :].set(vnew)
        kcache = kv[i, 0].reshape(b, s, h, dh)  # [B, S, H, Dh]
        vcache = kv[i, 1].reshape(b, s, h, dh)
        qh = q.reshape(b, h, dh)
        scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
        scores = jnp.einsum("bhd,bshd->bhs", qh, kcache) * scale
        bias = -slopes[None, :, None] * (pos - positions)[None, None, :].astype(jnp.float32)
        valid = (positions <= pos)[None, None, :]
        scores = jnp.where(valid, scores + bias, kref.NEG_INF)
        m = jnp.max(scores, axis=-1, keepdims=True)
        e = jnp.exp(scores - m)
        w = e / jnp.sum(e, axis=-1, keepdims=True)
        o = jnp.einsum("bhs,bshd->bhd", w, vcache).reshape(b, d)
        x = x + o @ params[p + "wo"]
        hn = kref.rmsnorm_ref(x, params[p + "mlp_norm"])
        x = x + jax.nn.gelu(hn @ params[p + "w1"], approximate=True) @ params[p + "w2"]
    x = kref.rmsnorm_ref(x, params["final_norm"])
    return x @ params["embed"].T, kv


# ---------------------------------------------------------------------------
# in-graph generation (dataset factory)

def generate(cfg: configs.ModelConfig, params: dict, prompt, seed, temp,
             n_tokens: int):
    """Sample `n_tokens` continuations for each prompt row, fully in-graph.

    prompt: int32 [B, P]; seed: int32 scalar; temp: f32 scalar.
    Returns int32 [B, n_tokens]. Sampling = softmax(logits / temp) via
    Gumbel-max; only byte tokens (0..255) are sampled (specials masked).
    """
    b, p = prompt.shape
    s = p + n_tokens
    kv = init_kv(cfg, b, s)
    key = jax.random.PRNGKey(seed)

    byte_mask = jnp.where(jnp.arange(VOCAB_SIZE) < 256, 0.0, kref.NEG_INF)

    def step(carry, t):
        kv, last_tok = carry
        # During the prompt phase feed the prompt token, else the sample.
        tok = jnp.where(t < p, prompt[:, jnp.minimum(t, p - 1)], last_tok)
        logits, kv = decode_step(cfg, params, kv, tok, t)
        g_key = jax.random.fold_in(key, t)
        gumbel = jax.random.gumbel(g_key, (b, VOCAB_SIZE), jnp.float32)
        scaled = logits / jnp.maximum(temp, 1e-4) + byte_mask
        sample = jnp.argmax(scaled + gumbel, axis=-1).astype(jnp.int32)
        return (kv, sample), sample

    (_, _), samples = jax.lax.scan(step, (kv, prompt[:, 0]), jnp.arange(s))
    # samples[t] is the token sampled AFTER seeing position t; the generated
    # stream is samples[p-1 : s-1] (continuations of the prompt).
    return samples.transpose(1, 0)[:, p - 1 : s - 1]
