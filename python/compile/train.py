"""Build-time training of the LM family on the procedural corpus.

Runs ONCE inside `make artifacts` (never on the request path). Hand-rolled
Adam (no optax in this environment), deterministic batching from a seeded
numpy generator, jnp kernel implementation for speed (pytest separately
enforces pallas == jnp numerics).
"""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import configs, model
from .vocab import BOS, domain_tag

BATCH = 16
TAG_PROB = 0.5  # fraction of sequences that carry a domain-tag prefix

# Corpora per recipe (see configs.ModelConfig.corpus).
RECIPE_FILES = {
    "mixed": ["wiki", "article", "code", "math", "clinical", "web", "science", "novel"],
    # Instruction tuning: QA pairs plus the two QA-structured domains
    # (paper §5.7.1: instruct models gain on question-answer data).
    "qa_mix": ["qa", "math", "science"],
    "math": ["math"],
    "code": ["code"],
}


def load_corpus(corpus_dir: str, recipe: str) -> dict[str, np.ndarray]:
    out = {}
    for name in RECIPE_FILES[recipe]:
        path = os.path.join(corpus_dir, f"{name}.txt")
        with open(path, "rb") as f:
            out[name] = np.frombuffer(f.read(), dtype=np.uint8)
    return out


def make_batch(rng: np.random.Generator, corpus: dict[str, np.ndarray], t: int):
    """Sample a batch of (input, target) windows of length `t` tokens."""
    names = list(corpus)
    inputs = np.zeros((BATCH, t), dtype=np.int32)
    targets = np.zeros((BATCH, t), dtype=np.int32)
    for i in range(BATCH):
        name = names[rng.integers(len(names))]
        data = corpus[name]
        use_tag = name in ("wiki", "article", "code", "math", "clinical", "web",
                           "science", "novel") and rng.random() < TAG_PROB
        n_text = t - (1 if use_tag else 0)  # sequence = [BOS, (TAG), bytes...]
        start = int(rng.integers(0, len(data) - n_text - 1))
        window = data[start : start + n_text + 1].astype(np.int32)
        seq = [BOS] + ([domain_tag(name)] if use_tag else []) + list(window)
        seq = np.asarray(seq[: t + 1], dtype=np.int32)
        inputs[i] = seq[:-1]
        targets[i] = seq[1:]
    return inputs, targets


def loss_fn(cfg, params, inputs, targets):
    logits = model.forward_logits(cfg, params, inputs, impl="jnp")
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def adam_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.98, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1 ** t.astype(jnp.float32))
    vhat_scale = 1.0 / (1 - b2 ** t.astype(jnp.float32))
    params = jax.tree.map(
        lambda p, m, v: p - lr * (m * mhat_scale) / (jnp.sqrt(v * vhat_scale) + eps),
        params, m, v)
    return params, {"m": m, "v": v, "t": t}


def lr_schedule(step: int, total: int, peak: float = 3e-3, warmup: int = 30) -> float:
    if step < warmup:
        return peak * (step + 1) / warmup
    frac = (step - warmup) / max(1, total - warmup)
    return float(peak * (0.1 + 0.9 * 0.5 * (1 + np.cos(np.pi * frac))))


def train(cfg: configs.ModelConfig, corpus_dir: str, steps: int,
          init: dict | None = None, seed: int = 0, log_every: int = 100):
    """Train (or fine-tune, when `init` given) and return params."""
    corpus = load_corpus(corpus_dir, cfg.corpus)
    rng = np.random.default_rng(seed + hash(cfg.name) % (1 << 16))
    params = init if init is not None else model.init_params(cfg, seed)
    opt = adam_init(params)
    t = configs.TRAIN_CONTEXT

    @jax.jit
    def step_fn(params, opt, inputs, targets, lr):
        loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, inputs, targets))(params)
        params, opt = adam_update(params, grads, opt, lr)
        return params, opt, loss

    t0 = time.time()
    losses = []
    for step in range(steps):
        inputs, targets = make_batch(rng, corpus, t)
        lr = lr_schedule(step, steps)
        params, opt, loss = step_fn(params, opt, jnp.asarray(inputs), jnp.asarray(targets),
                                    jnp.float32(lr))
        losses.append(float(loss))
        if step % log_every == 0 or step == steps - 1:
            recent = float(np.mean(losses[-20:]))
            bpb = recent / np.log(2)
            print(f"  [{cfg.name}] step {step:4d}/{steps} loss {recent:.3f} "
                  f"({bpb:.2f} bits/byte) {time.time()-t0:.0f}s", flush=True)
    return params, losses
