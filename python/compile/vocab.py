"""Byte-level LM vocabulary — MUST stay in lockstep with
`rust/src/tokenizer/vocab.rs` (the Rust side owns the same constants).

Layout:
  0..=255    raw bytes
  256        PAD (fills fixed-shape batches; never coded)
  257        BOS (chunk start)
  258        EOS (generation stop)
  259..=271  domain tags (generation conditioning)
"""

VOCAB_SIZE = 272
PAD = 256
BOS = 257
EOS = 258
DOMAIN_TAG_BASE = 259
NUM_DOMAIN_TAGS = 13

# Domain order matches rust `textgen::Domain::index()`.
DOMAINS = [
    "wiki", "article", "code", "math", "clinical", "web", "science", "novel", "tpch",
]


def domain_tag(domain: str) -> int:
    return DOMAIN_TAG_BASE + DOMAINS.index(domain)
