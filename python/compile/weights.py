"""Weights serialization (.lmz) — mirrored by `rust/src/runtime/weights.rs`.

Layout (little-endian):
  magic   u32  "LMZW" (0x575A4D4C)
  version u16
  count   u16  number of tensors
  per tensor, in `model.param_spec` order (sorted by name):
    name_len u8, name bytes (ascii)
    ndim     u8, dims u32 x ndim
    data     f32 x prod(dims)
"""

import struct

import numpy as np

from . import configs, model

MAGIC = 0x575A4D4C
VERSION = 1


def save(path: str, cfg: configs.ModelConfig, params: dict) -> None:
    spec = model.param_spec(cfg)
    with open(path, "wb") as f:
        f.write(struct.pack("<IHH", MAGIC, VERSION, len(spec)))
        for name, shape in spec:
            arr = np.asarray(params[name], dtype=np.float32)
            assert arr.shape == shape, (name, arr.shape, shape)
            nb = name.encode("ascii")
            f.write(struct.pack("<B", len(nb)))
            f.write(nb)
            f.write(struct.pack("<B", arr.ndim))
            for dim in arr.shape:
                f.write(struct.pack("<I", dim))
            f.write(arr.tobytes(order="C"))


def load(path: str) -> dict[str, np.ndarray]:
    params = {}
    with open(path, "rb") as f:
        magic, version, count = struct.unpack("<IHH", f.read(8))
        assert magic == MAGIC and version == VERSION, (magic, version)
        for _ in range(count):
            (nlen,) = struct.unpack("<B", f.read(1))
            name = f.read(nlen).decode("ascii")
            (ndim,) = struct.unpack("<B", f.read(1))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
            n = int(np.prod(dims)) if ndim else 1
            data = np.frombuffer(f.read(4 * n), dtype="<f4").reshape(dims)
            params[name] = data.copy()
    return params
