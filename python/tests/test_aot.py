"""AOT lowering tests: the HLO text artifacts parse, mention the right
shapes, and the vocab constants stay in lockstep with the rust side."""

import os
import re

import pytest

from compile import aot, configs, vocab


class TestVocabLockstep:
    def test_constants_match_rust(self):
        rust = open(os.path.join(os.path.dirname(__file__), "..", "..",
                                 "rust", "src", "tokenizer", "vocab.rs")).read()
        assert f"VOCAB_SIZE: usize = {vocab.VOCAB_SIZE}" in rust
        assert f"PAD: u32 = {vocab.PAD}" in rust
        assert f"BOS: u32 = {vocab.BOS}" in rust
        assert f"EOS: u32 = {vocab.EOS}" in rust
        assert f"DOMAIN_TAG_BASE: u32 = {vocab.DOMAIN_TAG_BASE}" in rust

    def test_registry_matches_rust(self):
        rust = open(os.path.join(os.path.dirname(__file__), "..", "..",
                                 "rust", "src", "lm", "config.rs")).read()
        for name, cfg in configs.MODELS.items():
            pat = (rf'name: "{re.escape(name)}", d_model: {cfg.d_model}, '
                   rf'n_layers: {cfg.n_layers}, n_heads: {cfg.n_heads}')
            assert re.search(pat, rust), f"rust registry missing/mismatched: {name}"


class TestLowering:
    def test_forward_hlo_text_parses(self):
        cfg = configs.MODELS["nano"]
        text = aot.to_hlo_text(aot.lower_forward(cfg, 2, 32, "jnp"))
        assert text.startswith("HloModule")
        # logits output shape appears
        assert f"f32[2,32,{vocab.VOCAB_SIZE}]" in text

    def test_step_hlo_single_flat_output(self):
        cfg = configs.MODELS["nano"]
        text = aot.to_hlo_text(aot.lower_step(cfg, 4, 32))
        assert text.startswith("HloModule")
        flat = 4 * vocab.VOCAB_SIZE + cfg.n_layers * 2 * 4 * 32 * cfg.d_model
        assert f"f32[{flat}]" in text, "step must emit one flat [logits|kv] array"

    def test_generate_hlo_output_shape(self):
        cfg = configs.MODELS["nano"]
        text = aot.to_hlo_text(aot.lower_generate(cfg, 2, 4, 8))
        assert "s32[2,8]" in text

    @pytest.mark.skipif(not os.path.isdir(os.path.join(os.path.dirname(__file__), "..", "..",
                                                       "artifacts", "hlo")),
                        reason="artifacts not built")
    def test_emitted_artifacts_exist_per_model(self):
        hlo = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "hlo")
        files = os.listdir(hlo)
        for name in configs.MODELS:
            assert any(f.startswith(f"{name}__forward_b") for f in files), name
            assert any(f.startswith(f"{name}__step_b") for f in files), name
            assert any(f.startswith(f"{name}__generate_b") for f in files), name
