"""L1 kernel correctness: Pallas vs pure-jnp oracle — the CORE correctness
signal for the kernels, swept over shapes/dtypes with hypothesis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import attention, mxu_utilization, vmem_bytes
from compile.kernels.ref import alibi_slopes, attention_ref, rmsnorm_ref
from compile.kernels.rmsnorm import rmsnorm


def rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


class TestAttention:
    @settings(max_examples=12, deadline=None)
    @given(
        b=st.integers(1, 3),
        h=st.sampled_from([1, 2, 4]),
        s=st.sampled_from([64, 128, 192]),
        dh=st.sampled_from([8, 16, 32]),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref_swept(self, b, h, s, dh, seed):
        q = rand(seed, (b, h, s, dh))
        k = rand(seed + 1, (b, h, s, dh))
        v = rand(seed + 2, (b, h, s, dh))
        slopes = alibi_slopes(h)
        out = attention(q, k, v, slopes)
        ref = attention_ref(q, k, v, slopes)
        np.testing.assert_allclose(out, ref, rtol=3e-5, atol=3e-5)

    def test_block_shapes_equivalent(self):
        q, k, v = (rand(i, (1, 2, 128, 16)) for i in range(3))
        slopes = alibi_slopes(2)
        ref = attention_ref(q, k, v, slopes)
        for bq, bk in [(32, 32), (64, 64), (128, 64), (64, 128), (128, 128)]:
            out = attention(q, k, v, slopes, block_q=bq, block_k=bk)
            np.testing.assert_allclose(out, ref, rtol=3e-5, atol=3e-5, err_msg=f"{bq}x{bk}")

    def test_causality(self):
        """Output at position t must not depend on inputs after t."""
        q, k, v = (rand(i + 10, (1, 1, 64, 8)) for i in range(3))
        slopes = alibi_slopes(1)
        out1 = attention(q, k, v, slopes)
        k2 = k.at[:, :, 40:, :].set(99.0)
        v2 = v.at[:, :, 40:, :].set(-99.0)
        out2 = attention(q, k2, v2, slopes)
        np.testing.assert_array_equal(np.asarray(out1[:, :, :40]), np.asarray(out2[:, :, :40]))

    def test_alibi_bias_decays_attention(self):
        """With identical K rows, ALiBi must favor recent positions."""
        s = 64
        q = jnp.ones((1, 1, s, 8), jnp.float32)
        k = jnp.ones((1, 1, s, 8), jnp.float32)
        # v encodes position index
        v = jnp.arange(s, dtype=jnp.float32)[None, None, :, None] * jnp.ones((1, 1, s, 8))
        slopes = jnp.asarray([0.5], jnp.float32)
        out = attention(q, k, v, slopes)
        # At the last position, attention mass should tilt to recent j,
        # so expected value > uniform average (31.5).
        assert float(out[0, 0, -1, 0]) > (s - 1) / 2

    def test_first_position_attends_only_itself(self):
        q, k, v = (rand(i + 20, (1, 1, 64, 8)) for i in range(3))
        out = attention(q, k, v, alibi_slopes(1))
        np.testing.assert_allclose(out[0, 0, 0], v[0, 0, 0], rtol=1e-6, atol=1e-6)


class TestRmsnorm:
    @settings(max_examples=12, deadline=None)
    @given(
        n=st.sampled_from([64, 128, 256]),
        d=st.sampled_from([16, 48, 96, 129]),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref_swept(self, n, d, seed):
        x = rand(seed, (n, d))
        g = 1.0 + 0.1 * rand(seed + 1, (d,))
        out = rmsnorm(x, g)
        ref = rmsnorm_ref(x, g)
        np.testing.assert_allclose(out, ref, rtol=2e-6, atol=2e-6)

    def test_unit_rms_output(self):
        x = 3.0 * rand(5, (64, 32))
        out = rmsnorm(x, jnp.ones((32,)))
        rms = jnp.sqrt(jnp.mean(jnp.square(out), axis=-1))
        np.testing.assert_allclose(rms, 1.0, rtol=1e-3)


class TestKernelAnalysis:
    def test_vmem_fits_tpu_budget(self):
        """Default block spec must fit a TPU core's ~16 MiB VMEM."""
        assert vmem_bytes(64, 64, 64, 256) < 16 * 1024 * 1024

    def test_mxu_utilization_estimates(self):
        # 128x128x128 tile = a full MXU pass.
        assert mxu_utilization(128, 128, 128) == pytest.approx(1.0)
        # Small-head tiles underfill the systolic array.
        assert mxu_utilization(64, 64, 16) < 0.1
