"""L2 model tests: shapes, causality, pallas/jnp parity, decode_step
consistency, generation, and the bit-exact prefix property the rust
decompressor depends on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import configs, model
from compile.vocab import BOS, PAD, VOCAB_SIZE


CFG = configs.MODELS["tiny"]


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, 0)


def tokens(b, s, seed=0):
    return jax.random.randint(jax.random.PRNGKey(seed), (b, s), 0, 256, jnp.int32)


class TestForward:
    def test_shapes(self, params):
        for b, s in [(1, 32), (2, 64), (4, 128)]:
            logits = model.forward_logits(CFG, params, tokens(b, s), impl="jnp")
            assert logits.shape == (b, s, VOCAB_SIZE)
            assert bool(jnp.isfinite(logits).all())

    def test_pallas_matches_jnp(self, params):
        t = tokens(2, 64, 3)
        a = model.forward_logits(CFG, params, t, impl="jnp")
        b = model.forward_logits(CFG, params, t, impl="pallas")
        np.testing.assert_allclose(a, b, rtol=5e-5, atol=5e-5)

    def test_prefix_property_bit_exact(self, params):
        """Logits at position t are BITWISE identical regardless of suffix
        tokens — the property prefix-replay decompression relies on."""
        t1 = tokens(2, 64, 4)
        t2 = t1.at[:, 32:].set(PAD)
        f = jax.jit(lambda p, t: model.forward_logits(CFG, p, t, impl="jnp"))
        a = np.asarray(f(params, t1))
        b = np.asarray(f(params, t2))
        np.testing.assert_array_equal(a[:, :32], b[:, :32])

    def test_batch_lanes_independent(self, params):
        """Lane 0's logits don't change when other lanes change."""
        t1 = tokens(4, 32, 5)
        t2 = t1.at[1:].set(7)
        f = jax.jit(lambda p, t: model.forward_logits(CFG, p, t, impl="jnp"))
        a = np.asarray(f(params, t1))
        b = np.asarray(f(params, t2))
        np.testing.assert_array_equal(a[0], b[0])

    @settings(max_examples=6, deadline=None)
    @given(s=st.sampled_from([16, 48, 96]), seed=st.integers(0, 1000))
    def test_swept_shapes_finite(self, params, s, seed):
        logits = model.forward_logits(CFG, params, tokens(1, s, seed), impl="jnp")
        assert bool(jnp.isfinite(logits).all())


class TestDecodeStep:
    def test_matches_forward(self, params):
        b, s = 2, 48
        t = tokens(b, s, 6)
        full = model.forward_logits(CFG, params, t, impl="jnp")
        kv = model.init_kv(CFG, b, s)
        step = jax.jit(lambda p, kv, tok, pos: model.decode_step(CFG, p, kv, tok, pos))
        for pos in range(s):
            logits, kv = step(params, kv, t[:, pos], pos)
            np.testing.assert_allclose(logits, full[:, pos], rtol=5e-4, atol=5e-4)

    def test_kv_positions_beyond_pos_ignored(self, params):
        b, s = 1, 16
        kv = model.init_kv(CFG, b, s)
        # Poison the tail of the cache: must not affect step at pos 0.
        kv_poisoned = kv.at[:, :, :, 8:, :].set(1e9)
        tok = jnp.asarray([65], jnp.int32)
        a, _ = model.decode_step(CFG, params, kv, tok, 0)
        b_, _ = model.decode_step(CFG, params, kv_poisoned, tok, 0)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))


class TestGenerate:
    def test_deterministic_given_seed(self, params):
        prompt = jnp.full((2, 4), BOS, jnp.int32)
        g = jax.jit(lambda p, pr, seed: model.generate(CFG, p, pr, seed, jnp.float32(0.8), 24))
        a = g(params, prompt, 1)
        b = g(params, prompt, 1)
        c = g(params, prompt, 2)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert not np.array_equal(np.asarray(a), np.asarray(c))

    def test_only_byte_tokens_sampled(self, params):
        prompt = jnp.full((2, 4), BOS, jnp.int32)
        out = model.generate(CFG, params, prompt, 3, jnp.float32(1.2), 48)
        assert out.shape == (2, 48)
        assert int(out.min()) >= 0 and int(out.max()) < 256


class TestParams:
    def test_spec_sorted_and_counts(self):
        for name, cfg in configs.MODELS.items():
            spec = model.param_spec(cfg)
            names = [n for n, _ in spec]
            assert names == sorted(names), name
            total = sum(int(np.prod(s)) for _, s in spec)
            assert total == configs.param_count(cfg), name

    def test_flatten_roundtrip(self, params):
        flat = model.flatten_params(CFG, params)
        back = model.unflatten_params(CFG, flat)
        assert set(back) == set(params)
        for k in params:
            np.testing.assert_array_equal(np.asarray(params[k]), np.asarray(back[k]))
