"""Training-loop and weights-serialization tests (build-path plumbing)."""

import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model, train, weights
from compile.vocab import BOS, DOMAIN_TAG_BASE


@pytest.fixture(scope="module")
def corpus_dir(tmp_path_factory):
    d = tmp_path_factory.mktemp("corpus")
    rng = np.random.default_rng(0)
    words = [b"the", b"cat", b"sat", b"on", b"a", b"mat", b"dog", b"ran"]
    for name in train.RECIPE_FILES["mixed"] + ["qa"]:
        blob = b" ".join(words[rng.integers(len(words))] for _ in range(4000))
        (d / f"{name}.txt").write_bytes(blob)
    return str(d)


class TestBatching:
    def test_batch_shapes_and_alignment(self, corpus_dir):
        corpus = train.load_corpus(corpus_dir, "mixed")
        rng = np.random.default_rng(1)
        inputs, targets = train.make_batch(rng, corpus, 64)
        assert inputs.shape == (train.BATCH, 64)
        assert targets.shape == (train.BATCH, 64)
        # input starts with BOS; target is input shifted by one.
        assert (inputs[:, 0] == BOS).all()
        np.testing.assert_array_equal(inputs[:, 1:], targets[:, :-1])

    def test_domain_tags_appear(self, corpus_dir):
        corpus = train.load_corpus(corpus_dir, "mixed")
        rng = np.random.default_rng(2)
        tags = 0
        for _ in range(20):
            inputs, _ = train.make_batch(rng, corpus, 32)
            tags += int((inputs[:, 1] >= DOMAIN_TAG_BASE).sum())
        assert tags > 0, "some sequences must carry domain tags"

    def test_deterministic_given_seed(self, corpus_dir):
        corpus = train.load_corpus(corpus_dir, "mixed")
        a = train.make_batch(np.random.default_rng(3), corpus, 32)
        b = train.make_batch(np.random.default_rng(3), corpus, 32)
        np.testing.assert_array_equal(a[0], b[0])


class TestTraining:
    def test_loss_decreases(self, corpus_dir):
        cfg = configs.ModelConfig("testnano", 32, 1, 2)
        params, losses = train.train(cfg, corpus_dir, steps=60, seed=0, log_every=1000)
        early = float(np.mean(losses[:10]))
        late = float(np.mean(losses[-10:]))
        assert late < early * 0.7, f"loss should drop: {early} -> {late}"
        # params stay finite
        for k, v in params.items():
            assert bool(jnp.isfinite(v).all()), k

    def test_lr_schedule_shape(self):
        total = 100
        lrs = [train.lr_schedule(s, total) for s in range(total)]
        peak = max(lrs)
        assert lrs[0] < peak
        assert lrs[-1] < 0.2 * peak


class TestWeightsIO:
    def test_roundtrip(self, tmp_path):
        cfg = configs.MODELS["nano"]
        params = model.init_params(cfg, 1)
        path = str(tmp_path / "w.lmz")
        weights.save(path, cfg, params)
        back = weights.load(path)
        assert set(back) == set(params)
        for k in params:
            np.testing.assert_array_equal(np.asarray(params[k]), back[k])

    def test_file_is_canonical_order(self, tmp_path):
        cfg = configs.MODELS["nano"]
        params = model.init_params(cfg, 2)
        path = str(tmp_path / "w.lmz")
        weights.save(path, cfg, params)
        raw = open(path, "rb").read()
        # Names must appear in sorted (spec) order within the file.
        offsets = [raw.find(name.encode()) for name, _ in model.param_spec(cfg)]
        assert offsets == sorted(offsets)
        assert all(o > 0 for o in offsets)
