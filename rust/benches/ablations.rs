//! Ablation benches for the design decisions DESIGN.md calls out:
//!  A1. AC-stream granularity (flush/table overhead amortization)
//!  A2. CDF quantization precision vs coding efficiency
//!  A3. LZ77 lazy parsing vs greedy (dictionary baselines' parse choice)
//!  A4. Context-mixing model count (nncp-sim vs trace-sim ladder)

#[path = "harness.rs"]
mod harness;

use harness::section;
use llmzip::baselines::cm::{CmConfig, ContextMixing};
use llmzip::compress::{Compressor, LlmCompressor};
use llmzip::lm::config::by_name;
use llmzip::lm::weights::Weights;

fn main() {
    // A1: stream granularity with the native engine (no artifacts needed).
    section("A1: AC-stream granularity (native engine, 16 KiB wiki)");
    let cfg = by_name("small").unwrap();
    let data = llmzip::experiments::human_text(llmzip::textgen::Domain::Wiki, 16 * 1024);
    println!("{:<14} {:>8} {:>14}", "STREAM", "RATIO", "bytes/stream");
    for stream in [256usize, 512, 1024, 2048, 4096, 8192] {
        let comp = LlmCompressor::from_weights(cfg, weights(), 256, 4)
            .unwrap()
            .with_stream_bytes(stream)
            .unwrap();
        let z = comp.compress(&data).unwrap();
        let n_streams = data.len().div_ceil(stream);
        println!(
            "{:<14} {:>7.3}x {:>14.1}",
            stream,
            data.len() as f64 / z.len() as f64,
            (z.len() as f64) / n_streams as f64 - 0.0,
        );
    }

    // A2 is structural: quantization reserves 1/65536 per symbol; measure
    // the bound directly.
    section("A2: CDF quantization loss bound");
    let spare_frac = 256.0 / 65536.0;
    println!(
        "reserved mass {:.4} -> worst-case overhead {:.4} bits/byte on a p=0.99 stream",
        spare_frac,
        -(1.0f64 - spare_frac).log2()
    );

    // A3: lazy vs greedy parse.
    section("A3: LZ77 parse quality (200 KiB mixed text)");
    let text = llmzip::textgen::quick_sample(200 * 1024, 3);
    let tokens = llmzip::baselines::lz77::tokenize(&text);
    let st = llmzip::baselines::lz77::parse_stats(&tokens);
    println!(
        "lazy parse: {} literals, {} matches, {:.1}% match coverage",
        st.literals,
        st.matches,
        100.0 * st.match_bytes as f64 / text.len() as f64
    );
    let gz = llmzip::baselines::GzipLike::new();
    let z = gz.compress(&text).unwrap();
    println!("gzip-like ratio {:.2}x", text.len() as f64 / z.len() as f64);

    // A4: CM model-count ladder.
    section("A4: context-mixing model ladder (64 KiB mixed text)");
    let small = &text[..64 * 1024];
    for (name, orders, bits) in [
        ("orders 0-1", &[0usize, 1][..], 16u32),
        ("orders 0-2 (trace-sim)", &[0, 1, 2][..], 16),
        ("orders 0-4", &[0, 1, 2, 3, 4][..], 20),
        ("orders 0-4+6 (nncp-sim)", &[0, 1, 2, 3, 4, 6][..], 20),
    ] {
        // leak: benches are one-shot processes; a 'static str is simplest
        let static_name: &'static str = Box::leak(name.to_string().into_boxed_str());
        let static_orders: &'static [usize] = Box::leak(orders.to_vec().into_boxed_slice());
        let cm = ContextMixing::new(CmConfig {
            name: static_name,
            orders: static_orders,
            table_bits: bits,
            lr: 6,
        });
        let z = cm.compress(small).unwrap();
        println!("{:<26} {:.3}x", name, small.len() as f64 / z.len() as f64);
    }
}

fn weights() -> Weights {
    // Prefer trained weights when artifacts exist; random otherwise.
    let cfg = by_name("small").unwrap();
    match llmzip::runtime::ArtifactStore::open(None).and_then(|s| s.weights(cfg)) {
        Ok(w) => w,
        Err(_) => Weights::random(cfg, 5),
    }
}
