//! Analysis-toolkit benchmarks + Fig 2 regeneration: n-gram statistics,
//! entropy measures, BPE training throughput.

#[path = "harness.rs"]
mod harness;

use harness::{bench, section};
use llmzip::analysis;
use llmzip::experiments::{self, DatasetCache};
use llmzip::runtime::ArtifactStore;
use llmzip::tokenizer::bpe::Bpe;

fn main() {
    let n = 256 * 1024;
    let data = llmzip::textgen::quick_sample(n, 9);
    let text = String::from_utf8(data.clone()).unwrap();

    section("analysis primitives (256 KiB text)");
    bench("ngram top-10 share (1..4-grams)", 2.0, || {
        std::hint::black_box(analysis::top_k_share(&text, 10));
    })
    .print_throughput(n);
    bench("char entropy/byte", 2.0, || {
        std::hint::black_box(analysis::char_entropy_per_byte(&text));
    })
    .print_throughput(n);
    bench("word entropy/byte", 2.0, || {
        std::hint::black_box(analysis::word_entropy_per_byte(&text));
    })
    .print_throughput(n);
    bench("mutual information", 2.0, || {
        std::hint::black_box(analysis::mutual_information(&text));
    })
    .print_throughput(n);
    bench("BPE train 256 merges (64 KiB)", 3.0, || {
        std::hint::black_box(Bpe::train(&data[..64 * 1024], 256));
    })
    .print();

    // Fig 2 regeneration (needs artifacts + datasets).
    match ArtifactStore::open(None) {
        Ok(store) => {
            let mut cache = DatasetCache::new(store, "data", 32 * 1024);
            match experiments::fig2(&mut cache, "medium") {
                Ok((h, rows)) => {
                    experiments::print_table("Fig 2: top-10 n-gram coverage", &h, &rows)
                }
                Err(e) => println!("SKIP fig2: {e:#}"),
            }
        }
        Err(e) => println!("SKIP fig2: {e:#}"),
    }
}
