//! Baseline-compressor benchmarks: compress/decompress throughput and ratio
//! for all nine Table 5 baselines on mixed procedural text.

#[path = "harness.rs"]
mod harness;

use harness::{bench, section};
use llmzip::compress::registry::all_baselines;

fn main() {
    let n = 256 * 1024;
    let data = llmzip::textgen::quick_sample(n, 7);
    section(&format!("baselines on {} of mixed text", llmzip::util::human_bytes(n as u64)));
    println!(
        "{:<12} {:>8} {:>14} {:>14}",
        "METHOD", "RATIO", "COMP MiB/s", "DECOMP MiB/s"
    );
    for c in all_baselines().expect("baseline registry") {
        let mut z = Vec::new();
        let enc = bench(&format!("{} compress", c.name()), 1.5, || {
            z = c.compress(&data).unwrap();
        });
        let mut back = Vec::new();
        let dec = bench(&format!("{} decompress", c.name()), 1.5, || {
            back = c.decompress(&z).unwrap();
        });
        assert_eq!(back, data);
        println!(
            "{:<12} {:>7.2}x {:>13.2} {:>13.2}",
            c.name(),
            data.len() as f64 / z.len() as f64,
            n as f64 / (1 << 20) as f64 / enc.mean_s,
            n as f64 / (1 << 20) as f64 / dec.mean_s,
        );
    }
}
