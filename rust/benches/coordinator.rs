//! Coordinator benchmarks: dynamic-batcher overhead, end-to-end server
//! throughput/latency with the native engine (no artifacts required),
//! batch-occupancy behaviour under concurrency, and the **elastic**
//! replica pool — steady-state vs bursty load against an autoscaling
//! server with cross-replica work stealing, recording replicas-over-time
//! and tokens/sec.
//!
//! Results are written as machine-readable JSON to
//! `BENCH_coordinator.json` (override with `LLMZIP_BENCH_COORD_JSON`) so
//! the elastic-pool trajectory is diffable across PRs. Set
//! `LLMZIP_BENCH_SMOKE=1` (CI does) for a seconds-long run that still
//! exercises every measured path and emits the full JSON schema.

#[path = "harness.rs"]
mod harness;

use harness::{bench, section};
use llmzip::compress::{
    Codec, Compressor, FileSource, LlmCompressor, LlmCompressorConfig, SeekableContainer,
};
use llmzip::coordinator::{
    BatchPolicy, DynamicBatcher, FleetConfig, FleetModelSpec, FleetServer, Priority, Server,
    ServerConfig, TenantSpec, WireService, WorkItem, WorkKind,
};
use llmzip::lm::config::by_name;
use llmzip::lm::weights::Weights;
use llmzip::lm::{ExecutorKind, Precision, StepPool};
use llmzip::util::stats::percentile;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------
// Allocation accounting: a counting global allocator makes
// "allocations per op" a measured number, not a claim. Bench binary
// only — the library never sees this allocator.
// ---------------------------------------------------------------------

struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);

unsafe impl std::alloc::GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: std::alloc::Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        std::alloc::System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: std::alloc::Layout) {
        std::alloc::System.dealloc(ptr, layout)
    }

    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: std::alloc::Layout,
        new_size: usize,
    ) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        std::alloc::System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn alloc_counts() -> (u64, u64) {
    (ALLOC_CALLS.load(Ordering::Relaxed), ALLOC_BYTES.load(Ordering::Relaxed))
}

/// CI smoke mode: tiny load, same measured paths, same JSON schema.
fn smoke() -> bool {
    std::env::var("LLMZIP_BENCH_SMOKE").is_ok_and(|v| !v.is_empty() && v != "0")
}

fn batcher_bench() {
    section("dynamic batcher (pure queueing)");
    bench("push+drain 10k items, 8 lanes", if smoke() { 0.2 } else { 2.0 }, || {
        let mut b = DynamicBatcher::new(BatchPolicy {
            lanes: 8,
            max_wait: Duration::from_millis(1),
        });
        let now = Instant::now();
        for i in 0..10_000u64 {
            b.push(WorkItem {
                request_id: i,
                chunk_index: 0,
                kind: WorkKind::Compress,
                priority: if i % 4 == 0 { Priority::Interactive } else { Priority::Bulk },
                tenant: (i % 3) as u32,
                data: Vec::new().into(),
                record: None,
                codec: Codec::Range,
                enqueued: now,
            });
        }
        while b.next_batch(now + Duration::from_secs(1)).is_some() {}
    })
    .print();
}

fn server_bench() {
    section("server end-to-end (native engine, nano model)");
    let server = Arc::new(
        Server::start(
            || {
                let cfg = by_name("nano")?;
                LlmCompressor::from_weights(cfg, Weights::random(cfg, 3), 128, 8)
            },
            ServerConfig {
                chunk_tokens: 128,
                policy: BatchPolicy { lanes: 8, max_wait: Duration::from_millis(4) },
                ..Default::default()
            },
        )
        .expect("server"),
    );
    let n_clients = 8;
    let rounds = if smoke() { 1 } else { 4 };
    let payload = llmzip::textgen::quick_sample(if smoke() { 512 } else { 2048 }, 1);
    let t0 = Instant::now();
    let mut lat: Vec<f64> = Vec::new();
    let handles: Vec<_> = (0..n_clients)
        .map(|_| {
            let srv = server.clone();
            let data = payload.clone();
            std::thread::spawn(move || {
                let mut l = Vec::new();
                for _ in 0..rounds {
                    let t = Instant::now();
                    let z = srv.compress(&data).unwrap();
                    let back = srv.decompress(&z).unwrap();
                    assert_eq!(back, data);
                    l.push(t.elapsed().as_secs_f64() * 1e3);
                }
                l
            })
        })
        .collect();
    for h in handles {
        lat.extend(h.join().unwrap());
    }
    let wall = t0.elapsed().as_secs_f64();
    let total = payload.len() * n_clients * rounds * 2;
    println!(
        "{} roundtrips, {:.2}s wall, {:.1} KiB/s, latency p50/p90 {:.0}/{:.0} ms",
        n_clients * rounds,
        wall,
        total as f64 / 1024.0 / wall,
        percentile(&mut lat, 0.5),
        percentile(&mut lat, 0.9),
    );
    println!(
        "occupancy mean {:.2}  batches {}",
        server.metrics.mean_occupancy(),
        server.metrics.batches.load(Ordering::Relaxed)
    );
}

// ---------------------------------------------------------------------
// Elastic pool: steady vs bursty load against an autoscaling server.
// ---------------------------------------------------------------------

const ELASTIC_MIN: usize = 1;
const ELASTIC_MAX: usize = 4;

struct ElasticScenario {
    name: &'static str,
    wall_s: f64,
    tokens_per_sec: f64,
    scale_ups: u64,
    scale_downs: u64,
    /// (elapsed ms, live replicas) sampled ~every 10 ms.
    replicas_over_time: Vec<(f64, u64)>,
}

/// Autoscaling server: nano model, shared weights, shared work-stealing
/// StepPool, fast scaler timings so the bench window sees real churn.
fn elastic_server() -> Arc<Server> {
    let cfg = by_name("nano").unwrap();
    let weights = Arc::new(Weights::random(cfg, 17));
    let pool = StepPool::new(2);
    Arc::new(
        Server::start(
            move || {
                LlmCompressor::from_shared_pooled(
                    by_name("nano")?,
                    weights.clone(),
                    LlmCompressorConfig {
                        model: "nano".into(),
                        chunk_tokens: 128,
                        stream_bytes: 512,
                        executor: ExecutorKind::Native,
                        lanes: 4,
                        threads: 1,
                        ..Default::default()
                    },
                    Some(pool.clone()),
                )
            },
            ServerConfig {
                chunk_tokens: 128,
                replicas: ELASTIC_MIN,
                min_replicas: ELASTIC_MIN,
                max_replicas: ELASTIC_MAX,
                autoscale: true,
                autoscale_cooldown: Duration::from_millis(25),
                autoscale_shrink_after: Duration::from_millis(60),
                policy: BatchPolicy { lanes: 4, max_wait: Duration::from_millis(2) },
                ..Default::default()
            },
        )
        .expect("elastic server"),
    )
}

/// Drive `load` against a fresh elastic server while a sampler thread
/// records the replica gauge; `load` returns the bytes it pushed through
/// one full compress+decompress cycle.
fn run_elastic<F>(name: &'static str, load: F) -> ElasticScenario
where
    F: FnOnce(Arc<Server>) -> usize,
{
    let server = elastic_server();
    let stop = Arc::new(AtomicBool::new(false));
    let sampler = {
        let srv = server.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            let t0 = Instant::now();
            let mut samples = Vec::new();
            while !stop.load(Ordering::Relaxed) {
                samples.push((
                    t0.elapsed().as_secs_f64() * 1e3,
                    srv.metrics.replicas.load(Ordering::Relaxed),
                ));
                std::thread::sleep(Duration::from_millis(10));
            }
            samples
        })
    };
    let t0 = Instant::now();
    let bytes = load(server.clone());
    let wall = t0.elapsed().as_secs_f64();
    stop.store(true, Ordering::Relaxed);
    let replicas_over_time = sampler.join().unwrap();
    let m = &server.metrics;
    let scenario = ElasticScenario {
        name,
        wall_s: wall,
        // Compress + decompress both touch every byte once.
        tokens_per_sec: (2 * bytes) as f64 / wall,
        scale_ups: m.scale_ups.load(Ordering::Relaxed),
        scale_downs: m.scale_downs.load(Ordering::Relaxed),
        replicas_over_time,
    };
    assert_eq!(m.errors.load(Ordering::Relaxed), 0, "elastic bench errored: {}", m.report());
    let peak = m.replicas_peak.load(Ordering::Relaxed);
    let low = m.replicas_low.load(Ordering::Relaxed);
    assert!(low as usize >= ELASTIC_MIN && peak as usize <= ELASTIC_MAX, "{}", m.report());
    println!(
        "{name:<8} {:>10.0} tok/s  wall {:.2}s  scale_ups {}  scale_downs {}  replicas [{}..{}]",
        scenario.tokens_per_sec, wall, scenario.scale_ups, scenario.scale_downs, low, peak
    );
    scenario
}

fn elastic_bench() -> Vec<ElasticScenario> {
    section(&format!(
        "elastic replica pool (nano, autoscale {ELASTIC_MIN}..{ELASTIC_MAX}, shared steal pool)"
    ));
    let payload_bytes = if smoke() { 768usize } else { 3072 };
    let rounds = if smoke() { 1usize } else { 3 };
    // Steady: a constant stream from a fixed client set — the pool should
    // settle at one level and hold it (the no-flap property under load).
    let steady = run_elastic("steady", move |server| {
        let handles: Vec<_> = (0..3u64)
            .map(|c| {
                let srv = server.clone();
                std::thread::spawn(move || {
                    let data = llmzip::textgen::quick_sample(payload_bytes, c);
                    let mut bytes = 0usize;
                    for _ in 0..rounds {
                        let z = srv.compress(&data).unwrap();
                        assert_eq!(srv.decompress(&z).unwrap(), data);
                        bytes += data.len();
                    }
                    bytes
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).sum()
    });
    // Bursty: waves of concurrent clients separated by quiet gaps longer
    // than shrink_after — the pool should breathe (grow in the wave,
    // shrink in the gap), visible in replicas_over_time.
    let bursty = run_elastic("bursty", move |server| {
        let cycles = if smoke() { 2u64 } else { 3 };
        let mut total = 0usize;
        for cycle in 0..cycles {
            let handles: Vec<_> = (0..6u64)
                .map(|c| {
                    let srv = server.clone();
                    std::thread::spawn(move || {
                        let data =
                            llmzip::textgen::quick_sample(payload_bytes, cycle * 10 + c);
                        let z = srv.compress(&data).unwrap();
                        assert_eq!(srv.decompress(&z).unwrap(), data);
                        data.len()
                    })
                })
                .collect();
            total += handles.into_iter().map(|h| h.join().unwrap()).sum::<usize>();
            std::thread::sleep(Duration::from_millis(150));
        }
        total
    });
    vec![steady, bursty]
}

// ---------------------------------------------------------------------
// Zero-copy serve path: allocations/op with buffer pooling on vs off,
// the pool's hit/return counters, the RSS high-water mark, and the
// positioned-read property of ranged decode (frames touched vs total).
// ---------------------------------------------------------------------

struct AllocSample {
    name: &'static str,
    ops: u64,
    allocs_per_op: f64,
    kb_per_op: f64,
}

struct AllocReport {
    samples: Vec<AllocSample>,
    /// (hits, misses, returns, discards) of the pooled server's pool.
    pool: (u64, u64, u64, u64),
    /// (frames_touched, frames_total, bytes_read, file_bytes) for one
    /// small ranged decode off an on-disk container.
    range: (u64, u64, u64, u64),
    vm_hwm_kb: u64,
}

/// Process high-water RSS in KiB (Linux; 0 elsewhere).
fn vm_hwm_kb() -> u64 {
    std::fs::read_to_string("/proc/self/status")
        .ok()
        .and_then(|s| {
            s.lines()
                .find(|l| l.starts_with("VmHWM:"))
                .and_then(|l| l.split_whitespace().nth(1))
                .and_then(|v| v.parse().ok())
        })
        .unwrap_or(0)
}

/// One warmup call (lazy inits, pool fill), then `ops` measured calls.
fn measured(name: &'static str, ops: u64, mut f: impl FnMut()) -> AllocSample {
    f();
    let (c0, b0) = alloc_counts();
    for _ in 0..ops {
        f();
    }
    let (c1, b1) = alloc_counts();
    let s = AllocSample {
        name,
        ops,
        allocs_per_op: (c1 - c0) as f64 / ops as f64,
        kb_per_op: (b1 - b0) as f64 / 1024.0 / ops as f64,
    };
    println!("{:<28} {:>10.0} allocs/op  {:>10.0} KiB/op", s.name, s.allocs_per_op, s.kb_per_op);
    s
}

fn job_server(pooling: bool) -> Arc<Server> {
    Arc::new(
        Server::start(
            || {
                let cfg = by_name("nano")?;
                LlmCompressor::from_weights(cfg, Weights::random(cfg, 3), 128, 8)
            },
            ServerConfig {
                chunk_tokens: 128,
                pooling,
                policy: BatchPolicy { lanes: 8, max_wait: Duration::from_millis(2) },
                ..Default::default()
            },
        )
        .expect("server"),
    )
}

fn alloc_bench() -> AllocReport {
    section("zero-copy serve path (allocations/op, pooling A/B)");
    let payload = llmzip::textgen::quick_sample(if smoke() { 2048 } else { 8192 }, 5);
    let ops = if smoke() { 3 } else { 10 };
    let pooled = job_server(true);
    let unpooled = job_server(false);
    // Pooling changes where bytes live, never their values.
    let golden = pooled.compress(&payload).unwrap();
    assert_eq!(unpooled.compress(&payload).unwrap(), golden, "pooling changed the container");
    let mut samples = Vec::new();
    for (name, srv) in [
        ("server_roundtrip_pooled", &pooled),
        ("server_roundtrip_unpooled", &unpooled),
    ] {
        samples.push(measured(name, ops, || {
            let z = srv.compress(&payload).unwrap();
            assert_eq!(srv.decompress(&z).unwrap(), payload);
        }));
    }
    let st = pooled.pool().stats();
    println!(
        "pool: {} hits  {} misses  {} returns  {} discards",
        st.hits, st.misses, st.returns, st.discards
    );

    // Ranged decode off disk: positioned reads must touch the frames the
    // range overlaps — not the file.
    let comp = {
        let cfg = by_name("nano").unwrap();
        LlmCompressor::from_weights(cfg, Weights::random(cfg, 3), 128, 4).unwrap()
    };
    let big = llmzip::textgen::quick_sample(if smoke() { 16 << 10 } else { 64 << 10 }, 9);
    let z = comp.compress(&big).unwrap();
    let path =
        std::env::temp_dir().join(format!("llmzip-bench-range-{}.lmz", std::process::id()));
    std::fs::write(&path, &z).unwrap();
    let file = FileSource::open(&path).unwrap();
    let cont = SeekableContainer::open(&file).unwrap();
    let got = comp.decompress_range_from(&cont, 100, 64).unwrap();
    assert_eq!(&got[..], &big[100..164]);
    let range = (cont.frames_read(), cont.n_chunks() as u64, cont.bytes_read(), z.len() as u64);
    std::fs::remove_file(&path).ok();
    println!(
        "range decode [100, 164): {}/{} frames, {}/{} container bytes read",
        range.0, range.1, range.2, range.3
    );
    AllocReport {
        samples,
        pool: (st.hits, st.misses, st.returns, st.discards),
        range,
        vm_hwm_kb: vm_hwm_kb(),
    }
}

// ---------------------------------------------------------------------
// Model fleet: two pools (nano f32/range + nano int8/fse) behind one
// FleetServer — per-model throughput under mixed-tenant load, forced
// page-out churn under a tiny memory budget, and the shed rate at a
// 1-deep in-flight cap.
// ---------------------------------------------------------------------

struct FleetReport {
    /// (route key, tokens/sec) under the mixed-tenant phase.
    per_model: Vec<(String, f64)>,
    page_outs: u64,
    page_ins: u64,
    shed: u64,
    shed_attempts: u64,
}

fn fleet_spec(key: &str, precision: Precision, codec: Codec, seed: u64) -> FleetModelSpec {
    FleetModelSpec {
        key: key.to_string(),
        compressor: LlmCompressorConfig {
            model: "nano".into(),
            chunk_tokens: 128,
            stream_bytes: 512,
            executor: ExecutorKind::Native,
            lanes: 4,
            threads: 1,
            precision,
            codec,
            ..Default::default()
        },
        server: ServerConfig {
            chunk_tokens: 128,
            codec,
            policy: BatchPolicy { lanes: 4, max_wait: Duration::from_millis(2) },
            ..Default::default()
        },
        load: Arc::new(move || Ok(Weights::random(by_name("nano")?, seed))),
    }
}

fn fleet_bench() -> FleetReport {
    section("model fleet (two pools, tenant QoS, paging, shedding)");
    let payload_bytes = if smoke() { 768usize } else { 3072 };
    let rounds = if smoke() { 2usize } else { 6 };

    // Phase 1: mixed-tenant throughput per model (weights 3:1 — QoS is
    // a queueing policy; both tenants' bytes count toward the pool).
    let fleet = Arc::new(
        FleetServer::start(
            vec![
                fleet_spec("nano-f32", Precision::F32, Codec::Range, 21),
                fleet_spec("nano-int8", Precision::Int8, Codec::Fse, 22),
            ],
            FleetConfig {
                tenants: vec![
                    TenantSpec {
                        name: "alice".into(),
                        weight: 3,
                        rate_bytes_per_sec: 0.0,
                        burst_bytes: 0.0,
                    },
                    TenantSpec {
                        name: "bob".into(),
                        weight: 1,
                        rate_bytes_per_sec: 0.0,
                        burst_bytes: 0.0,
                    },
                ],
                ..Default::default()
            },
        )
        .expect("fleet"),
    );
    let alice = fleet.bind_tenant("alice").unwrap();
    let bob = fleet.bind_tenant("bob").unwrap();
    let mut per_model = Vec::new();
    for key in ["nano-f32", "nano-int8"] {
        let t0 = Instant::now();
        let handles: Vec<_> = [(alice, 31u64), (bob, 32)]
            .into_iter()
            .map(|(tenant, seed)| {
                let fl = fleet.clone();
                std::thread::spawn(move || {
                    let data = llmzip::textgen::quick_sample(payload_bytes, seed);
                    let mut bytes = 0usize;
                    for _ in 0..rounds {
                        let z = fl.compress_for(tenant, key, &data).unwrap();
                        assert_eq!(fl.decompress(&z).unwrap(), data, "{key} roundtrip");
                        bytes += data.len();
                    }
                    bytes
                })
            })
            .collect();
        let bytes: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        let tps = (2 * bytes) as f64 / t0.elapsed().as_secs_f64();
        println!("{key:<10} {tps:>10.0} tok/s (2 tenants, weights 3:1)");
        per_model.push((key.to_string(), tps));
    }
    drop(fleet);

    // Phase 2: a 1-byte memory budget forces the coldest pool out on
    // every model switch — page-out/page-in churn with byte-identical
    // results (the fingerprint check rides every re-materialization).
    let paged = Arc::new(
        FleetServer::start(
            vec![
                fleet_spec("nano-f32", Precision::F32, Codec::Range, 21),
                fleet_spec("nano-int8", Precision::Int8, Codec::Fse, 22),
            ],
            FleetConfig { memory_budget_bytes: 1, ..Default::default() },
        )
        .expect("paged fleet"),
    );
    let data = llmzip::textgen::quick_sample(payload_bytes, 33);
    for i in 0..if smoke() { 4u64 } else { 8 } {
        let key = if i % 2 == 0 { "nano-f32" } else { "nano-int8" };
        let z = paged.compress_for(0, key, &data).unwrap();
        assert_eq!(paged.decompress(&z).unwrap(), data, "{key} paged roundtrip");
    }
    let page_outs = paged.metrics.page_outs.load(Ordering::Relaxed);
    let page_ins = paged.metrics.page_ins.load(Ordering::Relaxed);
    println!("paging under 1-byte budget: {page_outs} page-outs, {page_ins} page-ins");
    drop(paged);

    // Phase 3: in-flight cap 1 + a thundering herd — the overflow must
    // shed with clean errors (counted), never hang.
    let capped = Arc::new(
        FleetServer::start(
            vec![fleet_spec("nano-f32", Precision::F32, Codec::Range, 21)],
            FleetConfig { max_inflight: 1, ..Default::default() },
        )
        .expect("capped fleet"),
    );
    let shed_attempts = 8u64;
    let handles: Vec<_> = (0..shed_attempts)
        .map(|seed| {
            let fl = capped.clone();
            std::thread::spawn(move || {
                let data = llmzip::textgen::quick_sample(512, 40 + seed);
                fl.compress_for(0, "nano-f32", &data).is_ok()
            })
        })
        .collect();
    let ok = handles.into_iter().filter(|h| h.join().unwrap()).count() as u64;
    let shed = capped.metrics.shed.load(Ordering::Relaxed);
    println!(
        "shed at cap 1: {ok}/{shed_attempts} served, {shed} shed ({:.0}%)",
        100.0 * shed as f64 / shed_attempts as f64
    );
    assert!(ok >= 1, "at least one request must get through the cap");

    FleetReport { per_model, page_outs, page_ins, shed, shed_attempts }
}

/// Hand-rolled JSON (no serde in this offline crate set).
fn write_bench_json(scenarios: &[ElasticScenario], alloc: &AllocReport, fleet: &FleetReport) {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"coordinator\",\n");
    s.push_str("  \"schema\": 3,\n");
    s.push_str("  \"elastic\": {\n");
    s.push_str(&format!(
        "    \"model\": \"nano\", \"min_replicas\": {ELASTIC_MIN}, \
         \"max_replicas\": {ELASTIC_MAX}, \"unit\": \"tokens_per_sec\",\n"
    ));
    s.push_str("    \"scenarios\": [\n");
    for (i, sc) in scenarios.iter().enumerate() {
        s.push_str(&format!(
            "      {{\"name\": \"{}\", \"tokens_per_sec\": {:.1}, \"wall_s\": {:.3}, \
             \"scale_ups\": {}, \"scale_downs\": {}, \"replicas_over_time\": [",
            sc.name, sc.tokens_per_sec, sc.wall_s, sc.scale_ups, sc.scale_downs
        ));
        for (j, (t_ms, replicas)) in sc.replicas_over_time.iter().enumerate() {
            s.push_str(&format!(
                "{}{{\"t_ms\": {t_ms:.0}, \"replicas\": {replicas}}}",
                if j == 0 { "" } else { ", " }
            ));
        }
        s.push_str(&format!("]}}{}\n", if i + 1 < scenarios.len() { "," } else { "" }));
    }
    s.push_str("    ]\n  },\n");
    s.push_str("  \"alloc\": {\n");
    s.push_str("    \"unit\": \"allocations_per_op\",\n");
    s.push_str(&format!("    \"vm_hwm_kb\": {},\n", alloc.vm_hwm_kb));
    s.push_str("    \"samples\": [\n");
    for (i, sm) in alloc.samples.iter().enumerate() {
        s.push_str(&format!(
            "      {{\"name\": \"{}\", \"ops\": {}, \"allocs_per_op\": {:.1}, \
             \"kb_per_op\": {:.1}}}{}\n",
            sm.name,
            sm.ops,
            sm.allocs_per_op,
            sm.kb_per_op,
            if i + 1 < alloc.samples.len() { "," } else { "" }
        ));
    }
    s.push_str("    ],\n");
    let (hits, misses, returns, discards) = alloc.pool;
    s.push_str(&format!(
        "    \"pool\": {{\"hits\": {hits}, \"misses\": {misses}, \"returns\": {returns}, \
         \"discards\": {discards}}},\n"
    ));
    let (frames_touched, frames_total, bytes_read, file_bytes) = alloc.range;
    s.push_str(&format!(
        "    \"range_decode\": {{\"frames_touched\": {frames_touched}, \"frames_total\": \
         {frames_total}, \"bytes_read\": {bytes_read}, \"file_bytes\": {file_bytes}}}\n"
    ));
    s.push_str("  },\n");
    s.push_str("  \"fleet\": {\n");
    s.push_str("    \"unit\": \"tokens_per_sec\",\n    \"models\": [\n");
    for (i, (key, tps)) in fleet.per_model.iter().enumerate() {
        s.push_str(&format!(
            "      {{\"key\": \"{key}\", \"tokens_per_sec\": {tps:.1}}}{}\n",
            if i + 1 < fleet.per_model.len() { "," } else { "" }
        ));
    }
    s.push_str("    ],\n");
    s.push_str(&format!(
        "    \"page_outs\": {}, \"page_ins\": {},\n",
        fleet.page_outs, fleet.page_ins
    ));
    s.push_str(&format!(
        "    \"shed\": {}, \"shed_attempts\": {}, \"shed_rate\": {:.3}\n",
        fleet.shed,
        fleet.shed_attempts,
        fleet.shed as f64 / fleet.shed_attempts.max(1) as f64
    ));
    s.push_str("  }\n}\n");
    let path = std::env::var("LLMZIP_BENCH_COORD_JSON")
        .unwrap_or_else(|_| "BENCH_coordinator.json".to_string());
    match std::fs::write(&path, &s) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => println!("\nWARN could not write {path}: {e}"),
    }
}

fn main() {
    batcher_bench();
    server_bench();
    let scenarios = elastic_bench();
    let alloc = alloc_bench();
    let fleet = fleet_bench();
    write_bench_json(&scenarios, &alloc, &fleet);
}
