//! Coordinator benchmarks: dynamic-batcher overhead, end-to-end server
//! throughput/latency with the native engine (no artifacts required),
//! batch-occupancy behaviour under concurrency, and the **elastic**
//! replica pool — steady-state vs bursty load against an autoscaling
//! server with cross-replica work stealing, recording replicas-over-time
//! and tokens/sec.
//!
//! Results are written as machine-readable JSON to
//! `BENCH_coordinator.json` (override with `LLMZIP_BENCH_COORD_JSON`) so
//! the elastic-pool trajectory is diffable across PRs. Set
//! `LLMZIP_BENCH_SMOKE=1` (CI does) for a seconds-long run that still
//! exercises every measured path and emits the full JSON schema.

#[path = "harness.rs"]
mod harness;

use harness::{bench, section};
use llmzip::compress::{Codec, LlmCompressor, LlmCompressorConfig};
use llmzip::coordinator::{
    BatchPolicy, DynamicBatcher, Priority, Server, ServerConfig, WorkItem, WorkKind,
};
use llmzip::lm::config::by_name;
use llmzip::lm::weights::Weights;
use llmzip::lm::{ExecutorKind, StepPool};
use llmzip::util::stats::percentile;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// CI smoke mode: tiny load, same measured paths, same JSON schema.
fn smoke() -> bool {
    std::env::var("LLMZIP_BENCH_SMOKE").is_ok_and(|v| !v.is_empty() && v != "0")
}

fn batcher_bench() {
    section("dynamic batcher (pure queueing)");
    bench("push+drain 10k items, 8 lanes", if smoke() { 0.2 } else { 2.0 }, || {
        let mut b = DynamicBatcher::new(BatchPolicy {
            lanes: 8,
            max_wait: Duration::from_millis(1),
        });
        let now = Instant::now();
        for i in 0..10_000u64 {
            b.push(WorkItem {
                request_id: i,
                chunk_index: 0,
                kind: WorkKind::Compress,
                priority: if i % 4 == 0 { Priority::Interactive } else { Priority::Bulk },
                data: Vec::new(),
                record: None,
                codec: Codec::Range,
                enqueued: now,
            });
        }
        while b.next_batch(now + Duration::from_secs(1)).is_some() {}
    })
    .print();
}

fn server_bench() {
    section("server end-to-end (native engine, nano model)");
    let server = Arc::new(
        Server::start(
            || {
                let cfg = by_name("nano")?;
                LlmCompressor::from_weights(cfg, Weights::random(cfg, 3), 128, 8)
            },
            ServerConfig {
                chunk_tokens: 128,
                policy: BatchPolicy { lanes: 8, max_wait: Duration::from_millis(4) },
                ..Default::default()
            },
        )
        .expect("server"),
    );
    let n_clients = 8;
    let rounds = if smoke() { 1 } else { 4 };
    let payload = llmzip::textgen::quick_sample(if smoke() { 512 } else { 2048 }, 1);
    let t0 = Instant::now();
    let mut lat: Vec<f64> = Vec::new();
    let handles: Vec<_> = (0..n_clients)
        .map(|_| {
            let srv = server.clone();
            let data = payload.clone();
            std::thread::spawn(move || {
                let mut l = Vec::new();
                for _ in 0..rounds {
                    let t = Instant::now();
                    let z = srv.compress(&data).unwrap();
                    let back = srv.decompress(&z).unwrap();
                    assert_eq!(back, data);
                    l.push(t.elapsed().as_secs_f64() * 1e3);
                }
                l
            })
        })
        .collect();
    for h in handles {
        lat.extend(h.join().unwrap());
    }
    let wall = t0.elapsed().as_secs_f64();
    let total = payload.len() * n_clients * rounds * 2;
    println!(
        "{} roundtrips, {:.2}s wall, {:.1} KiB/s, latency p50/p90 {:.0}/{:.0} ms",
        n_clients * rounds,
        wall,
        total as f64 / 1024.0 / wall,
        percentile(&mut lat, 0.5),
        percentile(&mut lat, 0.9),
    );
    println!(
        "occupancy mean {:.2}  batches {}",
        server.metrics.mean_occupancy(),
        server.metrics.batches.load(Ordering::Relaxed)
    );
}

// ---------------------------------------------------------------------
// Elastic pool: steady vs bursty load against an autoscaling server.
// ---------------------------------------------------------------------

const ELASTIC_MIN: usize = 1;
const ELASTIC_MAX: usize = 4;

struct ElasticScenario {
    name: &'static str,
    wall_s: f64,
    tokens_per_sec: f64,
    scale_ups: u64,
    scale_downs: u64,
    /// (elapsed ms, live replicas) sampled ~every 10 ms.
    replicas_over_time: Vec<(f64, u64)>,
}

/// Autoscaling server: nano model, shared weights, shared work-stealing
/// StepPool, fast scaler timings so the bench window sees real churn.
fn elastic_server() -> Arc<Server> {
    let cfg = by_name("nano").unwrap();
    let weights = Arc::new(Weights::random(cfg, 17));
    let pool = StepPool::new(2);
    Arc::new(
        Server::start(
            move || {
                LlmCompressor::from_shared_pooled(
                    by_name("nano")?,
                    weights.clone(),
                    LlmCompressorConfig {
                        model: "nano".into(),
                        chunk_tokens: 128,
                        stream_bytes: 512,
                        executor: ExecutorKind::Native,
                        lanes: 4,
                        threads: 1,
                        ..Default::default()
                    },
                    Some(pool.clone()),
                )
            },
            ServerConfig {
                chunk_tokens: 128,
                replicas: ELASTIC_MIN,
                min_replicas: ELASTIC_MIN,
                max_replicas: ELASTIC_MAX,
                autoscale: true,
                autoscale_cooldown: Duration::from_millis(25),
                autoscale_shrink_after: Duration::from_millis(60),
                policy: BatchPolicy { lanes: 4, max_wait: Duration::from_millis(2) },
                ..Default::default()
            },
        )
        .expect("elastic server"),
    )
}

/// Drive `load` against a fresh elastic server while a sampler thread
/// records the replica gauge; `load` returns the bytes it pushed through
/// one full compress+decompress cycle.
fn run_elastic<F>(name: &'static str, load: F) -> ElasticScenario
where
    F: FnOnce(Arc<Server>) -> usize,
{
    let server = elastic_server();
    let stop = Arc::new(AtomicBool::new(false));
    let sampler = {
        let srv = server.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            let t0 = Instant::now();
            let mut samples = Vec::new();
            while !stop.load(Ordering::Relaxed) {
                samples.push((
                    t0.elapsed().as_secs_f64() * 1e3,
                    srv.metrics.replicas.load(Ordering::Relaxed),
                ));
                std::thread::sleep(Duration::from_millis(10));
            }
            samples
        })
    };
    let t0 = Instant::now();
    let bytes = load(server.clone());
    let wall = t0.elapsed().as_secs_f64();
    stop.store(true, Ordering::Relaxed);
    let replicas_over_time = sampler.join().unwrap();
    let m = &server.metrics;
    let scenario = ElasticScenario {
        name,
        wall_s: wall,
        // Compress + decompress both touch every byte once.
        tokens_per_sec: (2 * bytes) as f64 / wall,
        scale_ups: m.scale_ups.load(Ordering::Relaxed),
        scale_downs: m.scale_downs.load(Ordering::Relaxed),
        replicas_over_time,
    };
    assert_eq!(m.errors.load(Ordering::Relaxed), 0, "elastic bench errored: {}", m.report());
    let peak = m.replicas_peak.load(Ordering::Relaxed);
    let low = m.replicas_low.load(Ordering::Relaxed);
    assert!(low as usize >= ELASTIC_MIN && peak as usize <= ELASTIC_MAX, "{}", m.report());
    println!(
        "{name:<8} {:>10.0} tok/s  wall {:.2}s  scale_ups {}  scale_downs {}  replicas [{}..{}]",
        scenario.tokens_per_sec, wall, scenario.scale_ups, scenario.scale_downs, low, peak
    );
    scenario
}

fn elastic_bench() -> Vec<ElasticScenario> {
    section(&format!(
        "elastic replica pool (nano, autoscale {ELASTIC_MIN}..{ELASTIC_MAX}, shared steal pool)"
    ));
    let payload_bytes = if smoke() { 768usize } else { 3072 };
    let rounds = if smoke() { 1usize } else { 3 };
    // Steady: a constant stream from a fixed client set — the pool should
    // settle at one level and hold it (the no-flap property under load).
    let steady = run_elastic("steady", move |server| {
        let handles: Vec<_> = (0..3u64)
            .map(|c| {
                let srv = server.clone();
                std::thread::spawn(move || {
                    let data = llmzip::textgen::quick_sample(payload_bytes, c);
                    let mut bytes = 0usize;
                    for _ in 0..rounds {
                        let z = srv.compress(&data).unwrap();
                        assert_eq!(srv.decompress(&z).unwrap(), data);
                        bytes += data.len();
                    }
                    bytes
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).sum()
    });
    // Bursty: waves of concurrent clients separated by quiet gaps longer
    // than shrink_after — the pool should breathe (grow in the wave,
    // shrink in the gap), visible in replicas_over_time.
    let bursty = run_elastic("bursty", move |server| {
        let cycles = if smoke() { 2u64 } else { 3 };
        let mut total = 0usize;
        for cycle in 0..cycles {
            let handles: Vec<_> = (0..6u64)
                .map(|c| {
                    let srv = server.clone();
                    std::thread::spawn(move || {
                        let data =
                            llmzip::textgen::quick_sample(payload_bytes, cycle * 10 + c);
                        let z = srv.compress(&data).unwrap();
                        assert_eq!(srv.decompress(&z).unwrap(), data);
                        data.len()
                    })
                })
                .collect();
            total += handles.into_iter().map(|h| h.join().unwrap()).sum::<usize>();
            std::thread::sleep(Duration::from_millis(150));
        }
        total
    });
    vec![steady, bursty]
}

/// Hand-rolled JSON (no serde in this offline crate set).
fn write_bench_json(scenarios: &[ElasticScenario]) {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"coordinator\",\n");
    s.push_str("  \"schema\": 1,\n");
    s.push_str("  \"elastic\": {\n");
    s.push_str(&format!(
        "    \"model\": \"nano\", \"min_replicas\": {ELASTIC_MIN}, \
         \"max_replicas\": {ELASTIC_MAX}, \"unit\": \"tokens_per_sec\",\n"
    ));
    s.push_str("    \"scenarios\": [\n");
    for (i, sc) in scenarios.iter().enumerate() {
        s.push_str(&format!(
            "      {{\"name\": \"{}\", \"tokens_per_sec\": {:.1}, \"wall_s\": {:.3}, \
             \"scale_ups\": {}, \"scale_downs\": {}, \"replicas_over_time\": [",
            sc.name, sc.tokens_per_sec, sc.wall_s, sc.scale_ups, sc.scale_downs
        ));
        for (j, (t_ms, replicas)) in sc.replicas_over_time.iter().enumerate() {
            s.push_str(&format!(
                "{}{{\"t_ms\": {t_ms:.0}, \"replicas\": {replicas}}}",
                if j == 0 { "" } else { ", " }
            ));
        }
        s.push_str(&format!("]}}{}\n", if i + 1 < scenarios.len() { "," } else { "" }));
    }
    s.push_str("    ]\n  }\n}\n");
    let path = std::env::var("LLMZIP_BENCH_COORD_JSON")
        .unwrap_or_else(|_| "BENCH_coordinator.json".to_string());
    match std::fs::write(&path, &s) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => println!("\nWARN could not write {path}: {e}"),
    }
}

fn main() {
    batcher_bench();
    server_bench();
    let scenarios = elastic_bench();
    write_bench_json(&scenarios);
}
