//! Coordinator benchmarks: dynamic-batcher overhead, end-to-end server
//! throughput/latency with the native engine (no artifacts required), and
//! batch-occupancy behaviour under concurrency.

#[path = "harness.rs"]
mod harness;

use harness::{bench, section};
use llmzip::compress::LlmCompressor;
use llmzip::coordinator::{
    BatchPolicy, DynamicBatcher, Priority, Server, ServerConfig, WorkItem, WorkKind,
};
use llmzip::lm::config::by_name;
use llmzip::lm::weights::Weights;
use llmzip::util::stats::percentile;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    section("dynamic batcher (pure queueing)");
    bench("push+drain 10k items, 8 lanes", 2.0, || {
        let mut b = DynamicBatcher::new(BatchPolicy {
            lanes: 8,
            max_wait: Duration::from_millis(1),
        });
        let now = Instant::now();
        for i in 0..10_000u64 {
            b.push(WorkItem {
                request_id: i,
                chunk_index: 0,
                kind: WorkKind::Compress,
                priority: if i % 4 == 0 { Priority::Interactive } else { Priority::Bulk },
                data: Vec::new(),
                record: None,
                enqueued: now,
            });
        }
        while b.next_batch(now + Duration::from_secs(1)).is_some() {}
    })
    .print();

    section("server end-to-end (native engine, nano model)");
    let server = Arc::new(
        Server::start(
            || {
                let cfg = by_name("nano")?;
                LlmCompressor::from_weights(cfg, Weights::random(cfg, 3), 128, 8)
            },
            ServerConfig {
                chunk_tokens: 128,
                policy: BatchPolicy { lanes: 8, max_wait: Duration::from_millis(4) },
                ..Default::default()
            },
        )
        .expect("server"),
    );
    let n_clients = 8;
    let payload = llmzip::textgen::quick_sample(2048, 1);
    let t0 = Instant::now();
    let mut lat: Vec<f64> = Vec::new();
    let handles: Vec<_> = (0..n_clients)
        .map(|_| {
            let srv = server.clone();
            let data = payload.clone();
            std::thread::spawn(move || {
                let mut l = Vec::new();
                for _ in 0..4 {
                    let t = Instant::now();
                    let z = srv.compress(&data).unwrap();
                    let back = srv.decompress(&z).unwrap();
                    assert_eq!(back, data);
                    l.push(t.elapsed().as_secs_f64() * 1e3);
                }
                l
            })
        })
        .collect();
    for h in handles {
        lat.extend(h.join().unwrap());
    }
    let wall = t0.elapsed().as_secs_f64();
    let total = payload.len() * n_clients * 4 * 2;
    println!(
        "{} roundtrips, {:.2}s wall, {:.1} KiB/s, latency p50/p90 {:.0}/{:.0} ms",
        n_clients * 4,
        wall,
        total as f64 / 1024.0 / wall,
        percentile(&mut lat, 0.5),
        percentile(&mut lat, 0.9),
    );
    println!("occupancy mean {:.2}  batches {}", server.metrics.mean_occupancy(),
        server.metrics.batches.load(Ordering::Relaxed));
}
