//! Entropy-coder microbenchmarks: throughput of the range coder, binary
//! coder, Huffman and FSE stages (the L3 hot path underneath every
//! compressor, including the paper's).

#[path = "harness.rs"]
mod harness;

use harness::{bench, section};
use llmzip::entropy::fse::{self, FseTable};
use llmzip::entropy::huffman::{HuffDecoder, HuffEncoder};
use llmzip::entropy::range::{RangeDecoder, RangeEncoder};
use llmzip::entropy::{BinDecoder, BinEncoder, BitModel, BitReader, BitWriter};
use llmzip::util::Pcg64;

const N: usize = 1 << 20;

fn main() {
    let data = llmzip::textgen::quick_sample(N, 5);

    section("range coder (order-0 static model)");
    let mut counts = [0u64; 256];
    for &b in &data {
        counts[b as usize] += 1;
    }
    let freqs = llmzip::entropy::arith::quantize_counts(&counts, 1 << 16);
    let mut cums = [0u32; 257];
    for i in 0..256 {
        cums[i + 1] = cums[i] + freqs[i];
    }
    let mut encoded = Vec::new();
    bench("range encode 1 MiB", 2.0, || {
        let mut enc = RangeEncoder::new();
        for &b in &data {
            let s = b as usize;
            enc.encode(cums[s], freqs[s], 1 << 16);
        }
        encoded = enc.finish();
    })
    .print_throughput(N);
    bench("range decode 1 MiB", 2.0, || {
        let mut dec = RangeDecoder::new(&encoded);
        for _ in 0..N {
            let f = dec.decode_freq(1 << 16);
            let sym = cums.partition_point(|&c| c <= f) - 1;
            dec.decode_update(cums[sym], freqs[sym]);
        }
    })
    .print_throughput(N);

    section("binary coder (adaptive bit model)");
    let mut bin_encoded = Vec::new();
    bench("binary encode 1 MiB (8 bits/byte)", 2.0, || {
        let mut enc = BinEncoder::new();
        let mut models = vec![BitModel::default(); 256];
        for &b in &data {
            llmzip::entropy::binary::encode_byte_tree(&mut enc, &mut models, b);
        }
        bin_encoded = enc.finish();
    })
    .print_throughput(N);
    bench("binary decode 1 MiB", 2.0, || {
        let mut dec = BinDecoder::new(&bin_encoded);
        let mut models = vec![BitModel::default(); 256];
        for _ in 0..N {
            llmzip::entropy::binary::decode_byte_tree(&mut dec, &mut models);
        }
    })
    .print_throughput(N);

    section("huffman");
    let mut freqs32 = vec![0u32; 256];
    for &b in &data {
        freqs32[b as usize] += 1;
    }
    let enc = HuffEncoder::from_freqs(&freqs32, 15);
    let mut huff_bits = Vec::new();
    bench("huffman encode 1 MiB", 2.0, || {
        let mut w = BitWriter::new();
        for &b in &data {
            enc.encode(&mut w, b as usize);
        }
        huff_bits = w.finish();
    })
    .print_throughput(N);
    let dec = HuffDecoder::from_lengths(enc.lengths()).unwrap();
    bench("huffman decode 1 MiB", 2.0, || {
        let mut r = BitReader::new(&huff_bits);
        for _ in 0..N {
            dec.decode(&mut r).unwrap();
        }
    })
    .print_throughput(N);

    section("FSE / tANS");
    let counts64: Vec<u64> = counts.to_vec();
    let norm = fse::normalize_freqs(&counts64, 12).unwrap();
    let table = FseTable::new(&norm, 12).unwrap();
    let symbols: Vec<usize> = data.iter().map(|&b| b as usize).collect();
    let mut fse_out = (0u32, Vec::new());
    bench("fse encode 1 MiB", 2.0, || {
        fse_out = fse::encode_all(&table, &symbols);
    })
    .print_throughput(N);
    bench("fse decode 1 MiB", 2.0, || {
        let _ = fse::decode_all(&table, fse_out.0, &fse_out.1, symbols.len());
    })
    .print_throughput(N);

    section("CDF quantization (LLM coder inner loop)");
    let mut rng = Pcg64::seeded(1);
    // Flat profile (worst case) and peaked profile (what a trained model
    // actually emits: a handful of candidates, the rest far below max).
    let flat: Vec<f32> = (0..272).map(|_| (rng.gen_f64() * 8.0 - 4.0) as f32).collect();
    let peaked: Vec<f32> = (0..272)
        .map(|i| if i % 37 == 0 { 5.0 } else { -20.0 + (rng.gen_f64() * 4.0) as f32 })
        .collect();
    bench("logits_to_cdf x 4096 (flat)", 1.0, || {
        for _ in 0..4096 {
            std::hint::black_box(llmzip::compress::llm::logits_to_cdf(&flat));
        }
    })
    .print();
    bench("logits_to_cdf x 4096 (peaked)", 1.0, || {
        for _ in 0..4096 {
            std::hint::black_box(llmzip::compress::llm::logits_to_cdf(&peaked));
        }
    })
    .print();
}
