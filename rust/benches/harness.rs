//! Minimal benchmark harness (criterion is not in this offline crate set):
//! warms up, runs timed iterations, reports mean/stddev/min and derived
//! throughput. Used by every bench target via `#[path] mod harness;`.

#![allow(dead_code)]

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub mean_s: f64,
    pub stddev_s: f64,
    pub min_s: f64,
    pub iters: usize,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "{:<44} {:>10.3} ms/iter  (±{:>6.3} min {:.3}, n={})",
            self.name,
            self.mean_s * 1e3,
            self.stddev_s * 1e3,
            self.min_s * 1e3,
            self.iters
        );
    }

    pub fn print_throughput(&self, bytes: usize) {
        let mibs = bytes as f64 / (1 << 20) as f64 / self.mean_s;
        println!(
            "{:<44} {:>10.3} ms/iter  {:>9.2} MiB/s  (n={})",
            self.name,
            self.mean_s * 1e3,
            mibs,
            self.iters
        );
    }
}

/// Time `f` adaptively: ~`budget_s` seconds of measurement after 1 warmup.
pub fn bench<F: FnMut()>(name: &str, budget_s: f64, mut f: F) -> BenchResult {
    // Warmup + estimate.
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((budget_s / once).ceil() as usize).clamp(1, 10_000);
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>()
        / samples.len().max(1) as f64;
    BenchResult {
        name: name.to_string(),
        mean_s: mean,
        stddev_s: var.sqrt(),
        min_s: samples.iter().copied().fold(f64::INFINITY, f64::min),
        iters,
    }
}

/// Section header.
pub fn section(title: &str) {
    println!("\n===== {title} =====");
}
