//! PJRT runtime benchmarks (require `make artifacts`): forward-call
//! latency, step-call latency, in-graph generation throughput, LLM
//! compressor throughput per executor, plus the §5.4 chunk sweep and the
//! Figs 5-9 regenerations.

#[path = "harness.rs"]
mod harness;

use harness::{bench, section};
use llmzip::compress::{Compressor, LlmCompressor, LlmCompressorConfig};
use llmzip::experiments::{self, DatasetCache};
use llmzip::lm::config::{self, by_name};
use llmzip::lm::ExecutorKind;
use llmzip::runtime::{ArtifactStore, PjrtForwardExecutor, PjrtGenerator, PjrtStepExecutor};
use llmzip::lm::LmExecutor;

fn main() {
    let store = match ArtifactStore::open(None) {
        Ok(s) => s,
        Err(e) => {
            println!("SKIP runtime bench: {e:#}");
            return;
        }
    };
    let cfg = by_name("medium").unwrap();

    section("PJRT call latency (medium)");
    let fwd = PjrtForwardExecutor::from_store(&store, cfg).expect("forward");
    let tokens = vec![65i32; config::FORWARD_BATCH * config::MAX_CONTEXT];
    bench("forward [8,256] (one chunk batch)", 3.0, || {
        std::hint::black_box(fwd.forward_raw(&tokens).unwrap());
    })
    .print_throughput(config::FORWARD_BATCH * config::MAX_CONTEXT);
    let mut step = PjrtStepExecutor::from_store(&store, cfg).expect("step");
    let toks = vec![65u32; config::STEP_BATCH];
    bench("decode_step [32 lanes]", 3.0, || {
        step.reset();
        std::hint::black_box(step.step(&toks).unwrap());
    })
    .print();

    section("in-graph generation (dataset factory)");
    let generator = PjrtGenerator::from_store(&store, cfg).expect("generator");
    let prompts: Vec<Vec<u32>> = (0..generator.batch)
        .map(|_| vec![257u32; generator.prompt_len])
        .collect();
    let out_bytes = generator.batch * generator.n_tokens;
    bench("generate [16 x 240 tokens]", 5.0, || {
        std::hint::black_box(generator.generate(&prompts, 1, 0.7).unwrap());
    })
    .print_throughput(out_bytes);

    section("LLM compressor throughput per executor (16 KiB, medium)");
    let data = llmzip::experiments::human_text(llmzip::textgen::Domain::Wiki, 16 * 1024);
    for exec in [ExecutorKind::PjrtForward, ExecutorKind::PjrtStep, ExecutorKind::Native] {
        let comp = LlmCompressor::open(
            &store,
            LlmCompressorConfig {
                model: "medium".into(),
                chunk_tokens: 256,
                stream_bytes: 4096,
                executor: exec,
            },
        )
        .expect("compressor");
        let mut z = Vec::new();
        let enc = bench(&format!("{exec:?} compress 16 KiB"), 4.0, || {
            z = comp.compress(&data).unwrap();
        });
        enc.print_throughput(data.len());
        // Decompress once (the slow path for PjrtForward is the point).
        let t = std::time::Instant::now();
        let back = comp.decompress(&z).unwrap();
        assert_eq!(back, data);
        println!(
            "{:<44} {:>10.3} ms  ({:.2} KiB/s)",
            format!("{exec:?} decompress 16 KiB (single run)"),
            t.elapsed().as_secs_f64() * 1e3,
            data.len() as f64 / 1024.0 / t.elapsed().as_secs_f64()
        );
    }

    let fig_bytes = std::env::var("LLMZIP_BENCH_BYTES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(32 * 1024);
    section(&format!("figure regenerations ({} datasets)",
        llmzip::util::human_bytes(fig_bytes as u64)));
    let mut cache = DatasetCache::new(store, "data", fig_bytes);
    for (name, table) in [
        ("Fig 5", experiments::fig5(&mut cache, 256)),
        ("Fig 6", experiments::fig6(&mut cache, 256)),
        ("Fig 7", experiments::fig7(&mut cache, "medium", 256)),
        ("Fig 8", experiments::fig8(&mut cache, 256)),
        ("Fig 9", experiments::fig9(&mut cache, "medium")),
        ("Chunk sweep (§5.4)", experiments::chunk_sweep(&mut cache, llmzip::textgen::Domain::Wiki)),
    ] {
        match table {
            Ok((h, rows)) => experiments::print_table(name, &h, &rows),
            Err(e) => println!("SKIP {name}: {e:#}"),
        }
    }
}
