//! Runtime benchmarks.
//!
//! Three tiers:
//!
//! 1. **Native engine (always runs, no artifacts needed)** — tokens/sec of
//!    the batched resolved-plan engine vs. the frozen seed implementation
//!    (`llmzip::lm::reference`), single-threaded and multi-threaded (the
//!    persistent worker pool), plus the bulk-encode path, per model size —
//!    and an **f32-vs-int8** section (quantized weight path: tokens/sec +
//!    resident weight bytes, panel copies included), plus **kernel
//!    microbenchmarks** (`"kernels"` JSON section): per-kernel GFLOP/s /
//!    GOP/s of the scalar specification vs the detected-best SIMD tier at
//!    representative projection shapes, with the selected tier string.
//! 2. **Streaming sessions (always runs)** — `CompressWriter` /
//!    `DecompressReader` tokens/sec vs the one-shot calls (bytes asserted
//!    identical), plus a peak-RSS proxy (`VmHWM`), in the `"stream"`
//!    JSON section.
//! 3. **Coordinator replica scaling (always runs)** — end-to-end server
//!    tokens/sec with 1 vs N engine replicas sharing one `Arc<Weights>`,
//!    under concurrent client load.
//! 3b. **Entropy backends (always runs)** — the `"entropy"` JSON section:
//!    coder-stage MB/s of the adaptive range coder vs the table-driven
//!    fse/tANS rank coder on a synthetic skewed rank stream, plus
//!    end-to-end compression ratios (range vs fse) on a few textgen
//!    domains through the nano model.
//! 4. **PJRT runtime (requires `make artifacts`)** — forward/step call
//!    latency, in-graph generation, compressor throughput per executor,
//!    and the figure regenerations. Skipped with a message when artifacts
//!    (or the real xla crate) are absent.
//!
//! Results are written as machine-readable JSON to `BENCH_runtime.json`
//! (override the path with `LLMZIP_BENCH_JSON`) so the bench trajectory is
//! diffable across PRs. Set `LLMZIP_BENCH_SMOKE=1` (CI does) to shrink
//! budgets and model coverage to a seconds-long smoke run that still
//! exercises every measured path and emits the full JSON schema.

#[path = "harness.rs"]
mod harness;

use harness::{bench, section};
use llmzip::compress::{Codec, Compressor, LlmCompressor, LlmCompressorConfig};
use llmzip::coordinator::{BatchPolicy, Server, ServerConfig};
use llmzip::experiments::{self, DatasetCache};
use llmzip::lm::config::{self, by_name, VOCAB};
use llmzip::lm::executor::LmExecutor;
use llmzip::lm::kernels::{self, KernelTier, PanelF32, PanelI8};
use llmzip::lm::native::NativeExecutor;
use llmzip::lm::reference::{ReferenceLane, ReferenceModel};
use llmzip::lm::weights::Weights;
use llmzip::lm::ExecutorKind;
use llmzip::util::Pcg64;
use llmzip::runtime::{ArtifactStore, PjrtForwardExecutor, PjrtGenerator, PjrtStepExecutor};
use llmzip::tokenizer::vocab::BOS;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Engine lanes for the native comparison (the PJRT forward batch width).
const LANES: usize = 8;
/// Positions per window (context resets per window, like the compressor).
const WINDOW: usize = 64;

/// CI smoke mode: tiny budgets, reduced model coverage, same JSON schema.
fn smoke() -> bool {
    std::env::var("LLMZIP_BENCH_SMOKE").is_ok_and(|v| !v.is_empty() && v != "0")
}

/// Measurement budget per data point, seconds.
fn budget_s() -> f64 {
    if smoke() {
        0.05
    } else {
        1.0
    }
}

struct NativeRow {
    model: &'static str,
    reference_tps: f64,
    batched_1t_tps: f64,
    batched_mt_tps: f64,
    mt_threads: usize,
    bulk_encode_tps: f64,
}

/// Run `step` (one full window = `LANES * WINDOW` tokens) repeatedly for
/// ~`budget_s()` seconds after a warmup pass; returns tokens/sec.
fn measure_tps<F: FnMut()>(mut step: F) -> f64 {
    step(); // warmup
    let budget = budget_s();
    let t0 = Instant::now();
    let mut iters = 0usize;
    while t0.elapsed().as_secs_f64() < budget {
        step();
        iters += 1;
    }
    (iters * LANES * WINDOW) as f64 / t0.elapsed().as_secs_f64()
}

fn native_engine_benches() -> Vec<NativeRow> {
    let mt_threads =
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(LANES);
    section(&format!(
        "native engine tokens/sec ({LANES} lanes, {WINDOW}-token windows, mt={mt_threads} threads)"
    ));
    println!(
        "{:<10} {:>14} {:>14} {:>14} {:>14} {:>9} {:>9}",
        "MODEL", "seed t/s", "batched-1t", "batched-mt", "bulk t/s", "x1t", "xmt"
    );
    let mut rows = Vec::new();
    let models: &[&'static str] =
        if smoke() { &["nano", "small"] } else { &["nano", "small", "medium", "large"] };
    for &name in models {
        let cfg = by_name(name).unwrap();
        let weights = Weights::random(cfg, 17);
        let toks: Vec<u32> = std::iter::once(BOS)
            .chain((0..WINDOW - 1).map(|i| ((i * 31 + 7) % 256) as u32))
            .collect();

        // Seed baseline: string-keyed lookups, per-token allocations,
        // serial lanes — exactly what the pre-refactor executor ran.
        let reference = ReferenceModel::new(cfg, weights.clone());
        let mut ref_lanes: Vec<ReferenceLane> =
            (0..LANES).map(|_| ReferenceLane::new(cfg, WINDOW)).collect();
        let reference_tps = measure_tps(|| {
            for l in ref_lanes.iter_mut() {
                l.reset();
            }
            for &t in &toks {
                for lane in ref_lanes.iter_mut() {
                    std::hint::black_box(reference.advance(lane, t).unwrap());
                }
            }
        });

        // Batched resolved-plan engine, single thread.
        let mut row = vec![0u32; LANES];
        let mut out = vec![0.0f32; LANES * VOCAB];
        let mut ex1 = NativeExecutor::new(cfg, weights.clone(), LANES);
        let batched_1t_tps = measure_tps(|| {
            ex1.reset();
            for &t in &toks {
                row.fill(t);
                ex1.step_into(&row, &mut out).unwrap();
            }
        });

        // Batched engine, lanes partitioned across threads.
        let mut exm = NativeExecutor::new(cfg, weights.clone(), LANES).with_threads(mt_threads);
        let batched_mt_tps = measure_tps(|| {
            exm.reset();
            for &t in &toks {
                row.fill(t);
                exm.step_into(&row, &mut out).unwrap();
            }
        });

        // Bulk-encode path (the compressor's encode-side entry point).
        let lane_inputs: Vec<Vec<u32>> = (0..LANES).map(|_| toks.clone()).collect();
        let mut exb = NativeExecutor::new(cfg, weights, LANES).with_threads(mt_threads);
        let bulk_encode_tps = measure_tps(|| {
            std::hint::black_box(exb.encode_logits(&lane_inputs, WINDOW).unwrap());
        });

        println!(
            "{:<10} {:>14.0} {:>14.0} {:>14.0} {:>14.0} {:>8.2}x {:>8.2}x",
            name,
            reference_tps,
            batched_1t_tps,
            batched_mt_tps,
            bulk_encode_tps,
            batched_1t_tps / reference_tps,
            batched_mt_tps / reference_tps,
        );
        rows.push(NativeRow {
            model: name,
            reference_tps,
            batched_1t_tps,
            batched_mt_tps,
            mt_threads,
            bulk_encode_tps,
        });
    }
    rows
}

struct Int8Row {
    model: &'static str,
    f32_tps: f64,
    int8_tps: f64,
    f32_weight_bytes: usize,
    int8_weight_bytes: usize,
}

/// F32 vs int8-quantized weights on the single-threaded step path (the
/// memory-bandwidth-bound loop quantization targets), plus the resident
/// weight bytes each engine streams per step.
fn int8_engine_benches() -> Vec<Int8Row> {
    section("int8 quantized weights vs f32 (1 thread, step path)");
    println!(
        "{:<10} {:>14} {:>14} {:>8} {:>12} {:>12}",
        "MODEL", "f32 t/s", "int8 t/s", "x", "f32 bytes", "int8 bytes"
    );
    let mut rows = Vec::new();
    let models: &[&'static str] =
        if smoke() { &["nano", "small"] } else { &["nano", "small", "medium", "large"] };
    for &name in models {
        let cfg = by_name(name).unwrap();
        let weights = Arc::new(Weights::random(cfg, 17));
        let quantized = Arc::new(weights.quantize());
        let toks: Vec<u32> = std::iter::once(BOS)
            .chain((0..WINDOW - 1).map(|i| ((i * 31 + 7) % 256) as u32))
            .collect();
        let mut row = vec![0u32; LANES];
        let mut out = vec![0.0f32; LANES * VOCAB];
        let mut f32_ex = NativeExecutor::new(cfg, weights.clone(), LANES);
        let f32_tps = measure_tps(|| {
            f32_ex.reset();
            for &t in &toks {
                row.fill(t);
                f32_ex.step_into(&row, &mut out).unwrap();
            }
        });
        let mut int8_ex = NativeExecutor::new(cfg, quantized.clone(), LANES);
        let int8_tps = measure_tps(|| {
            int8_ex.reset();
            for &t in &toks {
                row.fill(t);
                int8_ex.step_into(&row, &mut out).unwrap();
            }
        });
        // Resident bytes AFTER the engines exist: building a plan
        // materializes the interleaved panel copies in the shared bundle,
        // and the honest memory number includes them.
        let (f32_bytes, int8_bytes) = (weights.resident_bytes(), quantized.resident_bytes());
        println!(
            "{:<10} {:>14.0} {:>14.0} {:>7.2}x {:>12} {:>12}",
            name,
            f32_tps,
            int8_tps,
            int8_tps / f32_tps.max(1e-9),
            f32_bytes,
            int8_bytes,
        );
        rows.push(Int8Row {
            model: name,
            f32_tps,
            int8_tps,
            f32_weight_bytes: f32_bytes,
            int8_weight_bytes: int8_bytes,
        });
    }
    rows
}

struct KernelRow {
    op: &'static str,
    shape: String,
    unit: &'static str,
    scalar_gops: f64,
    best_gops: f64,
}

/// Ops/sec (in G-units) of `f`, where one call performs `ops_per_iter`
/// scalar operations.
fn measure_gops<F: FnMut()>(ops_per_iter: f64, mut f: F) -> f64 {
    f(); // warmup
    let budget = budget_s();
    let t0 = Instant::now();
    let mut iters = 0usize;
    while t0.elapsed().as_secs_f64() < budget {
        f();
        iters += 1;
    }
    ops_per_iter * iters as f64 / t0.elapsed().as_secs_f64() / 1e9
}

/// Kernel microbenchmarks: the scalar specification vs the detected-best
/// tier, per primitive, at representative projection shapes (8 lanes at
/// `d_model → d_model` and `d_model → d_ff` widths). Since every tier is
/// bit-identical by construction, the only interesting number is the rate.
fn kernel_benches() -> (&'static str, Vec<KernelRow>) {
    let best = KernelTier::detect();
    section(&format!("kernel microbenchmarks (selected tier: {})", best.as_str()));
    println!(
        "{:<14} {:<14} {:>10} {:>12} {:>12} {:>8}",
        "OP", "SHAPE", "UNIT", "scalar", best.as_str(), "x"
    );
    let mut rng = Pcg64::seeded(23);
    let mut rand_f32 =
        |n: usize| -> Vec<f32> { (0..n).map(|_| (rng.next_u32() as f32 / u32::MAX as f32) - 0.5).collect() };
    let n = LANES;
    let mut rows = Vec::new();
    let mut push = |op: &'static str, shape: String, unit: &'static str, per_tier: &mut dyn FnMut(KernelTier) -> f64| {
        let scalar_gops = per_tier(KernelTier::Scalar);
        let best_gops =
            if best == KernelTier::Scalar { scalar_gops } else { per_tier(best) };
        println!(
            "{:<14} {:<14} {:>10} {:>12.3} {:>12.3} {:>7.2}x",
            op,
            shape,
            unit,
            scalar_gops,
            best_gops,
            best_gops / scalar_gops.max(1e-12),
        );
        rows.push(KernelRow { op, shape, unit, scalar_gops, best_gops });
    };

    // f32 matmul, panel layout: d_model→d_model and d_model→d_ff of the
    // "large" config.
    for (d_in, d_out) in [(128usize, 128usize), (128, 512)] {
        let xs = rand_f32(n * d_in);
        let w = rand_f32(d_in * d_out);
        let panel = PanelF32::build(&w, d_in, d_out);
        let mut ys = vec![0.0f32; n * d_out];
        let flops = (2 * n * d_in * d_out) as f64;
        push("matmul_f32", format!("{n}x{d_in}x{d_out}"), "gflops", &mut |t| {
            measure_gops(flops, || {
                ys.fill(0.0);
                kernels::matmul_f32(t, n, d_in, d_out, &xs, &w, Some(&panel), &mut ys);
                std::hint::black_box(&mut ys);
            })
        });
    }

    // int8 matmul over prequantized activations at the same wide shape.
    {
        let (d_in, d_out) = (128usize, 512usize);
        let xs = rand_f32(n * d_in);
        let wf = rand_f32(d_in * d_out);
        let wq: Vec<i8> =
            wf.iter().map(|v| (v * 254.0).clamp(-127.0, 127.0) as i8).collect();
        let ws = rand_f32(d_out).iter().map(|v| v.abs() + 1e-3).collect::<Vec<_>>();
        let mut qx = vec![0i8; n * d_in];
        let mut sx = vec![0.0f32; n];
        kernels::quantize_lanes(KernelTier::Scalar, n, d_in, &xs, &mut qx, &mut sx);
        let panel = PanelI8::build(&wq, d_in, d_out);
        let mut acc = vec![0i32; n * d_out];
        let mut ys = vec![0.0f32; n * d_out];
        let ops = (2 * n * d_in * d_out) as f64;
        push("matmul_i8", format!("{n}x{d_in}x{d_out}"), "gops", &mut |t| {
            measure_gops(ops, || {
                ys.fill(0.0);
                kernels::matmul_i8(
                    t, n, d_in, d_out, &wq, &ws, Some(&panel), &qx, &sx, &mut acc, &mut ys,
                );
                std::hint::black_box(&mut ys);
            })
        });
    }

    // Reduction/elementwise primitives at head width (d_model = 128).
    {
        let d = 128usize;
        let a = rand_f32(d);
        let b = rand_f32(d);
        push("dot_f32", format!("{d}"), "gflops", &mut |t| {
            measure_gops(2.0 * d as f64, || {
                std::hint::black_box(kernels::dot_f32(t, &a, &b));
            })
        });
        let qa: Vec<i8> = a.iter().map(|v| (v * 254.0) as i8).collect();
        let qb: Vec<i8> = b.iter().map(|v| (v * 254.0) as i8).collect();
        push("dot_i8", format!("{d}"), "gops", &mut |t| {
            measure_gops(2.0 * d as f64, || {
                std::hint::black_box(kernels::dot_i8(t, &qa, &qb));
            })
        });
        let xs = rand_f32(n * d);
        let mut qx = vec![0i8; n * d];
        let mut sx = vec![0.0f32; n];
        push("quantize", format!("{n}x{d}"), "gelems", &mut |t| {
            measure_gops((n * d) as f64, || {
                kernels::quantize_lanes(t, n, d, &xs, &mut qx, &mut sx);
                std::hint::black_box(&mut qx);
            })
        });
    }
    (best.as_str(), rows)
}

struct StreamRow {
    bytes: usize,
    one_shot_compress_tps: f64,
    stream_compress_tps: f64,
    one_shot_decompress_tps: f64,
    stream_decompress_tps: f64,
    /// Peak-RSS proxy (VmHWM, KiB; 0 where /proc is unavailable), sampled
    /// AFTER the streaming phases but BEFORE the one-shot calls run — the
    /// streaming path's claim is bounded working memory (one lane group),
    /// and the one-shot whole-input buffers must not pollute the mark.
    vm_hwm_kb: u64,
}

/// Process high-water RSS in KiB (Linux; 0 elsewhere) — the bench's peak
/// memory proxy.
fn vm_hwm_kb() -> u64 {
    std::fs::read_to_string("/proc/self/status")
        .ok()
        .and_then(|s| {
            s.lines()
                .find(|l| l.starts_with("VmHWM:"))
                .and_then(|l| l.split_whitespace().nth(1))
                .and_then(|v| v.parse().ok())
        })
        .unwrap_or(0)
}

/// Streaming session API vs the one-shot calls (nano, native engine):
/// identical bytes by contract, so the interesting numbers are
/// tokens/sec on each face and the RSS proxy.
fn stream_bench() -> StreamRow {
    use std::io::{Read, Write};
    let cfg = by_name("nano").unwrap();
    let comp = LlmCompressor::from_weights(cfg, Weights::random(cfg, 17), 128, 4).unwrap();
    let bytes = if smoke() { 16 * 1024 } else { 256 * 1024 };
    section(&format!("streaming vs one-shot (nano, {} input)", bytes));
    let data = llmzip::textgen::quick_sample(bytes, 99);

    // Streaming phases FIRST, then the RSS snapshot: VmHWM is a monotonic
    // process-wide high-water mark, so sampling before the one-shot calls
    // keeps their whole-input buffers out of the streaming number.
    let t0 = Instant::now();
    let mut w = comp.stream_compress(Vec::new()).unwrap();
    for piece in data.chunks(4096) {
        w.write_all(piece).unwrap();
    }
    let (zs, summary) = w.finish().unwrap();
    let stream_compress_tps = bytes as f64 / t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    let mut r = comp.stream_decompress(&zs[..]).unwrap();
    let mut back = Vec::with_capacity(bytes);
    r.read_to_end(&mut back).unwrap();
    let stream_decompress_tps = bytes as f64 / t0.elapsed().as_secs_f64();
    assert_eq!(back, data);
    let vm = vm_hwm_kb();

    let t0 = Instant::now();
    let z = comp.compress(&data).unwrap();
    let one_shot_compress_tps = bytes as f64 / t0.elapsed().as_secs_f64();
    assert_eq!(zs, z, "streamed container must be byte-identical to one-shot");
    assert_eq!(summary.bytes_out as usize, z.len());

    let t0 = Instant::now();
    let back = comp.decompress(&z).unwrap();
    let one_shot_decompress_tps = bytes as f64 / t0.elapsed().as_secs_f64();
    assert_eq!(back, data);
    println!(
        "{:<28} {:>12.0} tok/s (one-shot)  {:>12.0} tok/s (stream)",
        "compress", one_shot_compress_tps, stream_compress_tps
    );
    println!(
        "{:<28} {:>12.0} tok/s (one-shot)  {:>12.0} tok/s (stream)",
        "decompress", one_shot_decompress_tps, stream_decompress_tps
    );
    println!("{:<28} {:>12} KiB (VmHWM proxy)", "peak RSS", vm);
    StreamRow {
        bytes,
        one_shot_compress_tps,
        stream_compress_tps,
        one_shot_decompress_tps,
        stream_decompress_tps,
        vm_hwm_kb: vm,
    }
}

struct ReplicaPoint {
    replicas: usize,
    tokens_per_sec: f64,
    decompress_p99_ms: f64,
}

/// End-to-end coordinator throughput at 1 vs N engine replicas, all
/// replicas sharing ONE `Arc<Weights>`. Concurrent clients keep every
/// replica busy; tokens/sec counts both passes (compress + decompress),
/// exactly like `Metrics::record_engine`.
fn replica_scaling_bench() -> Vec<ReplicaPoint> {
    let cfg = by_name("nano").unwrap();
    let weights = Arc::new(Weights::random(cfg, 17));
    let n_clients = 8usize;
    let reqs_per_client = if smoke() { 1usize } else { 4 };
    let payload_bytes = if smoke() { 1024usize } else { 4096 };
    let replica_counts: &[usize] = if smoke() { &[1, 2] } else { &[1, 2, 4] };
    section(&format!(
        "coordinator replica scaling (nano, shared weights, {n_clients} clients)"
    ));
    let mut points = Vec::new();
    for &replicas in replica_counts {
        let w = weights.clone();
        let server = Arc::new(
            Server::start(
                move || {
                    LlmCompressor::from_shared(
                        by_name("nano").unwrap(),
                        w.clone(),
                        LlmCompressorConfig {
                            model: "nano".into(),
                            chunk_tokens: 128,
                            stream_bytes: 512,
                            executor: ExecutorKind::Native,
                            lanes: 4,
                            threads: 1,
                            ..Default::default()
                        },
                    )
                },
                ServerConfig {
                    chunk_tokens: 128,
                    replicas,
                    policy: BatchPolicy { lanes: 4, max_wait: Duration::from_millis(2) },
                    ..Default::default()
                },
            )
            .expect("replica server"),
        );
        let t0 = Instant::now();
        let handles: Vec<_> = (0..n_clients)
            .map(|c| {
                let srv = server.clone();
                std::thread::spawn(move || {
                    let data = llmzip::textgen::quick_sample(payload_bytes, c as u64);
                    for _ in 0..reqs_per_client {
                        let z = srv.compress(&data).unwrap();
                        assert_eq!(srv.decompress(&z).unwrap(), data);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        // Both passes touch every byte once.
        let total_tokens = 2 * payload_bytes * n_clients * reqs_per_client;
        let tps = total_tokens as f64 / wall;
        let p99 = server
            .metrics
            .latency_percentile_ms(llmzip::coordinator::WorkKind::Decompress, 0.99);
        println!(
            "replicas={replicas:<2} {tps:>12.0} tok/s  decompress_p99={p99:>8.1} ms  \
             (wall {wall:.2}s)"
        );
        points.push(ReplicaPoint { replicas, tokens_per_sec: tps, decompress_p99_ms: p99 });
    }
    if let (Some(one), Some(last)) = (points.first(), points.last()) {
        println!(
            "scaling: {:.2}x at {} replicas",
            last.tokens_per_sec / one.tokens_per_sec.max(1e-9),
            last.replicas
        );
    }
    points
}

struct EntropyCoderRow {
    symbols: usize,
    range_bytes: usize,
    fse_bytes: usize,
    range_encode_mbps: f64,
    range_decode_mbps: f64,
    fse_encode_mbps: f64,
    fse_decode_mbps: f64,
}

struct EntropyRatioRow {
    domain: String,
    bytes: usize,
    range_ratio: f64,
    fse_ratio: f64,
}

/// Like `measure_tps` but counts `bytes` per iteration; returns MB/s.
fn measure_mbps<F: FnMut()>(bytes: usize, mut f: F) -> f64 {
    f(); // warmup
    let budget = budget_s();
    let t0 = Instant::now();
    let mut iters = 0usize;
    while t0.elapsed().as_secs_f64() < budget {
        f();
        iters += 1;
    }
    (iters * bytes) as f64 / (1 << 20) as f64 / t0.elapsed().as_secs_f64()
}

fn entropy_benches() -> (EntropyCoderRow, Vec<EntropyRatioRow>) {
    use llmzip::compress::llm::CDF_TOTAL;
    use llmzip::compress::rank::{decode_rank_stream, encode_rank_stream};
    use llmzip::entropy::{RangeDecoder, RangeEncoder};
    use llmzip::textgen::Domain;

    let n: usize = if smoke() { 1 << 16 } else { 1 << 20 };
    section(&format!("entropy coder stage (skewed rank stream, {} KiB)", n >> 10));

    // The stream the coder stage actually sees after the rank transform:
    // heavily skewed toward rank 0, a geometric-ish tail, and a sprinkle
    // of escape-range ranks (>= 64) — the same shape the fuzz suite uses.
    let mut rng = Pcg64::seeded(0x0e117_0b5);
    let ranks: Vec<u8> = (0..n)
        .map(|_| {
            let x = rng.gen_index(1000);
            if x < 880 {
                0
            } else if x < 940 {
                1 + rng.gen_index(3) as u8
            } else if x < 985 {
                4 + rng.gen_index(28) as u8
            } else {
                64 + rng.gen_index(192) as u8
            }
        })
        .collect();

    // Static CDF over the stream's own histogram, quantized to CDF_TOTAL
    // with every symbol kept codable. The per-symbol arithmetic (one
    // divide + multiply per encode/decode step) is exactly what the
    // production range backend pays per token, so this isolates coder
    // cost from model cost.
    let mut counts = [1u64; 256];
    for &r in &ranks {
        counts[r as usize] += 1;
    }
    let total: u64 = counts.iter().sum();
    let mut freqs = [0u32; 256];
    let mut assigned = 0u32;
    for i in 0..256 {
        let f = (counts[i] as u128 * (CDF_TOTAL as u128 - 256) / total as u128) as u32 + 1;
        freqs[i] = f;
        assigned += f;
    }
    let top = (0..256).max_by_key(|&i| counts[i]).unwrap();
    freqs[top] += CDF_TOTAL - assigned;
    let mut cum = [0u32; 257];
    for i in 0..256 {
        cum[i + 1] = cum[i] + freqs[i];
    }

    let range_payload = {
        let mut enc = RangeEncoder::new();
        for &r in &ranks {
            let s = r as usize;
            enc.encode(cum[s], cum[s + 1] - cum[s], CDF_TOTAL);
        }
        enc.finish()
    };
    let fse_payload = encode_rank_stream(&ranks).expect("fse encode");

    // Sanity before timing: both payloads decode back to the stream.
    assert_eq!(decode_rank_stream(&fse_payload, n).expect("fse decode"), ranks);
    {
        let mut dec = RangeDecoder::new(&range_payload);
        for &r in &ranks {
            let target = dec.decode_freq(CDF_TOTAL);
            let s = cum[1..].partition_point(|&c| c <= target);
            dec.decode_update(cum[s], cum[s + 1] - cum[s]);
            assert_eq!(s, r as usize);
        }
    }

    let range_encode_mbps = measure_mbps(n, || {
        let mut enc = RangeEncoder::new();
        for &r in &ranks {
            let s = r as usize;
            enc.encode(cum[s], cum[s + 1] - cum[s], CDF_TOTAL);
        }
        std::hint::black_box(enc.finish());
    });
    let range_decode_mbps = measure_mbps(n, || {
        let mut dec = RangeDecoder::new(&range_payload);
        let mut out = vec![0u8; n];
        for slot in out.iter_mut() {
            let target = dec.decode_freq(CDF_TOTAL);
            let s = cum[1..].partition_point(|&c| c <= target);
            dec.decode_update(cum[s], cum[s + 1] - cum[s]);
            *slot = s as u8;
        }
        std::hint::black_box(out);
    });
    let fse_encode_mbps = measure_mbps(n, || {
        std::hint::black_box(encode_rank_stream(&ranks).unwrap());
    });
    let fse_decode_mbps = measure_mbps(n, || {
        std::hint::black_box(decode_rank_stream(&fse_payload, n).unwrap());
    });

    println!(
        "{:<30} {:>9.1} MB/s enc {:>9.1} MB/s dec  ({} bytes)",
        "range (static cdf)", range_encode_mbps, range_decode_mbps, range_payload.len()
    );
    println!(
        "{:<30} {:>9.1} MB/s enc {:>9.1} MB/s dec  ({} bytes)",
        "fse/tANS (table-driven)", fse_encode_mbps, fse_decode_mbps, fse_payload.len()
    );
    println!(
        "fse speedup: {:.2}x encode, {:.2}x decode",
        fse_encode_mbps / range_encode_mbps.max(1e-9),
        fse_decode_mbps / range_decode_mbps.max(1e-9)
    );

    let coder = EntropyCoderRow {
        symbols: n,
        range_bytes: range_payload.len(),
        fse_bytes: fse_payload.len(),
        range_encode_mbps,
        range_decode_mbps,
        fse_encode_mbps,
        fse_decode_mbps,
    };

    // End-to-end: same model, same input, both backends — the ratio cost
    // (or gain) of swapping the adaptive range coder for the table-driven
    // one, per input domain.
    section("entropy end-to-end ratio (nano, range vs fse)");
    let cfg = by_name("nano").unwrap();
    let bytes = if smoke() { 2048 } else { 16 * 1024 };
    let range_c = LlmCompressor::from_weights(cfg, Weights::random(cfg, 17), 128, LANES)
        .expect("range compressor");
    let fse_c = LlmCompressor::from_weights(cfg, Weights::random(cfg, 17), 128, LANES)
        .expect("fse compressor")
        .with_codec(Codec::Fse);
    let mut rows = Vec::new();
    for domain in [Domain::EVAL[0], Domain::EVAL[2], Domain::EVAL[5]] {
        let data = llmzip::textgen::generate(domain, bytes, 7);
        let zr = range_c.compress(&data).unwrap();
        let zf = fse_c.compress(&data).unwrap();
        // Cross-decode keeps the bench honest about interoperability.
        assert_eq!(range_c.decompress(&zf).unwrap(), data);
        let range_ratio = data.len() as f64 / zr.len() as f64;
        let fse_ratio = data.len() as f64 / zf.len() as f64;
        println!("{domain:?}: range {range_ratio:.3}x  fse {fse_ratio:.3}x");
        rows.push(EntropyRatioRow {
            domain: format!("{domain:?}"),
            bytes,
            range_ratio,
            fse_ratio,
        });
    }
    (coder, rows)
}

/// Hand-rolled JSON (no serde in this offline crate set).
fn write_bench_json(
    rows: &[NativeRow],
    int8_rows: &[Int8Row],
    kernel_tier: &str,
    kernel_rows: &[KernelRow],
    stream: &StreamRow,
    entropy: &EntropyCoderRow,
    entropy_e2e: &[EntropyRatioRow],
    replica_points: &[ReplicaPoint],
) {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"runtime\",\n");
    s.push_str("  \"schema\": 5,\n");
    s.push_str(&format!("  \"lanes\": {LANES},\n"));
    s.push_str(&format!("  \"window\": {WINDOW},\n"));
    s.push_str("  \"unit\": \"tokens_per_sec\",\n");
    s.push_str("  \"models\": [\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"model\": \"{}\", \"reference_step_tps\": {:.1}, \
             \"batched_step_tps_1t\": {:.1}, \"batched_step_tps_mt\": {:.1}, \
             \"mt_threads\": {}, \"bulk_encode_tps\": {:.1}, \
             \"speedup_1t\": {:.3}, \"speedup_mt\": {:.3}}}{}\n",
            r.model,
            r.reference_tps,
            r.batched_1t_tps,
            r.batched_mt_tps,
            r.mt_threads,
            r.bulk_encode_tps,
            r.reference_tps.max(1e-9).recip() * r.batched_1t_tps,
            r.reference_tps.max(1e-9).recip() * r.batched_mt_tps,
            if i + 1 < rows.len() { "," } else { "" },
        ));
    }
    s.push_str("  ],\n");
    s.push_str("  \"int8\": [\n");
    for (i, r) in int8_rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"model\": \"{}\", \"f32_step_tps\": {:.1}, \"int8_step_tps\": {:.1}, \
             \"speedup\": {:.3}, \"f32_weight_bytes\": {}, \"int8_weight_bytes\": {}}}{}\n",
            r.model,
            r.f32_tps,
            r.int8_tps,
            r.int8_tps / r.f32_tps.max(1e-9),
            r.f32_weight_bytes,
            r.int8_weight_bytes,
            if i + 1 < int8_rows.len() { "," } else { "" },
        ));
    }
    s.push_str("  ],\n");
    s.push_str(&format!("  \"kernels\": {{\n    \"tier\": \"{kernel_tier}\",\n    \"rows\": [\n"));
    for (i, r) in kernel_rows.iter().enumerate() {
        s.push_str(&format!(
            "      {{\"op\": \"{}\", \"shape\": \"{}\", \"unit\": \"{}\", \
             \"scalar_gops\": {:.4}, \"best_gops\": {:.4}, \"speedup\": {:.3}}}{}\n",
            r.op,
            r.shape,
            r.unit,
            r.scalar_gops,
            r.best_gops,
            r.best_gops / r.scalar_gops.max(1e-12),
            if i + 1 < kernel_rows.len() { "," } else { "" },
        ));
    }
    s.push_str("    ]\n  },\n");
    s.push_str(&format!(
        "  \"stream\": {{\"model\": \"nano\", \"bytes\": {}, \
         \"one_shot_compress_tps\": {:.1}, \"stream_compress_tps\": {:.1}, \
         \"one_shot_decompress_tps\": {:.1}, \"stream_decompress_tps\": {:.1}, \
         \"vm_hwm_kb\": {}}},\n",
        stream.bytes,
        stream.one_shot_compress_tps,
        stream.stream_compress_tps,
        stream.one_shot_decompress_tps,
        stream.stream_decompress_tps,
        stream.vm_hwm_kb,
    ));
    s.push_str(&format!(
        "  \"entropy\": {{\n    \"coder\": {{\"symbols\": {}, \"range_bytes\": {}, \
         \"fse_bytes\": {}, \"range_encode_mbps\": {:.2}, \"range_decode_mbps\": {:.2}, \
         \"fse_encode_mbps\": {:.2}, \"fse_decode_mbps\": {:.2}}},\n",
        entropy.symbols,
        entropy.range_bytes,
        entropy.fse_bytes,
        entropy.range_encode_mbps,
        entropy.range_decode_mbps,
        entropy.fse_encode_mbps,
        entropy.fse_decode_mbps,
    ));
    s.push_str("    \"e2e\": [\n");
    for (i, r) in entropy_e2e.iter().enumerate() {
        s.push_str(&format!(
            "      {{\"domain\": \"{}\", \"bytes\": {}, \"range_ratio\": {:.4}, \
             \"fse_ratio\": {:.4}}}{}\n",
            r.domain,
            r.bytes,
            r.range_ratio,
            r.fse_ratio,
            if i + 1 < entropy_e2e.len() { "," } else { "" },
        ));
    }
    s.push_str("    ]\n  },\n");
    s.push_str("  \"replica_scaling\": {\n");
    s.push_str("    \"model\": \"nano\", \"clients\": 8, \"unit\": \"tokens_per_sec\",\n");
    s.push_str("    \"points\": [\n");
    for (i, p) in replica_points.iter().enumerate() {
        s.push_str(&format!(
            "      {{\"replicas\": {}, \"tokens_per_sec\": {:.1}, \
             \"decompress_p99_ms\": {:.3}}}{}\n",
            p.replicas,
            p.tokens_per_sec,
            p.decompress_p99_ms,
            if i + 1 < replica_points.len() { "," } else { "" },
        ));
    }
    s.push_str("    ]\n  }\n}\n");
    let path =
        std::env::var("LLMZIP_BENCH_JSON").unwrap_or_else(|_| "BENCH_runtime.json".to_string());
    match std::fs::write(&path, &s) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => println!("\nWARN could not write {path}: {e}"),
    }
}

fn pjrt_benches() {
    let store = match ArtifactStore::open(None) {
        Ok(s) => s,
        Err(e) => {
            println!("\nSKIP PJRT runtime bench: {e:#}");
            return;
        }
    };
    let cfg = by_name("medium").unwrap();

    section("PJRT call latency (medium)");
    let fwd = PjrtForwardExecutor::from_store(&store, cfg).expect("forward");
    let tokens = vec![65i32; config::FORWARD_BATCH * config::MAX_CONTEXT];
    bench("forward [8,256] (one chunk batch)", 3.0, || {
        std::hint::black_box(fwd.forward_raw(&tokens).unwrap());
    })
    .print_throughput(config::FORWARD_BATCH * config::MAX_CONTEXT);
    let mut step = PjrtStepExecutor::from_store(&store, cfg).expect("step");
    let toks = vec![65u32; config::STEP_BATCH];
    bench("decode_step [32 lanes]", 3.0, || {
        step.reset();
        std::hint::black_box(step.step(&toks).unwrap());
    })
    .print();

    section("in-graph generation (dataset factory)");
    let generator = PjrtGenerator::from_store(&store, cfg).expect("generator");
    let prompts: Vec<Vec<u32>> = (0..generator.batch)
        .map(|_| vec![257u32; generator.prompt_len])
        .collect();
    let out_bytes = generator.batch * generator.n_tokens;
    bench("generate [16 x 240 tokens]", 5.0, || {
        std::hint::black_box(generator.generate(&prompts, 1, 0.7).unwrap());
    })
    .print_throughput(out_bytes);

    section("LLM compressor throughput per executor (16 KiB, medium)");
    let data = llmzip::experiments::human_text(llmzip::textgen::Domain::Wiki, 16 * 1024);
    for exec in [ExecutorKind::PjrtForward, ExecutorKind::PjrtStep, ExecutorKind::Native] {
        let comp = LlmCompressor::open(
            &store,
            LlmCompressorConfig {
                model: "medium".into(),
                chunk_tokens: 256,
                stream_bytes: 4096,
                executor: exec,
                ..Default::default()
            },
        )
        .expect("compressor");
        let mut z = Vec::new();
        let enc = bench(&format!("{exec:?} compress 16 KiB"), 4.0, || {
            z = comp.compress(&data).unwrap();
        });
        enc.print_throughput(data.len());
        // Decompress once (the slow path for PjrtForward is the point).
        let t = std::time::Instant::now();
        let back = comp.decompress(&z).unwrap();
        assert_eq!(back, data);
        println!(
            "{:<44} {:>10.3} ms  ({:.2} KiB/s)",
            format!("{exec:?} decompress 16 KiB (single run)"),
            t.elapsed().as_secs_f64() * 1e3,
            data.len() as f64 / 1024.0 / t.elapsed().as_secs_f64()
        );
    }

    let fig_bytes = std::env::var("LLMZIP_BENCH_BYTES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(32 * 1024);
    section(&format!("figure regenerations ({} datasets)",
        llmzip::util::human_bytes(fig_bytes as u64)));
    let mut cache = DatasetCache::new(store, "data", fig_bytes);
    for (name, table) in [
        ("Fig 5", experiments::fig5(&mut cache, 256)),
        ("Fig 6", experiments::fig6(&mut cache, 256)),
        ("Fig 7", experiments::fig7(&mut cache, "medium", 256)),
        ("Fig 8", experiments::fig8(&mut cache, 256)),
        ("Fig 9", experiments::fig9(&mut cache, "medium")),
        ("Chunk sweep (§5.4)", experiments::chunk_sweep(&mut cache, llmzip::textgen::Domain::Wiki)),
    ] {
        match table {
            Ok((h, rows)) => experiments::print_table(name, &h, &rows),
            Err(e) => println!("SKIP {name}: {e:#}"),
        }
    }
}

fn main() {
    // Streaming first: its VmHWM peak-RSS proxy is a process-wide
    // monotonic mark, so the whole-weight/whole-input buffers of the
    // later phases must not run before it is sampled.
    let stream = stream_bench();
    let rows = native_engine_benches();
    let int8_rows = int8_engine_benches();
    let (kernel_tier, kernel_rows) = kernel_benches();
    let (entropy, entropy_e2e) = entropy_benches();
    let replica_points = replica_scaling_bench();
    write_bench_json(
        &rows,
        &int8_rows,
        kernel_tier,
        &kernel_rows,
        &stream,
        &entropy,
        &entropy_e2e,
        &replica_points,
    );
    if smoke() {
        println!("\nSKIP PJRT runtime bench: smoke mode");
        return;
    }
    pjrt_benches();
}
