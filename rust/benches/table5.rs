//! End-to-end regeneration of the paper's Table 5 (all 9 baselines + Ours
//! on all 8 LLM-generated datasets) plus Tables 2/3. Requires `make
//! artifacts` + generated datasets (created on demand, cached in data/).

#[path = "harness.rs"]
mod harness;

use llmzip::experiments::{self, DatasetCache};
use llmzip::runtime::ArtifactStore;

fn main() {
    let store = match ArtifactStore::open(None) {
        Ok(s) => s,
        Err(e) => {
            println!("SKIP table5 bench: {e:#}");
            return;
        }
    };
    let bytes = std::env::var("LLMZIP_BENCH_BYTES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(32 * 1024);
    let mut cache = DatasetCache::new(store, "data", bytes);
    let t0 = std::time::Instant::now();

    let (h, rows) = experiments::table2(&mut cache, "medium").expect("table2");
    experiments::print_table("Table 2: entropy & mutual information", &h, &rows);

    let (h, rows) = experiments::table3(&mut cache, "medium").expect("table3");
    experiments::print_table("Table 3: traditional & neural compressors", &h, &rows);

    let (h, rows) = experiments::table5(&mut cache, "medium", 256).expect("table5");
    experiments::print_table("Table 5: all methods x all datasets", &h, &rows);

    println!("\n(total {:.1}s on {} per dataset)", t0.elapsed().as_secs_f64(),
        llmzip::util::human_bytes(bytes as u64));
}
