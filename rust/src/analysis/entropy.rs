//! Entropy-per-byte under three tokenizations and consecutive-word mutual
//! information (paper Table 2: Char-E, BP-E, W-E, Mutual Info).
//!
//! `H_byte = H_token / L_avg` where `H_token` is the Shannon entropy of the
//! token unigram distribution and `L_avg` the frequency-weighted mean token
//! byte length (paper §3.2).

use crate::tokenizer::{bpe::Bpe, words};
use std::collections::HashMap;

/// Shannon entropy (bits) of a count table.
fn entropy_from_counts<I: IntoIterator<Item = u64>>(counts: I) -> (f64, u64) {
    let counts: Vec<u64> = counts.into_iter().collect();
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return (0.0, 0);
    }
    let t = total as f64;
    let h = counts
        .iter()
        .filter(|&&c| c > 0)
        .map(|&c| {
            let p = c as f64 / t;
            -p * p.log2()
        })
        .sum();
    (h, total)
}

/// Generic entropy-per-byte over (token -> (count, byte_len)).
fn entropy_per_byte(table: &HashMap<String, u64>) -> f64 {
    let (h_token, total) = entropy_from_counts(table.values().copied());
    if total == 0 {
        return 0.0;
    }
    let weighted_len: f64 =
        table.iter().map(|(t, &c)| t.len() as f64 * c as f64).sum::<f64>() / total as f64;
    if weighted_len == 0.0 {
        0.0
    } else {
        h_token / weighted_len
    }
}

/// Char-E: entropy per byte under character tokenization.
pub fn char_entropy_per_byte(text: &str) -> f64 {
    let mut table: HashMap<String, u64> = HashMap::new();
    for c in text.chars() {
        *table.entry(c.to_string()).or_insert(0) += 1;
    }
    entropy_per_byte(&table)
}

/// W-E: entropy per byte under word tokenization.
pub fn word_entropy_per_byte(text: &str) -> f64 {
    let mut table: HashMap<String, u64> = HashMap::new();
    for w in words::words(text) {
        *table.entry(w.to_string()).or_insert(0) += 1;
    }
    entropy_per_byte(&table)
}

/// BP-E: entropy per byte under a BPE tokenization trained on the text
/// itself (`n_merges` merges; the paper does not fix a vocabulary, so we
/// train in-corpus like subword analyses usually do).
pub fn subword_entropy_per_byte(text: &str, n_merges: usize) -> f64 {
    let bytes = text.as_bytes();
    // Train on a bounded prefix to keep the O(n·merges) trainer fast.
    let train_slice = &bytes[..bytes.len().min(200_000)];
    let bpe = Bpe::train(train_slice, n_merges);
    let tokens = bpe.encode(bytes);
    let mut counts: HashMap<u32, u64> = HashMap::new();
    for &t in &tokens {
        *counts.entry(t).or_insert(0) += 1;
    }
    let (h_token, total) = entropy_from_counts(counts.values().copied());
    if total == 0 {
        return 0.0;
    }
    let weighted_len: f64 = counts
        .iter()
        .map(|(&t, &c)| bpe.expansion(t).len() as f64 * c as f64)
        .sum::<f64>()
        / total as f64;
    h_token / weighted_len
}

/// Mutual information between consecutive words (paper §3.2):
/// `MI = Σ p(w1,w2) log2( p(w1,w2) / (p(w1) p(w2)) )`.
pub fn mutual_information(text: &str) -> f64 {
    let ws: Vec<String> = words::words(text).iter().map(|w| w.to_lowercase()).collect();
    if ws.len() < 2 {
        return 0.0;
    }
    let mut uni: HashMap<&str, u64> = HashMap::new();
    let mut bi: HashMap<(&str, &str), u64> = HashMap::new();
    for w in ws.windows(2) {
        *uni.entry(&w[0]).or_insert(0) += 1;
        *bi.entry((&w[0], &w[1])).or_insert(0) += 1;
    }
    // Unigram marginal of the second position.
    let mut uni2: HashMap<&str, u64> = HashMap::new();
    for w in ws.windows(2) {
        *uni2.entry(&w[1]).or_insert(0) += 1;
    }
    let n = (ws.len() - 1) as f64;
    let mut mi = 0.0;
    for (&(a, b), &c) in &bi {
        let p_ab = c as f64 / n;
        let p_a = uni[a] as f64 / n;
        let p_b = uni2[b] as f64 / n;
        mi += p_ab * (p_ab / (p_a * p_b)).log2();
    }
    mi
}

/// Bundle of the Table 2 metrics for one dataset.
#[derive(Clone, Copy, Debug)]
pub struct EntropyReport {
    pub char_e: f64,
    pub bpe_e: f64,
    pub word_e: f64,
    pub mutual_info: f64,
}

impl EntropyReport {
    pub fn measure(text: &str) -> Self {
        EntropyReport {
            char_e: char_entropy_per_byte(text),
            bpe_e: subword_entropy_per_byte(text, 512),
            word_e: word_entropy_per_byte(text),
            mutual_info: mutual_information(text),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_bytes_have_8_bits_per_byte() {
        let text: String = (0..4096).map(|i| (b'A' + (i % 26) as u8) as char).collect();
        // 26 equiprobable chars -> H = log2(26) ≈ 4.70 bits/char = bits/byte.
        let h = char_entropy_per_byte(&text);
        assert!((h - 26f64.log2()).abs() < 0.01, "h={h}");
    }

    #[test]
    fn repeated_char_zero_entropy() {
        let text = "aaaaaaaaaa";
        assert!(char_entropy_per_byte(text) < 1e-9);
        assert!(word_entropy_per_byte(text) < 1e-9);
    }

    #[test]
    fn word_entropy_below_char_entropy_per_byte_on_text() {
        // Longer tokens amortize entropy over more bytes.
        let text = String::from_utf8(crate::textgen::generate(
            crate::textgen::Domain::Wiki,
            60_000,
            3,
        ))
        .unwrap();
        let c = char_entropy_per_byte(&text);
        let w = word_entropy_per_byte(&text);
        assert!(w < c, "W-E {w} should be < Char-E {c}");
    }

    #[test]
    fn bpe_entropy_between_char_and_word() {
        let text = String::from_utf8(crate::textgen::generate(
            crate::textgen::Domain::Novel,
            60_000,
            4,
        ))
        .unwrap();
        let c = char_entropy_per_byte(&text);
        let b = subword_entropy_per_byte(&text, 256);
        assert!(b < c * 1.05, "BP-E {b} vs Char-E {c}");
    }

    #[test]
    fn mi_zero_for_independent_words() {
        // Random word soup: MI near 0 (small positive bias from sampling).
        let mut rng = crate::util::Pcg64::seeded(1);
        let words = ["alpha", "beta", "gamma", "delta", "epsilon", "zeta"];
        let text: String = (0..30_000)
            .map(|_| rng.choose(&words))
            .collect::<Vec<_>>()
            .join(" ");
        let mi = mutual_information(&text);
        assert!(mi < 0.05, "mi={mi}");
    }

    #[test]
    fn mi_high_for_deterministic_pairs() {
        // "a b a b ..." -> knowing w_i determines w_{i+1}: MI = H(W) = 1 bit.
        let text = "ping pong ".repeat(5000);
        let mi = mutual_information(&text);
        assert!((mi - 1.0).abs() < 0.01, "mi={mi}");
    }

    #[test]
    fn structured_text_has_higher_mi_than_tpch() {
        // The Table 2 ordering: natural text MI >> TPC-H comment MI.
        let wiki = String::from_utf8(crate::textgen::generate(
            crate::textgen::Domain::Wiki,
            80_000,
            6,
        ))
        .unwrap();
        let tpch = String::from_utf8(crate::textgen::generate(
            crate::textgen::Domain::Tpch,
            80_000,
            6,
        ))
        .unwrap();
        let mi_wiki = mutual_information(&wiki);
        let mi_tpch = mutual_information(&tpch);
        assert!(mi_wiki > mi_tpch, "wiki MI {mi_wiki} vs tpch MI {mi_tpch}");
    }
}
