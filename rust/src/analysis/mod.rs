//! Compressibility analysis toolkit — reproduces the paper's §3:
//! n-gram redundancy (Fig 2), tokenization-level entropy per byte and
//! consecutive-word mutual information (Table 2).

pub mod entropy;
pub mod ngram;

pub use entropy::{char_entropy_per_byte, subword_entropy_per_byte, word_entropy_per_byte,
    mutual_information, EntropyReport};
pub use ngram::{top_k_share, NgramStats};
