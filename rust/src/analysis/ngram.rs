//! N-gram frequency statistics (paper Fig 2: share of text covered by the
//! top-10 tokens / bigrams / trigrams / four-grams per domain).

use std::collections::HashMap;

/// Frequency table of word n-grams for one `n`.
pub struct NgramStats {
    pub n: usize,
    /// n-gram -> occurrence count.
    counts: HashMap<Vec<String>, u64>,
    total: u64,
}

impl NgramStats {
    /// Count word n-grams of length `n` in `text`.
    pub fn from_text(text: &str, n: usize) -> Self {
        assert!(n >= 1);
        let words: Vec<String> =
            crate::tokenizer::words::words(text).iter().map(|w| w.to_lowercase()).collect();
        let mut counts = HashMap::new();
        let mut total = 0u64;
        if words.len() >= n {
            for w in words.windows(n) {
                *counts.entry(w.to_vec()).or_insert(0) += 1;
                total += 1;
            }
        }
        NgramStats { n, counts, total }
    }

    /// Total n-gram occurrences.
    pub fn total(&self) -> u64 {
        self.total
    }

    /// Number of distinct n-grams.
    pub fn distinct(&self) -> usize {
        self.counts.len()
    }

    /// Top `k` n-grams by count, ties broken lexicographically (deterministic).
    pub fn top_k(&self, k: usize) -> Vec<(Vec<String>, u64)> {
        let mut v: Vec<(Vec<String>, u64)> =
            self.counts.iter().map(|(g, &c)| (g.clone(), c)).collect();
        v.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
        v.truncate(k);
        v
    }

    /// Fraction of all n-gram occurrences covered by the top `k` n-grams —
    /// the quantity Fig 2 plots.
    pub fn top_k_share(&self, k: usize) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let covered: u64 = self.top_k(k).iter().map(|(_, c)| c).sum();
        covered as f64 / self.total as f64
    }
}

/// Convenience: top-10 share for n in 1..=4 (the Fig 2 series).
pub fn top_k_share(text: &str, k: usize) -> [f64; 4] {
    let mut out = [0.0; 4];
    for (i, slot) in out.iter_mut().enumerate() {
        *slot = NgramStats::from_text(text, i + 1).top_k_share(k);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_unigrams() {
        let s = NgramStats::from_text("a b a c a", 1);
        assert_eq!(s.total(), 5);
        assert_eq!(s.distinct(), 3);
        let top = s.top_k(1);
        assert_eq!(top[0].0, vec!["a".to_string()]);
        assert_eq!(top[0].1, 3);
    }

    #[test]
    fn bigram_share() {
        let s = NgramStats::from_text("x y x y x y", 2);
        // bigrams: xy yx xy yx xy -> top-1 = xy (3/5)
        assert_eq!(s.total(), 5);
        assert!((s.top_k_share(1) - 0.6).abs() < 1e-12);
    }

    #[test]
    fn share_decreases_with_n_on_natural_text() {
        // Paper's finding: top-10 share drops steeply from unigrams to
        // 4-grams on LLM-ish text.
        let text = String::from_utf8(crate::textgen::generate(
            crate::textgen::Domain::Clinical,
            80_000,
            5,
        ))
        .unwrap();
        let shares = top_k_share(&text, 10);
        assert!(shares[0] > shares[1] && shares[1] > shares[3],
            "shares {shares:?} must be decreasing");
    }

    #[test]
    fn empty_text() {
        let s = NgramStats::from_text("", 2);
        assert_eq!(s.total(), 0);
        assert_eq!(s.top_k_share(10), 0.0);
    }

    #[test]
    fn case_insensitive() {
        let s = NgramStats::from_text("The the THE", 1);
        assert_eq!(s.distinct(), 1);
    }
}
