//! Logistic context mixing — the `nncp-sim` / `trace-sim` baselines.
//!
//! Bit-level prediction: several context models (hashed byte-history
//! contexts of different orders) each predict the next bit; predictions are
//! mixed in the logistic domain with online-learned weights (exactly the
//! PAQ/NNCP-family recipe — NNCP replaces the mixer with a transformer, but
//! the adaptive-prediction + arithmetic-coding pipeline is the same), then
//! coded with the adaptive binary arithmetic coder.

use crate::compress::Compressor;
use crate::entropy::binary::{BinDecoder, BinEncoder, PROB_BITS};
use crate::Result;

/// Probability-domain <-> logistic-domain conversion tables.
///
/// Perf note (EXPERIMENTS.md §Perf L3-2): squash was originally computed
/// with a per-bit f64 `exp`; tabulating it over the clamped logistic domain
/// made the whole nncp-sim coder ~1.6x faster with identical outputs (the
/// table is exact at every reachable input).
struct Logistic {
    /// stretch[p] = ln(p/(1-p)) for p in 1/4096 units, scaled by 256.
    stretch: Vec<i32>,
    /// squash[x + SQUASH_CLAMP] for x in [-SQUASH_CLAMP, SQUASH_CLAMP].
    squash: Vec<u16>,
}

/// Logistic-domain clamp: stretch() output lies in ~[-2120, 2120].
const SQUASH_CLAMP: i32 = 4096;

impl Logistic {
    fn new() -> Self {
        let n = 1usize << PROB_BITS;
        let mut stretch = vec![0i32; n];
        for (i, s) in stretch.iter_mut().enumerate().skip(1).take(n - 2) {
            let p = i as f64 / n as f64;
            *s = ((p / (1.0 - p)).ln() * 256.0) as i32;
        }
        stretch[0] = stretch[1];
        stretch[n - 1] = stretch[n - 2];
        let squash = (-SQUASH_CLAMP..=SQUASH_CLAMP)
            .map(|x| {
                let xf = (x as f64) / 256.0;
                let p = 4096.0 / (1.0 + (-xf).exp());
                (p as i32).clamp(1, 4095) as u16
            })
            .collect();
        Logistic { stretch, squash }
    }

    #[inline]
    fn stretch(&self, p: u16) -> i32 {
        self.stretch[p as usize]
    }

    /// Inverse: squash(x) = 4096 / (1 + e^-x/256), clamped to [1, 4095].
    #[inline]
    fn squash(&self, x: i32) -> u16 {
        let i = x.clamp(-SQUASH_CLAMP, SQUASH_CLAMP) + SQUASH_CLAMP;
        self.squash[i as usize]
    }
}

/// One hashed context model: a table of 12-bit bit-probability counters.
struct ContextModel {
    table: Vec<u16>,
    mask: usize,
    /// Current slot base for this byte (set when context updates).
    ctx_hash: usize,
}

impl ContextModel {
    fn new(bits: u32) -> Self {
        ContextModel { table: vec![2048; 1 << bits], mask: (1 << bits) - 1, ctx_hash: 0 }
    }

    /// Refresh the context hash at a byte boundary from `history`.
    #[inline]
    fn set_context(&mut self, order: usize, history: u64) {
        // Keep `order` bytes of history; mix with the order id.
        let kept = if order == 0 { 0 } else { history & ((1u64 << (8 * order.min(8))) - 1) };
        let h = kept
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(order as u64)
            .wrapping_mul(0xC2B2_AE3D_27D4_EB4F);
        self.ctx_hash = (h >> 24) as usize;
    }

    /// Slot for the current (context, partial byte) pair.
    #[inline]
    fn slot(&self, partial: usize) -> usize {
        (self.ctx_hash ^ (partial.wrapping_mul(0x9E37_79B1))) & self.mask
    }

    #[inline]
    fn predict(&self, partial: usize) -> u16 {
        self.table[self.slot(partial)]
    }

    #[inline]
    fn update(&mut self, partial: usize, bit: u8) {
        let slot = self.slot(partial);
        let p = &mut self.table[slot];
        if bit != 0 {
            *p += (4096 - *p) >> 4;
        } else {
            *p -= *p >> 4;
        }
        *p = (*p).clamp(1, 4095);
    }
}

/// Configuration for a context-mixing coder.
#[derive(Clone)]
pub struct CmConfig {
    pub name: &'static str,
    pub orders: &'static [usize],
    pub table_bits: u32,
    /// Mixer learning rate (per-mille of the error term).
    pub lr: i32,
}

/// `nncp-sim`: 6 models (orders 0-4 + sparse order-6), big tables.
pub const NNCP_SIM: CmConfig =
    CmConfig { name: "nncp", orders: &[0, 1, 2, 3, 4, 6], table_bits: 20, lr: 6 };

/// `trace-sim`: slim variant — 3 models, small tables (TRACE = slim transformer).
pub const TRACE_SIM: CmConfig = CmConfig { name: "trace", orders: &[0, 1, 2], table_bits: 16, lr: 5 };

/// The context-mixing compressor.
pub struct ContextMixing {
    cfg: CmConfig,
}

impl ContextMixing {
    pub fn new(cfg: CmConfig) -> Self {
        ContextMixing { cfg }
    }

    pub fn nncp_sim() -> Self {
        Self::new(NNCP_SIM)
    }

    pub fn trace_sim() -> Self {
        Self::new(TRACE_SIM)
    }
}

/// Mixer + models bundle; deterministic, mirrored on both sides.
struct CmState {
    logistic: Logistic,
    models: Vec<ContextModel>,
    orders: Vec<usize>,
    /// Mixer weights (fixed point, 16.16), one set per top-3-bits-of-prev-byte.
    weights: Vec<Vec<i64>>,
    lr: i32,
    history: u64,
}

impl CmState {
    fn new(cfg: &CmConfig) -> Self {
        let models = cfg.orders.iter().map(|_| ContextModel::new(cfg.table_bits)).collect();
        CmState {
            logistic: Logistic::new(),
            models,
            orders: cfg.orders.to_vec(),
            weights: vec![vec![1 << 14; cfg.orders.len()]; 8],
            lr: cfg.lr,
            history: 0,
        }
    }

    #[inline]
    fn weight_set(&self) -> usize {
        ((self.history & 0xFF) >> 5) as usize
    }

    fn set_contexts(&mut self) {
        for (m, &o) in self.models.iter_mut().zip(&self.orders) {
            m.set_context(o, self.history);
        }
    }

    /// Predict P(bit=1) and keep the per-model stretches for the update.
    #[inline]
    fn predict(&self, partial: usize, stretches: &mut [i32]) -> u16 {
        let ws = &self.weights[self.weight_set()];
        let mut dot: i64 = 0;
        for (i, m) in self.models.iter().enumerate() {
            let s = self.logistic.stretch(m.predict(partial)) as i64;
            stretches[i] = s as i32;
            dot += ws[i] * s;
        }
        self.logistic.squash((dot >> 16) as i32)
    }

    #[inline]
    fn learn(&mut self, partial: usize, bit: u8, p: u16, stretches: &[i32]) {
        // error in probability domain, scaled 0..4096
        let err = ((bit as i32) << PROB_BITS) - p as i32;
        let ws = self.weight_set();
        for (i, m) in self.models.iter_mut().enumerate() {
            self.weights[ws][i] += (self.lr as i64 * err as i64 * stretches[i] as i64) >> 10;
            m.update(partial, bit);
        }
    }

    /// Advance a byte of history.
    #[inline]
    fn push_byte(&mut self, b: u8) {
        self.history = (self.history << 8) | b as u64;
    }
}

impl Compressor for ContextMixing {
    fn name(&self) -> &str {
        self.cfg.name
    }

    fn compress(&self, data: &[u8]) -> Result<Vec<u8>> {
        let mut st = CmState::new(&self.cfg);
        let mut enc = BinEncoder::new();
        let mut stretches = vec![0i32; st.models.len()];
        for &byte in data {
            st.set_contexts();
            let mut partial = 1usize; // 1-prefixed partial byte
            for i in (0..8).rev() {
                let bit = (byte >> i) & 1;
                let p = st.predict(partial, &mut stretches);
                enc.encode(bit, p);
                st.learn(partial, bit, p, &stretches);
                partial = (partial << 1) | bit as usize;
            }
            st.push_byte(byte);
        }
        let mut out = Vec::with_capacity(data.len() / 3 + 16);
        out.extend_from_slice(&(data.len() as u64).to_le_bytes());
        out.extend_from_slice(&enc.finish());
        Ok(out)
    }

    fn decompress(&self, data: &[u8]) -> Result<Vec<u8>> {
        if data.len() < 8 {
            anyhow::bail!("truncated cm stream");
        }
        let n = crate::util::read_u64_le(data, 0) as usize;
        let mut st = CmState::new(&self.cfg);
        let mut dec = BinDecoder::new(&data[8..]);
        let mut out = Vec::with_capacity(n);
        let mut stretches = vec![0i32; st.models.len()];
        for _ in 0..n {
            st.set_contexts();
            let mut partial = 1usize;
            for _ in 0..8 {
                let p = st.predict(partial, &mut stretches);
                let bit = dec.decode(p);
                st.learn(partial, bit, p, &stretches);
                partial = (partial << 1) | bit as usize;
            }
            let byte = (partial & 0xFF) as u8;
            out.push(byte);
            st.push_byte(byte);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::test_corpus;

    fn roundtrip(data: &[u8], cfg: CmConfig) -> usize {
        let c = ContextMixing::new(cfg);
        let z = c.compress(data).unwrap();
        assert_eq!(c.decompress(&z).unwrap(), data);
        z.len()
    }

    #[test]
    fn empty_and_tiny() {
        roundtrip(b"", NNCP_SIM);
        roundtrip(b"x", NNCP_SIM);
        roundtrip(b"xyxyxy", TRACE_SIM);
    }

    #[test]
    fn textish_both_variants() {
        let data = test_corpus::textish(30_000, 1);
        let n = roundtrip(&data, NNCP_SIM);
        let t = roundtrip(&data, TRACE_SIM);
        // The deeper model should win.
        assert!(n < t, "nncp-sim {n} vs trace-sim {t}");
    }

    #[test]
    fn beats_gzip_like_on_text() {
        use crate::baselines::gzip_like::GzipLike;
        let data = test_corpus::textish(50_000, 2);
        let n = roundtrip(&data, NNCP_SIM);
        let g = GzipLike::new().compress(&data).unwrap().len();
        assert!(n < g, "cm {n} should beat gzip-like {g}");
    }

    #[test]
    fn repetitive_input() {
        let data = test_corpus::repetitive(20_000);
        let z = roundtrip(&data, NNCP_SIM);
        assert!((data.len() as f64 / z as f64) > 15.0, "ratio {}", data.len() as f64 / z as f64);
    }

    #[test]
    fn random_input_bounded_overhead() {
        let data = test_corpus::random(20_000, 3);
        let z = roundtrip(&data, TRACE_SIM);
        assert!(z < data.len() + data.len() / 10 + 64);
    }

    #[test]
    fn logistic_tables_inverse() {
        let l = Logistic::new();
        for p in (1u16..4095).step_by(7) {
            let s = l.stretch(p);
            let q = l.squash(s);
            assert!((p as i32 - q as i32).abs() <= 24, "p={p} q={q}");
        }
    }

    #[test]
    fn truncated_stream_rejected() {
        let c = ContextMixing::nncp_sim();
        assert!(c.decompress(&[9]).is_err());
    }
}
