//! The paper's three entropy-coder baselines as [`Compressor`]s:
//! order-0 Huffman, order-0 arithmetic, and order-0 FSE over bytes.
//! All three ship their model in the header and code each byte
//! independently — which is exactly why the paper finds them capped
//! below 2× on LLM-generated text (Table 5, top block).

use crate::compress::Compressor;
use crate::entropy::arith;
use crate::entropy::fse::{self, FseTable};
use crate::entropy::huffman::{pack_lengths, unpack_lengths, HuffDecoder, HuffEncoder};
use crate::entropy::{BitReader, BitWriter};
use crate::Result;

/// Order-0 canonical Huffman over bytes (paper baseline "Huffman").
pub struct HuffmanOrder0;

impl Compressor for HuffmanOrder0 {
    fn name(&self) -> &str {
        "huffman"
    }

    fn compress(&self, data: &[u8]) -> Result<Vec<u8>> {
        let mut out = Vec::with_capacity(data.len() + 144);
        out.extend_from_slice(&(data.len() as u64).to_le_bytes());
        if data.is_empty() {
            return Ok(out);
        }
        let mut freqs = vec![0u32; 256];
        for &b in data {
            freqs[b as usize] += 1;
        }
        let enc = HuffEncoder::from_freqs(&freqs, 15);
        out.extend_from_slice(&pack_lengths(enc.lengths()));
        let mut w = BitWriter::new();
        for &b in data {
            enc.encode(&mut w, b as usize);
        }
        out.extend_from_slice(&w.finish());
        Ok(out)
    }

    fn decompress(&self, data: &[u8]) -> Result<Vec<u8>> {
        if data.len() < 8 {
            anyhow::bail!("truncated huffman stream");
        }
        let n = crate::util::read_u64_le(data, 0) as usize;
        if n == 0 {
            return Ok(Vec::new());
        }
        if data.len() < 8 + 128 {
            anyhow::bail!("truncated huffman header");
        }
        let lens = unpack_lengths(&data[8..8 + 128], 256);
        let dec = HuffDecoder::from_lengths(&lens)?;
        let mut r = BitReader::new(&data[8 + 128..]);
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(dec.decode(&mut r)? as u8);
        }
        Ok(out)
    }
}

/// Order-0 static arithmetic coding over bytes (paper baseline "Arithmetic").
pub struct ArithmeticOrder0;

impl Compressor for ArithmeticOrder0 {
    fn name(&self) -> &str {
        "arithmetic"
    }

    fn compress(&self, data: &[u8]) -> Result<Vec<u8>> {
        Ok(arith::compress_static(data))
    }

    fn decompress(&self, data: &[u8]) -> Result<Vec<u8>> {
        arith::decompress_static(data)
    }
}

/// Order-0 FSE over bytes (paper baseline "FSE").
pub struct FseOrder0;

const FSE_TABLE_LOG: u32 = 12;

impl Compressor for FseOrder0 {
    fn name(&self) -> &str {
        "fse"
    }

    fn compress(&self, data: &[u8]) -> Result<Vec<u8>> {
        let mut out = Vec::with_capacity(data.len() + 530);
        out.extend_from_slice(&(data.len() as u64).to_le_bytes());
        if data.is_empty() {
            return Ok(out);
        }
        let mut counts = vec![0u64; 256];
        for &b in data {
            counts[b as usize] += 1;
        }
        let norm = fse::normalize_freqs(&counts, FSE_TABLE_LOG)?;
        let table = FseTable::new(&norm, FSE_TABLE_LOG)?;
        let symbols: Vec<usize> = data.iter().map(|&b| b as usize).collect();
        let (state, payload) = fse::encode_all(&table, &symbols);
        out.extend_from_slice(&state.to_le_bytes());
        out.extend_from_slice(&fse::pack_norm(&norm));
        out.extend_from_slice(&payload);
        Ok(out)
    }

    fn decompress(&self, data: &[u8]) -> Result<Vec<u8>> {
        if data.len() < 8 {
            anyhow::bail!("truncated fse stream");
        }
        let n = crate::util::read_u64_le(data, 0) as usize;
        if n == 0 {
            return Ok(Vec::new());
        }
        if data.len() < 12 + 512 {
            anyhow::bail!("truncated fse header");
        }
        let state = crate::util::read_u32_le(data, 8);
        let norm = fse::unpack_norm(&data[12..12 + 512], 256, FSE_TABLE_LOG)?;
        if state < (1 << FSE_TABLE_LOG) || state >= (2 << FSE_TABLE_LOG) {
            anyhow::bail!("corrupt fse state");
        }
        let table = FseTable::new(&norm, FSE_TABLE_LOG)?;
        let syms = fse::decode_all(&table, state, &data[12 + 512..], n)?;
        Ok(syms.into_iter().map(|s| s as u8).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::test_corpus;

    fn all() -> Vec<Box<dyn Compressor>> {
        vec![Box::new(HuffmanOrder0), Box::new(ArithmeticOrder0), Box::new(FseOrder0)]
    }

    #[test]
    fn roundtrip_all_coders() {
        for c in all() {
            for data in [
                Vec::new(),
                b"a".to_vec(),
                b"hello world".to_vec(),
                test_corpus::textish(20_000, 1),
                test_corpus::repetitive(5_000),
                test_corpus::random(5_000, 2),
                vec![0u8; 10_000],
            ] {
                let z = c.compress(&data).unwrap();
                assert_eq!(c.decompress(&z).unwrap(), data, "{} len {}", c.name(), data.len());
            }
        }
    }

    #[test]
    fn order0_coders_land_in_papers_band() {
        // The paper's Table 5 caps entropy-only coders below ~2x on text.
        let data = test_corpus::textish(100_000, 3);
        for c in all() {
            let ratio = c.ratio(&data).unwrap();
            assert!((1.2..2.6).contains(&ratio), "{}: ratio {ratio}", c.name());
        }
    }

    #[test]
    fn arithmetic_at_least_as_good_as_huffman() {
        let data = test_corpus::textish(100_000, 4);
        let h = HuffmanOrder0.compress(&data).unwrap().len();
        let a = ArithmeticOrder0.compress(&data).unwrap().len();
        // Arithmetic reaches fractional-bit codes; Huffman is integer-bit.
        assert!(a <= h + h / 50, "arith {a} vs huffman {h}");
    }

    #[test]
    fn corrupt_inputs_rejected() {
        for c in all() {
            assert!(c.decompress(&[1, 2, 3]).is_err(), "{}", c.name());
        }
    }
}
