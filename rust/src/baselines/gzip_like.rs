//! `gzip`-shaped dictionary compressor: LZ77 + canonical Huffman.
//!
//! Structurally DEFLATE: a literal/length alphabet and a distance alphabet,
//! log-bucketed length/distance slots with raw extra bits, Huffman tables
//! shipped as packed code lengths. (The container is ours, not RFC 1951 —
//! the paper only needs the algorithmic family, not gzip interop.)

use crate::baselines::lz77::{self, Token, MIN_MATCH};
use crate::compress::Compressor;
use crate::entropy::huffman::{pack_lengths, unpack_lengths, HuffDecoder, HuffEncoder};
use crate::entropy::{BitReader, BitWriter};
use crate::Result;

/// Log-bucketed slot coding shared by lengths and distances:
/// values `< 16` get their own slot; above that, 4 slots per octave with
/// `log2(v) - 2` raw extra bits.
#[inline]
pub fn value_to_slot(v: u32) -> (u32, u32, u32) {
    if v < 16 {
        (v, 0, 0)
    } else {
        let b = crate::util::floor_log2(v);
        let extra_bits = b - 2;
        let slot = 16 + 4 * (b - 4) + ((v >> extra_bits) & 3);
        let extra_val = v & ((1 << extra_bits) - 1);
        (slot, extra_bits, extra_val)
    }
}

/// Inverse of [`value_to_slot`]: `(base, extra_bits)`.
#[inline]
pub fn slot_to_base(slot: u32) -> (u32, u32) {
    if slot < 16 {
        (slot, 0)
    } else {
        let b = 4 + (slot - 16) / 4;
        let m = (slot - 16) % 4;
        let extra_bits = b - 2;
        ((4 + m) << extra_bits, extra_bits)
    }
}

/// Number of slots needed for values up to 2^17 (covers WINDOW and MAX_MATCH).
pub const NUM_SLOTS: usize = 16 + 4 * 14;

/// Literal/length alphabet: 256 literals + NUM_SLOTS length slots.
const LITLEN_SYMS: usize = 256 + NUM_SLOTS;

pub struct GzipLike;

impl GzipLike {
    pub fn new() -> Self {
        GzipLike
    }
}

impl Default for GzipLike {
    fn default() -> Self {
        Self::new()
    }
}

impl Compressor for GzipLike {
    fn name(&self) -> &str {
        "gzip"
    }

    fn compress(&self, data: &[u8]) -> Result<Vec<u8>> {
        let tokens = lz77::tokenize(data);
        // Frequency pass.
        let mut litlen_freq = vec![0u32; LITLEN_SYMS];
        let mut dist_freq = vec![0u32; NUM_SLOTS];
        for t in &tokens {
            match *t {
                Token::Literal(b) => litlen_freq[b as usize] += 1,
                Token::Match { len, dist } => {
                    let (ls, _, _) = value_to_slot(len - MIN_MATCH as u32);
                    litlen_freq[256 + ls as usize] += 1;
                    let (ds, _, _) = value_to_slot(dist - 1);
                    dist_freq[ds as usize] += 1;
                }
            }
        }
        // Guarantee non-empty alphabets so the decoder tables always build.
        if litlen_freq.iter().all(|&f| f == 0) {
            litlen_freq[0] = 1;
        }
        if dist_freq.iter().all(|&f| f == 0) {
            dist_freq[0] = 1;
        }
        let litlen = HuffEncoder::from_freqs(&litlen_freq, 15);
        let dist = HuffEncoder::from_freqs(&dist_freq, 15);

        let mut out = Vec::new();
        out.extend_from_slice(&(data.len() as u64).to_le_bytes());
        out.extend_from_slice(&(tokens.len() as u64).to_le_bytes());
        out.extend_from_slice(&pack_lengths(litlen.lengths()));
        out.extend_from_slice(&pack_lengths(dist.lengths()));

        let mut w = BitWriter::new();
        for t in &tokens {
            match *t {
                Token::Literal(b) => litlen.encode(&mut w, b as usize),
                Token::Match { len, dist: d } => {
                    let (ls, lbits, lval) = value_to_slot(len - MIN_MATCH as u32);
                    litlen.encode(&mut w, 256 + ls as usize);
                    w.write_bits(lval as u64, lbits);
                    let (ds, dbits, dval) = value_to_slot(d - 1);
                    dist.encode(&mut w, ds as usize);
                    w.write_bits(dval as u64, dbits);
                }
            }
        }
        out.extend_from_slice(&w.finish());
        Ok(out)
    }

    fn decompress(&self, data: &[u8]) -> Result<Vec<u8>> {
        let litlen_hdr = LITLEN_SYMS.div_ceil(2);
        let dist_hdr = NUM_SLOTS.div_ceil(2);
        let hdr = 16 + litlen_hdr + dist_hdr;
        if data.len() < hdr {
            anyhow::bail!("truncated gzip-like stream");
        }
        let orig_len = crate::util::read_u64_le(data, 0) as usize;
        let n_tokens = crate::util::read_u64_le(data, 8) as usize;
        let litlen_lens = unpack_lengths(&data[16..16 + litlen_hdr], LITLEN_SYMS);
        let dist_lens = unpack_lengths(&data[16 + litlen_hdr..16 + litlen_hdr + dist_hdr], NUM_SLOTS);
        let litlen = HuffDecoder::from_lengths(&litlen_lens)?;
        let dist = HuffDecoder::from_lengths(&dist_lens)?;

        let mut r = BitReader::new(&data[hdr..]);
        let mut out: Vec<u8> = Vec::with_capacity(orig_len);
        for _ in 0..n_tokens {
            let sym = litlen.decode(&mut r)? as usize;
            if sym < 256 {
                out.push(sym as u8);
            } else {
                let (base, ebits) = slot_to_base((sym - 256) as u32);
                let len = (base + r.read_bits(ebits) as u32) as usize + MIN_MATCH;
                let dsym = dist.decode(&mut r)? as u32;
                let (dbase, dbits) = slot_to_base(dsym);
                let d = (dbase + r.read_bits(dbits) as u32) as usize + 1;
                if d == 0 || d > out.len() {
                    anyhow::bail!("invalid distance {d}");
                }
                let start = out.len() - d;
                for i in 0..len {
                    let b = out[start + i];
                    out.push(b);
                }
            }
        }
        if out.len() != orig_len {
            anyhow::bail!("gzip-like length mismatch: {} vs {}", out.len(), orig_len);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::test_corpus;

    fn roundtrip(data: &[u8]) -> usize {
        let c = GzipLike::new();
        let z = c.compress(data).unwrap();
        assert_eq!(c.decompress(&z).unwrap(), data);
        z.len()
    }

    #[test]
    fn slot_coding_bijective() {
        for v in 0..200_000u32 {
            let (slot, ebits, eval) = value_to_slot(v);
            let (base, ebits2) = slot_to_base(slot);
            assert_eq!(ebits, ebits2);
            assert_eq!(base + eval, v, "v={v}");
            assert!((slot as usize) < NUM_SLOTS, "v={v} slot={slot}");
        }
    }

    #[test]
    fn empty_input() {
        assert!(roundtrip(b"") < 400);
    }

    #[test]
    fn tiny_inputs() {
        roundtrip(b"a");
        roundtrip(b"ab");
        roundtrip(b"hello");
    }

    #[test]
    fn textish_compresses() {
        let data = test_corpus::textish(100_000, 1);
        let z = roundtrip(&data);
        let ratio = data.len() as f64 / z as f64;
        assert!(ratio > 2.5, "ratio {ratio}");
    }

    #[test]
    fn repetitive_compresses_hard() {
        let data = test_corpus::repetitive(100_000);
        let z = roundtrip(&data);
        assert!((data.len() as f64 / z as f64) > 50.0);
    }

    #[test]
    fn random_does_not_explode() {
        let data = test_corpus::random(50_000, 2);
        let z = roundtrip(&data);
        // At most ~2% expansion + header.
        assert!(z < data.len() + data.len() / 50 + 600, "z={z}");
    }

    #[test]
    fn all_byte_values() {
        let data: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        roundtrip(&data);
    }

    #[test]
    fn corrupt_stream_rejected() {
        let c = GzipLike::new();
        assert!(c.decompress(&[0u8; 4]).is_err());
        let mut z = c.compress(&test_corpus::textish(5000, 3)).unwrap();
        // Truncate payload: decoder must error (length mismatch or bad code),
        // not panic.
        z.truncate(z.len() / 2);
        assert!(c.decompress(&z).is_err());
    }
}
