//! Shared LZ77 match-finding engine.
//!
//! Hash-chain match finder over a sliding window, with one-step lazy
//! matching — the same structure as zlib's `deflate_slow`. The three
//! dictionary baselines (`gzip_like`, `zstd_lite`, `lzma_lite`) consume the
//! token stream this produces and differ only in how they entropy-code it.

/// Minimum match length worth emitting.
pub const MIN_MATCH: usize = 4;
/// Maximum match length (fits the length-code alphabets of all serializers).
pub const MAX_MATCH: usize = 1 << 16;
/// Sliding window (maximum match distance).
pub const WINDOW: usize = 1 << 16;

const HASH_BITS: u32 = 16;
const HASH_SIZE: usize = 1 << HASH_BITS;
/// Maximum chain positions examined per match attempt.
const MAX_CHAIN: usize = 96;

/// An LZ77 token.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Token {
    Literal(u8),
    /// `dist` in `[1, WINDOW]`, `len` in `[MIN_MATCH, MAX_MATCH]`.
    Match { len: u32, dist: u32 },
}

#[inline]
fn hash4(data: &[u8], pos: usize) -> usize {
    let v = u32::from_le_bytes([data[pos], data[pos + 1], data[pos + 2], data[pos + 3]]);
    (v.wrapping_mul(0x9E37_79B1) >> (32 - HASH_BITS)) as usize
}

/// Hash-chain match finder.
struct MatchFinder {
    head: Vec<i64>,
    prev: Vec<i64>,
}

impl MatchFinder {
    fn new() -> Self {
        MatchFinder { head: vec![-1; HASH_SIZE], prev: vec![-1; WINDOW] }
    }

    #[inline]
    fn insert(&mut self, data: &[u8], pos: usize) {
        if pos + MIN_MATCH > data.len() {
            return;
        }
        let h = hash4(data, pos);
        self.prev[pos % WINDOW] = self.head[h];
        self.head[h] = pos as i64;
    }

    /// Best `(len, dist)` match at `pos`, or `None`.
    fn find(&self, data: &[u8], pos: usize) -> Option<(usize, usize)> {
        if pos + MIN_MATCH > data.len() {
            return None;
        }
        let max_len = (data.len() - pos).min(MAX_MATCH);
        let mut best_len = MIN_MATCH - 1;
        let mut best_dist = 0usize;
        let mut cand = self.head[hash4(data, pos)];
        let mut chain = MAX_CHAIN;
        while cand >= 0 && chain > 0 {
            let c = cand as usize;
            if pos - c > WINDOW {
                break;
            }
            // Cheap reject: compare the byte one past the current best.
            if c + best_len < data.len()
                && pos + best_len < data.len()
                && data[c + best_len] == data[pos + best_len]
            {
                let mut len = 0usize;
                while len < max_len && data[c + len] == data[pos + len] {
                    len += 1;
                }
                if len > best_len {
                    best_len = len;
                    best_dist = pos - c;
                    if len >= max_len {
                        break;
                    }
                }
            }
            cand = self.prev[c % WINDOW];
            chain -= 1;
        }
        if best_len >= MIN_MATCH {
            Some((best_len, best_dist))
        } else {
            None
        }
    }
}

/// Tokenize `data` with greedy + one-step-lazy parsing.
pub fn tokenize(data: &[u8]) -> Vec<Token> {
    let mut tokens = Vec::with_capacity(data.len() / 4 + 8);
    let mut mf = MatchFinder::new();
    let mut pos = 0usize;
    while pos < data.len() {
        let here = mf.find(data, pos);
        match here {
            None => {
                tokens.push(Token::Literal(data[pos]));
                mf.insert(data, pos);
                pos += 1;
            }
            Some((len, dist)) => {
                // Lazy: if the next position has a strictly longer match,
                // emit a literal instead and take the better match next turn.
                mf.insert(data, pos);
                let take_lazy = if pos + 1 < data.len() {
                    match mf.find(data, pos + 1) {
                        Some((nlen, _)) => nlen > len + 1,
                        None => false,
                    }
                } else {
                    false
                };
                if take_lazy {
                    tokens.push(Token::Literal(data[pos]));
                    pos += 1;
                } else {
                    tokens.push(Token::Match { len: len as u32, dist: dist as u32 });
                    for p in pos + 1..pos + len {
                        mf.insert(data, p);
                    }
                    pos += len;
                }
            }
        }
    }
    tokens
}

/// Reconstruct bytes from a token stream (the decoder side's core loop).
pub fn detokenize(tokens: &[Token]) -> crate::Result<Vec<u8>> {
    let mut out: Vec<u8> = Vec::new();
    for t in tokens {
        match *t {
            Token::Literal(b) => out.push(b),
            Token::Match { len, dist } => {
                let dist = dist as usize;
                let len = len as usize;
                if dist == 0 || dist > out.len() {
                    anyhow::bail!("invalid match distance {dist} at output length {}", out.len());
                }
                let start = out.len() - dist;
                // Overlapping copies are the norm (dist < len == RLE).
                for i in 0..len {
                    let b = out[start + i];
                    out.push(b);
                }
            }
        }
    }
    Ok(out)
}

/// Useful stats for benchmarks / ablations.
pub struct ParseStats {
    pub literals: usize,
    pub matches: usize,
    pub match_bytes: usize,
}

pub fn parse_stats(tokens: &[Token]) -> ParseStats {
    let mut s = ParseStats { literals: 0, matches: 0, match_bytes: 0 };
    for t in tokens {
        match t {
            Token::Literal(_) => s.literals += 1,
            Token::Match { len, .. } => {
                s.matches += 1;
                s.match_bytes += *len as usize;
            }
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::test_corpus;

    fn roundtrip(data: &[u8]) -> Vec<Token> {
        let tokens = tokenize(data);
        assert_eq!(detokenize(&tokens).unwrap(), data);
        tokens
    }

    #[test]
    fn empty_and_tiny_inputs() {
        roundtrip(b"");
        roundtrip(b"a");
        roundtrip(b"ab");
        roundtrip(b"abc");
        roundtrip(b"abcd");
    }

    #[test]
    fn repetitive_input_produces_matches() {
        let data = test_corpus::repetitive(10_000);
        let tokens = roundtrip(&data);
        let stats = parse_stats(&tokens);
        assert!(stats.matches > 0);
        assert!(stats.match_bytes as f64 > data.len() as f64 * 0.95);
        // Token stream should be tiny relative to input.
        assert!(tokens.len() < 100, "{} tokens", tokens.len());
    }

    #[test]
    fn overlapping_match_rle() {
        // "aaaa..." forces dist=1 overlapping copies.
        let data = vec![b'a'; 5000];
        let tokens = roundtrip(&data);
        assert!(tokens.len() <= 3, "{:?}", &tokens[..tokens.len().min(5)]);
    }

    #[test]
    fn textish_roundtrip_and_gain() {
        let data = test_corpus::textish(50_000, 1);
        let tokens = roundtrip(&data);
        let stats = parse_stats(&tokens);
        assert!(stats.matches > 100);
        assert!(stats.match_bytes > stats.literals);
    }

    #[test]
    fn random_input_mostly_literals() {
        let data = test_corpus::random(20_000, 2);
        let tokens = roundtrip(&data);
        let stats = parse_stats(&tokens);
        assert!(stats.literals as f64 > data.len() as f64 * 0.98);
    }

    #[test]
    fn long_range_match_within_window() {
        let mut data = test_corpus::random(1000, 3);
        let tail = data.clone();
        data.extend_from_slice(&vec![b' '; 1000]);
        data.extend_from_slice(&tail); // repeat 2000 bytes back
        let tokens = roundtrip(&data);
        let stats = parse_stats(&tokens);
        assert!(stats.match_bytes >= 900, "match_bytes={}", stats.match_bytes);
    }

    #[test]
    fn match_beyond_window_not_found() {
        // Two identical random blocks separated by > WINDOW of random data:
        // matches must respect the window bound (correctness of decode relies
        // on dist <= out.len(), checked by roundtrip).
        let block = test_corpus::random(500, 4);
        let mut data = block.clone();
        data.extend(test_corpus::random(WINDOW + 100, 5));
        data.extend_from_slice(&block);
        roundtrip(&data);
    }

    #[test]
    fn detokenize_rejects_bad_distance() {
        let bad = vec![Token::Literal(b'a'), Token::Match { len: 4, dist: 9 }];
        assert!(detokenize(&bad).is_err());
    }

    #[test]
    fn max_match_cap_respected() {
        let data = vec![b'x'; MAX_MATCH * 3];
        let tokens = tokenize(&data);
        for t in &tokens {
            if let Token::Match { len, .. } = t {
                assert!(*len as usize <= MAX_MATCH);
            }
        }
        assert_eq!(detokenize(&tokens).unwrap(), data);
    }
}
