//! LZMA-shaped dictionary compressor: LZ77 parse + adaptive binary range
//! coding with context modelling.
//!
//! The structure follows LZMA's coder: a `is_match` flag per position coded
//! against a small state context, literals coded through an order-1
//! context-selected bit tree, match lengths and distance slots through
//! adaptive bit trees with direct bits for the distance remainder. All
//! probabilities adapt as they code, which is what gives LZMA its edge over
//! DEFLATE on text.

use crate::baselines::lz77::{self, Token, MIN_MATCH};
use crate::compress::Compressor;
use crate::entropy::binary::{BinDecoder, BinEncoder, BitModel};
use crate::Result;

/// Number of literal contexts: top `LC` bits of the previous byte.
const LC: u32 = 3;
const NUM_LIT_CTX: usize = 1 << LC;

/// Distance slots: 6-bit slot (like LZMA's 64 slots) covering 32-bit dists.
const DIST_SLOTS: usize = 64;
/// Length alphabet: lengths MIN_MATCH..MIN_MATCH+255 coded as a byte tree,
/// longer lengths escape to a second byte tree of the remainder's high bits.
const LEN_LOW_MAX: u32 = 254;

#[inline]
fn dist_slot(dist: u32) -> u32 {
    // slot = 2*log2(d) + top bit below msb (LZMA's scheme).
    if dist < 4 {
        dist
    } else {
        let b = crate::util::floor_log2(dist);
        2 * b + ((dist >> (b - 1)) & 1)
    }
}

#[inline]
fn slot_base_bits(slot: u32) -> (u32, u32) {
    if slot < 4 {
        (slot, 0)
    } else {
        let b = slot / 2;
        let m = slot & 1;
        let bits = b - 1;
        ((2 + m) << bits, bits)
    }
}

/// Encode a value through an adaptive `n_bits`-deep bit tree.
#[inline]
fn tree_encode(enc: &mut BinEncoder, models: &mut [BitModel], n_bits: u32, value: u32) {
    let mut node = 1usize;
    for i in (0..n_bits).rev() {
        let bit = ((value >> i) & 1) as u8;
        enc.encode_update(bit, &mut models[node]);
        node = (node << 1) | bit as usize;
    }
}

#[inline]
fn tree_decode(dec: &mut BinDecoder, models: &mut [BitModel], n_bits: u32) -> u32 {
    let mut node = 1usize;
    for _ in 0..n_bits {
        let bit = dec.decode_update(&mut models[node]);
        node = (node << 1) | bit as usize;
    }
    (node as u32) & ((1 << n_bits) - 1)
}

/// All adaptive probability state, identical on both sides.
struct Models {
    is_match: Vec<BitModel>,          // ctx: previous token was match (0/1)
    literal: Vec<Vec<BitModel>>,      // [lit ctx][256-node tree]
    len_low: Vec<BitModel>,           // 256-leaf tree over len - MIN_MATCH (0..=254)
    len_is_high: BitModel,            // escape flag for long lengths
    len_high: Vec<BitModel>,          // 16-bit tree for long lengths
    dist_slot: Vec<BitModel>,         // 64-leaf tree (6 bits)
}

impl Models {
    fn new() -> Self {
        Models {
            is_match: vec![BitModel::default(); 2],
            literal: (0..NUM_LIT_CTX).map(|_| vec![BitModel::default(); 256]).collect(),
            len_low: vec![BitModel::default(); 512],
            len_is_high: BitModel::default(),
            len_high: vec![BitModel::default(); 1 << 17],
            dist_slot: vec![BitModel::default(); 128],
        }
    }
}

pub struct LzmaLite;

impl LzmaLite {
    pub fn new() -> Self {
        LzmaLite
    }
}

impl Default for LzmaLite {
    fn default() -> Self {
        Self::new()
    }
}

impl Compressor for LzmaLite {
    fn name(&self) -> &str {
        "lzma"
    }

    fn compress(&self, data: &[u8]) -> Result<Vec<u8>> {
        let tokens = lz77::tokenize(data);
        let mut m = Models::new();
        let mut enc = BinEncoder::new();
        let mut prev_byte = 0u8;
        let mut prev_was_match = 0usize;
        for t in &tokens {
            match *t {
                Token::Literal(b) => {
                    enc.encode_update(0, &mut m.is_match[prev_was_match]);
                    let ctx = (prev_byte >> (8 - LC)) as usize;
                    crate::entropy::binary::encode_byte_tree(&mut enc, &mut m.literal[ctx], b);
                    prev_byte = b;
                    prev_was_match = 0;
                }
                Token::Match { len, dist } => {
                    enc.encode_update(1, &mut m.is_match[prev_was_match]);
                    let lv = len - MIN_MATCH as u32;
                    if lv <= LEN_LOW_MAX {
                        tree_encode(&mut enc, &mut m.len_low, 8, lv);
                    } else {
                        tree_encode(&mut enc, &mut m.len_low, 8, 255);
                        enc.encode_update(0, &mut m.len_is_high); // reserved flag
                        tree_encode(&mut enc, &mut m.len_high, 16, lv - 255);
                    }
                    let slot = dist_slot(dist);
                    tree_encode(&mut enc, &mut m.dist_slot, 6, slot);
                    let (base, bits) = slot_base_bits(slot);
                    if bits > 0 {
                        let rem = dist - base;
                        // Direct bits at p=1/2 (LZMA codes mid bits adaptively,
                        // low "align" bits directly; we code all directly).
                        for i in (0..bits).rev() {
                            enc.encode(((rem >> i) & 1) as u8, 2048);
                        }
                    }
                    prev_was_match = 1;
                    prev_byte = 0;
                }
            }
        }
        let mut out = Vec::with_capacity(data.len() / 3 + 16);
        out.extend_from_slice(&(data.len() as u64).to_le_bytes());
        out.extend_from_slice(&(tokens.len() as u64).to_le_bytes());
        out.extend_from_slice(&enc.finish());
        Ok(out)
    }

    fn decompress(&self, data: &[u8]) -> Result<Vec<u8>> {
        if data.len() < 16 {
            anyhow::bail!("truncated lzma-lite stream");
        }
        let orig_len = crate::util::read_u64_le(data, 0) as usize;
        let n_tokens = crate::util::read_u64_le(data, 8) as usize;
        let mut m = Models::new();
        let mut dec = BinDecoder::new(&data[16..]);
        let mut out: Vec<u8> = Vec::with_capacity(orig_len);
        let mut prev_byte = 0u8;
        let mut prev_was_match = 0usize;
        for _ in 0..n_tokens {
            let is_match = dec.decode_update(&mut m.is_match[prev_was_match]);
            if is_match == 0 {
                let ctx = (prev_byte >> (8 - LC)) as usize;
                let b = crate::entropy::binary::decode_byte_tree(&mut dec, &mut m.literal[ctx]);
                out.push(b);
                prev_byte = b;
                prev_was_match = 0;
            } else {
                let lv0 = tree_decode(&mut dec, &mut m.len_low, 8);
                let lv = if lv0 == 255 {
                    let _ = dec.decode_update(&mut m.len_is_high);
                    255 + tree_decode(&mut dec, &mut m.len_high, 16)
                } else {
                    lv0
                };
                let len = (lv + MIN_MATCH as u32) as usize;
                let slot = tree_decode(&mut dec, &mut m.dist_slot, 6);
                let (base, bits) = slot_base_bits(slot);
                let dist = if bits > 0 {
                    let mut rem = 0u32;
                    for _ in 0..bits {
                        rem = (rem << 1) | dec.decode(2048) as u32;
                    }
                    base + rem
                } else {
                    base
                } as usize;
                if dist == 0 || dist > out.len() {
                    anyhow::bail!("invalid lzma-lite distance {dist}");
                }
                let start = out.len() - dist;
                for i in 0..len {
                    let b = out[start + i];
                    out.push(b);
                }
                prev_was_match = 1;
                prev_byte = 0;
            }
        }
        if out.len() != orig_len {
            anyhow::bail!("lzma-lite length mismatch: {} vs {}", out.len(), orig_len);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::test_corpus;

    fn roundtrip(data: &[u8]) -> usize {
        let c = LzmaLite::new();
        let z = c.compress(data).unwrap();
        assert_eq!(c.decompress(&z).unwrap(), data);
        z.len()
    }

    #[test]
    fn slot_coding_bijective() {
        for d in 1..300_000u32 {
            let slot = dist_slot(d);
            let (base, bits) = slot_base_bits(slot);
            assert!(d >= base && d < base + (1 << bits).max(1), "d={d} slot={slot}");
            assert!((slot as usize) < DIST_SLOTS);
        }
    }

    #[test]
    fn empty_and_tiny() {
        roundtrip(b"");
        roundtrip(b"q");
        roundtrip(b"qq");
        roundtrip(b"hello hello hello hello");
    }

    #[test]
    fn textish_beats_gzip_like() {
        use crate::baselines::gzip_like::GzipLike;
        let data = test_corpus::textish(100_000, 1);
        let z = roundtrip(&data);
        let g = GzipLike::new().compress(&data).unwrap().len();
        assert!(z < g, "lzma {z} should beat gzip {g}");
    }

    #[test]
    fn repetitive_input() {
        let data = test_corpus::repetitive(60_000);
        let z = roundtrip(&data);
        assert!((data.len() as f64 / z as f64) > 50.0);
    }

    #[test]
    fn random_input_small_overhead() {
        let data = test_corpus::random(30_000, 2);
        let z = roundtrip(&data);
        assert!(z < data.len() + data.len() / 15 + 64, "z={z}");
    }

    #[test]
    fn long_match_path() {
        // Force a match longer than 259 (= MIN_MATCH + 255) to hit len_high.
        let mut data = test_corpus::random(2_000, 3);
        let copy = data.clone();
        data.extend_from_slice(&copy);
        roundtrip(&data);
    }

    #[test]
    fn corrupt_stream_rejected() {
        let c = LzmaLite::new();
        assert!(c.decompress(&[0u8; 3]).is_err());
    }
}
