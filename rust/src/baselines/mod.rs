//! The nine baseline compressors of the paper's evaluation (Table 5).
//!
//! | Paper baseline | Implementation here | Family |
//! |---|---|---|
//! | Huffman      | [`entropy_coders::HuffmanOrder0`]  | entropy |
//! | Arithmetic   | [`entropy_coders::ArithmeticOrder0`] | entropy |
//! | FSE          | [`entropy_coders::FseOrder0`]      | entropy |
//! | Gzip         | [`gzip_like::GzipLike`] (LZ77 + canonical Huffman) | dictionary |
//! | LZMA         | [`lzma_lite::LzmaLite`] (LZ77 + context-modelled range coder) | dictionary |
//! | Zstd-22      | [`zstd_lite::ZstdLite`] (LZ77 + FSE) | dictionary |
//! | NNCP         | [`cm::ContextMixing`] (`nncp-sim`: 5-model logistic mixing) | neural-sim |
//! | TRACE        | [`cm::ContextMixing`] (`trace-sim`: slim 3-model variant) | neural-sim |
//! | PAC          | [`ppm::Ppm`] (`pac-sim`: order-3 PPM, escape method C) | neural-sim |
//!
//! The NN-based compressors of the paper (NNCP = online transformer,
//! TRACE = slim transformer, PAC = MLP order model) cannot be reproduced
//! verbatim without their GPU training loops; per DESIGN.md §2 they are
//! substituted with adaptive statistical coders from the same
//! "learned, adaptive, stronger-than-LZ" class, which land in the same
//! compression band (5–12× on our corpora) and therefore preserve the
//! paper's comparison shape.

pub mod cm;
pub mod entropy_coders;
pub mod gzip_like;
pub mod lz77;
pub mod lzma_lite;
pub mod ppm;
pub mod zstd_lite;

pub use cm::ContextMixing;
pub use entropy_coders::{ArithmeticOrder0, FseOrder0, HuffmanOrder0};
pub use gzip_like::GzipLike;
pub use lzma_lite::LzmaLite;
pub use ppm::Ppm;
pub use zstd_lite::ZstdLite;

#[cfg(test)]
pub(crate) mod test_corpus {
    use crate::util::Pcg64;

    /// English-ish text with word repetition — exercises literals + matches.
    pub fn textish(n: usize, seed: u64) -> Vec<u8> {
        let words = [
            "the", "compression", "of", "language", "model", "generated", "text", "is", "a",
            "systems", "problem", "entropy", "token", "prediction", "arithmetic", "coding",
        ];
        let mut rng = Pcg64::seeded(seed);
        let mut out = Vec::with_capacity(n + 16);
        while out.len() < n {
            out.extend_from_slice(rng.choose(&words).as_bytes());
            out.push(if rng.gen_bool(0.1) { b'.' } else { b' ' });
        }
        out.truncate(n);
        out
    }

    /// Highly repetitive input — exercises long matches.
    pub fn repetitive(n: usize) -> Vec<u8> {
        b"abcabcabcd".iter().copied().cycle().take(n).collect()
    }

    /// Incompressible input.
    pub fn random(n: usize, seed: u64) -> Vec<u8> {
        let mut rng = Pcg64::seeded(seed);
        let mut v = vec![0u8; n];
        rng.fill_bytes(&mut v);
        v
    }
}
