//! PPM (Prediction by Partial Matching), escape method C, with exclusions.
//!
//! Stands in for the paper's **PAC** baseline (an MLP "order model" + entropy
//! coder): PPM is the classical adaptive order-k context model and lands in
//! the same compression band on text. Contexts of order `k..=0` are tried in
//! turn; a miss is coded as an *escape* whose frequency equals the number of
//! distinct symbols seen in the context (method C), with already-tried
//! symbols excluded from lower-order totals. A final order(-1) level codes
//! over the 256-symbol uniform alphabet.

use crate::compress::Compressor;
use crate::entropy::range::{RangeDecoder, RangeEncoder};
use crate::Result;
use std::collections::HashMap;

/// Per-context statistics: sparse symbol counts.
#[derive(Default, Clone)]
struct Ctx {
    /// (symbol, count), insertion-ordered; linear scans are fine because
    /// text contexts rarely hold more than a few dozen symbols.
    syms: Vec<(u8, u32)>,
    total: u32,
}

const MAX_CTX_TOTAL: u32 = 1 << 14;
const INC: u32 = 4;

impl Ctx {
    #[inline]
    fn find(&self, sym: u8) -> Option<usize> {
        self.syms.iter().position(|&(s, _)| s == sym)
    }

    fn add(&mut self, sym: u8) {
        match self.find(sym) {
            Some(i) => {
                self.syms[i].1 += INC;
                self.total += INC;
            }
            None => {
                self.syms.push((sym, INC));
                self.total += INC;
            }
        }
        if self.total >= MAX_CTX_TOTAL {
            self.rescale();
        }
    }

    fn rescale(&mut self) {
        self.total = 0;
        self.syms.retain_mut(|(_, c)| {
            *c >>= 1;
            *c > 0
        });
        for &(_, c) in &self.syms {
            self.total += c;
        }
    }

    /// Escape frequency (method C): number of distinct symbols.
    #[inline]
    fn escape(&self) -> u32 {
        self.syms.len() as u32
    }
}

/// The shared model state; encode and decode walk it identically.
struct PpmModel {
    order: usize,
    /// Context tables per order: key = last-k bytes packed into u64.
    tables: Vec<HashMap<u64, Ctx>>,
}

impl PpmModel {
    fn new(order: usize) -> Self {
        assert!(order <= 8);
        PpmModel { order, tables: (0..=order).map(|_| HashMap::new()).collect() }
    }

    #[inline]
    fn key(history: &[u8], k: usize) -> u64 {
        let mut key = 0u64;
        for &b in &history[history.len() - k..] {
            key = (key << 8) | b as u64;
        }
        // Tag with the order so order-0's single context is distinct.
        key | ((k as u64) << 56)
    }

    fn update(&mut self, history: &[u8], sym: u8) {
        for k in 0..=self.order.min(history.len()) {
            let key = Self::key(history, k);
            self.tables[k].entry(key).or_default().add(sym);
        }
    }
}

/// Encode one symbol; returns after coding (possibly several escapes).
fn encode_symbol(model: &PpmModel, enc: &mut RangeEncoder, history: &[u8], sym: u8) {
    let mut excluded = [false; 256];
    let top = model.order.min(history.len());
    for k in (0..=top).rev() {
        let key = PpmModel::key(history, k);
        let Some(ctx) = model.tables[k].get(&key) else { continue };
        if ctx.syms.is_empty() {
            continue;
        }
        // Build the effective table under exclusions.
        let mut total = 0u32;
        let mut cum_sym = None;
        let mut freq_sym = 0u32;
        let mut any = false;
        for &(s, c) in &ctx.syms {
            if excluded[s as usize] {
                continue;
            }
            any = true;
            if s == sym {
                cum_sym = Some(total);
                freq_sym = c;
            }
            total += c;
        }
        if !any {
            continue; // everything excluded; this level carries no information
        }
        let esc = ctx.escape();
        let grand = total + esc;
        match cum_sym {
            Some(cum) => {
                enc.encode(cum, freq_sym, grand);
                return;
            }
            None => {
                // escape occupies [total, total+esc)
                enc.encode(total, esc, grand);
                for &(s, _) in &ctx.syms {
                    excluded[s as usize] = true;
                }
            }
        }
    }
    // order(-1): uniform over non-excluded bytes.
    let mut cum = 0u32;
    let mut total = 0u32;
    let mut cum_sym = 0u32;
    for b in 0..256usize {
        if excluded[b] {
            continue;
        }
        if b == sym as usize {
            cum_sym = cum;
        }
        cum += 1;
        total += 1;
    }
    enc.encode(cum_sym, 1, total);
}

/// Mirror of [`encode_symbol`].
fn decode_symbol(model: &PpmModel, dec: &mut RangeDecoder, history: &[u8]) -> u8 {
    let mut excluded = [false; 256];
    let top = model.order.min(history.len());
    for k in (0..=top).rev() {
        let key = PpmModel::key(history, k);
        let Some(ctx) = model.tables[k].get(&key) else { continue };
        if ctx.syms.is_empty() {
            continue;
        }
        let mut total = 0u32;
        let mut any = false;
        for &(s, c) in &ctx.syms {
            if excluded[s as usize] {
                continue;
            }
            any = true;
            total += c;
        }
        if !any {
            continue;
        }
        let esc = ctx.escape();
        let grand = total + esc;
        let target = dec.decode_freq(grand);
        if target >= total {
            dec.decode_update(total, esc);
            for &(s, _) in &ctx.syms {
                excluded[s as usize] = true;
            }
            continue;
        }
        let mut cum = 0u32;
        for &(s, c) in &ctx.syms {
            if excluded[s as usize] {
                continue;
            }
            if target < cum + c {
                dec.decode_update(cum, c);
                return s;
            }
            cum += c;
        }
        unreachable!("target {target} below total {total} but no symbol matched");
    }
    let total = (0..256).filter(|&b| !excluded[b]).count() as u32;
    let target = dec.decode_freq(total);
    let mut cum = 0u32;
    for b in 0..256usize {
        if excluded[b] {
            continue;
        }
        if target == cum {
            dec.decode_update(cum, 1);
            return b as u8;
        }
        cum += 1;
    }
    unreachable!("uniform level must always decode")
}

/// PPM compressor (the `pac-sim` baseline).
pub struct Ppm {
    order: usize,
    name: String,
}

impl Ppm {
    pub fn new(order: usize) -> Self {
        Ppm { order, name: "pac".to_string() }
    }

    pub fn with_name(order: usize, name: &str) -> Self {
        Ppm { order, name: name.to_string() }
    }
}

impl Default for Ppm {
    fn default() -> Self {
        Self::new(3)
    }
}

impl Compressor for Ppm {
    fn name(&self) -> &str {
        &self.name
    }

    fn compress(&self, data: &[u8]) -> Result<Vec<u8>> {
        let mut model = PpmModel::new(self.order);
        let mut enc = RangeEncoder::new();
        for (i, &b) in data.iter().enumerate() {
            let history = &data[..i];
            encode_symbol(&model, &mut enc, history, b);
            model.update(history, b);
        }
        let mut out = Vec::with_capacity(data.len() / 3 + 16);
        out.extend_from_slice(&(data.len() as u64).to_le_bytes());
        out.extend_from_slice(&enc.finish());
        Ok(out)
    }

    fn decompress(&self, data: &[u8]) -> Result<Vec<u8>> {
        if data.len() < 8 {
            anyhow::bail!("truncated ppm stream");
        }
        let n = crate::util::read_u64_le(data, 0) as usize;
        let mut model = PpmModel::new(self.order);
        let mut dec = RangeDecoder::new(&data[8..]);
        let mut out: Vec<u8> = Vec::with_capacity(n);
        for _ in 0..n {
            let sym = {
                let history = &out[..];
                decode_symbol(&model, &mut dec, history)
            };
            // `update` needs history without the new symbol: compute first.
            model.update(&out, sym);
            out.push(sym);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::test_corpus;

    fn roundtrip(data: &[u8], order: usize) -> usize {
        let c = Ppm::new(order);
        let z = c.compress(data).unwrap();
        assert_eq!(c.decompress(&z).unwrap(), data);
        z.len()
    }

    #[test]
    fn empty_and_tiny() {
        for order in [0, 1, 3] {
            roundtrip(b"", order);
            roundtrip(b"a", order);
            roundtrip(b"ab", order);
            roundtrip(b"aaaa", order);
        }
    }

    #[test]
    fn textish_all_orders() {
        let data = test_corpus::textish(20_000, 1);
        let mut sizes = Vec::new();
        for order in [0, 1, 2, 3] {
            sizes.push(roundtrip(&data, order));
        }
        // Higher order should monotonically help on wordy text.
        assert!(sizes[3] < sizes[1], "order3 {} vs order1 {}", sizes[3], sizes[1]);
        assert!(sizes[1] < sizes[0], "order1 {} vs order0 {}", sizes[1], sizes[0]);
    }

    #[test]
    fn beats_dictionary_methods_on_text() {
        use crate::baselines::gzip_like::GzipLike;
        let data = test_corpus::textish(50_000, 2);
        let p = roundtrip(&data, 3);
        let g = GzipLike::new().compress(&data).unwrap().len();
        assert!(p < g, "ppm {p} should beat gzip-like {g} on text");
    }

    #[test]
    fn repetitive_input() {
        let data = test_corpus::repetitive(20_000);
        let z = roundtrip(&data, 3);
        assert!((data.len() as f64 / z as f64) > 20.0);
    }

    #[test]
    fn random_input_bounded_overhead() {
        let data = test_corpus::random(20_000, 3);
        let z = roundtrip(&data, 3);
        // PPM pays escape costs on incompressible data; stay within ~30%.
        assert!(z < data.len() + data.len() * 3 / 10 + 64, "z={z}");
    }

    #[test]
    fn all_bytes_roundtrip() {
        let data: Vec<u8> = (0..=255u8).cycle().take(4096).collect();
        roundtrip(&data, 2);
    }

    #[test]
    fn rescale_path() {
        // Enough repetition of a small alphabet to trigger context rescaling.
        let data: Vec<u8> = b"ab".iter().copied().cycle().take(40_000).collect();
        roundtrip(&data, 1);
    }

    #[test]
    fn truncated_stream_rejected() {
        let c = Ppm::default();
        assert!(c.decompress(&[1, 2]).is_err());
    }
}
