//! Zstd-shaped dictionary compressor: LZ77 sequences + FSE entropy stage.
//!
//! Mirrors Zstandard's block anatomy: the LZ parse is decomposed into
//! *sequences* `(literal_length, match_length, offset)`; the three slot
//! streams are FSE-coded with their own tables, extra bits go to a shared
//! raw bitstream, and the literal bytes are coded with an order-0 FSE table
//! (Zstd uses Huffman there; FSE keeps the entropy stage uniform and is what
//! the format's own `--ultra -22` levels lean on for sequences).

use crate::baselines::gzip_like::{slot_to_base, value_to_slot, NUM_SLOTS};
use crate::baselines::lz77::{self, Token, MIN_MATCH};
use crate::compress::Compressor;
use crate::entropy::fse::{
    decode_all, encode_all, normalize_freqs, pack_norm, unpack_norm, FseTable,
};
use crate::entropy::{BitReader, BitWriter};
use crate::Result;

const SEQ_TABLE_LOG: u32 = 9;
const LIT_TABLE_LOG: u32 = 11;

/// One LZ sequence: run of literals followed by one match (the trailing
/// sequence may have `match_len == 0`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct Sequence {
    lit_len: u32,
    match_len: u32, // 0 only for the trailing literal run
    offset: u32,    // undefined when match_len == 0
}

fn to_sequences(tokens: &[Token]) -> (Vec<Sequence>, Vec<u8>) {
    let mut seqs = Vec::new();
    let mut literals = Vec::new();
    let mut run = 0u32;
    for t in tokens {
        match *t {
            Token::Literal(b) => {
                literals.push(b);
                run += 1;
                // Keep literal runs inside the slot coder's value range.
                if run == 65_535 {
                    seqs.push(Sequence { lit_len: run, match_len: 0, offset: 0 });
                    run = 0;
                }
            }
            Token::Match { len, dist } => {
                seqs.push(Sequence { lit_len: run, match_len: len, offset: dist });
                run = 0;
            }
        }
    }
    if run > 0 {
        seqs.push(Sequence { lit_len: run, match_len: 0, offset: 0 });
    }
    (seqs, literals)
}

/// Write a `u32` length-prefixed section.
fn push_section(out: &mut Vec<u8>, payload: &[u8]) {
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(payload);
}

fn read_section<'a>(data: &'a [u8], pos: &mut usize) -> Result<&'a [u8]> {
    if *pos + 4 > data.len() {
        anyhow::bail!("truncated zstd-lite section header");
    }
    let len = crate::util::read_u32_le(data, *pos) as usize;
    *pos += 4;
    if *pos + len > data.len() {
        anyhow::bail!("truncated zstd-lite section body");
    }
    let s = &data[*pos..*pos + len];
    *pos += len;
    Ok(s)
}

/// FSE-encode a slice of small symbols with a fresh table; returns the
/// serialized section: `[n_syms u32][alphabet u16][table_log u8][state u32][norm][payload]`.
fn fse_section(symbols: &[usize], alphabet: usize, table_log: u32) -> Result<Vec<u8>> {
    let mut body = Vec::new();
    body.extend_from_slice(&(symbols.len() as u32).to_le_bytes());
    body.extend_from_slice(&(alphabet as u16).to_le_bytes());
    body.push(table_log as u8);
    if symbols.is_empty() {
        return Ok(body);
    }
    let mut counts = vec![0u64; alphabet];
    for &s in symbols {
        counts[s] += 1;
    }
    let norm = normalize_freqs(&counts, table_log)?;
    let table = FseTable::new(&norm, table_log)?;
    let (state, payload) = encode_all(&table, symbols);
    body.extend_from_slice(&state.to_le_bytes());
    body.extend_from_slice(&pack_norm(&norm));
    body.extend_from_slice(&payload);
    Ok(body)
}

fn fse_unsection(body: &[u8]) -> Result<Vec<usize>> {
    if body.len() < 7 {
        anyhow::bail!("truncated FSE section");
    }
    let n = crate::util::read_u32_le(body, 0) as usize;
    let alphabet = u16::from_le_bytes([body[4], body[5]]) as usize;
    let table_log = body[6] as u32;
    if n == 0 {
        return Ok(Vec::new());
    }
    if table_log > 15 || body.len() < 11 + alphabet * 2 {
        anyhow::bail!("corrupt FSE section header");
    }
    let state = crate::util::read_u32_le(body, 7);
    let norm = unpack_norm(&body[11..], alphabet, table_log)?;
    let table = FseTable::new(&norm, table_log)?;
    let payload = &body[11 + alphabet * 2..];
    decode_all(&table, state, payload, n)
}

pub struct ZstdLite;

impl ZstdLite {
    pub fn new() -> Self {
        ZstdLite
    }
}

impl Default for ZstdLite {
    fn default() -> Self {
        Self::new()
    }
}

impl Compressor for ZstdLite {
    fn name(&self) -> &str {
        "zstd"
    }

    fn compress(&self, data: &[u8]) -> Result<Vec<u8>> {
        let tokens = lz77::tokenize(data);
        let (seqs, literals) = to_sequences(&tokens);

        // Slot streams + extra bits.
        let mut ll_slots = Vec::with_capacity(seqs.len());
        let mut ml_slots = Vec::with_capacity(seqs.len());
        let mut of_slots = Vec::with_capacity(seqs.len());
        let mut extra = BitWriter::new();
        for s in &seqs {
            let (ls, lb, lv) = value_to_slot(s.lit_len);
            ll_slots.push(ls as usize);
            extra.write_bits(lv as u64, lb);
            // match_len == 0 marks the trailing literal run; shift by 1 so 0
            // stays representable alongside real lengths (>= MIN_MATCH).
            let ml = if s.match_len == 0 { 0 } else { s.match_len - MIN_MATCH as u32 + 1 };
            let (ms, mb, mv) = value_to_slot(ml);
            ml_slots.push(ms as usize);
            extra.write_bits(mv as u64, mb);
            if s.match_len > 0 {
                let (os, ob, ov) = value_to_slot(s.offset - 1);
                of_slots.push(os as usize);
                extra.write_bits(ov as u64, ob);
            }
        }

        let lit_syms: Vec<usize> = literals.iter().map(|&b| b as usize).collect();

        let mut out = Vec::new();
        out.extend_from_slice(&(data.len() as u64).to_le_bytes());
        push_section(&mut out, &fse_section(&ll_slots, NUM_SLOTS, SEQ_TABLE_LOG)?);
        push_section(&mut out, &fse_section(&ml_slots, NUM_SLOTS, SEQ_TABLE_LOG)?);
        push_section(&mut out, &fse_section(&of_slots, NUM_SLOTS, SEQ_TABLE_LOG)?);
        push_section(&mut out, &fse_section(&lit_syms, 256, LIT_TABLE_LOG)?);
        push_section(&mut out, &extra.finish());
        Ok(out)
    }

    fn decompress(&self, data: &[u8]) -> Result<Vec<u8>> {
        if data.len() < 8 {
            anyhow::bail!("truncated zstd-lite stream");
        }
        let orig_len = crate::util::read_u64_le(data, 0) as usize;
        let mut pos = 8usize;
        let ll_slots = fse_unsection(read_section(data, &mut pos)?)?;
        let ml_slots = fse_unsection(read_section(data, &mut pos)?)?;
        let of_slots = fse_unsection(read_section(data, &mut pos)?)?;
        let lit_syms = fse_unsection(read_section(data, &mut pos)?)?;
        let extra_bytes = read_section(data, &mut pos)?;
        let mut extra = BitReader::new(extra_bytes);

        if ll_slots.len() != ml_slots.len() {
            anyhow::bail!("sequence stream length mismatch");
        }
        let mut out: Vec<u8> = Vec::with_capacity(orig_len);
        let mut lit_pos = 0usize;
        let mut of_iter = of_slots.iter();
        for (&lls, &mls) in ll_slots.iter().zip(&ml_slots) {
            let (lbase, lbits) = slot_to_base(lls as u32);
            let lit_len = (lbase + extra.read_bits(lbits) as u32) as usize;
            let (mbase, mbits) = slot_to_base(mls as u32);
            let ml_raw = mbase + extra.read_bits(mbits) as u32;
            if lit_pos + lit_len > lit_syms.len() {
                anyhow::bail!("literal overrun");
            }
            for &s in &lit_syms[lit_pos..lit_pos + lit_len] {
                out.push(s as u8);
            }
            lit_pos += lit_len;
            if ml_raw > 0 {
                let match_len = (ml_raw - 1) as usize + MIN_MATCH;
                let ofs = *of_iter.next().ok_or_else(|| anyhow::anyhow!("offset underrun"))?;
                let (obase, obits) = slot_to_base(ofs as u32);
                let offset = (obase + extra.read_bits(obits) as u32) as usize + 1;
                if offset > out.len() {
                    anyhow::bail!("invalid offset {offset}");
                }
                let start = out.len() - offset;
                for i in 0..match_len {
                    let b = out[start + i];
                    out.push(b);
                }
            }
        }
        if out.len() != orig_len {
            anyhow::bail!("zstd-lite length mismatch: {} vs {}", out.len(), orig_len);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::test_corpus;

    fn roundtrip(data: &[u8]) -> usize {
        let c = ZstdLite::new();
        let z = c.compress(data).unwrap();
        assert_eq!(c.decompress(&z).unwrap(), data, "roundtrip failed for len {}", data.len());
        z.len()
    }

    #[test]
    fn empty_and_tiny() {
        roundtrip(b"");
        roundtrip(b"z");
        roundtrip(b"zz");
        roundtrip(b"abcabcabcabcabc");
    }

    #[test]
    fn textish_compresses_better_than_gzip_like() {
        use crate::baselines::gzip_like::GzipLike;
        let data = test_corpus::textish(100_000, 1);
        let z = roundtrip(&data);
        let g = GzipLike::new().compress(&data).unwrap().len();
        // FSE sequences + literal modelling should at least rival Huffman.
        assert!((z as f64) < (g as f64) * 1.10, "zstd {z} vs gzip {g}");
    }

    #[test]
    fn repetitive_input() {
        let data = test_corpus::repetitive(80_000);
        let z = roundtrip(&data);
        assert!((data.len() as f64 / z as f64) > 40.0);
    }

    #[test]
    fn random_input() {
        let data = test_corpus::random(40_000, 2);
        let z = roundtrip(&data);
        assert!(z < data.len() + data.len() / 20 + 600);
    }

    #[test]
    fn no_matches_all_literals() {
        // Short unique string under MIN_MATCH repetition.
        let data: Vec<u8> = (0..255u8).collect();
        roundtrip(&data);
    }

    #[test]
    fn trailing_literal_run() {
        let mut data = test_corpus::repetitive(1000);
        data.extend_from_slice(b"XYZQW"); // non-matching tail
        roundtrip(&data);
    }

    #[test]
    fn giant_literal_run_splits() {
        // A match-free stream longer than the 65535 literal-run cap: byte
        // stream of strictly increasing u32s has no repeated 4-grams.
        let data: Vec<u8> = (0..20_000u32).flat_map(|i| i.to_be_bytes()).collect();
        assert!(data.len() > 70_000);
        roundtrip(&data);
    }

    #[test]
    fn corrupt_sections_rejected() {
        let c = ZstdLite::new();
        assert!(c.decompress(&[0u8; 6]).is_err());
        let mut z = c.compress(&test_corpus::textish(5000, 3)).unwrap();
        z.truncate(20);
        assert!(c.decompress(&z).is_err());
    }
}
