//! Minimal `--flag value` argument parser (no CLI crates in this offline
//! environment). Flags are `--name value` pairs; `--name` alone is a boolean.

use llmzip::Result;
use std::collections::HashMap;

/// Parsed arguments: flag -> value ("" for bare booleans).
pub struct Args {
    flags: HashMap<String, String>,
}

impl Args {
    pub fn parse(args: &[String]) -> Result<Args> {
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            let Some(name) = a.strip_prefix("--") else {
                anyhow::bail!("unexpected positional argument '{a}'");
            };
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), String::new());
                i += 1;
            }
        }
        Ok(Args { flags })
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn required(&self, name: &str) -> Result<&str> {
        self.get(name).ok_or_else(|| anyhow::anyhow!("missing required flag --{name}"))
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{name} expects an integer")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{name} expects an integer")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{name} expects a number")),
        }
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(parts: &[&str]) -> Vec<String> {
        parts.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_pairs_and_bools() {
        let a = Args::parse(&sv(&["--out", "dir", "--force", "--n", "42"])).unwrap();
        assert_eq!(a.get("out"), Some("dir"));
        assert!(a.has("force"));
        assert_eq!(a.usize_or("n", 0).unwrap(), 42);
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
    }

    #[test]
    fn rejects_positional() {
        assert!(Args::parse(&sv(&["oops"])).is_err());
    }

    #[test]
    fn required_flag_errors() {
        let a = Args::parse(&sv(&[])).unwrap();
        assert!(a.required("model").is_err());
    }
}
