//! `compress` / `decompress` / `ratio` — file-level LLM compression.
//!
//! Both directions run through the incremental `compress::stream` path
//! with bounded memory (the container bytes are identical to the one-shot
//! API), so `--in -` / `--out -` pipe through stdin/stdout and multi-GB
//! files never need to be resident.

use crate::cli::Args;
use llmzip::compress::{
    Codec, FileSource, LlmCompressor, LlmCompressorConfig, SeekableContainer,
};
use llmzip::lm::{ExecutorKind, KernelTier, Precision};
use llmzip::runtime::ArtifactStore;
use llmzip::Result;
use std::io::{BufReader, BufWriter, Read, Write};
use std::time::Instant;

pub(crate) fn executor_from_str(s: &str) -> Result<ExecutorKind> {
    Ok(match s {
        "pjrt" | "forward" | "pjrt-forward" => ExecutorKind::PjrtForward,
        "step" | "pjrt-step" => ExecutorKind::PjrtStep,
        "native" => ExecutorKind::Native,
        other => anyhow::bail!("unknown executor '{other}' (pjrt|step|native)"),
    })
}

/// Shared `--precision {f32,int8}` flag (the weight-bytes contract both
/// stream ends must agree on; int8 is native-engine only).
pub(crate) fn precision_arg(args: &Args) -> Result<Precision> {
    Precision::parse(&args.str_or("precision", "f32"))
}

/// Shared `--kernel {auto,scalar,avx2,neon}` flag: `auto` (default) defers
/// to load-time resolution (`LLMZIP_FORCE_KERNEL` override, else CPU
/// detection); anything else forces a tier and errors at open if this CPU
/// lacks it. Pure execution knob — container bytes never change.
pub(crate) fn kernel_arg(args: &Args) -> Result<Option<KernelTier>> {
    match args.str_or("kernel", "auto").as_str() {
        "auto" => Ok(None),
        s => KernelTier::parse(s).map(Some),
    }
}

/// Shared `--codec {range,fse}` flag: the entropy backend newly written
/// containers use. Decompression always follows the codec recorded in the
/// container header, so the flag only changes the encode side.
pub(crate) fn codec_arg(args: &Args) -> Result<Codec> {
    Codec::parse(&args.str_or("codec", "range"))
}

pub(crate) fn open_compressor(args: &Args) -> Result<LlmCompressor> {
    let store = ArtifactStore::open(args.get("artifacts"))?;
    let chunk = args.usize_or("chunk", 256)?;
    let cfg = LlmCompressorConfig {
        model: args.str_or("model", "medium"),
        chunk_tokens: chunk,
        stream_bytes: args.usize_or("stream", 4096.max(chunk))?,
        executor: executor_from_str(&args.str_or("executor", "pjrt"))?,
        lanes: args.usize_or("lanes", 8)?,
        threads: args.usize_or("threads", super::default_threads())?,
        precision: precision_arg(args)?,
        kernel: kernel_arg(args)?,
        // `--no-panels`: skip the interleaved-panel weight copies on
        // memory-constrained hosts (slower matmuls, identical bytes).
        panel_layout: !args.has("no-panels"),
        codec: codec_arg(args)?,
    };
    LlmCompressor::open(&store, cfg)
}

/// `--in` source: `-` is stdin, anything else a file path.
fn open_input(path: &str) -> Result<Box<dyn Read>> {
    Ok(if path == "-" {
        Box::new(std::io::stdin().lock())
    } else {
        Box::new(BufReader::new(std::fs::File::open(path)?))
    })
}

/// Status line: stdout normally, stderr when the payload itself goes to
/// stdout.
fn report(to_stdout: bool, line: String) {
    if to_stdout {
        eprintln!("{line}");
    } else {
        println!("{line}");
    }
}

/// All-or-nothing file output: the streaming paths write as they go, so a
/// mid-stream failure would otherwise leave a truncated container (and
/// `File::create` would have already destroyed any pre-existing file of
/// the same name). File targets therefore stream into `<out>.partial` and
/// rename over the destination only on success; failure removes the
/// partial and never touches an existing `<out>`. Stdout is the caller's
/// problem, as for any pipe tool.
fn run_to_output<T>(out_path: &str, work: impl FnOnce(Box<dyn Write>) -> Result<T>) -> Result<T> {
    if out_path == "-" {
        return work(Box::new(std::io::stdout().lock()));
    }
    let tmp = format!("{out_path}.partial");
    let file: Box<dyn Write> = Box::new(BufWriter::new(std::fs::File::create(&tmp)?));
    match work(file) {
        Ok(v) => {
            std::fs::rename(&tmp, out_path)?;
            Ok(v)
        }
        Err(e) => {
            let _ = std::fs::remove_file(&tmp);
            Err(e)
        }
    }
}

fn compress_stream(
    comp: &LlmCompressor,
    input: &mut dyn Read,
    output: Box<dyn Write>,
) -> Result<llmzip::compress::StreamSummary> {
    let mut writer = comp.stream_compress(output)?;
    std::io::copy(input, &mut writer)?;
    let (mut output, summary) = writer.finish()?;
    output.flush()?;
    Ok(summary)
}

pub fn compress(args: &[String]) -> Result<()> {
    let args = Args::parse(args)?;
    let comp = open_compressor(&args)?;
    let mut input = open_input(args.required("in")?)?;
    let out_path = args.required("out")?.to_string();
    let t0 = Instant::now();
    let summary = run_to_output(&out_path, |out| compress_stream(&comp, &mut input, out))?;
    let dt = t0.elapsed();
    report(
        out_path == "-",
        format!(
            "{} -> {} bytes (ratio {:.2}x) in {:.2}s ({:.1} KiB/s, model={}, chunk={}, \
             executor={:?}, precision={}, codec={})",
            summary.bytes_in,
            summary.bytes_out,
            summary.bytes_in as f64 / summary.bytes_out as f64,
            dt.as_secs_f64(),
            summary.bytes_in as f64 / 1024.0 / dt.as_secs_f64(),
            comp.model_config().name,
            comp.chunk_tokens(),
            comp.executor_kind(),
            comp.precision().as_str(),
            comp.codec().as_str(),
        ),
    );
    Ok(())
}

fn decompress_stream(
    comp: &LlmCompressor,
    input: Box<dyn Read>,
    mut output: Box<dyn Write>,
) -> Result<u64> {
    let mut reader = comp.stream_decompress(input)?;
    let n = std::io::copy(&mut reader, &mut output)?;
    output.flush()?;
    debug_assert!(reader.verified(), "copy drains to EOF, which verifies");
    Ok(n)
}

/// `--range OFFSET:LEN` — which decoded bytes a partial decompress serves.
fn parse_range(s: &str) -> Result<(u64, u64)> {
    let Some((off, len)) = s.split_once(':') else {
        anyhow::bail!("--range expects OFFSET:LEN (decoded-byte offset and length)");
    };
    let off = off.parse().map_err(|_| anyhow::anyhow!("--range offset must be an integer"))?;
    let len = len.parse().map_err(|_| anyhow::anyhow!("--range length must be an integer"))?;
    Ok((off, len))
}

/// Ranged decode: positioned reads on file inputs (only the header, the
/// trailer index and the frames overlapping the range are fetched), a
/// slurp + the same chunk selection on stdin (pipes cannot seek). Returns
/// `(decoded bytes, frames fetched / total, container bytes read)` — the
/// counters are None for stdin.
fn decompress_range_input(
    comp: &LlmCompressor,
    in_path: &str,
    offset: u64,
    len: u64,
) -> Result<(Vec<u8>, Option<(u64, usize, u64)>)> {
    if in_path == "-" {
        let mut all = Vec::new();
        std::io::stdin().lock().read_to_end(&mut all)?;
        return Ok((comp.decompress_range(&all, offset, len)?, None));
    }
    let file = FileSource::open(std::path::Path::new(in_path))?;
    let cont = SeekableContainer::open(&file)?;
    let bytes = comp.decompress_range_from(&cont, offset, len)?;
    Ok((bytes, Some((cont.frames_read(), cont.n_chunks(), cont.bytes_read()))))
}

pub fn decompress(args: &[String]) -> Result<()> {
    let args = Args::parse(args)?;
    let comp = open_compressor(&args)?;
    let in_path = args.required("in")?.to_string();
    let out_path = args.required("out")?.to_string();
    if let Some(range) = args.get("range") {
        let (offset, len) = parse_range(range)?;
        let t0 = Instant::now();
        let (bytes, touched) = decompress_range_input(&comp, &in_path, offset, len)?;
        run_to_output(&out_path, |mut out| {
            out.write_all(&bytes)?;
            out.flush()?;
            Ok(())
        })?;
        let extent = match touched {
            Some((frames, total, read)) => {
                format!(", {frames}/{total} frames, {read} container bytes read")
            }
            None => String::new(),
        };
        report(
            out_path == "-",
            format!(
                "{} bytes decoded from range [{offset}, {}) in {:.2}s (partial decode — \
                 whole-stream CRC not checked{extent})",
                bytes.len(),
                offset + len,
                t0.elapsed().as_secs_f64(),
            ),
        );
        return Ok(());
    }
    let input = open_input(&in_path)?;
    let t0 = Instant::now();
    let n = run_to_output(&out_path, |out| decompress_stream(&comp, input, out))?;
    let dt = t0.elapsed();
    report(
        out_path == "-",
        format!(
            "{} bytes decoded (verified CRC) in {:.2}s ({:.1} KiB/s)",
            n,
            dt.as_secs_f64(),
            n as f64 / 1024.0 / dt.as_secs_f64(),
        ),
    );
    Ok(())
}

pub fn ratio(args: &[String]) -> Result<()> {
    let args = Args::parse(args)?;
    let comp = open_compressor(&args)?;
    // Stream through the compressor into a counting sink: the ratio needs
    // only the two byte totals, so the input is never resident (and `-`
    // reads stdin, like the other subcommands).
    let mut input = open_input(args.required("in")?)?;
    let mut writer = comp.stream_compress(std::io::sink())?;
    std::io::copy(&mut input, &mut writer)?;
    let (_, summary) = writer.finish()?;
    println!("{:.3}", summary.bytes_in as f64 / summary.bytes_out.max(1) as f64);
    Ok(())
}
