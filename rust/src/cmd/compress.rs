//! `compress` / `decompress` / `ratio` — file-level LLM compression.

use crate::cli::Args;
use llmzip::compress::{Compressor, LlmCompressor, LlmCompressorConfig};
use llmzip::lm::{ExecutorKind, Precision};
use llmzip::runtime::ArtifactStore;
use llmzip::Result;
use std::time::Instant;

pub(crate) fn executor_from_str(s: &str) -> Result<ExecutorKind> {
    Ok(match s {
        "pjrt" | "forward" | "pjrt-forward" => ExecutorKind::PjrtForward,
        "step" | "pjrt-step" => ExecutorKind::PjrtStep,
        "native" => ExecutorKind::Native,
        other => anyhow::bail!("unknown executor '{other}' (pjrt|step|native)"),
    })
}

/// Shared `--precision {f32,int8}` flag (the weight-bytes contract both
/// stream ends must agree on; int8 is native-engine only).
pub(crate) fn precision_arg(args: &Args) -> Result<Precision> {
    Precision::parse(&args.str_or("precision", "f32"))
}

pub(crate) fn open_compressor(args: &Args) -> Result<LlmCompressor> {
    let store = ArtifactStore::open(args.get("artifacts"))?;
    let chunk = args.usize_or("chunk", 256)?;
    let cfg = LlmCompressorConfig {
        model: args.str_or("model", "medium"),
        chunk_tokens: chunk,
        stream_bytes: args.usize_or("stream", 4096.max(chunk))?,
        executor: executor_from_str(&args.str_or("executor", "pjrt"))?,
        lanes: args.usize_or("lanes", 8)?,
        threads: args.usize_or("threads", super::default_threads())?,
        precision: precision_arg(args)?,
    };
    LlmCompressor::open(&store, cfg)
}

pub fn compress(args: &[String]) -> Result<()> {
    let args = Args::parse(args)?;
    let input = std::fs::read(args.required("in")?)?;
    let comp = open_compressor(&args)?;
    let t0 = Instant::now();
    let z = comp.compress(&input)?;
    let dt = t0.elapsed();
    std::fs::write(args.required("out")?, &z)?;
    println!(
        "{} -> {} bytes (ratio {:.2}x) in {:.2}s ({:.1} KiB/s, model={}, chunk={}, \
         executor={:?}, precision={})",
        input.len(),
        z.len(),
        input.len() as f64 / z.len() as f64,
        dt.as_secs_f64(),
        input.len() as f64 / 1024.0 / dt.as_secs_f64(),
        comp.model_config().name,
        comp.chunk_tokens(),
        comp.executor_kind(),
        comp.precision().as_str(),
    );
    Ok(())
}

pub fn decompress(args: &[String]) -> Result<()> {
    let args = Args::parse(args)?;
    let input = std::fs::read(args.required("in")?)?;
    let comp = open_compressor(&args)?;
    let t0 = Instant::now();
    let data = comp.decompress(&input)?;
    let dt = t0.elapsed();
    std::fs::write(args.required("out")?, &data)?;
    println!(
        "{} -> {} bytes (verified CRC) in {:.2}s ({:.1} KiB/s)",
        input.len(),
        data.len(),
        dt.as_secs_f64(),
        data.len() as f64 / 1024.0 / dt.as_secs_f64(),
    );
    Ok(())
}

pub fn ratio(args: &[String]) -> Result<()> {
    let args = Args::parse(args)?;
    let input = std::fs::read(args.required("in")?)?;
    let comp = open_compressor(&args)?;
    let z = comp.compress(&input)?;
    println!("{:.3}", input.len() as f64 / z.len() as f64);
    Ok(())
}
