//! `gen-corpus` / `gen-data` — build the training corpora (procedural
//! "human" text) and the LLM-generated evaluation datasets.

use crate::cli::Args;
use llmzip::textgen::{self, Domain};
use llmzip::Result;
use std::fs;
use std::path::Path;

/// Write the procedural corpora used to train the LMs:
/// one file per domain plus a QA corpus for instruction tuning.
pub fn gen_corpus(args: &[String]) -> Result<()> {
    let args = Args::parse(args)?;
    let out = args.str_or("out", "corpus");
    let bytes = args.usize_or("bytes", 1 << 20)?;
    let seed = args.u64_or("seed", 1)?;
    fs::create_dir_all(&out)?;
    for d in Domain::EVAL {
        let data = textgen::generate(d, bytes, seed);
        let path = Path::new(&out).join(format!("{}.txt", d.name()));
        fs::write(&path, &data)?;
        println!("wrote {} ({} bytes)", path.display(), data.len());
    }
    // TPC-H comments (Table 2) and QA corpus (instruction tuning).
    let tpch = textgen::generate(Domain::Tpch, bytes / 4, seed);
    fs::write(Path::new(&out).join("tpch.txt"), &tpch)?;
    let qa = textgen::generate_qa(bytes, seed + 7);
    fs::write(Path::new(&out).join("qa.txt"), &qa)?;
    // Human-register movie reviews (Fig 9).
    let mut rng = llmzip::util::Pcg64::new(seed, 77);
    let mut imdb = Vec::new();
    while imdb.len() < bytes / 2 {
        imdb.extend_from_slice(textgen::web::imdb_style(&mut rng).as_bytes());
        imdb.push(b'\n');
    }
    fs::write(Path::new(&out).join("imdb.txt"), &imdb)?;
    println!("corpus complete in {out}/");
    Ok(())
}

/// Sample the LLM-generated datasets from a trained model (requires
/// artifacts; see `llmzip::sampling`).
pub fn gen_data(args: &[String]) -> Result<()> {
    let args = Args::parse(args)?;
    let out = args.str_or("out", "data");
    let bytes = args.usize_or("bytes", 256 * 1024)?;
    let model = args.str_or("model", "medium");
    fs::create_dir_all(&out)?;
    let store = llmzip::runtime::ArtifactStore::open(args.get("artifacts"))?;
    for d in Domain::EVAL {
        let data = llmzip::experiments::llm_dataset(&store, &out, &model, d, bytes)?;
        println!("dataset {}_{} ready ({} bytes)", model, d.name(), data.len());
    }
    Ok(())
}
