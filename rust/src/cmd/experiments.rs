//! Experiment subcommands — thin wrappers over `llmzip::experiments`.

use crate::cli::Args;
use llmzip::analysis::{self, EntropyReport};
use llmzip::experiments::{self, DatasetCache};
use llmzip::runtime::ArtifactStore;
use llmzip::textgen::Domain;
use llmzip::Result;

fn cache_from(args: &Args, default_bytes: usize) -> Result<DatasetCache> {
    let store = ArtifactStore::open(args.get("artifacts"))?;
    let bytes = args.usize_or("bytes", default_bytes)?;
    Ok(DatasetCache::new(store, &args.str_or("data", "data"), bytes))
}

pub fn analyze(args: &[String]) -> Result<()> {
    let args = Args::parse(args)?;
    let data = std::fs::read(args.required("in")?)?;
    let text = String::from_utf8_lossy(&data).into_owned();
    let r = EntropyReport::measure(&text);
    println!("bytes            {}", data.len());
    println!("char entropy     {:.3} bits/byte", r.char_e);
    println!("bpe entropy      {:.3} bits/byte", r.bpe_e);
    println!("word entropy     {:.3} bits/byte", r.word_e);
    println!("mutual info      {:.3} bits", r.mutual_info);
    let shares = analysis::top_k_share(&text, 10);
    for (i, sh) in shares.iter().enumerate() {
        println!("top-10 {}-gram    {:.2}%", i + 1, sh * 100.0);
    }
    Ok(())
}

macro_rules! experiment {
    ($fn_name:ident, $bytes:expr, $title:expr, $body:expr) => {
        pub fn $fn_name(args: &[String]) -> Result<()> {
            let args = Args::parse(args)?;
            let mut cache = cache_from(&args, $bytes)?;
            let model = args.str_or("model", "medium");
            let chunk = args.usize_or("chunk", 256)?;
            let _ = (&model, chunk);
            #[allow(clippy::redundant_closure_call)]
            let (header, rows) = ($body)(&mut cache, &model, chunk)?;
            experiments::print_table($title, &header, &rows);
            Ok(())
        }
    };
}

experiment!(table2, 64 * 1024, "Table 2: entropy & mutual information",
    |c: &mut DatasetCache, m: &str, _k: usize| experiments::table2(c, m));
experiment!(table3, 64 * 1024, "Table 3: traditional & neural compressors",
    |c: &mut DatasetCache, m: &str, _k: usize| experiments::table3(c, m));
experiment!(table5, 64 * 1024, "Table 5: compression ratios, all methods x all datasets",
    |c: &mut DatasetCache, m: &str, k: usize| experiments::table5(c, m, k));
experiment!(fig2, 64 * 1024, "Fig 2: top-10 n-gram coverage",
    |c: &mut DatasetCache, m: &str, _k: usize| experiments::fig2(c, m));
experiment!(fig5, 32 * 1024, "Fig 5: base vs instruction-tuned across sizes",
    |c: &mut DatasetCache, _m: &str, k: usize| experiments::fig5(c, k));
experiment!(fig6, 32 * 1024, "Fig 6: model scale vs ratio",
    |c: &mut DatasetCache, _m: &str, k: usize| experiments::fig6(c, k));
experiment!(fig7, 64 * 1024, "Fig 7: dataset scale vs ratio",
    |c: &mut DatasetCache, m: &str, k: usize| experiments::fig7(c, m, k));
experiment!(fig8, 32 * 1024, "Fig 8: domain-specialist models",
    |c: &mut DatasetCache, _m: &str, k: usize| experiments::fig8(c, k));
experiment!(fig9, 32 * 1024, "Fig 9: human vs LLM-generated, by chunk size",
    |c: &mut DatasetCache, m: &str, _k: usize| experiments::fig9(c, m));

pub fn chunk_sweep(args: &[String]) -> Result<()> {
    let args = Args::parse(args)?;
    let mut cache = cache_from(&args, 32 * 1024)?;
    let domain = Domain::from_name(&args.str_or("domain", "wiki"))?;
    let (header, rows) = experiments::chunk_sweep(&mut cache, domain)?;
    experiments::print_table("Chunk-size sweep (§5.4)", &header, &rows);
    Ok(())
}
