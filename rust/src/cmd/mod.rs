//! CLI subcommand implementations.

pub mod compress;
pub mod data;
pub mod experiments;
pub mod models;
pub mod serve;

/// Default native-engine worker threads: all the machine offers.
pub(crate) fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}
