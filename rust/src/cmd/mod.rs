//! CLI subcommand implementations.

pub mod compress;
pub mod data;
pub mod experiments;
pub mod models;
pub mod serve;
