//! `models` — list registered model variants and artifact availability,
//! plus `.lmz` weight-file tooling:
//!
//! * `models quantize` — convert an `.lmz` v1 (f32) file to the v2
//!   int8-quantized format on disk (deterministic: the output bytes, and
//!   therefore the fingerprint the serving stack records in containers,
//!   depend only on the input bytes).
//! * `models gen` — write a deterministic random-weight `.lmz` fixture
//!   (the same `Weights::random` family the test suite uses), so CI and
//!   offline environments can exercise the full compress/serve/quantize
//!   path without trained artifacts.

use crate::cli::Args;
use llmzip::lm::config::{by_name, MODELS};
use llmzip::lm::weights::Weights;
use llmzip::runtime::ArtifactStore;
use llmzip::util::human_bytes;
use llmzip::Result;

pub fn run(args: &[String]) -> Result<()> {
    match args.first().map(String::as_str) {
        Some("quantize") => quantize(&args[1..]),
        Some("gen") => gen(&args[1..]),
        _ => list(args),
    }
}

pub fn list(_args: &[String]) -> Result<()> {
    let store = ArtifactStore::open(None).ok();
    println!(
        "{:<18} {:>7} {:>7} {:>6} {:>9}  {:<10} {}",
        "NAME", "D_MODEL", "LAYERS", "HEADS", "PARAMS", "ARTIFACTS", "SIMULATES"
    );
    for m in &MODELS {
        let have = store.as_ref().map(|s| s.has_model(m.name)).unwrap_or(false);
        println!(
            "{:<18} {:>7} {:>7} {:>6} {:>8}K  {:<10} {}",
            m.name,
            m.d_model,
            m.n_layers,
            m.n_heads,
            m.param_count() / 1000,
            if have { "yes" } else { "missing" },
            m.simulates,
        );
    }
    Ok(())
}

/// `models quantize --model M --in f32.lmz --out q8.lmz`
fn quantize(args: &[String]) -> Result<()> {
    let args = Args::parse(args)?;
    let cfg = by_name(&args.str_or("model", "medium"))?;
    let input = std::path::Path::new(args.required("in")?);
    let weights = Weights::load(input, cfg)?;
    let quantized = weights.quantize();
    let bytes = quantized.to_bytes();
    std::fs::write(args.required("out")?, &bytes)?;
    println!(
        "{}: {} (f32) -> {} (int8), fingerprint {:08x}",
        cfg.name,
        human_bytes(weights.resident_bytes() as u64),
        human_bytes(quantized.resident_bytes() as u64),
        quantized.fingerprint(),
    );
    Ok(())
}

/// `models gen --model M --out weights.lmz [--seed N]`
fn gen(args: &[String]) -> Result<()> {
    let args = Args::parse(args)?;
    let cfg = by_name(&args.str_or("model", "nano"))?;
    let seed = args.u64_or("seed", 17)?;
    let weights = Weights::random(cfg, seed);
    std::fs::write(args.required("out")?, weights.to_bytes())?;
    println!(
        "{}: wrote {} of deterministic random weights (seed {seed})",
        cfg.name,
        human_bytes(weights.resident_bytes() as u64),
    );
    Ok(())
}
