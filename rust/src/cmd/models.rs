//! `models` — list registered model variants and artifact availability.

use llmzip::lm::config::MODELS;
use llmzip::runtime::ArtifactStore;
use llmzip::Result;

pub fn list(_args: &[String]) -> Result<()> {
    let store = ArtifactStore::open(None).ok();
    println!(
        "{:<18} {:>7} {:>7} {:>6} {:>9}  {:<10} {}",
        "NAME", "D_MODEL", "LAYERS", "HEADS", "PARAMS", "ARTIFACTS", "SIMULATES"
    );
    for m in &MODELS {
        let have = store.as_ref().map(|s| s.has_model(m.name)).unwrap_or(false);
        println!(
            "{:<18} {:>7} {:>7} {:>6} {:>8}K  {:<10} {}",
            m.name,
            m.d_model,
            m.n_layers,
            m.n_heads,
            m.param_count() / 1000,
            if have { "yes" } else { "missing" },
            m.simulates,
        );
    }
    Ok(())
}
