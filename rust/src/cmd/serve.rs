//! `serve` — the batched compression service over TCP.
//!
//! Wire protocol (little-endian):
//!   request:  op u8 (1=compress, 2=decompress) | len u32 | payload
//!   response: status u8 (0=ok, 1=error)        | len u32 | payload/message
//! Connections are persistent; each request blocks until its response.

use crate::cli::Args;
use llmzip::compress::{LlmCompressor, LlmCompressorConfig};
use llmzip::coordinator::{BatchPolicy, Server, ServerConfig};
use llmzip::lm::{ExecutorKind, Precision, StepPool};
use llmzip::Result;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

pub fn serve(args: &[String]) -> Result<()> {
    let args = Args::parse(args)?;
    let model = args.str_or("model", "medium");
    let chunk = args.usize_or("chunk", 256)?;
    let port = args.usize_or("port", 7878)?;
    let max_wait_ms = args.u64_or("max-wait-ms", 20)?;
    let executor = super::compress::executor_from_str(&args.str_or("executor", "pjrt"))?;
    let artifacts = args.get("artifacts").map(str::to_string);
    // Engine width/parallelism knobs (native engine; PJRT engines take the
    // batch their HLO was lowered with). Threads default to the machine.
    let lanes = args.usize_or("lanes", 8)?;
    let threads = args.usize_or("threads", super::default_threads())?;
    // Engine replicas: parallel engine workers in the coordinator. Native
    // replicas share one Arc<Weights> (loaded once, below); PJRT replicas
    // each open their own thread-affine handles.
    let replicas = args.usize_or("replicas", 1)?;
    // Elastic pool: --min-replicas/--max-replicas open an autoscale range
    // around --replicas (the initial size). Any actual range (or an
    // explicit --autoscale) turns the metrics-driven scaler on; native
    // engines only — PJRT pools stay static.
    let min_replicas = args.usize_or("min-replicas", replicas)?;
    let max_replicas = args.usize_or("max-replicas", replicas.max(min_replicas))?;
    let autoscale = min_replicas != max_replicas || args.has("autoscale");
    // Weight precision: with int8, the bundle is quantized ONCE here and
    // every replica shares the quantized Arc (half the resident weight
    // bytes, and one fingerprint for the whole pool).
    let precision = super::compress::precision_arg(&args)?;

    let comp_cfg = LlmCompressorConfig {
        model: model.clone(),
        chunk_tokens: chunk,
        stream_bytes: 4096.max(chunk),
        executor,
        lanes,
        threads,
        precision,
    };
    let factory: Box<dyn Fn() -> Result<LlmCompressor> + Send + Sync> =
        if executor == ExecutorKind::Native {
            // Load the weights ONCE; every replica clones the Arc.
            let model_cfg = llmzip::lm::config::by_name(&model)?;
            let store = llmzip::runtime::ArtifactStore::open(artifacts.as_deref())?;
            let weights = store.weights(model_cfg)?;
            let weights = match (precision, weights.precision()) {
                (Precision::Int8, Precision::F32) => weights.quantize(),
                (Precision::F32, Precision::Int8) => anyhow::bail!(
                    "weights for '{model}' are int8-quantized on disk; serve them with \
                     --precision int8"
                ),
                _ => weights,
            };
            let weights = Arc::new(weights);
            // Cross-replica work stealing: ONE StepPool sized to the whole
            // thread budget (what N private pools would have spawned), so
            // replicas — including autoscale-grown ones — fan their lane
            // spans into a shared injector and idle step threads help busy
            // siblings. Only engaged when more than one replica can exist
            // (stealing cannot help a lone replica — it would pay injector
            // contention for nothing; the private per-replica pool is the
            // right shape there). --no-steal restores private pools.
            let pool = if max_replicas > 1 && !args.has("no-steal") {
                Some(StepPool::new(threads.max(1) * max_replicas))
            } else {
                None
            };
            Box::new(move || {
                LlmCompressor::from_shared_pooled(
                    model_cfg,
                    weights.clone(),
                    comp_cfg.clone(),
                    pool.clone(),
                )
            })
        } else {
            if precision != Precision::F32 {
                anyhow::bail!("--precision int8 requires --executor native");
            }
            Box::new(move || {
                let store = llmzip::runtime::ArtifactStore::open(artifacts.as_deref())?;
                LlmCompressor::open(&store, comp_cfg.clone())
            })
        };
    let server = Server::start(
        factory,
        ServerConfig {
            chunk_tokens: chunk,
            lanes,
            threads,
            replicas,
            min_replicas,
            max_replicas,
            autoscale,
            policy: BatchPolicy {
                lanes,
                max_wait: Duration::from_millis(max_wait_ms),
            },
            ..Default::default()
        },
    )?;
    let server = Arc::new(server);

    let listener = TcpListener::bind(("127.0.0.1", port as u16))?;
    println!(
        "llmzip serving on 127.0.0.1:{port} \
         (chunk={chunk}, lanes={lanes}, threads={threads}, replicas={replicas}, \
         autoscale={}, precision={})",
        if autoscale { format!("{min_replicas}..{max_replicas}") } else { "off".into() },
        precision.as_str()
    );
    loop {
        let (stream, peer) = listener.accept()?;
        let srv = server.clone();
        std::thread::spawn(move || {
            if let Err(e) = handle_conn(stream, &srv) {
                eprintln!("connection {peer}: {e:#}");
            }
        });
    }
}

/// Serve one persistent connection.
pub fn handle_conn(mut stream: TcpStream, server: &Server) -> Result<()> {
    loop {
        let mut hdr = [0u8; 5];
        match stream.read_exact(&mut hdr) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(()),
            Err(e) => return Err(e.into()),
        }
        let op = hdr[0];
        let len = u32::from_le_bytes(hdr[1..5].try_into().unwrap()) as usize;
        if len > 256 << 20 {
            anyhow::bail!("request too large: {len}");
        }
        let mut payload = vec![0u8; len];
        stream.read_exact(&mut payload)?;
        let result = match op {
            1 => server.compress(&payload),
            2 => server.decompress(&payload),
            other => Err(anyhow::anyhow!("unknown op {other}")),
        };
        match result {
            Ok(data) => {
                stream.write_all(&[0u8])?;
                stream.write_all(&(data.len() as u32).to_le_bytes())?;
                stream.write_all(&data)?;
            }
            Err(e) => {
                let msg = format!("{e:#}");
                stream.write_all(&[1u8])?;
                stream.write_all(&(msg.len() as u32).to_le_bytes())?;
                stream.write_all(msg.as_bytes())?;
            }
        }
        stream.flush()?;
    }
}

/// Minimal client used by examples and tests.
pub struct Client {
    stream: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        Ok(Client { stream: TcpStream::connect(addr)? })
    }

    fn call(&mut self, op: u8, payload: &[u8]) -> Result<Vec<u8>> {
        self.stream.write_all(&[op])?;
        self.stream.write_all(&(payload.len() as u32).to_le_bytes())?;
        self.stream.write_all(payload)?;
        self.stream.flush()?;
        let mut hdr = [0u8; 5];
        self.stream.read_exact(&mut hdr)?;
        let len = u32::from_le_bytes(hdr[1..5].try_into().unwrap()) as usize;
        let mut data = vec![0u8; len];
        self.stream.read_exact(&mut data)?;
        if hdr[0] != 0 {
            anyhow::bail!("server error: {}", String::from_utf8_lossy(&data));
        }
        Ok(data)
    }

    pub fn compress(&mut self, data: &[u8]) -> Result<Vec<u8>> {
        self.call(1, data)
    }

    pub fn decompress(&mut self, data: &[u8]) -> Result<Vec<u8>> {
        self.call(2, data)
    }
}
