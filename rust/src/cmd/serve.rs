//! `serve` — the batched compression service over TCP.
//!
//! Two wire protocols share the port, auto-detected per connection by
//! [`llmzip::coordinator::wire::serve_connection`]:
//!
//! * **v1 (legacy, serial):** `op u8 (1=compress, 2=decompress) | len u32 |
//!   payload` → `status u8 | len u32 | payload/message`, one request at a
//!   time per connection.
//! * **v2 (multiplexed):** the client opens with `"LZMX"`, then framed
//!   `type u8 | req_id u32 | len u32 | payload` messages flow both ways —
//!   many concurrent requests (and chunked streaming uploads) interleave
//!   on one persistent connection, responses returning in completion
//!   order. See the `wire` module docs for the frame types.
//!
//! With autoscaling on and work stealing enabled, the shared
//! [`StepPool`]'s thread count FOLLOWS the live replica gauge (scale hook
//! → [`StepPool::resize`]) instead of being provisioned for
//! `max_replicas` up front.

use crate::cli::Args;
use llmzip::compress::{Codec, LlmCompressor, LlmCompressorConfig};
use llmzip::coordinator::{
    BatchPolicy, FleetConfig, FleetModelSpec, FleetServer, ScaleHook, Server, ServerConfig,
    TenantSpec, WireService,
};
use llmzip::lm::{ExecutorKind, Precision, StepPool};
use llmzip::Result;
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

pub fn serve(args: &[String]) -> Result<()> {
    let args = Args::parse(args)?;
    // --models switches to fleet mode: several model pools behind one
    // port, with routing, a shared replica budget and tenant QoS.
    if let Some(models) = args.get("models") {
        let models = models.to_string();
        return serve_fleet(&args, &models);
    }
    let model = args.str_or("model", "medium");
    let chunk = args.usize_or("chunk", 256)?;
    let port = args.usize_or("port", 7878)?;
    let max_wait_ms = args.u64_or("max-wait-ms", 20)?;
    let executor = super::compress::executor_from_str(&args.str_or("executor", "pjrt"))?;
    let artifacts = args.get("artifacts").map(str::to_string);
    // Engine width/parallelism knobs (native engine; PJRT engines take the
    // batch their HLO was lowered with). Threads default to the machine.
    let lanes = args.usize_or("lanes", 8)?;
    let threads = args.usize_or("threads", super::default_threads())?;
    // Engine replicas: parallel engine workers in the coordinator. Native
    // replicas share one Arc<Weights> (loaded once, below); PJRT replicas
    // each open their own thread-affine handles.
    let replicas = args.usize_or("replicas", 1)?;
    // Elastic pool: --min-replicas/--max-replicas open an autoscale range
    // around --replicas (the initial size). Any actual range (or an
    // explicit --autoscale) turns the metrics-driven scaler on; native
    // engines only — PJRT pools stay static.
    let min_replicas = args.usize_or("min-replicas", replicas)?;
    let max_replicas = args.usize_or("max-replicas", replicas.max(min_replicas))?;
    let autoscale = min_replicas != max_replicas || args.has("autoscale");
    if min_replicas > max_replicas {
        anyhow::bail!("--min-replicas {min_replicas} > --max-replicas {max_replicas}");
    }
    // Weight precision: with int8, the bundle is quantized ONCE here and
    // every replica shares the quantized Arc (half the resident weight
    // bytes, and one fingerprint for the whole pool).
    let precision = super::compress::precision_arg(&args)?;
    // Kernel dispatch: --kernel forces a tier (errors at startup if the
    // CPU lacks it); --no-panels skips the interleaved weight copies on
    // memory-constrained hosts. Both are pure execution knobs — the
    // containers a replica produces never depend on them.
    let kernel = super::compress::kernel_arg(&args)?;
    let panel_layout = !args.has("no-panels");
    // Entropy backend for containers this server WRITES; it decodes both
    // (decompression follows the container's recorded codec).
    let codec = super::compress::codec_arg(&args)?;
    // Buffer recycling for wire frames and job payloads. Pure execution
    // knob — the byte-identity suites pass either way.
    let pooling = !args.has("no-pool");

    let comp_cfg = LlmCompressorConfig {
        model: model.clone(),
        chunk_tokens: chunk,
        stream_bytes: 4096.max(chunk),
        executor,
        lanes,
        threads,
        precision,
        kernel,
        panel_layout,
        codec,
    };
    let mut on_scale: Option<ScaleHook> = None;
    let factory: Box<dyn Fn() -> Result<LlmCompressor> + Send + Sync> =
        if executor == ExecutorKind::Native {
            // Load the weights ONCE; every replica clones the Arc.
            let model_cfg = llmzip::lm::config::by_name(&model)?;
            let store = llmzip::runtime::ArtifactStore::open(artifacts.as_deref())?;
            let weights = store.weights(model_cfg)?;
            let weights = match (precision, weights.precision()) {
                (Precision::Int8, Precision::F32) => weights.quantize(),
                (Precision::F32, Precision::Int8) => anyhow::bail!(
                    "weights for '{model}' are int8-quantized on disk; serve them with \
                     --precision int8"
                ),
                _ => weights,
            };
            let weights = Arc::new(weights);
            // Cross-replica work stealing: ONE StepPool shared by every
            // native replica, so replicas — including autoscale-grown ones
            // — fan their lane spans into a shared injector and idle step
            // threads help busy siblings. The pool starts sized for the
            // INITIAL replica count and then FOLLOWS the live replica
            // gauge via the scale hook (no more paying max_replicas worth
            // of threads while the pool is scaled down; resizing cannot
            // change the bytes). Only engaged when more than one replica
            // can exist (stealing cannot help a lone replica — it would
            // pay injector contention for nothing). --no-steal restores
            // private per-replica pools.
            let pool = if max_replicas > 1 && !args.has("no-steal") {
                let threads_per_replica = threads.max(1);
                let initial = replicas.clamp(min_replicas.max(1), max_replicas);
                let pool = StepPool::new(threads_per_replica * initial);
                let hook_pool = pool.clone();
                on_scale = Some(Arc::new(move |live: usize| {
                    hook_pool.resize(threads_per_replica * live.max(1));
                }));
                Some(pool)
            } else {
                None
            };
            Box::new(move || {
                LlmCompressor::from_shared_pooled(
                    model_cfg,
                    weights.clone(),
                    comp_cfg.clone(),
                    pool.clone(),
                )
            })
        } else {
            if precision != Precision::F32 {
                anyhow::bail!("--precision int8 requires --executor native");
            }
            Box::new(move || {
                let store = llmzip::runtime::ArtifactStore::open(artifacts.as_deref())?;
                LlmCompressor::open(&store, comp_cfg.clone())
            })
        };
    let server = Server::start_with_hook(
        factory,
        ServerConfig {
            chunk_tokens: chunk,
            lanes,
            threads,
            replicas,
            min_replicas,
            max_replicas,
            autoscale,
            panel_layout,
            codec,
            pooling,
            policy: BatchPolicy {
                lanes,
                max_wait: Duration::from_millis(max_wait_ms),
            },
            ..Default::default()
        },
        on_scale,
    )?;
    let server = Arc::new(server);

    let listener = TcpListener::bind(("127.0.0.1", port as u16))?;
    println!(
        "llmzip serving on 127.0.0.1:{port} \
         (chunk={chunk}, lanes={lanes}, threads={threads}, replicas={replicas}, \
         autoscale={}, precision={}, kernel={}, panels={}, codec={}, pool={}, \
         protocols=v1+v2-mux)",
        if autoscale { format!("{min_replicas}..{max_replicas}") } else { "off".into() },
        precision.as_str(),
        kernel.map_or("auto", |t| t.as_str()),
        if panel_layout { "on" } else { "off" },
        codec.as_str(),
        if pooling { "on" } else { "off" },
    );
    loop {
        let (stream, peer) = listener.accept()?;
        let srv = server.clone();
        std::thread::spawn(move || {
            if let Err(e) = handle_conn(stream, &*srv) {
                eprintln!("connection {peer}: {e:#}");
            }
        });
    }
}

/// Serve one connection (either protocol, auto-detected) against either
/// a single-model [`Server`] or a [`FleetServer`].
pub fn handle_conn(stream: TcpStream, service: &dyn WireService) -> Result<()> {
    llmzip::coordinator::wire::serve_connection(stream, service)
}

/// Parse one `--models` entry: `name[:int8][:fse]` (modifier order
/// free; `f32`/`range` are accepted as explicit spellings of the
/// defaults). The entry string itself becomes the fleet route key.
fn parse_model_entry(entry: &str) -> Result<(String, Precision, Codec)> {
    let mut parts = entry.split(':');
    let name = parts.next().unwrap_or("");
    if name.is_empty() {
        anyhow::bail!("empty model entry in --models");
    }
    let (mut precision, mut codec) = (Precision::F32, Codec::Range);
    for token in parts {
        match token {
            "int8" => precision = Precision::Int8,
            "f32" => precision = Precision::F32,
            "fse" => codec = Codec::Fse,
            "range" => codec = Codec::Range,
            other => anyhow::bail!(
                "unknown modifier '{other}' in --models entry '{entry}' \
                 (expected int8, f32, fse or range)"
            ),
        }
    }
    Ok((name.to_string(), precision, codec))
}

/// Parse one `--tenants` entry: `name:weight[:rateKB]` — WFQ weight plus
/// an optional sustained rate limit in KiB/s of payload bytes.
fn parse_tenant_entry(entry: &str) -> Result<TenantSpec> {
    let parts: Vec<&str> = entry.split(':').collect();
    if parts.is_empty() || parts[0].is_empty() || parts.len() > 3 {
        anyhow::bail!("bad --tenants entry '{entry}' (expected name:weight[:rateKB])");
    }
    let weight = match parts.get(1) {
        Some(w) => w
            .parse::<u64>()
            .map_err(|_| anyhow::anyhow!("bad weight in --tenants entry '{entry}'"))?,
        None => 1,
    };
    let rate_kb = match parts.get(2) {
        Some(r) => r
            .parse::<u64>()
            .map_err(|_| anyhow::anyhow!("bad rateKB in --tenants entry '{entry}'"))?,
        None => 0,
    };
    Ok(TenantSpec {
        name: parts[0].to_string(),
        weight,
        rate_bytes_per_sec: (rate_kb * 1024) as f64,
        burst_bytes: 0.0,
    })
}

/// Fleet mode: `--models nano,nano:int8:fse` hosts one replica pool per
/// entry behind the same port. Single-model knobs (chunk, lanes, threads,
/// replica range, batching) apply to EVERY pool; the fleet adds
/// `--max-total-replicas` (global autoscale budget),
/// `--memory-budget-mb` (page cold pools out beyond it),
/// `--max-inflight` (load shed) and `--tenants` (QoS).
fn serve_fleet(args: &Args, models: &str) -> Result<()> {
    let chunk = args.usize_or("chunk", 256)?;
    let port = args.usize_or("port", 7878)?;
    let max_wait_ms = args.u64_or("max-wait-ms", 20)?;
    let artifacts = args.get("artifacts").map(str::to_string);
    let lanes = args.usize_or("lanes", 8)?;
    let threads = args.usize_or("threads", super::default_threads())?;
    let replicas = args.usize_or("replicas", 1)?;
    let min_replicas = args.usize_or("min-replicas", replicas)?;
    let max_replicas = args.usize_or("max-replicas", replicas.max(min_replicas))?;
    let autoscale = min_replicas != max_replicas || args.has("autoscale");
    if min_replicas > max_replicas {
        anyhow::bail!("--min-replicas {min_replicas} > --max-replicas {max_replicas}");
    }
    let kernel = super::compress::kernel_arg(args)?;
    let panel_layout = !args.has("no-panels");
    let pooling = !args.has("no-pool");
    // Fleet pools are native-engine replicas sharing one Arc<Weights>
    // per model; PJRT's thread-affine handles don't page in and out.
    match args.str_or("executor", "native").as_str() {
        "native" => {}
        other => anyhow::bail!("fleet mode is native-only (got --executor {other})"),
    }

    let max_total_replicas = args.usize_or("max-total-replicas", 0)?;
    let memory_budget_bytes = args.usize_or("memory-budget-mb", 0)? << 20;
    let max_inflight = args.usize_or("max-inflight", 0)?;
    let tenants = match args.get("tenants") {
        Some(list) => list
            .split(',')
            .filter(|s| !s.is_empty())
            .map(parse_tenant_entry)
            .collect::<Result<Vec<_>>>()?,
        None => Vec::new(),
    };

    let mut specs = Vec::new();
    for entry in models.split(',').filter(|s| !s.is_empty()) {
        let (model, precision, codec) = parse_model_entry(entry)?;
        let compressor = LlmCompressorConfig {
            model: model.clone(),
            chunk_tokens: chunk,
            stream_bytes: 4096.max(chunk),
            executor: ExecutorKind::Native,
            lanes,
            threads,
            precision,
            kernel,
            panel_layout,
            codec,
        };
        let server = ServerConfig {
            chunk_tokens: chunk,
            lanes,
            threads,
            replicas,
            min_replicas,
            max_replicas,
            autoscale,
            panel_layout,
            codec,
            pooling,
            policy: BatchPolicy { lanes, max_wait: Duration::from_millis(max_wait_ms) },
            ..Default::default()
        };
        // The loader re-opens the artifact store per call so a paged-out
        // pool re-materializes from disk — the fingerprint check in the
        // fleet refuses weights that changed while the pool was out.
        let model_name = model.clone();
        let loader_artifacts = artifacts.clone();
        let load: llmzip::coordinator::WeightsLoader = Arc::new(move || {
            let cfg = llmzip::lm::config::by_name(&model_name)?;
            let store = llmzip::runtime::ArtifactStore::open(loader_artifacts.as_deref())?;
            store.weights(cfg)
        });
        specs.push(FleetModelSpec { key: entry.to_string(), compressor, server, load });
    }

    let tenant_count = tenants.len();
    let fleet = Arc::new(FleetServer::start(
        specs,
        FleetConfig { max_total_replicas, memory_budget_bytes, max_inflight, tenants, pooling },
    )?);

    let listener = TcpListener::bind(("127.0.0.1", port as u16))?;
    println!(
        "llmzip fleet serving on 127.0.0.1:{port} \
         (models=[{}], chunk={chunk}, lanes={lanes}, replicas={replicas}, autoscale={}, \
         budget={}, mem={}MB, inflight={}, tenants={}, protocols=v1+v2-mux)",
        fleet.model_keys().join(", "),
        if autoscale { format!("{min_replicas}..{max_replicas}") } else { "off".into() },
        if max_total_replicas > 0 { max_total_replicas.to_string() } else { "off".into() },
        memory_budget_bytes >> 20,
        if max_inflight > 0 { max_inflight.to_string() } else { "off".into() },
        tenant_count,
    );
    loop {
        let (stream, peer) = listener.accept()?;
        let srv = fleet.clone();
        std::thread::spawn(move || {
            if let Err(e) = handle_conn(stream, &*srv) {
                eprintln!("connection {peer}: {e:#}");
            }
        });
    }
}
