//! `serve` — the batched compression service over TCP.
//!
//! Two wire protocols share the port, auto-detected per connection by
//! [`llmzip::coordinator::wire::serve_connection`]:
//!
//! * **v1 (legacy, serial):** `op u8 (1=compress, 2=decompress) | len u32 |
//!   payload` → `status u8 | len u32 | payload/message`, one request at a
//!   time per connection.
//! * **v2 (multiplexed):** the client opens with `"LZMX"`, then framed
//!   `type u8 | req_id u32 | len u32 | payload` messages flow both ways —
//!   many concurrent requests (and chunked streaming uploads) interleave
//!   on one persistent connection, responses returning in completion
//!   order. See the `wire` module docs for the frame types.
//!
//! With autoscaling on and work stealing enabled, the shared
//! [`StepPool`]'s thread count FOLLOWS the live replica gauge (scale hook
//! → [`StepPool::resize`]) instead of being provisioned for
//! `max_replicas` up front.

use crate::cli::Args;
use llmzip::compress::{LlmCompressor, LlmCompressorConfig};
use llmzip::coordinator::{BatchPolicy, ScaleHook, Server, ServerConfig};
use llmzip::lm::{ExecutorKind, Precision, StepPool};
use llmzip::Result;
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

pub fn serve(args: &[String]) -> Result<()> {
    let args = Args::parse(args)?;
    let model = args.str_or("model", "medium");
    let chunk = args.usize_or("chunk", 256)?;
    let port = args.usize_or("port", 7878)?;
    let max_wait_ms = args.u64_or("max-wait-ms", 20)?;
    let executor = super::compress::executor_from_str(&args.str_or("executor", "pjrt"))?;
    let artifacts = args.get("artifacts").map(str::to_string);
    // Engine width/parallelism knobs (native engine; PJRT engines take the
    // batch their HLO was lowered with). Threads default to the machine.
    let lanes = args.usize_or("lanes", 8)?;
    let threads = args.usize_or("threads", super::default_threads())?;
    // Engine replicas: parallel engine workers in the coordinator. Native
    // replicas share one Arc<Weights> (loaded once, below); PJRT replicas
    // each open their own thread-affine handles.
    let replicas = args.usize_or("replicas", 1)?;
    // Elastic pool: --min-replicas/--max-replicas open an autoscale range
    // around --replicas (the initial size). Any actual range (or an
    // explicit --autoscale) turns the metrics-driven scaler on; native
    // engines only — PJRT pools stay static.
    let min_replicas = args.usize_or("min-replicas", replicas)?;
    let max_replicas = args.usize_or("max-replicas", replicas.max(min_replicas))?;
    let autoscale = min_replicas != max_replicas || args.has("autoscale");
    if min_replicas > max_replicas {
        anyhow::bail!("--min-replicas {min_replicas} > --max-replicas {max_replicas}");
    }
    // Weight precision: with int8, the bundle is quantized ONCE here and
    // every replica shares the quantized Arc (half the resident weight
    // bytes, and one fingerprint for the whole pool).
    let precision = super::compress::precision_arg(&args)?;
    // Kernel dispatch: --kernel forces a tier (errors at startup if the
    // CPU lacks it); --no-panels skips the interleaved weight copies on
    // memory-constrained hosts. Both are pure execution knobs — the
    // containers a replica produces never depend on them.
    let kernel = super::compress::kernel_arg(&args)?;
    let panel_layout = !args.has("no-panels");
    // Entropy backend for containers this server WRITES; it decodes both
    // (decompression follows the container's recorded codec).
    let codec = super::compress::codec_arg(&args)?;
    // Buffer recycling for wire frames and job payloads. Pure execution
    // knob — the byte-identity suites pass either way.
    let pooling = !args.has("no-pool");

    let comp_cfg = LlmCompressorConfig {
        model: model.clone(),
        chunk_tokens: chunk,
        stream_bytes: 4096.max(chunk),
        executor,
        lanes,
        threads,
        precision,
        kernel,
        panel_layout,
        codec,
    };
    let mut on_scale: Option<ScaleHook> = None;
    let factory: Box<dyn Fn() -> Result<LlmCompressor> + Send + Sync> =
        if executor == ExecutorKind::Native {
            // Load the weights ONCE; every replica clones the Arc.
            let model_cfg = llmzip::lm::config::by_name(&model)?;
            let store = llmzip::runtime::ArtifactStore::open(artifacts.as_deref())?;
            let weights = store.weights(model_cfg)?;
            let weights = match (precision, weights.precision()) {
                (Precision::Int8, Precision::F32) => weights.quantize(),
                (Precision::F32, Precision::Int8) => anyhow::bail!(
                    "weights for '{model}' are int8-quantized on disk; serve them with \
                     --precision int8"
                ),
                _ => weights,
            };
            let weights = Arc::new(weights);
            // Cross-replica work stealing: ONE StepPool shared by every
            // native replica, so replicas — including autoscale-grown ones
            // — fan their lane spans into a shared injector and idle step
            // threads help busy siblings. The pool starts sized for the
            // INITIAL replica count and then FOLLOWS the live replica
            // gauge via the scale hook (no more paying max_replicas worth
            // of threads while the pool is scaled down; resizing cannot
            // change the bytes). Only engaged when more than one replica
            // can exist (stealing cannot help a lone replica — it would
            // pay injector contention for nothing). --no-steal restores
            // private per-replica pools.
            let pool = if max_replicas > 1 && !args.has("no-steal") {
                let threads_per_replica = threads.max(1);
                let initial = replicas.clamp(min_replicas.max(1), max_replicas);
                let pool = StepPool::new(threads_per_replica * initial);
                let hook_pool = pool.clone();
                on_scale = Some(Arc::new(move |live: usize| {
                    hook_pool.resize(threads_per_replica * live.max(1));
                }));
                Some(pool)
            } else {
                None
            };
            Box::new(move || {
                LlmCompressor::from_shared_pooled(
                    model_cfg,
                    weights.clone(),
                    comp_cfg.clone(),
                    pool.clone(),
                )
            })
        } else {
            if precision != Precision::F32 {
                anyhow::bail!("--precision int8 requires --executor native");
            }
            Box::new(move || {
                let store = llmzip::runtime::ArtifactStore::open(artifacts.as_deref())?;
                LlmCompressor::open(&store, comp_cfg.clone())
            })
        };
    let server = Server::start_with_hook(
        factory,
        ServerConfig {
            chunk_tokens: chunk,
            lanes,
            threads,
            replicas,
            min_replicas,
            max_replicas,
            autoscale,
            panel_layout,
            codec,
            pooling,
            policy: BatchPolicy {
                lanes,
                max_wait: Duration::from_millis(max_wait_ms),
            },
            ..Default::default()
        },
        on_scale,
    )?;
    let server = Arc::new(server);

    let listener = TcpListener::bind(("127.0.0.1", port as u16))?;
    println!(
        "llmzip serving on 127.0.0.1:{port} \
         (chunk={chunk}, lanes={lanes}, threads={threads}, replicas={replicas}, \
         autoscale={}, precision={}, kernel={}, panels={}, codec={}, pool={}, \
         protocols=v1+v2-mux)",
        if autoscale { format!("{min_replicas}..{max_replicas}") } else { "off".into() },
        precision.as_str(),
        kernel.map_or("auto", |t| t.as_str()),
        if panel_layout { "on" } else { "off" },
        codec.as_str(),
        if pooling { "on" } else { "off" },
    );
    loop {
        let (stream, peer) = listener.accept()?;
        let srv = server.clone();
        std::thread::spawn(move || {
            if let Err(e) = handle_conn(stream, &srv) {
                eprintln!("connection {peer}: {e:#}");
            }
        });
    }
}

/// Serve one connection (either protocol, auto-detected).
pub fn handle_conn(stream: TcpStream, server: &Server) -> Result<()> {
    llmzip::coordinator::wire::serve_connection(stream, server)
}
