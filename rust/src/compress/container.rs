//! Self-describing container for LLM-compressed payloads.
//!
//! The LLM compressor works in fixed-size chunks (paper §5.4); the container
//! records everything decompression needs: which model, which chunk size,
//! per-chunk compressed extents, the original length and a CRC-32 of the
//! original bytes, verified on every decode (lossless-ness is checked, not
//! assumed).
//!
//! Two layouts share one magic and are distinguished by the version field
//! (all integers little-endian):
//!
//! ## v1 — table-first (legacy, still parsed and re-encoded byte-exactly)
//! ```text
//! magic        u32   "LZP1"
//! version      u16   = 1
//! flags        u16   no flag bits are defined for v1; nonzero is rejected
//! orig_len     u64
//! orig_crc32   u32
//! chunk_tokens u32   tokens per chunk (context reset boundary)
//! model_name   u8 len + bytes
//! n_chunks     u32
//! chunk table  n_chunks * { comp_len u32, n_tokens u32 }
//! payload      concatenated chunk payloads
//! ```
//!
//! v1 needs `orig_len`, the CRC and the full chunk table **before** the
//! first payload byte, so an encoder must hold the whole input. That is
//! exactly what the streaming API cannot do, hence:
//!
//! ## v2 — framed + seekable trailer (the format every encoder now emits)
//! ```text
//! magic        u32   "LZP1"
//! version      u16   = 2
//! flags        u16   must contain FLAG_SEEKABLE; unknown bits are rejected
//! chunk_tokens u32
//! model_name   u8 len + bytes
//! frames       n_chunks * { 0xF1 u8 | comp_len u32 | n_tokens u32 | payload }
//! trailer      0xEE u8
//!              n_chunks u32
//!              index      n_chunks * { comp_len u32, n_tokens u32 }
//!              orig_len   u64
//!              orig_crc32 u32
//!              trailer_off u64   byte offset of the 0xEE marker
//!              end_magic  u32   "LZP2"
//! ```
//!
//! Every frame carries its own record, so a [`crate::compress::stream::CompressWriter`]
//! emits it the moment the chunk is encoded — no lookahead, no buffering of
//! earlier frames — and a [`crate::compress::stream::DecompressReader`]
//! decodes frame-by-frame with bounded memory. The trailer duplicates the
//! records as a **seekable index**: a reader that has the whole file jumps
//! `len-12 → trailer_off → index`, computes payload offsets by prefix sum,
//! and decodes any chunk without touching the rest (random-access decode;
//! see `LlmCompressor::{decode_chunk, decompress_range}`). [`Container::from_bytes`]
//! cross-checks frame headers against the index, so the two copies of the
//! records can never disagree silently.
//!
//! The **payload bytes are identical between v1 and v2** for the same input:
//! only the envelope moved. Parsing either version yields the same
//! [`Container`] fields (modulo `version`/`flags`), and `to_bytes`
//! re-serializes whichever layout `version` names, byte-exactly.

use crate::util::{crc32, read_u32_le, read_u64_le};
use crate::Result;

/// Container magic: "LZP1".
pub const CONTAINER_MAGIC: u32 = 0x3150_5A4C;
/// Legacy table-first layout.
pub const CONTAINER_V1: u16 = 1;
/// Framed layout with a seekable trailer index.
pub const CONTAINER_V2: u16 = 2;
/// v2 end magic: "LZP2" (the last 4 bytes of every v2 container).
pub const CONTAINER_END_MAGIC: u32 = 0x3250_5A4C;

/// Flag bit: the container carries a trailer index for random-access
/// decode. Set on every v2 container; undefined (and rejected) on v1.
pub const FLAG_SEEKABLE: u16 = 0x0001;
/// Flag bit: chunk payloads are rank-transformed and FSE/tANS-coded
/// instead of range-coded (see [`Codec::Fse`]). v2 only — pre-FSE
/// releases refuse the bit by name through [`check_flags`], which is
/// exactly the forward-compat story the flag mask was built for.
pub const FLAG_CODEC_FSE: u16 = 0x0002;
/// All flag bits this release understands, per version. Anything outside
/// the mask is from a future release and must be refused, not ignored —
/// a reader that ignores a semantics-bearing bit would decode garbage.
const KNOWN_FLAGS_V1: u16 = 0;
const KNOWN_FLAGS_V2: u16 = FLAG_SEEKABLE | FLAG_CODEC_FSE;

/// Entropy backend used for chunk payloads — the pluggable stage behind
/// the `Codec` seam in [`crate::compress::llm`]. The choice is recorded
/// twice per container (a v2 flag bit and a [`super::ContainerTag`]
/// suffix), and the two records are cross-checked on decode.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Codec {
    /// Adaptive binary-search range coder over the model CDF (the seed
    /// bitstream; byte-for-byte unchanged since v1).
    #[default]
    Range,
    /// Rank transform (position of the observed byte in the CDF's
    /// frequency order) + static table-driven FSE/tANS over the ranks.
    Fse,
}

impl Codec {
    /// Parse a CLI/tag spelling.
    pub fn parse(s: &str) -> Result<Codec> {
        match s {
            "range" => Ok(Codec::Range),
            "fse" => Ok(Codec::Fse),
            other => anyhow::bail!("unknown codec '{other}' (expected 'range' or 'fse')"),
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Codec::Range => "range",
            Codec::Fse => "fse",
        }
    }

    /// The v2 flag bits this codec contributes.
    pub fn flag_bits(self) -> u16 {
        match self {
            Codec::Range => 0,
            Codec::Fse => FLAG_CODEC_FSE,
        }
    }

    /// Recover the codec from a validated v2 flag word.
    pub fn from_flags(flags: u16) -> Codec {
        if flags & FLAG_CODEC_FSE != 0 {
            Codec::Fse
        } else {
            Codec::Range
        }
    }
}

/// Validate a parsed `(version, flags)` pair — the single definition of
/// which flag bits this release understands, shared by
/// [`Container::from_bytes`] and the incremental
/// [`crate::compress::stream::DecompressReader`] so the two decode faces
/// cannot drift.
pub(crate) fn check_flags(version: u16, flags: u16) -> Result<()> {
    let known = match version {
        CONTAINER_V1 => KNOWN_FLAGS_V1,
        CONTAINER_V2 => KNOWN_FLAGS_V2,
        v => anyhow::bail!("unsupported container version {v}"),
    };
    if flags & !known != 0 {
        anyhow::bail!(
            "unknown v{version} container flag bits {flags:#06x} — file from a newer release?"
        );
    }
    if version == CONTAINER_V2 && flags & FLAG_SEEKABLE == 0 {
        anyhow::bail!("v2 container missing the seekable-index flag");
    }
    Ok(())
}

/// Marker byte opening each v2 frame.
pub const FRAME_MARKER: u8 = 0xF1;
/// Marker byte opening the v2 trailer.
pub const TRAILER_MARKER: u8 = 0xEE;

/// v2 fixed header size before the model name.
pub(crate) const V2_HEADER_FIXED: usize = 13;
/// v2 frame header size (marker + comp_len + n_tokens).
pub const FRAME_HEADER: usize = 9;
/// v2 trailer size excluding the index (marker + n_chunks + orig_len +
/// crc + trailer_off + end magic).
pub(crate) const V2_TRAILER_FIXED: usize = 1 + 4 + 8 + 4 + 8 + 4;

/// Per-chunk entry in the table.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChunkRecord {
    /// Compressed byte length of this chunk's payload.
    pub comp_len: u32,
    /// Number of tokens (bytes, for the byte-level model) in the chunk.
    pub n_tokens: u32,
}

/// Parsed/bundled container.
#[derive(Clone, Debug)]
pub struct Container {
    /// Serialized layout: [`CONTAINER_V1`] or [`CONTAINER_V2`]. Preserved
    /// by parse → re-encode, so v1 archives round-trip byte-exactly.
    pub version: u16,
    /// Format flags (carried through verbatim; see [`FLAG_SEEKABLE`]).
    pub flags: u16,
    pub orig_len: u64,
    pub orig_crc32: u32,
    pub chunk_tokens: u32,
    pub model_name: String,
    pub chunks: Vec<ChunkRecord>,
    pub payload: Vec<u8>,
}

impl Container {
    /// Build a legacy v1 container (flags: none defined).
    pub fn v1(
        orig_len: u64,
        orig_crc32: u32,
        chunk_tokens: u32,
        model_name: String,
        chunks: Vec<ChunkRecord>,
        payload: Vec<u8>,
    ) -> Container {
        Container {
            version: CONTAINER_V1,
            flags: 0,
            orig_len,
            orig_crc32,
            chunk_tokens,
            model_name,
            chunks,
            payload,
        }
    }

    /// Build a v2 framed container (always seekable, range-coded payload).
    pub fn v2(
        orig_len: u64,
        orig_crc32: u32,
        chunk_tokens: u32,
        model_name: String,
        chunks: Vec<ChunkRecord>,
        payload: Vec<u8>,
    ) -> Container {
        Self::v2_coded(
            Codec::Range,
            orig_len,
            orig_crc32,
            chunk_tokens,
            model_name,
            chunks,
            payload,
        )
    }

    /// Build a v2 framed container whose payload was produced by `codec`
    /// (the codec's flag bit is set alongside [`FLAG_SEEKABLE`]).
    pub fn v2_coded(
        codec: Codec,
        orig_len: u64,
        orig_crc32: u32,
        chunk_tokens: u32,
        model_name: String,
        chunks: Vec<ChunkRecord>,
        payload: Vec<u8>,
    ) -> Container {
        Container {
            version: CONTAINER_V2,
            flags: FLAG_SEEKABLE | codec.flag_bits(),
            orig_len,
            orig_crc32,
            chunk_tokens,
            model_name,
            chunks,
            payload,
        }
    }

    /// Serialize the v2 header (everything before the first frame). Shared
    /// by [`Self::to_bytes`] and the incremental
    /// [`crate::compress::stream::CompressWriter`], so the two paths
    /// cannot drift.
    pub fn v2_header(flags: u16, chunk_tokens: u32, model_name: &str) -> Vec<u8> {
        let name = model_name.as_bytes();
        assert!(name.len() <= 255, "model tag too long");
        assert!(
            flags & FLAG_SEEKABLE != 0 && flags & !KNOWN_FLAGS_V2 == 0,
            "v2 header flags {flags:#06x} must be seekable + known bits only"
        );
        let mut out = Vec::with_capacity(V2_HEADER_FIXED + name.len());
        out.extend_from_slice(&CONTAINER_MAGIC.to_le_bytes());
        out.extend_from_slice(&CONTAINER_V2.to_le_bytes());
        out.extend_from_slice(&flags.to_le_bytes());
        out.extend_from_slice(&chunk_tokens.to_le_bytes());
        out.push(name.len() as u8);
        out.extend_from_slice(name);
        out
    }

    /// Serialize one v2 frame header (marker + record); the chunk payload
    /// follows it verbatim.
    pub fn v2_frame_header(rec: ChunkRecord) -> [u8; FRAME_HEADER] {
        let mut h = [0u8; FRAME_HEADER];
        h[0] = FRAME_MARKER;
        h[1..5].copy_from_slice(&rec.comp_len.to_le_bytes());
        h[5..9].copy_from_slice(&rec.n_tokens.to_le_bytes());
        h
    }

    /// Serialize the v2 trailer. `trailer_off` is the byte offset (from
    /// the container start) at which this trailer begins.
    pub fn v2_trailer(
        chunks: &[ChunkRecord],
        orig_len: u64,
        orig_crc32: u32,
        trailer_off: u64,
    ) -> Vec<u8> {
        let mut out = Vec::with_capacity(V2_TRAILER_FIXED + chunks.len() * 8);
        out.push(TRAILER_MARKER);
        out.extend_from_slice(&(chunks.len() as u32).to_le_bytes());
        for c in chunks {
            out.extend_from_slice(&c.comp_len.to_le_bytes());
            out.extend_from_slice(&c.n_tokens.to_le_bytes());
        }
        out.extend_from_slice(&orig_len.to_le_bytes());
        out.extend_from_slice(&orig_crc32.to_le_bytes());
        out.extend_from_slice(&trailer_off.to_le_bytes());
        out.extend_from_slice(&CONTAINER_END_MAGIC.to_le_bytes());
        out
    }

    /// Serialize to bytes in the layout `self.version` names.
    pub fn to_bytes(&self) -> Vec<u8> {
        match self.version {
            CONTAINER_V1 => self.to_bytes_v1(),
            CONTAINER_V2 => self.to_bytes_v2(),
            v => panic!("unencodable container version {v}"),
        }
    }

    fn to_bytes_v1(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.payload.len() + 64 + self.chunks.len() * 8);
        out.extend_from_slice(&CONTAINER_MAGIC.to_le_bytes());
        out.extend_from_slice(&CONTAINER_V1.to_le_bytes());
        out.extend_from_slice(&self.flags.to_le_bytes());
        out.extend_from_slice(&self.orig_len.to_le_bytes());
        out.extend_from_slice(&self.orig_crc32.to_le_bytes());
        out.extend_from_slice(&self.chunk_tokens.to_le_bytes());
        let name = self.model_name.as_bytes();
        assert!(name.len() <= 255);
        out.push(name.len() as u8);
        out.extend_from_slice(name);
        out.extend_from_slice(&(self.chunks.len() as u32).to_le_bytes());
        for c in &self.chunks {
            out.extend_from_slice(&c.comp_len.to_le_bytes());
            out.extend_from_slice(&c.n_tokens.to_le_bytes());
        }
        out.extend_from_slice(&self.payload);
        out
    }

    fn to_bytes_v2(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(
            self.payload.len() + 64 + self.chunks.len() * (8 + FRAME_HEADER),
        );
        // v2_coded() always sets FLAG_SEEKABLE plus known codec bits; a
        // hand-built container with other flags would not survive parse,
        // so refuse to emit one (v2_header re-checks the same set).
        out.extend_from_slice(&Self::v2_header(self.flags, self.chunk_tokens, &self.model_name));
        let mut offset = 0usize;
        for &rec in &self.chunks {
            out.extend_from_slice(&Self::v2_frame_header(rec));
            out.extend_from_slice(&self.payload[offset..offset + rec.comp_len as usize]);
            offset += rec.comp_len as usize;
        }
        let trailer_off = out.len() as u64;
        out.extend_from_slice(&Self::v2_trailer(
            &self.chunks,
            self.orig_len,
            self.orig_crc32,
            trailer_off,
        ));
        out
    }

    /// Read just the model tag out of a serialized container's header —
    /// both layouts — without parsing the chunk table or touching the
    /// payload. This is how a multi-model router picks the pool for a
    /// decompress request: the container itself names its engine. Borrows
    /// from `data`, so routing a 100 MB container costs a few header
    /// bytes of work and no allocation.
    pub fn peek_model_name(data: &[u8]) -> Result<&str> {
        if data.len() < 8 {
            anyhow::bail!("container too short");
        }
        if read_u32_le(data, 0) != CONTAINER_MAGIC {
            anyhow::bail!("bad container magic");
        }
        let version = u16::from_le_bytes([data[4], data[5]]);
        // Offset of the `u8 len | bytes` model-name field per layout.
        let name_at = match version {
            CONTAINER_V1 => 24,
            CONTAINER_V2 => 12,
            v => anyhow::bail!("unsupported container version {v}"),
        };
        let name_len = *data
            .get(name_at)
            .ok_or_else(|| anyhow::anyhow!("truncated container header"))?
            as usize;
        let name = data
            .get(name_at + 1..name_at + 1 + name_len)
            .ok_or_else(|| anyhow::anyhow!("truncated container header"))?;
        std::str::from_utf8(name).map_err(|_| anyhow::anyhow!("model name is not UTF-8"))
    }

    /// Parse from bytes, validating structure (but not the CRC — that is
    /// checked against the *decompressed* output by the caller). Accepts
    /// both layouts; the parsed `version` records which one, so
    /// [`Self::to_bytes`] reproduces the input byte-exactly.
    pub fn from_bytes(data: &[u8]) -> Result<Self> {
        if data.len() < 8 {
            anyhow::bail!("container too short");
        }
        if read_u32_le(data, 0) != CONTAINER_MAGIC {
            anyhow::bail!("bad container magic");
        }
        let version = u16::from_le_bytes([data[4], data[5]]);
        let flags = u16::from_le_bytes([data[6], data[7]]);
        match version {
            CONTAINER_V1 => Self::from_bytes_v1(data, flags),
            CONTAINER_V2 => Self::from_bytes_v2(data, flags),
            v => anyhow::bail!("unsupported container version {v}"),
        }
    }

    fn from_bytes_v1(data: &[u8], flags: u16) -> Result<Self> {
        check_flags(CONTAINER_V1, flags)?;
        if data.len() < 27 {
            anyhow::bail!("container too short");
        }
        let orig_len = read_u64_le(data, 8);
        let orig_crc32 = read_u32_le(data, 16);
        let chunk_tokens = read_u32_le(data, 20);
        let name_len = data[24] as usize;
        let mut pos = 25;
        if data.len() < pos + name_len + 4 {
            anyhow::bail!("truncated container header");
        }
        let model_name = String::from_utf8(data[pos..pos + name_len].to_vec())
            .map_err(|_| anyhow::anyhow!("model name is not UTF-8"))?;
        pos += name_len;
        let n_chunks = read_u32_le(data, pos) as usize;
        pos += 4;
        if data.len() < pos + n_chunks * 8 {
            anyhow::bail!("truncated chunk table");
        }
        let mut chunks = Vec::with_capacity(n_chunks);
        let mut total_comp = 0u64;
        let mut total_tokens = 0u64;
        for i in 0..n_chunks {
            let comp_len = read_u32_le(data, pos + i * 8);
            let n_tokens = read_u32_le(data, pos + i * 8 + 4);
            total_comp += comp_len as u64;
            total_tokens += n_tokens as u64;
            chunks.push(ChunkRecord { comp_len, n_tokens });
        }
        pos += n_chunks * 8;
        if data.len() as u64 != pos as u64 + total_comp {
            anyhow::bail!(
                "container payload size mismatch: have {}, expect {}",
                data.len() - pos,
                total_comp
            );
        }
        if total_tokens != orig_len {
            anyhow::bail!("chunk token sum {total_tokens} != original length {orig_len}");
        }
        Ok(Container {
            version: CONTAINER_V1,
            flags,
            orig_len,
            orig_crc32,
            chunk_tokens,
            model_name,
            chunks,
            payload: data[pos..].to_vec(),
        })
    }

    fn from_bytes_v2(data: &[u8], flags: u16) -> Result<Self> {
        check_flags(CONTAINER_V2, flags)?;
        if data.len() < V2_HEADER_FIXED + V2_TRAILER_FIXED {
            anyhow::bail!("container too short");
        }
        let chunk_tokens = read_u32_le(data, 8);
        let name_len = data[12] as usize;
        let header_end = V2_HEADER_FIXED + name_len;
        if data.len() < header_end + V2_TRAILER_FIXED {
            anyhow::bail!("truncated container header");
        }
        let model_name = String::from_utf8(data[V2_HEADER_FIXED..header_end].to_vec())
            .map_err(|_| anyhow::anyhow!("model name is not UTF-8"))?;
        // Trailer first (the seekable path): the last 12 bytes locate it.
        if read_u32_le(data, data.len() - 4) != CONTAINER_END_MAGIC {
            anyhow::bail!("bad container end magic — truncated v2 container?");
        }
        let trailer_off64 = read_u64_le(data, data.len() - 12);
        let trailer_max = (data.len() - V2_TRAILER_FIXED) as u64;
        if trailer_off64 < header_end as u64 || trailer_off64 > trailer_max {
            anyhow::bail!("container trailer offset {trailer_off64} out of bounds");
        }
        let trailer_off = trailer_off64 as usize;
        if data[trailer_off] != TRAILER_MARKER {
            anyhow::bail!("container trailer marker missing at offset {trailer_off}");
        }
        let n_chunks = read_u32_le(data, trailer_off + 1) as usize;
        if trailer_off as u64 + V2_TRAILER_FIXED as u64 + 8 * n_chunks as u64 != data.len() as u64 {
            anyhow::bail!("container trailer size disagrees with its chunk count");
        }
        let index_at = trailer_off + 5;
        let mut chunks = Vec::with_capacity(n_chunks);
        let mut total_comp = 0u64;
        let mut total_tokens = 0u64;
        for i in 0..n_chunks {
            let comp_len = read_u32_le(data, index_at + i * 8);
            let n_tokens = read_u32_le(data, index_at + i * 8 + 4);
            total_comp += comp_len as u64;
            total_tokens += n_tokens as u64;
            chunks.push(ChunkRecord { comp_len, n_tokens });
        }
        let orig_len = read_u64_le(data, index_at + n_chunks * 8);
        let orig_crc32 = read_u32_le(data, index_at + n_chunks * 8 + 8);
        if total_tokens != orig_len {
            anyhow::bail!("chunk token sum {total_tokens} != original length {orig_len}");
        }
        // Frame walk: every frame header must agree with the index, and the
        // frames must tile [header_end, trailer_off) exactly.
        if trailer_off as u64
            != header_end as u64 + n_chunks as u64 * FRAME_HEADER as u64 + total_comp
        {
            anyhow::bail!("container frame region size disagrees with the trailer index");
        }
        let mut payload = Vec::with_capacity(total_comp as usize);
        let mut pos = header_end;
        for (i, rec) in chunks.iter().enumerate() {
            if data[pos] != FRAME_MARKER {
                anyhow::bail!("frame {i} marker missing at offset {pos}");
            }
            let comp_len = read_u32_le(data, pos + 1);
            let n_tokens = read_u32_le(data, pos + 5);
            if comp_len != rec.comp_len || n_tokens != rec.n_tokens {
                anyhow::bail!(
                    "frame {i} header ({comp_len}, {n_tokens}) disagrees with trailer index \
                     ({}, {})",
                    rec.comp_len,
                    rec.n_tokens
                );
            }
            pos += FRAME_HEADER;
            payload.extend_from_slice(&data[pos..pos + comp_len as usize]);
            pos += comp_len as usize;
        }
        debug_assert_eq!(pos, trailer_off);
        Ok(Container {
            version: CONTAINER_V2,
            flags,
            orig_len,
            orig_crc32,
            chunk_tokens,
            model_name,
            chunks,
            payload,
        })
    }

    /// Iterate `(record, payload_slice)` pairs.
    pub fn iter_chunks(&self) -> impl Iterator<Item = (ChunkRecord, &[u8])> {
        let mut offset = 0usize;
        self.chunks.iter().map(move |&rec| {
            let s = &self.payload[offset..offset + rec.comp_len as usize];
            offset += rec.comp_len as usize;
            (rec, s)
        })
    }

    /// Random access to one chunk: `(record, payload_slice)` for chunk `i`,
    /// plus the offset (in decoded bytes) at which that chunk begins — the
    /// trailer index makes this a table walk, no payload decoding.
    pub fn chunk(&self, i: usize) -> Result<(ChunkRecord, &[u8], u64)> {
        if i >= self.chunks.len() {
            anyhow::bail!("chunk {i} out of range (container has {})", self.chunks.len());
        }
        let mut comp_off = 0usize;
        let mut token_off = 0u64;
        for rec in &self.chunks[..i] {
            comp_off += rec.comp_len as usize;
            token_off += rec.n_tokens as u64;
        }
        let rec = self.chunks[i];
        Ok((rec, &self.payload[comp_off..comp_off + rec.comp_len as usize], token_off))
    }

    /// Verify a decompressed buffer against the recorded length + CRC.
    pub fn verify(&self, decompressed: &[u8]) -> Result<()> {
        if decompressed.len() as u64 != self.orig_len {
            anyhow::bail!("decompressed length {} != recorded {}", decompressed.len(), self.orig_len);
        }
        let crc = crc32(decompressed);
        if crc != self.orig_crc32 {
            anyhow::bail!("CRC mismatch: {crc:#010x} != {:#010x}", self.orig_crc32);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Container {
        Container::v1(
            1000,
            0xDEADBEEF,
            256,
            "medium".to_string(),
            vec![
                ChunkRecord { comp_len: 3, n_tokens: 256 },
                ChunkRecord { comp_len: 4, n_tokens: 256 },
                ChunkRecord { comp_len: 2, n_tokens: 256 },
                ChunkRecord { comp_len: 1, n_tokens: 232 },
            ],
            vec![1, 2, 3, 4, 5, 6, 7, 8, 9, 10],
        )
    }

    fn sample_v2() -> Container {
        let mut c = sample();
        c.version = CONTAINER_V2;
        c.flags = FLAG_SEEKABLE;
        c
    }

    fn assert_fields_eq(d: &Container, c: &Container) {
        assert_eq!(d.version, c.version);
        assert_eq!(d.flags, c.flags);
        assert_eq!(d.orig_len, c.orig_len);
        assert_eq!(d.orig_crc32, c.orig_crc32);
        assert_eq!(d.chunk_tokens, c.chunk_tokens);
        assert_eq!(d.model_name, c.model_name);
        assert_eq!(d.chunks, c.chunks);
        assert_eq!(d.payload, c.payload);
    }

    #[test]
    fn roundtrip() {
        for c in [sample(), sample_v2()] {
            let bytes = c.to_bytes();
            let d = Container::from_bytes(&bytes).unwrap();
            assert_fields_eq(&d, &c);
            assert_eq!(d.to_bytes(), bytes, "parse -> re-encode is the identity");
        }
    }

    #[test]
    fn v1_and_v2_carry_identical_payload_and_records() {
        let (a, b) = (sample(), sample_v2());
        let pa = Container::from_bytes(&a.to_bytes()).unwrap();
        let pb = Container::from_bytes(&b.to_bytes()).unwrap();
        assert_eq!(pa.payload, pb.payload);
        assert_eq!(pa.chunks, pb.chunks);
        assert_eq!(pa.orig_crc32, pb.orig_crc32);
    }

    #[test]
    fn peek_model_name_reads_both_layouts_without_parsing() {
        for c in [sample(), sample_v2()] {
            let bytes = c.to_bytes();
            assert_eq!(Container::peek_model_name(&bytes).unwrap(), c.model_name);
            // The peek reads the header only: truncating the payload off
            // the end still routes, a truncated header errors cleanly.
            assert_eq!(Container::peek_model_name(&bytes[..32]).unwrap(), c.model_name);
            assert!(Container::peek_model_name(&bytes[..10]).is_err());
        }
        assert!(Container::peek_model_name(b"not a container").is_err());
    }

    #[test]
    fn v2_empty_container_roundtrips() {
        let c = Container::v2(0, crc32(b""), 64, "nano:0".into(), vec![], vec![]);
        let bytes = c.to_bytes();
        let d = Container::from_bytes(&bytes).unwrap();
        assert_fields_eq(&d, &c);
        assert_eq!(d.to_bytes(), bytes);
    }

    #[test]
    fn iter_chunks_slices_payload() {
        for c in [sample(), sample_v2()] {
            let parts: Vec<Vec<u8>> = c.iter_chunks().map(|(_, s)| s.to_vec()).collect();
            assert_eq!(parts, vec![vec![1, 2, 3], vec![4, 5, 6, 7], vec![8, 9], vec![10]]);
        }
    }

    #[test]
    fn chunk_random_access_matches_iteration() {
        let c = sample_v2();
        let mut token_off = 0u64;
        for (i, (rec, slice)) in c.iter_chunks().enumerate() {
            let (r, s, t) = c.chunk(i).unwrap();
            assert_eq!(r, rec);
            assert_eq!(s, slice);
            assert_eq!(t, token_off);
            token_off += rec.n_tokens as u64;
        }
        assert!(c.chunk(4).is_err());
    }

    #[test]
    fn bad_magic_rejected() {
        for c in [sample(), sample_v2()] {
            let mut bytes = c.to_bytes();
            bytes[0] ^= 0xFF;
            assert!(Container::from_bytes(&bytes).is_err());
        }
    }

    #[test]
    fn truncation_rejected() {
        let bytes = sample().to_bytes();
        for cut in [5, 20, 26, bytes.len() - 1] {
            assert!(Container::from_bytes(&bytes[..cut]).is_err(), "cut={cut}");
        }
        // v2: EVERY proper prefix must be rejected (frame boundaries, mid
        // trailer, mid index — all of them).
        let bytes = sample_v2().to_bytes();
        for cut in 0..bytes.len() {
            assert!(Container::from_bytes(&bytes[..cut]).is_err(), "v2 cut={cut}");
        }
    }

    #[test]
    fn trailing_garbage_rejected() {
        for c in [sample(), sample_v2()] {
            let mut bytes = c.to_bytes();
            bytes.push(0);
            assert!(Container::from_bytes(&bytes).is_err());
        }
    }

    #[test]
    fn unknown_flag_bits_rejected() {
        // v1 defines no flags; v2 defines FLAG_SEEKABLE and FLAG_CODEC_FSE.
        // Any other bit means a future format revision — refuse it by name.
        let mut v1 = sample().to_bytes();
        v1[6] = 0x01;
        let err = Container::from_bytes(&v1).unwrap_err().to_string();
        assert!(err.contains("flag"), "{err}");
        let mut v2 = sample_v2().to_bytes();
        v2[6] = 0x05; // seekable + one unknown bit
        let err = Container::from_bytes(&v2).unwrap_err().to_string();
        assert!(err.contains("flag"), "{err}");
        // A v2 container WITHOUT the seekable bit is also malformed.
        let mut v2 = sample_v2().to_bytes();
        v2[6] = 0x00;
        assert!(Container::from_bytes(&v2).is_err());
    }

    #[test]
    fn fse_codec_flag_round_trips_and_maps_to_codec() {
        let mut c = sample_v2();
        c.flags = FLAG_SEEKABLE | FLAG_CODEC_FSE;
        let bytes = c.to_bytes();
        let parsed = Container::from_bytes(&bytes).unwrap();
        assert_eq!(parsed.flags, FLAG_SEEKABLE | FLAG_CODEC_FSE);
        assert_eq!(parsed.to_bytes(), bytes);
        assert_eq!(Codec::from_flags(parsed.flags), Codec::Fse);
        assert_eq!(Codec::from_flags(FLAG_SEEKABLE), Codec::Range);
        let via = Container::v2_coded(Codec::Fse, 10, 1, 64, "m".into(), vec![], vec![]);
        assert_eq!(via.flags, FLAG_SEEKABLE | FLAG_CODEC_FSE);
    }

    #[test]
    fn codec_parse_and_render() {
        assert_eq!(Codec::parse("range").unwrap(), Codec::Range);
        assert_eq!(Codec::parse("fse").unwrap(), Codec::Fse);
        assert!(Codec::parse("").is_err());
        assert!(Codec::parse("huffman").is_err());
        assert_eq!(Codec::Range.as_str(), "range");
        assert_eq!(Codec::Fse.as_str(), "fse");
        assert_eq!(Codec::Fse.flag_bits(), FLAG_CODEC_FSE);
        assert_eq!(Codec::Range.flag_bits(), 0);
        assert_eq!(Codec::default(), Codec::Range);
    }

    #[test]
    fn flags_round_trip_through_serialization() {
        let c = sample_v2();
        let parsed = Container::from_bytes(&c.to_bytes()).unwrap();
        assert_eq!(parsed.flags, FLAG_SEEKABLE, "flags carried, not hardcoded");
        assert_eq!(parsed.to_bytes(), c.to_bytes());
    }

    #[test]
    fn v2_frame_index_disagreement_rejected() {
        let c = sample_v2();
        let mut bytes = c.to_bytes();
        // Corrupt the first frame's n_tokens field (header starts right
        // after the 13+name header; marker at header_end).
        let header_end = 13 + c.model_name.len();
        assert_eq!(bytes[header_end], FRAME_MARKER);
        bytes[header_end + 5] ^= 1;
        let err = Container::from_bytes(&bytes).unwrap_err().to_string();
        assert!(err.contains("disagrees"), "{err}");
    }

    #[test]
    fn v2_corrupt_trailer_and_end_magic_rejected() {
        let c = sample_v2();
        let n = c.to_bytes().len();
        // End magic.
        let mut bytes = c.to_bytes();
        bytes[n - 1] ^= 0xFF;
        assert!(Container::from_bytes(&bytes).is_err());
        // Trailer offset.
        let mut bytes = c.to_bytes();
        bytes[n - 12] ^= 0xFF;
        assert!(Container::from_bytes(&bytes).is_err());
        // Chunk count in the trailer.
        let mut bytes = c.to_bytes();
        let trailer_off = read_u64_le(&bytes, n - 12) as usize;
        bytes[trailer_off + 1] ^= 0x01;
        assert!(Container::from_bytes(&bytes).is_err());
    }

    #[test]
    fn token_sum_must_match_orig_len() {
        for mut c in [sample(), sample_v2()] {
            c.chunks[0].n_tokens += 1;
            // (v2 keeps frame headers and index in sync — both lie here.)
            let bytes = c.to_bytes();
            assert!(Container::from_bytes(&bytes).is_err());
        }
    }

    #[test]
    fn verify_checks_crc_and_len() {
        let data = b"some original data".to_vec();
        let c = Container::v1(
            data.len() as u64,
            crate::util::crc32(&data),
            16,
            "m".into(),
            vec![ChunkRecord { comp_len: 0, n_tokens: data.len() as u32 }],
            vec![],
        );
        assert!(c.verify(&data).is_ok());
        assert!(c.verify(b"some original dat").is_err());
        let mut bad = data.clone();
        bad[0] ^= 1;
        assert!(c.verify(&bad).is_err());
    }
}
