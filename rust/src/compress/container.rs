//! Self-describing container for LLM-compressed payloads.
//!
//! The LLM compressor works in fixed-size chunks (paper §5.4); the container
//! records everything decompression needs: which model, which chunk size,
//! per-chunk compressed extents, the original length and a CRC-32 of the
//! original bytes, verified on every decode (lossless-ness is checked, not
//! assumed).
//!
//! Layout (all little-endian):
//! ```text
//! magic        u32   "LZP1"
//! version      u16
//! flags        u16
//! orig_len     u64
//! orig_crc32   u32
//! chunk_tokens u32   tokens per chunk (context reset boundary)
//! model_name   u8 len + bytes
//! n_chunks     u32
//! chunk table  n_chunks * { comp_len u32, n_tokens u32 }
//! payload      concatenated chunk payloads
//! ```

use crate::util::{crc32, read_u32_le, read_u64_le};
use crate::Result;

/// Container magic: "LZP1".
pub const CONTAINER_MAGIC: u32 = 0x3150_5A4C;
pub const CONTAINER_VERSION: u16 = 1;

/// Per-chunk entry in the table.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChunkRecord {
    /// Compressed byte length of this chunk's payload.
    pub comp_len: u32,
    /// Number of tokens (bytes, for the byte-level model) in the chunk.
    pub n_tokens: u32,
}

/// Parsed/bundled container.
#[derive(Clone, Debug)]
pub struct Container {
    pub orig_len: u64,
    pub orig_crc32: u32,
    pub chunk_tokens: u32,
    pub model_name: String,
    pub chunks: Vec<ChunkRecord>,
    pub payload: Vec<u8>,
}

impl Container {
    /// Serialize to bytes.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.payload.len() + 64 + self.chunks.len() * 8);
        out.extend_from_slice(&CONTAINER_MAGIC.to_le_bytes());
        out.extend_from_slice(&CONTAINER_VERSION.to_le_bytes());
        out.extend_from_slice(&0u16.to_le_bytes());
        out.extend_from_slice(&self.orig_len.to_le_bytes());
        out.extend_from_slice(&self.orig_crc32.to_le_bytes());
        out.extend_from_slice(&self.chunk_tokens.to_le_bytes());
        let name = self.model_name.as_bytes();
        assert!(name.len() <= 255);
        out.push(name.len() as u8);
        out.extend_from_slice(name);
        out.extend_from_slice(&(self.chunks.len() as u32).to_le_bytes());
        for c in &self.chunks {
            out.extend_from_slice(&c.comp_len.to_le_bytes());
            out.extend_from_slice(&c.n_tokens.to_le_bytes());
        }
        out.extend_from_slice(&self.payload);
        out
    }

    /// Parse from bytes, validating structure (but not the CRC — that is
    /// checked against the *decompressed* output by the caller).
    pub fn from_bytes(data: &[u8]) -> Result<Self> {
        if data.len() < 27 {
            anyhow::bail!("container too short");
        }
        if read_u32_le(data, 0) != CONTAINER_MAGIC {
            anyhow::bail!("bad container magic");
        }
        let version = u16::from_le_bytes([data[4], data[5]]);
        if version != CONTAINER_VERSION {
            anyhow::bail!("unsupported container version {version}");
        }
        let orig_len = read_u64_le(data, 8);
        let orig_crc32 = read_u32_le(data, 16);
        let chunk_tokens = read_u32_le(data, 20);
        let name_len = data[24] as usize;
        let mut pos = 25;
        if data.len() < pos + name_len + 4 {
            anyhow::bail!("truncated container header");
        }
        let model_name = String::from_utf8(data[pos..pos + name_len].to_vec())
            .map_err(|_| anyhow::anyhow!("model name is not UTF-8"))?;
        pos += name_len;
        let n_chunks = read_u32_le(data, pos) as usize;
        pos += 4;
        if data.len() < pos + n_chunks * 8 {
            anyhow::bail!("truncated chunk table");
        }
        let mut chunks = Vec::with_capacity(n_chunks);
        let mut total_comp = 0u64;
        let mut total_tokens = 0u64;
        for i in 0..n_chunks {
            let comp_len = read_u32_le(data, pos + i * 8);
            let n_tokens = read_u32_le(data, pos + i * 8 + 4);
            total_comp += comp_len as u64;
            total_tokens += n_tokens as u64;
            chunks.push(ChunkRecord { comp_len, n_tokens });
        }
        pos += n_chunks * 8;
        if data.len() as u64 != pos as u64 + total_comp {
            anyhow::bail!(
                "container payload size mismatch: have {}, expect {}",
                data.len() - pos,
                total_comp
            );
        }
        if total_tokens != orig_len {
            anyhow::bail!("chunk token sum {total_tokens} != original length {orig_len}");
        }
        Ok(Container {
            orig_len,
            orig_crc32,
            chunk_tokens,
            model_name,
            chunks,
            payload: data[pos..].to_vec(),
        })
    }

    /// Iterate `(record, payload_slice)` pairs.
    pub fn iter_chunks(&self) -> impl Iterator<Item = (ChunkRecord, &[u8])> {
        let mut offset = 0usize;
        self.chunks.iter().map(move |&rec| {
            let s = &self.payload[offset..offset + rec.comp_len as usize];
            offset += rec.comp_len as usize;
            (rec, s)
        })
    }

    /// Verify a decompressed buffer against the recorded length + CRC.
    pub fn verify(&self, decompressed: &[u8]) -> Result<()> {
        if decompressed.len() as u64 != self.orig_len {
            anyhow::bail!("decompressed length {} != recorded {}", decompressed.len(), self.orig_len);
        }
        let crc = crc32(decompressed);
        if crc != self.orig_crc32 {
            anyhow::bail!("CRC mismatch: {crc:#010x} != {:#010x}", self.orig_crc32);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Container {
        Container {
            orig_len: 1000,
            orig_crc32: 0xDEADBEEF,
            chunk_tokens: 256,
            model_name: "medium".to_string(),
            chunks: vec![
                ChunkRecord { comp_len: 3, n_tokens: 256 },
                ChunkRecord { comp_len: 4, n_tokens: 256 },
                ChunkRecord { comp_len: 2, n_tokens: 256 },
                ChunkRecord { comp_len: 1, n_tokens: 232 },
            ],
            payload: vec![1, 2, 3, 4, 5, 6, 7, 8, 9, 10],
        }
    }

    #[test]
    fn roundtrip() {
        let c = sample();
        let bytes = c.to_bytes();
        let d = Container::from_bytes(&bytes).unwrap();
        assert_eq!(d.orig_len, c.orig_len);
        assert_eq!(d.orig_crc32, c.orig_crc32);
        assert_eq!(d.chunk_tokens, c.chunk_tokens);
        assert_eq!(d.model_name, c.model_name);
        assert_eq!(d.chunks, c.chunks);
        assert_eq!(d.payload, c.payload);
    }

    #[test]
    fn iter_chunks_slices_payload() {
        let c = sample();
        let parts: Vec<Vec<u8>> = c.iter_chunks().map(|(_, s)| s.to_vec()).collect();
        assert_eq!(parts, vec![vec![1, 2, 3], vec![4, 5, 6, 7], vec![8, 9], vec![10]]);
    }

    #[test]
    fn bad_magic_rejected() {
        let mut bytes = sample().to_bytes();
        bytes[0] ^= 0xFF;
        assert!(Container::from_bytes(&bytes).is_err());
    }

    #[test]
    fn truncation_rejected() {
        let bytes = sample().to_bytes();
        for cut in [5, 20, 26, bytes.len() - 1] {
            assert!(Container::from_bytes(&bytes[..cut]).is_err(), "cut={cut}");
        }
    }

    #[test]
    fn token_sum_must_match_orig_len() {
        let mut c = sample();
        c.chunks[0].n_tokens += 1;
        let bytes = c.to_bytes();
        assert!(Container::from_bytes(&bytes).is_err());
    }

    #[test]
    fn verify_checks_crc_and_len() {
        let data = b"some original data".to_vec();
        let c = Container {
            orig_len: data.len() as u64,
            orig_crc32: crate::util::crc32(&data),
            chunk_tokens: 16,
            model_name: "m".into(),
            chunks: vec![ChunkRecord { comp_len: 0, n_tokens: data.len() as u32 }],
            payload: vec![],
        };
        assert!(c.verify(&data).is_ok());
        assert!(c.verify(b"some original dat").is_err());
        let mut bad = data.clone();
        bad[0] ^= 1;
        assert!(c.verify(&bad).is_err());
    }
}
