//! The paper's contribution: LLM next-token prediction + arithmetic coding.
//!
//! Pipeline (paper §4): split the text into chunks of `chunk_tokens` bytes;
//! for every position obtain `P(x_t | x_<t)` from the LM; quantize each
//! distribution to a 16-bit cumulative table; drive the range coder with it.
//! Decompression replays the same model autoregressively, decoding each
//! byte from the bitstream before feeding it back.
//!
//! ## Engine dispatch
//!
//! [`LlmCompressor`] holds a `Box<dyn LmExecutor>` — there is no per-engine
//! dispatch in this module. The bulk encode path
//! ([`LmExecutor::encode_logits`]) and the stepping decode path
//! ([`LmExecutor::step_into`]) are both trait methods: PJRT-forward
//! overrides the former with its one-call batched HLO, the native engine
//! overrides the latter with its zero-allocation batched scratch-arena
//! step, and everything else inherits the defaults. The native engine is
//! additionally opened with `head_rows = CODED_BYTES`: only byte symbols
//! 0..256 ever feed [`logits_to_cdf`], so special-token logit rows are
//! skipped (bit-identical on the coded region).
//!
//! The steady-state decode loop performs zero heap allocations per token:
//! one logits buffer is allocated per batch and refilled by `step_into`.
//!
//! Bit-exactness contract: encode and decode MUST see identical logits at
//! every position. This holds because (a) both sides run the same engine
//! kind (recorded in the container and enforced on decode), (b) the model
//! is strictly causal so logits at position `t` never depend on later
//! tokens, and (c) quantization is a deterministic function of the f32
//! logits (same code on both sides). `tests/golden_logits.rs` further pins
//! the native engine to the frozen seed implementation bit-for-bit, so
//! containers produced before the batched-engine refactor still decode.
//!
//! ## Precision is part of the contract
//!
//! Bit-identical logits also require bit-identical *weights*: an
//! int8-quantized bundle produces different logits than its f32 source,
//! so containers record the weight precision and (for quantized bundles)
//! the bundle's content fingerprint in the tag:
//! `model:executor_flag[:q8:<fingerprint-hex>]`. Legacy 2-part tags parse
//! as f32 — every pre-existing container keeps decoding, and f32
//! compressors keep emitting the 2-part tag so their container bytes are
//! unchanged. A precision or fingerprint mismatch is rejected up front
//! with a clear error instead of surfacing as a baffling CRC failure
//! after decoding garbage.

use crate::compress::container::{ChunkRecord, Codec, Container};
use crate::compress::rank::{FseChunkDecoder, FseChunkEncoder};
use crate::compress::source::SeekableContainer;
use crate::compress::Compressor;
use crate::entropy::range::{RangeDecoder, RangeEncoder};
use crate::lm::config::{self, LmConfig};
use crate::lm::executor::{ExecutorKind, LmExecutor};
use crate::lm::kernels::{KernelOptions, KernelTier};
use crate::lm::native::{NativeExecutor, StepPool};
use crate::lm::weights::{Precision, Weights};
use crate::runtime::{ArtifactStore, PjrtForwardExecutor, PjrtStepExecutor};
use crate::tokenizer::vocab::{BOS, PAD};
use crate::util::crc32;
use crate::Result;
use std::cell::RefCell;
use std::sync::Arc;

const VOCAB: usize = config::VOCAB;
/// Quantization total for the token CDF (fits the range coder's MAX_TOTAL).
pub const CDF_TOTAL: u32 = 1 << 16;

/// Softmax over the 256 byte symbols only (specials are never coded),
/// then deterministic quantization to a cumulative table summing CDF_TOTAL.
/// Returns `cums[257]` with `cums[256] == CDF_TOTAL`.
pub fn logits_to_cdf(logits: &[f32]) -> [u32; 257] {
    logits_to_cdf_argmax(logits).0
}

/// [`logits_to_cdf`] plus the index the leftover mass was assigned to — the
/// first symbol of maximal quantized frequency. The rank coder needs it (the
/// argmax IS rank 0 under the `(freq desc, index asc)` ordering), and it
/// falls out of the quantization loop for free.
pub fn logits_to_cdf_argmax(logits: &[f32]) -> ([u32; 257], usize) {
    debug_assert!(logits.len() >= 256);
    let bytes = &logits[..256];
    let mut max = f32::NEG_INFINITY;
    for &x in bytes {
        max = max.max(x);
    }
    // Perf (EXPERIMENTS.md §Perf L3-1): symbols more than 12 nats below the
    // max would quantize to the 1-count floor anyway; skipping their exp()
    // halves-to-quarters the per-position cost. Deterministic: encoder and
    // decoder run this exact code on identical logits.
    let mut exps = [0.0f32; 256];
    let mut sum = 0.0f32;
    for (i, &x) in bytes.iter().enumerate() {
        let d = x - max;
        if d >= -12.0 {
            let e = d.exp();
            exps[i] = e;
            sum += e;
        }
    }
    let spare = 256u32;
    let budget = (CDF_TOTAL - spare) as f32;
    let inv = 1.0 / sum;
    let mut freqs = [0u32; 256];
    let mut assigned = 0u32;
    let mut argmax = 0usize;
    for i in 0..256 {
        let f = (exps[i] * inv * budget) as u32 + 1;
        freqs[i] = f;
        assigned += f;
        if freqs[i] > freqs[argmax] {
            argmax = i;
        }
    }
    // Deterministic leftover assignment to the most probable symbol.
    freqs[argmax] += CDF_TOTAL - assigned;
    let mut cums = [0u32; 257];
    for i in 0..256 {
        cums[i + 1] = cums[i] + freqs[i];
    }
    debug_assert_eq!(cums[256], CDF_TOTAL);
    (cums, argmax)
}

/// Per-stream entropy-stage encoder behind the codec seam. One instance per
/// stream lane; `push` is called once per coded byte across every context
/// window of the stream, `finish` yields the stream's payload bytes.
///
/// `argmax` is the quantization argmax from [`logits_to_cdf_argmax`] — the
/// range backend ignores it, the rank backend uses it as the rank-0 symbol.
pub trait ChunkEncoder {
    fn push(&mut self, cdf: &[u32; 257], argmax: usize, sym: usize);
    fn finish(self: Box<Self>) -> Result<Vec<u8>>;
}

/// Per-stream entropy-stage decoder (mirror of [`ChunkEncoder`]). `next`
/// yields the symbol coded at the current position given the same CDF the
/// encoder saw; `finish` runs end-of-stream structural checks.
pub trait ChunkDecoder {
    fn next(&mut self, cdf: &[u32; 257], argmax: usize) -> Result<usize>;
    fn finish(&mut self) -> Result<()>;
}

/// The default backend: the adaptive-interval range coder, op-for-op the
/// pre-seam code path so range containers stay byte-identical.
struct RangeChunkEncoder {
    enc: RangeEncoder,
}

impl ChunkEncoder for RangeChunkEncoder {
    #[inline]
    fn push(&mut self, cdf: &[u32; 257], _argmax: usize, sym: usize) {
        self.enc.encode(cdf[sym], cdf[sym + 1] - cdf[sym], CDF_TOTAL);
    }

    fn finish(self: Box<Self>) -> Result<Vec<u8>> {
        Ok(self.enc.finish())
    }
}

struct RangeChunkDecoder<'a> {
    dec: RangeDecoder<'a>,
}

impl ChunkDecoder for RangeChunkDecoder<'_> {
    #[inline]
    fn next(&mut self, cdf: &[u32; 257], _argmax: usize) -> Result<usize> {
        let target = self.dec.decode_freq(CDF_TOTAL);
        let sym = cdf.partition_point(|&c| c <= target) - 1;
        self.dec.decode_update(cdf[sym], cdf[sym + 1] - cdf[sym]);
        Ok(sym)
    }

    fn finish(&mut self) -> Result<()> {
        // The range coder has no end-of-stream structure of its own; the
        // container CRC is the integrity check.
        Ok(())
    }
}

fn new_chunk_encoder(codec: Codec) -> Box<dyn ChunkEncoder> {
    match codec {
        Codec::Range => Box::new(RangeChunkEncoder { enc: RangeEncoder::new() }),
        Codec::Fse => Box::new(FseChunkEncoder::new()),
    }
}

fn new_chunk_decoder(codec: Codec, payload: &[u8]) -> Result<Box<dyn ChunkDecoder + '_>> {
    Ok(match codec {
        Codec::Range => Box::new(RangeChunkDecoder { dec: RangeDecoder::new(payload) }),
        Codec::Fse => Box::new(FseChunkDecoder::new(payload)?),
    })
}

/// Parsed container tag. The grammar, oldest form first:
///
/// - `model:executor_flag` — legacy, f32, range-coded
/// - `model:executor_flag:fse` — f32, FSE rank-coded
/// - `model:executor_flag:q8:<fingerprint-hex>` — int8, range-coded
/// - `model:executor_flag:q8:<fingerprint-hex>:fse` — int8, FSE rank-coded
///
/// Every pre-existing tag keeps its old meaning; the optional trailing
/// `fse` names the entropy backend and is cross-checked against the
/// container's codec flag bit on decode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ContainerTag<'a> {
    pub model: &'a str,
    pub executor: ExecutorKind,
    pub precision: Precision,
    /// Weight-bundle fingerprint; `None` for legacy f32 tags.
    pub fingerprint: Option<u32>,
    /// Entropy backend that coded the payloads (`Range` for legacy tags).
    pub codec: Codec,
}

impl<'a> ContainerTag<'a> {
    /// Parse a container's `model_name` field. Legacy 2-part tags are
    /// f32 + range; `q8` adds precision + fingerprint; a trailing `fse`
    /// names the table-driven rank backend.
    pub fn parse(tag: &'a str) -> Result<ContainerTag<'a>> {
        let parts: Vec<&str> = tag.split(':').collect();
        if !(2..=5).contains(&parts.len()) {
            anyhow::bail!("container missing executor tag");
        }
        let model = parts[0];
        let flag: u16 = parts[1].parse()?;
        let executor = ExecutorKind::from_flag(flag)?;
        let (precision, fingerprint, codec) = match &parts[2..] {
            [] => (Precision::F32, None, Codec::Range),
            ["fse"] => (Precision::F32, None, Codec::Fse),
            [other] => anyhow::bail!("unknown container codec tag '{other}'"),
            [prec, fp] | [prec, fp, "fse"] => {
                if *prec != "q8" {
                    anyhow::bail!("unknown container precision tag '{prec}'");
                }
                let fp = u32::from_str_radix(fp, 16)
                    .map_err(|_| anyhow::anyhow!("bad weight fingerprint '{fp}'"))?;
                let codec = if parts.len() == 5 { Codec::Fse } else { Codec::Range };
                (Precision::Int8, Some(fp), codec)
            }
            [_, _, other] => anyhow::bail!("unknown container codec tag '{other}'"),
            _ => unreachable!("length bounded above"),
        };
        Ok(ContainerTag { model, executor, precision, fingerprint, codec })
    }

    /// True when two tags name the same *model engine* — identical logits
    /// on both ends — ignoring the entropy backend. The codec changes how
    /// the probability stream is serialized, not what the model predicts,
    /// so a server can decode either codec's containers with one engine.
    pub fn same_engine(&self, other: &ContainerTag<'_>) -> bool {
        self.model == other.model
            && self.executor == other.executor
            && self.precision == other.precision
            && self.fingerprint == other.fingerprint
    }
}

/// Render the tag this compressor stamps into containers. F32 range bundles
/// use the legacy 2-part form so f32 container bytes are identical to every
/// earlier release (golden-pinned); quantized bundles add `q8` + the bundle
/// fingerprint; the FSE backend appends its codec name.
fn render_tag(
    model: &str,
    executor: ExecutorKind,
    weights: Option<&Weights>,
    codec: Codec,
) -> String {
    let flag = executor.as_flag();
    let base = match weights.map(|w| w.precision()) {
        None | Some(Precision::F32) => format!("{model}:{flag}"),
        Some(Precision::Int8) => {
            let fp = weights.expect("int8 implies weights").fingerprint();
            format!("{model}:{flag}:q8:{fp:08x}")
        }
    };
    match codec {
        Codec::Range => base,
        Codec::Fse => format!("{base}:fse"),
    }
}

/// Entropy backend a parsed container's payloads were written with,
/// cross-checking the tag's codec suffix against the header flag bits.
/// Used by the coordinator before any engine is in hand; the same check
/// runs inside every compressor decode path.
pub fn container_codec(container: &Container) -> Result<Codec> {
    let tag = ContainerTag::parse(&container.model_name)?;
    let flag_codec = Codec::from_flags(container.flags);
    if tag.codec != flag_codec {
        anyhow::bail!(
            "container tag says codec '{}' but the flag bits say '{}' — corrupt or \
             hand-edited header",
            tag.codec.as_str(),
            flag_codec.as_str()
        );
    }
    Ok(tag.codec)
}

/// Configuration for [`LlmCompressor`].
#[derive(Clone, Debug)]
pub struct LlmCompressorConfig {
    pub model: String,
    /// Context window: the model's context resets every `chunk_tokens`
    /// bytes (the paper's §5.4 "chunk size").
    pub chunk_tokens: usize,
    /// Arithmetic-coder stream granularity: one independent range-coded
    /// payload (and one decode lane) per `stream_bytes` of input. Larger
    /// streams amortize the coder flush + chunk-table overhead (~9 bytes
    /// per stream); smaller streams give finer-grained parallel decode.
    pub stream_bytes: usize,
    pub executor: ExecutorKind,
    /// Native engine lane count (batch width). PJRT engines use the batch
    /// their HLO artifact was lowered with and ignore this.
    pub lanes: usize,
    /// Native engine worker threads; lanes are partitioned across a
    /// persistent worker pool (bit-exact for any value). PJRT engines
    /// ignore this.
    pub threads: usize,
    /// Weight precision contract (native engine only; PJRT is f32). With
    /// `Int8`, an f32 bundle is quantized deterministically at open, the
    /// container tag records precision + bundle fingerprint, and decode
    /// refuses containers whose contract doesn't match.
    pub precision: Precision,
    /// Kernel dispatch tier for the native engine. `None` (default)
    /// resolves at load: the `LLMZIP_FORCE_KERNEL` environment override if
    /// set, else the best tier the CPU supports. `Some(tier)` forces one
    /// programmatically (tests; the CLI `--kernel` flag) and errors at
    /// open if the CPU lacks it. Pure execution knob — containers are
    /// byte-identical across tiers. PJRT engines ignore this.
    pub kernel: Option<KernelTier>,
    /// Build the interleaved-panel weight layout the vector matmuls stream
    /// from (native engine only; default on). Disable on memory-constrained
    /// hosts to save roughly one extra copy of the projection tensors per
    /// loaded model — matmuls then fall back to the strided no-panel
    /// kernels, slower but still bit-identical.
    pub panel_layout: bool,
    /// Entropy backend for *produced* containers. `Range` (default) keeps
    /// every container byte-identical to earlier releases; `Fse` codes the
    /// per-position CDF ranks with a table-driven tANS coder. Decode
    /// accepts either codec regardless of this knob (the container says
    /// which backend wrote it).
    pub codec: Codec,
}

impl Default for LlmCompressorConfig {
    fn default() -> Self {
        LlmCompressorConfig {
            model: "medium".into(),
            chunk_tokens: config::MAX_CONTEXT,
            stream_bytes: 4 * 1024,
            executor: ExecutorKind::PjrtForward,
            lanes: 8,
            threads: 1,
            precision: Precision::F32,
            kernel: None,
            panel_layout: true,
            codec: Codec::Range,
        }
    }
}

/// The LLM-based compressor ("Ours" in Table 5).
pub struct LlmCompressor {
    cfg: LlmCompressorConfig,
    model_cfg: &'static LmConfig,
    /// Tag stamped into every produced container (and matched on decode):
    /// `model:flag` for f32, `model:flag:q8:<fp>` for quantized weights.
    tag: String,
    engine: RefCell<Box<dyn LmExecutor>>,
}

impl LlmCompressor {
    /// Open from an artifact store (PJRT engines) or weights (native).
    pub fn open(store: &ArtifactStore, cfg: LlmCompressorConfig) -> Result<LlmCompressor> {
        let model_cfg = config::by_name(&cfg.model)?;
        if cfg.chunk_tokens == 0 || cfg.chunk_tokens > config::MAX_CONTEXT {
            anyhow::bail!("chunk_tokens must be in 1..={}", config::MAX_CONTEXT);
        }
        if cfg.stream_bytes < cfg.chunk_tokens {
            anyhow::bail!("stream_bytes must be >= chunk_tokens");
        }
        let engine: Box<dyn LmExecutor> = match cfg.executor {
            ExecutorKind::PjrtForward | ExecutorKind::PjrtStep
                if cfg.precision != Precision::F32 =>
            {
                anyhow::bail!(
                    "precision {:?} is supported by the native engine only (PJRT artifacts \
                     are lowered in f32)",
                    cfg.precision
                )
            }
            ExecutorKind::PjrtForward => {
                Box::new(PjrtForwardExecutor::from_store(store, model_cfg)?)
            }
            ExecutorKind::PjrtStep => Box::new(PjrtStepExecutor::from_store(store, model_cfg)?),
            // One construction path for native engines: the store path is
            // just the replica path with a freshly loaded bundle (quantized
            // here if the knob asks for int8), so the head-rows/threads/
            // precision/validation logic cannot drift between them.
            ExecutorKind::Native => {
                let weights = store.weights(model_cfg)?;
                let weights = match (cfg.precision, weights.precision()) {
                    (Precision::Int8, Precision::F32) => weights.quantize(),
                    (Precision::F32, Precision::Int8) => anyhow::bail!(
                        "weights for '{}' are int8-quantized on disk but the compressor asks \
                         for f32 — quantization is not reversible; use precision int8 or the \
                         original f32 .lmz",
                        model_cfg.name
                    ),
                    _ => weights,
                };
                return Self::from_shared(model_cfg, Arc::new(weights), cfg);
            }
        };
        let tag = render_tag(&cfg.model, cfg.executor, None, cfg.codec);
        Ok(LlmCompressor { cfg, model_cfg, tag, engine: RefCell::new(engine) })
    }

    /// Build a native-engine compressor from an explicit config and an
    /// already-shared weight bundle — the coordinator's replica path:
    /// every replica clones the same `Arc<Weights>`, so N replicas cost
    /// one copy of the tensors plus per-replica KV/scratch memory.
    pub fn from_shared(
        model_cfg: &'static LmConfig,
        weights: Arc<Weights>,
        cfg: LlmCompressorConfig,
    ) -> Result<LlmCompressor> {
        Self::from_shared_pooled(model_cfg, weights, cfg, None)
    }

    /// [`Self::from_shared`] with an optional cross-replica [`StepPool`]:
    /// the coordinator's elastic replica pool passes ONE shared pool so
    /// every replica's steps fan lane spans into a common injector and
    /// idle step threads steal sibling replicas' spans. With a pool,
    /// `cfg.threads` is ignored (the pool owns the thread budget); without
    /// one, the engine spawns its private `cfg.threads`-wide pool as
    /// before. Either way the containers are byte-identical — stealing is
    /// a pure execution knob (asserted by `tests/stress_elastic.rs`).
    pub fn from_shared_pooled(
        model_cfg: &'static LmConfig,
        weights: Arc<Weights>,
        cfg: LlmCompressorConfig,
        pool: Option<Arc<StepPool>>,
    ) -> Result<LlmCompressor> {
        if cfg.executor != ExecutorKind::Native {
            anyhow::bail!("from_shared builds native engines only, got {:?}", cfg.executor);
        }
        if cfg.chunk_tokens == 0 || cfg.chunk_tokens > config::MAX_CONTEXT {
            anyhow::bail!("chunk_tokens must be in 1..={}", config::MAX_CONTEXT);
        }
        if cfg.stream_bytes < cfg.chunk_tokens {
            anyhow::bail!("stream_bytes must be >= chunk_tokens");
        }
        // The precision knob is a contract, not a hint: a replica factory
        // handing over a bundle that contradicts it is a config bug, and
        // silently adopting either side would let the two ends of a stream
        // disagree about the logits.
        if cfg.precision != weights.precision() {
            anyhow::bail!(
                "compressor config asks for {:?} but the shared weight bundle is {:?}",
                cfg.precision,
                weights.precision()
            );
        }
        // The tag recorded in containers must name the engine actually
        // built, whatever the caller left in `cfg.model`.
        let mut cfg = cfg;
        cfg.model = model_cfg.name.into();
        let tag = render_tag(&cfg.model, ExecutorKind::Native, Some(&weights), cfg.codec);
        let base = NativeExecutor::with_opts(
            model_cfg,
            weights,
            cfg.lanes.max(1),
            KernelOptions { tier: cfg.kernel, panels: cfg.panel_layout },
        )?;
        let engine = match pool {
            Some(p) => base.with_shared_pool(p),
            None => base.with_threads(cfg.threads.max(1)),
        }
        .with_head_rows(config::CODED_BYTES);
        Ok(LlmCompressor { cfg, model_cfg, tag, engine: RefCell::new(Box::new(engine)) })
    }

    /// Build directly from weights with the native engine (no artifacts/PJRT
    /// required — used by tests and the fallback path). Accepts an owned
    /// `Weights` or an `Arc<Weights>` shared with other replicas; the
    /// precision contract is taken from the bundle itself (pass a
    /// `Weights::quantize()` bundle to build an int8 compressor).
    pub fn from_weights(
        model_cfg: &'static LmConfig,
        weights: impl Into<Arc<Weights>>,
        chunk_tokens: usize,
        lanes: usize,
    ) -> Result<LlmCompressor> {
        if chunk_tokens == 0 || chunk_tokens > config::MAX_CONTEXT {
            anyhow::bail!("chunk_tokens must be in 1..={}", config::MAX_CONTEXT);
        }
        let weights: Arc<Weights> = weights.into();
        let tag = render_tag(model_cfg.name, ExecutorKind::Native, Some(&weights), Codec::Range);
        Ok(LlmCompressor {
            cfg: LlmCompressorConfig {
                model: model_cfg.name.into(),
                chunk_tokens,
                stream_bytes: 4 * chunk_tokens,
                executor: ExecutorKind::Native,
                lanes,
                threads: 1,
                precision: weights.precision(),
                kernel: None,
                panel_layout: true,
                codec: Codec::Range,
            },
            model_cfg,
            tag,
            engine: RefCell::new(Box::new(
                NativeExecutor::new(model_cfg, weights, lanes)
                    .with_head_rows(config::CODED_BYTES),
            )),
        })
    }

    /// Override the arithmetic-coder stream granularity.
    pub fn with_stream_bytes(mut self, stream_bytes: usize) -> Result<LlmCompressor> {
        if stream_bytes < self.cfg.chunk_tokens {
            anyhow::bail!("stream_bytes must be >= chunk_tokens");
        }
        self.cfg.stream_bytes = stream_bytes;
        Ok(self)
    }

    /// Switch the entropy backend for *produced* containers (the tag is
    /// re-rendered to match). Decode is unaffected — it always follows the
    /// container's recorded codec.
    pub fn with_codec(mut self, codec: Codec) -> LlmCompressor {
        let base = self.tag.strip_suffix(":fse").unwrap_or(&self.tag).to_string();
        self.tag = match codec {
            Codec::Range => base,
            Codec::Fse => format!("{base}:fse"),
        };
        self.cfg.codec = codec;
        self
    }

    /// Entropy backend this compressor stamps into produced containers.
    pub fn codec(&self) -> Codec {
        self.cfg.codec
    }

    pub fn stream_bytes(&self) -> usize {
        self.cfg.stream_bytes
    }

    pub fn chunk_tokens(&self) -> usize {
        self.cfg.chunk_tokens
    }

    /// Engine lane count — the coordinator's maximum batch width.
    pub fn lanes(&self) -> usize {
        self.engine.borrow().lanes()
    }

    /// Executor kind tag recorded in containers produced by this compressor.
    pub fn executor_kind(&self) -> ExecutorKind {
        self.engine.borrow().kind()
    }

    /// Kernel dispatch tier the engine resolved at load (diagnostic only;
    /// `"pjrt-hlo"` for lowered engines).
    pub fn kernel_tier(&self) -> &'static str {
        self.engine.borrow().kernel_tier()
    }

    /// Weight precision contract this compressor operates under.
    pub fn precision(&self) -> Precision {
        self.cfg.precision
    }

    /// Model+executor(+precision+fingerprint) tag string stored in
    /// containers.
    pub fn container_tag(&self) -> String {
        self.tag.clone()
    }

    /// Compress one batch of chunks (`chunks.len() <= lanes()`); returns a
    /// payload per chunk. Public for the coordinator's cross-request
    /// batching.
    pub fn compress_chunks(&self, chunks: &[&[u8]]) -> Result<Vec<Vec<u8>>> {
        let mut engine = self.engine.borrow_mut();
        if chunks.len() > engine.lanes() {
            anyhow::bail!("{} chunks > {} lanes", chunks.len(), engine.lanes());
        }
        self.compress_batch(&mut **engine, chunks)
    }

    /// Decompress one batch of chunks (mirror of [`Self::compress_chunks`]).
    /// `codecs` names the entropy backend of each payload — per chunk, so
    /// the coordinator can batch chunks from range and FSE containers into
    /// one lane group.
    pub fn decompress_chunks(
        &self,
        chunk_tokens: usize,
        records: &[ChunkRecord],
        payloads: &[&[u8]],
        codecs: &[Codec],
    ) -> Result<Vec<Vec<u8>>> {
        let mut engine = self.engine.borrow_mut();
        if records.len() > engine.lanes() {
            anyhow::bail!("{} chunks > {} lanes", records.len(), engine.lanes());
        }
        if chunk_tokens == 0 || chunk_tokens > config::MAX_CONTEXT {
            anyhow::bail!("container chunk_tokens {chunk_tokens} out of range");
        }
        self.decompress_batch(&mut **engine, chunk_tokens, records, payloads, codecs)
    }

    pub fn model_config(&self) -> &'static LmConfig {
        self.model_cfg
    }

    /// Compress one batch of streams (one engine lane per stream). Each
    /// stream is split into context windows of `chunk_tokens` bytes (the
    /// model context resets per window) but all windows of a stream share
    /// its entropy coder, amortizing the flush/frame overhead.
    fn compress_batch(
        &self,
        engine: &mut dyn LmExecutor,
        streams: &[&[u8]],
    ) -> Result<Vec<Vec<u8>>> {
        let ct = self.cfg.chunk_tokens;
        let max_len = streams.iter().map(|s| s.len()).max().unwrap_or(0);
        let n_windows = max_len.div_ceil(ct);
        let mut encoders: Vec<Box<dyn ChunkEncoder>> =
            streams.iter().map(|_| new_chunk_encoder(self.cfg.codec)).collect();
        for w in 0..n_windows {
            // Lane input: BOS + window bytes except the last (position t
            // codes byte t, so the final byte is never fed on encode).
            let windows: Vec<&[u8]> = streams
                .iter()
                .map(|s| {
                    let lo = (w * ct).min(s.len());
                    let hi = ((w + 1) * ct).min(s.len());
                    &s[lo..hi]
                })
                .collect();
            let lanes: Vec<Vec<u32>> = windows
                .iter()
                .map(|win| {
                    let mut lane = Vec::with_capacity(win.len());
                    if !win.is_empty() {
                        lane.push(BOS);
                        lane.extend(win[..win.len() - 1].iter().map(|&b| b as u32));
                    }
                    lane
                })
                .collect();
            let n_positions = windows.iter().map(|w| w.len()).max().unwrap_or(0);
            if n_positions == 0 {
                break;
            }
            let logits = engine.encode_logits(&lanes, n_positions)?;
            for (l, win) in windows.iter().enumerate() {
                let enc = &mut encoders[l];
                for (t, &byte) in win.iter().enumerate() {
                    let base = (l * n_positions + t) * VOCAB;
                    let (cdf, argmax) = logits_to_cdf_argmax(&logits[base..base + VOCAB]);
                    enc.push(&cdf, argmax, byte as usize);
                }
            }
        }
        encoders.into_iter().map(|e| e.finish()).collect()
    }

    /// Decompress one batch of streams (lockstep lanes, context reset every
    /// `chunk_tokens` bytes — the mirror of [`Self::compress_batch`]).
    /// Steady state allocates nothing per token: the logits buffer is
    /// reused across every position via [`LmExecutor::step_into`].
    fn decompress_batch(
        &self,
        engine: &mut dyn LmExecutor,
        ct: usize,
        records: &[ChunkRecord],
        payloads: &[&[u8]],
        codecs: &[Codec],
    ) -> Result<Vec<Vec<u8>>> {
        let n_lanes = engine.lanes();
        debug_assert!(records.len() <= n_lanes);
        if codecs.len() != payloads.len() {
            anyhow::bail!("{} codecs for {} payloads", codecs.len(), payloads.len());
        }
        let mut decoders: Vec<Box<dyn ChunkDecoder + '_>> = payloads
            .iter()
            .zip(codecs)
            .map(|(p, &c)| new_chunk_decoder(c, p))
            .collect::<Result<_>>()?;
        let mut outputs: Vec<Vec<u8>> =
            records.iter().map(|r| Vec::with_capacity(r.n_tokens as usize)).collect();
        let n_max = records.iter().map(|r| r.n_tokens as usize).max().unwrap_or(0);
        let n_windows = n_max.div_ceil(ct);
        let mut logits = vec![0.0f32; n_lanes * VOCAB];
        let mut next_feed: Vec<u32> = vec![BOS; n_lanes];
        for w in 0..n_windows {
            engine.reset();
            let w_lo = w * ct;
            let w_hi = (w + 1) * ct;
            let win_max = n_max.min(w_hi) - w_lo;
            // Feed BOS at the window start, then each decoded byte; lanes
            // whose stream is exhausted feed PAD.
            next_feed.fill(BOS);
            for t in 0..win_max {
                engine.step_into(&next_feed, &mut logits)?;
                for (l, rec) in records.iter().enumerate() {
                    if w_lo + t >= rec.n_tokens as usize {
                        next_feed[l] = PAD;
                        continue;
                    }
                    let (cdf, argmax) =
                        logits_to_cdf_argmax(&logits[l * VOCAB..(l + 1) * VOCAB]);
                    let sym = decoders[l].next(&cdf, argmax)?;
                    outputs[l].push(sym as u8);
                    next_feed[l] = sym as u32;
                }
                for lane in records.len()..n_lanes {
                    next_feed[lane] = PAD;
                }
            }
        }
        for dec in &mut decoders {
            dec.finish()?;
        }
        Ok(outputs)
    }

    /// Check a container's tag + window against this compressor's engine;
    /// returns the container's `chunk_tokens` and the codec its payloads
    /// were written with. Shared by every decode entry point (one-shot,
    /// streaming reader, random access) so the model / executor /
    /// precision / fingerprint / codec contract cannot drift between them.
    ///
    /// The codec is NOT required to match `cfg.codec` — the engine contract
    /// covers the logits, and either backend can decode against them. It IS
    /// required to match the container's flag bits (`flags` as read from
    /// the header; 0 for v1 containers, which predate the codec field).
    pub(crate) fn validate_tag_and_window(
        &self,
        model_name: &str,
        chunk_tokens: usize,
        flags: u16,
    ) -> Result<(usize, Codec)> {
        let recorded = ContainerTag::parse(model_name)?;
        let flag_codec = Codec::from_flags(flags);
        if recorded.codec != flag_codec {
            anyhow::bail!(
                "container tag says codec '{}' but the flag bits say '{}' — corrupt or \
                 hand-edited header",
                recorded.codec.as_str(),
                flag_codec.as_str()
            );
        }
        if recorded.model != self.cfg.model {
            anyhow::bail!(
                "container was compressed with model '{}', this compressor uses '{}'",
                recorded.model,
                self.cfg.model
            );
        }
        let kind = self.engine.borrow().kind();
        if !recorded.executor.compatible(kind) {
            anyhow::bail!(
                "container needs executor {:?}, engine is {:?} (streams are only \
                 bit-identical within one executor kind)",
                recorded.executor,
                kind
            );
        }
        // Precision + fingerprint are the weight-bytes contract: a
        // mismatch would decode garbage and die on CRC, so refuse it here
        // with an actionable error instead.
        if recorded.precision != self.cfg.precision {
            anyhow::bail!(
                "container was compressed with {} weights, this compressor runs {} — both \
                 ends must hold the same precision (pass the matching --precision)",
                recorded.precision.as_str(),
                self.cfg.precision.as_str()
            );
        }
        let own = ContainerTag::parse(&self.tag).expect("compressor tag is well-formed");
        if let (Some(want), Some(have)) = (recorded.fingerprint, own.fingerprint) {
            if want != have {
                anyhow::bail!(
                    "quantized weight fingerprint mismatch: container {want:08x} vs engine \
                     {have:08x} — lossless decode requires bit-identical weights on both ends"
                );
            }
        }
        if chunk_tokens == 0 || chunk_tokens > config::MAX_CONTEXT {
            anyhow::bail!("container chunk_tokens {chunk_tokens} out of range");
        }
        Ok((chunk_tokens, recorded.codec))
    }

    fn validate_container(&self, container: &Container) -> Result<(usize, Codec)> {
        self.validate_tag_and_window(
            &container.model_name,
            container.chunk_tokens as usize,
            container.flags,
        )
    }

    /// Decode ONE chunk of a parsed container — random access: only chunk
    /// `i`'s payload goes through the model, everything else is a table
    /// walk. Returns the decoded bytes of that chunk (up to `stream_bytes`
    /// of them). Note the container CRC covers the WHOLE input, so a
    /// partial decode cannot be CRC-verified; the range coder + strict
    /// framing still catch corruption structurally.
    pub fn decode_chunk(&self, container: &Container, i: usize) -> Result<Vec<u8>> {
        let (ct, codec) = self.validate_container(container)?;
        let (rec, payload, _) = container.chunk(i)?;
        let mut engine = self.engine.borrow_mut();
        let decoded =
            self.decompress_batch(&mut **engine, ct, &[rec], &[payload], &[codec])?;
        Ok(decoded.into_iter().next().expect("one chunk in, one chunk out"))
    }

    /// Random-access decode of `len` original bytes starting at `offset`:
    /// only the chunks overlapping `[offset, offset + len)` are decoded.
    /// Equals the same slice of a full [`Compressor::decompress`] (the
    /// per-chunk range coders are independent, so partial decode is exact,
    /// not approximate). Chunks batch across lanes exactly like the full
    /// path.
    ///
    /// v2 slices route through [`SeekableContainer`], so only the header,
    /// the trailer index and the frames the range touches are ever parsed
    /// (v1 has no trailer index and falls back to a full parse).
    pub fn decompress_range(&self, data: &[u8], offset: u64, len: u64) -> Result<Vec<u8>> {
        if data.len() >= 6
            && crate::util::read_u32_le(data, 0) == crate::compress::CONTAINER_MAGIC
            && u16::from_le_bytes([data[4], data[5]]) == crate::compress::CONTAINER_V2
        {
            let cont = SeekableContainer::open(data)?;
            return self.decompress_range_from(&cont, offset, len);
        }
        let container = Container::from_bytes(data)?;
        let (ct, codec) = self.validate_container(&container)?;
        let end = offset
            .checked_add(len)
            .ok_or_else(|| anyhow::anyhow!("range overflows"))?;
        if end > container.orig_len {
            anyhow::bail!(
                "range [{offset}, {end}) exceeds original length {}",
                container.orig_len
            );
        }
        if len == 0 {
            return Ok(Vec::new());
        }
        // Select the chunks the range touches (token offsets are prefix
        // sums over the chunk table — no decoding).
        let mut touched: Vec<(ChunkRecord, &[u8])> = Vec::new();
        let mut first_start = 0u64;
        let mut token_off = 0u64;
        for (rec, payload) in container.iter_chunks() {
            let chunk_end = token_off + rec.n_tokens as u64;
            if chunk_end > offset && token_off < end {
                if touched.is_empty() {
                    first_start = token_off;
                }
                touched.push((rec, payload));
            }
            token_off = chunk_end;
            if token_off >= end {
                break;
            }
        }
        let mut engine = self.engine.borrow_mut();
        let lanes = engine.lanes();
        let mut out = Vec::with_capacity((end - first_start) as usize);
        for group in touched.chunks(lanes) {
            let records: Vec<ChunkRecord> = group.iter().map(|(r, _)| *r).collect();
            let payloads: Vec<&[u8]> = group.iter().map(|(_, p)| *p).collect();
            let codecs = vec![codec; payloads.len()];
            for d in self.decompress_batch(&mut **engine, ct, &records, &payloads, &codecs)? {
                out.extend(d);
            }
        }
        let lo = (offset - first_start) as usize;
        Ok(out[lo..lo + len as usize].to_vec())
    }

    /// Ranged decode over an open [`SeekableContainer`] — the positioned-
    /// read path: frames outside `[offset, offset + len)` are never
    /// fetched from the source, so a small range out of an on-disk
    /// archive reads O(frames-in-range) bytes, not the file.
    pub fn decompress_range_from(
        &self,
        cont: &SeekableContainer<'_>,
        offset: u64,
        len: u64,
    ) -> Result<Vec<u8>> {
        let (ct, codec) = self.validate_tag_and_window(
            cont.model_name(),
            cont.chunk_tokens() as usize,
            cont.flags(),
        )?;
        let touched = cont.chunks_in_range(offset, len)?;
        if touched.is_empty() {
            return Ok(Vec::new());
        }
        let first_start = cont.token_start(touched.start)?;
        let indices: Vec<usize> = touched.collect();
        let mut engine = self.engine.borrow_mut();
        let lanes = engine.lanes();
        let mut out = Vec::with_capacity((offset + len - first_start) as usize);
        for group in indices.chunks(lanes) {
            let records: Vec<ChunkRecord> =
                group.iter().map(|&i| cont.records()[i]).collect();
            let fetched: Vec<Vec<u8>> = group
                .iter()
                .map(|&i| cont.read_chunk_payload(i))
                .collect::<Result<_>>()?;
            let payloads: Vec<&[u8]> = fetched.iter().map(|p| p.as_slice()).collect();
            let codecs = vec![codec; payloads.len()];
            for d in self.decompress_batch(&mut **engine, ct, &records, &payloads, &codecs)? {
                out.extend(d);
            }
        }
        let lo = (offset - first_start) as usize;
        Ok(out[lo..lo + len as usize].to_vec())
    }

    /// Random-access decode of ONE chunk straight off a
    /// [`SeekableContainer`] — the positioned-read twin of
    /// [`Self::decode_chunk`]: exactly one frame is fetched.
    pub fn decode_chunk_from(&self, cont: &SeekableContainer<'_>, i: usize) -> Result<Vec<u8>> {
        let (ct, codec) = self.validate_tag_and_window(
            cont.model_name(),
            cont.chunk_tokens() as usize,
            cont.flags(),
        )?;
        let payload = cont.read_chunk_payload(i)?;
        let rec = cont.records()[i];
        let mut engine = self.engine.borrow_mut();
        let decoded =
            self.decompress_batch(&mut **engine, ct, &[rec], &[payload.as_slice()], &[codec])?;
        Ok(decoded.into_iter().next().expect("one chunk in, one chunk out"))
    }
}

impl Compressor for LlmCompressor {
    fn name(&self) -> &str {
        "llm"
    }

    fn compress(&self, data: &[u8]) -> Result<Vec<u8>> {
        let mut engine = self.engine.borrow_mut();
        let chunks: Vec<&[u8]> = data.chunks(self.cfg.stream_bytes).collect();
        let mut records = Vec::with_capacity(chunks.len());
        let mut payload = Vec::new();
        let lanes = engine.lanes();
        for group in chunks.chunks(lanes) {
            let compressed = self.compress_batch(&mut **engine, group)?;
            for (chunk, comp) in group.iter().zip(compressed) {
                records.push(ChunkRecord {
                    comp_len: comp.len() as u32,
                    n_tokens: chunk.len() as u32,
                });
                payload.extend(comp);
            }
        }
        let container = Container::v2_coded(
            self.cfg.codec,
            data.len() as u64,
            crc32(data),
            self.cfg.chunk_tokens as u32,
            self.tag.clone(),
            records,
            payload,
        );
        Ok(container.to_bytes())
    }

    fn decompress(&self, data: &[u8]) -> Result<Vec<u8>> {
        let container = Container::from_bytes(data)?;
        let (ct, codec) = self.validate_container(&container)?;
        let mut engine = self.engine.borrow_mut();
        let lanes = engine.lanes();
        let all: Vec<(ChunkRecord, &[u8])> = container.iter_chunks().collect();
        let mut out = Vec::with_capacity(container.orig_len as usize);
        for group in all.chunks(lanes) {
            let records: Vec<ChunkRecord> = group.iter().map(|(r, _)| *r).collect();
            let payloads: Vec<&[u8]> = group.iter().map(|(_, p)| *p).collect();
            let codecs = vec![codec; payloads.len()];
            let decoded =
                self.decompress_batch(&mut **engine, ct, &records, &payloads, &codecs)?;
            for d in decoded {
                out.extend(d);
            }
        }
        container.verify(&out)?;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lm::config::by_name;

    fn native_compressor(chunk: usize) -> LlmCompressor {
        let cfg = by_name("nano").unwrap();
        LlmCompressor::from_weights(cfg, Weights::random(cfg, 7), chunk, 2).unwrap()
    }

    #[test]
    fn cdf_is_valid_and_deterministic() {
        let mut rng = crate::util::Pcg64::seeded(1);
        for _ in 0..50 {
            let logits: Vec<f32> =
                (0..VOCAB).map(|_| (rng.gen_f64() * 10.0 - 5.0) as f32).collect();
            let a = logits_to_cdf(&logits);
            let b = logits_to_cdf(&logits);
            assert_eq!(a, b);
            assert_eq!(a[0], 0);
            assert_eq!(a[256], CDF_TOTAL);
            for w in a.windows(2) {
                assert!(w[1] > w[0], "every byte must have freq >= 1");
            }
        }
    }

    #[test]
    fn cdf_tracks_probabilities() {
        let mut logits = vec![0.0f32; VOCAB];
        logits[65] = 10.0;
        let cdf = logits_to_cdf(&logits);
        let freq_a = cdf[66] - cdf[65];
        assert!(freq_a > CDF_TOTAL * 9 / 10, "dominant symbol gets most mass: {freq_a}");
    }

    #[test]
    fn roundtrip_with_native_engine() {
        let c = native_compressor(32);
        for data in [
            b"".to_vec(),
            b"a".to_vec(),
            b"hello world".to_vec(),
            crate::textgen::quick_sample(500, 3),
        ] {
            let z = c.compress(&data).unwrap();
            assert_eq!(c.decompress(&z).unwrap(), data, "len {}", data.len());
        }
    }

    #[test]
    fn roundtrip_multi_batch_chunks() {
        // 5 chunks across 2 lanes -> 3 lane batches, uneven tail.
        let c = native_compressor(16);
        let data = crate::textgen::quick_sample(75, 4);
        let z = c.compress(&data).unwrap();
        assert_eq!(c.decompress(&z).unwrap(), data);
    }

    /// Compressor with an explicitly threaded native engine (mirrors the
    /// `open` construction path, which tests cannot reach without PJRT
    /// artifacts).
    fn threaded_compressor(chunk: usize, lanes: usize, threads: usize) -> LlmCompressor {
        let cfg = by_name("nano").unwrap();
        LlmCompressor::from_shared(
            cfg,
            Arc::new(Weights::random(cfg, 7)),
            LlmCompressorConfig {
                model: cfg.name.into(),
                chunk_tokens: chunk,
                stream_bytes: 4 * chunk,
                executor: ExecutorKind::Native,
                lanes,
                threads,
                precision: Precision::F32,
                ..Default::default()
            },
        )
        .unwrap()
    }

    #[test]
    fn threaded_native_engine_produces_identical_containers() {
        // threads is a pure execution knob: containers are bit-identical
        // and cross-decodable for any thread count.
        let data = crate::textgen::quick_sample(300, 6);
        let single = native_compressor(32);
        let threaded = threaded_compressor(32, 2, 2);
        let z1 = single.compress(&data).unwrap();
        let z2 = threaded.compress(&data).unwrap();
        assert_eq!(z1, z2, "containers must not depend on the thread count");
        assert_eq!(threaded.decompress(&z1).unwrap(), data);
        assert_eq!(single.decompress(&z2).unwrap(), data);
    }

    #[test]
    fn shared_weight_replicas_emit_identical_containers() {
        // Two replicas over ONE Arc<Weights> (the coordinator's replica
        // path) and an owned-weights compressor all produce the same bytes
        // and cross-decode.
        let cfg = by_name("nano").unwrap();
        let shared = Arc::new(Weights::random(cfg, 7));
        let replica_cfg = LlmCompressorConfig {
            model: cfg.name.into(),
            chunk_tokens: 32,
            stream_bytes: 128,
            executor: ExecutorKind::Native,
            lanes: 2,
            threads: 2,
            precision: Precision::F32,
            ..Default::default()
        };
        let a = LlmCompressor::from_shared(cfg, shared.clone(), replica_cfg.clone()).unwrap();
        let b = LlmCompressor::from_shared(cfg, shared.clone(), replica_cfg).unwrap();
        let owned = native_compressor(32);
        let data = crate::textgen::quick_sample(300, 8);
        let za = a.compress(&data).unwrap();
        assert_eq!(za, b.compress(&data).unwrap());
        assert_eq!(za, owned.compress(&data).unwrap());
        assert_eq!(b.decompress(&za).unwrap(), data);
        // PJRT configs are rejected: sharing host weights cannot build one.
        let pjrt = LlmCompressorConfig { executor: ExecutorKind::PjrtStep, ..Default::default() };
        assert!(LlmCompressor::from_shared(cfg, shared, pjrt).is_err());
    }

    #[test]
    fn shared_pool_compressors_emit_identical_containers() {
        // Two replicas fanning steps into ONE work-stealing StepPool (the
        // elastic coordinator's configuration) produce the same bytes as
        // the plain single-threaded compressor, and cross-decode.
        let cfg = by_name("nano").unwrap();
        let shared = Arc::new(Weights::random(cfg, 7));
        let pool = StepPool::new(2);
        let replica_cfg = LlmCompressorConfig {
            model: cfg.name.into(),
            chunk_tokens: 32,
            stream_bytes: 128,
            executor: ExecutorKind::Native,
            lanes: 2,
            threads: 1,
            precision: Precision::F32,
            ..Default::default()
        };
        let a = LlmCompressor::from_shared_pooled(
            cfg,
            shared.clone(),
            replica_cfg.clone(),
            Some(pool.clone()),
        )
        .unwrap();
        let b =
            LlmCompressor::from_shared_pooled(cfg, shared.clone(), replica_cfg, Some(pool))
                .unwrap();
        let plain = native_compressor(32);
        let data = crate::textgen::quick_sample(300, 9);
        let za = a.compress(&data).unwrap();
        assert_eq!(za, b.compress(&data).unwrap());
        assert_eq!(za, plain.compress(&data).unwrap(), "stealing must not change the bytes");
        assert_eq!(b.decompress(&za).unwrap(), data);
        assert_eq!(plain.decompress(&za).unwrap(), data);
        // PJRT configs are still rejected on the pooled path.
        let pjrt = LlmCompressorConfig { executor: ExecutorKind::PjrtStep, ..Default::default() };
        assert!(LlmCompressor::from_shared_pooled(cfg, shared, pjrt, None).is_err());
    }

    #[test]
    fn wrong_model_or_executor_rejected() {
        let c = native_compressor(32);
        let data = b"some test data".to_vec();
        let mut z = c.compress(&data).unwrap();
        // Flip the recorded executor flag: native(0) -> pjrt-step(1).
        let mut cont = Container::from_bytes(&z).unwrap();
        cont.model_name = "nano:1".into();
        z = cont.to_bytes();
        let err = c.decompress(&z).unwrap_err().to_string();
        assert!(err.contains("executor"), "{err}");
        let mut cont = Container::from_bytes(&c.compress(&data).unwrap()).unwrap();
        cont.model_name = "tiny:0".into();
        assert!(c.decompress(&cont.to_bytes()).is_err());
    }

    #[test]
    fn corrupted_payload_fails_crc() {
        let c = native_compressor(32);
        let data = crate::textgen::quick_sample(200, 5);
        let z = c.compress(&data).unwrap();
        let mut cont = Container::from_bytes(&z).unwrap();
        let n = cont.payload.len();
        cont.payload[n / 2] ^= 0x40;
        assert!(c.decompress(&cont.to_bytes()).is_err());
    }

    #[test]
    fn chunk_bounds_validated() {
        let cfg = by_name("nano").unwrap();
        assert!(LlmCompressor::from_weights(cfg, Weights::random(cfg, 8), 0, 1).is_err());
        assert!(LlmCompressor::from_weights(cfg, Weights::random(cfg, 8), 10_000, 1).is_err());
    }

    /// Int8 compressor over the deterministic quantization of seed-7 nano
    /// weights (the same source bundle `native_compressor` uses in f32).
    fn int8_compressor(chunk: usize, lanes: usize, threads: usize) -> LlmCompressor {
        let cfg = by_name("nano").unwrap();
        let weights = Arc::new(Weights::random(cfg, 7).quantize());
        LlmCompressor::from_shared(
            cfg,
            weights,
            LlmCompressorConfig {
                model: cfg.name.into(),
                chunk_tokens: chunk,
                stream_bytes: 4 * chunk,
                executor: ExecutorKind::Native,
                lanes,
                threads,
                precision: Precision::Int8,
                ..Default::default()
            },
        )
        .unwrap()
    }

    #[test]
    fn tag_parse_roundtrip_and_legacy_f32() {
        let legacy = ContainerTag::parse("nano:0").unwrap();
        assert_eq!(legacy.model, "nano");
        assert_eq!(legacy.executor, ExecutorKind::Native);
        assert_eq!(legacy.precision, Precision::F32);
        assert_eq!(legacy.fingerprint, None);
        let q8 = ContainerTag::parse("medium:0:q8:deadbeef").unwrap();
        assert_eq!(q8.precision, Precision::Int8);
        assert_eq!(q8.fingerprint, Some(0xDEADBEEF));
        assert!(ContainerTag::parse("untagged").is_err());
        assert!(ContainerTag::parse("nano:0:fp16:00000000").is_err());
        assert!(ContainerTag::parse("nano:0:q8:zzzz").is_err());
    }

    #[test]
    fn int8_roundtrip_lossless_on_every_textgen_domain() {
        // The acceptance bar for the quantized path: precision changes the
        // probability stream, not the losslessness.
        let c = int8_compressor(32, 2, 1);
        assert!(c.container_tag().starts_with("nano:0:q8:"), "{}", c.container_tag());
        for domain in crate::textgen::Domain::EVAL {
            let data = crate::textgen::generate(domain, 400, 11);
            let z = c.compress(&data).unwrap();
            assert_eq!(c.decompress(&z).unwrap(), data, "{domain:?}");
        }
        for data in [b"".to_vec(), b"a".to_vec(), (0u8..=255).collect()] {
            let z = c.compress(&data).unwrap();
            assert_eq!(c.decompress(&z).unwrap(), data, "len {}", data.len());
        }
    }

    #[test]
    fn int8_containers_identical_across_threads_and_lanes() {
        let data = crate::textgen::quick_sample(500, 13);
        let base = int8_compressor(32, 2, 1);
        let golden = base.compress(&data).unwrap();
        for (lanes, threads) in [(1usize, 1usize), (2, 2), (4, 3)] {
            let c = int8_compressor(32, lanes, threads);
            assert_eq!(
                c.compress(&data).unwrap(),
                golden,
                "lanes={lanes} threads={threads} must not change the bytes"
            );
            assert_eq!(c.decompress(&golden).unwrap(), data);
        }
    }

    #[test]
    fn precision_mismatch_rejected_with_clear_error_not_crc() {
        let data = crate::textgen::quick_sample(200, 14);
        let f32c = native_compressor(32);
        let q8c = int8_compressor(32, 2, 1);
        // Same source weights, opposite precision on the decode side.
        let z8 = q8c.compress(&data).unwrap();
        let err = f32c.decompress(&z8).unwrap_err().to_string();
        assert!(err.contains("precision"), "{err}");
        assert!(!err.contains("CRC"), "{err}");
        let zf = f32c.compress(&data).unwrap();
        let err = q8c.decompress(&zf).unwrap_err().to_string();
        assert!(err.contains("precision"), "{err}");
    }

    #[test]
    fn fingerprint_mismatch_rejected_with_clear_error_not_crc() {
        let data = crate::textgen::quick_sample(200, 15);
        let q8c = int8_compressor(32, 2, 1);
        let mut cont = Container::from_bytes(&q8c.compress(&data).unwrap()).unwrap();
        let (head, _) = cont.model_name.rsplit_once(':').unwrap();
        cont.model_name = format!("{head}:0bad0bad");
        let err = q8c.decompress(&cont.to_bytes()).unwrap_err().to_string();
        assert!(err.contains("fingerprint"), "{err}");
        assert!(!err.contains("CRC"), "{err}");
    }

    #[test]
    fn from_shared_enforces_the_precision_contract() {
        let cfg = by_name("nano").unwrap();
        let f32_w = Arc::new(Weights::random(cfg, 7));
        let cfg8 = LlmCompressorConfig {
            model: cfg.name.into(),
            executor: ExecutorKind::Native,
            precision: Precision::Int8,
            chunk_tokens: 32,
            stream_bytes: 128,
            lanes: 1,
            threads: 1,
            ..Default::default()
        };
        assert!(LlmCompressor::from_shared(cfg, f32_w.clone(), cfg8.clone()).is_err());
        let q8_w = Arc::new(f32_w.quantize());
        assert!(LlmCompressor::from_shared(cfg, q8_w.clone(), cfg8).is_ok());
        let cfg32 = LlmCompressorConfig {
            model: cfg.name.into(),
            executor: ExecutorKind::Native,
            precision: Precision::F32,
            chunk_tokens: 32,
            stream_bytes: 128,
            lanes: 1,
            threads: 1,
            ..Default::default()
        };
        assert!(LlmCompressor::from_shared(cfg, q8_w, cfg32).is_err());
    }

    #[test]
    fn int8_ratio_stays_in_the_same_ballpark_as_f32() {
        // Quantization perturbs the model, not the coder: the compressed
        // size on model-friendly text must stay within a modest factor of
        // the f32 size (a badly-broken kernel would blow this up).
        let data = crate::textgen::quick_sample(2000, 16);
        let zf = native_compressor(64).compress(&data).unwrap().len() as f64;
        let z8 = int8_compressor(64, 2, 1).compress(&data).unwrap().len() as f64;
        assert!(z8 < zf * 1.5, "int8 {z8} bytes vs f32 {zf} bytes");
    }

    #[test]
    fn fse_tag_grammar_parses_and_rejects() {
        let fse = ContainerTag::parse("nano:0:fse").unwrap();
        assert_eq!(fse.codec, Codec::Fse);
        assert_eq!(fse.precision, Precision::F32);
        assert_eq!(ContainerTag::parse("nano:0").unwrap().codec, Codec::Range);
        let q8_fse = ContainerTag::parse("medium:0:q8:deadbeef:fse").unwrap();
        assert_eq!(q8_fse.codec, Codec::Fse);
        assert_eq!(q8_fse.precision, Precision::Int8);
        assert_eq!(q8_fse.fingerprint, Some(0xDEADBEEF));
        // Range and fse tags for one engine differ only in the suffix.
        let range = ContainerTag::parse("medium:0:q8:deadbeef").unwrap();
        assert!(range.same_engine(&q8_fse));
        assert!(!range.same_engine(&fse));
        for bad in ["nano:0:xyz", "nano:0:q8:deadbeef:xyz", "nano:0:q8:deadbeef:fse:extra"] {
            assert!(ContainerTag::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn fse_roundtrip_with_native_engine() {
        let c = native_compressor(32).with_codec(Codec::Fse);
        assert!(c.container_tag().ends_with(":fse"), "{}", c.container_tag());
        for data in [
            b"".to_vec(),
            b"a".to_vec(),
            b"hello world".to_vec(),
            (0u8..=255).collect(),
            crate::textgen::quick_sample(500, 3),
        ] {
            let z = c.compress(&data).unwrap();
            let cont = Container::from_bytes(&z).unwrap();
            assert_eq!(Codec::from_flags(cont.flags), Codec::Fse);
            assert_eq!(c.decompress(&z).unwrap(), data, "len {}", data.len());
        }
    }

    #[test]
    fn fse_containers_identical_across_threads_lanes_and_pool() {
        // The fse path inherits the byte-identity spine: each stream is
        // rank-transformed and table-coded in exactly one lane, so the
        // container cannot depend on execution shape.
        let data = crate::textgen::quick_sample(500, 13);
        let golden = threaded_compressor(32, 2, 1).with_codec(Codec::Fse).compress(&data).unwrap();
        for (lanes, threads) in [(1usize, 1usize), (2, 2), (4, 3)] {
            let c = threaded_compressor(32, lanes, threads).with_codec(Codec::Fse);
            assert_eq!(
                c.compress(&data).unwrap(),
                golden,
                "lanes={lanes} threads={threads} must not change the fse bytes"
            );
            assert_eq!(c.decompress(&golden).unwrap(), data);
        }
        let cfg = by_name("nano").unwrap();
        let shared = Arc::new(Weights::random(cfg, 7));
        let pool = StepPool::new(2);
        let replica_cfg = LlmCompressorConfig {
            model: cfg.name.into(),
            chunk_tokens: 32,
            stream_bytes: 128,
            executor: ExecutorKind::Native,
            lanes: 2,
            threads: 1,
            precision: Precision::F32,
            codec: Codec::Fse,
            ..Default::default()
        };
        let pooled =
            LlmCompressor::from_shared_pooled(cfg, shared, replica_cfg, Some(pool)).unwrap();
        assert_eq!(pooled.compress(&data).unwrap(), golden, "stealing must not change the bytes");
    }

    #[test]
    fn codecs_cross_decode_but_produce_different_streams() {
        // Decompression follows the CONTAINER's recorded codec, not the
        // decoder's configured one — a range-configured compressor decodes
        // fse containers from the same engine, and vice versa.
        let data = crate::textgen::quick_sample(400, 17);
        let range_c = native_compressor(32);
        let fse_c = native_compressor(32).with_codec(Codec::Fse);
        let zr = range_c.compress(&data).unwrap();
        let zf = fse_c.compress(&data).unwrap();
        assert_ne!(zr, zf, "the two backends cannot emit the same container");
        assert_eq!(range_c.decompress(&zf).unwrap(), data);
        assert_eq!(fse_c.decompress(&zr).unwrap(), data);
        // Seekable faces stay codec-agnostic on the fse container.
        let slice = fse_c.decompress_range(&zf, 40, 100).unwrap();
        assert_eq!(slice, data[40..140]);
        let cont = Container::from_bytes(&zf).unwrap();
        assert_eq!(fse_c.decode_chunk(&cont, 1).unwrap(), data[32..64]);
    }

    #[test]
    fn fse_int8_roundtrip_and_tag() {
        let c = int8_compressor(32, 2, 1).with_codec(Codec::Fse);
        let tag = c.container_tag();
        assert!(tag.starts_with("nano:0:q8:") && tag.ends_with(":fse"), "{tag}");
        let data = crate::textgen::quick_sample(400, 11);
        let z = c.compress(&data).unwrap();
        assert_eq!(c.decompress(&z).unwrap(), data);
    }

    #[test]
    fn fse_corrupted_payload_fails_crc_not_panic() {
        let c = native_compressor(32).with_codec(Codec::Fse);
        let data = crate::textgen::quick_sample(200, 5);
        let z = c.compress(&data).unwrap();
        let mut cont = Container::from_bytes(&z).unwrap();
        let n = cont.payload.len();
        cont.payload[n / 2] ^= 0x40;
        assert!(c.decompress(&cont.to_bytes()).is_err());
    }

    #[test]
    fn codec_flag_and_tag_must_agree() {
        // A container whose tag says fse but whose flag bits say range (or
        // the reverse) is refused as corrupt, not silently mis-decoded.
        let c = native_compressor(32);
        let data = crate::textgen::quick_sample(100, 6);
        let mut cont = Container::from_bytes(&c.compress(&data).unwrap()).unwrap();
        cont.model_name = format!("{}:fse", cont.model_name);
        let err = c.decompress(&cont.to_bytes()).unwrap_err().to_string();
        assert!(err.contains("flag bits"), "{err}");
    }
}
