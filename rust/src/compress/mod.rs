//! Core compression API.
//!
//! [`Compressor`] is the interface every method in the paper's Table 5
//! implements — the three entropy coders, the three dictionary coders, the
//! three neural-simulation coders (see [`crate::baselines`]) and the paper's
//! contribution, [`LlmCompressor`].
//!
//! The buffer-to-buffer trait is the batch face; [`stream`] adds the
//! incremental one: [`CompressWriter`]/[`DecompressReader`] wrap an
//! [`LlmCompressor`] behind `std::io::{Write, Read}` with bounded memory
//! and byte-identical output, over the [`container`] v2 framed layout.

pub mod container;
pub mod llm;
pub mod rank;
pub mod registry;
pub mod source;
pub mod stream;

pub use container::{
    ChunkRecord, Codec, Container, CONTAINER_MAGIC, CONTAINER_V1, CONTAINER_V2,
};
pub use llm::{ContainerTag, LlmCompressor, LlmCompressorConfig};
pub use registry::{all_baseline_names, baseline_by_name, ModelRegistry, ModelRoute};
pub use source::{ContainerSource, FileSource, SeekableContainer};
pub use stream::{CompressWriter, DecompressReader, StreamSummary};

use crate::Result;

/// A lossless byte-stream compressor.
///
/// NOTE: not `Send`/`Sync` — the PJRT-backed implementation wraps
/// thread-affine FFI handles. The coordinator owns its compressor inside a
/// single worker thread; cross-thread access goes through channels.
pub trait Compressor {
    /// Short stable identifier (used by the CLI and benches), e.g. `"gzip"`.
    fn name(&self) -> &str;

    /// Compress `data` into a self-describing buffer.
    fn compress(&self, data: &[u8]) -> Result<Vec<u8>>;

    /// Invert [`Self::compress`]. Must reproduce `data` exactly.
    fn decompress(&self, data: &[u8]) -> Result<Vec<u8>>;

    /// Convenience: compression ratio on `data` (original / compressed).
    fn ratio(&self, data: &[u8]) -> Result<f64> {
        let c = self.compress(data)?;
        Ok(data.len() as f64 / c.len().max(1) as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Identity;
    impl Compressor for Identity {
        fn name(&self) -> &str {
            "identity"
        }
        fn compress(&self, data: &[u8]) -> Result<Vec<u8>> {
            Ok(data.to_vec())
        }
        fn decompress(&self, data: &[u8]) -> Result<Vec<u8>> {
            Ok(data.to_vec())
        }
    }

    #[test]
    fn ratio_default_impl() {
        let c = Identity;
        assert!((c.ratio(&[0u8; 100]).unwrap() - 1.0).abs() < 1e-12);
    }
}
