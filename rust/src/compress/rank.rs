//! Table-driven rank coding: the FSE/tANS entropy backend.
//!
//! The range backend codes each byte against its full 257-entry CDF with
//! two 32-bit divisions per symbol. This backend splits that work in two:
//!
//! 1. **Rank transform** — replace each byte by its *rank* under the
//!    deterministic ordering `(quantized freq desc, byte index asc)` of the
//!    position's CDF. A well-predicted stream maps overwhelmingly to rank 0
//!    (the model's argmax), with a geometric tail — exactly the shape a
//!    static table-driven coder handles at peak throughput.
//! 2. **tANS coding** — one normalized histogram of the chunk's ranks,
//!    serialized in the frame, drives a [`FseTable`] built once per chunk;
//!    decode is a pure table walk (no per-symbol adaptation, no division).
//!
//! Ranks `0..RANK_ESCAPE` are direct tANS symbols; rarer ranks go through
//! an escape symbol plus a raw literal byte, keeping the alphabet (and the
//! serialized histogram) small without giving up losslessness.
//!
//! Determinism: the rank of a byte is a pure function of the quantized CDF,
//! which both ends compute from identical logits (the same precision/kernel
//! contract the range backend relies on — see `docs/entropy.md`). The
//! ordering is a total order, so encode and decode agree on every rank even
//! under frequency ties.
//!
//! ## Frame layout (one frame per stream payload)
//!
//! ```text
//! table_log  u8            tANS table log (encoder emits 10, or 12 on
//!                          normalization underflow; decoder caps at 12)
//! alphabet   u8            highest coded symbol + 1 (1..=65)
//! fse_len    u32 LE        tANS bitstream length in bytes
//! state      u32 LE        initial decoder state, in [2^tl, 2^{tl+1})
//! norm       alphabet * u16 LE   normalized freqs, sum == 1 << table_log
//! fse        [fse_len]     tANS bitstream (decoded forwards)
//! escapes    rest          raw rank literals (>= RANK_ESCAPE), in position
//!                          order, one per escape symbol in the tANS stream
//! ```
//!
//! An empty stream (zero coded bytes) serializes to an empty payload.
//!
//! Corruption policy: framing, histogram sum, state range, escape
//! canonicality (literals must be `>= RANK_ESCAPE`) and escape accounting
//! are hard errors here; a bit flip *inside* the tANS bitstream decodes to
//! some wrong-but-well-formed rank sequence (the final decode step
//! legitimately reads past the written bits into the writer's zero padding,
//! so overrun is not a usable signal) and is caught by the container CRC,
//! exactly like a flipped range-coder payload.

use crate::compress::llm::{ChunkDecoder, ChunkEncoder};
use crate::entropy::fse::{self, normalize_freqs, pack_norm, unpack_norm, FseTable};
use crate::entropy::BitReader;
use crate::util::read_u32_le;
use crate::Result;

/// Ranks below this are direct tANS symbols; this value itself is the
/// escape symbol (so the alphabet is at most `RANK_ESCAPE + 1` wide).
pub const RANK_ESCAPE: usize = 64;

/// Table log the encoder prefers (1024 states — the rank alphabet is at
/// most 65 wide, so this is plenty of resolution at a quarter of the
/// Zstd-default table's cache footprint).
pub const RANK_TABLE_LOG: u32 = 10;

/// Fallback table log when normalization to [`RANK_TABLE_LOG`] underflows
/// (possible only for near-flat rank histograms over the full alphabet).
/// Proven sufficient: with `n` nonzero symbols of 65, rounding can
/// overshoot by at most `n * (65 - n) <= 1056 < 4096 - 65` slots, so the
/// most frequent symbol always keeps a positive count at log 12.
pub const RANK_TABLE_LOG_WIDE: u32 = 12;

const FRAME_FIXED: usize = 10; // table_log + alphabet + fse_len + state

/// Serialize a chunk's rank stream into one self-describing frame.
pub fn encode_rank_stream(ranks: &[u8]) -> Result<Vec<u8>> {
    if ranks.is_empty() {
        return Ok(Vec::new());
    }
    let mut symbols = Vec::with_capacity(ranks.len());
    let mut escapes = Vec::new();
    for &r in ranks {
        if (r as usize) < RANK_ESCAPE {
            symbols.push(r as usize);
        } else {
            symbols.push(RANK_ESCAPE);
            escapes.push(r);
        }
    }
    let alphabet = symbols.iter().copied().max().expect("non-empty") + 1;
    let mut counts = vec![0u64; alphabet];
    for &s in &symbols {
        counts[s] += 1;
    }
    // Deterministic table-log selection: prefer the small table, fall back
    // to the wide one when the histogram is too flat for it. The chosen log
    // travels in the frame, so the decoder never re-derives this choice.
    let (norm, table_log) = match normalize_freqs(&counts, RANK_TABLE_LOG) {
        Ok(n) => (n, RANK_TABLE_LOG),
        Err(_) => (normalize_freqs(&counts, RANK_TABLE_LOG_WIDE)?, RANK_TABLE_LOG_WIDE),
    };
    let table = FseTable::new(&norm, table_log)?;
    let (state, payload) = fse::encode_all(&table, &symbols);
    let mut out = Vec::with_capacity(FRAME_FIXED + 2 * alphabet + payload.len() + escapes.len());
    out.push(table_log as u8);
    out.push(alphabet as u8);
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&state.to_le_bytes());
    out.extend_from_slice(&pack_norm(&norm));
    out.extend_from_slice(&payload);
    out.extend_from_slice(&escapes);
    Ok(out)
}

/// Streaming decoder over one serialized rank frame: header parsed and
/// table built once at construction, then [`Self::next_rank`] is a pure
/// decode-table walk.
pub struct RankStreamDecoder<'a> {
    table: Option<FseTable>,
    reader: BitReader<'a>,
    state: u32,
    escapes: &'a [u8],
    escape_pos: usize,
}

impl<'a> RankStreamDecoder<'a> {
    pub fn new(payload: &'a [u8]) -> Result<Self> {
        if payload.is_empty() {
            // Valid for a zero-length stream; any decode attempt errors.
            return Ok(RankStreamDecoder {
                table: None,
                reader: BitReader::new(&[]),
                state: 0,
                escapes: &[],
                escape_pos: 0,
            });
        }
        if payload.len() < FRAME_FIXED {
            anyhow::bail!("truncated rank frame header");
        }
        let table_log = payload[0] as u32;
        if table_log == 0 || table_log > RANK_TABLE_LOG_WIDE {
            anyhow::bail!("corrupt rank frame: table_log {table_log} out of range (1..=12)");
        }
        let alphabet = payload[1] as usize;
        if alphabet == 0 || alphabet > RANK_ESCAPE + 1 {
            anyhow::bail!("corrupt rank frame: alphabet {alphabet} out of range (1..=65)");
        }
        let fse_len = read_u32_le(payload, 2) as usize;
        let state = read_u32_le(payload, 6);
        let norm_end = FRAME_FIXED + 2 * alphabet;
        if payload.len() < norm_end {
            anyhow::bail!("truncated rank frame: frequency table cut short");
        }
        let norm = unpack_norm(&payload[FRAME_FIXED..norm_end], alphabet, table_log)?;
        let table = FseTable::new(&norm, table_log)?;
        let table_size = 1u32 << table_log;
        if state < table_size || state >= 2 * table_size {
            anyhow::bail!("corrupt rank frame: initial state {state} out of range");
        }
        let Some(fse_end) = norm_end.checked_add(fse_len) else {
            anyhow::bail!("corrupt rank frame: bitstream length overflows");
        };
        if payload.len() < fse_end {
            anyhow::bail!("truncated rank frame: bitstream cut short");
        }
        Ok(RankStreamDecoder {
            table: Some(table),
            reader: BitReader::new(&payload[norm_end..fse_end]),
            state,
            escapes: &payload[fse_end..],
            escape_pos: 0,
        })
    }

    /// Decode the next rank (one decode-table walk, plus an escape-literal
    /// fetch for ranks `>= RANK_ESCAPE`).
    pub fn next_rank(&mut self) -> Result<u8> {
        let Some(table) = &self.table else {
            anyhow::bail!("rank stream underrun: empty frame decoded past its end");
        };
        let (sym, next) = table.decode_step(self.state, &mut self.reader);
        self.state = next;
        if sym < RANK_ESCAPE {
            return Ok(sym as u8);
        }
        let Some(&lit) = self.escapes.get(self.escape_pos) else {
            anyhow::bail!("rank stream underrun: escape literal missing");
        };
        self.escape_pos += 1;
        if (lit as usize) < RANK_ESCAPE {
            anyhow::bail!("non-canonical rank escape literal {lit} (< {RANK_ESCAPE})");
        }
        Ok(lit)
    }

    /// End-of-stream structural check: every escape literal the frame
    /// carried must have been claimed by an escape symbol.
    pub fn finish(&mut self) -> Result<()> {
        if self.escape_pos != self.escapes.len() {
            anyhow::bail!(
                "rank frame carries {} escape literals but only {} were consumed",
                self.escapes.len(),
                self.escape_pos
            );
        }
        Ok(())
    }
}

/// One-shot inverse of [`encode_rank_stream`]: decode exactly `n` ranks
/// and run the end-of-stream checks (tests and fuzzing).
pub fn decode_rank_stream(payload: &[u8], n: usize) -> Result<Vec<u8>> {
    let mut dec = RankStreamDecoder::new(payload)?;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(dec.next_rank()?);
    }
    dec.finish()?;
    Ok(out)
}

/// [`ChunkEncoder`] for the FSE backend: buffers the stream's ranks (one
/// byte each) across every context window, then serializes a single frame
/// at finish — mirroring how the range backend amortizes its flush.
pub struct FseChunkEncoder {
    ranks: Vec<u8>,
}

impl FseChunkEncoder {
    pub fn new() -> Self {
        FseChunkEncoder { ranks: Vec::new() }
    }
}

impl Default for FseChunkEncoder {
    fn default() -> Self {
        Self::new()
    }
}

impl ChunkEncoder for FseChunkEncoder {
    #[inline]
    fn push(&mut self, cdf: &[u32; 257], argmax: usize, sym: usize) {
        self.ranks.push(rank_of(cdf, argmax, sym));
    }

    fn finish(self: Box<Self>) -> Result<Vec<u8>> {
        encode_rank_stream(&self.ranks)
    }
}

/// [`ChunkDecoder`] for the FSE backend: rank off the table walk, byte via
/// the CDF's deterministic rank order.
pub struct FseChunkDecoder<'a> {
    inner: RankStreamDecoder<'a>,
}

impl<'a> FseChunkDecoder<'a> {
    pub fn new(payload: &'a [u8]) -> Result<Self> {
        Ok(FseChunkDecoder { inner: RankStreamDecoder::new(payload)? })
    }
}

impl ChunkDecoder for FseChunkDecoder<'_> {
    #[inline]
    fn next(&mut self, cdf: &[u32; 257], argmax: usize) -> Result<usize> {
        let rank = self.inner.next_rank()?;
        Ok(byte_of_rank(cdf, argmax, rank) as usize)
    }

    fn finish(&mut self) -> Result<()> {
        self.inner.finish()
    }
}

/// Rank of byte `sym` under the ordering `(quantized freq desc, index
/// asc)` of the CDF's 256 frequencies. `argmax` must be the quantization
/// argmax from `logits_to_cdf_argmax` — it is the unique rank-0 element
/// (first index of maximal frequency), giving the hot path an O(1) exit;
/// other symbols cost one pass over the 256 frequencies.
#[inline]
pub fn rank_of(cdf: &[u32; 257], argmax: usize, sym: usize) -> u8 {
    if sym == argmax {
        return 0;
    }
    let fs = cdf[sym + 1] - cdf[sym];
    let mut rank = 0u32;
    for j in 0..256 {
        let fj = cdf[j + 1] - cdf[j];
        if fj > fs || (fj == fs && j < sym) {
            rank += 1;
        }
    }
    debug_assert!(rank >= 1, "only the argmax has rank 0");
    rank as u8
}

/// Inverse of [`rank_of`]: the byte holding rank `rank` under the same
/// total order. Rank 0 is the argmax (O(1), the overwhelmingly common
/// case); deeper ranks select the `rank`-th element of the identity byte
/// array under `(freq desc, index asc)` — `select_nth_unstable_by` is
/// deterministic here because the comparator is a total order.
#[inline]
pub fn byte_of_rank(cdf: &[u32; 257], argmax: usize, rank: u8) -> u8 {
    if rank == 0 {
        return argmax as u8;
    }
    let mut idx: [u8; 256] = [0; 256];
    for (i, slot) in idx.iter_mut().enumerate() {
        *slot = i as u8;
    }
    let (_, nth, _) = idx.select_nth_unstable_by(rank as usize, |a, b| {
        let fa = cdf[*a as usize + 1] - cdf[*a as usize];
        let fb = cdf[*b as usize + 1] - cdf[*b as usize];
        fb.cmp(&fa).then(a.cmp(b))
    });
    *nth
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::llm::logits_to_cdf_argmax;
    use crate::util::Pcg64;

    fn random_cdf(rng: &mut Pcg64) -> ([u32; 257], usize) {
        let logits: Vec<f32> =
            (0..crate::lm::config::VOCAB).map(|_| (rng.gen_f64() * 12.0 - 6.0) as f32).collect();
        logits_to_cdf_argmax(&logits)
    }

    #[test]
    fn rank_transform_is_self_inverse_for_every_byte() {
        let mut rng = Pcg64::seeded(21);
        for _ in 0..20 {
            let (cdf, argmax) = random_cdf(&mut rng);
            let mut seen = [false; 256];
            for sym in 0..256usize {
                let r = rank_of(&cdf, argmax, sym);
                assert_eq!(byte_of_rank(&cdf, argmax, r) as usize, sym, "sym {sym} rank {r}");
                assert!(!seen[r as usize], "rank {r} assigned twice");
                seen[r as usize] = true;
            }
            assert_eq!(rank_of(&cdf, argmax, argmax), 0);
            assert_eq!(byte_of_rank(&cdf, argmax, 0) as usize, argmax);
        }
    }

    #[test]
    fn rank_zero_is_argmax_even_under_frequency_ties() {
        // A flat CDF maximizes quantized-frequency ties; the (freq desc,
        // index asc) order must still be total on both ends.
        let logits = vec![0.0f32; crate::lm::config::VOCAB];
        let (cdf, argmax) = logits_to_cdf_argmax(&logits);
        assert_eq!(byte_of_rank(&cdf, argmax, 0) as usize, argmax);
        for sym in 0..256usize {
            let r = rank_of(&cdf, argmax, sym);
            assert_eq!(byte_of_rank(&cdf, argmax, r) as usize, sym);
        }
    }

    fn skewed_ranks(n: usize, seed: u64) -> Vec<u8> {
        // The shape a trained model produces: ~90% rank 0, geometric tail,
        // occasional deep escapes.
        let mut rng = Pcg64::seeded(seed);
        (0..n)
            .map(|_| {
                let x = rng.gen_f64();
                if x < 0.90 {
                    0u8
                } else if x < 0.99 {
                    rng.gen_index(8) as u8 + 1
                } else if x < 0.999 {
                    rng.gen_index(55) as u8 + 9
                } else {
                    rng.gen_index(192) as u8 + 64
                }
            })
            .collect()
    }

    #[test]
    fn frame_roundtrips_on_skewed_and_degenerate_streams() {
        for (ranks, label) in [
            (skewed_ranks(10_000, 3), "skewed"),
            (vec![0u8; 5000], "all rank 0"),
            (vec![200u8; 64], "all escapes"),
            ((0..=255u8).collect::<Vec<u8>>(), "every rank once"),
            (vec![1u8], "single symbol"),
            (Vec::new(), "empty"),
        ] {
            let frame = encode_rank_stream(&ranks).unwrap();
            assert_eq!(decode_rank_stream(&frame, ranks.len()).unwrap(), ranks, "{label}");
            if ranks.is_empty() {
                assert!(frame.is_empty());
            }
        }
    }

    #[test]
    fn skewed_stream_compresses_far_below_one_byte_per_symbol() {
        let ranks = skewed_ranks(50_000, 4);
        let frame = encode_rank_stream(&ranks).unwrap();
        // ~0.6 bits/symbol entropy; allow generous slack over it.
        assert!(frame.len() < ranks.len() / 8, "{} bytes for {}", frame.len(), ranks.len());
    }

    #[test]
    fn every_strict_prefix_of_a_frame_errors() {
        let ranks = skewed_ranks(2000, 5);
        let frame = encode_rank_stream(&ranks).unwrap();
        for cut in 0..frame.len() {
            assert!(
                decode_rank_stream(&frame[..cut], ranks.len()).is_err(),
                "prefix of {cut}/{} bytes must not decode",
                frame.len()
            );
        }
    }

    #[test]
    fn structural_corruptions_are_errors_not_panics() {
        let ranks = skewed_ranks(500, 6);
        let frame = encode_rank_stream(&ranks).unwrap();
        // table_log out of range.
        let mut f = frame.clone();
        f[0] = 13;
        assert!(decode_rank_stream(&f, ranks.len()).is_err());
        // alphabet out of range.
        let mut f = frame.clone();
        f[1] = 66;
        assert!(decode_rank_stream(&f, ranks.len()).is_err());
        // fse_len pointing past the payload.
        let mut f = frame.clone();
        f[2..6].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_rank_stream(&f, ranks.len()).is_err());
        // Initial state below the table range.
        let mut f = frame.clone();
        f[6..10].copy_from_slice(&0u32.to_le_bytes());
        assert!(decode_rank_stream(&f, ranks.len()).is_err());
        // Histogram that lies about its sum.
        let mut f = frame.clone();
        f[FRAME_FIXED] ^= 0x01;
        assert!(decode_rank_stream(&f, ranks.len()).is_err());
        // Non-canonical escape literal (< RANK_ESCAPE).
        let mut f = frame.clone();
        let last = f.len() - 1;
        f[last] = 3; // the stream above always ends with escape literals present
        if decode_rank_stream(&f, ranks.len()).is_ok() {
            // If the tail byte happened to be bitstream, the frame had no
            // escapes — force one instead.
            let with_escape = encode_rank_stream(&[0, 0, 200]).unwrap();
            let mut g = with_escape.clone();
            let last = g.len() - 1;
            g[last] = 3;
            assert!(decode_rank_stream(&g, 3).is_err());
        }
        // Unconsumed escape literals.
        let mut f = frame.clone();
        f.push(200);
        assert!(decode_rank_stream(&f, ranks.len()).is_err());
    }

    #[test]
    fn arbitrary_bytes_never_panic() {
        let mut rng = Pcg64::seeded(7);
        for len in 0..200usize {
            let junk: Vec<u8> = (0..len).map(|_| rng.gen_index(256) as u8).collect();
            let _ = decode_rank_stream(&junk, rng.gen_index(300));
        }
        // And bit-flip sweeps over a valid frame: error or wrong ranks,
        // never a panic (CRC catches wrong-but-well-formed at the container
        // level).
        let ranks = skewed_ranks(300, 8);
        let frame = encode_rank_stream(&ranks).unwrap();
        for i in 0..frame.len() {
            for bit in [0x01u8, 0x10, 0x80] {
                let mut f = frame.clone();
                f[i] ^= bit;
                let _ = decode_rank_stream(&f, ranks.len());
            }
        }
    }

    #[test]
    fn flat_histogram_takes_the_wide_table_fallback() {
        // Every direct rank exactly once, plus escapes: a maximally flat
        // 65-symbol histogram. Whichever table log normalization lands on,
        // the frame must round-trip and record its own log.
        let mut ranks: Vec<u8> = (0..64u8).collect();
        ranks.push(100);
        let frame = encode_rank_stream(&ranks).unwrap();
        assert!(frame[0] == RANK_TABLE_LOG as u8 || frame[0] == RANK_TABLE_LOG_WIDE as u8);
        assert_eq!(decode_rank_stream(&frame, ranks.len()).unwrap(), ranks);
    }
}
