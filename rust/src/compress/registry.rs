//! Name-indexed registries: the nine baseline compressors of the paper's
//! Table 5, and the fleet's **model registry** — route keys resolved to
//! per-model pool slots by the multi-model coordinator
//! ([`crate::coordinator::FleetServer`]).
//!
//! The whole module is panic-free: lookups and registrations report the
//! offending name in a `Result` instead of unwrapping, so a bad route key
//! from the wire never takes the server down.

use crate::baselines::{
    ArithmeticOrder0, ContextMixing, FseOrder0, GzipLike, HuffmanOrder0, LzmaLite, Ppm, ZstdLite,
};
use crate::compress::llm::ContainerTag;
use crate::compress::Compressor;
use crate::Result;

/// Stable baseline order used by tables and benches (matches Table 5 rows).
pub const BASELINE_NAMES: [&str; 9] =
    ["huffman", "arithmetic", "fse", "gzip", "lzma", "zstd", "nncp", "trace", "pac"];

/// All baseline names in table order.
pub fn all_baseline_names() -> &'static [&'static str] {
    &BASELINE_NAMES
}

/// Instantiate a baseline by name.
pub fn baseline_by_name(name: &str) -> Result<Box<dyn Compressor>> {
    Ok(match name {
        "huffman" => Box::new(HuffmanOrder0),
        "arithmetic" => Box::new(ArithmeticOrder0),
        "fse" => Box::new(FseOrder0),
        "gzip" => Box::new(GzipLike::new()),
        "lzma" => Box::new(LzmaLite::new()),
        "zstd" => Box::new(ZstdLite::new()),
        "nncp" => Box::new(ContextMixing::nncp_sim()),
        "trace" => Box::new(ContextMixing::trace_sim()),
        "pac" => Box::new(Ppm::new(3)),
        other => anyhow::bail!("unknown baseline '{other}'"),
    })
}

/// Instantiate every baseline in table order. Propagates (rather than
/// unwraps) a construction failure, naming the baseline that failed.
pub fn all_baselines() -> Result<Vec<Box<dyn Compressor>>> {
    BASELINE_NAMES
        .iter()
        .map(|n| {
            baseline_by_name(n)
                .map_err(|e| anyhow::anyhow!("constructing baseline '{n}': {e:#}"))
        })
        .collect()
}

/// One hosted model in a [`ModelRegistry`]: a user-facing alias (the key
/// clients route by) bound to the full engine tag its pool stamps into
/// containers (`model:flag[:q8:<fp>][:fse]`).
#[derive(Clone, Debug)]
pub struct ModelRoute {
    /// User-facing route key, e.g. `"nano"` or `"nano-int8"`.
    pub alias: String,
    /// The pool's container tag, e.g. `"nano:0:q8:93ab01c2:fse"`.
    pub engine_tag: String,
}

/// Route-key → pool-slot registry for a multi-model fleet. Slots are the
/// insertion indices, which is how [`crate::coordinator::FleetServer`]
/// pairs entries with its pool vector.
///
/// Resolution order for a key (first match wins):
/// 1. exact alias match;
/// 2. the key parses as a [`ContainerTag`] naming the same engine as a
///    registered pool (codec suffix ignored — one engine decodes both);
/// 3. the key is a bare model name hosted by exactly ONE pool.
///
/// Every failure names the offending key and lists what the registry
/// holds — no panics anywhere in the module.
#[derive(Debug, Default)]
pub struct ModelRegistry {
    routes: Vec<ModelRoute>,
}

impl ModelRegistry {
    pub fn new() -> ModelRegistry {
        ModelRegistry::default()
    }

    pub fn len(&self) -> usize {
        self.routes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.routes.is_empty()
    }

    /// Registered routes in slot order.
    pub fn routes(&self) -> &[ModelRoute] {
        &self.routes
    }

    /// Comma-separated alias list for error messages.
    fn known(&self) -> String {
        if self.routes.is_empty() {
            return "(none)".into();
        }
        self.routes.iter().map(|r| r.alias.as_str()).collect::<Vec<_>>().join(", ")
    }

    /// Register a pool under `alias` with the engine tag it stamps into
    /// containers; returns the slot index. Duplicate aliases AND duplicate
    /// engines are rejected — either would make routing ambiguous.
    pub fn register(&mut self, alias: &str, engine_tag: &str) -> Result<usize> {
        if alias.is_empty() {
            anyhow::bail!("model route alias must be non-empty");
        }
        if let Some(dup) = self.routes.iter().find(|r| r.alias == alias) {
            anyhow::bail!(
                "model route alias '{alias}' already registered (engine '{}')",
                dup.engine_tag
            );
        }
        let tag = ContainerTag::parse(engine_tag)
            .map_err(|e| anyhow::anyhow!("engine tag '{engine_tag}' for '{alias}': {e:#}"))?;
        for r in &self.routes {
            let other = ContainerTag::parse(&r.engine_tag)
                .map_err(|e| anyhow::anyhow!("registry holds bad tag '{}': {e:#}", r.engine_tag))?;
            if tag.same_engine(&other) {
                anyhow::bail!(
                    "engine '{engine_tag}' already registered under alias '{}' — \
                     two pools for one engine would make routing ambiguous",
                    r.alias
                );
            }
        }
        self.routes.push(ModelRoute { alias: alias.into(), engine_tag: engine_tag.into() });
        Ok(self.routes.len() - 1)
    }

    /// Resolve a route key to its slot index (see the type docs for the
    /// matching order).
    pub fn resolve(&self, key: &str) -> Result<usize> {
        if let Some(i) = self.routes.iter().position(|r| r.alias == key) {
            return Ok(i);
        }
        // A full container tag routes by engine equivalence, so a client
        // holding only a container can ask for "whoever decodes this".
        if let Ok(tag) = ContainerTag::parse(key) {
            for (i, r) in self.routes.iter().enumerate() {
                if ContainerTag::parse(&r.engine_tag).is_ok_and(|own| own.same_engine(&tag)) {
                    return Ok(i);
                }
            }
        }
        // Bare model name: unambiguous only when a single pool hosts it.
        let by_model: Vec<usize> = self
            .routes
            .iter()
            .enumerate()
            .filter(|(_, r)| r.engine_tag.split(':').next() == Some(key))
            .map(|(i, _)| i)
            .collect();
        match by_model.as_slice() {
            [one] => Ok(*one),
            [] => anyhow::bail!(
                "unknown model route '{key}' — fleet hosts: {}",
                self.known()
            ),
            many => anyhow::bail!(
                "model route '{key}' is ambiguous ({} pools host that model: {}) — \
                 use a full alias or container tag",
                many.len(),
                many.iter()
                    .map(|&i| self.routes[i].alias.as_str())
                    .collect::<Vec<_>>()
                    .join(", ")
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_is_complete_and_names_match() {
        for name in BASELINE_NAMES {
            let c = baseline_by_name(name).unwrap();
            assert_eq!(c.name(), name);
        }
        assert!(baseline_by_name("nope").is_err());
    }

    #[test]
    fn every_baseline_roundtrips_shared_corpus() {
        let data = crate::textgen::quick_sample(8_000, 42);
        for c in all_baselines().unwrap() {
            let z = c.compress(&data).unwrap();
            assert_eq!(c.decompress(&z).unwrap(), data, "{}", c.name());
        }
    }

    #[test]
    fn model_registry_resolves_alias_tag_and_bare_name() {
        let mut reg = ModelRegistry::new();
        let f32_slot = reg.register("nano-f32", "nano:0").unwrap();
        let q8_slot = reg.register("nano-q8", "nano:0:q8:deadbeef:fse").unwrap();
        let med = reg.register("medium", "medium:0").unwrap();
        assert_eq!(reg.resolve("nano-q8").unwrap(), q8_slot);
        // A container tag routes by engine, ignoring the codec suffix.
        assert_eq!(reg.resolve("nano:0:q8:deadbeef").unwrap(), q8_slot);
        assert_eq!(reg.resolve("nano:0:fse").unwrap(), f32_slot);
        // Bare model name: unique → resolves, shared → ambiguous error.
        assert_eq!(reg.resolve("medium").unwrap(), med);
        let err = format!("{:#}", reg.resolve("nano").unwrap_err());
        assert!(err.contains("ambiguous"), "{err}");
        let err = format!("{:#}", reg.resolve("giant").unwrap_err());
        assert!(err.contains("unknown model route 'giant'"), "{err}");
        assert!(err.contains("nano-f32"), "{err}");
    }

    #[test]
    fn model_registry_rejects_duplicates_without_panicking() {
        let mut reg = ModelRegistry::new();
        reg.register("a", "nano:0").unwrap();
        let err = format!("{:#}", reg.register("a", "medium:0").unwrap_err());
        assert!(err.contains("alias 'a' already registered"), "{err}");
        // Same engine under a new alias (even with another codec suffix).
        let err = format!("{:#}", reg.register("b", "nano:0:fse").unwrap_err());
        assert!(err.contains("already registered under alias 'a'"), "{err}");
        // Malformed engine tags are errors naming the tag, not panics.
        let err = format!("{:#}", reg.register("c", "untagged").unwrap_err());
        assert!(err.contains("untagged"), "{err}");
    }
}
