//! Name-indexed registry of the nine baseline compressors — the rows of the
//! paper's Table 5 minus "Ours" (which needs a model and lives in
//! [`super::llm`]).

use crate::baselines::{
    ArithmeticOrder0, ContextMixing, FseOrder0, GzipLike, HuffmanOrder0, LzmaLite, Ppm, ZstdLite,
};
use crate::compress::Compressor;
use crate::Result;

/// Stable baseline order used by tables and benches (matches Table 5 rows).
pub const BASELINE_NAMES: [&str; 9] =
    ["huffman", "arithmetic", "fse", "gzip", "lzma", "zstd", "nncp", "trace", "pac"];

/// All baseline names in table order.
pub fn all_baseline_names() -> &'static [&'static str] {
    &BASELINE_NAMES
}

/// Instantiate a baseline by name.
pub fn baseline_by_name(name: &str) -> Result<Box<dyn Compressor>> {
    Ok(match name {
        "huffman" => Box::new(HuffmanOrder0),
        "arithmetic" => Box::new(ArithmeticOrder0),
        "fse" => Box::new(FseOrder0),
        "gzip" => Box::new(GzipLike::new()),
        "lzma" => Box::new(LzmaLite::new()),
        "zstd" => Box::new(ZstdLite::new()),
        "nncp" => Box::new(ContextMixing::nncp_sim()),
        "trace" => Box::new(ContextMixing::trace_sim()),
        "pac" => Box::new(Ppm::new(3)),
        other => anyhow::bail!("unknown baseline '{other}'"),
    })
}

/// Instantiate every baseline in table order.
pub fn all_baselines() -> Vec<Box<dyn Compressor>> {
    BASELINE_NAMES.iter().map(|n| baseline_by_name(n).unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_is_complete_and_names_match() {
        for name in BASELINE_NAMES {
            let c = baseline_by_name(name).unwrap();
            assert_eq!(c.name(), name);
        }
        assert!(baseline_by_name("nope").is_err());
    }

    #[test]
    fn every_baseline_roundtrips_shared_corpus() {
        let data = crate::textgen::quick_sample(8_000, 42);
        for c in all_baselines() {
            let z = c.compress(&data).unwrap();
            assert_eq!(c.decompress(&z).unwrap(), data, "{}", c.name());
        }
    }
}
