//! Positioned reads over seekable v2 containers.
//!
//! [`Container::from_bytes`](super::Container::from_bytes) needs the whole
//! container in memory: it copies every frame payload into one contiguous
//! buffer before anything can be decoded. That is the right shape for the
//! serve path (the request already arrived as bytes), but exactly wrong for
//! random access into an archive on disk — decoding 100 bytes out of a
//! 10 GB container should read the header, the trailer index, and the one
//! or two frames the range touches. Nothing else.
//!
//! [`ContainerSource`] abstracts "a thing positioned reads come from":
//! an in-memory slice, or a file via `pread` ([`FileSource`]). On top of it
//! [`SeekableContainer`] opens a v2 container by reading only the header
//! and the trailer index, computes every frame's byte offset by prefix sum
//! (the index stores per-chunk lengths), and serves individual frame
//! payloads on demand — each fetch cross-checks the frame's own header
//! against the index, so the two copies of the records cannot disagree
//! silently, same as the slurping parser.
//!
//! The ranged entry points live on the compressor:
//! [`LlmCompressor::decompress_range_from`](super::llm) and
//! [`decode_chunk_from`](super::llm). `decompress_range(&[u8], ..)` now
//! routes v2 slices through this module too, so both faces share one
//! frame-selection path. Byte/frame counters ([`SeekableContainer::bytes_read`],
//! [`SeekableContainer::frames_read`]) exist so tests and the allocation
//! bench can assert the O(frames-in-range) property instead of trusting it.

use crate::compress::container::{
    check_flags, ChunkRecord, CONTAINER_END_MAGIC, CONTAINER_MAGIC, CONTAINER_V2, FRAME_HEADER,
    FRAME_MARKER, TRAILER_MARKER, V2_HEADER_FIXED, V2_TRAILER_FIXED,
};
use crate::util::{read_u32_le, read_u64_le};
use crate::Result;
use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};

/// Something a container can be read out of at arbitrary offsets, without
/// consuming or buffering the rest. `read_at` is `&self` so one open
/// container can serve reads from multiple call sites (files use `pread`,
/// which never touches the shared cursor).
pub trait ContainerSource {
    /// Total size in bytes.
    fn len(&self) -> u64;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Fill `buf` exactly from `offset`. Short reads are errors — callers
    /// always know how many bytes the format says are there.
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> Result<()>;
}

impl ContainerSource for [u8] {
    fn len(&self) -> u64 {
        <[u8]>::len(self) as u64
    }

    fn read_at(&self, offset: u64, buf: &mut [u8]) -> Result<()> {
        let end = offset
            .checked_add(buf.len() as u64)
            .ok_or_else(|| anyhow::anyhow!("read range overflows"))?;
        if end > <[u8]>::len(self) as u64 {
            anyhow::bail!(
                "read [{offset}, {end}) past end of {}-byte container",
                <[u8]>::len(self)
            );
        }
        buf.copy_from_slice(&self[offset as usize..end as usize]);
        Ok(())
    }
}

/// A container file served by positioned reads (`pread(2)` on unix): no
/// seek state, no buffering, safe to share behind `&self`.
pub struct FileSource {
    #[cfg(unix)]
    file: std::fs::File,
    #[cfg(not(unix))]
    file: std::sync::Mutex<std::fs::File>,
    len: u64,
}

impl FileSource {
    pub fn open(path: &std::path::Path) -> Result<FileSource> {
        let file = std::fs::File::open(path)
            .map_err(|e| anyhow::anyhow!("open {}: {e}", path.display()))?;
        let len = file.metadata()?.len();
        #[cfg(not(unix))]
        let file = std::sync::Mutex::new(file);
        Ok(FileSource { file, len })
    }
}

impl ContainerSource for FileSource {
    fn len(&self) -> u64 {
        self.len
    }

    #[cfg(unix)]
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> Result<()> {
        use std::os::unix::fs::FileExt;
        self.file.read_exact_at(buf, offset)?;
        Ok(())
    }

    #[cfg(not(unix))]
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> Result<()> {
        use std::io::{Read, Seek, SeekFrom};
        // A poisoned lock only means another reader panicked mid-read; the
        // file handle itself carries no invariants, so recover the guard
        // rather than propagating the panic into the decode path.
        let mut f = self.file.lock().unwrap_or_else(|p| p.into_inner());
        f.seek(SeekFrom::Start(offset))?;
        f.read_exact(buf)?;
        Ok(())
    }
}

/// A v2 container opened for random access: header + trailer index are
/// read (and fully validated) up front; frame payloads are fetched on
/// demand by [`Self::read_chunk_payload`]. Total bytes touched for a
/// ranged decode: `header + trailer + Σ frames-in-range`.
pub struct SeekableContainer<'a> {
    src: &'a dyn ContainerSource,
    flags: u16,
    chunk_tokens: u32,
    model_name: String,
    records: Vec<ChunkRecord>,
    /// Byte offset of frame `i`'s header (prefix sums over the index).
    frame_offsets: Vec<u64>,
    /// Decoded-byte offset at which chunk `i` begins (prefix sums over
    /// `n_tokens`).
    token_starts: Vec<u64>,
    orig_len: u64,
    orig_crc32: u32,
    bytes_read: AtomicU64,
    frames_read: AtomicU64,
}

impl<'a> SeekableContainer<'a> {
    /// Open + validate: reads the fixed header, the model name, and the
    /// whole trailer. Every structural check `Container::from_bytes`
    /// performs on those regions happens here too; per-frame header
    /// checks are deferred to the frame fetch (that is the point).
    pub fn open(src: &'a dyn ContainerSource) -> Result<SeekableContainer<'a>> {
        let total = src.len();
        let bytes_read = AtomicU64::new(0);
        let min = (V2_HEADER_FIXED + V2_TRAILER_FIXED) as u64;
        if total < min {
            anyhow::bail!("container too short");
        }
        let mut fixed = [0u8; V2_HEADER_FIXED];
        src.read_at(0, &mut fixed)?;
        bytes_read.fetch_add(V2_HEADER_FIXED as u64, Ordering::Relaxed);
        if read_u32_le(&fixed, 0) != CONTAINER_MAGIC {
            anyhow::bail!("bad container magic");
        }
        let version = u16::from_le_bytes([fixed[4], fixed[5]]);
        if version != CONTAINER_V2 {
            anyhow::bail!(
                "positioned reads need a v2 (seekable) container, got version {version}"
            );
        }
        let flags = u16::from_le_bytes([fixed[6], fixed[7]]);
        check_flags(CONTAINER_V2, flags)?;
        let chunk_tokens = read_u32_le(&fixed, 8);
        let name_len = fixed[12] as usize;
        let header_end = (V2_HEADER_FIXED + name_len) as u64;
        if total < header_end + V2_TRAILER_FIXED as u64 {
            anyhow::bail!("truncated container header");
        }
        let mut name = vec![0u8; name_len];
        src.read_at(V2_HEADER_FIXED as u64, &mut name)?;
        bytes_read.fetch_add(name_len as u64, Ordering::Relaxed);
        let model_name = String::from_utf8(name)
            .map_err(|_| anyhow::anyhow!("model name is not UTF-8"))?;

        // The last 12 bytes locate the trailer.
        let mut tail = [0u8; 12];
        src.read_at(total - 12, &mut tail)?;
        bytes_read.fetch_add(12, Ordering::Relaxed);
        if read_u32_le(&tail, 8) != CONTAINER_END_MAGIC {
            anyhow::bail!("bad container end magic — truncated v2 container?");
        }
        let trailer_off = read_u64_le(&tail, 0);
        if trailer_off < header_end || trailer_off > total - V2_TRAILER_FIXED as u64 {
            anyhow::bail!("container trailer offset {trailer_off} out of bounds");
        }
        // Marker + chunk count pin the trailer's size before the index
        // allocation — a lying count cannot ask for more than the trailer
        // region the file actually has.
        let mut head = [0u8; 5];
        src.read_at(trailer_off, &mut head)?;
        bytes_read.fetch_add(5, Ordering::Relaxed);
        if head[0] != TRAILER_MARKER {
            anyhow::bail!("container trailer marker missing at offset {trailer_off}");
        }
        let n_chunks = read_u32_le(&head, 1) as usize;
        if trailer_off + V2_TRAILER_FIXED as u64 + 8 * n_chunks as u64 != total {
            anyhow::bail!("container trailer size disagrees with its chunk count");
        }
        let mut index = vec![0u8; 8 * n_chunks + 12];
        src.read_at(trailer_off + 5, &mut index)?;
        bytes_read.fetch_add(index.len() as u64, Ordering::Relaxed);
        let mut records = Vec::with_capacity(n_chunks);
        let mut frame_offsets = Vec::with_capacity(n_chunks);
        let mut token_starts = Vec::with_capacity(n_chunks);
        let mut comp_off = header_end;
        let mut token_off = 0u64;
        for i in 0..n_chunks {
            let rec = ChunkRecord {
                comp_len: read_u32_le(&index, i * 8),
                n_tokens: read_u32_le(&index, i * 8 + 4),
            };
            frame_offsets.push(comp_off);
            token_starts.push(token_off);
            // Widen BEFORE adding: `comp_len` is attacker-controlled index
            // bytes, and `FRAME_HEADER as u32 + comp_len` wraps at 4 GiB.
            comp_off += FRAME_HEADER as u64 + rec.comp_len as u64;
            token_off += rec.n_tokens as u64;
            records.push(rec);
        }
        let orig_len = read_u64_le(&index, 8 * n_chunks);
        let orig_crc32 = read_u32_le(&index, 8 * n_chunks + 8);
        if token_off != orig_len {
            anyhow::bail!("chunk token sum {token_off} != original length {orig_len}");
        }
        if comp_off != trailer_off {
            anyhow::bail!("container frame region size disagrees with the trailer index");
        }
        Ok(SeekableContainer {
            src,
            flags,
            chunk_tokens,
            model_name,
            records,
            frame_offsets,
            token_starts,
            orig_len,
            orig_crc32,
            bytes_read,
            frames_read: AtomicU64::new(0),
        })
    }

    pub fn flags(&self) -> u16 {
        self.flags
    }

    pub fn chunk_tokens(&self) -> u32 {
        self.chunk_tokens
    }

    pub fn model_name(&self) -> &str {
        &self.model_name
    }

    pub fn orig_len(&self) -> u64 {
        self.orig_len
    }

    pub fn orig_crc32(&self) -> u32 {
        self.orig_crc32
    }

    pub fn n_chunks(&self) -> usize {
        self.records.len()
    }

    pub fn records(&self) -> &[ChunkRecord] {
        &self.records
    }

    /// Decoded-byte offset at which chunk `i` begins. An out-of-range
    /// index is a caller bug, but this is decode-reachable code, so it
    /// reports instead of panicking.
    pub fn token_start(&self, i: usize) -> Result<u64> {
        match self.token_starts.get(i) {
            Some(&s) => Ok(s),
            None => anyhow::bail!("chunk {i} out of range (container has {})", self.records.len()),
        }
    }

    /// Total bytes fetched from the source so far (header + trailer +
    /// frames).
    pub fn bytes_read(&self) -> u64 {
        self.bytes_read.load(Ordering::Relaxed)
    }

    /// Frame payloads fetched so far — THE number a ranged decode is
    /// judged by: it must be the frames the range touches, not
    /// `n_chunks`.
    pub fn frames_read(&self) -> u64 {
        self.frames_read.load(Ordering::Relaxed)
    }

    /// Size of the underlying source.
    pub fn source_len(&self) -> u64 {
        self.src.len()
    }

    /// Which chunks `[offset, offset + len)` of the decoded stream
    /// touches. Validates the range against the recorded original
    /// length; `len == 0` yields an empty range.
    pub fn chunks_in_range(&self, offset: u64, len: u64) -> Result<Range<usize>> {
        let end = offset
            .checked_add(len)
            .ok_or_else(|| anyhow::anyhow!("range overflows"))?;
        if end > self.orig_len {
            anyhow::bail!("range [{offset}, {end}) exceeds original length {}", self.orig_len);
        }
        if len == 0 {
            return Ok(0..0);
        }
        // token_starts is strictly increasing (every chunk carries at
        // least one token), so both bounds are partition points.
        let first = self.token_starts.partition_point(|&s| s <= offset) - 1;
        let after = self.token_starts.partition_point(|&s| s < end);
        Ok(first..after)
    }

    /// Fetch chunk `i`'s payload: one positioned read of header+payload,
    /// cross-checked against the trailer index.
    pub fn read_chunk_payload(&self, i: usize) -> Result<Vec<u8>> {
        let Some(&rec) = self.records.get(i) else {
            anyhow::bail!("chunk {i} out of range (container has {})", self.records.len());
        };
        let mut buf = vec![0u8; FRAME_HEADER + rec.comp_len as usize];
        self.src.read_at(self.frame_offsets[i], &mut buf)?;
        self.bytes_read.fetch_add(buf.len() as u64, Ordering::Relaxed);
        self.frames_read.fetch_add(1, Ordering::Relaxed);
        if buf[0] != FRAME_MARKER {
            anyhow::bail!("frame {i} marker missing at offset {}", self.frame_offsets[i]);
        }
        let comp_len = read_u32_le(&buf, 1);
        let n_tokens = read_u32_le(&buf, 5);
        if comp_len != rec.comp_len || n_tokens != rec.n_tokens {
            anyhow::bail!(
                "frame {i} header ({comp_len}, {n_tokens}) disagrees with trailer index \
                 ({}, {})",
                rec.comp_len,
                rec.n_tokens
            );
        }
        buf.drain(..FRAME_HEADER);
        Ok(buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::container::Container;
    use crate::util::crc32;

    fn sample_v2_bytes() -> Vec<u8> {
        Container::v2(
            1000,
            0xDEADBEEF,
            256,
            "medium".to_string(),
            vec![
                ChunkRecord { comp_len: 3, n_tokens: 256 },
                ChunkRecord { comp_len: 4, n_tokens: 256 },
                ChunkRecord { comp_len: 2, n_tokens: 256 },
                ChunkRecord { comp_len: 1, n_tokens: 232 },
            ],
            vec![1, 2, 3, 4, 5, 6, 7, 8, 9, 10],
        )
        .to_bytes()
    }

    #[test]
    fn open_agrees_with_the_slurping_parser() {
        let bytes = sample_v2_bytes();
        let parsed = Container::from_bytes(&bytes).unwrap();
        let s = SeekableContainer::open(&bytes[..]).unwrap();
        assert_eq!(s.n_chunks(), parsed.chunks.len());
        assert_eq!(s.records(), &parsed.chunks[..]);
        assert_eq!(s.orig_len(), parsed.orig_len);
        assert_eq!(s.orig_crc32(), parsed.orig_crc32);
        assert_eq!(s.chunk_tokens(), parsed.chunk_tokens);
        assert_eq!(s.model_name(), parsed.model_name);
        assert_eq!(s.flags(), parsed.flags);
        // Payload fetches match iter_chunks, and only touch one frame each.
        for (i, (rec, slice)) in parsed.iter_chunks().enumerate() {
            let before = s.frames_read();
            let p = s.read_chunk_payload(i).unwrap();
            assert_eq!(p, slice, "chunk {i}");
            assert_eq!(p.len(), rec.comp_len as usize);
            assert_eq!(s.frames_read(), before + 1);
        }
        assert!(s.read_chunk_payload(4).is_err());
    }

    #[test]
    fn open_reads_only_header_and_trailer() {
        let bytes = sample_v2_bytes();
        let s = SeekableContainer::open(&bytes[..]).unwrap();
        let payload_total: u64 = s.records().iter().map(|r| r.comp_len as u64).sum();
        let frame_headers = (s.n_chunks() * FRAME_HEADER) as u64;
        assert_eq!(
            s.bytes_read(),
            bytes.len() as u64 - payload_total - frame_headers,
            "open must not touch the frame region"
        );
        assert_eq!(s.frames_read(), 0);
    }

    #[test]
    fn chunks_in_range_selects_exactly_the_overlapping_chunks() {
        let bytes = sample_v2_bytes();
        let s = SeekableContainer::open(&bytes[..]).unwrap();
        // Chunk token boundaries: 0, 256, 512, 768, 1000.
        assert_eq!(s.chunks_in_range(0, 1).unwrap(), 0..1);
        assert_eq!(s.chunks_in_range(255, 1).unwrap(), 0..1);
        assert_eq!(s.chunks_in_range(255, 2).unwrap(), 0..2);
        assert_eq!(s.chunks_in_range(256, 1).unwrap(), 1..2);
        assert_eq!(s.chunks_in_range(300, 600).unwrap(), 1..4);
        assert_eq!(s.chunks_in_range(0, 1000).unwrap(), 0..4);
        assert_eq!(s.chunks_in_range(999, 1).unwrap(), 3..4);
        assert_eq!(s.chunks_in_range(500, 0).unwrap(), 0..0);
        assert!(s.chunks_in_range(0, 1001).is_err());
        assert!(s.chunks_in_range(1000, 1).is_err());
        assert!(s.chunks_in_range(u64::MAX, 2).is_err());
    }

    #[test]
    fn rejects_v1_truncation_and_corruption() {
        let v1 = Container::v1(0, crc32(b""), 64, "m".into(), vec![], vec![]).to_bytes();
        let err = SeekableContainer::open(&v1[..]).unwrap_err().to_string();
        assert!(err.contains("v2"), "{err}");
        let bytes = sample_v2_bytes();
        for cut in 0..bytes.len() {
            assert!(SeekableContainer::open(&bytes[..cut]).is_err(), "cut={cut}");
        }
        // Frame header disagreeing with the index is caught at fetch time.
        let mut bad = bytes.clone();
        let header_end = 13 + "medium".len();
        assert_eq!(bad[header_end], FRAME_MARKER);
        bad[header_end + 5] ^= 1;
        let s = SeekableContainer::open(&bad[..]).unwrap();
        let err = s.read_chunk_payload(0).unwrap_err().to_string();
        assert!(err.contains("disagrees"), "{err}");
        // Corrupt trailer chunk count.
        let mut bad = bytes.clone();
        let trailer_off = read_u64_le(&bad, bytes.len() - 12) as usize;
        bad[trailer_off + 1] ^= 1;
        assert!(SeekableContainer::open(&bad[..]).is_err());
    }

    #[test]
    fn file_source_round_trips_via_pread() {
        let bytes = sample_v2_bytes();
        let dir = std::env::temp_dir();
        let path = dir.join(format!("llmzip-source-test-{}.lmz", std::process::id()));
        std::fs::write(&path, &bytes).unwrap();
        let file = FileSource::open(&path).unwrap();
        assert_eq!(ContainerSource::len(&file), bytes.len() as u64);
        let s = SeekableContainer::open(&file).unwrap();
        assert_eq!(s.n_chunks(), 4);
        let parsed = Container::from_bytes(&bytes).unwrap();
        for (i, (_, slice)) in parsed.iter_chunks().enumerate() {
            assert_eq!(s.read_chunk_payload(i).unwrap(), slice);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn empty_container_opens_with_zero_chunks() {
        let bytes = Container::v2(0, crc32(b""), 64, "nano:0".into(), vec![], vec![]).to_bytes();
        let s = SeekableContainer::open(&bytes[..]).unwrap();
        assert_eq!(s.n_chunks(), 0);
        assert_eq!(s.orig_len(), 0);
        assert_eq!(s.chunks_in_range(0, 0).unwrap(), 0..0);
        assert!(s.chunks_in_range(0, 1).is_err());
    }
}
