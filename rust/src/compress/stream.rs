//! Incremental compression sessions over `std::io` — the streaming face
//! of [`LlmCompressor`].
//!
//! The paper's predict-then-code loop is inherently online (LLMZip;
//! Delétang et al. 2023): every position needs only the model state and
//! the next byte. This module exposes that shape the way `zstd`'s stream
//! APIs do, instead of the buffer-to-buffer
//! [`Compressor`](crate::compress::Compressor) calls:
//!
//! * [`CompressWriter`] implements [`std::io::Write`]: bytes written are
//!   buffered to the compressor's stream granularity
//!   ([`LlmCompressor::stream_bytes`]), each full chunk is range-coded and
//!   flushed to the inner writer as a container-v2 frame the moment it is
//!   ready, and [`CompressWriter::finish`] seals the final partial chunk
//!   plus the seekable trailer. Memory stays bounded by
//!   `stream_bytes × lanes` no matter how large the input is.
//! * [`DecompressReader`] implements [`std::io::Read`]: container frames
//!   are decoded one lane-group at a time (up to `lanes` frames share one
//!   batched engine pass — the reader's parallelism; v2 incrementally, v1
//!   via its up-front table), so decoding an arbitrarily large archive
//!   holds at most `lanes` chunks of payload + output. The recorded
//!   CRC/length are verified when the final frame is drained — reading to
//!   EOF is the verified-lossless path, stopping early skips
//!   verification.
//!
//! **Byte-identity contract:** for the same input bytes, the container a
//! [`CompressWriter`] emits is byte-for-byte identical to the one-shot
//! [`compress`](crate::compress::Compressor::compress) container,
//! regardless of how the input was
//! split across `write` calls (1-byte writes, chunk-straddling writes,
//! empty writes — property-tested in `tests/stream_equiv.rs`). This holds
//! because chunk boundaries depend only on byte offsets, every chunk is
//! encoded in its own lane with its own range coder (so batch grouping
//! cannot leak into the bytes — the same invariant the coordinator's
//! cross-request batching is built on), and both paths serialize through
//! the same `Container` v2 framing helpers.

use crate::compress::container::{
    ChunkRecord, Codec, Container, CONTAINER_MAGIC, CONTAINER_V1, CONTAINER_V2, FLAG_SEEKABLE,
    FRAME_HEADER, FRAME_MARKER, TRAILER_MARKER,
};
use crate::compress::llm::LlmCompressor;
use crate::util::{BytePool, Crc32, PooledBuf};
use crate::Result;
use std::io::{Read, Write};

/// Upper bound on a single frame's declared payload/token size (matches
/// the serve path's request cap). A corrupt or hostile length field fails
/// with a clear error instead of attempting a multi-GiB allocation.
const MAX_FRAME_BYTES: u32 = 256 << 20;

fn to_io(e: anyhow::Error) -> std::io::Error {
    std::io::Error::other(format!("{e:#}"))
}

/// Validate a chunk/payload length against the container's u32 frame
/// fields and [`MAX_FRAME_BYTES`]. A bare `len as u32` here would
/// silently truncate at 4 GiB and write a frame header that lies about
/// its own payload — the same bug class the wire layer's
/// `check_wire_len` closed.
fn check_frame_len(len: usize, what: &str) -> Result<u32> {
    if len > MAX_FRAME_BYTES as usize {
        anyhow::bail!("{what} length {len} exceeds the {MAX_FRAME_BYTES}-byte frame cap");
    }
    // lint: allow(L2) the sanctioned truncation point; bounds-checked above
    Ok(len as u32)
}

/// What a finished streaming session produced.
#[derive(Clone, Copy, Debug)]
pub struct StreamSummary {
    /// Original bytes consumed.
    pub bytes_in: u64,
    /// Container bytes emitted (header + frames + trailer).
    pub bytes_out: u64,
    /// Chunks (container frames) written.
    pub chunks: usize,
}

/// Incremental encoder: see the module docs. Obtain via
/// [`LlmCompressor::stream_compress`].
pub struct CompressWriter<'c, W: Write> {
    comp: &'c LlmCompressor,
    inner: W,
    /// Bytes not yet forming a full chunk (< `stream_bytes` after every
    /// call; the final partial chunk is encoded by [`Self::finish`]).
    buf: Vec<u8>,
    records: Vec<ChunkRecord>,
    crc: Crc32,
    total_in: u64,
    /// Container bytes emitted so far == the trailer offset at finish.
    written: u64,
    /// An engine error leaves the coder state unusable; refuse further
    /// writes/finish instead of emitting a silently-wrong container.
    poisoned: bool,
}

impl<'c, W: Write> CompressWriter<'c, W> {
    /// Open a session: writes the container header immediately.
    pub(crate) fn new(comp: &'c LlmCompressor, mut inner: W) -> Result<CompressWriter<'c, W>> {
        let flags = FLAG_SEEKABLE | comp.codec().flag_bits();
        let header = Container::v2_header(flags, comp.chunk_tokens() as u32, &comp.container_tag());
        inner.write_all(&header)?;
        Ok(CompressWriter {
            comp,
            inner,
            buf: Vec::new(),
            records: Vec::new(),
            crc: Crc32::new(),
            total_in: 0,
            written: header.len() as u64,
            poisoned: false,
        })
    }

    /// Encode one group of chunks (≤ engine lanes) and emit their frames.
    fn encode_group(&mut self, chunks: &[&[u8]]) -> Result<()> {
        let compressed = self.comp.compress_chunks(chunks)?;
        for (chunk, comp) in chunks.iter().zip(&compressed) {
            self.emit_frame(check_frame_len(chunk.len(), "chunk")?, comp)?;
        }
        Ok(())
    }

    fn emit_frame(&mut self, n_tokens: u32, payload: &[u8]) -> Result<()> {
        let comp_len = check_frame_len(payload.len(), "compressed frame")?;
        let rec = ChunkRecord { comp_len, n_tokens };
        self.inner.write_all(&Container::v2_frame_header(rec))?;
        self.inner.write_all(payload)?;
        self.written += (FRAME_HEADER + payload.len()) as u64;
        self.records.push(rec);
        Ok(())
    }

    fn guard(&self) -> Result<()> {
        if self.poisoned {
            anyhow::bail!("compression stream previously failed; the session is unusable");
        }
        Ok(())
    }

    /// Consume `data` (equivalent to `io::Write::write_all`, with the
    /// crate's error type). Linear in `data.len()`: full chunks encode
    /// straight from the caller's slice; only the sub-chunk head/tail ever
    /// passes through the internal buffer.
    pub fn write_bytes(&mut self, data: &[u8]) -> Result<()> {
        self.guard()?;
        self.crc.update(data);
        self.total_in += data.len() as u64;
        if let Err(e) = self.ingest(data) {
            self.poisoned = true;
            return Err(e);
        }
        Ok(())
    }

    // NOTE: mirrored by `coordinator::router::StreamHandle::write_bytes`
    // (same top-up/slice/tail boundary rule, scheduler-message sink); the
    // byte-identity contract needs both to agree — change them together.
    fn ingest(&mut self, mut data: &[u8]) -> Result<()> {
        let sb = self.comp.stream_bytes();
        // Top the buffered partial chunk up to a boundary first.
        if !self.buf.is_empty() {
            let take = (sb - self.buf.len()).min(data.len());
            self.buf.extend_from_slice(&data[..take]);
            data = &data[take..];
            if self.buf.len() < sb {
                return Ok(());
            }
            // Take the staging buffer out to appease the borrow checker,
            // then put its storage back: the writer re-stages a partial
            // chunk on almost every call, and re-allocating `stream_bytes`
            // of capacity per boundary crossing is the serve path's hottest
            // avoidable allocation.
            let head = std::mem::take(&mut self.buf);
            let encoded = self.encode_group(&[&head]);
            self.buf = head;
            self.buf.clear();
            encoded?;
        }
        // Encode whole chunks directly from the caller's slice,
        // lane-batched.
        let lanes = self.comp.lanes().max(1);
        while data.len() >= sb {
            let n = (data.len() / sb).min(lanes);
            let chunks: Vec<&[u8]> = (0..n).map(|i| &data[i * sb..(i + 1) * sb]).collect();
            self.encode_group(&chunks)?;
            data = &data[n * sb..];
        }
        self.buf.extend_from_slice(data);
        Ok(())
    }

    /// Encode the final partial chunk, write the seekable trailer and
    /// return the inner writer plus session stats. The emitted container
    /// is byte-identical to `compressor.compress(all_input)`.
    pub fn finish(mut self) -> Result<(W, StreamSummary)> {
        self.guard()?;
        debug_assert!(self.buf.len() < self.comp.stream_bytes());
        if !self.buf.is_empty() {
            let tail = std::mem::take(&mut self.buf);
            self.encode_group(&[&tail])?;
        }
        let trailer = Container::v2_trailer(
            &self.records,
            self.total_in,
            self.crc.finalize(),
            self.written,
        );
        self.inner.write_all(&trailer)?;
        self.inner.flush()?;
        let summary = StreamSummary {
            bytes_in: self.total_in,
            bytes_out: self.written + trailer.len() as u64,
            chunks: self.records.len(),
        };
        Ok((self.inner, summary))
    }

    /// Original bytes consumed so far.
    pub fn bytes_in(&self) -> u64 {
        self.total_in
    }

    /// Container bytes emitted so far (excludes the future trailer).
    pub fn bytes_out(&self) -> u64 {
        self.written
    }
}

impl<W: Write> Write for CompressWriter<'_, W> {
    fn write(&mut self, data: &[u8]) -> std::io::Result<usize> {
        self.write_bytes(data).map_err(to_io)?;
        Ok(data.len())
    }

    /// Flushes the inner writer. A partial chunk stays buffered — the
    /// chunk boundary is part of the format, so only [`Self::finish`] may
    /// emit it.
    fn flush(&mut self) -> std::io::Result<()> {
        self.inner.flush()
    }
}

/// Reader-side state: where the decoded bytes come from next.
enum Frames {
    /// v1: the chunk table was read up front; decode payloads in order.
    V1 { table: Vec<ChunkRecord>, next: usize, orig_len: u64, orig_crc32: u32 },
    /// v2: frames arrive inline; records accumulate for the trailer check.
    V2 { seen: Vec<ChunkRecord> },
}

/// Incremental decoder: see the module docs. Obtain via
/// [`LlmCompressor::stream_decompress`].
pub struct DecompressReader<'c, R: Read> {
    comp: &'c LlmCompressor,
    inner: R,
    frames: Frames,
    /// Context window recorded in the header.
    ct: usize,
    /// Entropy backend recorded in the header (tag + flag bits, cross-
    /// checked at open).
    codec: Codec,
    /// Bytes consumed from `inner` (validates the v2 trailer offset).
    consumed: u64,
    crc: Crc32,
    total_out: u64,
    /// Current decoded chunk being served to `read`.
    chunk: Vec<u8>,
    pos: usize,
    done: bool,
    /// Recycles frame-payload buffers across lane groups (the reader's
    /// steady-state allocation). Honors `LLMZIP_POOL=0`.
    pool: BytePool,
}

impl<'c, R: Read> DecompressReader<'c, R> {
    /// Open a session: reads + validates the container header (either
    /// version) before returning, so tag/precision mismatches fail here,
    /// not after megabytes of decoding.
    pub(crate) fn new(comp: &'c LlmCompressor, inner: R) -> Result<DecompressReader<'c, R>> {
        let mut r = DecompressReader {
            comp,
            inner,
            frames: Frames::V2 { seen: Vec::new() },
            ct: 0,
            codec: Codec::Range,
            consumed: 0,
            crc: Crc32::new(),
            total_out: 0,
            chunk: Vec::new(),
            pos: 0,
            done: false,
            pool: BytePool::new(16),
        };
        if r.read_u32()? != CONTAINER_MAGIC {
            anyhow::bail!("bad container magic");
        }
        let version = r.read_u16()?;
        let flags = r.read_u16()?;
        // One definition of the known flag bits (shared with
        // `Container::from_bytes`), so the two decode faces cannot drift.
        crate::compress::container::check_flags(version, flags)?;
        match version {
            CONTAINER_V1 => {
                let orig_len = r.read_u64()?;
                let orig_crc32 = r.read_u32()?;
                let chunk_tokens = r.read_u32()? as usize;
                let name = r.read_name()?;
                (r.ct, r.codec) = comp.validate_tag_and_window(&name, chunk_tokens, flags)?;
                let n_chunks = r.read_u32()? as usize;
                let mut table = Vec::with_capacity(n_chunks.min(1 << 20));
                let mut total_tokens = 0u64;
                for _ in 0..n_chunks {
                    let rec =
                        ChunkRecord { comp_len: r.read_u32()?, n_tokens: r.read_u32()? };
                    Self::check_record(rec)?;
                    total_tokens += rec.n_tokens as u64;
                    table.push(rec);
                }
                if total_tokens != orig_len {
                    anyhow::bail!(
                        "chunk token sum {total_tokens} != original length {orig_len}"
                    );
                }
                r.frames = Frames::V1 { table, next: 0, orig_len, orig_crc32 };
            }
            CONTAINER_V2 => {
                let chunk_tokens = r.read_u32()? as usize;
                let name = r.read_name()?;
                (r.ct, r.codec) = comp.validate_tag_and_window(&name, chunk_tokens, flags)?;
            }
            v => anyhow::bail!("unsupported container version {v}"),
        }
        Ok(r)
    }

    fn check_record(rec: ChunkRecord) -> Result<()> {
        if rec.comp_len > MAX_FRAME_BYTES || rec.n_tokens > MAX_FRAME_BYTES {
            anyhow::bail!(
                "frame claims {} compressed / {} original bytes — corrupt or hostile",
                rec.comp_len,
                rec.n_tokens
            );
        }
        Ok(())
    }

    fn read_exact(&mut self, buf: &mut [u8]) -> Result<()> {
        self.inner.read_exact(buf)?;
        self.consumed += buf.len() as u64;
        Ok(())
    }

    fn read_u8(&mut self) -> Result<u8> {
        let mut b = [0u8; 1];
        self.read_exact(&mut b)?;
        Ok(b[0])
    }

    fn read_u16(&mut self) -> Result<u16> {
        let mut b = [0u8; 2];
        self.read_exact(&mut b)?;
        Ok(u16::from_le_bytes(b))
    }

    fn read_u32(&mut self) -> Result<u32> {
        let mut b = [0u8; 4];
        self.read_exact(&mut b)?;
        Ok(u32::from_le_bytes(b))
    }

    fn read_u64(&mut self) -> Result<u64> {
        let mut b = [0u8; 8];
        self.read_exact(&mut b)?;
        Ok(u64::from_le_bytes(b))
    }

    fn read_name(&mut self) -> Result<String> {
        let len = self.read_u8()? as usize;
        let mut buf = vec![0u8; len];
        self.read_exact(&mut buf)?;
        String::from_utf8(buf).map_err(|_| anyhow::anyhow!("model name is not UTF-8"))
    }

    /// Decode a group of frames (≤ engine lanes) in one batched engine
    /// pass — the reader's lane parallelism. Output order is frame order,
    /// so the served byte stream is unaffected.
    fn decode_group(&mut self, group: Vec<(ChunkRecord, PooledBuf)>) -> Result<()> {
        let records: Vec<ChunkRecord> = group.iter().map(|(r, _)| *r).collect();
        let payloads: Vec<&[u8]> = group.iter().map(|(_, p)| p.as_slice()).collect();
        let codecs = vec![self.codec; payloads.len()];
        let decoded = self.comp.decompress_chunks(self.ct, &records, &payloads, &codecs)?;
        self.chunk.clear();
        for d in decoded {
            self.chunk.extend_from_slice(&d);
        }
        self.pos = 0;
        self.crc.update(&self.chunk);
        self.total_out += self.chunk.len() as u64;
        Ok(())
    }

    /// Whole-stream integrity: recorded length + CRC, then EOF.
    fn verify_end(&mut self, orig_len: u64, orig_crc32: u32) -> Result<()> {
        if self.total_out != orig_len {
            anyhow::bail!("decompressed length {} != recorded {orig_len}", self.total_out);
        }
        let crc = self.crc.finalize();
        if crc != orig_crc32 {
            anyhow::bail!("CRC mismatch: {crc:#010x} != {orig_crc32:#010x}");
        }
        let mut probe = [0u8; 1];
        if self.inner.read(&mut probe)? != 0 {
            anyhow::bail!("trailing garbage after the container");
        }
        self.done = true;
        Ok(())
    }

    /// Validate the v2 trailer (whose marker was consumed at byte offset
    /// `marker_off`) against everything the stream carried, then verify
    /// totals + EOF.
    fn read_and_verify_trailer(&mut self, marker_off: u64) -> Result<()> {
        let n_chunks = self.read_u32()? as usize;
        // Only the v2 arm of `next_chunk` calls this, but the input is
        // hostile bytes: report state confusion as a decode error rather
        // than panicking mid-stream.
        let seen_count = match &self.frames {
            Frames::V2 { seen } => seen.len(),
            Frames::V1 { .. } => anyhow::bail!("v2 trailer encountered in a v1 container"),
        };
        if n_chunks != seen_count {
            anyhow::bail!("trailer counts {n_chunks} chunks, stream carried {seen_count}");
        }
        for i in 0..n_chunks {
            let rec = ChunkRecord { comp_len: self.read_u32()?, n_tokens: self.read_u32()? };
            let matches = match &self.frames {
                Frames::V2 { seen } => rec == seen[i],
                Frames::V1 { .. } => false,
            };
            if !matches {
                anyhow::bail!(
                    "trailer index entry {i} disagrees with the stream's frame header"
                );
            }
        }
        let orig_len = self.read_u64()?;
        let orig_crc32 = self.read_u32()?;
        let trailer_off = self.read_u64()?;
        if trailer_off != marker_off {
            anyhow::bail!(
                "trailer records offset {trailer_off}, stream position is {marker_off}"
            );
        }
        if self.read_u32()? != crate::compress::container::CONTAINER_END_MAGIC {
            anyhow::bail!("bad container end magic");
        }
        self.verify_end(orig_len, orig_crc32)
    }

    /// Advance by up to one LANE GROUP of frames (or verify the trailer
    /// and mark the stream done). Grouping frames per engine pass is the
    /// reader's lane parallelism; memory stays bounded by
    /// `lanes × stream granularity`.
    fn next_chunk(&mut self) -> Result<()> {
        let lanes = self.comp.lanes().max(1);
        match &mut self.frames {
            Frames::V1 { table, next, orig_len, orig_crc32 } => {
                if *next < table.len() {
                    let hi = (*next + lanes).min(table.len());
                    let records: Vec<ChunkRecord> = table[*next..hi].to_vec();
                    *next = hi;
                    let mut group = Vec::with_capacity(records.len());
                    for rec in records {
                        let mut payload = self.pool.take(rec.comp_len as usize);
                        payload.resize(rec.comp_len as usize, 0);
                        self.read_exact(&mut payload)?;
                        group.push((rec, payload));
                    }
                    self.decode_group(group)?;
                } else {
                    let (l, c) = (*orig_len, *orig_crc32);
                    self.verify_end(l, c)?;
                }
            }
            Frames::V2 { .. } => {
                let mut group: Vec<(ChunkRecord, PooledBuf)> = Vec::new();
                let mut trailer_at: Option<u64> = None;
                while group.len() < lanes && trailer_at.is_none() {
                    let marker_off = self.consumed;
                    match self.read_u8()? {
                        FRAME_MARKER => {
                            let rec = ChunkRecord {
                                comp_len: self.read_u32()?,
                                n_tokens: self.read_u32()?,
                            };
                            Self::check_record(rec)?;
                            let mut payload = self.pool.take(rec.comp_len as usize);
                            payload.resize(rec.comp_len as usize, 0);
                            self.read_exact(&mut payload)?;
                            group.push((rec, payload));
                        }
                        TRAILER_MARKER => trailer_at = Some(marker_off),
                        b => anyhow::bail!(
                            "corrupt container: unexpected frame marker {b:#04x}"
                        ),
                    }
                }
                if !group.is_empty() {
                    // The enclosing match arm proved v2; losing that state
                    // mid-group is a bug, but this path decodes hostile
                    // bytes, so it reports instead of panicking.
                    match &mut self.frames {
                        Frames::V2 { seen } => seen.extend(group.iter().map(|(r, _)| *r)),
                        Frames::V1 { .. } => {
                            anyhow::bail!("decoder lost v2 framing state mid-stream")
                        }
                    }
                    self.decode_group(group)?;
                }
                if let Some(marker_off) = trailer_at {
                    self.read_and_verify_trailer(marker_off)?;
                }
            }
        }
        Ok(())
    }

    /// Decoded bytes produced so far.
    pub fn bytes_out(&self) -> u64 {
        self.total_out
    }

    /// True once the trailer has been reached and length/CRC verified.
    pub fn verified(&self) -> bool {
        self.done
    }
}

impl<R: Read> Read for DecompressReader<'_, R> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        while self.pos == self.chunk.len() && !self.done {
            self.next_chunk().map_err(to_io)?;
        }
        if self.pos == self.chunk.len() {
            return Ok(0);
        }
        let n = buf.len().min(self.chunk.len() - self.pos);
        buf[..n].copy_from_slice(&self.chunk[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

impl LlmCompressor {
    /// Open an incremental compression session writing a container-v2
    /// stream into `inner`. Bytes identical to
    /// [`Compressor::compress`](crate::compress::Compressor::compress) of
    /// the concatenated input, for any write pattern.
    pub fn stream_compress<W: Write>(&self, inner: W) -> Result<CompressWriter<'_, W>> {
        CompressWriter::new(self, inner)
    }

    /// Open an incremental decompression session over a container stream
    /// (either version). Reading to EOF yields the verified original
    /// bytes, one chunk in memory at a time.
    pub fn stream_decompress<R: Read>(&self, inner: R) -> Result<DecompressReader<'_, R>> {
        DecompressReader::new(self, inner)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Compressor as _, LlmCompressorConfig};
    use crate::lm::config::by_name;
    use crate::lm::executor::ExecutorKind;
    use crate::lm::weights::{Precision, Weights};

    fn compressor() -> LlmCompressor {
        let cfg = by_name("nano").unwrap();
        LlmCompressor::from_shared(
            cfg,
            std::sync::Arc::new(Weights::random(cfg, 7)),
            LlmCompressorConfig {
                model: cfg.name.into(),
                chunk_tokens: 32,
                stream_bytes: 128,
                executor: ExecutorKind::Native,
                lanes: 2,
                threads: 1,
                precision: Precision::F32,
                ..Default::default()
            },
        )
        .unwrap()
    }

    #[test]
    fn writer_bytes_identical_to_one_shot() {
        let c = compressor();
        let data = crate::textgen::quick_sample(700, 3);
        let golden = c.compress(&data).unwrap();
        // Several write patterns, including empty writes and straddles.
        for splits in [
            vec![700usize],
            vec![1; 700],
            vec![0, 127, 1, 0, 128, 300, 144],
            vec![129, 127, 444],
        ] {
            let mut w = c.stream_compress(Vec::new()).unwrap();
            let mut off = 0;
            for s in splits {
                w.write_bytes(&data[off..off + s]).unwrap();
                off += s;
            }
            assert_eq!(off, 700);
            let (out, summary) = w.finish().unwrap();
            assert_eq!(out, golden);
            assert_eq!(summary.bytes_in, 700);
            assert_eq!(summary.bytes_out, golden.len() as u64);
            assert_eq!(summary.chunks, 6);
        }
    }

    #[test]
    fn fse_writer_bytes_identical_to_one_shot_and_verified_roundtrip() {
        let c = compressor().with_codec(Codec::Fse);
        let data = crate::textgen::quick_sample(700, 3);
        let golden = c.compress(&data).unwrap();
        let mut w = c.stream_compress(Vec::new()).unwrap();
        for chunk in data.chunks(97) {
            w.write_bytes(chunk).unwrap();
        }
        let (out, _) = w.finish().unwrap();
        assert_eq!(out, golden, "streaming FSE container must match one-shot");
        let mut r = c.stream_decompress(&out[..]).unwrap();
        let mut back = Vec::new();
        r.read_to_end(&mut back).unwrap();
        assert_eq!(back, data);
        assert!(r.verified());
        // A range-configured compressor decodes the FSE stream too: the
        // codec is the container's property, not the engine's.
        let range_side = compressor();
        let mut r = range_side.stream_decompress(&out[..]).unwrap();
        let mut back = Vec::new();
        r.read_to_end(&mut back).unwrap();
        assert_eq!(back, data);
    }

    #[test]
    fn empty_stream_matches_one_shot_empty() {
        let c = compressor();
        let golden = c.compress(b"").unwrap();
        let (out, summary) = c.stream_compress(Vec::new()).unwrap().finish().unwrap();
        assert_eq!(out, golden);
        assert_eq!(summary.chunks, 0);
        // And it reads back as nothing, verified.
        let mut r = c.stream_decompress(&out[..]).unwrap();
        let mut back = Vec::new();
        r.read_to_end(&mut back).unwrap();
        assert!(back.is_empty());
        assert!(r.verified());
    }

    #[test]
    fn reader_roundtrips_both_versions_with_tiny_reads() {
        let c = compressor();
        let data = crate::textgen::quick_sample(500, 4);
        let v2 = c.compress(&data).unwrap();
        let v1 = {
            let mut cont = Container::from_bytes(&v2).unwrap();
            cont.version = CONTAINER_V1;
            cont.flags = 0;
            cont.to_bytes()
        };
        for (name, z) in [("v2", &v2), ("v1", &v1)] {
            let mut r = c.stream_decompress(&z[..]).unwrap();
            let mut back = Vec::new();
            let mut tiny = [0u8; 3];
            loop {
                let n = r.read(&mut tiny).unwrap();
                if n == 0 {
                    break;
                }
                back.extend_from_slice(&tiny[..n]);
            }
            assert_eq!(back, data, "{name}");
            assert!(r.verified(), "{name}");
        }
    }

    #[test]
    fn reader_recycles_payload_buffers_across_lane_groups() {
        let c = compressor();
        // 900 bytes at stream_bytes=128 → 8 frames; lanes=2 → 4 groups, so
        // the second and later groups must hit the recycler.
        let data = crate::textgen::quick_sample(900, 8);
        let z = c.compress(&data).unwrap();
        let mut r = c.stream_decompress(&z[..]).unwrap();
        let mut back = Vec::new();
        r.read_to_end(&mut back).unwrap();
        assert_eq!(back, data);
        assert!(r.verified());
        if r.pool.is_enabled() {
            let stats = r.pool.stats();
            assert!(stats.hits > 0, "expected payload buffer reuse, got {stats:?}");
        }
    }

    #[test]
    fn reader_rejects_corruption_and_truncation() {
        let c = compressor();
        let data = crate::textgen::quick_sample(400, 5);
        let z = c.compress(&data).unwrap();
        // Truncation: reading must error, not return short data silently.
        let mut r = c.stream_decompress(&z[..z.len() - 10]).unwrap();
        let mut sink = Vec::new();
        assert!(r.read_to_end(&mut sink).is_err());
        // Flipped payload byte: CRC (or coder structure) must catch it.
        let mut bad = z.clone();
        bad[z.len() / 2] ^= 0x20;
        let mut sink = Vec::new();
        if let Ok(mut r) = c.stream_decompress(&bad[..]) {
            assert!(r.read_to_end(&mut sink).is_err());
        }
        // Trailing garbage after a valid container.
        let mut noisy = z.clone();
        noisy.push(0xAA);
        let mut r = c.stream_decompress(&noisy[..]).unwrap();
        assert!(r.read_to_end(&mut sink).is_err());
    }

    #[test]
    fn wrong_engine_rejected_at_open_not_after_decode() {
        let c = compressor();
        let data = crate::textgen::quick_sample(200, 6);
        let mut cont = Container::from_bytes(&c.compress(&data).unwrap()).unwrap();
        cont.model_name = "tiny:0".into();
        let err = match c.stream_decompress(&cont.to_bytes()[..]) {
            Err(e) => e.to_string(),
            Ok(_) => panic!("foreign tag must fail at open"),
        };
        assert!(err.contains("model"), "{err}");
    }

    #[test]
    fn one_shot_compress_emits_v2_and_v1_still_decodes() {
        let c = compressor();
        let data = crate::textgen::quick_sample(300, 7);
        let z = c.compress(&data).unwrap();
        let cont = Container::from_bytes(&z).unwrap();
        assert_eq!(cont.version, CONTAINER_V2);
        // Same payload re-enveloped as v1 decodes to the same bytes.
        let mut v1 = cont.clone();
        v1.version = CONTAINER_V1;
        v1.flags = 0;
        assert_eq!(c.decompress(&v1.to_bytes()).unwrap(), data);
        assert_eq!(c.decompress(&z).unwrap(), data);
    }
}
