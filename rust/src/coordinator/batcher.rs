//! Chunk-level dynamic batcher with priority-aware admission.
//!
//! Work items (one per chunk) accumulate in per-kind queues; a batch is
//! released when either `lanes` items are waiting (full batch) or the
//! oldest item has waited `max_wait` (deadline flush). This is the
//! standard continuous-batching admission policy of LLM serving systems,
//! applied to compression chunks, with two scheduling refinements:
//!
//! * **Decompress fast lane** — when both kinds have a releasable batch,
//!   decompress wins: interactive reads never sit behind bulk compress
//!   jobs (the queues cannot share an engine pass anyway). A starvation
//!   bound keeps the lane from being absolute: once compress's oldest
//!   item has waited [`DynamicBatcher::starvation_bound`], compress goes
//!   first regardless, so sustained decompress load cannot block
//!   compress forever.
//! * **Per-item priority** — within a kind, [`Priority::Interactive`]
//!   items drain ahead of [`Priority::Bulk`] items, FIFO inside each
//!   class, so a latency-sensitive compress request can overtake a bulk
//!   ingest job without a separate queueing tier.
//! * **Per-tenant weighted fair queueing** — within a (kind, class), items
//!   are kept in per-tenant FIFO lanes scheduled by start-time fair
//!   queueing: each lane carries a virtual time that advances by
//!   `bytes / weight` per popped item, and the lane with the smallest
//!   virtual time goes next. Backlogged tenants therefore share engine
//!   bytes in proportion to their weights, and no backlogged tenant can
//!   be starved (its virtual time stands still while others advance). A
//!   single-tenant server degenerates to one lane — plain FIFO, exactly
//!   the pre-fleet behavior.

use crate::compress::container::{ChunkRecord, Codec};
use crate::util::PooledBuf;
use std::collections::{HashMap, VecDeque};
use std::time::{Duration, Instant};

/// What kind of engine pass a work item needs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum WorkKind {
    Compress,
    Decompress,
}

/// Scheduling class of a work item within its kind queue.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Priority {
    /// Latency-sensitive: drains ahead of every queued [`Priority::Bulk`]
    /// item of the same kind. Decompress requests default to this.
    Interactive,
    /// Throughput work: compress requests default to this.
    Bulk,
}

/// One chunk of one request.
#[derive(Clone, Debug)]
pub struct WorkItem {
    pub request_id: u64,
    pub chunk_index: u32,
    pub kind: WorkKind,
    pub priority: Priority,
    /// Owning tenant (`0` = the default/anonymous tenant). Items of the
    /// same tenant stay FIFO within their (kind, class); across tenants
    /// the batcher schedules by weighted fair queueing.
    pub tenant: u32,
    /// Compress: raw bytes. Decompress: compressed payload. Rides a
    /// pool-recycled buffer: when the item is dropped after its batch
    /// completes, the storage returns to the server's [`BytePool`]
    /// (detached plain vectors convert with `.into()`).
    ///
    /// [`BytePool`]: crate::util::BytePool
    pub data: PooledBuf,
    /// Decompress only: the chunk record (token count).
    pub record: Option<ChunkRecord>,
    /// Entropy backend of this chunk's payload. Compress: the engine's
    /// configured codec. Decompress: the *container's* recorded codec —
    /// per item, so one engine batch may mix range and FSE chunks.
    pub codec: Codec,
    pub enqueued: Instant,
}

/// Admission policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Engine lane count (maximum batch size).
    pub lanes: usize,
    /// Deadline: flush a partial batch once the oldest item is this old.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { lanes: 8, max_wait: Duration::from_millis(20) }
    }
}

/// Virtual-time scale: one popped byte advances a weight-1 lane's virtual
/// time by this much, so integer division by the weight keeps resolution.
const VT_SCALE: u64 = 1024;

/// One tenant's backlog within a kind: two FIFO classes plus the lane's
/// weighted-fair virtual time.
struct TenantLane {
    tenant: u32,
    interactive: VecDeque<WorkItem>,
    bulk: VecDeque<WorkItem>,
    /// Start-time-fair-queueing tag: advances by `bytes * VT_SCALE /
    /// weight` per popped item. The lane with the smallest tag goes next
    /// within its class.
    vtime: u64,
}

impl TenantLane {
    fn len(&self) -> usize {
        self.interactive.len() + self.bulk.len()
    }

    fn class(&self, p: Priority) -> &VecDeque<WorkItem> {
        match p {
            Priority::Interactive => &self.interactive,
            Priority::Bulk => &self.bulk,
        }
    }

    fn class_mut(&mut self, p: Priority) -> &mut VecDeque<WorkItem> {
        match p {
            Priority::Interactive => &mut self.interactive,
            Priority::Bulk => &mut self.bulk,
        }
    }
}

/// One kind's queue: per-tenant WFQ lanes, each split into two FIFO
/// classes; interactive drains first across lanes.
#[derive(Default)]
struct KindQueue {
    lanes: Vec<TenantLane>,
    /// Virtual clock: the tag of the most recently served lane. A lane
    /// going from empty to backlogged starts no earlier than this, so an
    /// idle tenant cannot bank virtual time and then monopolize the
    /// queue.
    vclock: u64,
}

impl KindQueue {
    fn len(&self) -> usize {
        self.lanes.iter().map(TenantLane::len).sum()
    }

    /// Enqueue time of the oldest item across all lanes and classes.
    fn oldest(&self) -> Option<Instant> {
        self.lanes
            .iter()
            .flat_map(|l| [l.interactive.front(), l.bulk.front()])
            .flatten()
            .map(|i| i.enqueued)
            .min()
    }

    fn push(&mut self, item: WorkItem) {
        let idx = match self.lanes.iter().position(|l| l.tenant == item.tenant) {
            Some(i) => i,
            None => {
                self.lanes.push(TenantLane {
                    tenant: item.tenant,
                    interactive: VecDeque::new(),
                    bulk: VecDeque::new(),
                    vtime: 0,
                });
                self.lanes.len() - 1
            }
        };
        let lane = &mut self.lanes[idx];
        if lane.len() == 0 {
            // Newly backlogged: catch the lane up to the virtual clock.
            lane.vtime = lane.vtime.max(self.vclock);
        }
        lane.class_mut(item.priority).push_back(item);
    }

    /// Index of the non-empty `class` lane with the smallest virtual time
    /// (ties break on registration order, so selection is deterministic).
    fn min_vtime_lane(&self, class: Priority) -> Option<usize> {
        self.lanes
            .iter()
            .enumerate()
            .filter(|(_, l)| !l.class(class).is_empty())
            .min_by_key(|(i, l)| (l.vtime, *i))
            .map(|(i, _)| i)
    }

    /// Pop one item of `class` from the fairest lane and charge its lane.
    fn pop_fair(&mut self, class: Priority, weights: &HashMap<u32, u64>) -> Option<WorkItem> {
        let idx = self.min_vtime_lane(class)?;
        let lane = &mut self.lanes[idx];
        let item = lane.class_mut(class).pop_front().expect("lane selected non-empty");
        let weight = weights.get(&lane.tenant).copied().unwrap_or(1).max(1);
        let cost = (item.data.len() as u64).max(1);
        self.vclock = lane.vtime;
        lane.vtime = lane.vtime.saturating_add(cost.saturating_mul(VT_SCALE) / weight);
        Some(item)
    }

    /// Pop up to `n` items, interactive class first — unless bulk's oldest
    /// item has aged past `starve_after`, in which case bulk drains first
    /// this batch so a sustained interactive flood cannot starve it.
    /// Within each class, lanes interleave by weighted fair queueing.
    fn pop_batch(
        &mut self,
        n: usize,
        now: Instant,
        starve_after: Duration,
        weights: &HashMap<u32, u64>,
    ) -> Vec<WorkItem> {
        let bulk_starving = self
            .lanes
            .iter()
            .filter_map(|l| l.bulk.front())
            .any(|i| now.duration_since(i.enqueued) >= starve_after);
        let order = if bulk_starving {
            [Priority::Bulk, Priority::Interactive]
        } else {
            [Priority::Interactive, Priority::Bulk]
        };
        let mut batch = Vec::new();
        for class in order {
            while batch.len() < n {
                match self.pop_fair(class, weights) {
                    Some(item) => batch.push(item),
                    None => break,
                }
            }
        }
        batch
    }
}

/// The batcher: two kind queues (compress/decompress passes cannot share
/// an engine batch), each split into interactive/bulk priority classes.
pub struct DynamicBatcher {
    policy: BatchPolicy,
    compress_q: KindQueue,
    decompress_q: KindQueue,
    /// WFQ weight per tenant id; unlisted tenants (including the default
    /// tenant `0`) weigh 1.
    tenant_weights: HashMap<u32, u64>,
}

impl DynamicBatcher {
    pub fn new(policy: BatchPolicy) -> Self {
        DynamicBatcher {
            policy,
            compress_q: KindQueue::default(),
            decompress_q: KindQueue::default(),
            tenant_weights: HashMap::new(),
        }
    }

    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    /// Set one tenant's WFQ weight (relative share of engine bytes while
    /// backlogged). Weight `0` is clamped to 1; unset tenants weigh 1.
    pub fn set_tenant_weight(&mut self, tenant: u32, weight: u64) {
        self.tenant_weights.insert(tenant, weight.max(1));
    }

    pub fn push(&mut self, item: WorkItem) {
        match item.kind {
            WorkKind::Compress => self.compress_q.push(item),
            WorkKind::Decompress => self.decompress_q.push(item),
        }
    }

    pub fn pending(&self) -> usize {
        self.compress_q.len() + self.decompress_q.len()
    }

    /// How long a compress item may wait before it overrides the
    /// decompress fast lane (anti-starvation bound).
    pub fn starvation_bound(&self) -> Duration {
        (self.policy.max_wait * 8).max(Duration::from_millis(50))
    }

    /// Pop the next batch if the policy releases one at time `now`. The
    /// decompress queue is the fast lane: when both kinds are releasable,
    /// decompress goes first — unless compress's oldest item has aged
    /// past [`Self::starvation_bound`], which forces a compress batch so
    /// sustained decompress load cannot starve compress indefinitely.
    pub fn next_batch(&mut self, now: Instant) -> Option<(WorkKind, Vec<WorkItem>)> {
        let (lanes, max_wait) = (self.policy.lanes, self.policy.max_wait);
        let ready = |q: &KindQueue| -> bool {
            q.len() >= lanes || q.oldest().is_some_and(|t| now.duration_since(t) >= max_wait)
        };
        let starve_after = self.starvation_bound();
        let compress_starving =
            self.compress_q.oldest().is_some_and(|t| now.duration_since(t) >= starve_after);
        let (q, kind) = if ready(&self.decompress_q) && !compress_starving {
            (&mut self.decompress_q, WorkKind::Decompress)
        } else if ready(&self.compress_q) {
            (&mut self.compress_q, WorkKind::Compress)
        } else if ready(&self.decompress_q) {
            (&mut self.decompress_q, WorkKind::Decompress)
        } else {
            return None;
        };
        let n = q.len().min(lanes);
        Some((kind, q.pop_batch(n, now, starve_after, &self.tenant_weights)))
    }

    /// Earliest deadline among queued items (for the scheduler's sleep).
    pub fn next_deadline(&self) -> Option<Instant> {
        let c = self.compress_q.oldest().map(|t| t + self.policy.max_wait);
        let d = self.decompress_q.oldest().map(|t| t + self.policy.max_wait);
        match (c, d) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn item(id: u64, kind: WorkKind, at: Instant) -> WorkItem {
        WorkItem {
            request_id: id,
            chunk_index: 0,
            kind,
            priority: Priority::Bulk,
            tenant: 0,
            data: vec![1, 2, 3].into(),
            record: None,
            codec: Codec::Range,
            enqueued: at,
        }
    }

    #[test]
    fn full_batch_releases_immediately() {
        let mut b = DynamicBatcher::new(BatchPolicy { lanes: 3, max_wait: Duration::from_secs(10) });
        let now = Instant::now();
        for i in 0..3 {
            b.push(item(i, WorkKind::Compress, now));
        }
        let (kind, batch) = b.next_batch(now).expect("full batch");
        assert_eq!(kind, WorkKind::Compress);
        assert_eq!(batch.len(), 3);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn partial_batch_waits_for_deadline() {
        let mut b =
            DynamicBatcher::new(BatchPolicy { lanes: 4, max_wait: Duration::from_millis(50) });
        let t0 = Instant::now();
        b.push(item(1, WorkKind::Compress, t0));
        assert!(b.next_batch(t0).is_none(), "too early");
        let later = t0 + Duration::from_millis(51);
        let (_, batch) = b.next_batch(later).expect("deadline flush");
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn kinds_never_mix() {
        let mut b = DynamicBatcher::new(BatchPolicy { lanes: 2, max_wait: Duration::ZERO });
        let now = Instant::now();
        b.push(item(1, WorkKind::Compress, now));
        b.push(item(2, WorkKind::Decompress, now));
        let (k1, b1) = b.next_batch(now + Duration::from_millis(1)).unwrap();
        let (k2, b2) = b.next_batch(now + Duration::from_millis(1)).unwrap();
        assert_ne!(k1, k2);
        assert_eq!(b1.len(), 1);
        assert_eq!(b2.len(), 1);
    }

    #[test]
    fn decompress_fast_lane_wins_even_when_younger() {
        let mut b = DynamicBatcher::new(BatchPolicy { lanes: 8, max_wait: Duration::ZERO });
        let t0 = Instant::now();
        b.push(item(1, WorkKind::Compress, t0));
        b.push(item(2, WorkKind::Decompress, t0 + Duration::from_millis(5)));
        let (kind, _) = b.next_batch(t0 + Duration::from_millis(10)).unwrap();
        assert_eq!(kind, WorkKind::Decompress, "decompress is the fast lane");
        let (kind, _) = b.next_batch(t0 + Duration::from_millis(10)).unwrap();
        assert_eq!(kind, WorkKind::Compress);
    }

    #[test]
    fn starving_compress_overrides_fast_lane() {
        // Decompress arrives continuously, but once compress's oldest item
        // ages past the starvation bound it must be scheduled anyway.
        let mut b = DynamicBatcher::new(BatchPolicy { lanes: 8, max_wait: Duration::ZERO });
        let t0 = Instant::now();
        b.push(item(1, WorkKind::Compress, t0));
        b.push(item(2, WorkKind::Decompress, t0 + Duration::from_millis(1)));
        let starved = t0 + b.starvation_bound() + Duration::from_millis(1);
        let (kind, _) = b.next_batch(starved).unwrap();
        assert_eq!(kind, WorkKind::Compress, "aged compress beats the fast lane");
        let (kind, _) = b.next_batch(starved).unwrap();
        assert_eq!(kind, WorkKind::Decompress);
    }

    #[test]
    fn interactive_overtakes_bulk_within_kind() {
        let mut b = DynamicBatcher::new(BatchPolicy { lanes: 2, max_wait: Duration::ZERO });
        let t0 = Instant::now();
        for i in 0..3 {
            b.push(item(i, WorkKind::Compress, t0));
        }
        let mut hot = item(9, WorkKind::Compress, t0 + Duration::from_millis(1));
        hot.priority = Priority::Interactive;
        b.push(hot);
        let (_, batch) = b.next_batch(t0 + Duration::from_millis(2)).unwrap();
        // Interactive item jumps the three queued bulk items.
        assert_eq!(batch.iter().map(|i| i.request_id).collect::<Vec<_>>(), vec![9, 0]);
        let (_, batch) = b.next_batch(t0 + Duration::from_millis(2)).unwrap();
        assert_eq!(batch.iter().map(|i| i.request_id).collect::<Vec<_>>(), vec![1, 2]);
    }

    #[test]
    fn starving_bulk_overrides_interactive_class() {
        // A bulk item older than the starvation bound drains before fresh
        // interactive arrivals of the same kind.
        let mut b = DynamicBatcher::new(BatchPolicy { lanes: 1, max_wait: Duration::ZERO });
        let t0 = Instant::now();
        b.push(item(1, WorkKind::Compress, t0));
        let starved = t0 + b.starvation_bound() + Duration::from_millis(1);
        let mut hot = item(9, WorkKind::Compress, starved);
        hot.priority = Priority::Interactive;
        b.push(hot);
        let (_, batch) = b.next_batch(starved).unwrap();
        assert_eq!(batch[0].request_id, 1, "aged bulk item goes first");
        let (_, batch) = b.next_batch(starved).unwrap();
        assert_eq!(batch[0].request_id, 9);
    }

    #[test]
    fn fifo_within_queue_and_lane_cap() {
        let mut b = DynamicBatcher::new(BatchPolicy { lanes: 2, max_wait: Duration::ZERO });
        let now = Instant::now();
        for i in 0..5 {
            b.push(item(i, WorkKind::Compress, now));
        }
        let (_, batch) = b.next_batch(now).unwrap();
        assert_eq!(batch.iter().map(|i| i.request_id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(b.pending(), 3);
    }

    #[test]
    fn wfq_weighted_shares_within_tolerance() {
        // Two backlogged tenants, weights 3:1, equal item sizes: over a
        // long drain, popped items interleave near the 3:1 share. Assert
        // the first half of the drain honors the ratio within tolerance.
        let mut b = DynamicBatcher::new(BatchPolicy { lanes: 4, max_wait: Duration::ZERO });
        b.set_tenant_weight(1, 3);
        b.set_tenant_weight(2, 1);
        let t0 = Instant::now();
        for i in 0..200u64 {
            for tenant in [1u32, 2] {
                let mut it = item(i, WorkKind::Compress, t0);
                it.tenant = tenant;
                it.chunk_index = i as u32;
                b.push(it);
            }
        }
        let mut first_half = Vec::new();
        while first_half.len() < 200 {
            let (_, batch) = b.next_batch(t0).expect("backlogged");
            first_half.extend(batch);
        }
        let heavy = first_half.iter().filter(|i| i.tenant == 1).count();
        let light = first_half.len() - heavy;
        // Ideal split of the first 200 pops is 150/50; allow slack for
        // batch-boundary rounding.
        assert!(
            (140..=160).contains(&heavy),
            "weight-3 tenant got {heavy} of {} pops",
            first_half.len()
        );
        assert!(light > 0, "weight-1 tenant must not starve");
        // Everything still drains (work conservation).
        let mut total = first_half.len();
        while let Some((_, batch)) = b.next_batch(t0) {
            total += batch.len();
        }
        assert_eq!(total, 400);
    }

    #[test]
    fn wfq_no_tenant_starves_and_fifo_holds_per_tenant() {
        // Heavily weighted tenant 1 vs weight-1 tenant 2: tenant 2 still
        // progresses every few batches, and each tenant's items stay FIFO.
        let mut b = DynamicBatcher::new(BatchPolicy { lanes: 2, max_wait: Duration::ZERO });
        b.set_tenant_weight(1, 8);
        let t0 = Instant::now();
        for i in 0..40u64 {
            let mut it = item(i, WorkKind::Compress, t0);
            it.tenant = if i % 4 == 0 { 2 } else { 1 };
            it.chunk_index = i as u32;
            b.push(it);
        }
        let mut seen: HashMap<u32, Vec<u32>> = HashMap::new();
        while let Some((_, batch)) = b.next_batch(t0) {
            for it in batch {
                seen.entry(it.tenant).or_default().push(it.chunk_index);
            }
        }
        assert_eq!(seen.values().map(Vec::len).sum::<usize>(), 40);
        assert!(!seen[&2].is_empty(), "light tenant drained");
        for order in seen.values() {
            assert!(order.windows(2).all(|w| w[0] < w[1]), "FIFO within tenant");
        }
    }

    #[test]
    fn late_arriving_tenant_cannot_bank_virtual_time() {
        // Tenant 2 arrives after tenant 1 has drained many bytes; its
        // fresh lane starts at the virtual clock, so it shares from now on
        // instead of monopolizing the queue to "catch up".
        let mut b = DynamicBatcher::new(BatchPolicy { lanes: 1, max_wait: Duration::ZERO });
        let t0 = Instant::now();
        for i in 0..10u64 {
            let mut it = item(i, WorkKind::Compress, t0);
            it.tenant = 1;
            b.push(it);
        }
        for _ in 0..10 {
            b.next_batch(t0).expect("tenant 1 backlog");
        }
        for i in 10..14u64 {
            for tenant in [1u32, 2] {
                let mut it = item(i, WorkKind::Compress, t0);
                it.tenant = tenant;
                b.push(it);
            }
        }
        let mut tenants = Vec::new();
        while let Some((_, batch)) = b.next_batch(t0) {
            tenants.extend(batch.into_iter().map(|i| i.tenant));
        }
        // Equal weights, equal sizes: strict alternation, not a burst of
        // tenant-2 items first.
        let t2_lead = tenants.iter().take_while(|&&t| t == 2).count();
        assert!(t2_lead <= 1, "late tenant burst: {tenants:?}");
        assert_eq!(tenants.len(), 8);
    }

    #[test]
    fn randomized_never_exceeds_lanes_and_preserves_order() {
        // Hand-rolled property test: any arrival pattern yields batches that
        // respect the lane cap and per-class FIFO order.
        let mut rng = crate::util::Pcg64::seeded(42);
        for _ in 0..50 {
            let lanes = 1 + rng.gen_index(8);
            let mut b = DynamicBatcher::new(BatchPolicy {
                lanes,
                max_wait: Duration::from_millis(rng.gen_range(5) ),
            });
            let t0 = Instant::now();
            let n = rng.gen_index(40);
            for i in 0..n {
                let kind =
                    if rng.gen_bool(0.5) { WorkKind::Compress } else { WorkKind::Decompress };
                let mut it = item(1, kind, t0 + Duration::from_micros(i as u64));
                it.chunk_index = i as u32;
                if rng.gen_bool(0.3) {
                    it.priority = Priority::Interactive;
                }
                b.push(it);
            }
            let mut seen: std::collections::HashMap<(WorkKind, Priority), Vec<u32>> =
                std::collections::HashMap::new();
            let late = t0 + Duration::from_secs(1);
            let mut popped = 0usize;
            while let Some((kind, batch)) = b.next_batch(late) {
                assert!(batch.len() <= lanes);
                popped += batch.len();
                for it in batch {
                    seen.entry((kind, it.priority)).or_default().push(it.chunk_index);
                }
            }
            for order in seen.values() {
                assert!(order.windows(2).all(|w| w[0] < w[1]), "FIFO within kind+class");
            }
            assert_eq!(popped, n);
            assert_eq!(b.pending(), 0);
        }
    }
}
