//! Chunk-level dynamic batcher.
//!
//! Work items (one per chunk) accumulate in a queue; a batch is released
//! when either `lanes` items are waiting (full batch) or the oldest item
//! has waited `max_wait` (deadline flush). This is the standard
//! continuous-batching admission policy of LLM serving systems, applied to
//! compression chunks.

use crate::compress::container::ChunkRecord;
use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// What kind of engine pass a work item needs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkKind {
    Compress,
    Decompress,
}

/// One chunk of one request.
#[derive(Clone, Debug)]
pub struct WorkItem {
    pub request_id: u64,
    pub chunk_index: u32,
    pub kind: WorkKind,
    /// Compress: raw bytes. Decompress: compressed payload.
    pub data: Vec<u8>,
    /// Decompress only: the chunk record (token count).
    pub record: Option<ChunkRecord>,
    pub enqueued: Instant,
}

/// Admission policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Engine lane count (maximum batch size).
    pub lanes: usize,
    /// Deadline: flush a partial batch once the oldest item is this old.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { lanes: 8, max_wait: Duration::from_millis(20) }
    }
}

/// The batcher: two queues (compress/decompress passes cannot share an
/// engine batch), FIFO within each.
pub struct DynamicBatcher {
    policy: BatchPolicy,
    compress_q: VecDeque<WorkItem>,
    decompress_q: VecDeque<WorkItem>,
}

impl DynamicBatcher {
    pub fn new(policy: BatchPolicy) -> Self {
        DynamicBatcher { policy, compress_q: VecDeque::new(), decompress_q: VecDeque::new() }
    }

    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    pub fn push(&mut self, item: WorkItem) {
        match item.kind {
            WorkKind::Compress => self.compress_q.push_back(item),
            WorkKind::Decompress => self.decompress_q.push_back(item),
        }
    }

    pub fn pending(&self) -> usize {
        self.compress_q.len() + self.decompress_q.len()
    }

    /// Pop the next batch if the policy releases one at time `now`.
    /// Longest-waiting queue wins ties so neither op starves.
    pub fn next_batch(&mut self, now: Instant) -> Option<(WorkKind, Vec<WorkItem>)> {
        let ready = |q: &VecDeque<WorkItem>, lanes: usize, max_wait: Duration| -> bool {
            q.len() >= lanes
                || q.front().is_some_and(|i| now.duration_since(i.enqueued) >= max_wait)
        };
        let c_ready = ready(&self.compress_q, self.policy.lanes, self.policy.max_wait);
        let d_ready = ready(&self.decompress_q, self.policy.lanes, self.policy.max_wait);
        let pick_compress = match (c_ready, d_ready) {
            (false, false) => return None,
            (true, false) => true,
            (false, true) => false,
            (true, true) => {
                let c_age = self.compress_q.front().map(|i| i.enqueued);
                let d_age = self.decompress_q.front().map(|i| i.enqueued);
                c_age <= d_age
            }
        };
        let (q, kind) = if pick_compress {
            (&mut self.compress_q, WorkKind::Compress)
        } else {
            (&mut self.decompress_q, WorkKind::Decompress)
        };
        let n = q.len().min(self.policy.lanes);
        Some((kind, q.drain(..n).collect()))
    }

    /// Earliest deadline among queued items (for the worker's sleep).
    pub fn next_deadline(&self) -> Option<Instant> {
        let c = self.compress_q.front().map(|i| i.enqueued + self.policy.max_wait);
        let d = self.decompress_q.front().map(|i| i.enqueued + self.policy.max_wait);
        match (c, d) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn item(id: u64, kind: WorkKind, at: Instant) -> WorkItem {
        WorkItem {
            request_id: id,
            chunk_index: 0,
            kind,
            data: vec![1, 2, 3],
            record: None,
            enqueued: at,
        }
    }

    #[test]
    fn full_batch_releases_immediately() {
        let mut b = DynamicBatcher::new(BatchPolicy { lanes: 3, max_wait: Duration::from_secs(10) });
        let now = Instant::now();
        for i in 0..3 {
            b.push(item(i, WorkKind::Compress, now));
        }
        let (kind, batch) = b.next_batch(now).expect("full batch");
        assert_eq!(kind, WorkKind::Compress);
        assert_eq!(batch.len(), 3);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn partial_batch_waits_for_deadline() {
        let mut b =
            DynamicBatcher::new(BatchPolicy { lanes: 4, max_wait: Duration::from_millis(50) });
        let t0 = Instant::now();
        b.push(item(1, WorkKind::Compress, t0));
        assert!(b.next_batch(t0).is_none(), "too early");
        let later = t0 + Duration::from_millis(51);
        let (_, batch) = b.next_batch(later).expect("deadline flush");
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn kinds_never_mix() {
        let mut b = DynamicBatcher::new(BatchPolicy { lanes: 2, max_wait: Duration::ZERO });
        let now = Instant::now();
        b.push(item(1, WorkKind::Compress, now));
        b.push(item(2, WorkKind::Decompress, now));
        let (k1, b1) = b.next_batch(now + Duration::from_millis(1)).unwrap();
        let (k2, b2) = b.next_batch(now + Duration::from_millis(1)).unwrap();
        assert_ne!(k1, k2);
        assert_eq!(b1.len(), 1);
        assert_eq!(b2.len(), 1);
    }

    #[test]
    fn oldest_queue_wins() {
        let mut b = DynamicBatcher::new(BatchPolicy { lanes: 8, max_wait: Duration::ZERO });
        let t0 = Instant::now();
        b.push(item(1, WorkKind::Decompress, t0));
        b.push(item(2, WorkKind::Compress, t0 + Duration::from_millis(5)));
        let (kind, _) = b.next_batch(t0 + Duration::from_millis(10)).unwrap();
        assert_eq!(kind, WorkKind::Decompress, "older item first");
    }

    #[test]
    fn fifo_within_queue_and_lane_cap() {
        let mut b = DynamicBatcher::new(BatchPolicy { lanes: 2, max_wait: Duration::ZERO });
        let now = Instant::now();
        for i in 0..5 {
            b.push(item(i, WorkKind::Compress, now));
        }
        let (_, batch) = b.next_batch(now).unwrap();
        assert_eq!(batch.iter().map(|i| i.request_id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(b.pending(), 3);
    }

    #[test]
    fn randomized_never_exceeds_lanes_and_preserves_order() {
        // Hand-rolled property test: any arrival pattern yields batches that
        // respect the lane cap and per-request FIFO order.
        let mut rng = crate::util::Pcg64::seeded(42);
        for _ in 0..50 {
            let lanes = 1 + rng.gen_index(8);
            let mut b = DynamicBatcher::new(BatchPolicy {
                lanes,
                max_wait: Duration::from_millis(rng.gen_range(5) ),
            });
            let t0 = Instant::now();
            let n = rng.gen_index(40);
            for i in 0..n {
                let kind =
                    if rng.gen_bool(0.5) { WorkKind::Compress } else { WorkKind::Decompress };
                let mut it = item(1, kind, t0 + Duration::from_micros(i as u64));
                it.chunk_index = i as u32;
                b.push(it);
            }
            let mut seen_c = Vec::new();
            let mut seen_d = Vec::new();
            let late = t0 + Duration::from_secs(1);
            while let Some((kind, batch)) = b.next_batch(late) {
                assert!(batch.len() <= lanes);
                for it in batch {
                    match kind {
                        WorkKind::Compress => seen_c.push(it.chunk_index),
                        WorkKind::Decompress => seen_d.push(it.chunk_index),
                    }
                }
            }
            assert!(seen_c.windows(2).all(|w| w[0] < w[1]), "compress FIFO");
            assert!(seen_d.windows(2).all(|w| w[0] < w[1]), "decompress FIFO");
            assert_eq!(b.pending(), 0);
        }
    }
}
