//! Chunk-level dynamic batcher with priority-aware admission.
//!
//! Work items (one per chunk) accumulate in per-kind queues; a batch is
//! released when either `lanes` items are waiting (full batch) or the
//! oldest item has waited `max_wait` (deadline flush). This is the
//! standard continuous-batching admission policy of LLM serving systems,
//! applied to compression chunks, with two scheduling refinements:
//!
//! * **Decompress fast lane** — when both kinds have a releasable batch,
//!   decompress wins: interactive reads never sit behind bulk compress
//!   jobs (the queues cannot share an engine pass anyway). A starvation
//!   bound keeps the lane from being absolute: once compress's oldest
//!   item has waited [`DynamicBatcher::starvation_bound`], compress goes
//!   first regardless, so sustained decompress load cannot block
//!   compress forever.
//! * **Per-item priority** — within a kind, [`Priority::Interactive`]
//!   items drain ahead of [`Priority::Bulk`] items, FIFO inside each
//!   class, so a latency-sensitive compress request can overtake a bulk
//!   ingest job without a separate queueing tier.

use crate::compress::container::{ChunkRecord, Codec};
use crate::util::PooledBuf;
use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// What kind of engine pass a work item needs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum WorkKind {
    Compress,
    Decompress,
}

/// Scheduling class of a work item within its kind queue.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Priority {
    /// Latency-sensitive: drains ahead of every queued [`Priority::Bulk`]
    /// item of the same kind. Decompress requests default to this.
    Interactive,
    /// Throughput work: compress requests default to this.
    Bulk,
}

/// One chunk of one request.
#[derive(Clone, Debug)]
pub struct WorkItem {
    pub request_id: u64,
    pub chunk_index: u32,
    pub kind: WorkKind,
    pub priority: Priority,
    /// Compress: raw bytes. Decompress: compressed payload. Rides a
    /// pool-recycled buffer: when the item is dropped after its batch
    /// completes, the storage returns to the server's [`BytePool`]
    /// (detached plain vectors convert with `.into()`).
    ///
    /// [`BytePool`]: crate::util::BytePool
    pub data: PooledBuf,
    /// Decompress only: the chunk record (token count).
    pub record: Option<ChunkRecord>,
    /// Entropy backend of this chunk's payload. Compress: the engine's
    /// configured codec. Decompress: the *container's* recorded codec —
    /// per item, so one engine batch may mix range and FSE chunks.
    pub codec: Codec,
    pub enqueued: Instant,
}

/// Admission policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Engine lane count (maximum batch size).
    pub lanes: usize,
    /// Deadline: flush a partial batch once the oldest item is this old.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { lanes: 8, max_wait: Duration::from_millis(20) }
    }
}

/// One kind's queue: two FIFO classes, interactive drained first.
#[derive(Default)]
struct KindQueue {
    interactive: VecDeque<WorkItem>,
    bulk: VecDeque<WorkItem>,
}

impl KindQueue {
    fn len(&self) -> usize {
        self.interactive.len() + self.bulk.len()
    }

    /// Enqueue time of the oldest item across both classes.
    fn oldest(&self) -> Option<Instant> {
        match (self.interactive.front(), self.bulk.front()) {
            (Some(a), Some(b)) => Some(a.enqueued.min(b.enqueued)),
            (a, b) => a.or(b).map(|i| i.enqueued),
        }
    }

    fn push(&mut self, item: WorkItem) {
        match item.priority {
            Priority::Interactive => self.interactive.push_back(item),
            Priority::Bulk => self.bulk.push_back(item),
        }
    }

    /// Pop up to `n` items, interactive class first — unless bulk's oldest
    /// item has aged past `starve_after`, in which case bulk drains first
    /// this batch so a sustained interactive flood cannot starve it.
    fn pop_batch(&mut self, n: usize, now: Instant, starve_after: Duration) -> Vec<WorkItem> {
        let bulk_starving = self
            .bulk
            .front()
            .is_some_and(|i| now.duration_since(i.enqueued) >= starve_after);
        let (first, second) = if bulk_starving {
            (&mut self.bulk, &mut self.interactive)
        } else {
            (&mut self.interactive, &mut self.bulk)
        };
        let hi = first.len().min(n);
        let mut batch: Vec<WorkItem> = first.drain(..hi).collect();
        let lo = second.len().min(n - hi);
        batch.extend(second.drain(..lo));
        batch
    }
}

/// The batcher: two kind queues (compress/decompress passes cannot share
/// an engine batch), each split into interactive/bulk priority classes.
pub struct DynamicBatcher {
    policy: BatchPolicy,
    compress_q: KindQueue,
    decompress_q: KindQueue,
}

impl DynamicBatcher {
    pub fn new(policy: BatchPolicy) -> Self {
        DynamicBatcher {
            policy,
            compress_q: KindQueue::default(),
            decompress_q: KindQueue::default(),
        }
    }

    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    pub fn push(&mut self, item: WorkItem) {
        match item.kind {
            WorkKind::Compress => self.compress_q.push(item),
            WorkKind::Decompress => self.decompress_q.push(item),
        }
    }

    pub fn pending(&self) -> usize {
        self.compress_q.len() + self.decompress_q.len()
    }

    /// How long a compress item may wait before it overrides the
    /// decompress fast lane (anti-starvation bound).
    pub fn starvation_bound(&self) -> Duration {
        (self.policy.max_wait * 8).max(Duration::from_millis(50))
    }

    /// Pop the next batch if the policy releases one at time `now`. The
    /// decompress queue is the fast lane: when both kinds are releasable,
    /// decompress goes first — unless compress's oldest item has aged
    /// past [`Self::starvation_bound`], which forces a compress batch so
    /// sustained decompress load cannot starve compress indefinitely.
    pub fn next_batch(&mut self, now: Instant) -> Option<(WorkKind, Vec<WorkItem>)> {
        let (lanes, max_wait) = (self.policy.lanes, self.policy.max_wait);
        let ready = |q: &KindQueue| -> bool {
            q.len() >= lanes || q.oldest().is_some_and(|t| now.duration_since(t) >= max_wait)
        };
        let starve_after = self.starvation_bound();
        let compress_starving =
            self.compress_q.oldest().is_some_and(|t| now.duration_since(t) >= starve_after);
        let (q, kind) = if ready(&self.decompress_q) && !compress_starving {
            (&mut self.decompress_q, WorkKind::Decompress)
        } else if ready(&self.compress_q) {
            (&mut self.compress_q, WorkKind::Compress)
        } else if ready(&self.decompress_q) {
            (&mut self.decompress_q, WorkKind::Decompress)
        } else {
            return None;
        };
        let n = q.len().min(lanes);
        Some((kind, q.pop_batch(n, now, starve_after)))
    }

    /// Earliest deadline among queued items (for the scheduler's sleep).
    pub fn next_deadline(&self) -> Option<Instant> {
        let c = self.compress_q.oldest().map(|t| t + self.policy.max_wait);
        let d = self.decompress_q.oldest().map(|t| t + self.policy.max_wait);
        match (c, d) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn item(id: u64, kind: WorkKind, at: Instant) -> WorkItem {
        WorkItem {
            request_id: id,
            chunk_index: 0,
            kind,
            priority: Priority::Bulk,
            data: vec![1, 2, 3].into(),
            record: None,
            codec: Codec::Range,
            enqueued: at,
        }
    }

    #[test]
    fn full_batch_releases_immediately() {
        let mut b = DynamicBatcher::new(BatchPolicy { lanes: 3, max_wait: Duration::from_secs(10) });
        let now = Instant::now();
        for i in 0..3 {
            b.push(item(i, WorkKind::Compress, now));
        }
        let (kind, batch) = b.next_batch(now).expect("full batch");
        assert_eq!(kind, WorkKind::Compress);
        assert_eq!(batch.len(), 3);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn partial_batch_waits_for_deadline() {
        let mut b =
            DynamicBatcher::new(BatchPolicy { lanes: 4, max_wait: Duration::from_millis(50) });
        let t0 = Instant::now();
        b.push(item(1, WorkKind::Compress, t0));
        assert!(b.next_batch(t0).is_none(), "too early");
        let later = t0 + Duration::from_millis(51);
        let (_, batch) = b.next_batch(later).expect("deadline flush");
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn kinds_never_mix() {
        let mut b = DynamicBatcher::new(BatchPolicy { lanes: 2, max_wait: Duration::ZERO });
        let now = Instant::now();
        b.push(item(1, WorkKind::Compress, now));
        b.push(item(2, WorkKind::Decompress, now));
        let (k1, b1) = b.next_batch(now + Duration::from_millis(1)).unwrap();
        let (k2, b2) = b.next_batch(now + Duration::from_millis(1)).unwrap();
        assert_ne!(k1, k2);
        assert_eq!(b1.len(), 1);
        assert_eq!(b2.len(), 1);
    }

    #[test]
    fn decompress_fast_lane_wins_even_when_younger() {
        let mut b = DynamicBatcher::new(BatchPolicy { lanes: 8, max_wait: Duration::ZERO });
        let t0 = Instant::now();
        b.push(item(1, WorkKind::Compress, t0));
        b.push(item(2, WorkKind::Decompress, t0 + Duration::from_millis(5)));
        let (kind, _) = b.next_batch(t0 + Duration::from_millis(10)).unwrap();
        assert_eq!(kind, WorkKind::Decompress, "decompress is the fast lane");
        let (kind, _) = b.next_batch(t0 + Duration::from_millis(10)).unwrap();
        assert_eq!(kind, WorkKind::Compress);
    }

    #[test]
    fn starving_compress_overrides_fast_lane() {
        // Decompress arrives continuously, but once compress's oldest item
        // ages past the starvation bound it must be scheduled anyway.
        let mut b = DynamicBatcher::new(BatchPolicy { lanes: 8, max_wait: Duration::ZERO });
        let t0 = Instant::now();
        b.push(item(1, WorkKind::Compress, t0));
        b.push(item(2, WorkKind::Decompress, t0 + Duration::from_millis(1)));
        let starved = t0 + b.starvation_bound() + Duration::from_millis(1);
        let (kind, _) = b.next_batch(starved).unwrap();
        assert_eq!(kind, WorkKind::Compress, "aged compress beats the fast lane");
        let (kind, _) = b.next_batch(starved).unwrap();
        assert_eq!(kind, WorkKind::Decompress);
    }

    #[test]
    fn interactive_overtakes_bulk_within_kind() {
        let mut b = DynamicBatcher::new(BatchPolicy { lanes: 2, max_wait: Duration::ZERO });
        let t0 = Instant::now();
        for i in 0..3 {
            b.push(item(i, WorkKind::Compress, t0));
        }
        let mut hot = item(9, WorkKind::Compress, t0 + Duration::from_millis(1));
        hot.priority = Priority::Interactive;
        b.push(hot);
        let (_, batch) = b.next_batch(t0 + Duration::from_millis(2)).unwrap();
        // Interactive item jumps the three queued bulk items.
        assert_eq!(batch.iter().map(|i| i.request_id).collect::<Vec<_>>(), vec![9, 0]);
        let (_, batch) = b.next_batch(t0 + Duration::from_millis(2)).unwrap();
        assert_eq!(batch.iter().map(|i| i.request_id).collect::<Vec<_>>(), vec![1, 2]);
    }

    #[test]
    fn starving_bulk_overrides_interactive_class() {
        // A bulk item older than the starvation bound drains before fresh
        // interactive arrivals of the same kind.
        let mut b = DynamicBatcher::new(BatchPolicy { lanes: 1, max_wait: Duration::ZERO });
        let t0 = Instant::now();
        b.push(item(1, WorkKind::Compress, t0));
        let starved = t0 + b.starvation_bound() + Duration::from_millis(1);
        let mut hot = item(9, WorkKind::Compress, starved);
        hot.priority = Priority::Interactive;
        b.push(hot);
        let (_, batch) = b.next_batch(starved).unwrap();
        assert_eq!(batch[0].request_id, 1, "aged bulk item goes first");
        let (_, batch) = b.next_batch(starved).unwrap();
        assert_eq!(batch[0].request_id, 9);
    }

    #[test]
    fn fifo_within_queue_and_lane_cap() {
        let mut b = DynamicBatcher::new(BatchPolicy { lanes: 2, max_wait: Duration::ZERO });
        let now = Instant::now();
        for i in 0..5 {
            b.push(item(i, WorkKind::Compress, now));
        }
        let (_, batch) = b.next_batch(now).unwrap();
        assert_eq!(batch.iter().map(|i| i.request_id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(b.pending(), 3);
    }

    #[test]
    fn randomized_never_exceeds_lanes_and_preserves_order() {
        // Hand-rolled property test: any arrival pattern yields batches that
        // respect the lane cap and per-class FIFO order.
        let mut rng = crate::util::Pcg64::seeded(42);
        for _ in 0..50 {
            let lanes = 1 + rng.gen_index(8);
            let mut b = DynamicBatcher::new(BatchPolicy {
                lanes,
                max_wait: Duration::from_millis(rng.gen_range(5) ),
            });
            let t0 = Instant::now();
            let n = rng.gen_index(40);
            for i in 0..n {
                let kind =
                    if rng.gen_bool(0.5) { WorkKind::Compress } else { WorkKind::Decompress };
                let mut it = item(1, kind, t0 + Duration::from_micros(i as u64));
                it.chunk_index = i as u32;
                if rng.gen_bool(0.3) {
                    it.priority = Priority::Interactive;
                }
                b.push(it);
            }
            let mut seen: std::collections::HashMap<(WorkKind, Priority), Vec<u32>> =
                std::collections::HashMap::new();
            let late = t0 + Duration::from_secs(1);
            let mut popped = 0usize;
            while let Some((kind, batch)) = b.next_batch(late) {
                assert!(batch.len() <= lanes);
                popped += batch.len();
                for it in batch {
                    seen.entry((kind, it.priority)).or_default().push(it.chunk_index);
                }
            }
            for order in seen.values() {
                assert!(order.windows(2).all(|w| w[0] < w[1]), "FIFO within kind+class");
            }
            assert_eq!(popped, n);
            assert_eq!(b.pending(), 0);
        }
    }
}
