//! Multi-model **fleet** coordinator: many per-model replica pools behind
//! one wire endpoint, one shared autoscaling budget, tenant QoS, and
//! weight paging.
//!
//! The paper's predictor-must-match-the-source result means a production
//! compression service hosts *every* model whose traffic it stores. A
//! [`FleetServer`] owns one [`Server`] pool per hosted model and routes
//! each [`Op`] to the right pool:
//!
//! * **compress** — by an explicit route key (a registry alias, a bare
//!   model name, or a full container tag; see
//!   [`crate::compress::ModelRegistry`]);
//! * **decompress** — by the tag the container itself records
//!   ([`Container::peek_model_name`]), so clients never tag reads.
//!
//! Cross-pool arbitration happens through three shared levers:
//!
//! * a fleet-wide [`ReplicaBudget`]: every pool's startup replicas and
//!   autoscale grows draw permits from one cap, so the fleet's total
//!   replica count is bounded no matter which pools' scalers fire;
//! * a **memory budget** over [`Weights::resident_bytes`]: when live
//!   bundles exceed it, the coldest pool (LRU by last routed request) is
//!   *paged out* — its `Server` is dropped (draining in-flight work) and
//!   only the spec + weight [`Weights::fingerprint`] stay. The next
//!   request re-materializes it and the reloaded bundle must reproduce
//!   the pinned fingerprint, or the fleet refuses to serve from it;
//! * **admission control**: per-tenant token-bucket rate limits and a
//!   fleet-wide in-flight cap. Past the cap, requests are *shed* with a
//!   clear error (surfaced as `MSG_ERR` on wire v2) instead of queueing
//!   without bound.
//!
//! Tenancy is a pure scheduling layer: a tenant id rides each work item
//! into the per-pool [`crate::coordinator::DynamicBatcher`]'s weighted
//! fair queue. None of routing, paging, budgets or tenancy can change a
//! single container byte — every container a fleet produces is
//! byte-identical to the direct single-compressor path (pinned by
//! `tests/fleet.rs`).
//!
//! [`WireService`] is the seam the TCP layer ([`super::wire`]) speaks: a
//! plain [`Server`] implements it too, so one `serve_connection` serves
//! both shapes.

use crate::compress::container::Container;
use crate::compress::llm::ContainerTag;
use crate::compress::registry::ModelRegistry;
use crate::compress::{LlmCompressor, LlmCompressorConfig};
use crate::coordinator::batcher::Priority;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::router::{
    Op, ReplicaBudget, Server, ServerConfig, StreamHandle, Ticket,
};
use crate::lm::{config, ExecutorKind, Precision, Weights};
use crate::util::{crc32, BytePool};
use crate::Result;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// What the wire layer needs from a serving endpoint — implemented by
/// both the single-model [`Server`] (routing and admission are no-ops)
/// and the [`FleetServer`]. Object-safe: `serve_connection` holds a
/// `&dyn WireService`.
pub trait WireService: Send + Sync {
    /// Buffer recycler for reading request frames.
    fn wire_pool(&self) -> &BytePool;

    /// Resolve a tenant name to the scheduling id stamped on that
    /// connection's work. Empty name = the default tenant `0`.
    fn bind_tenant(&self, name: &str) -> Result<u32>;

    /// Submit one operation. `route` picks the model pool (`None` =
    /// unrouted: the sole pool for compress, the container's own tag for
    /// decompress). Errors here are *admission* errors (unknown route,
    /// rate limit, load shed) and map to a clean wire error frame.
    fn submit_wire(
        &self,
        tenant: u32,
        route: Option<&str>,
        op: Op,
        priority: Priority,
    ) -> Result<WireTicket>;

    /// Open a chunked-upload compression stream on the routed pool.
    fn open_wire_stream(&self, tenant: u32, route: Option<&str>) -> Result<WireStream>;
}

/// RAII admission slot: holds one unit of the fleet's in-flight cap and
/// returns it on drop — whether the request completed, errored, or the
/// connection died with the ticket unresolved.
pub struct InflightGuard {
    counter: Arc<AtomicUsize>,
}

impl Drop for InflightGuard {
    fn drop(&mut self) {
        self.counter.fetch_sub(1, Ordering::AcqRel);
    }
}

/// A routed, admitted in-flight operation: the pool's [`Ticket`] plus
/// whatever the admitting service needs pinned while it runs — the
/// owning `Server` (so a page-out cannot tear the pool down under an
/// active request) and the admission slot.
pub struct WireTicket {
    ticket: Ticket,
    server: Option<Arc<Server>>,
    guard: Option<InflightGuard>,
}

impl WireTicket {
    /// Block until the operation completes. Releases the admission slot
    /// as soon as the result is in hand.
    pub fn wait(self) -> Result<Vec<u8>> {
        let WireTicket { ticket, server, guard } = self;
        let out = ticket.wait();
        drop(guard);
        drop(server);
        out
    }

    /// Poll without blocking (see [`Ticket::try_wait`]). The admission
    /// slot is held until the `WireTicket` is dropped.
    pub fn try_wait(&self) -> Result<Option<Vec<u8>>> {
        self.ticket.try_wait()
    }
}

/// A routed, admitted upload stream; the admission slot and pool pin ride
/// into the final [`WireTicket`] at [`WireStream::finish`].
pub struct WireStream {
    handle: StreamHandle,
    server: Option<Arc<Server>>,
    guard: Option<InflightGuard>,
}

impl WireStream {
    pub fn write_bytes(&mut self, data: &[u8]) -> Result<()> {
        self.handle.write_bytes(data)
    }

    pub fn finish(self) -> Result<WireTicket> {
        let WireStream { handle, server, guard } = self;
        Ok(WireTicket { ticket: handle.finish()?, server, guard })
    }
}

/// Does `route` name the engine behind `engine_tag`? Accepts the full
/// tag, any tag for the same engine (codec suffix ignored — one engine
/// decodes both), or the bare model name.
pub(crate) fn route_matches(route: &str, engine_tag: &str) -> bool {
    if route == engine_tag || engine_tag.split(':').next() == Some(route) {
        return true;
    }
    match (ContainerTag::parse(route), ContainerTag::parse(engine_tag)) {
        (Ok(a), Ok(b)) => a.same_engine(&b),
        _ => false,
    }
}

fn ensure_route(route: &str, engine_tag: &str) -> Result<()> {
    if route_matches(route, engine_tag) {
        Ok(())
    } else {
        anyhow::bail!("unknown model route '{route}' — this server hosts '{engine_tag}'")
    }
}

/// Scheduling id for a free-form tenant name on an endpoint with no
/// configured tenant table: a stable hash, so each name gets its own WFQ
/// lane (at default weight 1). Never 0 — that is the anonymous tenant.
fn hashed_tenant(name: &str) -> u32 {
    crc32(name.as_bytes()).max(1)
}

/// The single-model server speaks the same wire seam: every route that
/// names its engine is accepted, tenants are pure lane labels, and
/// admission control is the pool's own backpressure.
impl WireService for Server {
    fn wire_pool(&self) -> &BytePool {
        self.pool()
    }

    fn bind_tenant(&self, name: &str) -> Result<u32> {
        Ok(if name.is_empty() { 0 } else { hashed_tenant(name) })
    }

    fn submit_wire(
        &self,
        tenant: u32,
        route: Option<&str>,
        op: Op,
        priority: Priority,
    ) -> Result<WireTicket> {
        if let Some(route) = route {
            ensure_route(route, self.engine_tag())?;
        }
        Ok(WireTicket { ticket: self.submit_for(tenant, op, priority)?, server: None, guard: None })
    }

    fn open_wire_stream(&self, tenant: u32, route: Option<&str>) -> Result<WireStream> {
        if let Some(route) = route {
            ensure_route(route, self.engine_tag())?;
        }
        Ok(WireStream { handle: self.open_stream_for(tenant)?, server: None, guard: None })
    }
}

/// How a fleet loads (and RE-loads, after a page-out) one model's weight
/// bundle. Must be deterministic: page-in verifies the reloaded bundle's
/// fingerprint against the one pinned at first materialization.
pub type WeightsLoader = Arc<dyn Fn() -> Result<Weights> + Send + Sync>;

/// One hosted model: the route key clients use, the compressor/pool
/// configuration, and the weights loader.
pub struct FleetModelSpec {
    /// Registry alias, e.g. `"nano"` or `"nano-int8"`.
    pub key: String,
    /// Per-replica compressor configuration (native executor only — fleet
    /// pools share one `Arc<Weights>` per model). With
    /// `precision == Int8` and an f32 loader, the bundle is quantized
    /// once per materialization, exactly like `cmd serve`.
    pub compressor: LlmCompressorConfig,
    /// This model's pool shape (replicas, autoscale range, batching).
    /// `replica_budget` and `tenants` are overwritten by the fleet.
    pub server: ServerConfig,
    pub load: WeightsLoader,
}

/// One tenant's QoS contract.
#[derive(Clone, Debug)]
pub struct TenantSpec {
    pub name: String,
    /// WFQ weight inside every pool's batcher (`0` counts as 1).
    pub weight: u64,
    /// Sustained admission rate in payload bytes/second (`0` = no limit).
    pub rate_bytes_per_sec: f64,
    /// Token-bucket depth in bytes (`0` = one second of rate). Requests
    /// larger than the burst are refused outright.
    pub burst_bytes: f64,
}

/// Fleet-wide arbitration knobs. Everything here is a pure
/// scheduling/placement policy: no setting changes any container byte.
#[derive(Clone, Debug)]
pub struct FleetConfig {
    /// Cap on replicas across ALL pools (`0` = uncapped, no shared
    /// budget). Startup claims what it can per pool (erroring only when a
    /// pool would get zero); autoscale grows need a free permit.
    pub max_total_replicas: usize,
    /// Cap on summed [`Weights::resident_bytes`] of live pools (`0` =
    /// unlimited). Exceeding it pages out the coldest pool(s). Soft: the
    /// fleet never pages out the pool a request is being routed to, so
    /// one oversized model still serves.
    pub memory_budget_bytes: usize,
    /// Fleet-wide in-flight request cap (`0` = unlimited). Beyond it,
    /// submissions are shed with a clear error instead of queueing.
    pub max_inflight: usize,
    pub tenants: Vec<TenantSpec>,
    /// Recycle wire-frame buffers (matches [`ServerConfig::pooling`]).
    pub pooling: bool,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            max_total_replicas: 0,
            memory_budget_bytes: 0,
            max_inflight: 0,
            tenants: Vec::new(),
            pooling: true,
        }
    }
}

/// Fleet-level counters (per-pool throughput lives in each pool's own
/// [`Metrics`], reachable via [`FleetServer::pool_metrics`]).
#[derive(Debug, Default)]
pub struct FleetMetrics {
    pub page_outs: AtomicU64,
    pub page_ins: AtomicU64,
    /// Requests refused by the in-flight cap.
    pub shed: AtomicU64,
    /// Requests refused by a tenant rate limit.
    pub rate_limited: AtomicU64,
}

/// Classic token bucket over payload bytes.
struct TenantBucket {
    rate: f64,
    burst: f64,
    /// `(tokens, last refill)`.
    state: Mutex<(f64, Instant)>,
}

impl TenantBucket {
    fn new(rate: f64, burst: f64) -> TenantBucket {
        TenantBucket { rate, burst, state: Mutex::new((burst, Instant::now())) }
    }

    fn try_take(&self, cost: f64) -> bool {
        let mut st = self.state.lock().unwrap();
        let now = Instant::now();
        let elapsed = now.duration_since(st.1).as_secs_f64();
        st.0 = (st.0 + elapsed * self.rate).min(self.burst);
        st.1 = now;
        if st.0 >= cost {
            st.0 -= cost;
            true
        } else {
            false
        }
    }
}

struct Tenant {
    name: String,
    id: u32,
    bucket: Option<TenantBucket>,
}

/// A pool slot: live (serving) or paged out (spec + pinned fingerprint
/// only; the weights and every replica thread are gone).
enum PoolState {
    Live {
        server: Arc<Server>,
        /// [`Weights::resident_bytes`] sampled at materialization — the
        /// memory-budget signal.
        resident: usize,
    },
    Paged,
}

struct PoolEntry {
    key: String,
    engine_tag: String,
    /// Weight fingerprint pinned at first materialization; every page-in
    /// must reproduce it or the pool refuses to serve.
    fingerprint: u32,
    spec: FleetModelSpec,
    state: Mutex<PoolState>,
    /// Logical LRU clock value of the last request routed here.
    last_used: AtomicU64,
}

/// Build (or re-build) one pool from its spec. Returns the server, the
/// bundle fingerprint and the resident-byte sample. `expect` pins the
/// fingerprint on page-in.
fn materialize(spec: &FleetModelSpec, expect: Option<u32>) -> Result<(Arc<Server>, u32, usize)> {
    let model_cfg = config::by_name(&spec.compressor.model)?;
    let weights = (spec.load)()?;
    let weights = match (spec.compressor.precision, weights.precision()) {
        (Precision::Int8, Precision::F32) => weights.quantize(),
        (Precision::F32, Precision::Int8) => anyhow::bail!(
            "weights for '{}' are int8-quantized but the pool is configured for f32",
            spec.compressor.model
        ),
        _ => weights,
    };
    let fp = weights.fingerprint();
    if let Some(expect) = expect {
        if fp != expect {
            anyhow::bail!(
                "weights for '{}' changed while paged out: fingerprint {fp:08x} on reload \
                 vs {expect:08x} at first materialization — refusing to serve (containers \
                 would decode against the wrong engine)",
                spec.key
            );
        }
    }
    let weights = Arc::new(weights);
    let resident_probe = weights.clone();
    let cfg = spec.compressor.clone();
    let server = Server::start(
        move || LlmCompressor::from_shared(model_cfg, weights.clone(), cfg.clone()),
        spec.server.clone(),
    )?;
    // Sampled after startup so panelized kernel copies (built by the
    // first replica, shared by the rest) are counted.
    let resident = resident_probe.resident_bytes();
    Ok((Arc::new(server), fp, resident))
}

/// The multi-model serving fleet. See the module docs for the contract.
pub struct FleetServer {
    pools: Vec<PoolEntry>,
    registry: ModelRegistry,
    tenants: Vec<Tenant>,
    budget: Option<Arc<ReplicaBudget>>,
    memory_budget: usize,
    max_inflight: usize,
    inflight: Arc<AtomicUsize>,
    /// Monotone logical clock feeding the pools' LRU stamps.
    clock: AtomicU64,
    pub metrics: FleetMetrics,
    pool: BytePool,
}

impl FleetServer {
    /// Materialize every pool eagerly (pinning each bundle fingerprint),
    /// then apply the memory budget — so a fleet configured tighter than
    /// its models starts with the coldest already paged out rather than
    /// overcommitted.
    pub fn start(specs: Vec<FleetModelSpec>, config: FleetConfig) -> Result<FleetServer> {
        if specs.is_empty() {
            anyhow::bail!("a fleet needs at least one model");
        }
        let budget =
            (config.max_total_replicas > 0).then(|| ReplicaBudget::new(config.max_total_replicas));
        let mut tenants: Vec<Tenant> = Vec::new();
        for (i, t) in config.tenants.iter().enumerate() {
            if t.name.is_empty() {
                anyhow::bail!("tenant names must be non-empty");
            }
            if tenants.iter().any(|x| x.name == t.name) {
                anyhow::bail!("tenant '{}' configured twice", t.name);
            }
            let bucket = (t.rate_bytes_per_sec > 0.0).then(|| {
                let burst =
                    if t.burst_bytes > 0.0 { t.burst_bytes } else { t.rate_bytes_per_sec };
                TenantBucket::new(t.rate_bytes_per_sec, burst)
            });
            tenants.push(Tenant { name: t.name.clone(), id: (i + 1) as u32, bucket });
        }
        let lane_weights: Vec<(u32, u64)> = tenants
            .iter()
            .zip(&config.tenants)
            .map(|(t, s)| (t.id, s.weight.max(1)))
            .collect();
        let mut registry = ModelRegistry::new();
        let mut pools: Vec<PoolEntry> = Vec::new();
        for mut spec in specs {
            if spec.compressor.executor != ExecutorKind::Native {
                anyhow::bail!(
                    "fleet pools require the native executor (model '{}' wants {:?})",
                    spec.key,
                    spec.compressor.executor
                );
            }
            spec.server.replica_budget = budget.clone();
            spec.server.tenants = lane_weights.clone();
            let (server, fingerprint, resident) = materialize(&spec, None)
                .map_err(|e| anyhow::anyhow!("starting model pool '{}': {e:#}", spec.key))?;
            let engine_tag = server.engine_tag().to_string();
            registry.register(&spec.key, &engine_tag)?;
            pools.push(PoolEntry {
                key: spec.key.clone(),
                engine_tag,
                fingerprint,
                spec,
                state: Mutex::new(PoolState::Live { server, resident }),
                last_used: AtomicU64::new(0),
            });
        }
        let fleet = FleetServer {
            pools,
            registry,
            tenants,
            budget,
            memory_budget: config.memory_budget_bytes,
            max_inflight: config.max_inflight,
            inflight: Arc::new(AtomicUsize::new(0)),
            clock: AtomicU64::new(0),
            metrics: FleetMetrics::default(),
            pool: if config.pooling { BytePool::new(64) } else { BytePool::disabled() },
        };
        fleet.enforce_memory_budget(None);
        Ok(fleet)
    }

    /// Route keys in registration order.
    pub fn model_keys(&self) -> Vec<String> {
        self.pools.iter().map(|p| p.key.clone()).collect()
    }

    /// The engine tag a pool stamps into containers.
    pub fn engine_tag(&self, key: &str) -> Result<String> {
        Ok(self.pools[self.registry.resolve(key)?].engine_tag.clone())
    }

    /// Is this model currently materialized?
    pub fn is_live(&self, key: &str) -> Result<bool> {
        let entry = &self.pools[self.registry.resolve(key)?];
        Ok(matches!(&*entry.state.lock().unwrap(), PoolState::Live { .. }))
    }

    /// A live pool's metrics (`None` while paged out) — the per-model
    /// throughput feed for benches and ops.
    pub fn pool_metrics(&self, key: &str) -> Result<Option<Arc<Metrics>>> {
        let entry = &self.pools[self.registry.resolve(key)?];
        Ok(match &*entry.state.lock().unwrap() {
            PoolState::Live { server, .. } => Some(server.metrics.clone()),
            PoolState::Paged => None,
        })
    }

    /// Summed resident weight bytes of the live pools.
    pub fn resident_bytes(&self) -> usize {
        self.pools
            .iter()
            .map(|e| match &*e.state.lock().unwrap() {
                PoolState::Live { resident, .. } => *resident,
                PoolState::Paged => 0,
            })
            .sum()
    }

    /// The shared replica budget, when one is configured.
    pub fn budget(&self) -> Option<&ReplicaBudget> {
        self.budget.as_deref()
    }

    /// Requests currently admitted and not yet resolved.
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Relaxed)
    }

    /// Explicitly page a model out (tests/ops; the memory budget does
    /// this automatically). Returns whether a live pool was dropped —
    /// in-flight work on it drains first ([`Server`] shutdown is
    /// graceful), pinned by any outstanding [`WireTicket`]'s own `Arc`.
    pub fn page_out(&self, key: &str) -> Result<bool> {
        Ok(self.page_out_slot(self.registry.resolve(key)?))
    }

    fn page_out_slot(&self, idx: usize) -> bool {
        let entry = &self.pools[idx];
        let Ok(mut st) = entry.state.try_lock() else {
            return false;
        };
        match &*st {
            PoolState::Live { .. } => {
                *st = PoolState::Paged;
                self.metrics.page_outs.fetch_add(1, Ordering::Relaxed);
                true
            }
            PoolState::Paged => false,
        }
    }

    /// Evict coldest-first until live weights fit the budget. `protect`
    /// exempts the pool a request is being routed to, so routing can
    /// never page out its own target. Uses `try_lock` throughout and
    /// stops on the first failed eviction — a busy pool is never waited
    /// on, and the budget is soft by design.
    fn enforce_memory_budget(&self, protect: Option<usize>) {
        if self.memory_budget == 0 {
            return;
        }
        loop {
            let mut total = 0usize;
            let mut coldest: Option<(usize, u64)> = None;
            for (i, e) in self.pools.iter().enumerate() {
                let Ok(st) = e.state.try_lock() else { continue };
                if let PoolState::Live { resident, .. } = &*st {
                    total += *resident;
                    if Some(i) != protect {
                        let used = e.last_used.load(Ordering::Relaxed);
                        if coldest.map_or(true, |(_, c)| used < c) {
                            coldest = Some((i, used));
                        }
                    }
                }
            }
            if total <= self.memory_budget {
                return;
            }
            let Some((victim, _)) = coldest else { return };
            if !self.page_out_slot(victim) {
                return;
            }
        }
    }

    /// Touch the LRU stamp and return the pool's server, re-materializing
    /// a paged-out pool first (with fingerprint verification).
    fn ensure_live(&self, idx: usize) -> Result<Arc<Server>> {
        let entry = &self.pools[idx];
        entry
            .last_used
            .store(self.clock.fetch_add(1, Ordering::Relaxed) + 1, Ordering::Relaxed);
        let server = {
            let mut st = entry.state.lock().unwrap();
            if let PoolState::Live { server, .. } = &*st {
                return Ok(server.clone());
            }
            let (server, _, resident) = materialize(&entry.spec, Some(entry.fingerprint))
                .map_err(|e| {
                    anyhow::anyhow!("re-materializing model pool '{}': {e:#}", entry.key)
                })?;
            self.metrics.page_ins.fetch_add(1, Ordering::Relaxed);
            *st = PoolState::Live { server: server.clone(), resident };
            server
        };
        self.enforce_memory_budget(Some(idx));
        Ok(server)
    }

    /// Admission control: tenant rate limit, then the in-flight cap.
    fn admit(&self, tenant: u32, bytes: usize) -> Result<Option<InflightGuard>> {
        if tenant != 0 {
            if let Some(t) = self.tenants.iter().find(|t| t.id == tenant) {
                if let Some(b) = &t.bucket {
                    if !b.try_take(bytes as f64) {
                        self.metrics.rate_limited.fetch_add(1, Ordering::Relaxed);
                        anyhow::bail!(
                            "tenant '{}' rate limit exceeded ({bytes}-byte request; \
                             {:.0} B/s sustained, {:.0} B burst) — retry later",
                            t.name,
                            b.rate,
                            b.burst
                        );
                    }
                }
            }
        }
        if self.max_inflight == 0 {
            return Ok(None);
        }
        let mut cur = self.inflight.load(Ordering::Relaxed);
        loop {
            if cur >= self.max_inflight {
                self.metrics.shed.fetch_add(1, Ordering::Relaxed);
                anyhow::bail!(
                    "fleet saturated: {cur} requests in flight (cap {}) — load shed, \
                     retry later",
                    self.max_inflight
                );
            }
            match self.inflight.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => return Ok(Some(InflightGuard { counter: self.inflight.clone() })),
                Err(now) => cur = now,
            }
        }
    }

    /// The only valid unrouted compress target: a single-model fleet.
    fn sole_pool(&self) -> Result<usize> {
        if self.pools.len() == 1 {
            Ok(0)
        } else {
            anyhow::bail!(
                "untagged compress request is ambiguous — fleet hosts {} models ({}); \
                 route it with a model key",
                self.pools.len(),
                self.model_keys().join(", ")
            )
        }
    }

    /// Blocking convenience: compress `data` on the pool `key` routes to,
    /// for `tenant`.
    pub fn compress_for(&self, tenant: u32, key: &str, data: &[u8]) -> Result<Vec<u8>> {
        let mut buf = self.pool.take(data.len());
        buf.extend_from_slice(data);
        self.submit_wire(tenant, Some(key), Op::Compress(buf), Priority::Bulk)?.wait()
    }

    /// Blocking convenience: decompress, routed by the container's own
    /// recorded tag.
    pub fn decompress(&self, container: &[u8]) -> Result<Vec<u8>> {
        let mut buf = self.pool.take(container.len());
        buf.extend_from_slice(container);
        self.submit_wire(0, None, Op::Decompress(buf), Priority::Interactive)?.wait()
    }
}

impl WireService for FleetServer {
    fn wire_pool(&self) -> &BytePool {
        &self.pool
    }

    fn bind_tenant(&self, name: &str) -> Result<u32> {
        if name.is_empty() {
            return Ok(0);
        }
        if self.tenants.is_empty() {
            // Open fleet: any name gets its own WFQ lane at weight 1.
            return Ok(hashed_tenant(name));
        }
        match self.tenants.iter().find(|t| t.name == name) {
            Some(t) => Ok(t.id),
            None => anyhow::bail!(
                "unknown tenant '{name}' — configured tenants: {}",
                self.tenants.iter().map(|t| t.name.as_str()).collect::<Vec<_>>().join(", ")
            ),
        }
    }

    fn submit_wire(
        &self,
        tenant: u32,
        route: Option<&str>,
        op: Op,
        priority: Priority,
    ) -> Result<WireTicket> {
        let idx = match (route, &op) {
            (Some(r), _) => self.registry.resolve(r)?,
            // Unrouted decompress: the container names its own engine.
            (None, Op::Decompress(p)) => self.registry.resolve(Container::peek_model_name(p)?)?,
            (None, Op::Compress(_)) => self.sole_pool()?,
        };
        let bytes = match &op {
            Op::Compress(p) | Op::Decompress(p) => p.len(),
        };
        let guard = self.admit(tenant, bytes)?;
        let server = self.ensure_live(idx)?;
        let ticket = server.submit_for(tenant, op, priority)?;
        Ok(WireTicket { ticket, server: Some(server), guard })
    }

    fn open_wire_stream(&self, tenant: u32, route: Option<&str>) -> Result<WireStream> {
        let idx = match route {
            Some(r) => self.registry.resolve(r)?,
            None => self.sole_pool()?,
        };
        // Streams admit at zero cost (their size is unknown at open); the
        // in-flight cap still applies.
        let guard = self.admit(tenant, 0)?;
        let server = self.ensure_live(idx)?;
        let handle = server.open_stream_for(tenant)?;
        Ok(WireStream { handle, server: Some(server), guard })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn route_matching_accepts_tag_name_and_engine_equivalents() {
        let tag = "nano:0:q8:deadbeef:fse";
        assert!(route_matches(tag, tag));
        assert!(route_matches("nano", tag));
        assert!(route_matches("nano:0:q8:deadbeef", tag), "codec suffix ignored");
        assert!(!route_matches("medium", tag));
        assert!(!route_matches("nano:0", tag), "f32 route must not hit a q8 engine");
        assert!(!route_matches("", tag));
    }

    #[test]
    fn token_bucket_enforces_rate_and_burst() {
        let b = TenantBucket::new(1_000_000.0, 100.0);
        assert!(b.try_take(60.0));
        assert!(b.try_take(40.0));
        // Bucket drained; an immediate third request is refused.
        assert!(!b.try_take(50.0));
        // Refill at 1 MB/s makes 50 bytes available in well under the
        // test's patience.
        let deadline = Instant::now() + Duration::from_secs(2);
        while !b.try_take(50.0) {
            assert!(Instant::now() < deadline, "bucket never refilled");
            std::thread::sleep(Duration::from_micros(200));
        }
        // A request larger than the burst can never pass.
        assert!(!b.try_take(1000.0));
    }

    #[test]
    fn inflight_guard_returns_slot_on_drop() {
        let counter = Arc::new(AtomicUsize::new(1));
        let g = InflightGuard { counter: counter.clone() };
        assert_eq!(counter.load(Ordering::Relaxed), 1);
        drop(g);
        assert_eq!(counter.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn hashed_tenants_are_stable_and_nonzero() {
        assert_eq!(hashed_tenant("alice"), hashed_tenant("alice"));
        assert_ne!(hashed_tenant("alice"), hashed_tenant("bob"));
        assert_ne!(hashed_tenant("alice"), 0);
    }
}
