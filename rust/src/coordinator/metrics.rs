//! Service metrics: counters, latency/occupancy summaries, per-op latency
//! percentiles (p50/p99) and per-engine-worker occupancy/queue-depth.

use crate::coordinator::batcher::WorkKind;
use crate::util::stats::{percentile, Summary};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Cap on retained latency samples (bounds memory on long-lived servers;
/// ~800 KiB per op kind at the cap).
const MAX_LATENCY_SAMPLES: usize = 100_000;

/// Bounded latency sample store: a ring once the cap is reached, so
/// percentiles always reflect the most recent `MAX_LATENCY_SAMPLES`
/// requests instead of freezing on warmup-era samples.
#[derive(Default)]
struct LatencyStore {
    samples: Vec<f64>,
    /// Total samples ever recorded (also the ring write cursor).
    total: u64,
}

impl LatencyStore {
    fn push(&mut self, ms: f64) {
        if self.samples.len() < MAX_LATENCY_SAMPLES {
            self.samples.push(ms);
        } else {
            self.samples[(self.total % MAX_LATENCY_SAMPLES as u64) as usize] = ms;
        }
        self.total += 1;
    }
}

/// Counters and summaries for ONE engine worker of the replica pool.
#[derive(Default)]
pub struct WorkerMetrics {
    /// Batches dispatched to this worker.
    pub batches: AtomicU64,
    /// Work items (chunks) across those batches.
    pub items: AtomicU64,
    /// Tokens this worker pushed through its engine replica.
    pub tokens: AtomicU64,
    /// Scheduler backlog (queued items) observed at each dispatch to this
    /// worker — a persistently high mean means the pool is undersized.
    queue_depth: Mutex<Summary>,
    /// Lane-fill fraction of this worker's batches.
    fill: Mutex<Summary>,
}

impl WorkerMetrics {
    pub fn mean_queue_depth(&self) -> f64 {
        self.queue_depth.lock().unwrap().mean()
    }

    pub fn mean_fill(&self) -> f64 {
        self.fill.lock().unwrap().mean()
    }
}

#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub chunks: AtomicU64,
    pub batches: AtomicU64,
    pub bytes_in: AtomicU64,
    pub bytes_out: AtomicU64,
    /// Total tokens pushed through the engines (compress + decompress).
    pub tokens: AtomicU64,
    pub errors: AtomicU64,
    latency_ms: Mutex<Summary>,
    occupancy: Mutex<Summary>,
    /// Per-batch engine throughput samples (tokens/second).
    tokens_per_sec: Mutex<Summary>,
    /// Recent per-request latency samples (ms) by op, for percentiles.
    compress_lat_ms: Mutex<LatencyStore>,
    decompress_lat_ms: Mutex<LatencyStore>,
    /// Live replica gauge (autoscaled pools move this at runtime).
    pub replicas: AtomicU64,
    /// Autoscale actions taken (a grow only counts once its worker is up).
    pub scale_ups: AtomicU64,
    pub scale_downs: AtomicU64,
    /// Grow decisions denied because the shared fleet
    /// [`ReplicaBudget`](crate::coordinator::ReplicaBudget) had no free
    /// permit — the fleet-level arbitration signal (always 0 without a
    /// budget).
    pub grows_denied: AtomicU64,
    /// Low/high watermarks of the replica gauge over the server's life —
    /// the bound the autoscale tests assert. `replicas_low` starts at
    /// `u64::MAX` ("never set") so a genuine gauge value of 0 — every
    /// replica dead — is a real watermark, not a sentinel. Construct
    /// through [`Metrics::new`]/[`Metrics::with_workers`] (a bare
    /// `Default` leaves the low watermark at 0).
    pub replicas_low: AtomicU64,
    pub replicas_peak: AtomicU64,
    /// One slot per engine worker (replica); empty on bare `new()`. An
    /// autoscaled server sizes this to `max_replicas` so every worker the
    /// pool can ever grow has its attribution slot from the start.
    pub workers: Vec<WorkerMetrics>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::with_workers(0)
    }

    /// Metrics for a server with `n` engine workers.
    pub fn with_workers(n: usize) -> Self {
        Metrics {
            workers: (0..n).map(|_| WorkerMetrics::default()).collect(),
            replicas_low: AtomicU64::new(u64::MAX),
            ..Default::default()
        }
    }

    pub fn record_request(&self, bytes_in: usize, bytes_out: usize, latency: Duration) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.bytes_in.fetch_add(bytes_in as u64, Ordering::Relaxed);
        self.bytes_out.fetch_add(bytes_out as u64, Ordering::Relaxed);
        self.latency_ms.lock().unwrap().add(latency.as_secs_f64() * 1e3);
    }

    /// Request completion with its op kind: updates the aggregate counters
    /// AND the per-op latency histogram behind the p50/p99 accessors.
    pub fn record_request_op(
        &self,
        kind: WorkKind,
        bytes_in: usize,
        bytes_out: usize,
        latency: Duration,
    ) {
        self.record_request(bytes_in, bytes_out, latency);
        self.latency_store(kind).lock().unwrap().push(latency.as_secs_f64() * 1e3);
    }

    fn latency_store(&self, kind: WorkKind) -> &Mutex<LatencyStore> {
        match kind {
            WorkKind::Compress => &self.compress_lat_ms,
            WorkKind::Decompress => &self.decompress_lat_ms,
        }
    }

    /// Latency percentile in ms for one op kind over the most recent
    /// samples (`q` in [0, 1]; 0 before any request of that kind
    /// completed).
    pub fn latency_percentile_ms(&self, kind: WorkKind, q: f64) -> f64 {
        let mut samples = self.latency_store(kind).lock().unwrap().samples.clone();
        percentile(&mut samples, q)
    }

    /// (p50, p99) in ms for one op kind from a single snapshot — one
    /// clone + sort serves both quantiles (`report()` uses this so it
    /// doesn't churn the sample window four times).
    pub fn latency_p50_p99_ms(&self, kind: WorkKind) -> (f64, f64) {
        let mut samples = self.latency_store(kind).lock().unwrap().samples.clone();
        let p50 = percentile(&mut samples, 0.5);
        // Already sorted by the first call; the second sort is a no-op pass.
        (p50, percentile(&mut samples, 0.99))
    }

    /// Completed-request count for one op kind (total ever, not capped).
    pub fn latency_samples(&self, kind: WorkKind) -> usize {
        self.latency_store(kind).lock().unwrap().total as usize
    }

    /// Per-batch fill: how many of the engine's lanes this batch used.
    pub fn record_batch(&self, items: usize, lanes: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.chunks.fetch_add(items as u64, Ordering::Relaxed);
        self.occupancy.lock().unwrap().add(items as f64 / lanes as f64);
    }

    /// A batch of `items` was handed to engine worker `worker` while
    /// `depth` items were still queued in the scheduler.
    pub fn record_dispatch(&self, worker: usize, items: usize, lanes: usize, depth: usize) {
        self.record_batch(items, lanes);
        if let Some(w) = self.workers.get(worker) {
            w.batches.fetch_add(1, Ordering::Relaxed);
            w.items.fetch_add(items as u64, Ordering::Relaxed);
            w.queue_depth.lock().unwrap().add(depth as f64);
            w.fill.lock().unwrap().add(items as f64 / lanes.max(1) as f64);
        }
    }

    /// Engine-pass throughput: `tokens` processed in `elapsed` wall time.
    pub fn record_engine(&self, tokens: usize, elapsed: Duration) {
        self.tokens.fetch_add(tokens as u64, Ordering::Relaxed);
        let secs = elapsed.as_secs_f64();
        if tokens > 0 && secs > 0.0 {
            self.tokens_per_sec.lock().unwrap().add(tokens as f64 / secs);
        }
    }

    /// [`Self::record_engine`] attributed to one engine worker.
    pub fn record_engine_worker(&self, worker: usize, tokens: usize, elapsed: Duration) {
        self.record_engine(tokens, elapsed);
        if let Some(w) = self.workers.get(worker) {
            w.tokens.fetch_add(tokens as u64, Ordering::Relaxed);
        }
    }

    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Update the live-replica gauge (and its low/high watermarks). A
    /// gauge of 0 — every replica dead — is recorded in the low watermark
    /// like any other value (it starts at `u64::MAX`, not 0).
    pub fn set_replicas(&self, n: usize) {
        let n = n as u64;
        self.replicas.store(n, Ordering::Relaxed);
        self.replicas_peak.fetch_max(n, Ordering::Relaxed);
        self.replicas_low.fetch_min(n, Ordering::Relaxed);
    }

    /// One autoscale action landed: the pool now serves `now_live` replicas.
    pub fn record_scale(&self, up: bool, now_live: usize) {
        if up {
            self.scale_ups.fetch_add(1, Ordering::Relaxed);
        } else {
            self.scale_downs.fetch_add(1, Ordering::Relaxed);
        }
        self.set_replicas(now_live);
    }

    /// A grow decision was vetoed by the shared fleet replica budget.
    pub fn record_grow_denied(&self) {
        self.grows_denied.fetch_add(1, Ordering::Relaxed);
    }

    /// Human-readable snapshot.
    pub fn report(&self) -> String {
        let (c_p50, c_p99) = self.latency_p50_p99_ms(WorkKind::Compress);
        let (d_p50, d_p99) = self.latency_p50_p99_ms(WorkKind::Decompress);
        let lat = self.latency_ms.lock().unwrap();
        let occ = self.occupancy.lock().unwrap();
        let tps = self.tokens_per_sec.lock().unwrap();
        let mut s = format!(
            "requests={} chunks={} batches={} bytes_in={} bytes_out={} tokens={} errors={} \
             replicas={} scale_ups={} scale_downs={} grows_denied={} \
             latency_ms[mean={:.2} max={:.2}] batch_fill[mean={:.2}] \
             engine_tok_per_s[mean={:.0} max={:.0}] \
             compress_ms[p50={:.2} p99={:.2}] decompress_ms[p50={:.2} p99={:.2}]",
            self.requests.load(Ordering::Relaxed),
            self.chunks.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.bytes_in.load(Ordering::Relaxed),
            self.bytes_out.load(Ordering::Relaxed),
            self.tokens.load(Ordering::Relaxed),
            self.errors.load(Ordering::Relaxed),
            self.replicas.load(Ordering::Relaxed),
            self.scale_ups.load(Ordering::Relaxed),
            self.scale_downs.load(Ordering::Relaxed),
            self.grows_denied.load(Ordering::Relaxed),
            lat.mean(),
            lat.max(),
            occ.mean(),
            tps.mean(),
            // max() is NEG_INFINITY on an empty summary; mean() is 0.
            if tps.count() == 0 { 0.0 } else { tps.max() },
            c_p50,
            c_p99,
            d_p50,
            d_p99,
        );
        for (i, w) in self.workers.iter().enumerate() {
            s.push_str(&format!(
                " worker{}[batches={} items={} tokens={} fill={:.2} qdepth={:.1}]",
                i,
                w.batches.load(Ordering::Relaxed),
                w.items.load(Ordering::Relaxed),
                w.tokens.load(Ordering::Relaxed),
                w.mean_fill(),
                w.mean_queue_depth(),
            ));
        }
        s
    }

    pub fn mean_occupancy(&self) -> f64 {
        self.occupancy.lock().unwrap().mean()
    }

    pub fn mean_latency_ms(&self) -> f64 {
        self.latency_ms.lock().unwrap().mean()
    }

    /// Mean per-batch engine throughput (tokens/second; 0 before any batch).
    pub fn mean_tokens_per_sec(&self) -> f64 {
        self.tokens_per_sec.lock().unwrap().mean()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_request(100, 10, Duration::from_millis(5));
        m.record_request(200, 20, Duration::from_millis(15));
        m.record_batch(6, 8);
        m.record_batch(8, 8);
        assert_eq!(m.requests.load(Ordering::Relaxed), 2);
        assert_eq!(m.bytes_in.load(Ordering::Relaxed), 300);
        assert!((m.mean_occupancy() - 0.875).abs() < 1e-12);
        assert!((m.mean_latency_ms() - 10.0).abs() < 1e-9);
        let r = m.report();
        assert!(r.contains("requests=2"));
    }

    #[test]
    fn engine_throughput_tracks_tokens() {
        let m = Metrics::new();
        assert_eq!(m.mean_tokens_per_sec(), 0.0);
        m.record_engine(1000, Duration::from_millis(500));
        m.record_engine(1000, Duration::from_millis(250));
        assert_eq!(m.tokens.load(Ordering::Relaxed), 2000);
        // Mean of 2000 t/s and 4000 t/s.
        assert!((m.mean_tokens_per_sec() - 3000.0).abs() < 1.0);
        // Zero-token or zero-duration passes don't poison the summary.
        m.record_engine(0, Duration::from_millis(10));
        assert!((m.mean_tokens_per_sec() - 3000.0).abs() < 1.0);
        assert!(m.report().contains("tokens=2000"));
    }

    #[test]
    fn per_op_latency_percentiles() {
        let m = Metrics::new();
        assert_eq!(m.latency_percentile_ms(WorkKind::Decompress, 0.99), 0.0);
        for i in 1..=100u64 {
            m.record_request_op(WorkKind::Decompress, 10, 10, Duration::from_millis(i));
        }
        m.record_request_op(WorkKind::Compress, 10, 10, Duration::from_millis(500));
        assert_eq!(m.latency_samples(WorkKind::Decompress), 100);
        let p50 = m.latency_percentile_ms(WorkKind::Decompress, 0.5);
        let p99 = m.latency_percentile_ms(WorkKind::Decompress, 0.99);
        assert!((p50 - 50.5).abs() < 1e-6, "{p50}");
        assert!((p99 - 99.01).abs() < 1e-6, "{p99}");
        // Single-snapshot accessor agrees with the per-quantile one.
        assert_eq!(m.latency_p50_p99_ms(WorkKind::Decompress), (p50, p99));
        // Kinds are independent histograms.
        assert!((m.latency_percentile_ms(WorkKind::Compress, 0.5) - 500.0).abs() < 1e-6);
        // The aggregate request counter sees both.
        assert_eq!(m.requests.load(Ordering::Relaxed), 101);
    }

    #[test]
    fn latency_ring_keeps_recent_samples() {
        // Past the cap, old samples are overwritten (percentiles track the
        // recent window) and the total keeps counting.
        let mut s = LatencyStore::default();
        for _ in 0..MAX_LATENCY_SAMPLES {
            s.push(1.0);
        }
        for _ in 0..MAX_LATENCY_SAMPLES {
            s.push(9.0);
        }
        assert_eq!(s.total as usize, 2 * MAX_LATENCY_SAMPLES);
        assert_eq!(s.samples.len(), MAX_LATENCY_SAMPLES);
        assert!(s.samples.iter().all(|&x| x == 9.0), "window fully refreshed");
    }

    #[test]
    fn replica_gauge_tracks_watermarks() {
        let m = Metrics::with_workers(4);
        assert_eq!(m.replicas_low.load(Ordering::Relaxed), u64::MAX, "MAX = never set");
        m.set_replicas(2);
        m.record_scale(true, 3);
        m.record_scale(true, 4);
        m.record_scale(false, 3);
        m.record_scale(false, 1);
        assert_eq!(m.replicas.load(Ordering::Relaxed), 1);
        assert_eq!(m.scale_ups.load(Ordering::Relaxed), 2);
        assert_eq!(m.scale_downs.load(Ordering::Relaxed), 2);
        assert_eq!(m.replicas_peak.load(Ordering::Relaxed), 4);
        assert_eq!(m.replicas_low.load(Ordering::Relaxed), 1);
        // A genuine all-dead window is a real watermark, not a sentinel:
        // later recoveries must not erase it.
        m.set_replicas(0);
        m.record_scale(true, 1);
        assert_eq!(m.replicas_low.load(Ordering::Relaxed), 0);
        let r = m.report();
        assert!(r.contains("replicas=1"), "{r}");
        assert!(r.contains("scale_ups=3"), "{r}");
    }

    #[test]
    fn per_worker_attribution() {
        let m = Metrics::with_workers(2);
        m.record_dispatch(0, 4, 8, 12);
        m.record_dispatch(1, 8, 8, 0);
        m.record_dispatch(1, 2, 8, 3);
        m.record_engine_worker(0, 400, Duration::from_millis(10));
        m.record_engine_worker(1, 600, Duration::from_millis(10));
        assert_eq!(m.workers[0].batches.load(Ordering::Relaxed), 1);
        assert_eq!(m.workers[1].batches.load(Ordering::Relaxed), 2);
        assert_eq!(m.workers[0].tokens.load(Ordering::Relaxed), 400);
        assert_eq!(m.tokens.load(Ordering::Relaxed), 1000);
        assert!((m.workers[1].mean_fill() - 0.625).abs() < 1e-12);
        assert!((m.workers[0].mean_queue_depth() - 12.0).abs() < 1e-12);
        assert_eq!(m.batches.load(Ordering::Relaxed), 3);
        // Out-of-range worker ids are ignored, not panicking.
        m.record_dispatch(9, 1, 8, 0);
        assert_eq!(m.batches.load(Ordering::Relaxed), 4);
        assert!(m.report().contains("worker1[batches=2"));
    }
}
