//! Service metrics: counters + latency/occupancy summaries.

use crate::util::stats::Summary;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub chunks: AtomicU64,
    pub batches: AtomicU64,
    pub bytes_in: AtomicU64,
    pub bytes_out: AtomicU64,
    /// Total tokens pushed through the engine (compress + decompress).
    pub tokens: AtomicU64,
    pub errors: AtomicU64,
    latency_ms: Mutex<Summary>,
    occupancy: Mutex<Summary>,
    /// Per-batch engine throughput samples (tokens/second).
    tokens_per_sec: Mutex<Summary>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_request(&self, bytes_in: usize, bytes_out: usize, latency: Duration) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.bytes_in.fetch_add(bytes_in as u64, Ordering::Relaxed);
        self.bytes_out.fetch_add(bytes_out as u64, Ordering::Relaxed);
        self.latency_ms.lock().unwrap().add(latency.as_secs_f64() * 1e3);
    }

    /// Per-batch fill: how many of the engine's lanes this batch used.
    pub fn record_batch(&self, items: usize, lanes: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.chunks.fetch_add(items as u64, Ordering::Relaxed);
        self.occupancy.lock().unwrap().add(items as f64 / lanes as f64);
    }

    /// Engine-pass throughput: `tokens` processed in `elapsed` wall time.
    pub fn record_engine(&self, tokens: usize, elapsed: Duration) {
        self.tokens.fetch_add(tokens as u64, Ordering::Relaxed);
        let secs = elapsed.as_secs_f64();
        if tokens > 0 && secs > 0.0 {
            self.tokens_per_sec.lock().unwrap().add(tokens as f64 / secs);
        }
    }

    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Human-readable snapshot.
    pub fn report(&self) -> String {
        let lat = self.latency_ms.lock().unwrap();
        let occ = self.occupancy.lock().unwrap();
        let tps = self.tokens_per_sec.lock().unwrap();
        format!(
            "requests={} chunks={} batches={} bytes_in={} bytes_out={} tokens={} errors={} \
             latency_ms[mean={:.2} max={:.2}] batch_fill[mean={:.2}] \
             engine_tok_per_s[mean={:.0} max={:.0}]",
            self.requests.load(Ordering::Relaxed),
            self.chunks.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.bytes_in.load(Ordering::Relaxed),
            self.bytes_out.load(Ordering::Relaxed),
            self.tokens.load(Ordering::Relaxed),
            self.errors.load(Ordering::Relaxed),
            lat.mean(),
            lat.max(),
            occ.mean(),
            tps.mean(),
            // max() is NEG_INFINITY on an empty summary; mean() is 0.
            if tps.count() == 0 { 0.0 } else { tps.max() },
        )
    }

    pub fn mean_occupancy(&self) -> f64 {
        self.occupancy.lock().unwrap().mean()
    }

    pub fn mean_latency_ms(&self) -> f64 {
        self.latency_ms.lock().unwrap().mean()
    }

    /// Mean per-batch engine throughput (tokens/second; 0 before any batch).
    pub fn mean_tokens_per_sec(&self) -> f64 {
        self.tokens_per_sec.lock().unwrap().mean()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_request(100, 10, Duration::from_millis(5));
        m.record_request(200, 20, Duration::from_millis(15));
        m.record_batch(6, 8);
        m.record_batch(8, 8);
        assert_eq!(m.requests.load(Ordering::Relaxed), 2);
        assert_eq!(m.bytes_in.load(Ordering::Relaxed), 300);
        assert!((m.mean_occupancy() - 0.875).abs() < 1e-12);
        assert!((m.mean_latency_ms() - 10.0).abs() < 1e-9);
        let r = m.report();
        assert!(r.contains("requests=2"));
    }

    #[test]
    fn engine_throughput_tracks_tokens() {
        let m = Metrics::new();
        assert_eq!(m.mean_tokens_per_sec(), 0.0);
        m.record_engine(1000, Duration::from_millis(500));
        m.record_engine(1000, Duration::from_millis(250));
        assert_eq!(m.tokens.load(Ordering::Relaxed), 2000);
        // Mean of 2000 t/s and 4000 t/s.
        assert!((m.mean_tokens_per_sec() - 3000.0).abs() < 1.0);
        // Zero-token or zero-duration passes don't poison the summary.
        m.record_engine(0, Duration::from_millis(10));
        assert!((m.mean_tokens_per_sec() - 3000.0).abs() < 1.0);
        assert!(m.report().contains("tokens=2000"));
    }
}
