//! Service metrics: counters + latency/occupancy summaries.

use crate::util::stats::Summary;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub chunks: AtomicU64,
    pub batches: AtomicU64,
    pub bytes_in: AtomicU64,
    pub bytes_out: AtomicU64,
    pub errors: AtomicU64,
    latency_ms: Mutex<Summary>,
    occupancy: Mutex<Summary>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_request(&self, bytes_in: usize, bytes_out: usize, latency: Duration) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.bytes_in.fetch_add(bytes_in as u64, Ordering::Relaxed);
        self.bytes_out.fetch_add(bytes_out as u64, Ordering::Relaxed);
        self.latency_ms.lock().unwrap().add(latency.as_secs_f64() * 1e3);
    }

    pub fn record_batch(&self, items: usize, lanes: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.chunks.fetch_add(items as u64, Ordering::Relaxed);
        self.occupancy.lock().unwrap().add(items as f64 / lanes as f64);
    }

    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Human-readable snapshot.
    pub fn report(&self) -> String {
        let lat = self.latency_ms.lock().unwrap();
        let occ = self.occupancy.lock().unwrap();
        format!(
            "requests={} chunks={} batches={} bytes_in={} bytes_out={} errors={} \
             latency_ms[mean={:.2} max={:.2}] batch_occupancy[mean={:.2}]",
            self.requests.load(Ordering::Relaxed),
            self.chunks.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.bytes_in.load(Ordering::Relaxed),
            self.bytes_out.load(Ordering::Relaxed),
            self.errors.load(Ordering::Relaxed),
            lat.mean(),
            lat.max(),
            occ.mean(),
        )
    }

    pub fn mean_occupancy(&self) -> f64 {
        self.occupancy.lock().unwrap().mean()
    }

    pub fn mean_latency_ms(&self) -> f64 {
        self.latency_ms.lock().unwrap().mean()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_request(100, 10, Duration::from_millis(5));
        m.record_request(200, 20, Duration::from_millis(15));
        m.record_batch(6, 8);
        m.record_batch(8, 8);
        assert_eq!(m.requests.load(Ordering::Relaxed), 2);
        assert_eq!(m.bytes_in.load(Ordering::Relaxed), 300);
        assert!((m.mean_occupancy() - 0.875).abs() < 1e-12);
        assert!((m.mean_latency_ms() - 10.0).abs() < 1e-9);
        let r = m.report();
        assert!(r.contains("requests=2"));
    }
}
