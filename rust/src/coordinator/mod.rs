//! L3 coordinator: the batched compression service over an engine-replica
//! pool.
//!
//! vLLM-router-shaped: requests are split into chunk work items, items
//! from *concurrent requests* are packed into shared `[lanes]`-wide engine
//! batches by the [`batcher::DynamicBatcher`] (flush on full-or-deadline,
//! decompress fast lane, per-item [`batcher::Priority`]), a scheduler
//! thread dispatches released batches onto an **elastic** pool of engine
//! workers (each owning a full compressor; native replicas share ONE
//! `Arc<Weights>` and can share one work-stealing
//! [`crate::lm::native::StepPool`]), and the [`router`] reassembles
//! per-request results in order. With [`router::ServerConfig::autoscale`]
//! the scheduler grows and shrinks the worker set between
//! `min_replicas`/`max_replicas` from its queue-depth (and optional p99)
//! signals — hysteresis + cooldown, provably invisible in the container
//! bytes (see `tests/stress_elastic.rs`). Metrics cover throughput, batch
//! occupancy, per-op latency percentiles (p50/p99), per-worker queue
//! depth/fill, and the replica gauge + scale-event counters.
//!
//! The client face is **ticketed and asynchronous**: [`router::Server::submit`]
//! returns a [`router::Ticket`] immediately (wait/try_wait), and
//! [`router::Server::open_stream`] opens an incremental compression
//! session whose chunks enter the batcher as they arrive — engine work
//! overlaps input arrival, and the finished container is byte-identical
//! to the one-shot path. [`wire`] exposes both over TCP: a multiplexed
//! framed protocol (request ids, chunked uploads, interleaved responses
//! on one persistent connection) with the legacy serial protocol
//! auto-detected for old clients.
//!
//! [`fleet`] stacks a multi-model layer on top: a [`fleet::FleetServer`]
//! hosts one replica pool per model, routes requests by model key or
//! container tag through a [`crate::compress::ModelRegistry`], arbitrates
//! every pool's autoscaler against ONE global [`router::ReplicaBudget`],
//! pages cold pools out under a memory budget (fingerprint-verified
//! reload), and layers tenant QoS on the batcher's weighted-fair queues —
//! with rate limits and load shedding that surface as clean wire errors.
//! See `docs/fleet.md` for the contract.
//!
//! No tokio in this environment: the coordinator is built on std threads +
//! mpsc channels — one scheduler plus one OS thread per engine replica,
//! which is exactly the right weight for CPU-bound engines.

pub mod batcher;
pub mod fleet;
pub mod metrics;
pub mod router;
pub mod wire;

pub use batcher::{BatchPolicy, DynamicBatcher, Priority, WorkItem, WorkKind};
pub use fleet::{
    FleetConfig, FleetMetrics, FleetModelSpec, FleetServer, TenantSpec, WeightsLoader,
    WireService, WireStream, WireTicket,
};
pub use metrics::{Metrics, WorkerMetrics};
pub use router::{Op, ReplicaBudget, ScaleHook, Server, ServerConfig, StreamHandle, Ticket};
pub use wire::{Client, MuxClient};
