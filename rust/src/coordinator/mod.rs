//! L3 coordinator: the batched compression service.
//!
//! vLLM-router-shaped: requests are split into chunk work items, items from
//! *concurrent requests* are packed into shared `[lanes]`-wide engine
//! batches by the [`batcher::DynamicBatcher`] (flush on full-or-deadline),
//! one worker thread owns the engine (the GPU-analog), and the
//! [`router`] reassembles per-request results in order. Metrics cover
//! throughput, batch occupancy and per-request latency.
//!
//! No tokio in this environment: the coordinator is built on std threads +
//! mpsc channels, which is exactly the right weight for a single-device
//! executor anyway (one worker saturates the one CPU).

pub mod batcher;
pub mod metrics;
pub mod router;

pub use batcher::{BatchPolicy, DynamicBatcher, WorkItem, WorkKind};
pub use metrics::Metrics;
pub use router::{Server, ServerConfig};
