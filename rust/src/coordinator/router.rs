//! Request router: intake, chunking, cross-request batching, reassembly.
//!
//! One worker thread owns the engine (via [`LlmCompressor`]); client
//! threads submit requests through a channel and block on a per-request
//! response channel. Chunks from concurrent requests share engine batches.

use crate::compress::container::{ChunkRecord, Container};
use crate::compress::llm::LlmCompressor;
use crate::coordinator::batcher::{BatchPolicy, DynamicBatcher, WorkItem, WorkKind};
use crate::coordinator::metrics::Metrics;
use crate::util::crc32;
use crate::Result;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub chunk_tokens: usize,
    /// Batch-width cap: limit engine batches to this many lanes
    /// (`0` = use the engine's full lane count). The effective width is
    /// always `min(lanes, engine lanes)`.
    pub lanes: usize,
    /// Native-engine worker threads. The worker cannot rebuild the engine
    /// (the factory owns construction), so this is the value `cmd/serve`
    /// wires into `LlmCompressorConfig::threads`; it is recorded here so
    /// the whole lane/thread configuration travels through one struct.
    pub threads: usize,
    pub policy: BatchPolicy,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { chunk_tokens: 256, lanes: 0, threads: 0, policy: BatchPolicy::default() }
    }
}

enum Op {
    Compress(Vec<u8>),
    Decompress(Vec<u8>),
}

struct Request {
    id: u64,
    op: Op,
    respond: SyncSender<Result<Vec<u8>>>,
    started: Instant,
}

/// Per-request reassembly state.
struct Pending {
    respond: SyncSender<Result<Vec<u8>>>,
    started: Instant,
    kind: WorkKind,
    /// Results by chunk index (compress: payloads; decompress: raw bytes).
    results: Vec<Option<Vec<u8>>>,
    remaining: usize,
    /// Compress: original lengths per chunk + source crc/len for container.
    chunk_sizes: Vec<u32>,
    orig_len: u64,
    orig_crc: u32,
    container_chunk_tokens: u32,
    bytes_in: usize,
}

/// The compression service.
pub struct Server {
    tx: SyncSender<Request>,
    next_id: AtomicU64,
    pub metrics: Arc<Metrics>,
    shutdown: Arc<AtomicBool>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start the worker thread. The compressor is built INSIDE the worker by
    /// `factory` because PJRT handles are thread-affine (`!Send`); the
    /// factory itself only captures plain data.
    pub fn start<F>(factory: F, config: ServerConfig) -> Result<Server>
    where
        F: FnOnce() -> Result<LlmCompressor> + Send + 'static,
    {
        let (tx, rx) = sync_channel::<Request>(256);
        let metrics = Arc::new(Metrics::new());
        let shutdown = Arc::new(AtomicBool::new(false));
        let m = metrics.clone();
        let sd = shutdown.clone();
        let (ready_tx, ready_rx) = sync_channel::<Result<()>>(1);
        let worker = std::thread::Builder::new()
            .name("llmzip-worker".into())
            .spawn(move || {
                let compressor = match factory() {
                    Ok(c) => {
                        let _ = ready_tx.send(Ok(()));
                        c
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                worker_loop(compressor, config, rx, m, sd)
            })
            .expect("spawning worker");
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("worker died during startup"))??;
        Ok(Server { tx, next_id: AtomicU64::new(1), metrics, shutdown, worker: Some(worker) })
    }

    fn submit(&self, op: Op) -> Result<Vec<u8>> {
        let (rtx, rrx) = sync_channel(1);
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.tx
            .send(Request { id, op, respond: rtx, started: Instant::now() })
            .map_err(|_| anyhow::anyhow!("server is shut down"))?;
        rrx.recv().map_err(|_| anyhow::anyhow!("server dropped the request"))?
    }

    /// Compress `data`, returning a container (blocks until done).
    pub fn compress(&self, data: &[u8]) -> Result<Vec<u8>> {
        self.submit(Op::Compress(data.to_vec()))
    }

    /// Decompress a container (blocks until done).
    pub fn decompress(&self, container: &[u8]) -> Result<Vec<u8>> {
        self.submit(Op::Decompress(container.to_vec()))
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    compressor: LlmCompressor,
    config: ServerConfig,
    rx: Receiver<Request>,
    metrics: Arc<Metrics>,
    shutdown: Arc<AtomicBool>,
) {
    let engine_lanes = compressor.lanes();
    let lanes = if config.lanes > 0 { config.lanes.min(engine_lanes) } else { engine_lanes };
    // Requests are split at the compressor's stream granularity; the
    // model-context chunk size is recorded in each container.
    let split = Split {
        stream_bytes: compressor.stream_bytes(),
        chunk_tokens: compressor.chunk_tokens() as u32,
    };
    let mut batcher = DynamicBatcher::new(BatchPolicy { lanes, ..config.policy });
    let mut pending: HashMap<u64, Pending> = HashMap::new();
    loop {
        if shutdown.load(Ordering::SeqCst) && pending.is_empty() && batcher.pending() == 0 {
            return;
        }
        // Intake: wait until the next deadline (or a short poll interval).
        let timeout = batcher
            .next_deadline()
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(10));
        match rx.recv_timeout(timeout) {
            Ok(req) => admit(req, split, &mut batcher, &mut pending),
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => {
                if pending.is_empty() && batcher.pending() == 0 {
                    return;
                }
            }
        }
        // Drain without blocking to fill batches.
        while batcher.pending() < lanes {
            match rx.try_recv() {
                Ok(req) => admit(req, split, &mut batcher, &mut pending),
                Err(_) => break,
            }
        }
        // Execute released batches.
        while let Some((kind, items)) = batcher.next_batch(Instant::now()) {
            metrics.record_batch(items.len(), lanes);
            run_batch(&compressor, kind, items, &mut pending, &metrics, &config);
        }
    }
}

#[derive(Clone, Copy)]
struct Split {
    stream_bytes: usize,
    chunk_tokens: u32,
}

fn admit(
    req: Request,
    split: Split,
    batcher: &mut DynamicBatcher,
    pending: &mut HashMap<u64, Pending>,
) {
    let now = Instant::now();
    match req.op {
        Op::Compress(data) => {
            let chunks: Vec<&[u8]> = data.chunks(split.stream_bytes).collect();
            let n = chunks.len().max(1);
            let entry = Pending {
                respond: req.respond,
                started: req.started,
                kind: WorkKind::Compress,
                results: vec![None; n],
                remaining: n,
                chunk_sizes: chunks.iter().map(|c| c.len() as u32).collect(),
                orig_len: data.len() as u64,
                orig_crc: crc32(&data),
                container_chunk_tokens: split.chunk_tokens,
                bytes_in: data.len(),
            };
            if data.is_empty() {
                // Zero-chunk request: answer immediately with an empty container.
                let container = Container {
                    orig_len: 0,
                    orig_crc32: entry.orig_crc,
                    chunk_tokens: entry.container_chunk_tokens,
                    model_name: String::new(), // filled by finish(); placeholder
                    chunks: vec![],
                    payload: vec![],
                };
                let _ = entry.respond.send(Ok(container.to_bytes()));
                return;
            }
            pending.insert(req.id, entry);
            for (i, chunk) in chunks.iter().enumerate() {
                batcher.push(WorkItem {
                    request_id: req.id,
                    chunk_index: i as u32,
                    kind: WorkKind::Compress,
                    data: chunk.to_vec(),
                    record: None,
                    enqueued: now,
                });
            }
        }
        Op::Decompress(bytes) => match Container::from_bytes(&bytes) {
            Err(e) => {
                let _ = req.respond.send(Err(e));
            }
            Ok(container) => {
                let items: Vec<(ChunkRecord, Vec<u8>)> =
                    container.iter_chunks().map(|(r, p)| (r, p.to_vec())).collect();
                let n = items.len().max(1);
                let entry = Pending {
                    respond: req.respond,
                    started: req.started,
                    kind: WorkKind::Decompress,
                    results: vec![None; n],
                    remaining: items.len(),
                    chunk_sizes: vec![],
                    orig_len: container.orig_len,
                    orig_crc: container.orig_crc32,
                    container_chunk_tokens: container.chunk_tokens,
                    bytes_in: bytes.len(),
                };
                if items.is_empty() {
                    let _ = entry.respond.send(Ok(Vec::new()));
                    return;
                }
                pending.insert(req.id, entry);
                for (i, (rec, payload)) in items.into_iter().enumerate() {
                    batcher.push(WorkItem {
                        request_id: req.id,
                        chunk_index: i as u32,
                        kind: WorkKind::Decompress,
                        data: payload,
                        record: Some(rec),
                        enqueued: now,
                    });
                }
            }
        },
    }
}

fn run_batch(
    compressor: &LlmCompressor,
    kind: WorkKind,
    items: Vec<WorkItem>,
    pending: &mut HashMap<u64, Pending>,
    metrics: &Metrics,
    config: &ServerConfig,
) {
    // Engine throughput: every byte is one model token, on both passes.
    let batch_tokens: usize = match kind {
        WorkKind::Compress => items.iter().map(|i| i.data.len()).sum(),
        WorkKind::Decompress => items
            .iter()
            .map(|i| i.record.map(|r| r.n_tokens as usize).unwrap_or(0))
            .sum(),
    };
    let engine_t0 = Instant::now();
    let result = match kind {
        WorkKind::Compress => {
            let chunks: Vec<&[u8]> = items.iter().map(|i| i.data.as_slice()).collect();
            compressor.compress_chunks(&chunks)
        }
        WorkKind::Decompress => {
            let records: Vec<ChunkRecord> =
                items.iter().map(|i| i.record.expect("decode item has record")).collect();
            let payloads: Vec<&[u8]> = items.iter().map(|i| i.data.as_slice()).collect();
            // All items in a decompress batch share the worker's configured
            // context window (the server decodes its own containers).
            compressor.decompress_chunks(compressor.chunk_tokens(), &records, &payloads)
        }
    };
    if result.is_ok() {
        metrics.record_engine(batch_tokens, engine_t0.elapsed());
    }
    match result {
        Err(e) => {
            // Fail every request that had a chunk in this batch.
            metrics.record_error();
            let msg = format!("batch failed: {e:#}");
            for item in items {
                if let Some(p) = pending.remove(&item.request_id) {
                    let _ = p.respond.send(Err(anyhow::anyhow!(msg.clone())));
                }
            }
        }
        Ok(outputs) => {
            for (item, out) in items.into_iter().zip(outputs) {
                let Some(p) = pending.get_mut(&item.request_id) else { continue };
                p.results[item.chunk_index as usize] = Some(out);
                p.remaining -= 1;
                if p.remaining == 0 {
                    let p = pending.remove(&item.request_id).unwrap();
                    finish(compressor, p, metrics, config);
                }
            }
        }
    }
}

fn finish(compressor: &LlmCompressor, p: Pending, metrics: &Metrics, _config: &ServerConfig) {
    let response: Result<Vec<u8>> = match p.kind {
        WorkKind::Compress => {
            let mut records = Vec::with_capacity(p.results.len());
            let mut payload = Vec::new();
            for (i, r) in p.results.iter().enumerate() {
                let bytes = r.as_ref().expect("all chunks done");
                records.push(ChunkRecord {
                    comp_len: bytes.len() as u32,
                    n_tokens: p.chunk_sizes[i],
                });
                payload.extend_from_slice(bytes);
            }
            Ok(Container {
                orig_len: p.orig_len,
                orig_crc32: p.orig_crc,
                chunk_tokens: p.container_chunk_tokens,
                model_name: compressor.container_tag(),
                chunks: records,
                payload,
            }
            .to_bytes())
        }
        WorkKind::Decompress => {
            let mut out = Vec::with_capacity(p.orig_len as usize);
            for r in &p.results {
                out.extend_from_slice(r.as_ref().expect("all chunks done"));
            }
            if out.len() as u64 != p.orig_len || crc32(&out) != p.orig_crc {
                Err(anyhow::anyhow!("decompressed output failed CRC/length verification"))
            } else {
                Ok(out)
            }
        }
    };
    let out_len = response.as_ref().map(|v| v.len()).unwrap_or(0);
    metrics.record_request(p.bytes_in, out_len, p.started.elapsed());
    let _ = p.respond.send(response);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lm::config::by_name;
    use crate::lm::weights::Weights;

    fn test_server(chunk: usize, lanes: usize) -> Server {
        Server::start(
            move || {
                let cfg = by_name("nano").unwrap();
                LlmCompressor::from_weights(cfg, Weights::random(cfg, 21), chunk, lanes)
            },
            ServerConfig {
                chunk_tokens: chunk,
                policy: BatchPolicy { lanes, max_wait: Duration::from_millis(5) },
                ..Default::default()
            },
        )
        .unwrap()
    }

    #[test]
    fn roundtrip_through_server() {
        let server = test_server(32, 2);
        let data = crate::textgen::quick_sample(300, 9);
        let z = server.compress(&data).unwrap();
        let back = server.decompress(&z).unwrap();
        assert_eq!(back, data);
        assert!(server.metrics.requests.load(Ordering::Relaxed) >= 2);
        // Engine throughput is recorded per batch: every input byte is one
        // token on the compress pass and again on the decompress pass.
        assert_eq!(server.metrics.tokens.load(Ordering::Relaxed), 2 * data.len() as u64);
        assert!(server.metrics.mean_tokens_per_sec() > 0.0);
    }

    #[test]
    fn lane_cap_limits_batch_width() {
        // Engine has 4 lanes but the server is configured to fill at most 2.
        let server = Server::start(
            move || {
                let cfg = by_name("nano").unwrap();
                LlmCompressor::from_weights(cfg, Weights::random(cfg, 22), 16, 4)
            },
            ServerConfig {
                chunk_tokens: 16,
                lanes: 2,
                policy: BatchPolicy { lanes: 8, max_wait: Duration::from_millis(2) },
                ..Default::default()
            },
        )
        .unwrap();
        // 6 chunks (stream granularity 64 bytes) -> at least 3 batches.
        let data = crate::textgen::quick_sample(6 * 64, 10);
        let z = server.compress(&data).unwrap();
        assert_eq!(server.decompress(&z).unwrap(), data);
        let batches = server.metrics.batches.load(Ordering::Relaxed);
        assert!(batches >= 3, "cap 2 lanes over 6 chunks needs >= 3 batches, got {batches}");
    }

    #[test]
    fn empty_request() {
        let server = test_server(32, 2);
        let z = server.compress(b"").unwrap();
        assert_eq!(server.decompress(&z).unwrap(), b"");
    }

    #[test]
    fn concurrent_requests_share_batches() {
        let server = Arc::new(test_server(16, 4));
        let mut handles = Vec::new();
        for i in 0..6 {
            let s = server.clone();
            handles.push(std::thread::spawn(move || {
                let data = crate::textgen::quick_sample(120 + i * 13, i as u64);
                let z = s.compress(&data).unwrap();
                let back = s.decompress(&z).unwrap();
                assert_eq!(back, data);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // Cross-request batching should produce fewer batches than chunks.
        let batches = server.metrics.batches.load(Ordering::Relaxed);
        let chunks = server.metrics.chunks.load(Ordering::Relaxed);
        assert!(batches < chunks, "batches {batches} chunks {chunks}");
    }

    #[test]
    fn corrupt_container_rejected() {
        let server = test_server(32, 2);
        assert!(server.decompress(&[1, 2, 3]).is_err());
        let data = crate::textgen::quick_sample(400, 1);
        let mut z = server.compress(&data).unwrap();
        // Corrupt mid-payload (the tail bytes of a range-coded stream can be
        // flush slack, so flip bits well inside the payload).
        let n = z.len();
        for i in [n / 2, n / 2 + 1, 3 * n / 4] {
            z[i] ^= 0x55;
        }
        assert!(server.decompress(&z).is_err());
    }
}
