//! Request router: ticketed intake, chunking, priority scheduling across
//! an engine-replica pool, and reassembly.
//!
//! Architecture (replica-pool refactor):
//!
//! * **Clients** submit requests through a channel. The primitive is
//!   asynchronous: [`Server::submit`] returns a [`Ticket`] immediately
//!   ([`Ticket::wait`] blocks, [`Ticket::try_wait`] polls), so one client
//!   thread can keep any number of operations in flight — the shape the
//!   multiplexed wire protocol in [`crate::coordinator::wire`] maps
//!   directly onto. The blocking `compress`/`decompress` calls are thin
//!   wrappers. [`Server::open_stream`] opens an **incremental** session:
//!   chunks enter the batcher as the client produces them, so engine work
//!   overlaps input arrival and the finished container is still
//!   byte-identical to the one-shot path.
//! * **One scheduler thread** (`llmzip-sched`) owns intake, the
//!   [`DynamicBatcher`] (decompress fast lane + per-item priorities),
//!   per-request reassembly state, and worker dispatch. It never touches
//!   an engine.
//! * **`replicas` engine workers** (`llmzip-engine-N`), each owning a full
//!   [`LlmCompressor`] built *inside its own thread* by the shared factory
//!   (PJRT handles are thread-affine). Native replicas built from one
//!   `Arc<Weights>` share a single copy of the tensors. Workers receive at
//!   most one batch at a time and report completions back on the
//!   scheduler's own channel, so scheduling stays single-threaded and
//!   race-free.
//!
//! Chunks from concurrent requests share engine batches, and independent
//! batches run on different replicas in parallel. Containers are
//! bit-identical for ANY `{replicas, threads, lanes}` configuration:
//! every chunk is encoded in its own lane with its own range coder, so
//! batch packing, dispatch order and replica choice cannot leak into the
//! payload bytes (asserted by `tests/integration_server.rs`).
//!
//! ## Elastic replica pool (autoscaling)
//!
//! With [`ServerConfig::autoscale`], the worker set is **elastic**: the
//! scheduler holds `max_replicas` worker *slots* and grows/shrinks the
//! live set between `min_replicas` and `max_replicas` from the signals it
//! already records into [`Metrics`] — the scheduler backlog (the same
//! queue depth attributed per worker at every dispatch) and, optionally,
//! the compress p99 latency histogram. The [`Autoscaler`] is deliberately
//! boring: grow only when more than one full batch per live replica is
//! queued *after* dispatch, shrink only a replica that has been idle with
//! an empty queue for a sustained window, and never act twice within the
//! cooldown — wide hysteresis, so constant load cannot flap the pool.
//! Native replicas are cheap to grow (the factory clones an
//! `Arc<Weights>`, and with a shared [`crate::lm::native::StepPool`] no
//! step threads spawn at all); PJRT replicas are thread-affine and static,
//! so autoscale is disabled for them.
//!
//! Scaling events are **provably invisible in the output bytes**: a chunk
//! is encoded entirely inside one lane of one replica with its own range
//! coder, and every replica is built by the same factory from the same
//! weights, so which replica (or how many existed at the time) cannot
//! reach the payload. `tests/stress_elastic.rs` pins this end-to-end
//! against the direct single-engine path under forced grow/shrink churn.

use crate::compress::container::{ChunkRecord, Codec, Container};
use crate::compress::llm::{container_codec, ContainerTag, LlmCompressor};
use crate::coordinator::batcher::{BatchPolicy, DynamicBatcher, Priority, WorkItem, WorkKind};
use crate::coordinator::metrics::Metrics;
use crate::lm::executor::ExecutorKind;
use crate::util::{crc32, BytePool, Crc32, PooledBuf};
use crate::Result;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A fleet-wide replica budget: one shared pool of replica permits
/// arbitrated across every [`Server`] that holds a clone of the `Arc`.
/// This is what turns the per-pool [`Autoscaler`] into a fleet-level one:
/// each pool still runs its own (pure, unit-tested) scaling brain, but a
/// Grow decision only lands if a permit is free — so the sum of live and
/// starting replicas across all pools never exceeds the cap, no matter
/// which pools' scalers fire. Shrinks, worker deaths, refused replicas
/// and shutdowns return permits, which other pools' next evaluation can
/// claim. Denied grows are counted in
/// [`Metrics::grows_denied`](crate::coordinator::Metrics).
#[derive(Debug)]
pub struct ReplicaBudget {
    cap: usize,
    used: AtomicUsize,
}

impl ReplicaBudget {
    pub fn new(cap: usize) -> Arc<ReplicaBudget> {
        Arc::new(ReplicaBudget { cap, used: AtomicUsize::new(0) })
    }

    /// Total permits.
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Permits currently held across all pools.
    pub fn used(&self) -> usize {
        self.used.load(Ordering::SeqCst)
    }

    /// Claim `n` permits atomically; `false` (claiming nothing) if fewer
    /// than `n` are free.
    pub fn try_acquire(&self, n: usize) -> bool {
        self.acquire_up_to_min(n, n) == n
    }

    /// Claim up to `n` permits (possibly fewer, possibly zero), returning
    /// how many were granted.
    pub fn acquire_up_to(&self, n: usize) -> usize {
        self.acquire_up_to_min(n, 0)
    }

    fn acquire_up_to_min(&self, n: usize, min: usize) -> usize {
        let mut used = self.used.load(Ordering::SeqCst);
        loop {
            let free = self.cap.saturating_sub(used);
            let grant = free.min(n);
            if grant < min {
                return 0;
            }
            match self.used.compare_exchange(
                used,
                used + grant,
                Ordering::SeqCst,
                Ordering::SeqCst,
            ) {
                Ok(_) => return grant,
                Err(actual) => used = actual,
            }
        }
    }

    /// Return `n` permits.
    pub fn release(&self, n: usize) {
        let prev = self.used.fetch_sub(n, Ordering::SeqCst);
        debug_assert!(prev >= n, "budget release underflow");
    }
}

/// Scheduler-side view of the optional shared budget: tracks how many
/// permits THIS pool holds so every exit path can settle them exactly.
struct BudgetHold {
    budget: Option<Arc<ReplicaBudget>>,
    held: usize,
}

impl BudgetHold {
    fn new(budget: Option<Arc<ReplicaBudget>>) -> BudgetHold {
        BudgetHold { budget, held: 0 }
    }

    /// Claim up to `n` startup permits; without a budget, everything is
    /// granted.
    fn acquire_up_to(&mut self, n: usize) -> usize {
        match &self.budget {
            None => n,
            Some(b) => {
                let granted = b.acquire_up_to(n);
                self.held += granted;
                granted
            }
        }
    }

    /// Claim one grow permit.
    fn try_acquire_one(&mut self) -> bool {
        match &self.budget {
            None => true,
            Some(b) => {
                let ok = b.try_acquire(1);
                if ok {
                    self.held += 1;
                }
                ok
            }
        }
    }

    /// Return one permit (replica retired, died, or refused).
    fn release_one(&mut self) {
        if let Some(b) = &self.budget {
            if self.held > 0 {
                b.release(1);
                self.held -= 1;
            }
        }
    }

    fn release_all(&mut self) {
        if let Some(b) = &self.budget {
            if self.held > 0 {
                b.release(self.held);
                self.held = 0;
            }
        }
    }
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub chunk_tokens: usize,
    /// Batch-width cap: limit engine batches to this many lanes
    /// (`0` = use the engine's full lane count). The effective width is
    /// always `min(lanes, engine lanes)`.
    pub lanes: usize,
    /// Native-engine worker threads per replica. The scheduler cannot
    /// rebuild engines (the factory owns construction), so this is the
    /// value `cmd/serve` wires into `LlmCompressorConfig::threads`; it is
    /// recorded here so the whole replica/lane/thread configuration
    /// travels through one struct. Total step threads = replicas x this.
    pub threads: usize,
    /// Engine replicas: parallel engine workers, each running a full
    /// compressor built by the factory (`0` behaves as `1`). Native
    /// replicas share one `Arc<Weights>` when the factory clones one.
    /// With [`Self::autoscale`] this is the INITIAL pool size.
    pub replicas: usize,
    /// Autoscale floor (`0` = `replicas`). The pool never shrinks below
    /// this many live replicas.
    pub min_replicas: usize,
    /// Autoscale ceiling (`0` = `replicas`). The pool never grows past
    /// this; it also sizes the per-worker metrics slots.
    pub max_replicas: usize,
    /// Grow/shrink the worker pool at runtime from the queue-depth (and
    /// optional p99) signals. Native engines only — PJRT replicas are
    /// thread-affine and stay static even with this set.
    pub autoscale: bool,
    /// Minimum interval between scaling actions (anti-flap hysteresis).
    pub autoscale_cooldown: Duration,
    /// Continuous idle time (empty queue + an idle replica) required
    /// before a shrink.
    pub autoscale_shrink_after: Duration,
    /// Optional secondary grow signal: also grow when the compress p99
    /// exceeds this many ms while work is queued (`INFINITY` = disabled,
    /// queue-depth only — the deterministic default the tests pin).
    pub autoscale_p99_ms: f64,
    /// Build the interleaved-panel weight layout in native replicas
    /// (default on). Panels cost roughly one extra copy of the projection
    /// tensors per loaded model — shared across all replicas via
    /// `Arc<Weights>`, but worth turning off on memory-constrained hosts;
    /// matmuls fall back to the strided kernels, slower but
    /// byte-identical. Like `threads`, the factory owns engine
    /// construction — `cmd/serve` wires this into
    /// `LlmCompressorConfig::panel_layout`; it is recorded here so the
    /// whole replica configuration travels through one struct.
    pub panel_layout: bool,
    /// Entropy backend the replicas encode with. Like `threads` and
    /// `panel_layout`, the factory owns engine construction — `cmd/serve`
    /// wires this into the compressor via [`LlmCompressor::with_codec`];
    /// it is recorded here so the whole replica configuration travels
    /// through one struct. Decompression always follows the *container's*
    /// recorded codec, so a server configured either way decodes both.
    pub codec: Codec,
    /// Recycle serve-path byte buffers through a shared
    /// [`BytePool`] (default on): wire frame reads, request chunking and
    /// stream staging reuse returned storage instead of allocating per
    /// op. `false` — or `LLMZIP_POOL=0` in the environment — makes every
    /// take a plain allocation; output bytes are identical either way
    /// (pinned by `tests/integration_server.rs`).
    pub pooling: bool,
    /// Optional fleet-wide replica budget shared with sibling pools.
    /// Startup claims as many permits as it can for the initial replicas
    /// (erroring only if ZERO are free), and every autoscale Grow needs a
    /// free permit; shrinks/deaths return theirs. `None` = this pool
    /// arbitrates nothing (single-server behavior, unchanged).
    pub replica_budget: Option<Arc<ReplicaBudget>>,
    /// Tenant WFQ weights `(tenant id, weight)` seeded into the
    /// [`DynamicBatcher`]. Unlisted tenants (including the default tenant
    /// `0`) weigh 1. Pure scheduling knob: which tenant a chunk belongs
    /// to can never change its bytes.
    pub tenants: Vec<(u32, u64)>,
    pub policy: BatchPolicy,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            chunk_tokens: 256,
            lanes: 0,
            threads: 0,
            replicas: 1,
            min_replicas: 0,
            max_replicas: 0,
            autoscale: false,
            autoscale_cooldown: Duration::from_millis(1000),
            autoscale_shrink_after: Duration::from_millis(2000),
            autoscale_p99_ms: f64::INFINITY,
            panel_layout: true,
            codec: Codec::Range,
            pooling: true,
            replica_budget: None,
            tenants: Vec::new(),
            policy: BatchPolicy::default(),
        }
    }
}

/// Effective `(min, initial, max)` pool bounds for a config: the legacy
/// `replicas` knob is the initial size, `min`/`max` default to it when
/// left `0`, and the initial size is clamped into `[min, max]`.
fn pool_bounds(config: &ServerConfig) -> (usize, usize, usize) {
    let replicas = config.replicas.max(1);
    let min = if config.min_replicas == 0 { replicas } else { config.min_replicas };
    let max = if config.max_replicas == 0 { replicas } else { config.max_replicas };
    let max = max.max(min);
    (min, replicas.clamp(min, max), max)
}

/// One operation for [`Server::submit`]: the async, ticketed intake. The
/// blocking [`Server::compress`]/[`Server::decompress`] calls are thin
/// wrappers over it.
pub enum Op {
    /// Compress raw bytes into a container. `PooledBuf` is an owned
    /// `Vec<u8>` whose storage recycles on drop; plain vectors convert
    /// with `.into()` (detached — they just drop normally).
    Compress(PooledBuf),
    /// Decompress a container back to the original bytes.
    Decompress(PooledBuf),
}

/// Handle to one in-flight [`Server::submit`] operation. The scheduler
/// answers on a private one-shot channel; [`Ticket::wait`] parks until it
/// does, [`Ticket::try_wait`] polls — a client can hold any number of
/// tickets, which is what lets one connection multiplex many requests.
pub struct Ticket {
    rx: Receiver<Result<Vec<u8>>>,
}

impl Ticket {
    /// Block until the operation completes.
    pub fn wait(self) -> Result<Vec<u8>> {
        self.rx.recv().map_err(|_| anyhow::anyhow!("server dropped the request"))?
    }

    /// Poll without blocking: `Ok(None)` while still in flight,
    /// `Ok(Some(bytes))` exactly once on completion.
    pub fn try_wait(&self) -> Result<Option<Vec<u8>>> {
        match self.rx.try_recv() {
            Ok(result) => result.map(Some),
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => {
                anyhow::bail!("server dropped the request")
            }
        }
    }
}

struct Request {
    id: u64,
    op: Op,
    priority: Priority,
    tenant: u32,
    respond: SyncSender<Result<Vec<u8>>>,
    started: Instant,
}

/// Everything the scheduler hears about: client intake (one-shot requests
/// AND incremental stream sessions), worker completions and runtime-grown
/// worker readiness share one channel, so a single `recv` drives all of
/// them.
enum ToScheduler {
    Request(Request),
    /// A streaming compress session opened: reassembly state is created
    /// with an unknown chunk count; chunks follow as the client produces
    /// them.
    StreamOpen { id: u64, tenant: u32, respond: SyncSender<Result<Vec<u8>>>, started: Instant },
    /// One stream chunk (already cut at the engine's stream granularity by
    /// the [`StreamHandle`]); goes straight into the batcher, so batching
    /// starts before the input has finished arriving.
    StreamChunk { id: u64, index: u32, data: PooledBuf },
    /// The stream's input is complete: `n_chunks` chunks were sent, the
    /// original byte count and CRC are final.
    StreamFinish { id: u64, n_chunks: u32, orig_len: u64, orig_crc: u32 },
    /// The client dropped its handle without finishing.
    StreamAbort { id: u64 },
    Done(BatchDone),
    /// An autoscale-grown worker finished construction (`Ok` = serving).
    Ready { worker: usize, info: Result<EngineInfo> },
}

/// One batch handed to an engine worker.
struct EngineJob {
    kind: WorkKind,
    items: Vec<WorkItem>,
    /// Context window for decompress batches (the server decodes its own
    /// containers, so this is the worker's configured `chunk_tokens`).
    chunk_tokens: usize,
}

/// A worker's completion report.
struct BatchDone {
    worker: usize,
    items: Vec<WorkItem>,
    result: Result<Vec<Vec<u8>>>,
}

/// What the scheduler needs to know about the (identical) replicas,
/// reported by the first worker to finish construction.
#[derive(Clone)]
struct EngineInfo {
    lanes: usize,
    stream_bytes: usize,
    chunk_tokens: usize,
    /// `model:executor_flag` tag stamped into every produced container —
    /// including empty ones, which never reach a worker.
    tag: String,
    /// Executor kind: autoscale only moves native pools (PJRT handles are
    /// thread-affine and their replicas stay static).
    kind: ExecutorKind,
    /// Entropy backend the replicas were built with; stamped into every
    /// compress `WorkItem` and every container this server produces.
    codec: Codec,
}

/// Per-request reassembly state.
struct Pending {
    respond: SyncSender<Result<Vec<u8>>>,
    started: Instant,
    kind: WorkKind,
    /// Owning tenant: stamped into every work item this request feeds the
    /// batcher (streams learn it at open; one-shots at admit).
    tenant: u32,
    /// Results by chunk index (compress: payloads; decompress: raw bytes).
    /// For streams this grows as chunks arrive.
    results: Vec<Option<Vec<u8>>>,
    remaining: usize,
    /// Compress: original lengths per chunk + source crc/len for container.
    chunk_sizes: Vec<u32>,
    orig_len: u64,
    orig_crc: u32,
    container_chunk_tokens: u32,
    bytes_in: usize,
    /// One-shot requests know their chunk count at admit (`true` from the
    /// start); a stream flips this at `StreamFinish`, when `orig_len`,
    /// `orig_crc` and the chunk count become final. A request completes
    /// when `finished && remaining == 0`.
    finished: bool,
}

/// Callback the scheduler fires whenever the live replica count changes
/// (startup, grow, shrink, worker death) — the autoscale-aware sizing
/// hook. `cmd serve` uses it to retarget the shared
/// [`crate::lm::native::StepPool`] so the step-thread budget follows the
/// replica gauge instead of being provisioned for `max_replicas` up
/// front. Runs on the scheduler thread: keep it quick and non-blocking.
pub type ScaleHook = Arc<dyn Fn(usize) + Send + Sync>;

/// The compression service.
pub struct Server {
    tx: SyncSender<ToScheduler>,
    next_id: AtomicU64,
    pub metrics: Arc<Metrics>,
    shutdown: Arc<AtomicBool>,
    scheduler: Option<std::thread::JoinHandle<()>>,
    /// What the (identical) replicas reported at startup; fixed for the
    /// server's life, so clients can read it without a scheduler roundtrip.
    info: EngineInfo,
    /// Shared buffer recycler for the serve path: wire frame reads,
    /// request chunking and stream staging all draw from (and return
    /// to) this pool. Disabled pools hand out plain allocations.
    pool: BytePool,
}

impl Server {
    /// Start the scheduler and its engine-worker pool. Each replica's
    /// compressor is built INSIDE its worker thread by `factory` because
    /// PJRT handles are thread-affine (`!Send`); the factory itself only
    /// captures plain data (clone an `Arc<Weights>` into it to make native
    /// replicas share tensors).
    pub fn start<F>(factory: F, config: ServerConfig) -> Result<Server>
    where
        F: Fn() -> Result<LlmCompressor> + Send + Sync + 'static,
    {
        Self::start_with_hook(factory, config, None)
    }

    /// [`Self::start`] with a [`ScaleHook`] observing every live-replica
    /// change.
    pub fn start_with_hook<F>(
        factory: F,
        config: ServerConfig,
        on_scale: Option<ScaleHook>,
    ) -> Result<Server>
    where
        F: Fn() -> Result<LlmCompressor> + Send + Sync + 'static,
    {
        if config.min_replicas > 0
            && config.max_replicas > 0
            && config.min_replicas > config.max_replicas
        {
            anyhow::bail!(
                "min_replicas {} > max_replicas {}",
                config.min_replicas,
                config.max_replicas
            );
        }
        let (_, _, max_replicas) = pool_bounds(&config);
        // Serve-path buffer recycler. `BytePool::new` additionally honors
        // `LLMZIP_POOL=0`, so CI can pin the fallback path without a
        // config change. The cap bounds idle hoarding: free buffers are
        // at most `cap x MAX_RECYCLED_CAPACITY` bytes, and oversized
        // one-offs are never retained.
        let pool = if config.pooling {
            BytePool::new(32 + 16 * max_replicas)
        } else {
            BytePool::disabled()
        };
        let (tx, rx) = sync_channel::<ToScheduler>(256 + 4 * max_replicas);
        // One metrics slot per worker the pool can EVER hold, so a grown
        // replica's attribution works from its first batch.
        let metrics = Arc::new(Metrics::with_workers(max_replicas));
        let shutdown = Arc::new(AtomicBool::new(false));
        let factory = Arc::new(factory);
        let m = metrics.clone();
        let sd = shutdown.clone();
        let worker_tx = tx.clone();
        let (ready_tx, ready_rx) = sync_channel::<Result<EngineInfo>>(1);
        let sched_pool = pool.clone();
        let scheduler = std::thread::Builder::new()
            .name("llmzip-sched".into())
            .spawn(move || {
                scheduler_main(factory, config, rx, worker_tx, m, sd, ready_tx, on_scale, sched_pool)
            })
            .expect("spawning scheduler");
        let info = ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("scheduler died during startup"))??;
        Ok(Server {
            tx,
            next_id: AtomicU64::new(1),
            metrics,
            shutdown,
            scheduler: Some(scheduler),
            info,
            pool,
        })
    }

    /// The server's shared serve-path buffer pool. The wire layer reads
    /// request frames into buffers from here, so their storage recycles
    /// once the request's work items are done.
    pub fn pool(&self) -> &BytePool {
        &self.pool
    }

    /// Submit an operation asynchronously at its default priority
    /// (compress: bulk, decompress: interactive — the fast lane) and get a
    /// [`Ticket`] back immediately. The calling thread never blocks on
    /// engine work; many tickets can be in flight at once.
    pub fn submit(&self, op: Op) -> Result<Ticket> {
        let priority = match op {
            Op::Compress(_) => Priority::Bulk,
            Op::Decompress(_) => Priority::Interactive,
        };
        self.submit_with(op, priority)
    }

    /// [`Self::submit`] with an explicit scheduling class (default
    /// tenant).
    pub fn submit_with(&self, op: Op, priority: Priority) -> Result<Ticket> {
        self.submit_for(0, op, priority)
    }

    /// [`Self::submit_with`] on behalf of a tenant: the request's chunks
    /// ride that tenant's WFQ lane in the batcher. Tenant ids are a pure
    /// scheduling label — the produced bytes are identical for any id.
    pub fn submit_for(&self, tenant: u32, op: Op, priority: Priority) -> Result<Ticket> {
        let (rtx, rrx) = sync_channel(1);
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.tx
            .send(ToScheduler::Request(Request {
                id,
                op,
                priority,
                tenant,
                respond: rtx,
                started: Instant::now(),
            }))
            .map_err(|_| anyhow::anyhow!("server is shut down"))?;
        Ok(Ticket { rx: rrx })
    }

    /// Open an incremental compression session: bytes written to the
    /// returned [`StreamHandle`] are cut into engine-granularity chunks
    /// and fed into the batcher AS THEY ARRIVE, so encoding (and
    /// cross-request batching) overlaps with input production instead of
    /// waiting for it. [`StreamHandle::finish`] yields the [`Ticket`] for
    /// the final container — byte-identical to [`Self::compress`] of the
    /// concatenated input.
    pub fn open_stream(&self) -> Result<StreamHandle> {
        self.open_stream_for(0)
    }

    /// [`Self::open_stream`] on behalf of a tenant (see
    /// [`Self::submit_for`]).
    pub fn open_stream_for(&self, tenant: u32) -> Result<StreamHandle> {
        let (rtx, rrx) = sync_channel(1);
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.tx
            .send(ToScheduler::StreamOpen { id, tenant, respond: rtx, started: Instant::now() })
            .map_err(|_| anyhow::anyhow!("server is shut down"))?;
        Ok(StreamHandle {
            tx: self.tx.clone(),
            id,
            stream_bytes: self.info.stream_bytes,
            pool: self.pool.clone(),
            buf: Vec::new(),
            next_index: 0,
            crc: Crc32::new(),
            total: 0,
            rx: Some(rrx),
            finished: false,
        })
    }

    /// Compress `data`, returning a container (blocks until done). Bulk
    /// priority: queued decompress work and interactive compressions go
    /// first. Thin wrapper over [`Self::submit_with`].
    pub fn compress(&self, data: &[u8]) -> Result<Vec<u8>> {
        self.submit_with(Op::Compress(self.pooled_copy(data)), Priority::Bulk)?.wait()
    }

    /// [`Self::compress`] at interactive priority: overtakes queued bulk
    /// compress chunks (decompress keeps its own fast lane regardless).
    pub fn compress_interactive(&self, data: &[u8]) -> Result<Vec<u8>> {
        self.submit_with(Op::Compress(self.pooled_copy(data)), Priority::Interactive)?.wait()
    }

    /// Decompress a container (blocks until done). Always interactive:
    /// reads ride the fast lane past bulk compress jobs.
    pub fn decompress(&self, container: &[u8]) -> Result<Vec<u8>> {
        self.submit_with(Op::Decompress(self.pooled_copy(container)), Priority::Interactive)?
            .wait()
    }

    fn pooled_copy(&self, data: &[u8]) -> PooledBuf {
        let mut buf = self.pool.take(data.len());
        buf.extend_from_slice(data);
        buf
    }

    /// Stream granularity of the replica engines: the chunk size
    /// [`Self::open_stream`] sessions are cut at.
    pub fn stream_bytes(&self) -> usize {
        self.info.stream_bytes
    }

    /// Model-context window recorded in every produced container.
    pub fn chunk_tokens(&self) -> usize {
        self.info.chunk_tokens
    }

    /// The engine tag (`model:flag[:q8:<fp>]`) stamped into every
    /// container this server produces.
    pub fn engine_tag(&self) -> &str {
        &self.info.tag
    }
}

/// Client half of one [`Server::open_stream`] session. Implements
/// [`std::io::Write`]; drop without [`StreamHandle::finish`] aborts the
/// session server-side.
pub struct StreamHandle {
    tx: SyncSender<ToScheduler>,
    id: u64,
    stream_bytes: usize,
    /// The owning server's buffer recycler: chunks ship to the
    /// scheduler in pooled buffers, whose storage returns once the
    /// engine has encoded them.
    pool: BytePool,
    buf: Vec<u8>,
    next_index: u32,
    crc: Crc32,
    total: u64,
    rx: Option<Receiver<Result<Vec<u8>>>>,
    finished: bool,
}

impl StreamHandle {
    /// Feed input bytes; every completed `stream_bytes` chunk is shipped
    /// to the scheduler immediately (client-side buffering is bounded by
    /// one chunk, and a large write is chunked straight from the caller's
    /// slice — linear, no repeated buffer shifting).
    ///
    /// NOTE: this boundary-cutting state machine mirrors
    /// `compress::stream::CompressWriter::ingest` (same top-up/slice/tail
    /// rule; different sink — frames there, scheduler messages here). The
    /// byte-identity contract depends on the two agreeing; both are
    /// pinned by split-point property tests, so change them together.
    pub fn write_bytes(&mut self, mut data: &[u8]) -> Result<()> {
        if self.finished {
            anyhow::bail!("stream already finished");
        }
        self.crc.update(data);
        self.total += data.len() as u64;
        let sb = self.stream_bytes;
        if !self.buf.is_empty() {
            let take = (sb - self.buf.len()).min(data.len());
            self.buf.extend_from_slice(&data[..take]);
            data = &data[take..];
            if self.buf.len() < sb {
                return Ok(());
            }
            // Ship a pooled COPY and keep `self.buf`'s storage: the
            // staging buffer reaches `stream_bytes` capacity once and
            // never reallocates again, and the shipped chunk's storage
            // recycles through the pool after encoding.
            let mut chunk = self.pool.take(self.buf.len());
            chunk.extend_from_slice(&self.buf);
            self.buf.clear();
            self.send_chunk(chunk)?;
        }
        while data.len() >= sb {
            let mut chunk = self.pool.take(sb);
            chunk.extend_from_slice(&data[..sb]);
            self.send_chunk(chunk)?;
            data = &data[sb..];
        }
        self.buf.extend_from_slice(data);
        Ok(())
    }

    fn send_chunk(&mut self, data: PooledBuf) -> Result<()> {
        let index = self.next_index;
        self.next_index += 1;
        self.tx
            .send(ToScheduler::StreamChunk { id: self.id, index, data })
            .map_err(|_| anyhow::anyhow!("server is shut down"))
    }

    /// Declare the input complete: ships the final partial chunk and the
    /// stream totals, and returns the [`Ticket`] for the assembled
    /// container.
    pub fn finish(mut self) -> Result<Ticket> {
        if !self.buf.is_empty() {
            let mut tail = self.pool.take(self.buf.len());
            tail.extend_from_slice(&self.buf);
            self.buf.clear();
            self.send_chunk(tail)?;
        }
        self.finished = true;
        self.tx
            .send(ToScheduler::StreamFinish {
                id: self.id,
                n_chunks: self.next_index,
                orig_len: self.total,
                orig_crc: self.crc.finalize(),
            })
            .map_err(|_| anyhow::anyhow!("server is shut down"))?;
        Ok(Ticket { rx: self.rx.take().expect("unfinished handle holds its receiver") })
    }

    /// Bytes fed so far.
    pub fn bytes_in(&self) -> u64 {
        self.total
    }
}

impl Drop for StreamHandle {
    fn drop(&mut self) {
        if !self.finished {
            let _ = self.tx.send(ToScheduler::StreamAbort { id: self.id });
        }
    }
}

impl std::io::Write for StreamHandle {
    fn write(&mut self, data: &[u8]) -> std::io::Result<usize> {
        self.write_bytes(data).map_err(|e| std::io::Error::other(format!("{e:#}")))?;
        Ok(data.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(s) = self.scheduler.take() {
            let _ = s.join();
        }
    }
}

/// Where a worker reports construction readiness: startup replicas feed
/// the blocking startup collector, autoscale-grown replicas feed the
/// scheduler's own intake channel.
enum ReadySink {
    Startup(SyncSender<(usize, Result<EngineInfo>)>),
    Runtime(SyncSender<ToScheduler>),
}

impl ReadySink {
    fn send(self, id: usize, info: Result<EngineInfo>) {
        match self {
            ReadySink::Startup(tx) => {
                let _ = tx.send((id, info));
            }
            ReadySink::Runtime(tx) => {
                let _ = tx.send(ToScheduler::Ready { worker: id, info });
            }
        }
    }
}

/// An engine worker: builds its compressor, reports readiness, then runs
/// one batch at a time until the scheduler drops its job channel.
fn engine_worker<F>(
    id: usize,
    factory: Arc<F>,
    job_rx: Receiver<EngineJob>,
    done_tx: SyncSender<ToScheduler>,
    ready: ReadySink,
    metrics: Arc<Metrics>,
) where
    F: Fn() -> Result<LlmCompressor> + Send + Sync + 'static,
{
    // A panicking factory must not strand the slot in Starting forever:
    // contain it and report the grow (or startup) as failed.
    let built = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| factory()))
        .unwrap_or_else(|_| Err(anyhow::anyhow!("engine factory panicked")));
    let compressor = match built {
        Ok(c) => {
            let info = EngineInfo {
                lanes: c.lanes(),
                stream_bytes: c.stream_bytes(),
                chunk_tokens: c.chunk_tokens(),
                tag: c.container_tag(),
                kind: c.executor_kind(),
                codec: c.codec(),
            };
            ready.send(id, Ok(info));
            c
        }
        Err(e) => {
            ready.send(id, Err(e));
            return;
        }
    };
    while let Ok(job) = job_rx.recv() {
        // Engine throughput: every byte is one model token, on both passes.
        let batch_tokens: usize = match job.kind {
            WorkKind::Compress => job.items.iter().map(|i| i.data.len()).sum(),
            WorkKind::Decompress => job
                .items
                .iter()
                .map(|i| i.record.map(|r| r.n_tokens as usize).unwrap_or(0))
                .sum(),
        };
        let t0 = Instant::now();
        // A panicking batch must not kill the worker (the scheduler would
        // count the slot busy forever): convert it to a failed batch. The
        // engine re-resets per batch/window, so its state recovers.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| match job.kind {
            WorkKind::Compress => {
                let chunks: Vec<&[u8]> = job.items.iter().map(|i| i.data.as_slice()).collect();
                compressor.compress_chunks(&chunks)
            }
            WorkKind::Decompress => {
                let records: Vec<ChunkRecord> = job
                    .items
                    .iter()
                    .map(|i| i.record.expect("decode item has record"))
                    .collect();
                let payloads: Vec<&[u8]> = job.items.iter().map(|i| i.data.as_slice()).collect();
                // Decode follows each *container's* recorded codec (stamped
                // into the item at admit), not the replica's configured one.
                let codecs: Vec<Codec> = job.items.iter().map(|i| i.codec).collect();
                compressor.decompress_chunks(job.chunk_tokens, &records, &payloads, &codecs)
            }
        }))
        .unwrap_or_else(|_| Err(anyhow::anyhow!("engine batch panicked")));
        if result.is_ok() {
            metrics.record_engine_worker(id, batch_tokens, t0.elapsed());
        }
        let done = BatchDone { worker: id, items: job.items, result };
        if done_tx.send(ToScheduler::Done(done)).is_err() {
            return;
        }
    }
}

/// Lifecycle of one worker slot in the elastic pool.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum SlotState {
    /// Never started, or a failed grow — free for a future grow.
    Empty,
    /// Factory running inside the new worker thread.
    Starting,
    /// Ready for a batch.
    Idle,
    /// Holds a dispatched batch.
    Busy,
    /// Cleanly retired by a shrink (thread exiting or exited).
    Retired,
    /// Died unexpectedly (job channel closed under a live dispatch).
    Dead,
}

struct Slot {
    state: SlotState,
    job_tx: Option<SyncSender<EngineJob>>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Slot {
    fn empty() -> Slot {
        Slot { state: SlotState::Empty, job_tx: None, handle: None }
    }
}

/// Workers currently able to serve (ready or mid-batch).
fn live_count(slots: &[Slot]) -> usize {
    slots.iter().filter(|s| matches!(s.state, SlotState::Idle | SlotState::Busy)).count()
}

fn count_state(slots: &[Slot], st: SlotState) -> usize {
    slots.iter().filter(|s| s.state == st).count()
}

/// Spawn one engine worker into slot `id` (state `Starting` until its
/// readiness report lands). An OS thread-spawn failure is an `Err`, not a
/// panic — during a runtime grow it must be containable (thread limits are
/// most likely to bite exactly when the autoscaler reacts to a burst).
fn spawn_worker<F>(
    id: usize,
    factory: &Arc<F>,
    done_tx: &SyncSender<ToScheduler>,
    startup: Option<&SyncSender<(usize, Result<EngineInfo>)>>,
    metrics: &Arc<Metrics>,
) -> Result<Slot>
where
    F: Fn() -> Result<LlmCompressor> + Send + Sync + 'static,
{
    let (job_tx, job_rx) = sync_channel::<EngineJob>(1);
    let ready = match startup {
        Some(tx) => ReadySink::Startup(tx.clone()),
        None => ReadySink::Runtime(done_tx.clone()),
    };
    let f = factory.clone();
    let dt = done_tx.clone();
    let m = metrics.clone();
    let handle = std::thread::Builder::new()
        .name(format!("llmzip-engine-{id}"))
        .spawn(move || engine_worker(id, f, job_rx, dt, ready, m))
        .map_err(|e| anyhow::anyhow!("spawning engine worker {id}: {e}"))?;
    Ok(Slot { state: SlotState::Starting, job_tx: Some(job_tx), handle: Some(handle) })
}

/// What the autoscaler sees at one evaluation point (taken AFTER dispatch,
/// so `queued` is work no live replica could absorb).
#[derive(Clone, Copy, Debug)]
struct PoolSnapshot {
    /// Idle + busy workers.
    live: usize,
    /// Workers mid-construction (count toward capacity, so one burst
    /// cannot spawn the whole range before the first grow lands).
    starting: usize,
    /// Idle workers.
    idle: usize,
    /// Items still queued in the batcher.
    queued: usize,
    /// Compress p99 ms (only sampled when the p99 signal is enabled).
    p99_ms: f64,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum ScaleDecision {
    Grow,
    Shrink,
    Hold,
}

/// The scaling brain: a pure function of time + pool snapshots, kept free
/// of thread/channel machinery so its bounds, cooldown and no-flap
/// properties are unit-testable (see the tests below).
///
/// * **Grow** when more than one full batch per unit of capacity is queued
///   (or the p99 signal trips while work is queued) and capacity < max.
/// * **Shrink** when the queue has been empty with at least one idle
///   replica for `shrink_after`, and capacity > min.
/// * Never act twice within `cooldown`; never leave `[min, max]`.
///
/// Grow and shrink thresholds are far apart (backlog > lanes×capacity vs.
/// queue == 0 sustained), so a constant load level cannot oscillate the
/// pool.
struct Autoscaler {
    min: usize,
    max: usize,
    lanes: usize,
    cooldown: Duration,
    shrink_after: Duration,
    p99_grow_ms: f64,
    last_action: Option<Instant>,
    idle_since: Option<Instant>,
}

impl Autoscaler {
    fn new(min: usize, max: usize, lanes: usize, config: &ServerConfig) -> Autoscaler {
        Autoscaler {
            min,
            max,
            lanes: lanes.max(1),
            cooldown: config.autoscale_cooldown,
            shrink_after: config.autoscale_shrink_after,
            p99_grow_ms: config.autoscale_p99_ms,
            last_action: None,
            idle_since: None,
        }
    }

    fn decide(&mut self, now: Instant, s: PoolSnapshot) -> ScaleDecision {
        let capacity = s.live + s.starting;
        // Track sustained idleness independently of the cooldown, so
        // `shrink_after` measures real idle time.
        if s.queued == 0 && s.idle > 0 && s.starting == 0 {
            if self.idle_since.is_none() {
                self.idle_since = Some(now);
            }
        } else {
            self.idle_since = None;
        }
        let cooled = match self.last_action {
            None => true,
            Some(t) => now.duration_since(t) >= self.cooldown,
        };
        if !cooled {
            return ScaleDecision::Hold;
        }
        let backlog = s.queued > self.lanes * capacity.max(1);
        let slow = s.queued > 0 && s.p99_ms > self.p99_grow_ms;
        if (backlog || slow) && capacity < self.max {
            self.last_action = Some(now);
            self.idle_since = None;
            return ScaleDecision::Grow;
        }
        if capacity > self.min
            && s.idle > 0
            && self.idle_since.is_some_and(|t| now.duration_since(t) >= self.shrink_after)
        {
            self.last_action = Some(now);
            self.idle_since = None;
            return ScaleDecision::Shrink;
        }
        ScaleDecision::Hold
    }
}

/// Mutable scheduler state threaded through message handling.
struct SchedState {
    batcher: DynamicBatcher,
    pending: HashMap<u64, Pending>,
    slots: Vec<Slot>,
    /// Idle slot ids (stack: most recently freed dispatched first).
    idle: Vec<usize>,
    /// Handles of retired/replaced workers, joined at shutdown so a slow
    /// engine teardown never stalls scheduling.
    graveyard: Vec<std::thread::JoinHandle<()>>,
}

#[allow(clippy::too_many_arguments)]
fn scheduler_main<F>(
    factory: Arc<F>,
    config: ServerConfig,
    rx: Receiver<ToScheduler>,
    worker_tx: SyncSender<ToScheduler>,
    metrics: Arc<Metrics>,
    shutdown: Arc<AtomicBool>,
    ready_tx: SyncSender<Result<EngineInfo>>,
    on_scale: Option<ScaleHook>,
    pool: BytePool,
) where
    F: Fn() -> Result<LlmCompressor> + Send + Sync + 'static,
{
    let (min_replicas, initial, max_replicas) = pool_bounds(&config);
    // Fleet budget: claim permits for the initial replicas. A contended
    // budget can grant fewer than asked (the pool starts smaller and the
    // autoscaler grows it later, permits allowing); zero free permits is
    // a startup error — a pool with no replica can serve nothing.
    let mut budget = BudgetHold::new(config.replica_budget.clone());
    let initial = match budget.acquire_up_to(initial) {
        0 => {
            let cap = config.replica_budget.as_ref().map(|b| b.cap()).unwrap_or(0);
            let _ = ready_tx.send(Err(anyhow::anyhow!(
                "fleet replica budget exhausted: 0 of {cap} permits free at pool startup"
            )));
            return;
        }
        granted => granted,
    };
    // Spawn the initial workers; each gets a 1-deep private job channel
    // (a worker never holds more than one batch) and reports completions
    // on the scheduler's own intake channel. The remaining slots up to
    // `max_replicas` stay empty until the autoscaler grows into them.
    let (worker_ready_tx, worker_ready_rx) = sync_channel::<(usize, Result<EngineInfo>)>(initial);
    let mut slots: Vec<Slot> = Vec::with_capacity(max_replicas);
    let mut startup_err: Option<anyhow::Error> = None;
    for id in 0..initial {
        match spawn_worker(id, &factory, &worker_tx, Some(&worker_ready_tx), &metrics) {
            Ok(slot) => slots.push(slot),
            Err(e) => {
                slots.push(Slot::empty());
                if startup_err.is_none() {
                    startup_err = Some(e);
                }
            }
        }
    }
    for _ in initial..max_replicas {
        slots.push(Slot::empty());
    }
    let spawned = count_state(&slots, SlotState::Starting);
    drop(worker_ready_tx);
    // Collect readiness from every startup replica that spawned; any
    // failure aborts startup.
    let mut info: Option<EngineInfo> = None;
    for _ in 0..spawned {
        match worker_ready_rx.recv() {
            Ok((id, Ok(i))) => {
                slots[id].state = SlotState::Idle;
                if info.is_none() {
                    info = Some(i);
                }
            }
            Ok((id, Err(e))) => {
                slots[id].state = SlotState::Empty;
                if startup_err.is_none() {
                    startup_err = Some(e);
                }
            }
            Err(_) => {
                if startup_err.is_none() {
                    startup_err = Some(anyhow::anyhow!("engine worker died during startup"));
                }
                break;
            }
        }
    }
    if let Some(e) = startup_err {
        let _ = ready_tx.send(Err(e));
        for s in slots.iter_mut() {
            s.job_tx = None;
        }
        drop(rx);
        for s in slots.iter_mut() {
            if let Some(h) = s.handle.take() {
                let _ = h.join();
            }
        }
        budget.release_all();
        return;
    }
    let info = info.expect("initial replicas >= 1 reported ready");
    let _ = ready_tx.send(Ok(info.clone()));

    let lanes = if config.lanes > 0 { config.lanes.min(info.lanes) } else { info.lanes };
    // Requests are split at the compressor's stream granularity; the
    // model-context chunk size is recorded in each container.
    let split = Split { stream_bytes: info.stream_bytes, chunk_tokens: info.chunk_tokens as u32 };
    let autoscale_on = config.autoscale && info.kind == ExecutorKind::Native;
    if config.autoscale && !autoscale_on {
        eprintln!("llmzip-sched: autoscale disabled — PJRT replicas are static");
    }
    let mut scaler = Autoscaler::new(min_replicas, max_replicas, lanes, &config);
    let mut batcher = DynamicBatcher::new(BatchPolicy { lanes, ..config.policy });
    for (tenant, weight) in &config.tenants {
        batcher.set_tenant_weight(*tenant, *weight);
    }
    let mut st = SchedState {
        batcher,
        pending: HashMap::new(),
        slots,
        idle: (0..initial).rev().collect(),
        graveyard: Vec::new(),
    };
    metrics.set_replicas(initial);
    if let Some(hook) = &on_scale {
        hook(initial);
    }
    loop {
        let busy = count_state(&st.slots, SlotState::Busy);
        let starting = count_state(&st.slots, SlotState::Starting);
        if shutdown.load(Ordering::SeqCst)
            && st.pending.is_empty()
            && st.batcher.pending() == 0
            && busy == 0
            && starting == 0
        {
            break;
        }
        // Sleep until the next flush deadline (or a short poll interval);
        // worker completions arrive on this same channel and wake us. With
        // every replica busy, deadlines can't be acted on anyway — wait on
        // messages instead of spinning on an expired deadline.
        let timeout = if st.idle.is_empty() {
            Duration::from_millis(50)
        } else {
            st.batcher
                .next_deadline()
                .map(|d| d.saturating_duration_since(Instant::now()))
                .unwrap_or(Duration::from_millis(10))
        };
        match rx.recv_timeout(timeout) {
            Ok(msg) => {
                handle_message(msg, &info, split, &mut st, &metrics, &on_scale, &pool, &mut budget)
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => {
                // Unreachable in practice: the scheduler holds its own
                // clone of the intake sender (`worker_tx`, used to spawn
                // grown workers), so this channel cannot disconnect while
                // the loop runs. Shutdown is driven by the flag above.
            }
        }
        // Drain without blocking to fill batches before dispatching.
        while let Ok(msg) = rx.try_recv() {
            handle_message(msg, &info, split, &mut st, &metrics, &on_scale, &pool, &mut budget);
        }
        // Shutdown drains in-flight work, but a stream whose client never
        // finished can never complete — fail it instead of wedging the
        // join in `Server::drop`. (Streams still decoding their last
        // chunks keep `remaining > 0` and drain normally first.)
        if shutdown.load(Ordering::SeqCst) {
            st.pending.retain(|_, p| {
                if !p.finished && p.remaining == 0 {
                    let _ = p.respond.send(Err(anyhow::anyhow!("server shut down mid-stream")));
                    false
                } else {
                    true
                }
            });
        }
        // Dispatch released batches onto idle replicas.
        while !st.idle.is_empty() {
            let Some((kind, items)) = st.batcher.next_batch(Instant::now()) else { break };
            let worker = st.idle.pop().expect("checked non-empty");
            metrics.record_dispatch(worker, items.len(), lanes, st.batcher.pending());
            st.slots[worker].state = SlotState::Busy;
            let job = EngineJob { kind, items, chunk_tokens: info.chunk_tokens };
            let sent = st.slots[worker]
                .job_tx
                .as_ref()
                .expect("idle slot has a job channel")
                .send(job);
            if let Err(failed) = sent {
                // Worker died. Fail the affected requests rather than
                // wedging them, and free the slot so the autoscaler can
                // respawn into it instead of shutdown waiting forever.
                st.slots[worker].state = SlotState::Dead;
                st.slots[worker].job_tx = None;
                if let Some(h) = st.slots[worker].handle.take() {
                    st.graveyard.push(h);
                }
                budget.release_one();
                metrics.record_error();
                let live = live_count(&st.slots);
                metrics.set_replicas(live);
                if let Some(hook) = &on_scale {
                    hook(live);
                }
                for item in failed.0.items {
                    if let Some(p) = st.pending.remove(&item.request_id) {
                        let _ = p
                            .respond
                            .send(Err(anyhow::anyhow!("engine worker {worker} died")));
                    }
                }
            }
        }
        // Elastic pool: evaluate AFTER dispatch, so the queue depth the
        // scaler sees is work no live replica could absorb. Skip entirely
        // during shutdown — draining is not load.
        if autoscale_on && !shutdown.load(Ordering::SeqCst) {
            let snap = PoolSnapshot {
                live: live_count(&st.slots),
                starting: count_state(&st.slots, SlotState::Starting),
                idle: st.idle.len(),
                queued: st.batcher.pending(),
                p99_ms: if scaler.p99_grow_ms.is_finite() {
                    metrics.latency_percentile_ms(WorkKind::Compress, 0.99)
                } else {
                    0.0
                },
            };
            match scaler.decide(Instant::now(), snap) {
                ScaleDecision::Hold => {}
                ScaleDecision::Grow => {
                    if let Some(id) = st
                        .slots
                        .iter()
                        .position(|s| {
                            matches!(
                                s.state,
                                SlotState::Empty | SlotState::Retired | SlotState::Dead
                            )
                        })
                    {
                        // Fleet arbitration: a Grow only lands with a free
                        // budget permit. Denials are counted, not errors —
                        // another pool is using the capacity, and a later
                        // evaluation retries once permits free up.
                        if !budget.try_acquire_one() {
                            metrics.record_grow_denied();
                        } else {
                            if let Some(h) = st.slots[id].handle.take() {
                                st.graveyard.push(h);
                            }
                            match spawn_worker(id, &factory, &worker_tx, None, &metrics) {
                                Ok(slot) => st.slots[id] = slot,
                                Err(e) => {
                                    // Thread limit hit mid-burst: contain
                                    // it exactly like a failed factory —
                                    // the slot stays free and a later
                                    // evaluation retries after the
                                    // cooldown.
                                    st.slots[id] = Slot::empty();
                                    budget.release_one();
                                    metrics.record_error();
                                    eprintln!("llmzip-sched: {e:#}");
                                }
                            }
                        }
                    }
                }
                ScaleDecision::Shrink => {
                    // Retire the highest idle id: drop its channel and let
                    // the worker drain out. Only idle workers shrink, so
                    // queued work never strands.
                    if let Some(pos) =
                        (0..st.idle.len()).max_by_key(|&p| st.idle[p])
                    {
                        let id = st.idle.swap_remove(pos);
                        st.slots[id].state = SlotState::Retired;
                        st.slots[id].job_tx = None;
                        if let Some(h) = st.slots[id].handle.take() {
                            st.graveyard.push(h);
                        }
                        budget.release_one();
                        let live = live_count(&st.slots);
                        metrics.record_scale(false, live);
                        if let Some(hook) = &on_scale {
                            hook(live);
                        }
                    }
                }
            }
        }
    }
    // Disconnect the workers and wait them out.
    for s in st.slots.iter_mut() {
        s.job_tx = None;
    }
    drop(rx);
    for s in st.slots.iter_mut() {
        if let Some(h) = s.handle.take() {
            let _ = h.join();
        }
    }
    for h in st.graveyard {
        let _ = h.join();
    }
    // Hand every remaining permit back to the fleet.
    budget.release_all();
}

#[allow(clippy::too_many_arguments)]
fn handle_message(
    msg: ToScheduler,
    info: &EngineInfo,
    split: Split,
    st: &mut SchedState,
    metrics: &Metrics,
    on_scale: &Option<ScaleHook>,
    pool: &BytePool,
    budget: &mut BudgetHold,
) {
    match msg {
        ToScheduler::Request(req) => {
            admit(req, info, split, &mut st.batcher, &mut st.pending, metrics, pool)
        }
        ToScheduler::StreamOpen { id, tenant, respond, started } => {
            st.pending.insert(
                id,
                Pending {
                    respond,
                    started,
                    kind: WorkKind::Compress,
                    tenant,
                    results: Vec::new(),
                    remaining: 0,
                    chunk_sizes: Vec::new(),
                    orig_len: 0,
                    orig_crc: 0,
                    container_chunk_tokens: split.chunk_tokens,
                    bytes_in: 0,
                    finished: false,
                },
            );
        }
        ToScheduler::StreamChunk { id, index, data } => {
            // An aborted/failed stream's entry is gone; late chunks are
            // dropped silently (their results would be too).
            let Some(p) = st.pending.get_mut(&id) else { return };
            if index as usize != p.results.len() {
                let p = st.pending.remove(&id).unwrap();
                let _ = p.respond.send(Err(anyhow::anyhow!(
                    "stream chunk {index} arrived out of order (expected {})",
                    p.results.len()
                )));
                return;
            }
            p.results.push(None);
            p.chunk_sizes.push(data.len() as u32);
            p.remaining += 1;
            p.bytes_in += data.len();
            let tenant = p.tenant;
            st.batcher.push(WorkItem {
                request_id: id,
                chunk_index: index,
                kind: WorkKind::Compress,
                priority: Priority::Bulk,
                tenant,
                data,
                record: None,
                codec: info.codec,
                enqueued: Instant::now(),
            });
        }
        ToScheduler::StreamFinish { id, n_chunks, orig_len, orig_crc } => {
            let Some(p) = st.pending.get_mut(&id) else { return };
            if n_chunks as usize != p.results.len() {
                let p = st.pending.remove(&id).unwrap();
                let _ = p.respond.send(Err(anyhow::anyhow!(
                    "stream finished with {n_chunks} chunks, scheduler saw {}",
                    p.results.len()
                )));
                return;
            }
            p.finished = true;
            p.orig_len = orig_len;
            p.orig_crc = orig_crc;
            if p.remaining == 0 {
                let p = st.pending.remove(&id).unwrap();
                finish(info, p, metrics);
            }
        }
        ToScheduler::StreamAbort { id } => {
            st.pending.remove(&id);
        }
        ToScheduler::Done(done) => {
            st.slots[done.worker].state = SlotState::Idle;
            st.idle.push(done.worker);
            complete_batch(done, info, &mut st.pending, metrics);
        }
        ToScheduler::Ready { worker, info: Ok(grown) } => {
            // Bit-identity guard: a grown replica must be indistinguishable
            // from the startup ones. A differing tag or window would mean a
            // nondeterministic factory — refuse the replica entirely
            // rather than let two engines disagree about the bytes.
            if grown.tag != info.tag
                || grown.chunk_tokens != info.chunk_tokens
                || grown.stream_bytes != info.stream_bytes
            {
                st.slots[worker].state = SlotState::Retired;
                st.slots[worker].job_tx = None;
                budget.release_one();
                metrics.record_error();
                eprintln!(
                    "llmzip-sched: grown worker {worker} reported engine '{}' != pool '{}' — \
                     refused",
                    grown.tag, info.tag
                );
            } else {
                st.slots[worker].state = SlotState::Idle;
                st.idle.push(worker);
                let live = live_count(&st.slots);
                metrics.record_scale(true, live);
                if let Some(hook) = on_scale {
                    hook(live);
                }
            }
        }
        ToScheduler::Ready { worker, info: Err(e) } => {
            // The grow failed (factory error or panic): free the slot so a
            // later evaluation can retry, and surface the error.
            st.slots[worker].state = SlotState::Empty;
            st.slots[worker].job_tx = None;
            budget.release_one();
            metrics.record_error();
            eprintln!("llmzip-sched: growing engine worker {worker} failed: {e:#}");
        }
    }
}

#[derive(Clone, Copy)]
struct Split {
    stream_bytes: usize,
    chunk_tokens: u32,
}

#[allow(clippy::too_many_arguments)]
fn admit(
    req: Request,
    info: &EngineInfo,
    split: Split,
    batcher: &mut DynamicBatcher,
    pending: &mut HashMap<u64, Pending>,
    metrics: &Metrics,
    pool: &BytePool,
) {
    let now = Instant::now();
    match req.op {
        Op::Compress(data) => {
            let n = data.chunks(split.stream_bytes).count().max(1);
            let entry = Pending {
                respond: req.respond,
                started: req.started,
                kind: WorkKind::Compress,
                tenant: req.tenant,
                results: vec![None; n],
                remaining: n,
                chunk_sizes: data.chunks(split.stream_bytes).map(|c| c.len() as u32).collect(),
                orig_len: data.len() as u64,
                orig_crc: crc32(&data),
                container_chunk_tokens: split.chunk_tokens,
                bytes_in: data.len(),
                finished: true,
            };
            if data.is_empty() {
                // Zero-chunk request: answer immediately with an empty
                // container carrying the REAL engine tag — `finish` never
                // sees this request, and decoding through
                // `LlmCompressor::decompress` requires the `model:flag` tag.
                let container = Container::v2_coded(
                    info.codec,
                    0,
                    entry.orig_crc,
                    entry.container_chunk_tokens,
                    info.tag.clone(),
                    vec![],
                    vec![],
                );
                metrics.record_request_op(WorkKind::Compress, 0, 0, entry.started.elapsed());
                let _ = entry.respond.send(Ok(container.to_bytes()));
                return;
            }
            pending.insert(req.id, entry);
            if data.len() <= split.stream_bytes {
                // Single-chunk request: the wire payload IS the work
                // item — move it through, zero copies end-to-end.
                batcher.push(WorkItem {
                    request_id: req.id,
                    chunk_index: 0,
                    kind: WorkKind::Compress,
                    priority: req.priority,
                    tenant: req.tenant,
                    data,
                    record: None,
                    codec: info.codec,
                    enqueued: now,
                });
            } else {
                for (i, chunk) in data.chunks(split.stream_bytes).enumerate() {
                    let mut item = pool.take(chunk.len());
                    item.extend_from_slice(chunk);
                    batcher.push(WorkItem {
                        request_id: req.id,
                        chunk_index: i as u32,
                        kind: WorkKind::Compress,
                        priority: req.priority,
                        tenant: req.tenant,
                        data: item,
                        record: None,
                        codec: info.codec,
                        enqueued: now,
                    });
                }
                // `data` drops here: the request buffer's storage goes
                // back to the pool for the next frame read.
            }
        }
        Op::Decompress(bytes) => match Container::from_bytes(&bytes) {
            Err(e) => {
                let _ = req.respond.send(Err(e));
            }
            Ok(container) => {
                // Legacy exception: pre-fix servers stamped empty containers
                // with an empty tag; they carry no payload, so decoding them
                // stays valid on any engine.
                let legacy_empty = container.model_name.is_empty() && container.chunks.is_empty();
                // Engine identity ignores the codec suffix: a range-configured
                // server decodes fse containers from the same model (and vice
                // versa) — decompression always follows the *container's*
                // recorded codec, cross-checked against the flag bits.
                let codec = if legacy_empty {
                    Codec::Range
                } else {
                    let same = match (
                        ContainerTag::parse(&container.model_name),
                        ContainerTag::parse(&info.tag),
                    ) {
                        (Ok(theirs), Ok(ours)) => theirs.same_engine(&ours),
                        _ => container.model_name == info.tag,
                    };
                    if !same {
                        let _ = req.respond.send(Err(anyhow::anyhow!(
                            "container was produced by engine '{}', this server runs '{}'",
                            container.model_name,
                            info.tag
                        )));
                        return;
                    }
                    match container_codec(&container) {
                        Ok(c) => c,
                        Err(e) => {
                            let _ = req.respond.send(Err(e));
                            return;
                        }
                    }
                };
                // Batches mix chunks from concurrent requests and the
                // engine decodes a whole batch with ONE context-window
                // size, so this server can only decode containers written
                // with its own chunk_tokens. Reject a mismatch up front —
                // otherwise it would surface as a baffling CRC failure.
                if container.chunk_tokens as usize != info.chunk_tokens
                    && !container.chunks.is_empty()
                {
                    let _ = req.respond.send(Err(anyhow::anyhow!(
                        "container was written with chunk_tokens={}, this server decodes with \
                         chunk_tokens={} — use a matching server or the offline CLI",
                        container.chunk_tokens,
                        info.chunk_tokens
                    )));
                    return;
                }
                let items: Vec<(ChunkRecord, PooledBuf)> = container
                    .iter_chunks()
                    .map(|(r, p)| {
                        let mut buf = pool.take(p.len());
                        buf.extend_from_slice(p);
                        (r, buf)
                    })
                    .collect();
                let n = items.len().max(1);
                let entry = Pending {
                    respond: req.respond,
                    started: req.started,
                    kind: WorkKind::Decompress,
                    tenant: req.tenant,
                    results: vec![None; n],
                    remaining: items.len(),
                    chunk_sizes: vec![],
                    orig_len: container.orig_len,
                    orig_crc: container.orig_crc32,
                    container_chunk_tokens: container.chunk_tokens,
                    bytes_in: bytes.len(),
                    finished: true,
                };
                if items.is_empty() {
                    metrics.record_request_op(
                        WorkKind::Decompress,
                        entry.bytes_in,
                        0,
                        entry.started.elapsed(),
                    );
                    let _ = entry.respond.send(Ok(Vec::new()));
                    return;
                }
                pending.insert(req.id, entry);
                for (i, (rec, payload)) in items.into_iter().enumerate() {
                    batcher.push(WorkItem {
                        request_id: req.id,
                        chunk_index: i as u32,
                        kind: WorkKind::Decompress,
                        priority: req.priority,
                        tenant: req.tenant,
                        data: payload,
                        record: Some(rec),
                        codec,
                        enqueued: now,
                    });
                }
            }
        },
    }
}

/// Fold a worker's completed batch back into per-request state.
fn complete_batch(
    done: BatchDone,
    info: &EngineInfo,
    pending: &mut HashMap<u64, Pending>,
    metrics: &Metrics,
) {
    match done.result {
        Err(e) => {
            // Fail every request that had a chunk in this batch.
            metrics.record_error();
            let msg = format!("batch failed: {e:#}");
            for item in done.items {
                if let Some(p) = pending.remove(&item.request_id) {
                    let _ = p.respond.send(Err(anyhow::anyhow!(msg.clone())));
                }
            }
        }
        Ok(outputs) => {
            for (item, out) in done.items.into_iter().zip(outputs) {
                let Some(p) = pending.get_mut(&item.request_id) else { continue };
                p.results[item.chunk_index as usize] = Some(out);
                p.remaining -= 1;
                // Streams complete only once the client declared the input
                // finished; one-shot requests are `finished` from admit.
                if p.remaining == 0 && p.finished {
                    let p = pending.remove(&item.request_id).unwrap();
                    finish(info, p, metrics);
                }
            }
        }
    }
}

fn finish(info: &EngineInfo, p: Pending, metrics: &Metrics) {
    let response: Result<Vec<u8>> = match p.kind {
        WorkKind::Compress => {
            let mut records = Vec::with_capacity(p.results.len());
            let mut payload = Vec::new();
            for (i, r) in p.results.iter().enumerate() {
                let bytes = r.as_ref().expect("all chunks done");
                records.push(ChunkRecord {
                    comp_len: bytes.len() as u32,
                    n_tokens: p.chunk_sizes[i],
                });
                payload.extend_from_slice(bytes);
            }
            Ok(Container::v2_coded(
                info.codec,
                p.orig_len,
                p.orig_crc,
                p.container_chunk_tokens,
                info.tag.to_string(),
                records,
                payload,
            )
            .to_bytes())
        }
        WorkKind::Decompress => {
            let mut out = Vec::with_capacity(p.orig_len as usize);
            for r in &p.results {
                out.extend_from_slice(r.as_ref().expect("all chunks done"));
            }
            if out.len() as u64 != p.orig_len || crc32(&out) != p.orig_crc {
                Err(anyhow::anyhow!("decompressed output failed CRC/length verification"))
            } else {
                Ok(out)
            }
        }
    };
    let out_len = response.as_ref().map(|v| v.len()).unwrap_or(0);
    metrics.record_request_op(p.kind, p.bytes_in, out_len, p.started.elapsed());
    let _ = p.respond.send(response);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lm::config::by_name;
    use crate::lm::weights::Weights;

    fn test_server(chunk: usize, lanes: usize) -> Server {
        Server::start(
            move || {
                let cfg = by_name("nano").unwrap();
                LlmCompressor::from_weights(cfg, Weights::random(cfg, 21), chunk, lanes)
            },
            ServerConfig {
                chunk_tokens: chunk,
                policy: BatchPolicy { lanes, max_wait: Duration::from_millis(5) },
                ..Default::default()
            },
        )
        .unwrap()
    }

    #[test]
    fn roundtrip_through_server() {
        let server = test_server(32, 2);
        let data = crate::textgen::quick_sample(300, 9);
        let z = server.compress(&data).unwrap();
        let back = server.decompress(&z).unwrap();
        assert_eq!(back, data);
        assert!(server.metrics.requests.load(Ordering::Relaxed) >= 2);
        // Engine throughput is recorded per batch: every input byte is one
        // token on the compress pass and again on the decompress pass.
        assert_eq!(server.metrics.tokens.load(Ordering::Relaxed), 2 * data.len() as u64);
        assert!(server.metrics.mean_tokens_per_sec() > 0.0);
        // Both op latencies landed in the per-op histograms.
        assert!(server.metrics.latency_samples(WorkKind::Compress) >= 1);
        assert!(server.metrics.latency_samples(WorkKind::Decompress) >= 1);
        assert!(server.metrics.latency_percentile_ms(WorkKind::Decompress, 0.99) > 0.0);
    }

    #[test]
    fn tickets_resolve_out_of_order_without_blocking() {
        // The async primitive: submit several ops up front, then collect
        // results via try_wait polling — no call ever parks the client
        // until it chooses to.
        let server = test_server(32, 2);
        let data: Vec<Vec<u8>> =
            (0..4).map(|i| crate::textgen::quick_sample(200 + i * 57, i as u64)).collect();
        let golden: Vec<Vec<u8>> = data.iter().map(|d| server.compress(d).unwrap()).collect();
        let tickets: Vec<Ticket> = golden
            .iter()
            .map(|z| server.submit(Op::Decompress(z.clone().into())).unwrap())
            .collect();
        let deadline = Instant::now() + Duration::from_secs(30);
        let mut results: Vec<Option<Vec<u8>>> = vec![None; tickets.len()];
        while results.iter().any(Option::is_none) {
            assert!(Instant::now() < deadline, "tickets never resolved");
            for (t, slot) in tickets.iter().zip(results.iter_mut()) {
                if slot.is_none() {
                    *slot = t.try_wait().unwrap();
                }
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        for (got, want) in results.into_iter().zip(&data) {
            assert_eq!(&got.unwrap(), want);
        }
        // Wait-based tickets work too, and submit defaults priorities.
        let t = server.submit(Op::Compress(data[0].clone().into())).unwrap();
        assert_eq!(t.wait().unwrap(), golden[0]);
    }

    #[test]
    fn open_stream_matches_one_shot_bytes_for_any_write_split() {
        let server = test_server(32, 2);
        let data = crate::textgen::quick_sample(1100, 17);
        let golden = server.compress(&data).unwrap();
        for splits in [vec![1100usize], vec![1; 1100], vec![0, 127, 1, 128, 500, 344]] {
            let mut stream = server.open_stream().unwrap();
            let mut off = 0;
            for s in splits {
                stream.write_bytes(&data[off..off + s]).unwrap();
                off += s;
            }
            assert_eq!(off, data.len());
            assert_eq!(stream.bytes_in(), data.len() as u64);
            let z = stream.finish().unwrap().wait().unwrap();
            assert_eq!(z, golden, "streamed container must equal the one-shot bytes");
        }
        // Empty stream == one-shot empty compress (tagged empty container).
        let z = server.open_stream().unwrap().finish().unwrap().wait().unwrap();
        assert_eq!(z, server.compress(b"").unwrap());
        assert_eq!(server.decompress(&z).unwrap(), b"");
    }

    #[test]
    fn abandoned_stream_aborts_cleanly_and_server_keeps_serving() {
        let server = test_server(32, 2);
        {
            let mut stream = server.open_stream().unwrap();
            stream.write_bytes(&crate::textgen::quick_sample(300, 3)).unwrap();
            // Dropped without finish: the scheduler must reap the session.
        }
        let data = crate::textgen::quick_sample(250, 4);
        let z = server.compress(&data).unwrap();
        assert_eq!(server.decompress(&z).unwrap(), data);
    }

    #[test]
    fn scale_hook_follows_the_replica_gauge() {
        // The hook fires at startup and on every grow/shrink with the live
        // count — the signal cmd/serve uses to retarget the shared
        // StepPool.
        let observed = Arc::new(std::sync::Mutex::new(Vec::<usize>::new()));
        let obs = observed.clone();
        let server = Server::start_with_hook(
            move || {
                let cfg = by_name("nano").unwrap();
                LlmCompressor::from_weights(cfg, Weights::random(cfg, 21), 32, 2)
            },
            ServerConfig {
                chunk_tokens: 32,
                replicas: 1,
                min_replicas: 1,
                max_replicas: 3,
                autoscale: true,
                autoscale_cooldown: Duration::from_millis(15),
                autoscale_shrink_after: Duration::from_millis(30),
                policy: BatchPolicy { lanes: 2, max_wait: Duration::from_millis(2) },
                ..Default::default()
            },
            Some(Arc::new(move |n| obs.lock().unwrap().push(n))),
        )
        .unwrap();
        assert_eq!(observed.lock().unwrap().clone(), vec![1usize], "startup fires the hook");
        // Burst load to force a grow, then idle to force the shrink back.
        let server = Arc::new(server);
        let mut handles = Vec::new();
        for i in 0..6u64 {
            let s = server.clone();
            handles.push(std::thread::spawn(move || {
                let data = crate::textgen::quick_sample(1000, i);
                for _ in 0..3 {
                    let z = s.compress(&data).unwrap();
                    assert_eq!(s.decompress(&z).unwrap(), data);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let deadline = Instant::now() + Duration::from_secs(10);
        while server.metrics.scale_downs.load(Ordering::Relaxed) == 0 {
            assert!(Instant::now() < deadline, "no shrink: {}", server.metrics.report());
            std::thread::sleep(Duration::from_millis(20));
        }
        let seen = observed.lock().unwrap().clone();
        assert!(seen.len() >= 3, "startup + grow + shrink: {seen:?}");
        assert!(seen.iter().all(|&n| (1..=3).contains(&n)), "{seen:?}");
        // Every hook value matches a gauge the metrics saw too.
        let peak = server.metrics.replicas_peak.load(Ordering::Relaxed);
        assert!(*seen.iter().max().unwrap() as u64 <= peak);
    }

    #[test]
    fn lane_cap_limits_batch_width() {
        // Engine has 4 lanes but the server is configured to fill at most 2.
        let server = Server::start(
            move || {
                let cfg = by_name("nano").unwrap();
                LlmCompressor::from_weights(cfg, Weights::random(cfg, 22), 16, 4)
            },
            ServerConfig {
                chunk_tokens: 16,
                lanes: 2,
                policy: BatchPolicy { lanes: 8, max_wait: Duration::from_millis(2) },
                ..Default::default()
            },
        )
        .unwrap();
        // 6 chunks (stream granularity 64 bytes) -> at least 3 batches.
        let data = crate::textgen::quick_sample(6 * 64, 10);
        let z = server.compress(&data).unwrap();
        assert_eq!(server.decompress(&z).unwrap(), data);
        let batches = server.metrics.batches.load(Ordering::Relaxed);
        assert!(batches >= 3, "cap 2 lanes over 6 chunks needs >= 3 batches, got {batches}");
    }

    #[test]
    fn empty_request_roundtrips_and_carries_engine_tag() {
        let server = test_server(32, 2);
        let z = server.compress(b"").unwrap();
        // Regression: the empty container must carry the real engine tag
        // (it used to ship `model_name: ""`, which only the server's own
        // lenient path could decode).
        let container = Container::from_bytes(&z).unwrap();
        assert_eq!(container.model_name, "nano:0");
        assert_eq!(server.decompress(&z).unwrap(), b"");
    }

    #[test]
    fn legacy_untagged_empty_container_still_decodes() {
        // Pre-fix servers emitted empty containers with model_name: "";
        // they carry no payload, so the new tag check must let them pass.
        let server = test_server(32, 2);
        let legacy = Container::v1(0, crate::util::crc32(b""), 32, String::new(), vec![], vec![])
            .to_bytes();
        assert_eq!(server.decompress(&legacy).unwrap(), b"");
    }

    #[test]
    fn server_empty_container_decodes_through_compressor_path() {
        // The regression test for the zero-length-compress fix: a
        // server-produced empty container must decode through
        // `LlmCompressor::decompress`, which requires the `model:flag` tag.
        use crate::compress::Compressor;
        let server = test_server(32, 2);
        let z = server.compress(b"").unwrap();
        let cfg = by_name("nano").unwrap();
        let compressor =
            LlmCompressor::from_weights(cfg, Weights::random(cfg, 21), 32, 2).unwrap();
        assert_eq!(compressor.decompress(&z).unwrap(), b"");
    }

    #[test]
    fn concurrent_requests_share_batches() {
        let server = Arc::new(test_server(16, 4));
        let mut handles = Vec::new();
        for i in 0..6 {
            let s = server.clone();
            handles.push(std::thread::spawn(move || {
                let data = crate::textgen::quick_sample(120 + i * 13, i as u64);
                let z = s.compress(&data).unwrap();
                let back = s.decompress(&z).unwrap();
                assert_eq!(back, data);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // Cross-request batching should produce fewer batches than chunks.
        let batches = server.metrics.batches.load(Ordering::Relaxed);
        let chunks = server.metrics.chunks.load(Ordering::Relaxed);
        assert!(batches < chunks, "batches {batches} chunks {chunks}");
    }

    #[test]
    fn corrupt_container_rejected() {
        let server = test_server(32, 2);
        assert!(server.decompress(&[1, 2, 3]).is_err());
        let data = crate::textgen::quick_sample(400, 1);
        let mut z = server.compress(&data).unwrap();
        // Corrupt mid-payload (the tail bytes of a range-coded stream can be
        // flush slack, so flip bits well inside the payload).
        let n = z.len();
        for i in [n / 2, n / 2 + 1, 3 * n / 4] {
            z[i] ^= 0x55;
        }
        assert!(server.decompress(&z).is_err());
    }

    #[test]
    fn foreign_engine_container_rejected_early() {
        let server = test_server(32, 2);
        let data = crate::textgen::quick_sample(200, 2);
        let mut container = Container::from_bytes(&server.compress(&data).unwrap()).unwrap();
        container.model_name = "medium:0".into();
        let err = server.decompress(&container.to_bytes()).unwrap_err().to_string();
        assert!(err.contains("produced by engine"), "{err}");
    }

    #[test]
    fn mismatched_chunk_tokens_rejected_with_clear_error() {
        // Same engine, different context window: decoding would produce
        // garbage + a CRC failure, so the server refuses up front.
        let server = test_server(32, 2);
        let data = crate::textgen::quick_sample(200, 3);
        let mut container = Container::from_bytes(&server.compress(&data).unwrap()).unwrap();
        container.chunk_tokens = 16;
        let err = server.decompress(&container.to_bytes()).unwrap_err().to_string();
        assert!(err.contains("chunk_tokens"), "{err}");
    }

    #[test]
    fn replica_pool_serves_and_attributes_work() {
        let server = Arc::new(Server::start(
            move || {
                let cfg = by_name("nano").unwrap();
                LlmCompressor::from_weights(cfg, Weights::random(cfg, 23), 16, 2)
            },
            ServerConfig {
                chunk_tokens: 16,
                replicas: 3,
                policy: BatchPolicy { lanes: 2, max_wait: Duration::from_millis(2) },
                ..Default::default()
            },
        )
        .unwrap());
        assert_eq!(server.metrics.workers.len(), 3);
        let mut handles = Vec::new();
        for i in 0..6u64 {
            let s = server.clone();
            handles.push(std::thread::spawn(move || {
                let data = crate::textgen::quick_sample(400 + i as usize * 29, i);
                let z = s.compress(&data).unwrap();
                assert_eq!(s.decompress(&z).unwrap(), data);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(server.metrics.errors.load(Ordering::Relaxed), 0);
        let per_worker: Vec<u64> = server
            .metrics
            .workers
            .iter()
            .map(|w| w.batches.load(Ordering::Relaxed))
            .collect();
        let total: u64 = per_worker.iter().sum();
        assert_eq!(total, server.metrics.batches.load(Ordering::Relaxed));
        assert!(total > 0);
    }

    #[test]
    fn failed_factory_fails_startup() {
        let r = Server::start(
            || -> Result<LlmCompressor> { anyhow::bail!("no engine for you") },
            ServerConfig { replicas: 2, ..Default::default() },
        );
        assert!(r.is_err());
    }

    #[test]
    fn panicking_factory_fails_startup_cleanly() {
        // The catch_unwind around the factory converts a construction
        // panic into a startup error instead of a wedged scheduler.
        let r = Server::start(
            || -> Result<LlmCompressor> { panic!("factory exploded") },
            ServerConfig { replicas: 2, ..Default::default() },
        );
        assert!(r.unwrap_err().to_string().contains("panicked"));
    }

    #[test]
    fn min_above_max_rejected() {
        let r = Server::start(
            move || {
                let cfg = by_name("nano").unwrap();
                LlmCompressor::from_weights(cfg, Weights::random(cfg, 21), 32, 2)
            },
            ServerConfig { min_replicas: 3, max_replicas: 2, autoscale: true, ..Default::default() },
        );
        assert!(r.unwrap_err().to_string().contains("min_replicas"));
    }

    #[test]
    fn pool_bounds_defaults_and_clamping() {
        let mut c = ServerConfig { replicas: 3, ..Default::default() };
        assert_eq!(pool_bounds(&c), (3, 3, 3), "min/max default to replicas");
        c.min_replicas = 1;
        c.max_replicas = 5;
        assert_eq!(pool_bounds(&c), (1, 3, 5));
        c.replicas = 9;
        assert_eq!(pool_bounds(&c), (1, 5, 5), "initial clamps into [min, max]");
        c.replicas = 0;
        assert_eq!(pool_bounds(&c), (1, 1, 5), "replicas 0 behaves as 1");
    }

    fn test_scaler(min: usize, max: usize, lanes: usize) -> Autoscaler {
        Autoscaler::new(
            min,
            max,
            lanes,
            &ServerConfig {
                autoscale_cooldown: Duration::from_millis(100),
                autoscale_shrink_after: Duration::from_millis(300),
                ..Default::default()
            },
        )
    }

    fn snap(live: usize, idle: usize, queued: usize) -> PoolSnapshot {
        PoolSnapshot { live, starting: 0, idle, queued, p99_ms: 0.0 }
    }

    #[test]
    fn autoscaler_grows_on_backlog_within_cooldown_and_max() {
        let mut a = test_scaler(1, 3, 4);
        let t0 = Instant::now();
        // Backlog over one full batch per replica -> grow.
        assert_eq!(a.decide(t0, snap(1, 0, 9)), ScaleDecision::Grow);
        // Cooldown gates the next action even with the signal still hot.
        assert_eq!(a.decide(t0 + Duration::from_millis(50), snap(2, 0, 20)), ScaleDecision::Hold);
        assert_eq!(a.decide(t0 + Duration::from_millis(150), snap(2, 0, 20)), ScaleDecision::Grow);
        // At max, backlog can no longer grow the pool.
        assert_eq!(a.decide(t0 + Duration::from_millis(300), snap(3, 0, 99)), ScaleDecision::Hold);
        // Mid-construction workers count toward capacity: one burst must
        // not spawn the whole range at once.
        let mut b = test_scaler(1, 4, 4);
        assert_eq!(b.decide(t0, snap(1, 0, 9)), ScaleDecision::Grow);
        let busy_building =
            PoolSnapshot { live: 1, starting: 1, idle: 0, queued: 7, p99_ms: 0.0 };
        assert_eq!(
            b.decide(t0 + Duration::from_millis(150), busy_building),
            ScaleDecision::Hold,
            "queued 7 <= lanes 4 * capacity 2 (the Starting worker counts)"
        );
    }

    #[test]
    fn autoscaler_shrinks_only_after_sustained_idle_above_min() {
        let mut a = test_scaler(1, 3, 4);
        let t0 = Instant::now();
        // Idle but not yet sustained: hold.
        assert_eq!(a.decide(t0, snap(2, 1, 0)), ScaleDecision::Hold);
        assert_eq!(a.decide(t0 + Duration::from_millis(200), snap(2, 1, 0)), ScaleDecision::Hold);
        // Past shrink_after: shrink.
        assert_eq!(a.decide(t0 + Duration::from_millis(320), snap(2, 1, 0)), ScaleDecision::Shrink);
        // A queued item resets the idle clock.
        let t1 = t0 + Duration::from_millis(500);
        assert_eq!(a.decide(t1, snap(1, 1, 2)), ScaleDecision::Hold);
        assert_eq!(
            a.decide(t1 + Duration::from_millis(400), snap(1, 1, 0)),
            ScaleDecision::Hold,
            "idle restarted at the first idle observation"
        );
        // At min, sustained idleness never shrinks.
        let mut b = test_scaler(2, 3, 4);
        for ms in [0u64, 400, 800, 1200] {
            assert_eq!(b.decide(t0 + Duration::from_millis(ms), snap(2, 2, 0)), ScaleDecision::Hold);
        }
    }

    #[test]
    fn autoscaler_p99_signal_grows_only_with_queued_work() {
        let mut a = Autoscaler::new(
            1,
            3,
            4,
            &ServerConfig {
                autoscale_cooldown: Duration::from_millis(100),
                autoscale_p99_ms: 50.0,
                ..Default::default()
            },
        );
        let t0 = Instant::now();
        let slow_idle = PoolSnapshot { live: 1, starting: 0, idle: 1, queued: 0, p99_ms: 400.0 };
        assert_eq!(a.decide(t0, slow_idle), ScaleDecision::Hold, "p99 alone is history, not load");
        let slow_busy = PoolSnapshot { live: 1, starting: 0, idle: 0, queued: 2, p99_ms: 400.0 };
        assert_eq!(a.decide(t0, slow_busy), ScaleDecision::Grow);
    }

    #[test]
    fn autoscaler_never_flaps_under_constant_load() {
        // Property: for ANY constant load level, the pool moves monotonely
        // to an equilibrium and then holds — grow and shrink never
        // alternate without the load changing.
        let t0 = Instant::now();
        for queued in [0usize, 1, 3, 4, 5, 8, 12, 40] {
            let mut a = test_scaler(1, 4, 4);
            let mut live = 2usize;
            let mut dirs: Vec<ScaleDecision> = Vec::new();
            for tick in 0..400u64 {
                let now = t0 + Duration::from_millis(tick * 10);
                let idle = if queued == 0 { live } else { 0 };
                match a.decide(now, snap(live, idle, queued)) {
                    ScaleDecision::Hold => {}
                    d @ ScaleDecision::Grow => {
                        live += 1;
                        dirs.push(d);
                    }
                    d @ ScaleDecision::Shrink => {
                        live -= 1;
                        dirs.push(d);
                    }
                }
                assert!((1..=4).contains(&live), "queued={queued} live={live}");
            }
            assert!(
                dirs.windows(2).all(|w| w[0] == w[1]),
                "queued={queued}: direction flip under constant load: {dirs:?}"
            );
            // And the tail of the run is quiescent.
            let mut a2 = test_scaler(1, 4, 4);
            let idle = if queued == 0 { live } else { 0 };
            for tick in 400..420u64 {
                let now = t0 + Duration::from_millis(tick * 10);
                assert_eq!(a2.decide(now, snap(live, idle, queued)), ScaleDecision::Hold);
            }
        }
    }

    #[test]
    fn autoscaler_bounded_and_cooled_under_random_load() {
        // Property: for ANY load sequence, capacity stays within
        // [min, max] and actions are never closer than the cooldown.
        let mut rng = crate::util::Pcg64::seeded(4242);
        let t0 = Instant::now();
        for _ in 0..30 {
            let min = 1 + rng.gen_index(2);
            let max = min + rng.gen_index(4);
            let lanes = 1 + rng.gen_index(8);
            let mut a = test_scaler(min, max, lanes);
            let mut live = min + rng.gen_index(max - min + 1);
            let mut now = t0;
            let mut last_action: Option<Instant> = None;
            for _ in 0..300 {
                now += Duration::from_millis(rng.gen_range(40) + 1);
                let queued = if rng.gen_bool(0.4) { 0 } else { rng.gen_index(60) };
                let idle = if queued == 0 { rng.gen_index(live + 1) } else { 0 };
                let d = a.decide(now, snap(live, idle, queued));
                if d != ScaleDecision::Hold {
                    if let Some(t) = last_action {
                        assert!(
                            now.duration_since(t) >= Duration::from_millis(100),
                            "action inside cooldown"
                        );
                    }
                    last_action = Some(now);
                }
                match d {
                    ScaleDecision::Grow => live += 1,
                    ScaleDecision::Shrink => live -= 1,
                    ScaleDecision::Hold => {}
                }
                assert!(live >= min && live <= max, "live {live} outside [{min}, {max}]");
            }
        }
    }

    /// An elastic test server: nano model, aggressive autoscale timings so
    /// grow/shrink both happen inside a test run.
    fn elastic_server(min: usize, max: usize) -> Server {
        Server::start(
            move || {
                let cfg = by_name("nano").unwrap();
                LlmCompressor::from_weights(cfg, Weights::random(cfg, 21), 32, 2)
            },
            ServerConfig {
                chunk_tokens: 32,
                replicas: min,
                min_replicas: min,
                max_replicas: max,
                autoscale: true,
                autoscale_cooldown: Duration::from_millis(15),
                autoscale_shrink_after: Duration::from_millis(30),
                policy: BatchPolicy { lanes: 2, max_wait: Duration::from_millis(2) },
                ..Default::default()
            },
        )
        .unwrap()
    }

    #[test]
    fn elastic_pool_grows_under_burst_then_shrinks_idle() {
        let server = Arc::new(elastic_server(1, 3));
        assert_eq!(server.metrics.workers.len(), 3, "metrics sized to max_replicas");
        assert_eq!(server.metrics.replicas.load(Ordering::Relaxed), 1);
        // Burst: concurrent multi-chunk bulk requests build a backlog the
        // single replica cannot absorb -> the pool must grow.
        let mut handles = Vec::new();
        for i in 0..6u64 {
            let s = server.clone();
            handles.push(std::thread::spawn(move || {
                // 128-byte streams -> ~8 chunks per request.
                let data = crate::textgen::quick_sample(1000 + i as usize * 17, i);
                for _ in 0..3 {
                    let z = s.compress(&data).unwrap();
                    assert_eq!(s.decompress(&z).unwrap(), data);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(
            server.metrics.scale_ups.load(Ordering::Relaxed) >= 1,
            "burst load must grow the pool: {}",
            server.metrics.report()
        );
        // Quiet: a sustained idle window must shrink back toward min.
        let deadline = Instant::now() + Duration::from_secs(10);
        while server.metrics.scale_downs.load(Ordering::Relaxed) == 0 {
            assert!(Instant::now() < deadline, "no shrink: {}", server.metrics.report());
            std::thread::sleep(Duration::from_millis(20));
        }
        // Bounds held for the whole run.
        assert!(server.metrics.replicas_peak.load(Ordering::Relaxed) <= 3);
        assert!(server.metrics.replicas_low.load(Ordering::Relaxed) >= 1);
        assert_eq!(server.metrics.errors.load(Ordering::Relaxed), 0);
        // Still serving after the churn.
        let data = crate::textgen::quick_sample(300, 77);
        let z = server.compress(&data).unwrap();
        assert_eq!(server.decompress(&z).unwrap(), data);
    }

    #[test]
    fn failed_and_panicking_grows_are_contained() {
        // The first build (startup) succeeds; the first grow fails with an
        // error; every later grow panics. The pool must keep serving at
        // its current size through all of it.
        let builds = Arc::new(AtomicU64::new(0));
        let b = builds.clone();
        let server = Arc::new(
            Server::start(
                move || {
                    let n = b.fetch_add(1, Ordering::SeqCst);
                    if n == 1 {
                        anyhow::bail!("grow refused");
                    }
                    if n >= 2 {
                        panic!("grow exploded");
                    }
                    let cfg = by_name("nano").unwrap();
                    LlmCompressor::from_weights(cfg, Weights::random(cfg, 21), 32, 2)
                },
                ServerConfig {
                    chunk_tokens: 32,
                    replicas: 1,
                    min_replicas: 1,
                    max_replicas: 3,
                    autoscale: true,
                    autoscale_cooldown: Duration::from_millis(10),
                    autoscale_shrink_after: Duration::from_millis(30),
                    policy: BatchPolicy { lanes: 2, max_wait: Duration::from_millis(2) },
                    ..Default::default()
                },
            )
            .unwrap(),
        );
        // Load until at least two failed grows were RECORDED (the bailed
        // one and a panicked one — both surface as scheduler errors).
        let deadline = Instant::now() + Duration::from_secs(10);
        let data = crate::textgen::quick_sample(1200, 5);
        while server.metrics.errors.load(Ordering::Relaxed) < 2 {
            assert!(Instant::now() < deadline, "grows never attempted");
            let mut handles = Vec::new();
            for _ in 0..4 {
                let s = server.clone();
                let d = data.clone();
                handles.push(std::thread::spawn(move || {
                    let z = s.compress(&d).unwrap();
                    assert_eq!(s.decompress(&z).unwrap(), d);
                }));
            }
            for h in handles {
                h.join().unwrap();
            }
        }
        assert!(builds.load(Ordering::SeqCst) >= 3, "startup + two grow attempts");
        assert_eq!(server.metrics.scale_ups.load(Ordering::Relaxed), 0);
        assert_eq!(server.metrics.replicas.load(Ordering::Relaxed), 1, "pool held at one");
        // And the survivor still serves.
        let z = server.compress(&data).unwrap();
        assert_eq!(server.decompress(&z).unwrap(), data);
    }

    #[test]
    fn replica_budget_grants_partially_and_atomically() {
        let b = ReplicaBudget::new(3);
        assert_eq!((b.cap(), b.used()), (3, 0));
        // All-or-nothing: asking for more than is free claims NOTHING.
        assert!(!b.try_acquire(4));
        assert_eq!(b.used(), 0);
        assert!(b.try_acquire(2));
        // Best-effort: grants what is free, down to zero.
        assert_eq!(b.acquire_up_to(5), 1);
        assert_eq!(b.acquire_up_to(5), 0);
        assert_eq!(b.used(), 3);
        b.release(2);
        assert!(b.try_acquire(1));
        assert_eq!(b.used(), 2);
        b.release(2);
        assert_eq!(b.used(), 0);
    }

    #[test]
    fn replica_budget_is_race_free_across_pools() {
        // 8 contenders hammer a 4-permit budget; at no observable point
        // may more than 4 permits be out, and the final balance is zero.
        let b = ReplicaBudget::new(4);
        let peak = Arc::new(AtomicUsize::new(0));
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let b = b.clone();
                let peak = peak.clone();
                std::thread::spawn(move || {
                    for _ in 0..200 {
                        if b.try_acquire(1) {
                            peak.fetch_max(b.used(), Ordering::SeqCst);
                            assert!(b.used() <= 4, "budget overshot its cap");
                            b.release(1);
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(b.used(), 0, "permits leaked");
        assert!(peak.load(Ordering::SeqCst) >= 1);
    }

    #[test]
    fn pool_startup_respects_a_contended_budget() {
        // A 2-permit budget with 1 permit already held elsewhere: a pool
        // asking for 2 starting replicas gets granted 1 and RUNS with it.
        let budget = ReplicaBudget::new(2);
        assert!(budget.try_acquire(1));
        let server = Server::start(
            || {
                let cfg = by_name("nano")?;
                LlmCompressor::from_weights(cfg, Weights::random(cfg, 21), 32, 2)
            },
            ServerConfig {
                chunk_tokens: 32,
                replicas: 2,
                min_replicas: 1,
                max_replicas: 2,
                replica_budget: Some(budget.clone()),
                policy: BatchPolicy { lanes: 2, max_wait: Duration::from_millis(2) },
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(budget.used(), 2, "pool claimed the one free permit");
        assert_eq!(server.metrics.replicas.load(Ordering::Relaxed), 1);
        let data = crate::textgen::quick_sample(300, 4);
        let z = server.compress(&data).unwrap();
        assert_eq!(server.decompress(&z).unwrap(), data);
        drop(server);
        assert_eq!(budget.used(), 1, "shutdown returned the pool's permits");
        budget.release(1);
    }

    #[test]
    fn pool_startup_fails_cleanly_on_an_exhausted_budget() {
        let budget = ReplicaBudget::new(1);
        assert!(budget.try_acquire(1));
        let err = Server::start(
            || {
                let cfg = by_name("nano")?;
                LlmCompressor::from_weights(cfg, Weights::random(cfg, 21), 32, 2)
            },
            ServerConfig {
                chunk_tokens: 32,
                replica_budget: Some(budget.clone()),
                policy: BatchPolicy { lanes: 2, max_wait: Duration::from_millis(2) },
                ..Default::default()
            },
        )
        .unwrap_err();
        assert!(
            format!("{err:#}").contains("replica budget exhausted"),
            "unexpected error: {err:#}"
        );
        assert_eq!(budget.used(), 1, "failed startup must not leak or steal permits");
    }
}
