//! Request router: intake, chunking, priority scheduling across an
//! engine-replica pool, and reassembly.
//!
//! Architecture (replica-pool refactor):
//!
//! * **Clients** submit requests through a channel and block on a
//!   per-request response channel.
//! * **One scheduler thread** (`llmzip-sched`) owns intake, the
//!   [`DynamicBatcher`] (decompress fast lane + per-item priorities),
//!   per-request reassembly state, and worker dispatch. It never touches
//!   an engine.
//! * **`replicas` engine workers** (`llmzip-engine-N`), each owning a full
//!   [`LlmCompressor`] built *inside its own thread* by the shared factory
//!   (PJRT handles are thread-affine). Native replicas built from one
//!   `Arc<Weights>` share a single copy of the tensors. Workers receive at
//!   most one batch at a time and report completions back on the
//!   scheduler's own channel, so scheduling stays single-threaded and
//!   race-free.
//!
//! Chunks from concurrent requests share engine batches, and independent
//! batches run on different replicas in parallel. Containers are
//! bit-identical for ANY `{replicas, threads, lanes}` configuration:
//! every chunk is encoded in its own lane with its own range coder, so
//! batch packing, dispatch order and replica choice cannot leak into the
//! payload bytes (asserted by `tests/integration_server.rs`).

use crate::compress::container::{ChunkRecord, Container};
use crate::compress::llm::LlmCompressor;
use crate::coordinator::batcher::{BatchPolicy, DynamicBatcher, Priority, WorkItem, WorkKind};
use crate::coordinator::metrics::Metrics;
use crate::util::crc32;
use crate::Result;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub chunk_tokens: usize,
    /// Batch-width cap: limit engine batches to this many lanes
    /// (`0` = use the engine's full lane count). The effective width is
    /// always `min(lanes, engine lanes)`.
    pub lanes: usize,
    /// Native-engine worker threads per replica. The scheduler cannot
    /// rebuild engines (the factory owns construction), so this is the
    /// value `cmd/serve` wires into `LlmCompressorConfig::threads`; it is
    /// recorded here so the whole replica/lane/thread configuration
    /// travels through one struct. Total step threads = replicas x this.
    pub threads: usize,
    /// Engine replicas: parallel engine workers, each running a full
    /// compressor built by the factory (`0` behaves as `1`). Native
    /// replicas share one `Arc<Weights>` when the factory clones one.
    pub replicas: usize,
    pub policy: BatchPolicy,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            chunk_tokens: 256,
            lanes: 0,
            threads: 0,
            replicas: 1,
            policy: BatchPolicy::default(),
        }
    }
}

enum Op {
    Compress(Vec<u8>),
    Decompress(Vec<u8>),
}

struct Request {
    id: u64,
    op: Op,
    priority: Priority,
    respond: SyncSender<Result<Vec<u8>>>,
    started: Instant,
}

/// Everything the scheduler hears about: client intake and worker
/// completions share one channel, so a single `recv` drives both.
enum ToScheduler {
    Request(Request),
    Done(BatchDone),
}

/// One batch handed to an engine worker.
struct EngineJob {
    kind: WorkKind,
    items: Vec<WorkItem>,
    /// Context window for decompress batches (the server decodes its own
    /// containers, so this is the worker's configured `chunk_tokens`).
    chunk_tokens: usize,
}

/// A worker's completion report.
struct BatchDone {
    worker: usize,
    items: Vec<WorkItem>,
    result: Result<Vec<Vec<u8>>>,
}

/// What the scheduler needs to know about the (identical) replicas,
/// reported by the first worker to finish construction.
#[derive(Clone)]
struct EngineInfo {
    lanes: usize,
    stream_bytes: usize,
    chunk_tokens: usize,
    /// `model:executor_flag` tag stamped into every produced container —
    /// including empty ones, which never reach a worker.
    tag: String,
}

/// Per-request reassembly state.
struct Pending {
    respond: SyncSender<Result<Vec<u8>>>,
    started: Instant,
    kind: WorkKind,
    /// Results by chunk index (compress: payloads; decompress: raw bytes).
    results: Vec<Option<Vec<u8>>>,
    remaining: usize,
    /// Compress: original lengths per chunk + source crc/len for container.
    chunk_sizes: Vec<u32>,
    orig_len: u64,
    orig_crc: u32,
    container_chunk_tokens: u32,
    bytes_in: usize,
}

/// The compression service.
pub struct Server {
    tx: SyncSender<ToScheduler>,
    next_id: AtomicU64,
    pub metrics: Arc<Metrics>,
    shutdown: Arc<AtomicBool>,
    scheduler: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start the scheduler and its engine-worker pool. Each replica's
    /// compressor is built INSIDE its worker thread by `factory` because
    /// PJRT handles are thread-affine (`!Send`); the factory itself only
    /// captures plain data (clone an `Arc<Weights>` into it to make native
    /// replicas share tensors).
    pub fn start<F>(factory: F, config: ServerConfig) -> Result<Server>
    where
        F: Fn() -> Result<LlmCompressor> + Send + Sync + 'static,
    {
        let replicas = config.replicas.max(1);
        let (tx, rx) = sync_channel::<ToScheduler>(256 + 4 * replicas);
        let metrics = Arc::new(Metrics::with_workers(replicas));
        let shutdown = Arc::new(AtomicBool::new(false));
        let factory = Arc::new(factory);
        let m = metrics.clone();
        let sd = shutdown.clone();
        let worker_tx = tx.clone();
        let (ready_tx, ready_rx) = sync_channel::<Result<()>>(1);
        let scheduler = std::thread::Builder::new()
            .name("llmzip-sched".into())
            .spawn(move || scheduler_main(factory, config, rx, worker_tx, m, sd, ready_tx))
            .expect("spawning scheduler");
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("scheduler died during startup"))??;
        Ok(Server { tx, next_id: AtomicU64::new(1), metrics, shutdown, scheduler: Some(scheduler) })
    }

    fn submit(&self, op: Op, priority: Priority) -> Result<Vec<u8>> {
        let (rtx, rrx) = sync_channel(1);
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.tx
            .send(ToScheduler::Request(Request {
                id,
                op,
                priority,
                respond: rtx,
                started: Instant::now(),
            }))
            .map_err(|_| anyhow::anyhow!("server is shut down"))?;
        rrx.recv().map_err(|_| anyhow::anyhow!("server dropped the request"))?
    }

    /// Compress `data`, returning a container (blocks until done). Bulk
    /// priority: queued decompress work and interactive compressions go
    /// first.
    pub fn compress(&self, data: &[u8]) -> Result<Vec<u8>> {
        self.submit(Op::Compress(data.to_vec()), Priority::Bulk)
    }

    /// [`Self::compress`] at interactive priority: overtakes queued bulk
    /// compress chunks (decompress keeps its own fast lane regardless).
    pub fn compress_interactive(&self, data: &[u8]) -> Result<Vec<u8>> {
        self.submit(Op::Compress(data.to_vec()), Priority::Interactive)
    }

    /// Decompress a container (blocks until done). Always interactive:
    /// reads ride the fast lane past bulk compress jobs.
    pub fn decompress(&self, container: &[u8]) -> Result<Vec<u8>> {
        self.submit(Op::Decompress(container.to_vec()), Priority::Interactive)
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(s) = self.scheduler.take() {
            let _ = s.join();
        }
    }
}

/// An engine worker: builds its compressor, reports readiness, then runs
/// one batch at a time until the scheduler drops its job channel.
fn engine_worker<F>(
    id: usize,
    factory: Arc<F>,
    job_rx: Receiver<EngineJob>,
    done_tx: SyncSender<ToScheduler>,
    ready_tx: SyncSender<(usize, Result<EngineInfo>)>,
    metrics: Arc<Metrics>,
) where
    F: Fn() -> Result<LlmCompressor> + Send + Sync + 'static,
{
    let compressor = match factory() {
        Ok(c) => {
            let info = EngineInfo {
                lanes: c.lanes(),
                stream_bytes: c.stream_bytes(),
                chunk_tokens: c.chunk_tokens(),
                tag: c.container_tag(),
            };
            let _ = ready_tx.send((id, Ok(info)));
            drop(ready_tx);
            c
        }
        Err(e) => {
            let _ = ready_tx.send((id, Err(e)));
            return;
        }
    };
    while let Ok(job) = job_rx.recv() {
        // Engine throughput: every byte is one model token, on both passes.
        let batch_tokens: usize = match job.kind {
            WorkKind::Compress => job.items.iter().map(|i| i.data.len()).sum(),
            WorkKind::Decompress => job
                .items
                .iter()
                .map(|i| i.record.map(|r| r.n_tokens as usize).unwrap_or(0))
                .sum(),
        };
        let t0 = Instant::now();
        // A panicking batch must not kill the worker (the scheduler would
        // count the slot busy forever): convert it to a failed batch. The
        // engine re-resets per batch/window, so its state recovers.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| match job.kind {
            WorkKind::Compress => {
                let chunks: Vec<&[u8]> = job.items.iter().map(|i| i.data.as_slice()).collect();
                compressor.compress_chunks(&chunks)
            }
            WorkKind::Decompress => {
                let records: Vec<ChunkRecord> = job
                    .items
                    .iter()
                    .map(|i| i.record.expect("decode item has record"))
                    .collect();
                let payloads: Vec<&[u8]> = job.items.iter().map(|i| i.data.as_slice()).collect();
                compressor.decompress_chunks(job.chunk_tokens, &records, &payloads)
            }
        }))
        .unwrap_or_else(|_| Err(anyhow::anyhow!("engine batch panicked")));
        if result.is_ok() {
            metrics.record_engine_worker(id, batch_tokens, t0.elapsed());
        }
        let done = BatchDone { worker: id, items: job.items, result };
        if done_tx.send(ToScheduler::Done(done)).is_err() {
            return;
        }
    }
}

fn scheduler_main<F>(
    factory: Arc<F>,
    config: ServerConfig,
    rx: Receiver<ToScheduler>,
    worker_tx: SyncSender<ToScheduler>,
    metrics: Arc<Metrics>,
    shutdown: Arc<AtomicBool>,
    ready_tx: SyncSender<Result<()>>,
) where
    F: Fn() -> Result<LlmCompressor> + Send + Sync + 'static,
{
    let replicas = config.replicas.max(1);
    // Spawn the engine workers; each gets a 1-deep private job channel
    // (a worker never holds more than one batch) and reports completions
    // on the scheduler's own intake channel.
    let (worker_ready_tx, worker_ready_rx) = sync_channel::<(usize, Result<EngineInfo>)>(replicas);
    let mut job_txs = Vec::with_capacity(replicas);
    let mut handles = Vec::with_capacity(replicas);
    for id in 0..replicas {
        let (job_tx, job_rx) = sync_channel::<EngineJob>(1);
        let f = factory.clone();
        let dt = worker_tx.clone();
        let rt = worker_ready_tx.clone();
        let m = metrics.clone();
        let handle = std::thread::Builder::new()
            .name(format!("llmzip-engine-{id}"))
            .spawn(move || engine_worker(id, f, job_rx, dt, rt, m))
            .expect("spawning engine worker");
        job_txs.push(job_tx);
        handles.push(handle);
    }
    drop(worker_ready_tx);
    drop(worker_tx);
    // Collect readiness from every replica; any failure aborts startup.
    let mut info: Option<EngineInfo> = None;
    let mut startup_err: Option<anyhow::Error> = None;
    for _ in 0..replicas {
        match worker_ready_rx.recv() {
            Ok((_, Ok(i))) => {
                if info.is_none() {
                    info = Some(i);
                }
            }
            Ok((_, Err(e))) => {
                if startup_err.is_none() {
                    startup_err = Some(e);
                }
            }
            Err(_) => {
                if startup_err.is_none() {
                    startup_err = Some(anyhow::anyhow!("engine worker died during startup"));
                }
                break;
            }
        }
    }
    if let Some(e) = startup_err {
        let _ = ready_tx.send(Err(e));
        drop(job_txs);
        for h in handles {
            let _ = h.join();
        }
        return;
    }
    let info = info.expect("replicas >= 1 reported ready");
    let _ = ready_tx.send(Ok(()));

    let lanes = if config.lanes > 0 { config.lanes.min(info.lanes) } else { info.lanes };
    // Requests are split at the compressor's stream granularity; the
    // model-context chunk size is recorded in each container.
    let split = Split { stream_bytes: info.stream_bytes, chunk_tokens: info.chunk_tokens as u32 };
    let mut batcher = DynamicBatcher::new(BatchPolicy { lanes, ..config.policy });
    let mut pending: HashMap<u64, Pending> = HashMap::new();
    // Idle worker ids (stack: lowest id dispatched first at startup) and
    // retired slots (a worker whose job channel disconnected).
    let mut idle: Vec<usize> = (0..replicas).rev().collect();
    let mut dead = 0usize;
    loop {
        let busy = replicas - idle.len() - dead;
        if shutdown.load(Ordering::SeqCst)
            && pending.is_empty()
            && batcher.pending() == 0
            && busy == 0
        {
            break;
        }
        // Sleep until the next flush deadline (or a short poll interval);
        // worker completions arrive on this same channel and wake us. With
        // every replica busy, deadlines can't be acted on anyway — wait on
        // messages instead of spinning on an expired deadline.
        let timeout = if idle.is_empty() {
            Duration::from_millis(50)
        } else {
            batcher
                .next_deadline()
                .map(|d| d.saturating_duration_since(Instant::now()))
                .unwrap_or(Duration::from_millis(10))
        };
        match rx.recv_timeout(timeout) {
            Ok(msg) => {
                handle_message(msg, &info, split, &mut batcher, &mut pending, &mut idle, &metrics)
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => {
                if pending.is_empty()
                    && batcher.pending() == 0
                    && replicas - idle.len() - dead == 0
                {
                    break;
                }
            }
        }
        // Drain without blocking to fill batches before dispatching.
        while let Ok(msg) = rx.try_recv() {
            handle_message(msg, &info, split, &mut batcher, &mut pending, &mut idle, &metrics);
        }
        // Dispatch released batches onto idle replicas.
        while !idle.is_empty() {
            let Some((kind, items)) = batcher.next_batch(Instant::now()) else { break };
            let worker = idle.pop().expect("checked non-empty");
            metrics.record_dispatch(worker, items.len(), lanes, batcher.pending());
            let job = EngineJob { kind, items, chunk_tokens: info.chunk_tokens };
            if let Err(failed) = job_txs[worker].send(job) {
                // Worker died. Fail the affected requests rather than
                // wedging them, and retire the slot so shutdown doesn't
                // wait for a completion that will never come.
                dead += 1;
                metrics.record_error();
                for item in failed.0.items {
                    if let Some(p) = pending.remove(&item.request_id) {
                        let _ = p
                            .respond
                            .send(Err(anyhow::anyhow!("engine worker {worker} died")));
                    }
                }
            }
        }
    }
    // Disconnect the workers and wait them out.
    drop(job_txs);
    for h in handles {
        let _ = h.join();
    }
}

fn handle_message(
    msg: ToScheduler,
    info: &EngineInfo,
    split: Split,
    batcher: &mut DynamicBatcher,
    pending: &mut HashMap<u64, Pending>,
    idle: &mut Vec<usize>,
    metrics: &Metrics,
) {
    match msg {
        ToScheduler::Request(req) => admit(req, info, split, batcher, pending, metrics),
        ToScheduler::Done(done) => {
            idle.push(done.worker);
            complete_batch(done, info, pending, metrics);
        }
    }
}

#[derive(Clone, Copy)]
struct Split {
    stream_bytes: usize,
    chunk_tokens: u32,
}

fn admit(
    req: Request,
    info: &EngineInfo,
    split: Split,
    batcher: &mut DynamicBatcher,
    pending: &mut HashMap<u64, Pending>,
    metrics: &Metrics,
) {
    let now = Instant::now();
    match req.op {
        Op::Compress(data) => {
            let chunks: Vec<&[u8]> = data.chunks(split.stream_bytes).collect();
            let n = chunks.len().max(1);
            let entry = Pending {
                respond: req.respond,
                started: req.started,
                kind: WorkKind::Compress,
                results: vec![None; n],
                remaining: n,
                chunk_sizes: chunks.iter().map(|c| c.len() as u32).collect(),
                orig_len: data.len() as u64,
                orig_crc: crc32(&data),
                container_chunk_tokens: split.chunk_tokens,
                bytes_in: data.len(),
            };
            if data.is_empty() {
                // Zero-chunk request: answer immediately with an empty
                // container carrying the REAL engine tag — `finish` never
                // sees this request, and decoding through
                // `LlmCompressor::decompress` requires the `model:flag` tag.
                let container = Container {
                    orig_len: 0,
                    orig_crc32: entry.orig_crc,
                    chunk_tokens: entry.container_chunk_tokens,
                    model_name: info.tag.clone(),
                    chunks: vec![],
                    payload: vec![],
                };
                metrics.record_request_op(WorkKind::Compress, 0, 0, entry.started.elapsed());
                let _ = entry.respond.send(Ok(container.to_bytes()));
                return;
            }
            pending.insert(req.id, entry);
            for (i, chunk) in chunks.iter().enumerate() {
                batcher.push(WorkItem {
                    request_id: req.id,
                    chunk_index: i as u32,
                    kind: WorkKind::Compress,
                    priority: req.priority,
                    data: chunk.to_vec(),
                    record: None,
                    enqueued: now,
                });
            }
        }
        Op::Decompress(bytes) => match Container::from_bytes(&bytes) {
            Err(e) => {
                let _ = req.respond.send(Err(e));
            }
            Ok(container) => {
                // Legacy exception: pre-fix servers stamped empty containers
                // with an empty tag; they carry no payload, so decoding them
                // stays valid on any engine.
                let legacy_empty = container.model_name.is_empty() && container.chunks.is_empty();
                if container.model_name != info.tag && !legacy_empty {
                    let _ = req.respond.send(Err(anyhow::anyhow!(
                        "container was produced by engine '{}', this server runs '{}'",
                        container.model_name,
                        info.tag
                    )));
                    return;
                }
                // Batches mix chunks from concurrent requests and the
                // engine decodes a whole batch with ONE context-window
                // size, so this server can only decode containers written
                // with its own chunk_tokens. Reject a mismatch up front —
                // otherwise it would surface as a baffling CRC failure.
                if container.chunk_tokens as usize != info.chunk_tokens
                    && !container.chunks.is_empty()
                {
                    let _ = req.respond.send(Err(anyhow::anyhow!(
                        "container was written with chunk_tokens={}, this server decodes with \
                         chunk_tokens={} — use a matching server or the offline CLI",
                        container.chunk_tokens,
                        info.chunk_tokens
                    )));
                    return;
                }
                let items: Vec<(ChunkRecord, Vec<u8>)> =
                    container.iter_chunks().map(|(r, p)| (r, p.to_vec())).collect();
                let n = items.len().max(1);
                let entry = Pending {
                    respond: req.respond,
                    started: req.started,
                    kind: WorkKind::Decompress,
                    results: vec![None; n],
                    remaining: items.len(),
                    chunk_sizes: vec![],
                    orig_len: container.orig_len,
                    orig_crc: container.orig_crc32,
                    container_chunk_tokens: container.chunk_tokens,
                    bytes_in: bytes.len(),
                };
                if items.is_empty() {
                    metrics.record_request_op(
                        WorkKind::Decompress,
                        entry.bytes_in,
                        0,
                        entry.started.elapsed(),
                    );
                    let _ = entry.respond.send(Ok(Vec::new()));
                    return;
                }
                pending.insert(req.id, entry);
                for (i, (rec, payload)) in items.into_iter().enumerate() {
                    batcher.push(WorkItem {
                        request_id: req.id,
                        chunk_index: i as u32,
                        kind: WorkKind::Decompress,
                        priority: req.priority,
                        data: payload,
                        record: Some(rec),
                        enqueued: now,
                    });
                }
            }
        },
    }
}

/// Fold a worker's completed batch back into per-request state.
fn complete_batch(
    done: BatchDone,
    info: &EngineInfo,
    pending: &mut HashMap<u64, Pending>,
    metrics: &Metrics,
) {
    match done.result {
        Err(e) => {
            // Fail every request that had a chunk in this batch.
            metrics.record_error();
            let msg = format!("batch failed: {e:#}");
            for item in done.items {
                if let Some(p) = pending.remove(&item.request_id) {
                    let _ = p.respond.send(Err(anyhow::anyhow!(msg.clone())));
                }
            }
        }
        Ok(outputs) => {
            for (item, out) in done.items.into_iter().zip(outputs) {
                let Some(p) = pending.get_mut(&item.request_id) else { continue };
                p.results[item.chunk_index as usize] = Some(out);
                p.remaining -= 1;
                if p.remaining == 0 {
                    let p = pending.remove(&item.request_id).unwrap();
                    finish(&info.tag, p, metrics);
                }
            }
        }
    }
}

fn finish(tag: &str, p: Pending, metrics: &Metrics) {
    let response: Result<Vec<u8>> = match p.kind {
        WorkKind::Compress => {
            let mut records = Vec::with_capacity(p.results.len());
            let mut payload = Vec::new();
            for (i, r) in p.results.iter().enumerate() {
                let bytes = r.as_ref().expect("all chunks done");
                records.push(ChunkRecord {
                    comp_len: bytes.len() as u32,
                    n_tokens: p.chunk_sizes[i],
                });
                payload.extend_from_slice(bytes);
            }
            Ok(Container {
                orig_len: p.orig_len,
                orig_crc32: p.orig_crc,
                chunk_tokens: p.container_chunk_tokens,
                model_name: tag.to_string(),
                chunks: records,
                payload,
            }
            .to_bytes())
        }
        WorkKind::Decompress => {
            let mut out = Vec::with_capacity(p.orig_len as usize);
            for r in &p.results {
                out.extend_from_slice(r.as_ref().expect("all chunks done"));
            }
            if out.len() as u64 != p.orig_len || crc32(&out) != p.orig_crc {
                Err(anyhow::anyhow!("decompressed output failed CRC/length verification"))
            } else {
                Ok(out)
            }
        }
    };
    let out_len = response.as_ref().map(|v| v.len()).unwrap_or(0);
    metrics.record_request_op(p.kind, p.bytes_in, out_len, p.started.elapsed());
    let _ = p.respond.send(response);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lm::config::by_name;
    use crate::lm::weights::Weights;

    fn test_server(chunk: usize, lanes: usize) -> Server {
        Server::start(
            move || {
                let cfg = by_name("nano").unwrap();
                LlmCompressor::from_weights(cfg, Weights::random(cfg, 21), chunk, lanes)
            },
            ServerConfig {
                chunk_tokens: chunk,
                policy: BatchPolicy { lanes, max_wait: Duration::from_millis(5) },
                ..Default::default()
            },
        )
        .unwrap()
    }

    #[test]
    fn roundtrip_through_server() {
        let server = test_server(32, 2);
        let data = crate::textgen::quick_sample(300, 9);
        let z = server.compress(&data).unwrap();
        let back = server.decompress(&z).unwrap();
        assert_eq!(back, data);
        assert!(server.metrics.requests.load(Ordering::Relaxed) >= 2);
        // Engine throughput is recorded per batch: every input byte is one
        // token on the compress pass and again on the decompress pass.
        assert_eq!(server.metrics.tokens.load(Ordering::Relaxed), 2 * data.len() as u64);
        assert!(server.metrics.mean_tokens_per_sec() > 0.0);
        // Both op latencies landed in the per-op histograms.
        assert!(server.metrics.latency_samples(WorkKind::Compress) >= 1);
        assert!(server.metrics.latency_samples(WorkKind::Decompress) >= 1);
        assert!(server.metrics.latency_percentile_ms(WorkKind::Decompress, 0.99) > 0.0);
    }

    #[test]
    fn lane_cap_limits_batch_width() {
        // Engine has 4 lanes but the server is configured to fill at most 2.
        let server = Server::start(
            move || {
                let cfg = by_name("nano").unwrap();
                LlmCompressor::from_weights(cfg, Weights::random(cfg, 22), 16, 4)
            },
            ServerConfig {
                chunk_tokens: 16,
                lanes: 2,
                policy: BatchPolicy { lanes: 8, max_wait: Duration::from_millis(2) },
                ..Default::default()
            },
        )
        .unwrap();
        // 6 chunks (stream granularity 64 bytes) -> at least 3 batches.
        let data = crate::textgen::quick_sample(6 * 64, 10);
        let z = server.compress(&data).unwrap();
        assert_eq!(server.decompress(&z).unwrap(), data);
        let batches = server.metrics.batches.load(Ordering::Relaxed);
        assert!(batches >= 3, "cap 2 lanes over 6 chunks needs >= 3 batches, got {batches}");
    }

    #[test]
    fn empty_request_roundtrips_and_carries_engine_tag() {
        let server = test_server(32, 2);
        let z = server.compress(b"").unwrap();
        // Regression: the empty container must carry the real engine tag
        // (it used to ship `model_name: ""`, which only the server's own
        // lenient path could decode).
        let container = Container::from_bytes(&z).unwrap();
        assert_eq!(container.model_name, "nano:0");
        assert_eq!(server.decompress(&z).unwrap(), b"");
    }

    #[test]
    fn legacy_untagged_empty_container_still_decodes() {
        // Pre-fix servers emitted empty containers with model_name: "";
        // they carry no payload, so the new tag check must let them pass.
        let server = test_server(32, 2);
        let legacy = Container {
            orig_len: 0,
            orig_crc32: crate::util::crc32(b""),
            chunk_tokens: 32,
            model_name: String::new(),
            chunks: vec![],
            payload: vec![],
        }
        .to_bytes();
        assert_eq!(server.decompress(&legacy).unwrap(), b"");
    }

    #[test]
    fn server_empty_container_decodes_through_compressor_path() {
        // The regression test for the zero-length-compress fix: a
        // server-produced empty container must decode through
        // `LlmCompressor::decompress`, which requires the `model:flag` tag.
        use crate::compress::Compressor;
        let server = test_server(32, 2);
        let z = server.compress(b"").unwrap();
        let cfg = by_name("nano").unwrap();
        let compressor =
            LlmCompressor::from_weights(cfg, Weights::random(cfg, 21), 32, 2).unwrap();
        assert_eq!(compressor.decompress(&z).unwrap(), b"");
    }

    #[test]
    fn concurrent_requests_share_batches() {
        let server = Arc::new(test_server(16, 4));
        let mut handles = Vec::new();
        for i in 0..6 {
            let s = server.clone();
            handles.push(std::thread::spawn(move || {
                let data = crate::textgen::quick_sample(120 + i * 13, i as u64);
                let z = s.compress(&data).unwrap();
                let back = s.decompress(&z).unwrap();
                assert_eq!(back, data);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // Cross-request batching should produce fewer batches than chunks.
        let batches = server.metrics.batches.load(Ordering::Relaxed);
        let chunks = server.metrics.chunks.load(Ordering::Relaxed);
        assert!(batches < chunks, "batches {batches} chunks {chunks}");
    }

    #[test]
    fn corrupt_container_rejected() {
        let server = test_server(32, 2);
        assert!(server.decompress(&[1, 2, 3]).is_err());
        let data = crate::textgen::quick_sample(400, 1);
        let mut z = server.compress(&data).unwrap();
        // Corrupt mid-payload (the tail bytes of a range-coded stream can be
        // flush slack, so flip bits well inside the payload).
        let n = z.len();
        for i in [n / 2, n / 2 + 1, 3 * n / 4] {
            z[i] ^= 0x55;
        }
        assert!(server.decompress(&z).is_err());
    }

    #[test]
    fn foreign_engine_container_rejected_early() {
        let server = test_server(32, 2);
        let data = crate::textgen::quick_sample(200, 2);
        let mut container = Container::from_bytes(&server.compress(&data).unwrap()).unwrap();
        container.model_name = "medium:0".into();
        let err = server.decompress(&container.to_bytes()).unwrap_err().to_string();
        assert!(err.contains("produced by engine"), "{err}");
    }

    #[test]
    fn mismatched_chunk_tokens_rejected_with_clear_error() {
        // Same engine, different context window: decoding would produce
        // garbage + a CRC failure, so the server refuses up front.
        let server = test_server(32, 2);
        let data = crate::textgen::quick_sample(200, 3);
        let mut container = Container::from_bytes(&server.compress(&data).unwrap()).unwrap();
        container.chunk_tokens = 16;
        let err = server.decompress(&container.to_bytes()).unwrap_err().to_string();
        assert!(err.contains("chunk_tokens"), "{err}");
    }

    #[test]
    fn replica_pool_serves_and_attributes_work() {
        let server = Arc::new(Server::start(
            move || {
                let cfg = by_name("nano").unwrap();
                LlmCompressor::from_weights(cfg, Weights::random(cfg, 23), 16, 2)
            },
            ServerConfig {
                chunk_tokens: 16,
                replicas: 3,
                policy: BatchPolicy { lanes: 2, max_wait: Duration::from_millis(2) },
                ..Default::default()
            },
        )
        .unwrap());
        assert_eq!(server.metrics.workers.len(), 3);
        let mut handles = Vec::new();
        for i in 0..6u64 {
            let s = server.clone();
            handles.push(std::thread::spawn(move || {
                let data = crate::textgen::quick_sample(400 + i as usize * 29, i);
                let z = s.compress(&data).unwrap();
                assert_eq!(s.decompress(&z).unwrap(), data);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(server.metrics.errors.load(Ordering::Relaxed), 0);
        let per_worker: Vec<u64> = server
            .metrics
            .workers
            .iter()
            .map(|w| w.batches.load(Ordering::Relaxed))
            .collect();
        let total: u64 = per_worker.iter().sum();
        assert_eq!(total, server.metrics.batches.load(Ordering::Relaxed));
        assert!(total > 0);
    }

    #[test]
    fn failed_factory_fails_startup() {
        let r = Server::start(
            || -> Result<LlmCompressor> { anyhow::bail!("no engine for you") },
            ServerConfig { replicas: 2, ..Default::default() },
        );
        assert!(r.is_err());
    }
}
