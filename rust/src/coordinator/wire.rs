//! TCP wire protocols for the compression service.
//!
//! Two protocols share one listening port; the first byte a client sends
//! picks the session kind ([`serve_connection`] auto-detects):
//!
//! ## v1 — serial request/response (legacy clients)
//! ```text
//! request:  op u8 (1=compress, 2=decompress) | len u32 | payload
//! response: status u8 (0=ok, 1=error)        | len u32 | payload/message
//! ```
//! One outstanding request per connection; the op byte is never `b'L'`,
//! which is how v1 stays distinguishable from the v2 handshake.
//!
//! ## v2 — multiplexed frames (one persistent connection, many requests)
//! The client opens with the 4-byte handshake `"LZMX"`, then both sides
//! exchange frames:
//! ```text
//! frame: type u8 | req_id u32 | len u32 | payload
//! ```
//! Client→server types: [`MSG_COMPRESS`], [`MSG_DECOMPRESS`],
//! [`MSG_COMPRESS_INTERACTIVE`], and the streaming trio
//! [`MSG_STREAM_OPEN`] / [`MSG_STREAM_CHUNK`] / [`MSG_STREAM_FINISH`]
//! (chunked payload upload: the server starts batching the moment the
//! first chunk lands, long before the input finishes arriving).
//! Server→client: [`MSG_OK`] / [`MSG_ERR`], tagged with the request id —
//! responses interleave in COMPLETION order, not submission order, which
//! is the whole point: a fast interactive op overtakes a bulk one on the
//! same socket instead of queueing behind it head-of-line.
//!
//! `req_id` is client-chosen and only needs to be unique among that
//! connection's in-flight requests. Every frame payload is capped at
//! [`MAX_PAYLOAD`]; beyond that, in-flight memory is bounded by what the
//! client chooses to submit before collecting responses (the scheduler
//! admits queued work eagerly, and each outstanding one-shot ticket is
//! parked on a waiter thread) — flow control across requests is the
//! client's job, exactly as with the thread-per-connection v1 protocol.
//!
//! The server side maps frames 1:1 onto the coordinator's ticketed API
//! ([`Server::submit_with`] / [`Server::open_stream`]); each ticket is
//! resolved on a small waiter thread that forwards the result to the
//! connection's single writer thread. [`MuxClient`] is the matching
//! client (used by tests, benches and examples); [`Client`] speaks v1.

use crate::coordinator::batcher::Priority;
use crate::coordinator::router::{Op, Server, StreamHandle};
use crate::Result;
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::mpsc::{channel, Sender};

/// v2 handshake bytes; the first (`b'L'`) doubles as the version sniff.
pub const V2_HANDSHAKE: [u8; 4] = *b"LZMX";

/// Hard cap on any single payload (request, chunk or response).
pub const MAX_PAYLOAD: usize = 256 << 20;

pub const MSG_COMPRESS: u8 = 1;
pub const MSG_DECOMPRESS: u8 = 2;
pub const MSG_COMPRESS_INTERACTIVE: u8 = 3;
pub const MSG_STREAM_OPEN: u8 = 0x10;
pub const MSG_STREAM_CHUNK: u8 = 0x11;
pub const MSG_STREAM_FINISH: u8 = 0x12;
pub const MSG_OK: u8 = 0x80;
pub const MSG_ERR: u8 = 0x81;

fn write_frame(w: &mut impl Write, typ: u8, req_id: u32, payload: &[u8]) -> Result<()> {
    w.write_all(&[typ])?;
    w.write_all(&req_id.to_le_bytes())?;
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

fn read_frame(r: &mut impl Read) -> Result<Option<(u8, u32, Vec<u8>)>> {
    let mut hdr = [0u8; 9];
    match r.read_exact(&mut hdr) {
        Ok(()) => {}
        // Clean EOF between frames ends the session.
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e.into()),
    }
    let typ = hdr[0];
    let req_id = u32::from_le_bytes(hdr[1..5].try_into().unwrap());
    let len = u32::from_le_bytes(hdr[5..9].try_into().unwrap()) as usize;
    if len > MAX_PAYLOAD {
        anyhow::bail!("frame too large: {len}");
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Ok(Some((typ, req_id, payload)))
}

/// Serve one TCP connection, auto-detecting the protocol from its first
/// byte. Returns when the client disconnects.
pub fn serve_connection(mut stream: TcpStream, server: &Server) -> Result<()> {
    let mut first = [0u8; 1];
    match stream.read_exact(&mut first) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(()),
        Err(e) => return Err(e.into()),
    }
    match first[0] {
        b if b == V2_HANDSHAKE[0] => {
            let mut rest = [0u8; 3];
            stream.read_exact(&mut rest)?;
            if rest != V2_HANDSHAKE[1..] {
                anyhow::bail!("bad protocol handshake");
            }
            serve_v2(stream, server)
        }
        op @ (MSG_COMPRESS | MSG_DECOMPRESS) => serve_v1(stream, server, Some(op)),
        other => anyhow::bail!("unknown protocol opening byte {other:#04x}"),
    }
}

/// The v1 serial loop. `first_op` is the already-consumed op byte of the
/// first request (protocol sniffing ate it).
fn serve_v1(mut stream: TcpStream, server: &Server, mut first_op: Option<u8>) -> Result<()> {
    loop {
        let op = match first_op.take() {
            Some(op) => op,
            None => {
                let mut b = [0u8; 1];
                match stream.read_exact(&mut b) {
                    Ok(()) => b[0],
                    Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(()),
                    Err(e) => return Err(e.into()),
                }
            }
        };
        let mut lenb = [0u8; 4];
        stream.read_exact(&mut lenb)?;
        let len = u32::from_le_bytes(lenb) as usize;
        if len > MAX_PAYLOAD {
            anyhow::bail!("request too large: {len}");
        }
        let mut payload = vec![0u8; len];
        stream.read_exact(&mut payload)?;
        let result = match op {
            MSG_COMPRESS => server.compress(&payload),
            MSG_DECOMPRESS => server.decompress(&payload),
            other => Err(anyhow::anyhow!("unknown op {other}")),
        };
        match result {
            Ok(data) => {
                stream.write_all(&[0u8])?;
                stream.write_all(&(data.len() as u32).to_le_bytes())?;
                stream.write_all(&data)?;
            }
            Err(e) => {
                let msg = format!("{e:#}");
                stream.write_all(&[1u8])?;
                stream.write_all(&(msg.len() as u32).to_le_bytes())?;
                stream.write_all(msg.as_bytes())?;
            }
        }
        stream.flush()?;
    }
}

/// One connection's response path: completions (from waiter threads) are
/// serialized by a single writer thread, so interleaved tickets never
/// corrupt the frame stream.
type RespSender = Sender<(u32, Result<Vec<u8>>)>;

fn spawn_waiter(resp: &RespSender, req_id: u32, ticket: crate::coordinator::router::Ticket) {
    let tx = resp.clone();
    std::thread::spawn(move || {
        // The connection may be gone by completion time; nothing to do.
        let _ = tx.send((req_id, ticket.wait()));
    });
}

/// The v2 multiplexed loop.
fn serve_v2(stream: TcpStream, server: &Server) -> Result<()> {
    let mut reader = stream.try_clone()?;
    let (resp_tx, resp_rx) = channel::<(u32, Result<Vec<u8>>)>();
    let writer = std::thread::spawn(move || -> Result<()> {
        let mut stream = stream;
        for (req_id, result) in resp_rx {
            match result {
                Ok(data) => write_frame(&mut stream, MSG_OK, req_id, &data)?,
                Err(e) => write_frame(&mut stream, MSG_ERR, req_id, format!("{e:#}").as_bytes())?,
            }
        }
        Ok(())
    });
    let served = v2_reader_loop(&mut reader, server, &resp_tx);
    // EOF (or a read error): open uploads were dropped by the loop (their
    // Drop aborts the server-side session); let in-flight waiters drain
    // into the writer, then take the writer down once the last sender is
    // gone.
    drop(resp_tx);
    let write_result = writer.join().unwrap_or_else(|_| Err(anyhow::anyhow!("writer panicked")));
    served?;
    write_result
}

/// The v2 reader half: frames in, tickets + waiter threads out. Returns
/// on client EOF; open upload sessions are dropped (= aborted) with it.
fn v2_reader_loop(reader: &mut TcpStream, server: &Server, resp_tx: &RespSender) -> Result<()> {
    // Open upload sessions by client-chosen request id.
    let mut streams: HashMap<u32, StreamHandle> = HashMap::new();
    while let Some((typ, req_id, payload)) = read_frame(reader)? {
        match typ {
            MSG_COMPRESS => {
                spawn_waiter(
                    resp_tx,
                    req_id,
                    server.submit_with(Op::Compress(payload), Priority::Bulk)?,
                );
            }
            MSG_COMPRESS_INTERACTIVE => {
                spawn_waiter(
                    resp_tx,
                    req_id,
                    server.submit_with(Op::Compress(payload), Priority::Interactive)?,
                );
            }
            MSG_DECOMPRESS => {
                spawn_waiter(
                    resp_tx,
                    req_id,
                    server.submit_with(Op::Decompress(payload), Priority::Interactive)?,
                );
            }
            MSG_STREAM_OPEN => {
                if streams.contains_key(&req_id) {
                    let _ = resp_tx
                        .send((req_id, Err(anyhow::anyhow!("stream {req_id} already open"))));
                } else {
                    streams.insert(req_id, server.open_stream()?);
                }
            }
            MSG_STREAM_CHUNK => match streams.get_mut(&req_id) {
                Some(handle) => {
                    if let Err(e) = handle.write_bytes(&payload) {
                        streams.remove(&req_id);
                        let _ = resp_tx.send((req_id, Err(e)));
                    }
                }
                None => {
                    let _ = resp_tx
                        .send((req_id, Err(anyhow::anyhow!("stream {req_id} is not open"))));
                }
            },
            MSG_STREAM_FINISH => match streams.remove(&req_id) {
                Some(handle) => spawn_waiter(resp_tx, req_id, handle.finish()?),
                None => {
                    let _ = resp_tx
                        .send((req_id, Err(anyhow::anyhow!("stream {req_id} is not open"))));
                }
            },
            other => {
                let _ = resp_tx
                    .send((req_id, Err(anyhow::anyhow!("unknown frame type {other:#04x}"))));
            }
        }
    }
    Ok(())
}

/// Minimal v1 client (kept for protocol back-compat and as the
/// auto-detect regression fixture).
pub struct Client {
    stream: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        Ok(Client { stream: TcpStream::connect(addr)? })
    }

    fn call(&mut self, op: u8, payload: &[u8]) -> Result<Vec<u8>> {
        self.stream.write_all(&[op])?;
        self.stream.write_all(&(payload.len() as u32).to_le_bytes())?;
        self.stream.write_all(payload)?;
        self.stream.flush()?;
        let mut hdr = [0u8; 5];
        self.stream.read_exact(&mut hdr)?;
        let len = u32::from_le_bytes(hdr[1..5].try_into().unwrap()) as usize;
        let mut data = vec![0u8; len];
        self.stream.read_exact(&mut data)?;
        if hdr[0] != 0 {
            anyhow::bail!("server error: {}", String::from_utf8_lossy(&data));
        }
        Ok(data)
    }

    pub fn compress(&mut self, data: &[u8]) -> Result<Vec<u8>> {
        self.call(MSG_COMPRESS, data)
    }

    pub fn decompress(&mut self, data: &[u8]) -> Result<Vec<u8>> {
        self.call(MSG_DECOMPRESS, data)
    }
}

/// v2 multiplexed client: submit any number of operations, then collect
/// responses (in completion order) with [`MuxClient::recv`].
pub struct MuxClient {
    stream: TcpStream,
    next_id: u32,
}

impl MuxClient {
    pub fn connect(addr: &str) -> Result<MuxClient> {
        let mut stream = TcpStream::connect(addr)?;
        stream.write_all(&V2_HANDSHAKE)?;
        stream.flush()?;
        Ok(MuxClient { stream, next_id: 1 })
    }

    fn alloc_id(&mut self) -> u32 {
        let id = self.next_id;
        self.next_id = self.next_id.wrapping_add(1).max(1);
        id
    }

    fn send(&mut self, typ: u8, req_id: u32, payload: &[u8]) -> Result<()> {
        write_frame(&mut self.stream, typ, req_id, payload)
    }

    /// Submit a bulk compress; returns the request id to match in
    /// [`Self::recv`].
    pub fn submit_compress(&mut self, data: &[u8]) -> Result<u32> {
        let id = self.alloc_id();
        self.send(MSG_COMPRESS, id, data)?;
        Ok(id)
    }

    /// Submit an interactive-priority compress.
    pub fn submit_compress_interactive(&mut self, data: &[u8]) -> Result<u32> {
        let id = self.alloc_id();
        self.send(MSG_COMPRESS_INTERACTIVE, id, data)?;
        Ok(id)
    }

    /// Submit a decompress.
    pub fn submit_decompress(&mut self, data: &[u8]) -> Result<u32> {
        let id = self.alloc_id();
        self.send(MSG_DECOMPRESS, id, data)?;
        Ok(id)
    }

    /// Open a chunked-upload compression stream; feed it with
    /// [`Self::stream_chunk`] and seal it with [`Self::stream_finish`]
    /// (the response to the returned id is the finished container).
    pub fn open_stream(&mut self) -> Result<u32> {
        let id = self.alloc_id();
        self.send(MSG_STREAM_OPEN, id, &[])?;
        Ok(id)
    }

    /// Upload one piece of a stream's input (any size; the server re-cuts
    /// at its engine granularity).
    pub fn stream_chunk(&mut self, id: u32, data: &[u8]) -> Result<()> {
        self.send(MSG_STREAM_CHUNK, id, data)
    }

    pub fn stream_finish(&mut self, id: u32) -> Result<()> {
        self.send(MSG_STREAM_FINISH, id, &[])
    }

    /// Receive the next response frame: `(request id, result)`. Responses
    /// arrive in completion order — the caller matches ids.
    pub fn recv(&mut self) -> Result<(u32, Result<Vec<u8>>)> {
        let Some((typ, req_id, payload)) = read_frame(&mut self.stream)? else {
            anyhow::bail!("server closed the connection");
        };
        match typ {
            MSG_OK => Ok((req_id, Ok(payload))),
            MSG_ERR => Ok((
                req_id,
                Err(anyhow::anyhow!("server error: {}", String::from_utf8_lossy(&payload))),
            )),
            other => anyhow::bail!("unexpected response frame type {other:#04x}"),
        }
    }
}
