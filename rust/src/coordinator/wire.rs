//! TCP wire protocols for the compression service.
//!
//! Two protocols share one listening port; the first byte a client sends
//! picks the session kind ([`serve_connection`] auto-detects). The server
//! side of both speaks through the [`WireService`] seam, so one accept
//! loop serves a single-model [`crate::coordinator::Server`] or a
//! multi-model [`crate::coordinator::FleetServer`] identically.
//!
//! ## v1 — serial request/response (legacy clients)
//! ```text
//! request:  op u8 (1=compress, 2=decompress) | len u32 | payload
//! response: status u8 (0=ok, 1=error)        | len u32 | payload/message
//! ```
//! One outstanding request per connection; the op byte is never `b'L'`,
//! which is how v1 stays distinguishable from the v2 handshake.
//!
//! ## v2 — multiplexed frames (one persistent connection, many requests)
//! The client opens with the 4-byte handshake `"LZMX"`, then both sides
//! exchange frames:
//! ```text
//! frame: type u8 | req_id u32 | len u32 | payload
//! ```
//! Client→server types: [`MSG_COMPRESS`], [`MSG_DECOMPRESS`],
//! [`MSG_COMPRESS_INTERACTIVE`], the streaming trio [`MSG_STREAM_OPEN`]
//! / [`MSG_STREAM_CHUNK`] / [`MSG_STREAM_FINISH`] (chunked payload
//! upload: the server starts batching the moment the first chunk lands),
//! and the fleet pair [`MSG_SET_TENANT`] (bind the connection's QoS
//! identity) / [`MSG_COMPRESS_TAGGED`] (compress routed to a named model
//! pool; `MSG_STREAM_OPEN`'s payload optionally carries the same route
//! key). Server→client: [`MSG_OK`] / [`MSG_ERR`], tagged with the
//! request id — responses interleave in COMPLETION order, not submission
//! order, which is the whole point: a fast interactive op overtakes a
//! bulk one on the same socket instead of queueing behind it
//! head-of-line. Admission failures (unknown route, tenant rate limit,
//! fleet load shed) come back as ordinary [`MSG_ERR`] frames — the
//! connection survives them.
//!
//! `req_id` is client-chosen and only needs to be unique among that
//! connection's in-flight requests ([`MuxClient`] enforces exactly that —
//! see [`IdAlloc`]). Every frame payload is capped at [`MAX_PAYLOAD`],
//! and every WRITE path validates its length before emitting a single
//! header byte: a payload the u32 length field cannot carry is refused
//! with a clear error, never silently truncated into a corrupt frame.
//! Beyond that, in-flight memory is bounded by what the client chooses
//! to submit before collecting responses — flow control across requests
//! is the client's job, exactly as with the thread-per-connection v1
//! protocol.
//!
//! The server side maps frames 1:1 onto the service's ticketed API; each
//! ticket is resolved on a small waiter thread that forwards the result
//! to the connection's single writer thread. [`MuxClient`] is the
//! matching client (used by tests, benches and examples); [`Client`]
//! speaks v1.

use crate::coordinator::batcher::Priority;
use crate::coordinator::fleet::{WireService, WireTicket};
use crate::coordinator::router::Op;
use crate::util::{BytePool, PooledBuf};
use crate::Result;
use std::collections::{HashMap, HashSet};
use std::io::{IoSlice, Read, Write};
use std::net::TcpStream;
use std::sync::mpsc::{channel, Sender};

/// v2 handshake bytes; the first (`b'L'`) doubles as the version sniff.
pub const V2_HANDSHAKE: [u8; 4] = *b"LZMX";

/// Hard cap on any single payload (request, chunk or response).
pub const MAX_PAYLOAD: usize = 256 << 20;

/// Up-front reservation cap for an incoming frame payload. Reads grow
/// the buffer adaptively beyond this with what actually arrives, so a
/// lying length header cannot force a [`MAX_PAYLOAD`]-sized allocation
/// out of a 9-byte frame header.
const FRAME_PREALLOC: usize = 64 << 10;

pub const MSG_COMPRESS: u8 = 1;
pub const MSG_DECOMPRESS: u8 = 2;
pub const MSG_COMPRESS_INTERACTIVE: u8 = 3;
pub const MSG_STREAM_OPEN: u8 = 0x10;
pub const MSG_STREAM_CHUNK: u8 = 0x11;
pub const MSG_STREAM_FINISH: u8 = 0x12;
/// Bind the connection's tenant identity (payload: UTF-8 tenant name;
/// empty = the anonymous default). Acked with an empty [`MSG_OK`].
pub const MSG_SET_TENANT: u8 = 0x20;
/// Compress routed to a model pool. Payload: `priority u8 (0=bulk,
/// 1=interactive) | key_len u16 LE | route key | data`.
pub const MSG_COMPRESS_TAGGED: u8 = 0x21;
pub const MSG_OK: u8 = 0x80;
pub const MSG_ERR: u8 = 0x81;

/// Validate a payload length against the u32 frame field and the
/// protocol cap BEFORE any header byte reaches the wire. The old
/// `payload.len() as u32` silently truncated at 4 GiB, emitting a frame
/// whose length field lied — the peer would misparse every byte after
/// it. Refusing up front keeps the stream parseable: the caller turns
/// the error into a response the peer can read.
fn check_wire_len(len: usize) -> Result<u32> {
    if len > MAX_PAYLOAD {
        anyhow::bail!("payload too large for wire frame: {len} bytes (cap {MAX_PAYLOAD})");
    }
    // lint: allow(L2) the sanctioned truncation point; bounds-checked above
    Ok(len as u32)
}

/// Validate a route-key length against the u16 field of the tagged-frame
/// payload. Same contract as [`check_wire_len`], one field narrower.
fn check_key_len(len: usize) -> Result<u16> {
    if len > u16::MAX as usize {
        anyhow::bail!("route key too long for the tagged frame ({len} bytes)");
    }
    // lint: allow(L2) the sanctioned truncation point; bounds-checked above
    Ok(len as u16)
}

/// Cap an error message to something the frame can always carry. Byte
/// truncation may split a UTF-8 sequence; receivers render lossily.
fn error_payload(e: &anyhow::Error) -> Vec<u8> {
    let mut msg = format!("{e:#}").into_bytes();
    msg.truncate(MAX_PAYLOAD);
    msg
}

/// Write one frame (header + payload) with vectored I/O and NO flush.
/// The 9-byte header and the payload reach the kernel in a single
/// `write_vectored` call in the common case, instead of the four
/// `write_all` round-trips the old encoder made. The manual advance
/// loop keeps this on stable Rust (`Write::write_all_vectored` is
/// unstable) and handles short writes byte-exactly.
fn write_frame_vectored(w: &mut impl Write, typ: u8, req_id: u32, payload: &[u8]) -> Result<()> {
    let len = check_wire_len(payload.len())?;
    let mut hdr = [0u8; 9];
    hdr[0] = typ;
    hdr[1..5].copy_from_slice(&req_id.to_le_bytes());
    hdr[5..9].copy_from_slice(&len.to_le_bytes());
    let mut hpos = 0usize; // bytes of the header already written
    let mut ppos = 0usize; // bytes of the payload already written
    while hpos < hdr.len() || ppos < payload.len() {
        let res = if hpos < hdr.len() {
            w.write_vectored(&[IoSlice::new(&hdr[hpos..]), IoSlice::new(payload)])
        } else {
            w.write(&payload[ppos..])
        };
        let n = match res {
            Ok(0) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::WriteZero,
                    "failed to write whole frame",
                )
                .into());
            }
            Ok(n) => n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        };
        if hpos < hdr.len() {
            let hdr_left = hdr.len() - hpos;
            if n >= hdr_left {
                hpos = hdr.len();
                ppos = n - hdr_left;
            } else {
                hpos += n;
            }
        } else {
            ppos += n;
        }
    }
    Ok(())
}

/// Frame write for request/response endpoints that need the frame on
/// the wire now: vectored write + flush. The v2 server writer thread
/// deliberately does NOT use this — it flushes once per wakeup, not per
/// frame (see [`serve_v2`]).
fn write_frame(w: &mut impl Write, typ: u8, req_id: u32, payload: &[u8]) -> Result<()> {
    write_frame_vectored(w, typ, req_id, payload)?;
    w.flush()?;
    Ok(())
}

/// Read one frame into a pool-recycled buffer. Allocation is bounded by
/// what the connection actually delivers: the declared length only caps
/// the read, it does not size an up-front buffer, so a peer declaring
/// 256 MB and sending 10 bytes costs ~10 bytes, then errors.
fn read_frame(r: &mut impl Read, pool: &BytePool) -> Result<Option<(u8, u32, PooledBuf)>> {
    let mut hdr = [0u8; 9];
    match r.read_exact(&mut hdr) {
        Ok(()) => {}
        // Clean EOF between frames ends the session.
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e.into()),
    }
    let typ = hdr[0];
    let req_id = u32::from_le_bytes(hdr[1..5].try_into().unwrap());
    let len = u32::from_le_bytes(hdr[5..9].try_into().unwrap()) as usize;
    if len > MAX_PAYLOAD {
        anyhow::bail!("frame too large: {len}");
    }
    let mut payload = pool.take(len.min(FRAME_PREALLOC));
    let got = (&mut *r).take(len as u64).read_to_end(&mut payload)?;
    if got < len {
        anyhow::bail!("connection ended after {got} of {len} declared payload bytes");
    }
    Ok(Some((typ, req_id, payload)))
}

/// Serve one TCP connection, auto-detecting the protocol from its first
/// byte. Returns when the client disconnects. `service` is either a
/// single-model `Server` or a `FleetServer` (both coerce).
pub fn serve_connection(mut stream: TcpStream, service: &dyn WireService) -> Result<()> {
    let mut first = [0u8; 1];
    match stream.read_exact(&mut first) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(()),
        Err(e) => return Err(e.into()),
    }
    match first[0] {
        b if b == V2_HANDSHAKE[0] => {
            let mut rest = [0u8; 3];
            stream.read_exact(&mut rest)?;
            if rest != V2_HANDSHAKE[1..] {
                anyhow::bail!("bad protocol handshake");
            }
            serve_v2(stream, service)
        }
        op @ (MSG_COMPRESS | MSG_DECOMPRESS) => serve_v1(stream, service, Some(op)),
        other => anyhow::bail!("unknown protocol opening byte {other:#04x}"),
    }
}

/// The v1 serial loop. `first_op` is the already-consumed op byte of the
/// first request (protocol sniffing ate it). v1 predates tenancy and
/// routing: requests run as the anonymous tenant on the default route
/// (decompress still routes by the container's own tag on a fleet).
fn serve_v1(
    mut stream: TcpStream,
    service: &dyn WireService,
    mut first_op: Option<u8>,
) -> Result<()> {
    loop {
        let op = match first_op.take() {
            Some(op) => op,
            None => {
                let mut b = [0u8; 1];
                match stream.read_exact(&mut b) {
                    Ok(()) => b[0],
                    Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(()),
                    Err(e) => return Err(e.into()),
                }
            }
        };
        let mut lenb = [0u8; 4];
        stream.read_exact(&mut lenb)?;
        let len = u32::from_le_bytes(lenb) as usize;
        if len > MAX_PAYLOAD {
            anyhow::bail!("request too large: {len}");
        }
        // Same bounded-allocation discipline as the v2 frame reader.
        let mut payload = service.wire_pool().take(len.min(FRAME_PREALLOC));
        let got = (&mut stream).take(len as u64).read_to_end(&mut payload)?;
        if got < len {
            anyhow::bail!("connection ended after {got} of {len} declared payload bytes");
        }
        let result = match op {
            MSG_COMPRESS => service
                .submit_wire(0, None, Op::Compress(payload), Priority::Bulk)
                .and_then(WireTicket::wait),
            MSG_DECOMPRESS => service
                .submit_wire(0, None, Op::Decompress(payload), Priority::Interactive)
                .and_then(WireTicket::wait),
            other => Err(anyhow::anyhow!("unknown op {other}")),
        };
        // A result too large for the u32 length field becomes the error
        // reply — never a truncated frame the client would misparse.
        let result = result.and_then(|data| check_wire_len(data.len()).map(|_| data));
        match result {
            Ok(data) => {
                stream.write_all(&[0u8])?;
                stream.write_all(&check_wire_len(data.len())?.to_le_bytes())?;
                stream.write_all(&data)?;
            }
            Err(e) => {
                let msg = error_payload(&e);
                stream.write_all(&[1u8])?;
                stream.write_all(&check_wire_len(msg.len())?.to_le_bytes())?;
                stream.write_all(&msg)?;
            }
        }
        stream.flush()?;
    }
}

/// One connection's response path: completions (from waiter threads) are
/// serialized by a single writer thread, so interleaved tickets never
/// corrupt the frame stream.
type RespSender = Sender<(u32, Result<Vec<u8>>)>;

fn spawn_waiter(resp: &RespSender, req_id: u32, ticket: WireTicket) {
    let tx = resp.clone();
    std::thread::spawn(move || {
        // The connection may be gone by completion time; nothing to do.
        let _ = tx.send((req_id, ticket.wait()));
    });
}

/// Submit one routed op; admission errors become error frames for THIS
/// request instead of tearing the connection down.
fn submit(
    service: &dyn WireService,
    resp_tx: &RespSender,
    tenant: u32,
    route: Option<&str>,
    req_id: u32,
    op: Op,
    priority: Priority,
) {
    match service.submit_wire(tenant, route, op, priority) {
        Ok(ticket) => spawn_waiter(resp_tx, req_id, ticket),
        Err(e) => {
            let _ = resp_tx.send((req_id, Err(e)));
        }
    }
}

/// Parse a [`MSG_COMPRESS_TAGGED`] payload: `priority u8 | key_len u16 LE
/// | route key | data`. The data tail is copied into a pool buffer (the
/// route prefix cannot be sliced off a `PooledBuf` in place).
fn parse_tagged(pool: &BytePool, payload: &[u8]) -> Result<(Priority, String, PooledBuf)> {
    if payload.len() < 3 {
        anyhow::bail!("tagged compress frame too short for its header");
    }
    let priority = match payload[0] {
        0 => Priority::Bulk,
        1 => Priority::Interactive,
        other => anyhow::bail!("bad priority byte {other} in tagged compress frame"),
    };
    let klen = u16::from_le_bytes([payload[1], payload[2]]) as usize;
    let key = payload
        .get(3..3 + klen)
        .ok_or_else(|| anyhow::anyhow!("tagged compress frame truncated inside its route key"))?;
    let key = std::str::from_utf8(key)
        .map_err(|_| anyhow::anyhow!("route key is not UTF-8"))?
        .to_string();
    let rest = &payload[3 + klen..];
    let mut data = pool.take(rest.len());
    data.extend_from_slice(rest);
    Ok((priority, key, data))
}

/// The v2 multiplexed loop.
fn serve_v2(stream: TcpStream, service: &dyn WireService) -> Result<()> {
    let mut reader = stream.try_clone()?;
    let (resp_tx, resp_rx) = channel::<(u32, Result<Vec<u8>>)>();
    let writer = std::thread::spawn(move || -> Result<()> {
        let mut stream = stream;
        // Flush once per WAKEUP, not per frame: block for one
        // completion, then drain everything else already queued before
        // touching flush. Under load many response frames ride one
        // flush; when idle this degrades to flush-per-frame, which is
        // the latency-optimal case anyway.
        while let Ok(mut next) = resp_rx.recv() {
            loop {
                let (req_id, result) = next;
                // An oversize result cannot be framed — downgrade it to
                // this request's error frame, keeping the stream intact.
                let result = result.and_then(|data| check_wire_len(data.len()).map(|_| data));
                match result {
                    Ok(data) => write_frame_vectored(&mut stream, MSG_OK, req_id, &data)?,
                    Err(e) => {
                        write_frame_vectored(&mut stream, MSG_ERR, req_id, &error_payload(&e))?
                    }
                }
                match resp_rx.try_recv() {
                    Ok(m) => next = m,
                    Err(_) => break,
                }
            }
            stream.flush()?;
        }
        Ok(())
    });
    let served = v2_reader_loop(&mut reader, service, &resp_tx);
    // EOF (or a read error): open uploads were dropped by the loop (their
    // Drop aborts the server-side session); let in-flight waiters drain
    // into the writer, then take the writer down once the last sender is
    // gone.
    drop(resp_tx);
    let write_result = writer.join().unwrap_or_else(|_| Err(anyhow::anyhow!("writer panicked")));
    served?;
    write_result
}

/// The v2 reader half: frames in, tickets + waiter threads out. Returns
/// on client EOF; open upload sessions are dropped (= aborted) with it.
/// Per-request failures — admission, routing, rate limits, shedding —
/// are answered with [`MSG_ERR`] and the connection lives on.
fn v2_reader_loop(
    reader: &mut TcpStream,
    service: &dyn WireService,
    resp_tx: &RespSender,
) -> Result<()> {
    // Open upload sessions by client-chosen request id.
    let mut streams: HashMap<u32, crate::coordinator::fleet::WireStream> = HashMap::new();
    // The connection's bound tenant (MSG_SET_TENANT); 0 = anonymous.
    let mut tenant: u32 = 0;
    while let Some((typ, req_id, payload)) = read_frame(reader, service.wire_pool())? {
        match typ {
            MSG_SET_TENANT => {
                let bound = std::str::from_utf8(&payload)
                    .map_err(|_| anyhow::anyhow!("tenant name is not UTF-8"))
                    .and_then(|name| service.bind_tenant(name));
                match bound {
                    Ok(id) => {
                        tenant = id;
                        let _ = resp_tx.send((req_id, Ok(Vec::new())));
                    }
                    Err(e) => {
                        let _ = resp_tx.send((req_id, Err(e)));
                    }
                }
            }
            MSG_COMPRESS => {
                submit(service, resp_tx, tenant, None, req_id, Op::Compress(payload), Priority::Bulk)
            }
            MSG_COMPRESS_INTERACTIVE => submit(
                service,
                resp_tx,
                tenant,
                None,
                req_id,
                Op::Compress(payload),
                Priority::Interactive,
            ),
            MSG_DECOMPRESS => submit(
                service,
                resp_tx,
                tenant,
                None,
                req_id,
                Op::Decompress(payload),
                Priority::Interactive,
            ),
            MSG_COMPRESS_TAGGED => match parse_tagged(service.wire_pool(), &payload) {
                Ok((priority, route, data)) => submit(
                    service,
                    resp_tx,
                    tenant,
                    Some(&route),
                    req_id,
                    Op::Compress(data),
                    priority,
                ),
                Err(e) => {
                    let _ = resp_tx.send((req_id, Err(e)));
                }
            },
            MSG_STREAM_OPEN => {
                if streams.contains_key(&req_id) {
                    let _ = resp_tx
                        .send((req_id, Err(anyhow::anyhow!("stream {req_id} already open"))));
                    continue;
                }
                // Optional payload: a route key for fleet endpoints.
                let opened = std::str::from_utf8(&payload)
                    .map_err(|_| anyhow::anyhow!("stream route key is not UTF-8"))
                    .and_then(|route| {
                        let route = (!route.is_empty()).then_some(route);
                        service.open_wire_stream(tenant, route)
                    });
                match opened {
                    Ok(handle) => {
                        streams.insert(req_id, handle);
                    }
                    Err(e) => {
                        let _ = resp_tx.send((req_id, Err(e)));
                    }
                }
            }
            MSG_STREAM_CHUNK => match streams.get_mut(&req_id) {
                Some(handle) => {
                    if let Err(e) = handle.write_bytes(&payload) {
                        streams.remove(&req_id);
                        let _ = resp_tx.send((req_id, Err(e)));
                    }
                }
                None => {
                    let _ = resp_tx
                        .send((req_id, Err(anyhow::anyhow!("stream {req_id} is not open"))));
                }
            },
            MSG_STREAM_FINISH => match streams.remove(&req_id) {
                Some(handle) => match handle.finish() {
                    Ok(ticket) => spawn_waiter(resp_tx, req_id, ticket),
                    Err(e) => {
                        let _ = resp_tx.send((req_id, Err(e)));
                    }
                },
                None => {
                    let _ = resp_tx
                        .send((req_id, Err(anyhow::anyhow!("stream {req_id} is not open"))));
                }
            },
            other => {
                let _ = resp_tx
                    .send((req_id, Err(anyhow::anyhow!("unknown frame type {other:#04x}"))));
            }
        }
    }
    Ok(())
}

/// Minimal v1 client (kept for protocol back-compat and as the
/// auto-detect regression fixture).
pub struct Client {
    stream: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        Ok(Client { stream: TcpStream::connect(addr)? })
    }

    fn call(&mut self, op: u8, payload: &[u8]) -> Result<Vec<u8>> {
        let len = check_wire_len(payload.len())?;
        self.stream.write_all(&[op])?;
        self.stream.write_all(&len.to_le_bytes())?;
        self.stream.write_all(payload)?;
        self.stream.flush()?;
        let mut hdr = [0u8; 5];
        self.stream.read_exact(&mut hdr)?;
        let len = u32::from_le_bytes(hdr[1..5].try_into().unwrap()) as usize;
        let mut data = vec![0u8; len];
        self.stream.read_exact(&mut data)?;
        if hdr[0] != 0 {
            anyhow::bail!("server error: {}", String::from_utf8_lossy(&data));
        }
        Ok(data)
    }

    pub fn compress(&mut self, data: &[u8]) -> Result<Vec<u8>> {
        self.call(MSG_COMPRESS, data)
    }

    pub fn decompress(&mut self, data: &[u8]) -> Result<Vec<u8>> {
        self.call(MSG_DECOMPRESS, data)
    }
}

/// Request-id allocator for [`MuxClient`]. Ids must be unique among the
/// connection's IN-FLIGHT requests — the server tags responses with
/// them, so a duplicate cross-wires two answers. A bare wrapping counter
/// breaks that guarantee after 2^32 requests on a long-lived connection;
/// this allocator tracks live ids, skips them at the wrap, and refuses
/// (with a clear reconnect error) in the pathological case of every id
/// being in flight at once. Id 0 is never handed out (reserved, matching
/// the legacy allocator's behavior).
struct IdAlloc {
    next: u32,
    live: HashSet<u32>,
}

impl IdAlloc {
    fn new() -> IdAlloc {
        IdAlloc { next: 1, live: HashSet::new() }
    }

    fn alloc(&mut self) -> Result<u32> {
        if self.live.len() >= u32::MAX as usize {
            anyhow::bail!("all request ids are in flight on this connection — reconnect");
        }
        loop {
            let id = self.next;
            // Wrap past u32::MAX straight to 1, skipping the reserved 0.
            self.next = self.next.wrapping_add(1).max(1);
            if self.live.insert(id) {
                return Ok(id);
            }
        }
    }

    fn release(&mut self, id: u32) {
        self.live.remove(&id);
    }
}

/// v2 multiplexed client: submit any number of operations, then collect
/// responses (in completion order) with [`MuxClient::recv`].
pub struct MuxClient {
    stream: TcpStream,
    ids: IdAlloc,
    /// Client responses are handed to the caller as plain `Vec<u8>`
    /// (public API), so recycling buys nothing here; a disabled pool
    /// keeps [`read_frame`]'s bounded-read path shared with the server.
    pool: BytePool,
}

impl MuxClient {
    pub fn connect(addr: &str) -> Result<MuxClient> {
        let mut stream = TcpStream::connect(addr)?;
        stream.write_all(&V2_HANDSHAKE)?;
        stream.flush()?;
        Ok(MuxClient { stream, ids: IdAlloc::new(), pool: BytePool::disabled() })
    }

    fn send(&mut self, typ: u8, req_id: u32, payload: &[u8]) -> Result<()> {
        write_frame(&mut self.stream, typ, req_id, payload)
    }

    /// Bind this connection's tenant identity; later submissions ride
    /// that tenant's QoS lane server-side. Synchronous: waits for the
    /// server's ack, so call it BEFORE submitting other work (an
    /// interleaved completion would be misread as the ack).
    pub fn set_tenant(&mut self, name: &str) -> Result<()> {
        let id = self.ids.alloc()?;
        self.send(MSG_SET_TENANT, id, name.as_bytes())?;
        let (rid, result) = self.recv()?;
        if rid != id {
            anyhow::bail!(
                "response {rid} interleaved with tenant handshake {id} — bind the tenant \
                 before submitting work"
            );
        }
        result.map(|_| ())
    }

    /// Submit a bulk compress; returns the request id to match in
    /// [`Self::recv`].
    pub fn submit_compress(&mut self, data: &[u8]) -> Result<u32> {
        let id = self.ids.alloc()?;
        self.send(MSG_COMPRESS, id, data)?;
        Ok(id)
    }

    /// Submit an interactive-priority compress.
    pub fn submit_compress_interactive(&mut self, data: &[u8]) -> Result<u32> {
        let id = self.ids.alloc()?;
        self.send(MSG_COMPRESS_INTERACTIVE, id, data)?;
        Ok(id)
    }

    /// Submit a compress routed to a fleet model (`route` is a model key,
    /// bare model name or container tag).
    pub fn submit_compress_tagged(
        &mut self,
        route: &str,
        data: &[u8],
        interactive: bool,
    ) -> Result<u32> {
        let key_len = check_key_len(route.len())?;
        let id = self.ids.alloc()?;
        let mut payload = Vec::with_capacity(3 + route.len() + data.len());
        payload.push(interactive as u8);
        payload.extend_from_slice(&key_len.to_le_bytes());
        payload.extend_from_slice(route.as_bytes());
        payload.extend_from_slice(data);
        self.send(MSG_COMPRESS_TAGGED, id, &payload)?;
        Ok(id)
    }

    /// Submit a decompress (a fleet routes it by the container's own
    /// recorded tag).
    pub fn submit_decompress(&mut self, data: &[u8]) -> Result<u32> {
        let id = self.ids.alloc()?;
        self.send(MSG_DECOMPRESS, id, data)?;
        Ok(id)
    }

    /// Open a chunked-upload compression stream; feed it with
    /// [`Self::stream_chunk`] and seal it with [`Self::stream_finish`]
    /// (the response to the returned id is the finished container).
    pub fn open_stream(&mut self) -> Result<u32> {
        let id = self.ids.alloc()?;
        self.send(MSG_STREAM_OPEN, id, &[])?;
        Ok(id)
    }

    /// [`Self::open_stream`] routed to a fleet model key.
    pub fn open_stream_for(&mut self, route: &str) -> Result<u32> {
        let id = self.ids.alloc()?;
        self.send(MSG_STREAM_OPEN, id, route.as_bytes())?;
        Ok(id)
    }

    /// Upload one piece of a stream's input (any size; the server re-cuts
    /// at its engine granularity).
    pub fn stream_chunk(&mut self, id: u32, data: &[u8]) -> Result<()> {
        self.send(MSG_STREAM_CHUNK, id, data)
    }

    pub fn stream_finish(&mut self, id: u32) -> Result<()> {
        self.send(MSG_STREAM_FINISH, id, &[])
    }

    /// Receive the next response frame: `(request id, result)`. Responses
    /// arrive in completion order — the caller matches ids. The id is
    /// released for reuse the moment its response lands.
    pub fn recv(&mut self) -> Result<(u32, Result<Vec<u8>>)> {
        let Some((typ, req_id, payload)) = read_frame(&mut self.stream, &self.pool)? else {
            anyhow::bail!("server closed the connection");
        };
        self.ids.release(req_id);
        match typ {
            MSG_OK => Ok((req_id, Ok(payload.detach()))),
            MSG_ERR => Ok((
                req_id,
                Err(anyhow::anyhow!("server error: {}", String::from_utf8_lossy(&payload))),
            )),
            other => anyhow::bail!("unexpected response frame type {other:#04x}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip_vectored() {
        let mut buf = Vec::new();
        write_frame(&mut buf, MSG_OK, 42, b"payload-bytes").unwrap();
        let pool = BytePool::with_enabled(2, true);
        let mut cur = std::io::Cursor::new(buf);
        let (typ, id, payload) = read_frame(&mut cur, &pool).unwrap().unwrap();
        assert_eq!((typ, id), (MSG_OK, 42));
        assert_eq!(&payload[..], b"payload-bytes");
        assert!(read_frame(&mut cur, &pool).unwrap().is_none());
    }

    #[test]
    fn empty_payload_roundtrip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, MSG_STREAM_FINISH, 7, &[]).unwrap();
        assert_eq!(buf.len(), 9);
        let pool = BytePool::disabled();
        let mut cur = std::io::Cursor::new(buf);
        let (typ, id, payload) = read_frame(&mut cur, &pool).unwrap().unwrap();
        assert_eq!((typ, id, payload.len()), (MSG_STREAM_FINISH, 7, 0));
    }

    /// Regression (u32 length truncation): every write path used to
    /// encode `payload.len() as u32`, silently truncating ≥ 4 GiB
    /// payloads into corrupt frames. The length check must refuse BOTH
    /// the u32-overflow case and the protocol cap, and must do so before
    /// a single header byte is emitted.
    #[test]
    fn oversize_payload_is_refused_not_truncated() {
        assert!(check_wire_len(0).is_ok());
        assert!(check_wire_len(MAX_PAYLOAD).is_ok());
        // The exact overflow boundary: u32::MAX + 1 would truncate to 0.
        let err = check_wire_len((u32::MAX as usize).saturating_add(1)).unwrap_err();
        assert!(
            format!("{err:#}").contains("payload too large for wire frame"),
            "unexpected error: {err:#}"
        );
        assert!(check_wire_len(MAX_PAYLOAD + 1).is_err());
        // The frame writer refuses without emitting partial bytes.
        let payload = vec![0u8; MAX_PAYLOAD + 1];
        let mut out = Vec::new();
        let err = write_frame(&mut out, MSG_OK, 1, &payload).unwrap_err();
        assert!(format!("{err:#}").contains("payload too large for wire frame"));
        assert!(out.is_empty(), "no partial frame may reach the wire");
    }

    /// Regression (req-id reuse): with `next_id = u32::MAX` the old
    /// allocator wrapped to 1 regardless of which ids were still in
    /// flight. The new one skips live ids and the reserved 0.
    #[test]
    fn id_allocator_survives_wrap_and_skips_live_ids() {
        let mut ids = IdAlloc::new();
        assert_eq!(ids.alloc().unwrap(), 1);
        assert_eq!(ids.alloc().unwrap(), 2);
        ids.release(1);
        ids.next = u32::MAX;
        assert_eq!(ids.alloc().unwrap(), u32::MAX);
        // Wraps past 0 (reserved) to 1, which was released above.
        assert_eq!(ids.alloc().unwrap(), 1);
        // 2 is still in flight and must be skipped.
        assert_eq!(ids.alloc().unwrap(), 3);
        ids.release(2);
        ids.release(3);
        assert!(ids.live.contains(&1) && ids.live.contains(&u32::MAX));
    }

    #[test]
    fn tagged_frame_parses_and_rejects_malformed() {
        let pool = BytePool::disabled();
        let mut p = vec![1u8];
        p.extend_from_slice(&4u16.to_le_bytes());
        p.extend_from_slice(b"nano");
        p.extend_from_slice(b"data!");
        let (prio, key, data) = parse_tagged(&pool, &p).unwrap();
        assert_eq!(prio, Priority::Interactive);
        assert_eq!(key, "nano");
        assert_eq!(&data[..], b"data!");
        // Empty data is legal (an empty compress is a valid op).
        let p = [0u8, 1, 0, b'x'];
        let (prio, key, data) = parse_tagged(&pool, &p).unwrap();
        assert_eq!((prio, key.as_str(), data.len()), (Priority::Bulk, "x", 0));
        assert!(parse_tagged(&pool, &[]).is_err(), "empty frame");
        assert!(parse_tagged(&pool, &[0, 10, 0, b'x']).is_err(), "truncated key");
        assert!(parse_tagged(&pool, &[9, 1, 0, b'x']).is_err(), "bad priority byte");
    }

    /// Regression (lying length header): a frame declaring MAX_PAYLOAD
    /// but delivering 5 bytes must fail with a clear error after those
    /// 5 bytes — not commit a 256 MB buffer up front. The bounded read
    /// grows with arrival, so the allocation is ~5 bytes + slack.
    #[test]
    fn lying_length_header_is_bounded() {
        let mut frame = vec![MSG_COMPRESS];
        frame.extend_from_slice(&9u32.to_le_bytes());
        frame.extend_from_slice(&(MAX_PAYLOAD as u32).to_le_bytes());
        frame.extend_from_slice(b"hello");
        let pool = BytePool::with_enabled(2, true);
        let mut cur = std::io::Cursor::new(frame);
        let err = read_frame(&mut cur, &pool).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("5 of"), "unexpected error: {msg}");
        assert!(msg.contains("declared"), "unexpected error: {msg}");
    }

    #[test]
    fn oversize_declared_len_is_rejected() {
        let mut frame = vec![MSG_COMPRESS];
        frame.extend_from_slice(&1u32.to_le_bytes());
        frame.extend_from_slice(&u32::MAX.to_le_bytes());
        let pool = BytePool::disabled();
        let mut cur = std::io::Cursor::new(frame);
        let err = read_frame(&mut cur, &pool).unwrap_err();
        assert!(format!("{err:#}").contains("frame too large"));
    }

    /// A writer that accepts at most `k` bytes per call: the vectored
    /// frame writer must survive arbitrary short writes byte-exactly.
    struct Dribble {
        out: Vec<u8>,
        k: usize,
    }

    impl Write for Dribble {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            let n = buf.len().min(self.k);
            self.out.extend_from_slice(&buf[..n]);
            Ok(n)
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn vectored_write_survives_short_writes() {
        let payload = b"0123456789abcdef";
        let mut want = Vec::new();
        write_frame(&mut want, 7, 9, payload).unwrap();
        for k in 1..=want.len() {
            let mut d = Dribble { out: Vec::new(), k };
            write_frame(&mut d, 7, 9, payload).unwrap();
            assert_eq!(d.out, want, "short-write cap {k}");
        }
    }

    #[test]
    fn pooled_read_recycles_frame_buffers() {
        let pool = BytePool::with_enabled(4, true);
        let mut wire = Vec::new();
        write_frame(&mut wire, MSG_OK, 1, &[0xAB; 100]).unwrap();
        let mut cur = std::io::Cursor::new(&wire[..]);
        let (_, _, payload) = read_frame(&mut cur, &pool).unwrap().unwrap();
        drop(payload);
        assert_eq!(pool.free_len(), 1);
        // Second read of the same frame reuses that storage.
        let mut cur = std::io::Cursor::new(&wire[..]);
        let (_, _, payload) = read_frame(&mut cur, &pool).unwrap().unwrap();
        assert_eq!(payload.len(), 100);
        assert_eq!(pool.stats().hits, 1);
    }
}
