//! Order-0 arithmetic coding over bytes — the paper's "Arithmetic" baseline.
//!
//! Two flavours:
//! * **static**: one pass counts byte frequencies, quantizes them to a
//!   16-bit-total table stored in the header, then range-codes the stream.
//! * **adaptive**: starts from a uniform model and increments counts as it
//!   codes, no header; identical model evolution at decode time.

use crate::entropy::range::{RangeDecoder, RangeEncoder};
use crate::Result;

/// Quantization total for the static table.
const STATIC_TOTAL: u32 = 1 << 16;

/// Quantize raw counts to sum exactly `total`, every present symbol >= 1.
pub fn quantize_counts(counts: &[u64], total: u32) -> Vec<u32> {
    let raw_total: u64 = counts.iter().sum();
    assert!(raw_total > 0);
    let mut q = vec![0u32; counts.len()];
    let mut assigned = 0u64;
    let mut max_idx = 0usize;
    for (i, &c) in counts.iter().enumerate() {
        if c == 0 {
            continue;
        }
        let mut f = (c as u128 * total as u128 / raw_total as u128) as u64;
        if f == 0 {
            f = 1;
        }
        q[i] = f as u32;
        assigned += f;
        if q[max_idx] == 0 || counts[i] > counts[max_idx] {
            max_idx = i;
        }
    }
    let diff = total as i64 - assigned as i64;
    let adjusted = q[max_idx] as i64 + diff;
    assert!(adjusted >= 1, "quantization underflow");
    q[max_idx] = adjusted as u32;
    q
}

/// Compress with a static order-0 byte model.
///
/// Layout: `[orig_len: u64le][freq table: 256 * u16le][payload]`.
pub fn compress_static(data: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() / 2 + 512 + 8);
    out.extend_from_slice(&(data.len() as u64).to_le_bytes());
    if data.is_empty() {
        return out;
    }
    let mut counts = [0u64; 256];
    for &b in data {
        counts[b as usize] += 1;
    }
    let freqs = quantize_counts(&counts, STATIC_TOTAL);
    for &f in &freqs {
        debug_assert!(f < (1 << 16) || f == STATIC_TOTAL);
        // A degenerate single-symbol file would need f == 65536 which does
        // not fit in u16; store f-1 instead (all freqs here are >= 0 and the
        // present ones >= 1, so this is reversible given presence bits from
        // counts... simpler: store min(f, 65535) and re-derive the deficit
        // on the dominant symbol at load time).
        out.extend_from_slice(&(f.min(65_535) as u16).to_le_bytes());
    }
    let mut cums = [0u32; 257];
    for i in 0..256 {
        cums[i + 1] = cums[i] + freqs[i];
    }
    let mut enc = RangeEncoder::new();
    for &b in data {
        let s = b as usize;
        enc.encode(cums[s], freqs[s], STATIC_TOTAL);
    }
    out.extend_from_slice(&enc.finish());
    out
}

/// Decompress [`compress_static`] output.
pub fn decompress_static(data: &[u8]) -> Result<Vec<u8>> {
    if data.len() < 8 {
        anyhow::bail!("truncated static-arith stream");
    }
    let n = u64::from_le_bytes(data[0..8].try_into().unwrap()) as usize;
    if n == 0 {
        return Ok(Vec::new());
    }
    if data.len() < 8 + 512 {
        anyhow::bail!("truncated static-arith header");
    }
    let mut freqs = [0u32; 256];
    let mut sum = 0u32;
    for i in 0..256 {
        freqs[i] = u16::from_le_bytes([data[8 + i * 2], data[9 + i * 2]]) as u32;
        sum += freqs[i];
    }
    // Restore a clamped dominant frequency (see compress_static).
    if sum < STATIC_TOTAL {
        let max_idx = (0..256).max_by_key(|&i| freqs[i]).unwrap();
        freqs[max_idx] += STATIC_TOTAL - sum;
    } else if sum > STATIC_TOTAL {
        anyhow::bail!("corrupt static-arith frequency table");
    }
    let mut cums = [0u32; 257];
    for i in 0..256 {
        cums[i + 1] = cums[i] + freqs[i];
    }
    let mut dec = RangeDecoder::new(&data[8 + 512..]);
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let f = dec.decode_freq(STATIC_TOTAL);
        let sym = cums.partition_point(|&c| c <= f) - 1;
        dec.decode_update(cums[sym], freqs[sym]);
        out.push(sym as u8);
    }
    Ok(out)
}

/// Adaptive order-0 model: per-byte counts with periodic halving.
struct AdaptiveModel {
    freqs: [u32; 256],
    total: u32,
}

impl AdaptiveModel {
    const MAX_TOTAL: u32 = 1 << 20;

    fn new() -> Self {
        AdaptiveModel { freqs: [1; 256], total: 256 }
    }

    #[inline]
    fn cum(&self, sym: usize) -> u32 {
        self.freqs[..sym].iter().sum()
    }

    #[inline]
    fn find(&self, target: u32) -> (usize, u32) {
        let mut cum = 0u32;
        for (i, &f) in self.freqs.iter().enumerate() {
            if target < cum + f {
                return (i, cum);
            }
            cum += f;
        }
        (255, cum - self.freqs[255])
    }

    #[inline]
    fn update(&mut self, sym: usize) {
        self.freqs[sym] += 32;
        self.total += 32;
        if self.total >= Self::MAX_TOTAL {
            self.total = 0;
            for f in self.freqs.iter_mut() {
                *f = (*f >> 1) | 1;
                self.total += *f;
            }
        }
    }
}

/// Compress with the adaptive order-0 model. Layout: `[orig_len: u64le][payload]`.
pub fn compress_adaptive(data: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() / 2 + 16);
    out.extend_from_slice(&(data.len() as u64).to_le_bytes());
    let mut model = AdaptiveModel::new();
    let mut enc = RangeEncoder::new();
    for &b in data {
        let s = b as usize;
        enc.encode(model.cum(s), model.freqs[s], model.total);
        model.update(s);
    }
    out.extend_from_slice(&enc.finish());
    out
}

/// Decompress [`compress_adaptive`] output.
pub fn decompress_adaptive(data: &[u8]) -> Result<Vec<u8>> {
    if data.len() < 8 {
        anyhow::bail!("truncated adaptive-arith stream");
    }
    let n = u64::from_le_bytes(data[0..8].try_into().unwrap()) as usize;
    let mut model = AdaptiveModel::new();
    let mut dec = RangeDecoder::new(&data[8..]);
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let target = dec.decode_freq(model.total);
        let (sym, cum) = model.find(target);
        dec.decode_update(cum, model.freqs[sym]);
        model.update(sym);
        out.push(sym as u8);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn skewed_text(n: usize, seed: u64) -> Vec<u8> {
        let mut rng = Pcg64::seeded(seed);
        let letters = b"etaoin shrdlu.ETAOIN";
        (0..n).map(|_| rng.choose(letters)).collect()
    }

    #[test]
    fn static_roundtrip() {
        for seed in 0..3 {
            let data = skewed_text(10_000, seed);
            let c = compress_static(&data);
            assert_eq!(decompress_static(&c).unwrap(), data);
            assert!(c.len() < data.len());
        }
    }

    #[test]
    fn adaptive_roundtrip() {
        for seed in 0..3 {
            let data = skewed_text(10_000, seed);
            let c = compress_adaptive(&data);
            assert_eq!(decompress_adaptive(&c).unwrap(), data);
            assert!(c.len() < data.len());
        }
    }

    #[test]
    fn empty_input() {
        assert_eq!(decompress_static(&compress_static(b"")).unwrap(), b"");
        assert_eq!(decompress_adaptive(&compress_adaptive(b"")).unwrap(), b"");
    }

    #[test]
    fn single_byte_and_degenerate() {
        let one = b"x".to_vec();
        assert_eq!(decompress_static(&compress_static(&one)).unwrap(), one);
        assert_eq!(decompress_adaptive(&compress_adaptive(&one)).unwrap(), one);
        // Degenerate single-symbol file exercises the u16 clamp path.
        let same = vec![b'z'; 50_000];
        let c = compress_static(&same);
        assert_eq!(decompress_static(&c).unwrap(), same);
        assert!(c.len() < 1000);
        let c = compress_adaptive(&same);
        assert_eq!(decompress_adaptive(&c).unwrap(), same);
    }

    #[test]
    fn all_256_bytes() {
        let mut rng = Pcg64::seeded(7);
        let mut data = vec![0u8; 20_000];
        rng.fill_bytes(&mut data);
        assert_eq!(decompress_static(&compress_static(&data)).unwrap(), data);
        assert_eq!(decompress_adaptive(&compress_adaptive(&data)).unwrap(), data);
    }

    #[test]
    fn static_close_to_entropy() {
        let data = skewed_text(100_000, 9);
        let mut counts = [0u64; 256];
        for &b in &data {
            counts[b as usize] += 1;
        }
        let total = data.len() as f64;
        let h: f64 = counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| {
                let p = c as f64 / total;
                -p * p.log2()
            })
            .sum();
        let c = compress_static(&data);
        let bits = (c.len() - 8 - 512) as f64 * 8.0 / total;
        assert!(bits < h * 1.01 + 0.01, "bits {bits} vs H {h}");
    }

    #[test]
    fn quantize_preserves_presence_and_total() {
        let mut rng = Pcg64::seeded(11);
        for _ in 0..20 {
            let counts: Vec<u64> = (0..256).map(|_| rng.gen_range(10_000)).collect();
            if counts.iter().sum::<u64>() == 0 {
                continue;
            }
            let q = quantize_counts(&counts, 1 << 16);
            assert_eq!(q.iter().sum::<u32>(), 1 << 16);
            for i in 0..256 {
                assert_eq!(counts[i] > 0, q[i] > 0);
            }
        }
    }

    #[test]
    fn corrupt_header_rejected() {
        assert!(decompress_static(&[1, 2, 3]).is_err());
        assert!(decompress_adaptive(&[1]).is_err());
        let mut c = compress_static(b"hello world hello world");
        // Inflate the stored frequency table so it over-sums.
        c[8] = 0xFF;
        c[9] = 0xFF;
        c[10] = 0xFF;
        c[11] = 0xFF;
        assert!(decompress_static(&c).is_err());
    }
}
