//! Adaptive binary arithmetic coder and 12-bit probability bit models.
//!
//! The context-mixing baselines (`nncp-sim`, `trace-sim`) and LZMA-lite code
//! one *bit* at a time against an adaptive probability. The coder here is a
//! binary specialization of the range coder in [`super::range`]: same carry
//! handling, but the split point is `range * p` instead of a cumulative
//! table walk.

/// Probability precision: probabilities live in `[1, 4095]` out of 4096.
pub const PROB_BITS: u32 = 12;
pub const PROB_ONE: u16 = 1 << PROB_BITS;

/// Adaptive probability of the next bit being 1, with shift-update.
#[derive(Clone, Copy, Debug)]
pub struct BitModel {
    /// P(bit = 1) in 1/4096 units.
    p: u16,
    /// Adaptation rate: larger shift = slower adaptation.
    shift: u8,
}

impl Default for BitModel {
    fn default() -> Self {
        BitModel::new(5)
    }
}

impl BitModel {
    pub fn new(shift: u8) -> Self {
        BitModel { p: PROB_ONE / 2, shift }
    }

    #[inline]
    pub fn prob(&self) -> u16 {
        self.p
    }

    /// Update toward the observed bit.
    #[inline]
    pub fn update(&mut self, bit: u8) {
        if bit != 0 {
            self.p += (PROB_ONE - self.p) >> self.shift;
        } else {
            self.p -= self.p >> self.shift;
        }
        // Keep probabilities strictly inside (0, 1) so both branches stay
        // codable.
        self.p = self.p.clamp(1, PROB_ONE - 1);
    }
}

const TOP: u32 = 1 << 24;

/// Binary arithmetic encoder.
pub struct BinEncoder {
    low: u64,
    range: u32,
    cache: u8,
    cache_size: u64,
    out: Vec<u8>,
}

impl Default for BinEncoder {
    fn default() -> Self {
        Self::new()
    }
}

impl BinEncoder {
    pub fn new() -> Self {
        BinEncoder { low: 0, range: u32::MAX, cache: 0, cache_size: 1, out: Vec::new() }
    }

    #[inline]
    fn shift_low(&mut self) {
        if self.low < 0xFF00_0000 || self.low > 0xFFFF_FFFF {
            let carry = (self.low >> 32) as u8;
            self.out.push(self.cache.wrapping_add(carry));
            for _ in 1..self.cache_size {
                self.out.push(0xFFu8.wrapping_add(carry));
            }
            self.cache = (self.low >> 24) as u8;
            self.cache_size = 0;
        }
        self.cache_size += 1;
        self.low = (self.low << 8) & 0xFFFF_FFFF;
    }

    /// Encode `bit` with probability `p1/4096` of being 1.
    #[inline]
    pub fn encode(&mut self, bit: u8, p1: u16) {
        debug_assert!(p1 >= 1 && p1 < PROB_ONE);
        let bound = (self.range >> PROB_BITS) * p1 as u32;
        if bit != 0 {
            self.range = bound;
        } else {
            self.low += bound as u64;
            self.range -= bound;
        }
        while self.range < TOP {
            self.shift_low();
            self.range <<= 8;
        }
    }

    /// Encode a bit and adapt the model.
    #[inline]
    pub fn encode_update(&mut self, bit: u8, model: &mut BitModel) {
        self.encode(bit, model.prob());
        model.update(bit);
    }

    pub fn finish(mut self) -> Vec<u8> {
        for _ in 0..5 {
            self.shift_low();
        }
        self.out
    }

    pub fn len(&self) -> usize {
        self.out.len()
    }

    pub fn is_empty(&self) -> bool {
        self.out.is_empty()
    }
}

/// Binary arithmetic decoder.
pub struct BinDecoder<'a> {
    code: u32,
    range: u32,
    data: &'a [u8],
    pos: usize,
}

impl<'a> BinDecoder<'a> {
    pub fn new(data: &'a [u8]) -> Self {
        let mut d = BinDecoder { code: 0, range: u32::MAX, data, pos: 1 };
        for _ in 0..4 {
            d.code = (d.code << 8) | d.next_byte() as u32;
        }
        d
    }

    #[inline]
    fn next_byte(&mut self) -> u8 {
        let b = if self.pos < self.data.len() { self.data[self.pos] } else { 0 };
        self.pos += 1;
        b
    }

    /// Decode a bit coded with probability `p1/4096`.
    #[inline]
    pub fn decode(&mut self, p1: u16) -> u8 {
        let bound = (self.range >> PROB_BITS) * p1 as u32;
        let bit = if self.code < bound {
            self.range = bound;
            1
        } else {
            self.code -= bound;
            self.range -= bound;
            0
        };
        while self.range < TOP {
            self.code = (self.code << 8) | self.next_byte() as u32;
            self.range <<= 8;
        }
        bit
    }

    /// Decode a bit and adapt the model (mirror of `encode_update`).
    #[inline]
    pub fn decode_update(&mut self, model: &mut BitModel) -> u8 {
        let bit = self.decode(model.prob());
        model.update(bit);
        bit
    }
}

/// Encode a byte through an adaptive 256-leaf bit tree (8 decisions).
/// `models` must hold 256 entries; index 0 is unused, node `i` has children
/// `2i` and `2i+1`. A shared helper for LZMA-lite literals and lengths.
#[inline]
pub fn encode_byte_tree(enc: &mut BinEncoder, models: &mut [BitModel], byte: u8) {
    debug_assert!(models.len() >= 256);
    let mut node = 1usize;
    for i in (0..8).rev() {
        let bit = (byte >> i) & 1;
        enc.encode_update(bit, &mut models[node]);
        node = (node << 1) | bit as usize;
    }
}

/// Decode a byte written by [`encode_byte_tree`].
#[inline]
pub fn decode_byte_tree(dec: &mut BinDecoder, models: &mut [BitModel]) -> u8 {
    debug_assert!(models.len() >= 256);
    let mut node = 1usize;
    for _ in 0..8 {
        let bit = dec.decode_update(&mut models[node]);
        node = (node << 1) | bit as usize;
    }
    (node & 0xFF) as u8
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn fixed_prob_roundtrip() {
        let mut rng = Pcg64::seeded(1);
        let bits: Vec<u8> = (0..20_000).map(|_| rng.gen_bool(0.3) as u8).collect();
        let mut enc = BinEncoder::new();
        for &b in &bits {
            enc.encode(b, 1228); // ~0.3 * 4096
        }
        let buf = enc.finish();
        let mut dec = BinDecoder::new(&buf);
        for &b in &bits {
            assert_eq!(dec.decode(1228), b);
        }
        // Entropy(0.3) ~ 0.881 bits; allow 5% coder overhead.
        assert!(buf.len() as f64 <= 20_000.0 * 0.881 / 8.0 * 1.05 + 16.0);
    }

    #[test]
    fn adaptive_roundtrip() {
        let mut rng = Pcg64::seeded(2);
        let bits: Vec<u8> = (0..20_000).map(|_| rng.gen_bool(0.05) as u8).collect();
        let mut enc = BinEncoder::new();
        let mut m = BitModel::default();
        for &b in &bits {
            enc.encode_update(b, &mut m);
        }
        let buf = enc.finish();
        let mut dec = BinDecoder::new(&buf);
        let mut m = BitModel::default();
        for &b in &bits {
            assert_eq!(dec.decode_update(&mut m), b);
        }
        // Adaptive model should approach H(0.05) ~ 0.286 bits/bit.
        assert!(buf.len() < 20_000 / 8 / 2, "len {}", buf.len());
    }

    #[test]
    fn model_stays_in_open_interval() {
        let mut m = BitModel::new(4);
        for _ in 0..10_000 {
            m.update(1);
        }
        assert!(m.prob() >= 1 && m.prob() < PROB_ONE);
        for _ in 0..10_000 {
            m.update(0);
        }
        assert!(m.prob() >= 1 && m.prob() < PROB_ONE);
    }

    #[test]
    fn byte_tree_roundtrip() {
        let mut rng = Pcg64::seeded(3);
        let bytes: Vec<u8> = (0..5000).map(|_| (rng.gen_index(64) + 32) as u8).collect();
        let mut enc = BinEncoder::new();
        let mut models = vec![BitModel::default(); 256];
        for &b in &bytes {
            encode_byte_tree(&mut enc, &mut models, b);
        }
        let buf = enc.finish();
        let mut dec = BinDecoder::new(&buf);
        let mut models = vec![BitModel::default(); 256];
        for &b in &bytes {
            assert_eq!(decode_byte_tree(&mut dec, &mut models), b);
        }
        // Adaptive tree should beat raw storage on a 64-symbol alphabet.
        assert!(buf.len() < 5000, "len {}", buf.len());
    }

    #[test]
    fn alternating_bits_cost_about_one_bit_each() {
        let mut enc = BinEncoder::new();
        let mut m = BitModel::default();
        for i in 0..8000u32 {
            enc.encode_update((i & 1) as u8, &mut m);
        }
        let buf = enc.finish();
        let per_bit = buf.len() as f64 * 8.0 / 8000.0;
        assert!((0.9..1.2).contains(&per_bit), "{per_bit} bits/bit");
    }
}
