//! MSB-first bit-granular I/O over byte buffers.
//!
//! Used by the Huffman and FSE coders. Bits are packed most-significant
//! first within each byte so that multi-bit values written with
//! [`BitWriter::write_bits`] read back with [`BitReader::read_bits`]
//! independently of how they were chunked.

/// Accumulating bit writer.
#[derive(Default)]
pub struct BitWriter {
    out: Vec<u8>,
    acc: u64,
    nbits: u32,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append the low `n` bits of `value` (MSB of the field first). `n <= 57`.
    #[inline]
    pub fn write_bits(&mut self, value: u64, n: u32) {
        debug_assert!(n <= 57);
        let mask = if n == 0 { 0 } else { u64::MAX >> (64 - n) };
        debug_assert!(value <= mask || n == 0);
        self.acc = (self.acc << n) | (value & mask);
        self.nbits += n;
        while self.nbits >= 8 {
            self.nbits -= 8;
            self.out.push((self.acc >> self.nbits) as u8);
        }
    }

    /// Append a single bit.
    #[inline]
    pub fn write_bit(&mut self, bit: bool) {
        self.write_bits(bit as u64, 1);
    }

    /// Number of bits written so far.
    pub fn bit_len(&self) -> u64 {
        self.out.len() as u64 * 8 + self.nbits as u64
    }

    /// Flush (zero-padding the final partial byte) and return the buffer.
    pub fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            let pad = 8 - self.nbits;
            self.out.push(((self.acc << pad) & 0xFF) as u8);
            self.nbits = 0;
        }
        self.out
    }
}

/// Bit reader over a byte slice; reads in the same MSB-first order.
pub struct BitReader<'a> {
    data: &'a [u8],
    pos: usize,
    acc: u64,
    nbits: u32,
}

impl<'a> BitReader<'a> {
    pub fn new(data: &'a [u8]) -> Self {
        BitReader { data, pos: 0, acc: 0, nbits: 0 }
    }

    /// Read `n` bits (MSB of the field first). Returns 0 bits past the end
    /// (callers track logical lengths themselves).
    #[inline]
    pub fn read_bits(&mut self, n: u32) -> u64 {
        debug_assert!(n <= 57);
        while self.nbits < n {
            let byte = if self.pos < self.data.len() { self.data[self.pos] } else { 0 };
            self.pos += 1;
            self.acc = (self.acc << 8) | byte as u64;
            self.nbits += 8;
        }
        self.nbits -= n;
        let v = (self.acc >> self.nbits) & if n == 0 { 0 } else { (1u64 << n) - 1 };
        v
    }

    /// Read one bit.
    #[inline]
    pub fn read_bit(&mut self) -> bool {
        self.read_bits(1) != 0
    }

    /// True once every real input byte has been consumed into the accumulator.
    pub fn exhausted(&self) -> bool {
        self.pos >= self.data.len() && self.nbits == 0
    }

    /// True if more bits were requested than the buffer holds (reads past
    /// the end return zeros but advance `pos` beyond the data) — the
    /// structural-corruption signal for fixed-length bitstream frames.
    pub fn overran(&self) -> bool {
        self.pos > self.data.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn roundtrip_single_bits() {
        let mut w = BitWriter::new();
        let pattern = [true, false, true, true, false, false, true, false, true, true, true];
        for &b in &pattern {
            w.write_bit(b);
        }
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        for &b in &pattern {
            assert_eq!(r.read_bit(), b);
        }
    }

    #[test]
    fn roundtrip_mixed_widths() {
        let mut rng = Pcg64::seeded(100);
        let fields: Vec<(u64, u32)> = (0..5000)
            .map(|_| {
                let n = 1 + rng.gen_index(32) as u32;
                let v = rng.next_u64() & ((1u64 << n) - 1);
                (v, n)
            })
            .collect();
        let mut w = BitWriter::new();
        for &(v, n) in &fields {
            w.write_bits(v, n);
        }
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        for &(v, n) in &fields {
            assert_eq!(r.read_bits(n), v, "width {n}");
        }
    }

    #[test]
    fn zero_width_write_is_noop() {
        let mut w = BitWriter::new();
        w.write_bits(0, 0);
        w.write_bits(0b101, 3);
        w.write_bits(0, 0);
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        assert_eq!(r.read_bits(0), 0);
        assert_eq!(r.read_bits(3), 0b101);
    }

    #[test]
    fn bit_len_counts() {
        let mut w = BitWriter::new();
        w.write_bits(0x3, 2);
        assert_eq!(w.bit_len(), 2);
        w.write_bits(0xFFF, 12);
        assert_eq!(w.bit_len(), 14);
        let buf = w.finish();
        assert_eq!(buf.len(), 2); // 14 bits -> 2 bytes
    }

    #[test]
    fn byte_alignment_msb_first() {
        let mut w = BitWriter::new();
        w.write_bits(0b1010_1010, 8);
        let buf = w.finish();
        assert_eq!(buf, vec![0b1010_1010]);
    }
}
