//! Finite State Entropy — tabled asymmetric numeral systems (tANS).
//!
//! This is the paper's "FSE" baseline and the entropy stage of the
//! Zstd-shaped `zstd_lite` baseline. Standard construction: frequencies are
//! normalized to `1 << table_log`, spread across the state table with the
//! golden-ratio step, encoding walks states backwards emitting variable bit
//! counts, decoding walks forwards.

use crate::entropy::{BitReader, BitWriter};
use crate::util::floor_log2;
use crate::Result;

/// Default table log (4096 states) — Zstd's default for literals.
pub const DEFAULT_TABLE_LOG: u32 = 12;

/// Normalize raw counts so they sum to `1 << table_log`, keeping every
/// present symbol at frequency >= 1. Degenerate inputs (empty
/// distribution, alphabet too wide for the table) are clean errors, not
/// panics: these paths are reachable from decoding untrusted containers.
pub fn normalize_freqs(counts: &[u64], table_log: u32) -> Result<Vec<u32>> {
    let table_size = 1u64 << table_log;
    let total: u64 = counts.iter().sum();
    if total == 0 {
        anyhow::bail!("cannot normalize an empty distribution");
    }
    let mut norm = vec![0u32; counts.len()];
    let mut assigned: u64 = 0;
    let mut max_idx = 0;
    for (i, &c) in counts.iter().enumerate() {
        if c == 0 {
            continue;
        }
        let mut f = (c as u128 * table_size as u128 / total as u128) as u64;
        if f == 0 {
            f = 1;
        }
        norm[i] = f as u32;
        assigned += f;
        if counts[i] > counts[max_idx] || norm[max_idx] == 0 {
            max_idx = i;
        }
    }
    // Fix rounding drift on the most frequent symbol.
    if assigned != table_size {
        let diff = table_size as i64 - assigned as i64;
        let adjusted = norm[max_idx] as i64 + diff;
        if adjusted < 1 {
            anyhow::bail!("normalization underflow: distribution too flat for table_log");
        }
        norm[max_idx] = adjusted as u32;
    }
    debug_assert_eq!(norm.iter().map(|&x| x as u64).sum::<u64>(), table_size);
    Ok(norm)
}

/// Spread symbols over the state table (Yann Collet's step function).
fn spread_symbols(norm: &[u32], table_log: u32) -> Vec<u16> {
    let table_size = 1usize << table_log;
    let step = (table_size >> 1) + (table_size >> 3) + 3;
    let mask = table_size - 1;
    let mut table = vec![0u16; table_size];
    let mut pos = 0usize;
    for (sym, &f) in norm.iter().enumerate() {
        for _ in 0..f {
            table[pos] = sym as u16;
            pos = (pos + step) & mask;
        }
    }
    debug_assert_eq!(pos, 0);
    table
}

#[derive(Clone, Copy)]
struct DecodeEntry {
    symbol: u16,
    nb_bits: u8,
    base: u32, // (x << nb_bits) - table_size
}

/// A built FSE table for one alphabet (encode + decode directions).
pub struct FseTable {
    table_log: u32,
    norm: Vec<u32>,
    decode: Vec<DecodeEntry>,
    /// encode_state[sym][x - norm[sym]] = next state (in [TS, 2TS)).
    encode: Vec<Vec<u32>>,
}

impl FseTable {
    /// Build from normalized frequencies (must sum to `1 << table_log`).
    /// The sum is a hard precondition of the spread/decode construction,
    /// so it is validated for real — tables are built from container
    /// headers, and a lying header must be an error, not a panic.
    pub fn new(norm: &[u32], table_log: u32) -> Result<Self> {
        if table_log == 0 || table_log > 15 {
            anyhow::bail!("FSE table_log {table_log} out of range (1..=15)");
        }
        let table_size = 1u32 << table_log;
        if norm.iter().map(|&f| f as u64).sum::<u64>() != table_size as u64 {
            anyhow::bail!("FSE frequencies do not sum to table size 1<<{table_log}");
        }
        let spread = spread_symbols(norm, table_log);
        let mut next: Vec<u32> = norm.to_vec();
        let mut decode = vec![DecodeEntry { symbol: 0, nb_bits: 0, base: 0 }; table_size as usize];
        let mut encode: Vec<Vec<u32>> =
            norm.iter().map(|&f| vec![0u32; f as usize]).collect();
        for (i, &s) in spread.iter().enumerate() {
            let s = s as usize;
            let x = next[s];
            next[s] += 1;
            let nb_bits = (table_log - floor_log2(x)) as u8;
            decode[i] = DecodeEntry {
                symbol: s as u16,
                nb_bits,
                base: (x << nb_bits) - table_size,
            };
            // State value for the encoder: i + table_size in [TS, 2TS).
            encode[s][(x - norm[s]) as usize] = i as u32 + table_size;
        }
        Ok(FseTable { table_log, norm: norm.to_vec(), decode, encode })
    }

    pub fn table_log(&self) -> u32 {
        self.table_log
    }

    pub fn norm(&self) -> &[u32] {
        &self.norm
    }

    /// One decode-table walk for external streaming decoders: consumes the
    /// entry's bits from `reader` and returns `(symbol, next_state)`.
    /// `state` must be in `[table_size, 2 * table_size)` — validate the
    /// frame's initial state once (as [`FseDecoder::new`] does) and every
    /// state this returns stays in range by construction.
    #[inline]
    pub fn decode_step(&self, state: u32, reader: &mut BitReader) -> (usize, u32) {
        let table_size = 1u32 << self.table_log;
        let entry = self.decode[(state - table_size) as usize];
        let bits = reader.read_bits(entry.nb_bits as u32) as u32;
        (entry.symbol as usize, entry.base + table_size + bits)
    }
}

/// Streaming FSE encoder. Symbols MUST be fed in **reverse** order; the
/// emitted bit-chunks are buffered and written first-symbol-first so the
/// decoder can stream forwards.
pub struct FseEncoder<'t> {
    table: &'t FseTable,
    state: u32,
    /// (value, nb_bits) chunks, pushed in reverse symbol order.
    chunks: Vec<(u32, u8)>,
    primed: bool,
}

impl<'t> FseEncoder<'t> {
    pub fn new(table: &'t FseTable) -> Self {
        FseEncoder { table, state: 0, chunks: Vec::new(), primed: false }
    }

    /// Feed the next symbol **from the back of the message**.
    pub fn push_reverse(&mut self, sym: usize) {
        let f = self.table.norm[sym];
        debug_assert!(f > 0, "symbol {sym} not in table");
        if !self.primed {
            // Initialize the state to the first (=last-decoded... i.e. the
            // final) occurrence slot for this symbol: any valid state works;
            // use the canonical x = f slot.
            self.state = self.table.encode[sym][0];
            self.primed = true;
            return;
        }
        let table_size = 1u32 << self.table.table_log;
        let mut x = self.state;
        let mut nb = 0u8;
        while x >= 2 * f {
            nb += 1;
            x >>= 1;
        }
        debug_assert!(x >= f && x < 2 * f);
        self.chunks.push((self.state & ((1 << nb) - 1).max(0), nb));
        let _ = table_size;
        self.state = self.table.encode[sym][(x - f) as usize];
    }

    /// Finish: returns (initial_decoder_state, bitstream bytes).
    pub fn finish(self) -> (u32, Vec<u8>) {
        let mut w = BitWriter::new();
        // Chunks were pushed last-symbol-first; decoder consumes
        // first-symbol-first, so write them in reverse push order.
        for &(v, nb) in self.chunks.iter().rev() {
            w.write_bits(v as u64, nb as u32);
        }
        (self.state, w.finish())
    }
}

/// Streaming FSE decoder (forward order).
pub struct FseDecoder<'t, 'a> {
    table: &'t FseTable,
    state: u32,
    reader: BitReader<'a>,
}

impl<'t, 'a> FseDecoder<'t, 'a> {
    /// The initial state comes straight off the wire, so it is validated
    /// here once; every state [`Self::next`] computes afterwards is in
    /// `[TS, 2TS)` by table construction.
    pub fn new(table: &'t FseTable, initial_state: u32, data: &'a [u8]) -> Result<Self> {
        let table_size = 1u32 << table.table_log;
        if initial_state < table_size || initial_state >= 2 * table_size {
            anyhow::bail!("corrupt FSE initial state {initial_state}");
        }
        Ok(FseDecoder { table, state: initial_state, reader: BitReader::new(data) })
    }

    /// Decode the next symbol.
    pub fn next(&mut self) -> usize {
        let (sym, next) = self.table.decode_step(self.state, &mut self.reader);
        self.state = next;
        sym
    }
}

/// One-shot helper: FSE-encode a symbol slice with a prebuilt table.
/// Returns `(initial_state, payload)`.
pub fn encode_all(table: &FseTable, symbols: &[usize]) -> (u32, Vec<u8>) {
    let mut enc = FseEncoder::new(table);
    for &s in symbols.iter().rev() {
        enc.push_reverse(s);
    }
    enc.finish()
}

/// One-shot helper: decode `n` symbols.
pub fn decode_all(
    table: &FseTable,
    initial_state: u32,
    payload: &[u8],
    n: usize,
) -> Result<Vec<usize>> {
    let mut dec = FseDecoder::new(table, initial_state, payload)?;
    Ok((0..n).map(|_| dec.next()).collect())
}

/// Serialize normalized frequencies compactly (u16 little-endian each).
pub fn pack_norm(norm: &[u32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(norm.len() * 2);
    for &f in norm {
        debug_assert!(f < (1 << 16));
        out.extend_from_slice(&(f as u16).to_le_bytes());
    }
    out
}

/// Inverse of [`pack_norm`].
pub fn unpack_norm(data: &[u8], n: usize, table_log: u32) -> Result<Vec<u32>> {
    if data.len() < n * 2 {
        anyhow::bail!("truncated FSE header");
    }
    let norm: Vec<u32> =
        (0..n).map(|i| u16::from_le_bytes([data[i * 2], data[i * 2 + 1]]) as u32).collect();
    if norm.iter().sum::<u32>() != 1 << table_log {
        anyhow::bail!("corrupt FSE frequency table");
    }
    Ok(norm)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn sample(freq_weights: &[f64], n: usize, seed: u64) -> Vec<usize> {
        let mut rng = Pcg64::seeded(seed);
        (0..n).map(|_| rng.choose_weighted(freq_weights)).collect()
    }

    fn roundtrip(symbols: &[usize], alphabet: usize, table_log: u32) -> usize {
        let mut counts = vec![0u64; alphabet];
        for &s in symbols {
            counts[s] += 1;
        }
        let norm = normalize_freqs(&counts, table_log).unwrap();
        let table = FseTable::new(&norm, table_log).unwrap();
        let (state, payload) = encode_all(&table, symbols);
        let decoded = decode_all(&table, state, &payload, symbols.len()).unwrap();
        assert_eq!(decoded, symbols);
        payload.len()
    }

    #[test]
    fn roundtrip_uniform() {
        let syms = sample(&[1.0; 16], 8000, 1);
        let bytes = roundtrip(&syms, 16, 10);
        // 4 bits/symbol ideal.
        assert!((bytes as f64) < 8000.0 * 4.0 / 8.0 * 1.05);
    }

    #[test]
    fn roundtrip_skewed() {
        let mut w = vec![1.0; 64];
        w[0] = 1000.0;
        let syms = sample(&w, 20_000, 2);
        let bytes = roundtrip(&syms, 64, 12);
        // Entropy of this mixture is ~0.68 bits/sym; stay within 5%.
        assert!((bytes as f64) < 20_000.0 * 0.68 / 8.0 * 1.05 + 16.0, "bytes {bytes}");
    }

    #[test]
    fn roundtrip_binary_alphabet() {
        let syms = sample(&[0.95, 0.05], 10_000, 3);
        roundtrip(&syms, 2, 9);
    }

    #[test]
    fn roundtrip_single_symbol() {
        let syms = vec![5usize; 1000];
        let mut counts = vec![0u64; 8];
        counts[5] = 1000;
        let norm = normalize_freqs(&counts, 6).unwrap();
        let table = FseTable::new(&norm, 6).unwrap();
        let (state, payload) = encode_all(&table, &syms);
        let decoded = decode_all(&table, state, &payload, syms.len()).unwrap();
        assert_eq!(decoded, syms);
        // Degenerate distribution costs ~0 bits per symbol.
        assert!(payload.len() <= 2);
    }

    #[test]
    fn roundtrip_all_bytes() {
        let mut rng = Pcg64::seeded(4);
        let syms: Vec<usize> = (0..30_000)
            .map(|_| if rng.gen_bool(0.7) { rng.gen_index(16) + 90 } else { rng.gen_index(256) })
            .collect();
        roundtrip(&syms, 256, 12);
    }

    #[test]
    fn normalize_sums_to_table_size() {
        let mut rng = Pcg64::seeded(5);
        for _ in 0..50 {
            let counts: Vec<u64> = (0..100).map(|_| rng.gen_range(1000)).collect();
            if counts.iter().sum::<u64>() == 0 {
                continue;
            }
            let norm = normalize_freqs(&counts, 12).unwrap();
            assert_eq!(norm.iter().sum::<u32>(), 1 << 12);
            for (i, &c) in counts.iter().enumerate() {
                assert_eq!(c > 0, norm[i] > 0, "presence must be preserved");
            }
        }
    }

    #[test]
    fn pack_unpack_norm_roundtrip() {
        let counts = vec![3u64, 0, 10, 1, 1, 500];
        let norm = normalize_freqs(&counts, 10).unwrap();
        let packed = pack_norm(&norm);
        let restored = unpack_norm(&packed, norm.len(), 10).unwrap();
        assert_eq!(restored, norm);
    }

    #[test]
    fn unpack_rejects_bad_sum() {
        let bad = pack_norm(&[1, 2, 3]);
        assert!(unpack_norm(&bad, 3, 10).is_err());
    }

    #[test]
    fn degenerate_inputs_are_errors_not_panics() {
        // Satellite hardening: every decode-reachable constructor refuses
        // corrupt inputs with a clean error.
        let err = normalize_freqs(&[0u64; 8], 10).unwrap_err().to_string();
        assert!(err.contains("empty distribution"), "{err}");
        // 64 present symbols cannot each get >= 1 slot in a 32-slot table.
        let err = normalize_freqs(&vec![1u64; 64], 5).unwrap_err().to_string();
        assert!(err.contains("underflow"), "{err}");
        // Frequencies that lie about the table size.
        assert!(FseTable::new(&[1, 2, 3], 10).is_err());
        assert!(FseTable::new(&[1 << 10], 16).is_err());
        // Out-of-range initial state off the wire.
        let norm = normalize_freqs(&[10, 20, 30], 8).unwrap();
        let table = FseTable::new(&norm, 8).unwrap();
        for bad_state in [0u32, 255, 512, u32::MAX] {
            assert!(FseDecoder::new(&table, bad_state, &[]).is_err(), "{bad_state}");
            assert!(decode_all(&table, bad_state, &[], 4).is_err(), "{bad_state}");
        }
    }

    #[test]
    fn decode_step_matches_streaming_decoder() {
        let syms = sample(&[8.0, 4.0, 2.0, 1.0], 5000, 7);
        let mut counts = vec![0u64; 4];
        for &s in &syms {
            counts[s] += 1;
        }
        let norm = normalize_freqs(&counts, 9).unwrap();
        let table = FseTable::new(&norm, 9).unwrap();
        let (state0, payload) = encode_all(&table, &syms);
        let mut reader = BitReader::new(&payload);
        let mut state = state0;
        let mut out = Vec::with_capacity(syms.len());
        for _ in 0..syms.len() {
            let (sym, next) = table.decode_step(state, &mut reader);
            out.push(sym);
            state = next;
        }
        assert_eq!(out, syms);
    }

    #[test]
    fn compression_close_to_entropy() {
        // Geometric-ish distribution; measured bits/sym should be within 3%
        // of Shannon entropy (FSE is near-optimal).
        let w: Vec<f64> = (0..32).map(|i| 0.7f64.powi(i)).collect();
        let syms = sample(&w, 50_000, 6);
        let mut counts = vec![0u64; 32];
        for &s in &syms {
            counts[s] += 1;
        }
        let total: f64 = syms.len() as f64;
        let entropy: f64 = counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| {
                let p = c as f64 / total;
                -p * p.log2()
            })
            .sum();
        let bytes = roundtrip(&syms, 32, 12);
        let bits_per_sym = bytes as f64 * 8.0 / total;
        assert!(bits_per_sym < entropy * 1.03 + 0.02, "{bits_per_sym} vs H={entropy}");
    }
}
