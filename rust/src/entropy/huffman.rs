//! Canonical, length-limited Huffman coding.
//!
//! Implements the paper's "Huffman" baseline (order-0 over bytes) and the
//! entropy stage of the DEFLATE-shaped `gzip_like` baseline. Code lengths
//! are built with a heap-based Huffman tree; if the depth exceeds the limit
//! the frequencies are repeatedly flattened (`f = f/2 + 1`) until it fits —
//! the classic zlib-style workaround, within a fraction of a percent of
//! package-merge on text.

use crate::entropy::{BitReader, BitWriter};
use crate::Result;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Maximum code length supported by the canonical tables.
pub const MAX_CODE_LEN: u32 = 15;

/// Compute Huffman code lengths for `freqs`, limited to `max_len` bits.
/// Symbols with zero frequency get length 0 (no code).
pub fn code_lengths(freqs: &[u32], max_len: u32) -> Vec<u8> {
    assert!(max_len <= MAX_CODE_LEN);
    let mut f: Vec<u64> = freqs.iter().map(|&x| x as u64).collect();
    loop {
        let lens = tree_lengths(&f);
        let deepest = lens.iter().copied().max().unwrap_or(0);
        if deepest as u32 <= max_len {
            return lens;
        }
        for x in f.iter_mut() {
            if *x > 0 {
                *x = *x / 2 + 1;
            }
        }
    }
}

/// Unlimited-depth Huffman code lengths via pairing on a min-heap.
fn tree_lengths(freqs: &[u64]) -> Vec<u8> {
    #[derive(PartialEq, Eq, PartialOrd, Ord)]
    struct Node {
        freq: u64,
        id: usize,
    }
    let n = freqs.len();
    let live: Vec<usize> = (0..n).filter(|&i| freqs[i] > 0).collect();
    let mut lens = vec![0u8; n];
    match live.len() {
        0 => return lens,
        1 => {
            // A single-symbol alphabet still needs one bit on the wire.
            lens[live[0]] = 1;
            return lens;
        }
        _ => {}
    }
    // parent pointers over a forest of at most 2n-1 nodes
    let mut parent = vec![usize::MAX; 2 * n];
    let mut next_id = n;
    let mut heap: BinaryHeap<Reverse<Node>> =
        live.iter().map(|&i| Reverse(Node { freq: freqs[i], id: i })).collect();
    while heap.len() > 1 {
        let a = heap.pop().unwrap().0;
        let b = heap.pop().unwrap().0;
        let id = next_id;
        next_id += 1;
        parent[a.id] = id;
        parent[b.id] = id;
        heap.push(Reverse(Node { freq: a.freq + b.freq, id }));
    }
    for &i in &live {
        let mut depth = 0u8;
        let mut node = i;
        while parent[node] != usize::MAX {
            node = parent[node];
            depth += 1;
        }
        lens[i] = depth;
    }
    lens
}

/// Canonical codes (MSB-first integers) from code lengths.
pub fn canonical_codes(lens: &[u8]) -> Vec<u32> {
    let max = lens.iter().copied().max().unwrap_or(0) as u32;
    let mut count = vec![0u32; max as usize + 1];
    for &l in lens {
        if l > 0 {
            count[l as usize] += 1;
        }
    }
    let mut next = vec![0u32; max as usize + 2];
    let mut code = 0u32;
    for len in 1..=max as usize {
        code = (code + count[len - 1]) << 1;
        next[len] = code;
    }
    lens.iter()
        .map(|&l| {
            if l == 0 {
                0
            } else {
                let c = next[l as usize];
                next[l as usize] += 1;
                c
            }
        })
        .collect()
}

/// Canonical Huffman encoder for one alphabet.
pub struct HuffEncoder {
    lens: Vec<u8>,
    codes: Vec<u32>,
}

impl HuffEncoder {
    pub fn from_freqs(freqs: &[u32], max_len: u32) -> Self {
        let lens = code_lengths(freqs, max_len);
        let codes = canonical_codes(&lens);
        HuffEncoder { lens, codes }
    }

    pub fn from_lengths(lens: Vec<u8>) -> Self {
        let codes = canonical_codes(&lens);
        HuffEncoder { lens, codes }
    }

    pub fn lengths(&self) -> &[u8] {
        &self.lens
    }

    /// Cost of symbol `s` in bits (0 = not encodable).
    #[inline]
    pub fn cost(&self, s: usize) -> u32 {
        self.lens[s] as u32
    }

    #[inline]
    pub fn encode(&self, w: &mut BitWriter, s: usize) {
        debug_assert!(self.lens[s] > 0, "symbol {s} has no code");
        w.write_bits(self.codes[s] as u64, self.lens[s] as u32);
    }
}

/// Table-driven canonical Huffman decoder.
pub struct HuffDecoder {
    /// For each length: (first_code, first_index, count).
    first_code: Vec<u32>,
    first_index: Vec<u32>,
    count: Vec<u32>,
    /// Symbols sorted by (length, symbol).
    symbols: Vec<u32>,
    max_len: u32,
}

impl HuffDecoder {
    pub fn from_lengths(lens: &[u8]) -> Result<Self> {
        let max = lens.iter().copied().max().unwrap_or(0) as u32;
        if max == 0 {
            anyhow::bail!("empty Huffman alphabet");
        }
        let mut count = vec![0u32; max as usize + 1];
        for &l in lens {
            if l > 0 {
                count[l as usize] += 1;
            }
        }
        // Kraft check: must be a valid (possibly incomplete) prefix code.
        let mut kraft: u64 = 0;
        for l in 1..=max as usize {
            kraft += (count[l] as u64) << (max as usize - l);
        }
        if kraft > 1u64 << max {
            anyhow::bail!("over-subscribed Huffman code");
        }
        let mut first_code = vec![0u32; max as usize + 2];
        let mut first_index = vec![0u32; max as usize + 2];
        let mut code = 0u32;
        let mut index = 0u32;
        for len in 1..=max as usize {
            code = (code + count[len - 1]) << 1;
            first_code[len] = code;
            first_index[len] = index;
            index += count[len];
        }
        let mut order: Vec<u32> = (0..lens.len() as u32).filter(|&s| lens[s as usize] > 0).collect();
        order.sort_by_key(|&s| (lens[s as usize], s));
        Ok(HuffDecoder { first_code, first_index, count, symbols: order, max_len: max })
    }

    /// Decode one symbol bit-by-bit (canonical walk).
    #[inline]
    pub fn decode(&self, r: &mut BitReader) -> Result<u32> {
        let mut code = 0u32;
        for len in 1..=self.max_len as usize {
            code = (code << 1) | r.read_bits(1) as u32;
            let count = self.count[len];
            if count > 0 && code >= self.first_code[len] && code < self.first_code[len] + count {
                let idx = self.first_index[len] + (code - self.first_code[len]);
                return Ok(self.symbols[idx as usize]);
            }
        }
        anyhow::bail!("invalid Huffman code")
    }
}

/// Serialize code lengths as 4-bit nibbles (for container headers).
pub fn pack_lengths(lens: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(lens.len().div_ceil(2));
    for pair in lens.chunks(2) {
        let hi = pair[0] & 0x0F;
        let lo = if pair.len() > 1 { pair[1] & 0x0F } else { 0 };
        out.push((hi << 4) | lo);
    }
    out
}

/// Inverse of [`pack_lengths`].
pub fn unpack_lengths(data: &[u8], n: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity(n);
    for &b in data {
        out.push(b >> 4);
        if out.len() < n {
            out.push(b & 0x0F);
        }
        if out.len() >= n {
            break;
        }
    }
    out.truncate(n);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn roundtrip(freqs: &[u32], stream: &[usize]) {
        let enc = HuffEncoder::from_freqs(freqs, MAX_CODE_LEN);
        let mut w = BitWriter::new();
        for &s in stream {
            enc.encode(&mut w, s);
        }
        let buf = w.finish();
        let dec = HuffDecoder::from_lengths(enc.lengths()).unwrap();
        let mut r = BitReader::new(&buf);
        for &s in stream {
            assert_eq!(dec.decode(&mut r).unwrap() as usize, s);
        }
    }

    #[test]
    fn roundtrip_uniform_alphabet() {
        let freqs = vec![10u32; 16];
        let mut rng = Pcg64::seeded(1);
        let stream: Vec<usize> = (0..5000).map(|_| rng.gen_index(16)).collect();
        roundtrip(&freqs, &stream);
    }

    #[test]
    fn roundtrip_skewed_alphabet() {
        let mut freqs = vec![0u32; 256];
        for (i, f) in freqs.iter_mut().enumerate() {
            *f = if i < 8 { 10_000 } else if i < 64 { 10 } else { 1 };
        }
        let mut rng = Pcg64::seeded(2);
        let stream: Vec<usize> =
            (0..5000).map(|_| if rng.gen_bool(0.9) { rng.gen_index(8) } else { rng.gen_index(256) }).collect();
        roundtrip(&freqs, &stream);
    }

    #[test]
    fn single_symbol_alphabet() {
        let mut freqs = vec![0u32; 10];
        freqs[3] = 100;
        roundtrip(&freqs, &vec![3usize; 100]);
    }

    #[test]
    fn two_symbol_alphabet() {
        let freqs = vec![1u32, 1];
        let lens = code_lengths(&freqs, 15);
        assert_eq!(lens, vec![1, 1]);
        roundtrip(&freqs, &[0, 1, 1, 0, 0, 0, 1]);
    }

    #[test]
    fn length_limit_is_respected() {
        // Fibonacci-like frequencies force deep trees without a limit.
        let mut freqs = vec![0u32; 32];
        let (mut a, mut b) = (1u32, 1u32);
        for f in freqs.iter_mut() {
            *f = a;
            let c = a.saturating_add(b);
            a = b;
            b = c;
        }
        let lens = code_lengths(&freqs, 15);
        assert!(lens.iter().all(|&l| l <= 15));
        // Still a valid prefix code (Kraft sum <= 1).
        let kraft: f64 = lens.iter().filter(|&&l| l > 0).map(|&l| 2f64.powi(-(l as i32))).sum();
        assert!(kraft <= 1.0 + 1e-12);
    }

    #[test]
    fn optimality_on_known_distribution() {
        // freqs 1,1,2,4 -> depths 3,3,2,1 (classic).
        let freqs = vec![1u32, 1, 2, 4];
        let lens = code_lengths(&freqs, 15);
        assert_eq!(lens, vec![3, 3, 2, 1]);
    }

    #[test]
    fn pack_unpack_lengths() {
        let lens: Vec<u8> = (0..33).map(|i| (i % 16) as u8).collect();
        let packed = pack_lengths(&lens);
        assert_eq!(unpack_lengths(&packed, lens.len()), lens);
    }

    #[test]
    fn oversubscribed_code_rejected() {
        // Three symbols with length 1 is invalid.
        assert!(HuffDecoder::from_lengths(&[1, 1, 1]).is_err());
    }

    #[test]
    fn decoder_rejects_garbage() {
        // lengths {2,2} leave half the code space unused; an all-ones stream
        // of sufficient depth must fail rather than loop forever.
        let dec = HuffDecoder::from_lengths(&[2, 2]).unwrap();
        let buf = vec![0xFF; 4];
        let mut r = BitReader::new(&buf);
        assert!(dec.decode(&mut r).is_err());
    }
}
