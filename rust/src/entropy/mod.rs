//! Entropy-coding substrate.
//!
//! Everything the paper's baselines and the LLM compressor need to turn
//! probability models into bits:
//!
//! * [`bitio`] — MSB-first bit reader/writer.
//! * [`range`] — byte-oriented carry-propagating range coder (LZMA-style),
//!   the backend for the LLM arithmetic coder, PPM and LZMA-lite.
//! * [`binary`] — adaptive binary arithmetic coder + 12-bit bit models,
//!   the backend for the context-mixing coders.
//! * [`huffman`] — canonical, length-limited Huffman coding.
//! * [`fse`] — tabled asymmetric numeral system (tANS), i.e. Finite State
//!   Entropy, Zstd's entropy stage.
//! * [`arith`] — order-0 static & adaptive arithmetic coders over bytes
//!   (the paper's "Arithmetic" baseline).

pub mod arith;
pub mod binary;
pub mod bitio;
pub mod fse;
pub mod huffman;
pub mod range;

pub use binary::{BinDecoder, BinEncoder, BitModel};
pub use bitio::{BitReader, BitWriter};
pub use range::{RangeDecoder, RangeEncoder};
