//! Byte-oriented range coder with carry propagation (LZMA lineage).
//!
//! This is the entropy backend of the paper's contribution: the LLM
//! compressor quantizes each next-token distribution to a cumulative
//! frequency table and feeds `(cum, freq, total)` triples to this coder.
//! It is also used by the PPM baseline and LZMA-lite.
//!
//! Invariants: `total <= 1 << 22` (so `range / total` never underflows the
//! 24-bit renormalization threshold) and `freq >= 1` for every encodable
//! symbol.

/// Renormalization threshold — top 8 bits flushed when range drops below it.
const TOP: u32 = 1 << 24;

/// Maximum supported cumulative total.
pub const MAX_TOTAL: u32 = 1 << 22;

/// Range encoder writing to an internal buffer.
pub struct RangeEncoder {
    low: u64,
    range: u32,
    cache: u8,
    cache_size: u64,
    out: Vec<u8>,
}

impl Default for RangeEncoder {
    fn default() -> Self {
        Self::new()
    }
}

impl RangeEncoder {
    pub fn new() -> Self {
        RangeEncoder { low: 0, range: u32::MAX, cache: 0, cache_size: 1, out: Vec::new() }
    }

    #[inline]
    fn shift_low(&mut self) {
        if self.low < 0xFF00_0000 || self.low > 0xFFFF_FFFF {
            let carry = (self.low >> 32) as u8;
            self.out.push(self.cache.wrapping_add(carry));
            for _ in 1..self.cache_size {
                self.out.push(0xFFu8.wrapping_add(carry));
            }
            self.cache = (self.low >> 24) as u8;
            self.cache_size = 0;
        }
        self.cache_size += 1;
        self.low = (self.low << 8) & 0xFFFF_FFFF;
    }

    /// Encode a symbol occupying `[cum, cum+freq)` out of `total`.
    #[inline]
    pub fn encode(&mut self, cum: u32, freq: u32, total: u32) {
        debug_assert!(freq > 0, "zero-frequency symbol");
        debug_assert!(cum + freq <= total);
        debug_assert!(total <= MAX_TOTAL);
        let r = self.range / total;
        self.low += r as u64 * cum as u64;
        self.range = r * freq;
        while self.range < TOP {
            self.shift_low();
            self.range <<= 8;
        }
    }

    /// Encode `n` raw bits (uniform distribution), MSB first. `n <= 30`.
    #[inline]
    pub fn encode_direct_bits(&mut self, value: u32, n: u32) {
        debug_assert!(n <= 30);
        for i in (0..n).rev() {
            let bit = (value >> i) & 1;
            self.range >>= 1;
            self.low += self.range as u64 * bit as u64;
            while self.range < TOP {
                self.shift_low();
                self.range <<= 8;
            }
        }
    }

    /// Flush and return the encoded bytes.
    pub fn finish(mut self) -> Vec<u8> {
        for _ in 0..5 {
            self.shift_low();
        }
        self.out
    }

    /// Bytes emitted so far (an underestimate until `finish`).
    pub fn len(&self) -> usize {
        self.out.len()
    }

    pub fn is_empty(&self) -> bool {
        self.out.is_empty()
    }
}

/// Range decoder over an encoded byte slice.
pub struct RangeDecoder<'a> {
    code: u32,
    range: u32,
    data: &'a [u8],
    pos: usize,
}

impl<'a> RangeDecoder<'a> {
    pub fn new(data: &'a [u8]) -> Self {
        // First byte emitted by the encoder is the initial (zero) cache.
        let mut d = RangeDecoder { code: 0, range: u32::MAX, data, pos: 1 };
        for _ in 0..4 {
            d.code = (d.code << 8) | d.next_byte() as u32;
        }
        d
    }

    #[inline]
    fn next_byte(&mut self) -> u8 {
        let b = if self.pos < self.data.len() { self.data[self.pos] } else { 0 };
        self.pos += 1;
        b
    }

    /// First decode phase: return a value in `[0, total)`; the caller maps it
    /// to a symbol via its cumulative table then calls [`Self::decode_update`].
    #[inline]
    pub fn decode_freq(&mut self, total: u32) -> u32 {
        debug_assert!(total <= MAX_TOTAL);
        self.range /= total;
        (self.code / self.range).min(total - 1)
    }

    /// Second decode phase: commit the symbol `[cum, cum+freq)`.
    #[inline]
    pub fn decode_update(&mut self, cum: u32, freq: u32) {
        self.code -= cum * self.range;
        self.range *= freq;
        while self.range < TOP {
            self.code = (self.code << 8) | self.next_byte() as u32;
            self.range <<= 8;
        }
    }

    /// Decode `n` raw bits written by [`RangeEncoder::encode_direct_bits`].
    #[inline]
    pub fn decode_direct_bits(&mut self, n: u32) -> u32 {
        let mut value = 0u32;
        for _ in 0..n {
            self.range >>= 1;
            let bit = if self.code >= self.range {
                self.code -= self.range;
                1
            } else {
                0
            };
            value = (value << 1) | bit;
            while self.range < TOP {
                self.code = (self.code << 8) | self.next_byte() as u32;
                self.range <<= 8;
            }
        }
        value
    }

    /// Bytes consumed so far.
    pub fn consumed(&self) -> usize {
        self.pos.min(self.data.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    /// Encode/decode a symbol stream against a fixed frequency table.
    fn roundtrip_with_freqs(symbols: &[usize], freqs: &[u32]) {
        let total: u32 = freqs.iter().sum();
        let mut cums = vec![0u32; freqs.len() + 1];
        for i in 0..freqs.len() {
            cums[i + 1] = cums[i] + freqs[i];
        }
        let mut enc = RangeEncoder::new();
        for &s in symbols {
            enc.encode(cums[s], freqs[s], total);
        }
        let buf = enc.finish();
        let mut dec = RangeDecoder::new(&buf);
        for &s in symbols {
            let f = dec.decode_freq(total);
            let sym = cums.partition_point(|&c| c <= f) - 1;
            assert_eq!(sym, s);
            dec.decode_update(cums[sym], freqs[sym]);
        }
    }

    #[test]
    fn roundtrip_uniform() {
        let mut rng = Pcg64::seeded(1);
        let freqs = vec![1u32; 256];
        let syms: Vec<usize> = (0..10_000).map(|_| rng.gen_index(256)).collect();
        roundtrip_with_freqs(&syms, &freqs);
    }

    #[test]
    fn roundtrip_skewed() {
        let mut rng = Pcg64::seeded(2);
        let freqs: Vec<u32> = (0..16).map(|i| 1 << i).collect(); // heavy skew
        let total: u32 = freqs.iter().sum();
        let syms: Vec<usize> = (0..10_000)
            .map(|_| {
                let mut t = rng.gen_range(total as u64) as u32;
                for (i, &f) in freqs.iter().enumerate() {
                    if t < f {
                        return i;
                    }
                    t -= f;
                }
                freqs.len() - 1
            })
            .collect();
        roundtrip_with_freqs(&syms, &freqs);
    }

    #[test]
    fn roundtrip_large_total() {
        // 16-bit quantized CDF like the LLM coder uses.
        let mut rng = Pcg64::seeded(3);
        let mut freqs = vec![1u32; 300];
        freqs[7] = 60_000; // one dominant token
        let syms: Vec<usize> =
            (0..5_000).map(|_| if rng.gen_bool(0.9) { 7 } else { rng.gen_index(300) }).collect();
        roundtrip_with_freqs(&syms, &freqs);
    }

    #[test]
    fn skewed_stream_is_small() {
        // A 99%-probable symbol should code well under 1 bit each.
        let freqs = vec![990u32, 10];
        let syms = vec![0usize; 10_000];
        let total: u32 = freqs.iter().sum();
        let mut enc = RangeEncoder::new();
        for &s in &syms {
            enc.encode(if s == 0 { 0 } else { 990 }, freqs[s], total);
        }
        let buf = enc.finish();
        // Entropy is ~0.0145 bits/symbol => ~18 bytes + overhead.
        assert!(buf.len() < 60, "len {}", buf.len());
    }

    #[test]
    fn direct_bits_roundtrip() {
        let mut rng = Pcg64::seeded(4);
        let values: Vec<(u32, u32)> = (0..2000)
            .map(|_| {
                let n = 1 + rng.gen_index(24) as u32;
                (rng.next_u32() & ((1 << n) - 1), n)
            })
            .collect();
        let mut enc = RangeEncoder::new();
        for &(v, n) in &values {
            enc.encode_direct_bits(v, n);
        }
        let buf = enc.finish();
        let mut dec = RangeDecoder::new(&buf);
        for &(v, n) in &values {
            assert_eq!(dec.decode_direct_bits(n), v);
        }
    }

    #[test]
    fn mixed_symbols_and_direct_bits() {
        let mut rng = Pcg64::seeded(5);
        let freqs = [5u32, 10, 1, 100];
        let cums = [0u32, 5, 15, 16];
        let total = 116;
        let ops: Vec<(bool, u32)> = (0..4000)
            .map(|_| {
                if rng.gen_bool(0.5) {
                    (true, rng.gen_index(4) as u32)
                } else {
                    (false, rng.next_u32() & 0xFFF)
                }
            })
            .collect();
        let mut enc = RangeEncoder::new();
        for &(is_sym, v) in &ops {
            if is_sym {
                enc.encode(cums[v as usize], freqs[v as usize], total);
            } else {
                enc.encode_direct_bits(v, 12);
            }
        }
        let buf = enc.finish();
        let mut dec = RangeDecoder::new(&buf);
        for &(is_sym, v) in &ops {
            if is_sym {
                let f = dec.decode_freq(total);
                let sym = (0..4).find(|&s| f < cums[s] + freqs[s]).unwrap();
                assert_eq!(sym as u32, v);
                dec.decode_update(cums[sym], freqs[sym]);
            } else {
                assert_eq!(dec.decode_direct_bits(12), v);
            }
        }
    }

    #[test]
    fn empty_stream() {
        let enc = RangeEncoder::new();
        let buf = enc.finish();
        assert_eq!(buf.len(), 5);
        let _ = RangeDecoder::new(&buf); // must not panic
    }
}
