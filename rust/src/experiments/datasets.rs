//! Evaluation-dataset management.
//!
//! *LLM-generated* datasets are sampled from a trained model (the paper's
//! §5.1.1 datasets are all LLM output) and cached under `data/`. *Human*
//! datasets are procedural-generator output with a seed disjoint from the
//! training corpus seed (same distribution family, unseen specifics — the
//! analog of held-out human text).

use crate::runtime::ArtifactStore;
use crate::sampling::DatasetFactory;
use crate::textgen::{self, Domain};
use crate::util::Pcg64;
use crate::Result;
use std::collections::HashMap;
use std::path::PathBuf;

/// Seed disjoint from the training corpus (`make corpus` uses seed 1).
pub const HELD_OUT_SEED: u64 = 4242;
/// The dataset-generating model. Deliberately NOT one of the evaluation
/// models (the paper's datasets come from GPT-3.5/4/Mixtral while the
/// compressors are Llama/Qwen — no model compresses its own samples).
pub const GENERATOR_MODEL: &str = "teacher";
/// Default sampling temperature for the LLM datasets (paper's models decode
/// around this regime; 0.6 keeps our small models on-distribution).
pub const DATASET_TEMP: f64 = 0.6;

/// Held-out "human" text for a domain (never seen in training).
pub fn human_text(domain: Domain, bytes: usize) -> Vec<u8> {
    textgen::generate(domain, bytes, HELD_OUT_SEED)
}

/// Held-out human movie reviews in the colloquial imdb register (Fig 9).
pub fn imdb_text(bytes: usize) -> Vec<u8> {
    let mut rng = Pcg64::new(HELD_OUT_SEED, 77);
    let mut out = Vec::with_capacity(bytes + 256);
    while out.len() < bytes {
        out.extend_from_slice(textgen::web::imdb_style(&mut rng).as_bytes());
        out.push(b'\n');
    }
    out.truncate(bytes);
    out
}

/// Generate (or load from the on-disk cache) one LLM dataset.
pub fn llm_dataset(
    store: &ArtifactStore,
    cache_dir: &str,
    model: &str,
    domain: Domain,
    bytes: usize,
) -> Result<Vec<u8>> {
    std::fs::create_dir_all(cache_dir)?;
    let path = PathBuf::from(cache_dir).join(format!("{}_{}.txt", model, domain.name()));
    if let Ok(data) = std::fs::read(&path) {
        if data.len() >= bytes {
            return Ok(data[..bytes].to_vec());
        }
    }
    let factory = DatasetFactory::from_store(store, model)?;
    let data = factory.generate_dataset(domain, bytes, DATASET_TEMP, 42)?;
    std::fs::write(&path, &data)?;
    Ok(data)
}

/// In-memory cache of LLM datasets keyed by (model, domain).
pub struct DatasetCache {
    store: ArtifactStore,
    cache_dir: String,
    bytes: usize,
    mem: HashMap<(String, Domain), Vec<u8>>,
}

impl DatasetCache {
    pub fn new(store: ArtifactStore, cache_dir: &str, bytes: usize) -> Self {
        DatasetCache { store, cache_dir: cache_dir.to_string(), bytes, mem: HashMap::new() }
    }

    pub fn store(&self) -> &ArtifactStore {
        &self.store
    }

    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// The LLM dataset for `(model, domain)`, generated on first use.
    pub fn get(&mut self, model: &str, domain: Domain) -> Result<&[u8]> {
        let key = (model.to_string(), domain);
        if !self.mem.contains_key(&key) {
            let data = llm_dataset(&self.store, &self.cache_dir, model, domain, self.bytes)?;
            self.mem.insert(key.clone(), data);
        }
        Ok(self.mem.get(&key).unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_text_differs_from_training_corpus() {
        let held_out = human_text(Domain::Wiki, 4000);
        let training = textgen::generate(Domain::Wiki, 4000, 1);
        assert_ne!(held_out, training);
    }

    #[test]
    fn imdb_register() {
        let text = String::from_utf8(imdb_text(3000)).unwrap();
        assert!(text.contains("/10 from me"));
        assert_eq!(text.len(), 3000);
    }
}
