//! Experiment drivers that regenerate every table and figure of the paper's
//! evaluation (§5). Shared by the CLI (`llmzip table5` etc.) and the bench
//! harness. Results are returned structurally and printed as aligned
//! tables; EXPERIMENTS.md records paper-vs-measured for each.

pub mod datasets;
pub mod tables;

pub use datasets::{human_text, llm_dataset, DatasetCache, GENERATOR_MODEL};
pub use tables::*;

/// Print an aligned table: header row + data rows.
pub fn print_table(title: &str, header: &[String], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let ncol = header.len();
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let fmt_row = |cells: &[String]| {
        let mut line = String::new();
        for i in 0..ncol {
            let cell = cells.get(i).map(String::as_str).unwrap_or("");
            line.push_str(&format!("{:<width$}  ", cell, width = widths[i]));
        }
        println!("{}", line.trim_end());
    };
    fmt_row(header);
    for row in rows {
        fmt_row(row);
    }
}
