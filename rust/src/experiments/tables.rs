//! One function per paper table/figure. Each returns `(header, rows)` for
//! [`super::print_table`] and is exercised end-to-end by the CLI and the
//! bench harness.

use crate::analysis::{self, EntropyReport};
use crate::compress::registry::{all_baselines, baseline_by_name};
use crate::compress::{Compressor, LlmCompressor, LlmCompressorConfig};
use crate::experiments::datasets::{human_text, imdb_text, DatasetCache, GENERATOR_MODEL};
use crate::lm::ExecutorKind;
use crate::textgen::Domain;
use crate::Result;

pub type Table = (Vec<String>, Vec<Vec<String>>);

fn s(v: impl ToString) -> String {
    v.to_string()
}

fn f2(x: f64) -> String {
    format!("{x:.2}")
}

/// Open an LLM compressor for experiments (PJRT forward engine).
pub fn open_llm(cache: &DatasetCache, model: &str, chunk: usize) -> Result<LlmCompressor> {
    LlmCompressor::open(
        cache.store(),
        LlmCompressorConfig {
            model: model.to_string(),
            chunk_tokens: chunk,
            stream_bytes: 4096.max(chunk),
            executor: ExecutorKind::PjrtForward,
            ..Default::default()
        },
    )
}

fn ratio_of(c: &dyn Compressor, data: &[u8]) -> Result<f64> {
    let z = c.compress(data)?;
    Ok(data.len() as f64 / z.len() as f64)
}

/// Table 2: Char-E / BP-E / W-E / Mutual Info for LLM-generated wiki text,
/// held-out human wiki text, and TPC-H comments.
pub fn table2(cache: &mut DatasetCache, model: &str) -> Result<Table> {
    let _ = model;
    let bytes = cache.bytes();
    let llm = cache.get(GENERATOR_MODEL, Domain::Wiki)?.to_vec();
    let rows: Vec<(&str, Vec<u8>)> = vec![
        ("LLM-Generated", llm),
        ("Human-Generated", human_text(Domain::Wiki, bytes)),
        ("TPC-H", human_text(Domain::Tpch, bytes)),
    ];
    let header = vec![s("Dataset"), s("Char-E"), s("BP-E"), s("W-E"), s("Mutual Info")];
    let mut out = Vec::new();
    for (name, data) in rows {
        let text = String::from_utf8_lossy(&data).into_owned();
        let r = EntropyReport::measure(&text);
        out.push(vec![s(name), f2(r.char_e), f2(r.bpe_e), f2(r.word_e), f2(r.mutual_info)]);
    }
    Ok((header, out))
}

/// Table 3: the six strongest traditional/neural baselines on Wiki/Code/Math.
pub fn table3(cache: &mut DatasetCache, model: &str) -> Result<Table> {
    let _ = model;
    let domains = [Domain::Wiki, Domain::Code, Domain::Math];
    let methods = ["gzip", "lzma", "zstd", "nncp", "trace", "pac"];
    let mut header = vec![s("Dataset")];
    header.extend(methods.iter().map(|m| s(paper_name(m))));
    let mut rows = Vec::new();
    for d in domains {
        let data = cache.get(GENERATOR_MODEL, d)?.to_vec();
        let mut row = vec![s(capitalize(d.name()))];
        for m in methods {
            let c = baseline_by_name(m)?;
            row.push(f2(ratio_of(c.as_ref(), &data)?));
        }
        rows.push(row);
    }
    Ok((header, rows))
}

/// Table 5: all nine baselines + Ours on all eight datasets.
pub fn table5(cache: &mut DatasetCache, model: &str, chunk: usize) -> Result<Table> {
    let mut header = vec![s("Method")];
    header.extend(Domain::EVAL.iter().map(|d| s(capitalize(d.name()))));
    let mut rows = Vec::new();
    // Pre-generate all datasets once.
    let mut data: Vec<Vec<u8>> = Vec::new();
    for d in Domain::EVAL {
        data.push(cache.get(GENERATOR_MODEL, d)?.to_vec());
    }
    for c in all_baselines()? {
        let mut row = vec![s(paper_name(c.name()))];
        for d in &data {
            row.push(f2(ratio_of(c.as_ref(), d)?));
        }
        rows.push(row);
    }
    let ours = open_llm(cache, model, chunk)?;
    let mut row = vec![s("Ours")];
    for d in &data {
        row.push(f2(ratio_of(&ours, d)?));
    }
    rows.push(row);
    Ok((header, rows))
}

/// Fig 2: top-10 n-gram coverage share (n = 1..4) per domain.
pub fn fig2(cache: &mut DatasetCache, model: &str) -> Result<Table> {
    let _ = model;
    let domains = [Domain::Clinical, Domain::Code, Domain::Math];
    let header =
        vec![s("Dataset"), s("top10 1-gram %"), s("2-gram %"), s("3-gram %"), s("4-gram %")];
    let mut rows = Vec::new();
    for d in domains {
        let data = cache.get(GENERATOR_MODEL, d)?.to_vec();
        let text = String::from_utf8_lossy(&data);
        let shares = analysis::top_k_share(&text, 10);
        let mut row = vec![s(capitalize(d.name()))];
        row.extend(shares.iter().map(|&x| f2(x * 100.0)));
        rows.push(row);
    }
    Ok((header, rows))
}

/// Fig 5: base-vs-instruct across the Llama-tier ladder, all datasets.
pub fn fig5(cache: &mut DatasetCache, chunk: usize) -> Result<Table> {
    let models =
        ["tiny", "tiny-instruct", "small", "small-instruct", "medium", "medium-instruct"];
    model_by_domain(cache, &models, &Domain::EVAL, chunk)
}

/// Fig 6: model scale vs compression ratio (the size ladder).
pub fn fig6(cache: &mut DatasetCache, chunk: usize) -> Result<Table> {
    let models = ["nano", "tiny", "small", "medium", "large"];
    let domains = [Domain::Wiki, Domain::Web, Domain::Science, Domain::Novel];
    let (header, mut rows) = model_by_domain(cache, &models, &domains, chunk)?;
    // Append parameter counts for the scale axis.
    for (row, m) in rows.iter_mut().zip(models) {
        let cfg = crate::lm::config::by_name(m)?;
        row[0] = format!("{m} ({}K params)", cfg.param_count() / 1000);
    }
    Ok((header, rows))
}

/// Fig 7: compression ratio vs dataset scale on Wiki.
pub fn fig7(cache: &mut DatasetCache, model: &str, chunk: usize) -> Result<Table> {
    let full = cache.get(GENERATOR_MODEL, Domain::Wiki)?.to_vec();
    let max = full.len();
    let sizes: Vec<usize> =
        [max / 16, max / 8, max / 4, max / 2, max].into_iter().filter(|&n| n >= 4096).collect();
    let methods = ["huffman", "arithmetic", "fse", "gzip", "lzma", "zstd", "trace", "pac"];
    let mut header = vec![s("Size")];
    header.extend(methods.iter().map(|m| s(paper_name(m))));
    header.push(s("Ours"));
    let ours = open_llm(cache, model, chunk)?;
    let mut rows = Vec::new();
    for &n in &sizes {
        let slice = &full[..n];
        let mut row = vec![crate::util::human_bytes(n as u64)];
        for m in methods {
            let c = baseline_by_name(m)?;
            row.push(f2(ratio_of(c.as_ref(), slice)?));
        }
        row.push(f2(ratio_of(&ours, slice)?));
        rows.push(row);
    }
    Ok((header, rows))
}

/// Fig 8: domain-specialist models on Math and Code.
pub fn fig8(cache: &mut DatasetCache, chunk: usize) -> Result<Table> {
    let header = vec![s("Model"), s("Math"), s("Code")];
    let models = ["tiny", "small", "small-math", "small-code", "medium", "large"];
    let math = cache.get(GENERATOR_MODEL, Domain::Math)?.to_vec();
    let code = cache.get(GENERATOR_MODEL, Domain::Code)?.to_vec();
    let mut rows = Vec::new();
    for m in models {
        let ours = open_llm(cache, m, chunk)?;
        rows.push(vec![s(m), f2(ratio_of(&ours, &math)?), f2(ratio_of(&ours, &code)?)]);
    }
    Ok((header, rows))
}

/// Fig 9: LLM-generated vs human movie reviews across chunk sizes.
pub fn fig9(cache: &mut DatasetCache, model: &str) -> Result<Table> {
    let chunks = [16usize, 32, 64, 128, 256];
    let llm = cache.get(GENERATOR_MODEL, Domain::Web)?.to_vec();
    let human = imdb_text(cache.bytes());
    let mut header = vec![s("Data")];
    header.extend(chunks.iter().map(|c| format!("chunk {c}")));
    let mut rows = Vec::new();
    for (name, data) in [("LLM-generated", &llm), ("Human (imdb)", &human)] {
        let mut row = vec![s(name)];
        for &c in &chunks {
            let ours = open_llm(cache, model, c)?;
            row.push(f2(ratio_of(&ours, data)?));
        }
        rows.push(row);
    }
    Ok((header, rows))
}

/// §5.4 chunk-size sweep: ratio vs chunk size per model.
pub fn chunk_sweep(cache: &mut DatasetCache, domain: Domain) -> Result<Table> {
    let chunks = [16usize, 32, 64, 128, 256];
    let models =
        ["tiny", "tiny-instruct", "small", "small-instruct", "medium", "medium-instruct"];
    let data = cache.get(GENERATOR_MODEL, domain)?.to_vec();
    let mut header = vec![s("Model")];
    header.extend(chunks.iter().map(|c| format!("chunk {c}")));
    let mut rows = Vec::new();
    for m in models {
        let mut row = vec![s(m)];
        for &c in &chunks {
            let ours = open_llm(cache, m, c)?;
            row.push(f2(ratio_of(&ours, &data)?));
        }
        rows.push(row);
    }
    Ok((header, rows))
}

/// Shared: models x domains ratio matrix.
fn model_by_domain(
    cache: &mut DatasetCache,
    models: &[&str],
    domains: &[Domain],
    chunk: usize,
) -> Result<Table> {
    let mut header = vec![s("Model")];
    header.extend(domains.iter().map(|d| s(capitalize(d.name()))));
    // Datasets come from the teacher model (the paper compresses the same
    // GPT/Mixtral-generated files with every evaluation LLM).
    let mut data = Vec::new();
    for &d in domains {
        data.push(cache.get(GENERATOR_MODEL, d)?.to_vec());
    }
    let mut rows = Vec::new();
    for &m in models {
        let ours = open_llm(cache, m, chunk)?;
        let mut row = vec![s(m)];
        for d in &data {
            row.push(f2(ratio_of(&ours, d)?));
        }
        rows.push(row);
    }
    Ok((header, rows))
}

/// Map internal baseline ids to the paper's method names.
pub fn paper_name(id: &str) -> &'static str {
    match id {
        "huffman" => "Huffman",
        "arithmetic" => "Arithmetic",
        "fse" => "FSE",
        "gzip" => "Gzip",
        "lzma" => "LZMA",
        "zstd" => "Zstd-22",
        "nncp" => "NNCP",
        "trace" => "TRACE",
        "pac" => "PAC",
        "llm" => "Ours",
        _ => "?",
    }
}

fn capitalize(x: &str) -> String {
    let mut c = x.chars();
    match c.next() {
        Some(f) => f.to_uppercase().to_string() + c.as_str(),
        None => String::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_names_cover_registry() {
        for id in crate::compress::registry::BASELINE_NAMES {
            assert_ne!(paper_name(id), "?", "{id}");
        }
    }
}
