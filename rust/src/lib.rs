//! # llmzip — lossless compression of LLM-generated text via next-token prediction
//!
//! Reproduction of *"Lossless Compression of Large Language Model-Generated
//! Text via Next-Token Prediction"* (Mao, Pirk, Xue — CS.LG 2025) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **L1** — Pallas kernels (build-time Python, `python/compile/kernels/`)
//! * **L2** — JAX byte-level transformer (build-time Python, lowered to HLO
//!   text artifacts under `artifacts/`)
//! * **L3** — this crate: the request-path coordinator, PJRT runtime,
//!   arithmetic coder, all nine baseline compressors, the procedural corpus
//!   generators, the dataset factory and the analysis toolkit.
//!
//! The public entry points are [`compress::Compressor`] (the trait every
//! compressor in the paper's Table 5 implements), [`compress::LlmCompressor`]
//! (the paper's contribution), its streaming faces
//! [`compress::stream::CompressWriter`] / [`compress::stream::DecompressReader`]
//! (incremental `std::io` sessions, byte-identical to the one-shot calls),
//! and [`coordinator::Server`] (the batched compression service: ticketed
//! async submits, incremental streams, and a multiplexed TCP protocol in
//! [`coordinator::wire`]).
//!
//! See `DESIGN.md` for the full system inventory and `EXPERIMENTS.md` for the
//! paper-vs-measured record of every table and figure.

pub mod analysis;
pub mod baselines;
pub mod compress;
pub mod coordinator;
pub mod entropy;
pub mod experiments;
pub mod lm;
pub mod runtime;
pub mod sampling;
pub mod textgen;
pub mod tokenizer;
pub mod util;

/// Crate-wide result type (thin alias over `anyhow`).
pub type Result<T> = anyhow::Result<T>;
