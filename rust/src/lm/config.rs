//! Model registry — MUST mirror `python/compile/configs.py` (the AOT side
//! owns training; this side owns serving). A mismatch is caught at weights
//! load time via shape checks against the manifest.

use crate::Result;

/// Vocabulary size (256 bytes + specials; see `tokenizer::vocab`).
pub const VOCAB: usize = 272;
/// Rows of the weight-tied output head that are ever range-coded: only the
/// 256 raw byte symbols feed `logits_to_cdf`. The compressor's native
/// engine restricts the head matvec to these rows (specials are fed as
/// inputs but never predicted), which is bit-identical on the coded region.
pub const CODED_BYTES: usize = 256;
/// Maximum context length = maximum chunk size (paper §5.4 sweeps up to 256).
pub const MAX_CONTEXT: usize = 256;

/// Batch shapes the HLO artifacts were lowered with
/// (`python/compile/configs.py`).
pub const FORWARD_BATCH: usize = 8;
pub const STEP_BATCH: usize = 32;
pub const GEN_BATCH: usize = 16;
pub const GEN_PROMPT: usize = 16;
pub const GEN_TOKENS: usize = 240;

/// One model variant.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LmConfig {
    pub name: &'static str,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    /// Which paper model this tier stands in for (DESIGN.md §6).
    pub simulates: &'static str,
}

impl LmConfig {
    pub const fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub const fn d_ff(&self) -> usize {
        4 * self.d_model
    }

    /// Total parameter count (embed + blocks + final norm).
    pub fn param_count(&self) -> usize {
        let d = self.d_model;
        let per_block = 4 * d * d + 2 * d * (4 * d) + 2 * d;
        VOCAB * d + self.n_layers * per_block + d
    }

    /// ALiBi slope for head `h` (2^(-8(h+1)/H)).
    pub fn alibi_slope(&self, head: usize) -> f32 {
        (2.0f32).powf(-8.0 * (head as f32 + 1.0) / self.n_heads as f32)
    }
}

/// All registered models, in registry order (matches DESIGN.md §6 table).
pub const MODELS: [LmConfig; 11] = [
    LmConfig { name: "nano", d_model: 32, n_layers: 1, n_heads: 2,
               simulates: "OpenELM-1.1B / AMD-OLMo-1B tier" },
    LmConfig { name: "tiny", d_model: 48, n_layers: 2, n_heads: 2,
               simulates: "Llama-3.2-1B" },
    LmConfig { name: "tiny-instruct", d_model: 48, n_layers: 2, n_heads: 2,
               simulates: "Llama-3.2-1B-Instruct" },
    LmConfig { name: "small", d_model: 64, n_layers: 2, n_heads: 4,
               simulates: "Llama-3.2-3B" },
    LmConfig { name: "small-instruct", d_model: 64, n_layers: 2, n_heads: 4,
               simulates: "Llama-3.2-3B-Instruct" },
    LmConfig { name: "small-math", d_model: 64, n_layers: 2, n_heads: 4,
               simulates: "Qwen2.5-Math-1.5B / Rho-Math-1B" },
    LmConfig { name: "small-code", d_model: 64, n_layers: 2, n_heads: 4,
               simulates: "Qwen2.5-Coder-1.5B / DeepSeek-Coder-1.3B" },
    LmConfig { name: "medium", d_model: 96, n_layers: 3, n_heads: 4,
               simulates: "Llama-3.1-8B (default)" },
    LmConfig { name: "teacher", d_model: 112, n_layers: 3, n_heads: 4,
               simulates: "the data-generating LLMs (GPT-3.5/4, Mixtral)" },
    LmConfig { name: "medium-instruct", d_model: 96, n_layers: 3, n_heads: 4,
               simulates: "Llama-3.1-8B-Instruct" },
    LmConfig { name: "large", d_model: 128, n_layers: 4, n_heads: 4,
               simulates: "Qwen2.5-14B(-Instruct-1M)" },
];

/// Look a model up by name.
pub fn by_name(name: &str) -> Result<&'static LmConfig> {
    MODELS
        .iter()
        .find(|m| m.name == name)
        .ok_or_else(|| anyhow::anyhow!("unknown model '{name}' (see `llmzip models`)"))
}

/// The canonical parameter order: (name, shape) sorted by name — identical
/// to `python/compile/model.py::param_spec`.
pub fn param_spec(cfg: &LmConfig) -> Vec<(String, Vec<usize>)> {
    let d = cfg.d_model;
    let ff = cfg.d_ff();
    let mut spec: Vec<(String, Vec<usize>)> =
        vec![("embed".into(), vec![VOCAB, d]), ("final_norm".into(), vec![d])];
    for i in 0..cfg.n_layers {
        let p = format!("layer{i:02}.");
        spec.push((format!("{p}attn_norm"), vec![d]));
        spec.push((format!("{p}mlp_norm"), vec![d]));
        spec.push((format!("{p}wq"), vec![d, d]));
        spec.push((format!("{p}wk"), vec![d, d]));
        spec.push((format!("{p}wv"), vec![d, d]));
        spec.push((format!("{p}wo"), vec![d, d]));
        spec.push((format!("{p}w1"), vec![d, ff]));
        spec.push((format!("{p}w2"), vec![ff, d]));
    }
    spec.sort_by(|a, b| a.0.cmp(&b.0));
    spec
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_lookup() {
        assert_eq!(by_name("medium").unwrap().d_model, 96);
        assert!(by_name("gpt5").is_err());
    }

    #[test]
    fn param_counts_scale_with_tier() {
        let sizes: Vec<usize> = ["nano", "tiny", "small", "medium", "large"]
            .iter()
            .map(|n| by_name(n).unwrap().param_count())
            .collect();
        for w in sizes.windows(2) {
            assert!(w[1] > w[0], "{sizes:?} must be increasing");
        }
    }

    #[test]
    fn spec_is_sorted_and_complete() {
        let cfg = by_name("medium").unwrap();
        let spec = param_spec(cfg);
        let mut names: Vec<&str> = spec.iter().map(|(n, _)| n.as_str()).collect();
        let sorted = {
            let mut s = names.clone();
            s.sort();
            s
        };
        assert_eq!(names, sorted);
        assert!(names.contains(&"embed"));
        assert!(names.contains(&"layer02.w2"));
        names.dedup();
        assert_eq!(names.len(), spec.len(), "no duplicate names");
        let total: usize = spec.iter().map(|(_, s)| s.iter().product::<usize>()).sum();
        assert_eq!(total, cfg.param_count());
    }

    #[test]
    fn alibi_slopes_decay() {
        let cfg = by_name("small").unwrap();
        let s: Vec<f32> = (0..4).map(|h| cfg.alibi_slope(h)).collect();
        assert!((s[0] - 0.25).abs() < 1e-6);
        for w in s.windows(2) {
            assert!(w[1] < w[0]);
        }
    }

    #[test]
    fn heads_divide_dims() {
        for m in &MODELS {
            assert_eq!(m.d_model % m.n_heads, 0, "{}", m.name);
        }
    }
}
